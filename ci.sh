#!/usr/bin/env bash
# CI gate for the CompAir repo. Run from the repository root:
#
#     ./ci.sh            # full gate
#     ./ci.sh --fast     # skip the doc and fmt passes
#
# Steps (each must pass):
#   1. cargo build --release        — the crate and all targets compile
#   2. cargo test -q                — unit + integration tests (tier-1)
#   3. --format json gate           — one simulate + one list invocation must
#                                     parse with `python3 -m json.tool`
#   4. NoC calibration self-check   — the noc-calibration figure's calibrated
#                                     error must be <= 20% at every anchor
#   5. pool determinism gate        — `figures --jobs 4 --format json` must be
#                                     byte-identical to `--jobs 1`
#   5b. mapping never-lose gate     — every `r=` marker in the mapping-search
#                                     figure must be <= 1 (searched placement
#                                     never beats static), and its --jobs 4
#                                     output must equal --jobs 1
#   5c. static verifier gate        — `compair check --format json` must report
#                                     zero error diagnostics over every shipped
#                                     (arch, model) point, and its --jobs 4
#                                     output must equal --jobs 1
#   5d. semantic audit gate         — `compair audit --format json` must report
#                                     zero invariant violations over the pow2
#                                     point lattice (conservation, monotonicity,
#                                     coherence, fidelity bands), and its
#                                     --jobs 4 output must equal --jobs 1
#   5e. cost-expression proof gate  — `compair prove --format json` must report
#                                     zero failed proof obligations (units,
#                                     monotonicity, overflow headroom, pricing
#                                     coverage, eval drift) with every point
#                                     certified completely, and its --jobs 4
#                                     output must equal --jobs 1
#   6. bench artifacts gate         — bench_hotpath runs in fast mode and both
#                                     BENCH_serving.json / BENCH_parallel.json
#                                     must parse
#   7. cargo clippy --all-targets   — lints with warnings denied
#   8. cargo doc --no-deps          — rustdoc with warnings denied
#   9. cargo fmt --check            — formatting (skipped if rustfmt absent)
#  10. python tests                 — kernel/model oracles (skipped without jax)
#
# A missing `cargo` is a hard failure, never a silent skip: a gate that
# checked nothing must not look green.
#
# PJRT-dependent tests self-skip when built without the `pjrt` feature; see
# rust/Cargo.toml for how to enable it with a vendored xla crate.
set -euo pipefail
cd "$(dirname "$0")"

FAST=0
[[ "${1:-}" == "--fast" ]] && FAST=1

say() { printf '\n== %s ==\n' "$*"; }

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH — install a Rust toolchain (rustup.rs)" >&2
    echo "       or enter the image that bakes one in; nothing was checked." >&2
    exit 1
fi

say "cargo build --release"
cargo build --release

say "cargo test -q"
cargo test -q

say "JSON report gate (--format json must parse)"
# every subcommand routes through the hand-rolled util/json.rs writer; one
# simulate and one list invocation must produce parseable documents
SIM_JSON=$(./target/release/compair simulate --arch compair-opt --model tiny --batch 2 --seqlen 256 --format json)
LIST_JSON=$(./target/release/compair list --format json)
if command -v python3 >/dev/null 2>&1; then
    printf '%s\n' "$SIM_JSON" | python3 -m json.tool >/dev/null
    printf '%s\n' "$LIST_JSON" | python3 -m json.tool >/dev/null
    echo "ok: simulate + list --format json parse"
else
    echo "error: python3 not found — the JSON gate cannot validate anything," >&2
    echo "       and a gate that checked nothing must not look green." >&2
    exit 1
fi

say "NoC calibration self-check (calibrated error <= 20% per anchor)"
# the noc-calibration figure prices every collective anchor through the
# analytic, simulated and calibrated tiers; the only %-formatted column is
# the calibrated-vs-simulated residual, which must stay within the 20%
# contract the serving numbers rely on
CAL_JSON=$(./target/release/compair figures noc-calibration --format json)
printf '%s\n' "$CAL_JSON" | python3 -c '
import json, re, sys
doc = json.load(sys.stdin)
out = next(f["output"] for f in doc["figures"] if f["figure"] == "noc-calibration")
if re.search(r"(?i)(nan|inf)%", out):
    sys.exit("non-finite calibrated error in the noc-calibration table")
errs = [float(m) for m in re.findall(r"(\d+(?:\.\d+)?)%", out)]
if not errs:
    sys.exit("no calibrated-error values found in the noc-calibration table")
bad = [e for e in errs if e > 20.0]
if bad:
    sys.exit(f"calibrated NoC error exceeds 20% at {len(bad)} anchor(s): {bad}")
print(f"ok: {len(errs)} anchors, max calibrated error {max(errs):.2f}%")
'

say "pool determinism gate (figures --jobs 4 == --jobs 1)"
# the worker pool merges results in submission order, so pooled output is
# contractually bit-identical to serial; diff the full figures JSON to hold
# the CLI to it (a representative subset keeps the gate under a minute:
# cell-sweep figures, the serving tables, and the calibration fit)
DET_FIGS="fig5 fig9 fig16 fig23 scenarios noc-calibration"
J1=$(./target/release/compair figures $DET_FIGS --jobs 1 --format json)
J4=$(./target/release/compair figures $DET_FIGS --jobs 4 --format json)
if [[ "$J1" == "$J4" ]]; then
    echo "ok: --jobs 4 output is byte-identical to --jobs 1 ($DET_FIGS)"
else
    echo "error: figures output diverges between --jobs 1 and --jobs 4" >&2
    diff <(printf '%s\n' "$J1") <(printf '%s\n' "$J4") | head -40 >&2
    exit 1
fi

say "mapping never-lose gate (mapping-search r= markers <= 1)"
# every phase-level row of the mapping-search figure carries an
# `r=<auto/static>` marker; the auto-mapper's structural guarantee is that
# no searched placement ever scores worse than the paper's static one
MAP_J1=$(./target/release/compair figures mapping-search --jobs 1 --format json)
printf '%s\n' "$MAP_J1" | python3 -c '
import json, re, sys
doc = json.load(sys.stdin)
out = next(f["output"] for f in doc["figures"] if f["figure"] == "mapping-search")
ratios = [float(m) for m in re.findall(r"r=([0-9]+(?:\.[0-9]+)?)", out)]
if not ratios:
    sys.exit("no r= never-lose markers found in the mapping-search table")
bad = [r for r in ratios if r > 1.0 + 1e-9]
if bad:
    sys.exit(f"auto mapping scored worse than static in {len(bad)} cell(s): {bad}")
print(f"ok: {len(ratios)} cells, min ratio {min(ratios):.4f}")
'
# the search itself must be jobs-invariant end to end
MAP_J4=$(./target/release/compair figures mapping-search --jobs 4 --format json)
if [[ "$MAP_J1" == "$MAP_J4" ]]; then
    echo "ok: mapping-search --jobs 4 output is byte-identical to --jobs 1"
else
    echo "error: mapping-search output diverges between --jobs 1 and --jobs 4" >&2
    diff <(printf '%s\n' "$MAP_J1") <(printf '%s\n' "$MAP_J4") | head -40 >&2
    exit 1
fi

say "static verifier gate (compair check: zero errors over shipped configs)"
# the check subcommand lints every shipped (arch, model) point, the Row-Level
# ISA programs (with the static flit/op count cross-check) and the scenario
# SLO tables; error-severity diagnostics fail CI (warnings are reported but
# pass — capacity overflows are priced as streaming, not rejected)
CHK_J1=$(./target/release/compair check --jobs 1 --format json)
printf '%s\n' "$CHK_J1" | python3 -c '
import json, sys
doc = json.load(sys.stdin)
assert doc["command"] == "check", "unexpected command field"
assert doc["isa"]["errors"] == 0, "ISA program lint errors: %r" % doc["isa"]
assert doc["scenarios"]["errors"] == 0, "scenario SLO errors: %r" % doc["scenarios"]
assert doc["points"], "check covered no (arch, model) points"
bad = [p for p in doc["points"] if p["report"]["errors"]]
if bad:
    sys.exit("check errors at: " + ", ".join(f"{p['arch']}/{p['model']}" for p in bad))
assert doc["errors"] == 0 and doc["ok"] is True, "check reported errors"
warns = doc["warnings"]
print(f"ok: {len(doc['points'])} points clean, {warns} warning(s)")
'
# the point fan-out runs on the pool; the report must not depend on --jobs
CHK_J4=$(./target/release/compair check --jobs 4 --format json)
if [[ "$CHK_J1" == "$CHK_J4" ]]; then
    echo "ok: check --jobs 4 output is byte-identical to --jobs 1"
else
    echo "error: check output diverges between --jobs 1 and --jobs 4" >&2
    diff <(printf '%s\n' "$CHK_J1") <(printf '%s\n' "$CHK_J4") | head -40 >&2
    exit 1
fi

say "semantic audit gate (compair audit: zero invariant violations)"
# the audit subcommand proves physical invariants — finiteness, op/energy/
# bytes conservation, monotonicity, cache coherence, never-lose, fidelity
# bands, calibration bounds — over the pow2 point lattice plus the
# arch-independent global slice; any error-severity diagnostic fails CI
AUD_J1=$(./target/release/compair audit --jobs 1 --format json)
printf '%s\n' "$AUD_J1" | python3 -c '
import json, sys
doc = json.load(sys.stdin)
assert doc["command"] == "audit", "unexpected command field"
assert doc["global"]["errors"] == 0, "global audit errors: %r" % doc["global"]
assert doc["points"], "audit covered no lattice points"
bad = [p for p in doc["points"] if p["report"]["errors"]]
if bad:
    sys.exit("audit errors at: " + ", ".join(p["point"] for p in bad))
assert doc["errors"] == 0 and doc["ok"] is True, "audit reported errors"
print(f"ok: {len(doc['points'])} lattice points clean, {doc['warnings']} warning(s)")
'
# the lattice fan-out runs on the pool; the report must not depend on --jobs
AUD_J4=$(./target/release/compair audit --jobs 4 --format json)
if [[ "$AUD_J1" == "$AUD_J4" ]]; then
    echo "ok: audit --jobs 4 output is byte-identical to --jobs 1"
else
    echo "error: audit output diverges between --jobs 1 and --jobs 4" >&2
    diff <(printf '%s\n' "$AUD_J1") <(printf '%s\n' "$AUD_J4") | head -40 >&2
    exit 1
fi

say "cost-expression proof gate (compair prove: zero failed proof obligations)"
# the prove subcommand captures the cost pipeline as a unit-checked
# expression IR and certifies unit consistency, monotonicity, overflow
# headroom, interval bounds and energy-pricing coverage over the whole
# shape box (not sampled); any error-severity diagnostic fails CI, and
# every point must certify completely (no budget-exhaustion partials)
PRV_J1=$(./target/release/compair prove --jobs 1 --format json)
printf '%s\n' "$PRV_J1" | python3 -c '
import json, sys
doc = json.load(sys.stdin)
assert doc["command"] == "prove", "unexpected command field"
assert doc["global"]["errors"] == 0, "global pricing-coverage errors: %r" % doc["global"]
assert doc["points"], "prove covered no lattice points"
bad = [p for p in doc["points"] if p["report"]["errors"]]
if bad:
    sys.exit("proof failures at: " + ", ".join(p["point"] for p in bad))
partial = [p for p in doc["points"] if not p["summary"]["complete"]]
if partial:
    sys.exit("incomplete proofs at: " + ", ".join(p["point"] for p in partial))
assert doc["errors"] == 0 and doc["ok"] is True, "prove reported errors"
cells = sum(p["summary"]["certified"] for p in doc["points"])
print(f"ok: {len(doc['points'])} points certified ({cells} cells), {doc['warnings']} warning(s)")
'
# the point fan-out runs on the pool; the report must not depend on --jobs
PRV_J4=$(./target/release/compair prove --jobs 4 --format json)
if [[ "$PRV_J1" == "$PRV_J4" ]]; then
    echo "ok: prove --jobs 4 output is byte-identical to --jobs 1"
else
    echo "error: prove output diverges between --jobs 1 and --jobs 4" >&2
    diff <(printf '%s\n' "$PRV_J1") <(printf '%s\n' "$PRV_J4") | head -40 >&2
    exit 1
fi

say "bench artifacts gate (BENCH_serving.json + BENCH_parallel.json parse)"
# fast mode shrinks the Bencher budget; the pool section always runs its
# single timed serial-vs-pooled passes and asserts bit-identity itself
COMPAIR_BENCH_FAST=1 cargo bench -q --bench bench_hotpath
for artifact in BENCH_serving.json BENCH_parallel.json; do
    if [[ ! -f "$artifact" ]]; then
        echo "error: bench_hotpath did not write $artifact" >&2
        exit 1
    fi
    python3 -m json.tool < "$artifact" > /dev/null
done
python3 -c '
import json
doc = json.load(open("BENCH_parallel.json"))
cases = doc["cases"]
assert cases, "BENCH_parallel.json has no cases"
for c in cases:
    for k in ("name", "serial_ns", "parallel_ns", "speedup", "identical"):
        assert k in c, "case missing %s: %r" % (k, c)
    assert c["identical"] is True, "pooled output diverged in %s" % c["name"]
speedups = ", ".join("%s %.2fx" % (c["name"], c["speedup"]) for c in cases)
print("ok: %d pool cases (%s)" % (len(cases), speedups))
'

if [[ "$FAST" == "0" ]]; then
    say "cargo clippy --all-targets (warnings are errors)"
    if cargo clippy --version >/dev/null 2>&1; then
        cargo clippy -q --all-targets -- -D warnings
    else
        echo "error: clippy not installed (rustup component add clippy);" >&2
        echo "       the lint gate cannot be skipped silently." >&2
        exit 1
    fi

    say "cargo doc --no-deps (warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

    if command -v rustfmt >/dev/null 2>&1; then
        say "cargo fmt --check"
        cargo fmt --all --check
    else
        echo "skipping fmt: rustfmt not installed"
    fi
fi

if python3 -c 'import jax' >/dev/null 2>&1; then
    if python3 -c 'import pytest' >/dev/null 2>&1; then
        say "python kernel/model tests"
        (cd python && python3 -m pytest -q tests)
    else
        echo "skipping python tests: pytest not installed"
    fi
else
    echo "skipping python tests: jax not installed"
fi

say "CI gate passed"
