"""L2: the JAX transformer (tiny Llama-style) built on the L1 kernels.

This is the numeric golden model for the rust coordinator: ``aot.py``
lowers ``block_prefill`` and ``decode_step`` (with the deterministic TINY
parameters baked in as constants) to HLO text, and the rust runtime
executes them on the PJRT CPU client. Python never runs at request time.

The TINY config must match rust ``config::ModelConfig::tiny()``.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import curry, gemv_bank, ref, rmsnorm, rope, softmax, sram_macro


@dataclass(frozen=True)
class TinyConfig:
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ffn: int = 128
    vocab: int = 256
    max_seq: int = 64

    @property
    def d_head(self):
        return self.d_model // self.n_heads


TINY = TinyConfig()


def init_params(cfg: TinyConfig = TINY, seed: int = 0):
    """Deterministic parameter pytree (baked into the AOT artifacts)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, cfg.n_layers * 8 + 1)
    scale = 0.08
    params = {"layers": []}
    d, f = cfg.d_model, cfg.d_ffn
    kv = cfg.n_kv_heads * cfg.d_head
    for l in range(cfg.n_layers):
        k = ks[l * 8 : (l + 1) * 8]
        params["layers"].append(
            {
                "wq": jax.random.normal(k[0], (d, d), jnp.float32) * scale,
                "wk": jax.random.normal(k[1], (d, kv), jnp.float32) * scale,
                "wv": jax.random.normal(k[2], (d, kv), jnp.float32) * scale,
                "wo": jax.random.normal(k[3], (d, d), jnp.float32) * scale,
                "w_up": jax.random.normal(k[4], (d, f), jnp.float32) * scale,
                "w_gate": jax.random.normal(k[5], (d, f), jnp.float32) * scale,
                "w_down": jax.random.normal(k[6], (f, d), jnp.float32) * scale,
                "g1": 1.0 + 0.01 * jax.random.normal(k[7], (d,), jnp.float32),
                "g2": jnp.ones((d,), jnp.float32),
            }
        )
    return params


def _fc(x, w):
    """Dense through the SRAM-macro kernel when shapes tile, else jnp."""
    b, din = x.shape
    din2, dout = w.shape
    if din % sram_macro.MACRO_IN == 0 and dout % sram_macro.MACRO_OUT == 0:
        return sram_macro.gemm_macro(x, w)
    return ref.bf16_round(ref.gemm_ref(x, w))


def _attention(q, k, v, cfg: TinyConfig):
    """q: [B, H, Tq, Dh]; k/v: [B, H, Tk, Dh] -> [B, H, Tq, Dh].

    Causal only when Tq == Tk (prefill); decode passes Tq=1 with a full
    cache view and masks by length upstream.
    """
    B, H, Tq, Dh = q.shape
    Tk = k.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(Dh))
    if Tq == Tk:
        mask = jnp.tril(jnp.ones((Tq, Tk), bool))
        scores = jnp.where(mask[None, None], scores, -1e9)
    probs = softmax.curry_softmax(scores.reshape(-1, Tk)).reshape(scores.shape)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def _rope_qk(x, positions, cfg: TinyConfig):
    """x: [B, T, H, Dh] with per-token positions [T] -> [B, H, T, Dh]."""
    B, T, H, Dh = x.shape
    cos, sin = ref.rope_tables(positions, Dh)
    flat = x.transpose(0, 2, 1, 3).reshape(-1, Dh)
    cos_f = jnp.tile(cos, (B * H, 1))
    sin_f = jnp.tile(sin, (B * H, 1))
    out = rope.rope(flat, cos_f, sin_f)
    return out.reshape(B, H, T, Dh)


def block_fwd(params_l, x, positions, cfg: TinyConfig, kv=None):
    """One transformer block. x: [B, T, d]. kv: optional (k_cache, v_cache,
    pos) for decode. Returns (y, (k_new, v_new))."""
    B, T, d = x.shape
    H, Dh = cfg.n_heads, cfg.d_head
    xf = x.reshape(-1, d)
    h = rmsnorm.rmsnorm(xf, params_l["g1"])
    q = _fc(h, params_l["wq"]).reshape(B, T, H, Dh)
    k = _fc(h, params_l["wk"]).reshape(B, T, cfg.n_kv_heads, Dh)
    v = _fc(h, params_l["wv"]).reshape(B, T, cfg.n_kv_heads, Dh)
    q = _rope_qk(q, positions, cfg)  # [B, H, T, Dh]
    k = _rope_qk(k, positions, cfg)
    v = v.transpose(0, 2, 1, 3)

    if kv is None:
        attn = _attention(q, k, v, cfg)
        k_out, v_out = k, v
    else:
        k_cache, v_cache, pos = kv  # [B, H, max_seq, Dh]
        k_out = jax.lax.dynamic_update_slice(k_cache, k, (0, 0, pos, 0))
        v_out = jax.lax.dynamic_update_slice(v_cache, v, (0, 0, pos, 0))
        Tk = k_cache.shape[2]
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k_out) / jnp.sqrt(float(Dh))
        valid = jnp.arange(Tk)[None, None, None, :] <= pos
        scores = jnp.where(valid, scores, -1e9)
        probs = softmax.curry_softmax(scores.reshape(-1, Tk)).reshape(scores.shape)
        attn = jnp.einsum("bhqk,bhkd->bhqd", probs, v_out)

    attn = attn.transpose(0, 2, 1, 3).reshape(-1, d)
    x1 = xf + _fc(attn, params_l["wo"])
    h2 = rmsnorm.rmsnorm(x1, params_l["g2"])
    up = _fc(h2, params_l["w_up"])
    gate = _fc(h2, params_l["w_gate"])
    act = ref.bf16_round(up * ref.silu_ref(gate))
    y = x1 + _fc(act, params_l["w_down"])
    return y.reshape(B, T, d), (k_out, v_out)


def model_prefill(params, x, cfg: TinyConfig = TINY):
    """Full prefill over all layers. x: [B, T, d]. Returns (y, caches)."""
    T = x.shape[1]
    positions = jnp.arange(T)
    caches = []
    for pl_ in params["layers"]:
        x, kvs = block_fwd(pl_, x, positions, cfg)
        caches.append(kvs)
    return x, caches


def model_decode_step(params, x, k_caches, v_caches, pos, cfg: TinyConfig = TINY):
    """One decode step. x: [B, 1, d]; caches: [L, B, H, max_seq, Dh]; pos is
    a traced scalar. Returns (y, k_caches', v_caches')."""
    positions = jnp.full((1,), pos)
    ks, vs = [], []
    for li, pl_ in enumerate(params["layers"]):
        x, (k2, v2) = block_fwd(pl_, x, positions, cfg, kv=(k_caches[li], v_caches[li], pos))
        ks.append(k2)
        vs.append(v2)
    return x, jnp.stack(ks), jnp.stack(vs)


# ---- AOT entry points (fixed shapes, params baked as constants) ----

def make_entry_points(cfg: TinyConfig = TINY, batch: int = 2, prompt: int = 8):
    """Returns {name: (fn, example_args)} for aot.py to lower."""
    params = init_params(cfg)
    H, Dh, L = cfg.n_heads, cfg.d_head, cfg.n_layers

    def prefill_fn(x):
        y, _ = model_prefill(params, x, cfg)
        return (y,)

    def decode_fn(x, k_caches, v_caches, pos):
        y, k2, v2 = model_decode_step(params, x, k_caches, v_caches, pos, cfg)
        return (y, k2, v2)

    def softmax_fn(x):
        return (softmax.curry_softmax(x),)

    def exp_fn(x):
        return (curry.curry_exp(x),)

    def rope_fn(x, cos, sin):
        return (rope.rope(x, cos, sin),)

    def gemv_fn(w, x):
        return (gemv_bank.gemv_bank(w, x),)

    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return {
        "block_prefill": (prefill_fn, (spec((batch, prompt, cfg.d_model), f32),)),
        "decode_step": (
            decode_fn,
            (
                spec((batch, 1, cfg.d_model), f32),
                spec((L, batch, H, cfg.max_seq, Dh), f32),
                spec((L, batch, H, cfg.max_seq, Dh), f32),
                spec((), jnp.int32),
            ),
        ),
        "curry_softmax": (softmax_fn, (spec((8, 128), f32),)),
        "curry_exp": (exp_fn, (spec((64,), f32),)),
        "rope": (rope_fn, (spec((16, 16), f32),) * 3),
        "gemv_bank": (gemv_fn, (spec((64, 64), f32), spec((64,), f32))),
    }
