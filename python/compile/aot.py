"""AOT lowering: every entry point -> artifacts/<name>.hlo.txt.

HLO *text* is the interchange format (NOT lowered.compile() serialization):
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the rust
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import hashlib
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import make_entry_points


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large constants as "{...}", which
    # the HLO text parser silently reads back as zeros — baked-in model
    # weights would vanish. Print them in full.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # the crate's XLA 0.5.1 text parser predates newer metadata attributes
    # (source_end_line etc.) — strip metadata entirely
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided constants"
    return text


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of entry points")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = make_entry_points()
    names = args.only or sorted(entries)
    for name in names:
        fn, example = entries[name]
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:12]
        print(f"wrote {path}  ({len(text)} chars, sha256 {digest})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
