"""CompAir build-path package (never imported at runtime)."""
