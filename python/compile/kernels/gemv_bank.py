"""L1 Pallas kernel: bank-tiled GeMV — the DRAM-PIM 16-lane MAC datapath.

Hardware mapping (DESIGN.md §Hardware-Adaptation): each grid step is one
bank's output tile; the weight BlockSpec streams (LANES x d_in) tiles from
HBM into VMEM the way a bank's column decoder streams rows into the MAC
lanes. Inputs are BF16 (the bank datapath), accumulation is f32 (the MAC
accumulator), outputs round back through BF16.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated on the interpret path and TPU
performance is estimated structurally (EXPERIMENTS.md §Perf-L1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 16 BF16 MAC lanes per bank (Table 3).
LANES = 16


def _kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.bfloat16).astype(jnp.float32)
    x = x_ref[...].astype(jnp.bfloat16).astype(jnp.float32)
    acc = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = acc.astype(jnp.bfloat16).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def gemv_bank(w, x):
    """w: [out, in] (out % 16 == 0), x: [in] -> [out] f32 (BF16-rounded)."""
    out_dim, in_dim = w.shape
    assert out_dim % LANES == 0, f"out dim {out_dim} must tile by {LANES} lanes"
    return pl.pallas_call(
        _kernel,
        grid=(out_dim // LANES,),
        in_specs=[
            pl.BlockSpec((LANES, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((in_dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((LANES,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((out_dim,), jnp.float32),
        interpret=True,
    )(w, x)
