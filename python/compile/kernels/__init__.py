"""CompAir L1 Pallas kernels + the pure-jnp oracle (ref)."""
from . import curry, gemv_bank, ref, rmsnorm, rope, softmax, sram_macro  # noqa: F401
