"""L1 Pallas kernel: CompAir-style softmax.

Dataflow mirrors the hardware split: per-row max shift (scheduler-side),
Curry exponential in transit, tree-reduced sum (binary fold, the §4.3.3
reduce tree), and an in-transit divide. Rows are grid-parallel like banks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .curry import EXP_RR_ROUNDS


def _bf16(v):
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def _softmax_kernel(x_ref, o_ref, *, rounds, tree_width):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    # range clamp: scores below max-8 are ~0 in the distribution
    zc = _bf16(jnp.clip(x - m, -8.0, 0.0))
    z = zc / 4.0  # range reduction: exp(z) = exp(z/4)^4

    # Curry exponential (Horner, BF16 per step)
    def body(i, carry):
        t, k = carry
        t = _bf16(t * z)
        t = _bf16(t / _bf16(k))
        t = _bf16(t + 1.0)
        return t, _bf16(k - 1.0)

    t0 = jnp.ones_like(z)
    k0 = jnp.full_like(z, float(rounds))
    e, _ = jax.lax.fori_loop(0, rounds, body, (t0, k0))
    e = _bf16(e * e)
    e = _bf16(e * e)

    # binary-tree reduction over the row (the bank reduce tree)
    s = e.reshape(e.shape[:-1] + (tree_width, e.shape[-1] // tree_width))
    partial = jnp.sum(s, axis=-1)  # per-bank partial (MAC lanes)
    total = jnp.sum(partial, axis=-1, keepdims=True)  # tree fold
    o_ref[...] = _bf16(e / _bf16(total))


@functools.partial(jax.jit, static_argnames=("rounds",))
def curry_softmax(x, rounds=EXP_RR_ROUNDS):
    """Row softmax over the last axis of a 2-D array [rows, seq]."""
    rows, seq = x.shape
    tree_width = 16 if seq % 16 == 0 else 1  # 16 banks per channel
    return pl.pallas_call(
        functools.partial(_softmax_kernel, rounds=rounds, tree_width=tree_width),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, seq), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, seq), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, seq), jnp.float32),
        interpret=True,
    )(x)
