"""L1 Pallas kernel: RoPE with the NoC pair-exchange rearrangement.

The (x0, x1) -> (-x1, x0) neighbour swap is exactly NoC_Exchange(R-, .., 1, 2)
(paper Fig 12); the cos/sin multiplies are the bank's EWMUL pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bf16(v):
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    pairs = x.reshape(x.shape[:-1] + (x.shape[-1] // 2, 2))
    rot = jnp.stack([-pairs[..., 1], pairs[..., 0]], axis=-1)
    rot = _bf16(rot.reshape(x.shape))
    o_ref[...] = _bf16(_bf16(x * cos_ref[...]) + _bf16(rot * sin_ref[...]))


@functools.partial(jax.jit, static_argnames=())
def rope(x, cos, sin):
    """x: [tokens, d_head], cos/sin: [tokens, d_head] -> rotated x."""
    assert x.shape == cos.shape == sin.shape
    assert x.shape[-1] % 2 == 0
    return pl.pallas_call(
        _rope_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, cos, sin)
