"""L1 Pallas kernels for the Curry-ALU iterative non-linear functions.

The exponential is Fig 13's Horner chain — per iteration
``t *= x; t /= k; t += 1; k -= 1`` — with a BF16 round after every ALU
touch, matching the 16-bit flit payload. The rust simulator
(``noc::curry::curry_exp``) implements the identical recurrence; the pytest
suite pins them together through ``ref.curry_exp_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EXP_ROUNDS = 6
EXP_RR_ROUNDS = 8
SQRT_ROUNDS = 8


def _bf16(v):
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def _exp_kernel(x_ref, o_ref, *, rounds):
    x = _bf16(x_ref[...])

    def body(i, carry):
        t, k = carry
        t = _bf16(t * x)
        t = _bf16(t / _bf16(k))
        t = _bf16(t + 1.0)
        k = _bf16(k - 1.0)
        return t, k

    t0 = jnp.ones_like(x)
    k0 = jnp.full_like(x, float(rounds))
    t, _ = jax.lax.fori_loop(0, rounds, body, (t0, k0))
    o_ref[...] = t


@functools.partial(jax.jit, static_argnames=("rounds",))
def curry_exp(x, rounds=EXP_ROUNDS):
    """Element-wise Curry exponential over a 1-D or 2-D array."""
    return pl.pallas_call(
        functools.partial(_exp_kernel, rounds=rounds),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)


def _exp_rr_kernel(x_ref, o_ref, *, rounds, squarings):
    x = _bf16(x_ref[...]) / float(1 << squarings)

    def body(i, carry):
        t, k = carry
        t = _bf16(t * x)
        t = _bf16(t / _bf16(k))
        t = _bf16(t + 1.0)
        return t, _bf16(k - 1.0)

    t, _ = jax.lax.fori_loop(
        0, rounds, body, (jnp.ones_like(x), jnp.full_like(x, float(rounds)))
    )
    for _ in range(squarings):
        t = _bf16(t * t)
    o_ref[...] = t


@functools.partial(jax.jit, static_argnames=("rounds", "squarings"))
def curry_exp_rr(x, rounds=8, squarings=2):
    """Range-reduced Curry exponential (convergent over wide ranges)."""
    return pl.pallas_call(
        functools.partial(_exp_rr_kernel, rounds=rounds, squarings=squarings),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)


def _sqrt_kernel(x_ref, o_ref, *, rounds):
    x = _bf16(x_ref[...])
    y0 = _bf16(jnp.maximum(x, 1.0))

    def body(i, y):
        q = _bf16(x / y)
        s = _bf16(y + q)
        return _bf16(s / 2.0)

    y = jax.lax.fori_loop(0, rounds, body, y0)
    o_ref[...] = jnp.where(x <= 0.0, jnp.zeros_like(x), y)


@functools.partial(jax.jit, static_argnames=("rounds",))
def curry_sqrt(x, rounds=SQRT_ROUNDS):
    """Element-wise Newton square root (the RMSNorm path's rsqrt core)."""
    return pl.pallas_call(
        functools.partial(_sqrt_kernel, rounds=rounds),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)
