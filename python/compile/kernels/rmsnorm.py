"""L1 Pallas kernel: RMSNorm via the hardware path — per-bank square
accumulation (MAC lanes), tree-reduced mean, Newton rsqrt (Curry), scale.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bf16(v):
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def _rms_kernel(x_ref, g_ref, o_ref, *, eps, newton_rounds):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True) + eps

    # Newton sqrt seeded at max(ms, 1): y <- (y + ms/y)/2
    y = jnp.maximum(ms, 1.0)

    def body(i, y):
        return 0.5 * (y + ms / y)

    y = jax.lax.fori_loop(0, newton_rounds, body, y)
    o_ref[...] = _bf16(x / y * g_ref[...])


@functools.partial(jax.jit, static_argnames=("eps", "newton_rounds"))
def rmsnorm(x, g, eps=1e-5, newton_rounds=12):
    """x: [tokens, d], g: [d] -> normalized x (Newton-rsqrt hardware path)."""
    return pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps, newton_rounds=newton_rounds),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, g)
