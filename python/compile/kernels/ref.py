"""Pure-jnp oracles for every kernel — the correctness ground truth.

Two flavours live here:

* ``*_ref``: textbook float32 implementations (what the math should be);
* ``curry_*_ref``: step-exact models of the CompAir hardware algorithms
  (BF16-rounded Horner/Newton iterations, pair-swap RoPE), which the Pallas
  kernels AND the rust ISA interpreter must match bit-for-bit.
"""

import jax.numpy as jnp


def bf16_round(x):
    """Round f32 -> bf16 -> f32 (the hardware's per-step rounding)."""
    return jnp.asarray(x, jnp.float32).astype(jnp.bfloat16).astype(jnp.float32)


# ---------------------------------------------------------------- matmuls

def gemv_ref(w, x):
    """w: [out, in], x: [in] -> [out] in f32."""
    return jnp.asarray(w, jnp.float32) @ jnp.asarray(x, jnp.float32)


def gemm_ref(x, w):
    """x: [batch, in], w: [in, out] -> [batch, out]."""
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)


def bank_gemv_ref(w, x):
    """BF16-input GeMV with f32 accumulation (the 16-lane MAC datapath)."""
    wb = bf16_round(w)
    xb = bf16_round(x)
    return bf16_round(wb @ xb)


# ------------------------------------------------------------- non-linear

def curry_exp_ref(x, rounds=6):
    """Fig 13 Horner exponential, BF16-rounded per step.

    Per iteration: t *= x; t /= k; t += 1; k -= 1 (k counts down from
    ``rounds``). Must match rust ``noc::curry::curry_exp`` exactly.
    """
    x = bf16_round(x)
    t = jnp.ones_like(x)
    k = float(rounds)
    for _ in range(rounds):
        t = bf16_round(bf16_round(t) * x)
        t = bf16_round(t / bf16_round(jnp.float32(k)))
        t = bf16_round(t + jnp.float32(1.0))
        k -= 1.0
    return t


def curry_exp_rr_ref(x, rounds=8, squarings=2):
    """Range-reduced Curry exponential: exp(x) = exp(x / 2^s)^(2^s).

    The Horner chain runs on x/2^s (convergent for |x/2^s| <= 2) and the
    squarings are two extra Mul passes through the same ALU. Matches rust
    ``noc::curry::curry_exp_rr``."""
    t = curry_exp_ref(jnp.asarray(x, jnp.float32) / float(1 << squarings), rounds)
    for _ in range(squarings):
        t = bf16_round(t * t)
    return t


def curry_sqrt_ref(x, rounds=8):
    """Newton sqrt as the NoC executes it (seed max(x, 1), BF16 steps)."""
    x = bf16_round(x)
    y = bf16_round(jnp.maximum(x, 1.0))
    for _ in range(rounds):
        q = bf16_round(x / y)
        s = bf16_round(y + q)
        y = bf16_round(s / 2.0)
    return jnp.where(x <= 0.0, jnp.zeros_like(x), y)


def softmax_ref(x, axis=-1):
    """Numerically-stable float32 softmax."""
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def curry_softmax_ref(x, rounds=8):
    """Softmax as CompAir computes it: max-shift (scheduler-side), Curry
    exponential, tree-reduce sum, in-transit divide. Rows on the last axis.
    """
    x = jnp.asarray(x, jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)  # scheduler-side stabilization
    z = jnp.clip(x - m, -8.0, 0.0)  # range clamp (exp(-8) ~ 3e-4 ~ 0)
    e = curry_exp_rr_ref(z, rounds)
    s = jnp.sum(e, axis=-1, keepdims=True)  # tree reduce (exact adds)
    return bf16_round(e / bf16_round(s))


def rmsnorm_ref(x, g, eps=1e-5):
    """Float32 RMSNorm with learned gain g."""
    x = jnp.asarray(x, jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g


def silu_ref(x):
    x = jnp.asarray(x, jnp.float32)
    return x / (1.0 + jnp.exp(-x))


# ------------------------------------------------------------------ RoPE

def rope_rearrange_ref(x):
    """Neighbour swap with negation: (x0, x1) -> (-x1, x0) per pair, on the
    last axis (the NoC_Exchange(R-, .., 1, 2) semantics)."""
    x = jnp.asarray(x, jnp.float32)
    x2 = x.reshape(x.shape[:-1] + (-1, 2))
    out = jnp.stack([-x2[..., 1], x2[..., 0]], axis=-1)
    return bf16_round(out.reshape(x.shape))


def rope_apply_ref(x, cos, sin):
    """Full RoPE: x*cos + rearrange(x)*sin (interleaved-pair convention)."""
    return bf16_round(
        bf16_round(jnp.asarray(x, jnp.float32) * cos)
        + bf16_round(rope_rearrange_ref(x) * sin)
    )


def rope_tables(positions, d_head, base=10000.0):
    """cos/sin tables for interleaved-pair RoPE: [len(positions), d_head]."""
    pos = jnp.asarray(positions, jnp.float32)[:, None]
    idx = jnp.arange(d_head // 2, dtype=jnp.float32)
    inv = base ** (-2.0 * idx / d_head)
    ang = pos * inv[None, :]
    cos = jnp.repeat(jnp.cos(ang), 2, axis=-1)
    sin = jnp.repeat(jnp.sin(ang), 2, axis=-1)
    return cos, sin
