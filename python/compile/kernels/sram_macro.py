"""L1 Pallas kernel: macro-tiled GeMM — the SRAM-PIM 128-in x 8-out array.

Hardware mapping: the (MACRO_IN x MACRO_OUT) weight BlockSpec *is* the CIM
macro's array; the in-tile grid axis walks the weight reloads the hybrid
bonding performs, and the f32 accumulator block mirrors the macro's
accumulation registers across in-tiles. Batch rides in the block's leading
dim — exactly the weight-reuse axis that makes SRAM-PIM win at batch>1
(paper Fig 4B).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MACRO_IN = 128
MACRO_OUT = 8


def _kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.bfloat16).astype(jnp.float32)
    w = w_ref[...].astype(jnp.bfloat16).astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def gemm_macro(x, w):
    """x: [batch, in], w: [in, out] -> [batch, out] f32.

    in % 128 == 0 and out % 8 == 0 (macro tiling).
    """
    batch, in_dim = x.shape
    in_dim2, out_dim = w.shape
    assert in_dim == in_dim2
    assert in_dim % MACRO_IN == 0, f"in dim {in_dim} must tile by {MACRO_IN}"
    assert out_dim % MACRO_OUT == 0, f"out dim {out_dim} must tile by {MACRO_OUT}"
    grid = (out_dim // MACRO_OUT, in_dim // MACRO_IN)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, MACRO_IN), lambda o, i: (0, i)),
            pl.BlockSpec((MACRO_IN, MACRO_OUT), lambda o, i: (i, o)),
        ],
        out_specs=pl.BlockSpec((batch, MACRO_OUT), lambda o, i: (0, o)),
        out_shape=jax.ShapeDtypeStruct((batch, out_dim), jnp.float32),
        interpret=True,
    )(x, w)
