"""L2 correctness: the tiny transformer's shapes, decode/prefill
consistency, and AOT entry-point lowering."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.model import TINY, init_params


@pytest.fixture(scope="module")
def params():
    return init_params()


class TestBlocks:
    def test_prefill_shapes(self, params):
        x = np.random.default_rng(0).standard_normal((2, 8, TINY.d_model)).astype(np.float32)
        y, caches = model.model_prefill(params, x)
        assert y.shape == (2, 8, TINY.d_model)
        assert len(caches) == TINY.n_layers
        k, v = caches[0]
        assert k.shape == (2, TINY.n_heads, 8, TINY.d_head)

    def test_decode_step_shapes(self, params):
        B, L, H, S, Dh = 2, TINY.n_layers, TINY.n_heads, TINY.max_seq, TINY.d_head
        x = np.zeros((B, 1, TINY.d_model), np.float32)
        kc = np.zeros((L, B, H, S, Dh), np.float32)
        vc = np.zeros((L, B, H, S, Dh), np.float32)
        y, k2, v2 = model.model_decode_step(params, x, kc, vc, 0)
        assert y.shape == (B, 1, TINY.d_model)
        assert k2.shape == (L, B, H, S, Dh)

    def test_decode_matches_prefill(self, params):
        """Token-by-token decode must reproduce the prefill output of the
        final position (the KV-cache correctness invariant)."""
        rng = np.random.default_rng(7)
        B, T = 1, 4
        x = rng.standard_normal((B, T, TINY.d_model)).astype(np.float32) * 0.5
        y_pref, _ = model.model_prefill(params, x)

        L, H, S, Dh = TINY.n_layers, TINY.n_heads, TINY.max_seq, TINY.d_head
        kc = jnp.zeros((L, B, H, S, Dh), jnp.float32)
        vc = jnp.zeros((L, B, H, S, Dh), jnp.float32)
        y_last = None
        for t in range(T):
            y_last, kc, vc = model.model_decode_step(
                params, x[:, t : t + 1, :], kc, vc, t
            )
        np.testing.assert_allclose(
            np.array(y_last[:, 0]), np.array(y_pref[:, -1]), atol=0.08, rtol=0.05
        )

    def test_determinism(self, params):
        x = np.ones((1, 2, TINY.d_model), np.float32) * 0.1
        y1, _ = model.model_prefill(params, x)
        y2, _ = model.model_prefill(params, x)
        np.testing.assert_array_equal(np.array(y1), np.array(y2))

    def test_params_deterministic_per_seed(self):
        a = init_params(seed=3)
        b = init_params(seed=3)
        np.testing.assert_array_equal(
            np.array(a["layers"][0]["wq"]), np.array(b["layers"][0]["wq"])
        )


class TestEntryPoints:
    def test_all_entries_lower(self):
        import jax
        from compile.aot import to_hlo_text

        for name, (fn, example) in model.make_entry_points().items():
            text = to_hlo_text(jax.jit(fn).lower(*example))
            assert "ENTRY" in text, f"{name} produced no HLO entry"
            assert len(text) > 500, f"{name} suspiciously small"
