"""L1 correctness: every Pallas kernel against the pure-jnp oracle,
including hypothesis sweeps over shapes and value ranges."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image without hypothesis: run the
    # deterministic oracle tests, skip only the property sweeps

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property sweep skipped"
            )(fn)

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        """Evaluates strategy expressions like st.integers(1, 6) to None."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from compile.kernels import curry, gemv_bank, ref, rmsnorm, rope, softmax, sram_macro

RNG = np.random.default_rng(1234)


def randn(*shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


# ------------------------------------------------------------ gemv_bank

class TestGemvBank:
    def test_matches_bank_ref_exactly(self):
        w, x = randn(32, 48), randn(48)
        got = np.array(gemv_bank.gemv_bank(w, x))
        want = np.array(ref.bank_gemv_ref(w, x))
        np.testing.assert_array_equal(got, want)

    def test_close_to_f32_gemv(self):
        w, x = randn(64, 128, scale=0.1), randn(128, scale=0.1)
        got = np.array(gemv_bank.gemv_bank(w, x))
        want = np.array(ref.gemv_ref(w, x))
        np.testing.assert_allclose(got, want, atol=0.05)

    def test_rejects_unaligned_output(self):
        with pytest.raises(AssertionError):
            gemv_bank.gemv_bank(randn(17, 8), randn(8))

    @settings(max_examples=20, deadline=None)
    @given(
        out_tiles=st.integers(1, 6),
        in_dim=st.integers(1, 200),
    )
    def test_shape_sweep(self, out_tiles, in_dim):
        w, x = randn(16 * out_tiles, in_dim, scale=0.3), randn(in_dim, scale=0.3)
        got = np.array(gemv_bank.gemv_bank(w, x))
        want = np.array(ref.bank_gemv_ref(w, x))
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------ sram_macro

class TestSramMacro:
    def test_close_to_f32_gemm(self):
        x, w = randn(4, 256, scale=0.1), randn(256, 16, scale=0.1)
        got = np.array(sram_macro.gemm_macro(x, w))
        want = np.array(ref.gemm_ref(x, w))
        np.testing.assert_allclose(got, want, atol=0.1)

    def test_matches_bf16_quantized_ref(self):
        x, w = randn(3, 128), randn(128, 8)
        got = np.array(sram_macro.gemm_macro(x, w))
        want = np.array(ref.gemm_ref(ref.bf16_round(x), ref.bf16_round(w)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_bad_tiling(self):
        with pytest.raises(AssertionError):
            sram_macro.gemm_macro(randn(2, 100), randn(100, 8))

    @settings(max_examples=15, deadline=None)
    @given(
        batch=st.integers(1, 8),
        in_tiles=st.integers(1, 4),
        out_tiles=st.integers(1, 4),
    )
    def test_shape_sweep(self, batch, in_tiles, out_tiles):
        x = randn(batch, 128 * in_tiles, scale=0.2)
        w = randn(128 * in_tiles, 8 * out_tiles, scale=0.2)
        got = np.array(sram_macro.gemm_macro(x, w))
        want = np.array(ref.gemm_ref(ref.bf16_round(x), ref.bf16_round(w)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ curry exp/sqrt

class TestCurry:
    def test_exp_matches_curry_ref_exactly(self):
        x = randn(64)
        np.testing.assert_array_equal(
            np.array(curry.curry_exp(x)), np.array(ref.curry_exp_ref(x))
        )

    def test_exp_approximates_true_exp(self):
        x = np.linspace(-2.0, 1.0, 64, dtype=np.float32)
        got = np.array(curry.curry_exp(x, rounds=8))
        np.testing.assert_allclose(got, np.exp(x), rtol=0.05, atol=0.02)

    def test_more_rounds_improve(self):
        x = np.full(8, 1.0, np.float32)
        e3 = abs(np.array(curry.curry_exp(x, rounds=3))[0] - np.e)
        e8 = abs(np.array(curry.curry_exp(x, rounds=8))[0] - np.e)
        assert e8 <= e3

    def test_sqrt_matches_ref_and_truth(self):
        x = np.abs(randn(32)) * 10 + 0.1
        got = np.array(curry.curry_sqrt(x))
        np.testing.assert_array_equal(got, np.array(ref.curry_sqrt_ref(x)))
        np.testing.assert_allclose(got, np.sqrt(x), rtol=0.02)

    def test_sqrt_zero_and_negative(self):
        x = np.array([0.0, -1.0, 4.0], np.float32)
        got = np.array(curry.curry_sqrt(x))
        assert got[0] == 0.0 and got[1] == 0.0
        np.testing.assert_allclose(got[2], 2.0, rtol=0.01)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(-2.0, 1.5, width=32), min_size=1, max_size=64))
    def test_exp_value_sweep(self, xs):
        x = np.array(xs, np.float32)
        got = np.array(curry.curry_exp(x, rounds=8))
        np.testing.assert_allclose(got, np.exp(x), rtol=0.08, atol=0.03)


# ------------------------------------------------------------ softmax

class TestSoftmax:
    def test_matches_curry_ref(self):
        x = randn(4, 64, scale=2.0)
        np.testing.assert_array_equal(
            np.array(softmax.curry_softmax(x)), np.array(ref.curry_softmax_ref(x))
        )

    def test_close_to_true_softmax(self):
        x = randn(8, 128, scale=3.0)
        got = np.array(softmax.curry_softmax(x))
        want = np.array(ref.softmax_ref(x))
        # bf16 datapath + 8-round range-reduced exp: ~5% worst-case on probs
        np.testing.assert_allclose(got, want, atol=0.05)

    def test_rows_sum_to_one(self):
        x = randn(16, 64, scale=4.0)
        got = np.array(softmax.curry_softmax(x))
        np.testing.assert_allclose(got.sum(-1), 1.0, atol=0.05)

    @settings(max_examples=15, deadline=None)
    @given(rows=st.integers(1, 8), seq=st.sampled_from([16, 32, 48, 64, 128]))
    def test_shape_sweep(self, rows, seq):
        x = randn(rows, seq, scale=2.0)
        got = np.array(softmax.curry_softmax(x))
        want = np.array(ref.softmax_ref(x))
        np.testing.assert_allclose(got, want, atol=0.06)


# ------------------------------------------------------------ rope

class TestRope:
    def test_matches_ref(self):
        x = randn(8, 32)
        cos, sin = ref.rope_tables(np.arange(8), 32)
        np.testing.assert_array_equal(
            np.array(rope.rope(x, cos, sin)),
            np.array(ref.rope_apply_ref(x, cos, sin)),
        )

    def test_position_zero_is_identity(self):
        x = ref.bf16_round(randn(1, 16))
        cos, sin = ref.rope_tables([0], 16)
        np.testing.assert_allclose(np.array(rope.rope(np.array(x), cos, sin)), x, atol=1e-6)

    def test_norm_preserved(self):
        x = randn(4, 64)
        cos, sin = ref.rope_tables([3, 7, 100, 1000], 64)
        y = np.array(rope.rope(x, cos, sin))
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(x, axis=-1), rtol=0.03
        )

    def test_rejects_odd_dim(self):
        with pytest.raises(AssertionError):
            rope.rope(randn(2, 7), randn(2, 7), randn(2, 7))


# ------------------------------------------------------------ rmsnorm

class TestRmsNorm:
    def test_close_to_ref(self):
        x, g = randn(8, 64), 1.0 + 0.1 * randn(64)
        got = np.array(rmsnorm.rmsnorm(x, g))
        want = np.array(ref.rmsnorm_ref(x, g))
        np.testing.assert_allclose(got, want, atol=0.02)

    def test_unit_rms_output(self):
        x = randn(4, 128, scale=5.0)
        got = np.array(rmsnorm.rmsnorm(x, np.ones(128, np.float32)))
        rms = np.sqrt((got**2).mean(-1))
        np.testing.assert_allclose(rms, 1.0, atol=0.05)

    @settings(max_examples=15, deadline=None)
    @given(tokens=st.integers(1, 8), d=st.sampled_from([16, 32, 64, 128]))
    def test_shape_sweep(self, tokens, d):
        x = randn(tokens, d, scale=2.0)
        g = np.ones(d, np.float32)
        got = np.array(rmsnorm.rmsnorm(x, g))
        want = np.array(ref.rmsnorm_ref(x, g))
        np.testing.assert_allclose(got, want, atol=0.03)
