//! System-level integration: whole-pipeline behaviours spanning workload →
//! mapping → substrates → energy, and the serving coordinator on top.

use compair::arch::{attacc, simulate, AttAccConfig};
use compair::config::{ArchKind, FcMapping, ModelConfig, Phase, RunConfig, SramGang};
use compair::coordinator::{run_scenario, ServeConfig, Server};
use compair::workload::Scenario;

#[test]
fn headline_decode_speedups_hold_across_models() {
    // paper headline: 1.95-6.28x decode at batch 64 vs fully-PIM baseline
    for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_70b()] {
        let mut cent = RunConfig::new(ArchKind::Cent, m.clone());
        cent.batch = 64;
        cent.seq_len = 4096;
        let mut ca = cent.clone();
        ca.arch = ArchKind::CompAirOpt;
        ca.hw = compair::config::HwConfig::paper_opt();
        let s = simulate(cent).latency_ns / simulate(ca).latency_ns;
        assert!((1.5..14.0).contains(&s), "{}: decode speedup {s:.2}", m.name);
    }
}

#[test]
fn energy_vs_attacc_headline() {
    // paper: CompAir 3.52x lower energy/token than AttAcc at comparable
    // throughput (4K ctx). Our roofline reproduces the direction and a
    // >2x factor (EXPERIMENTS.md records the exact paper-vs-measured gap).
    let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::gpt3_175b());
    rc.batch = 64;
    rc.seq_len = 4096;
    rc.devices = 96;
    rc.tp = 8;
    let compair_e = simulate(rc.clone()).energy.total_pj();
    let mut ra = rc;
    ra.arch = ArchKind::AttAcc;
    let attacc_e = attacc::simulate(&ra, &AttAccConfig::default()).energy.total_pj();
    let ratio = attacc_e / compair_e;
    assert!(ratio > 2.0, "energy advantage only {ratio:.2}x");
}

#[test]
fn input_split_beats_output_split_with_noc_reduction() {
    // §3.3: with cheap inter-bank reduction, input-split mapping wins for
    // SRAM-PIM FC layers at moderate batch.
    let mut a = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_13b());
    a.batch = 16;
    a.seq_len = 4096;
    a.fc_mapping = FcMapping::OutputSplit;
    let mut b = a.clone();
    b.fc_mapping = FcMapping::InputSplit;
    let ta = simulate(a).latency_ns;
    let tb = simulate(b).latency_ns;
    // input-split must at least be competitive (within 30%) and often wins
    assert!(tb < ta * 1.3, "input-split {tb} vs output-split {ta}");
}

#[test]
fn gang_shapes_tradeoff_visible() {
    let mut a = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_13b());
    a.batch = 16;
    a.sram_gang = SramGang::In512Out8;
    let mut b = a.clone();
    b.sram_gang = SramGang::In256Out16;
    let (ta, tb) = (simulate(a).latency_ns, simulate(b).latency_ns);
    // both must run; (256,16) should not be drastically worse
    assert!(tb < ta * 1.5, "(256,16)={tb} vs (512,8)={ta}");
}

#[test]
fn prefill_and_decode_internally_consistent() {
    // a 1-token prefill and a decode step at seq 1 should be same order of
    // magnitude (they execute near-identical op lists)
    let mut d = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
    d.batch = 1;
    d.seq_len = 1;
    let mut p = d.clone();
    p.phase = Phase::Prefill;
    let (td, tp_) = (simulate(d).latency_ns, simulate(p).latency_ns);
    let ratio = tp_ / td;
    assert!((0.2..5.0).contains(&ratio), "prefill/decode ratio {ratio}");
}

#[test]
fn serving_under_all_archs_completes() {
    for arch in [ArchKind::Cent, ArchKind::CompAirOpt] {
        let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
        rc.tp = 8;
        let r = Server::new(
            rc,
            ServeConfig { n_requests: 10, gen_len: 4, prompt_len: 64, ..Default::default() },
        )
        .run();
        assert_eq!(r.completed, 10, "{arch:?}");
        assert!(r.ttft_p50_ns > 0.0);
    }
}

#[test]
fn mixed_scenario_compair_beats_cent_on_slo_and_energy_direction() {
    // the scenario engine composed with the full hardware stack: the same
    // multi-tenant trace must serve faster on CompAir than on the CENT
    // baseline, with every request accounted for on both
    let run = |arch: ArchKind| {
        let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        run_scenario(rc, Scenario::by_name("mixed").unwrap(), 24, 42).report
    };
    let ca = run(ArchKind::CompAirOpt);
    let cent = run(ArchKind::Cent);
    assert_eq!(ca.completed + ca.rejected as usize, 24);
    assert_eq!(cent.completed + cent.rejected as usize, 24);
    assert!(
        ca.makespan_ns < cent.makespan_ns,
        "CompAir {} vs CENT {}",
        ca.makespan_ns,
        cent.makespan_ns
    );
    assert!((0.0..=1.0).contains(&ca.slo_attainment));
    assert!((0.0..=1.0).contains(&cent.slo_attainment));
}

#[test]
fn kv_capacity_feasibility_gpt3_128k() {
    // 32 devices x 512 banks x 32MB = 512GB/device-group; check the KV
    // cache of the Fig 15 point actually fits in the modeled fabric
    let m = ModelConfig::gpt3_175b();
    // Capacity audit of the Fig 15 workload. A CompAir device holds
    // 512 banks x 32 MB = 16 GB. GPT3-175B KV at 128K x batch 64 is ~36 TB
    // — beyond ANY configuration in the paper (96 devices = 1.5 TB), so the
    // 128K headline necessarily relies on KV streaming/paging; we document
    // this in EXPERIMENTS.md. The 4K-context energy-comparison point plus
    // weights must genuinely fit on 96 devices.
    let hw = compair::config::HwConfig::paper();
    let per_device: u64 = hw.dram.banks_per_device() as u64 * ((hw.dram.bank_mb as u64) << 20);
    assert_eq!(per_device, 16 << 30);
    let weights = m.total_fc_params() * 2;
    let kv_4k = m.kv_bytes_per_token() * 4096 * 64;
    assert!(
        kv_4k + weights <= 96 * per_device,
        "4K point must fit 96 devices: kv={kv_4k} w={weights} cap={}",
        96 * per_device
    );
    let kv_128k = m.kv_bytes_per_token() * 128 * 1024 * 64;
    assert!(kv_128k > 96 * per_device, "128K point relies on KV streaming (documented)");
}
