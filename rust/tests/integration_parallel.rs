//! Determinism goldens for the worker pool (PR 6): pooled execution must
//! be bit-identical to serial, everywhere the pool is wired in — every
//! figure in the registry, the `Engine::sweep` batch facade, and the
//! `CalibratedNoc` parallel anchor fit. These are the same contracts
//! ci.sh gates at the CLI level (`figures --jobs 4` diffed against
//! `--jobs 1`); here they run in-process so a divergence names the
//! figure instead of dumping a JSON diff.

use compair::config::{ArchKind, HwConfig, ModelConfig, NocFidelity, RunConfig};
use compair::figures::{self, FigCtx};
use compair::noc::model::{
    anchor_grid, calibration_report, collective_cost, CalibratedNoc, NocModel,
};
use compair::Engine;

/// Every registered figure, `--jobs 4` vs `--jobs 1`, byte-for-byte.
/// Exercises both fan-out levels: `run_all` runs whole figures as pool
/// jobs, and the sweep-shaped figures par_map their cells internally.
#[test]
fn every_registry_figure_is_jobs_invariant() {
    let serial = figures::run_all(&FigCtx { jobs: 1, ..FigCtx::default() });
    let pooled = figures::run_all(&FigCtx { jobs: 4, ..FigCtx::default() });
    assert_eq!(serial.len(), pooled.len());
    assert_eq!(serial.len(), figures::registry().len(), "run_all must cover the registry");
    for ((n1, s), (n2, p)) in serial.iter().zip(&pooled) {
        assert_eq!(n1, n2, "run_all must preserve registry order");
        assert_eq!(s, p, "figure '{n1}' diverged between --jobs 1 and --jobs 4");
    }
}

/// The figure-level contract also holds under the calibrated NoC tier,
/// where each worker owns a memoizing simulator instance. One figure is
/// enough here (the full registry under calibration is minutes of work);
/// fig16 sweeps 9 cells x 4 archs, all through the calibrated tier.
#[test]
fn calibrated_tier_figure_is_jobs_invariant() {
    let cx1 = FigCtx { jobs: 1, noc_fidelity: NocFidelity::Calibrated };
    let cx4 = FigCtx { jobs: 4, noc_fidelity: NocFidelity::Calibrated };
    let s = figures::run("fig16", &cx1).expect("fig16 registered");
    let p = figures::run("fig16", &cx4).expect("fig16 registered");
    assert_eq!(s, p);
}

/// `Engine::sweep(configs, jobs)` element i is exactly
/// `Engine::new(configs[i]).simulate()`, whatever `jobs` is.
#[test]
fn engine_sweep_equals_a_serial_loop() {
    let mut configs = Vec::new();
    for arch in [ArchKind::Cent, ArchKind::CompAirBase, ArchKind::CompAirOpt, ArchKind::AttAcc] {
        for seq in [4096usize, 16384] {
            let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
            rc.batch = 16;
            rc.seq_len = seq;
            configs.push(rc);
        }
    }
    let serial: Vec<_> = configs.iter().map(|c| Engine::new(c.clone()).simulate()).collect();
    let pooled = Engine::sweep(configs, 4);
    assert_eq!(serial.len(), pooled.len());
    for (a, b) in serial.iter().zip(&pooled) {
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        assert_eq!(a.throughput_tok_s.to_bits(), b.throughput_tok_s.to_bits());
        assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
        assert_eq!(a.layer_cost, b.layer_cost);
    }
}

/// Parallel anchor prefit ≡ lazy serial fit: a `CalibratedNoc` whose
/// anchors were warmed on 4 workers prices every collective with the
/// exact bits of one that fit each factor on demand.
#[test]
fn calibration_parallel_fit_matches_serial_fit() {
    let hw = HwConfig::paper();
    let warmed = CalibratedNoc::new(&hw);
    warmed.prefit(4);
    let lazy = CalibratedNoc::new(&hw);
    // price every anchor shape through both instances: the pool-warmed fit
    // and the on-demand serial fit must produce the same bits
    for (kind, elems, param) in anchor_grid(&hw) {
        let w = collective_cost(&warmed, kind, elems, param);
        let l = collective_cost(&lazy, kind, elems, param);
        assert_eq!(
            w.latency_ns.to_bits(),
            l.latency_ns.to_bits(),
            "{kind:?} elems={elems} param={param} diverged between prefit(4) and lazy fit"
        );
    }
    // and the rendered calibration table itself is jobs-invariant
    let r1 = calibration_report(&hw, 1);
    let r4 = calibration_report(&hw, 4);
    assert_eq!(r1.len(), r4.len());
    for (a, b) in r1.iter().zip(&r4) {
        assert_eq!(a.collective, b.collective);
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.analytic_ns.to_bits(), b.analytic_ns.to_bits());
        assert_eq!(a.simulated_ns.to_bits(), b.simulated_ns.to_bits());
        assert_eq!(a.calibrated_ns.to_bits(), b.calibrated_ns.to_bits());
    }
}
