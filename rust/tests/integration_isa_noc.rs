//! Integration: hierarchical-ISA programs driving the flit-level NoC,
//! cross-checked against the analytical collective models and the Python
//! reference semantics (through shared closed-form recurrences).

use compair::config::{HwConfig, SramGang};
use compair::isa::{plan, Machine, Plan, RowInst, RowProgram, ALL_BANKS};
use compair::noc::{curry_exp, exchange, StepOp};
use compair::util::bf16::bf16_round;

fn machine() -> Machine {
    Machine::new(&HwConfig::paper(), SramGang::In256Out16)
}

#[test]
fn full_softmax_denominator_pipeline() {
    // exp on every bank's score, reduce to bank 0, broadcast back, divide:
    // the Fig 10 softmax dataflow end to end on the machine.
    let mut m = machine();
    let scores: Vec<f32> = (0..16).map(|b| -0.1 * b as f32).collect();
    for (b, &s) in scores.iter().enumerate() {
        m.write_row(b, 0, &[s]);
    }
    let mut p = RowProgram::new();
    for i in RowProgram::exp_program(0, 10, 1, 6, ALL_BANKS).insts {
        p.push(i);
    }
    p.push(RowInst::NocReduce {
        op: StepOp::Add,
        src: 10,
        dst: 20,
        mask: ALL_BANKS,
        dst_bank: 0,
        len: 1,
    });
    p.push(RowInst::NocBCast { src: 20, dst: 30, mask: ALL_BANKS, src_bank: 0, len: 1 });
    let cost = m.run(&p, true);
    assert!(cost.latency_ns > 0.0);
    assert!(cost.counts.noc_alu_ops > 0);

    let exps: Vec<f32> = scores.iter().map(|&s| curry_exp(bf16_round(s), 6)).collect();
    let total: f32 = {
        // tree fold order (bf16)
        let mut v = exps.clone();
        let mut stride = 1;
        while stride < 16 {
            for i in (0..16).step_by(2 * stride) {
                v[i] = StepOp::Add.apply(v[i + stride], v[i]);
            }
            stride *= 2;
        }
        v[0]
    };
    for b in 0..16 {
        let got = m.read_row(b, 30, 1)[0];
        assert_eq!(got, total, "bank {b} denominator");
    }
}

#[test]
fn rope_pipeline_exchange_plus_ewmul_matches_reference() {
    let mut m = machine();
    let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
    m.write_row(2, 0, &x);
    let mut p = RowProgram::new();
    p.push(RowInst::rope_exchange(0, 100, x.len()));
    m.run(&p, true);
    let got = m.read_row(2, 100, x.len());
    // bank memory stores BF16 — compare against the rearrangement of the
    // quantized vector
    let xb: Vec<f32> = x.iter().map(|&v| bf16_round(v)).collect();
    assert_eq!(got, exchange::rope_rearrange(&xb));
}

#[test]
fn fused_plans_absorb_whole_programs() {
    for rounds in [2u32, 4, 6] {
        let p = RowProgram::exp_program(0, 50, 2, rounds, 1);
        let plans = plan(&p.insts, true);
        let chains: Vec<_> = plans
            .iter()
            .filter_map(|pl| match pl {
                Plan::Chain(c) => Some(c),
                _ => None,
            })
            .collect();
        assert_eq!(chains.len(), 1, "rounds={rounds}");
        assert_eq!(chains[0].iter_num as u32, rounds);
    }
}

#[test]
fn mixed_program_costs_compose() {
    let mut m = machine();
    for b in 0..16 {
        m.write_row(b, 0, &[1.0, 2.0, 3.0, 4.0]);
    }
    let mut p = RowProgram::new();
    p.push(RowInst::scalar(StepOp::Mul, 0, 50, 4, 2.0));
    p.push(RowInst::scalar(StepOp::Add, 50, 60, 4, -1.0));
    p.push(RowInst::NocReduce {
        op: StepOp::Add,
        src: 60,
        dst: 70,
        mask: ALL_BANKS,
        dst_bank: 5,
        len: 4,
    });
    let c = m.run(&p, true);
    // (x*2)-1 per bank, summed over 16 identical banks
    assert_eq!(m.read_row(5, 70, 4), vec![16.0, 48.0, 80.0, 112.0]);
    assert!(c.counts.noc_flit_hops > 0);
    assert!(c.counts.dram_col_rd > 0, "DRAM endpoints must be accounted");
}
