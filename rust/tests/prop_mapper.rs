//! Property tests for the operator auto-mapper: the search never loses to
//! the paper's static placement, never puts a non-linear op on a PIM bank,
//! and is bit-deterministic across worker counts. Uses the in-crate
//! deterministic property harness (no proptest vendored offline).

use compair::arch::{CachedCostModel, CostModel, System};
use compair::config::{ArchKind, MappingMode, ModelConfig, NocFidelity, Phase, RunConfig};
use compair::mapper::{
    search_phase, search_space_size, supported_placements, AutoMappedCostModel, Mapping,
    Placement, SearchConfig, Slot,
};
use compair::util::prop::check;
use compair::Engine;

/// Every architecture with a cost model (AttAcc is a roofline reference
/// and has no mapping space).
const PIM_ARCHS: [ArchKind; 5] = [
    ArchKind::Cent,
    ArchKind::CentCurry,
    ArchKind::CompAirBase,
    ArchKind::CompAirOpt,
    ArchKind::SramStack,
];

fn rc_for(arch: ArchKind, fid: NocFidelity) -> RunConfig {
    let mut rc = RunConfig::new(arch, ModelConfig::tiny());
    rc.noc_fidelity = fid;
    rc
}

/// (a) Never-lose: for every arch, at the closed-form fidelities, the
/// searched mapping's phase cost is <= the static mapping's, at random
/// shapes — and the winner re-prices to exactly the reported score
/// through the same lowering the report uses.
#[test]
fn prop_search_never_loses_at_closed_form_fidelities() {
    check("mapper never loses (analytic/calibrated)", 4, |g| {
        let batch = *g.pick(&[1usize, 8, 32]);
        let seq = g.usize_in(128, 2048);
        let phase = if g.bool(0.5) { Phase::Decode } else { Phase::Prefill };
        for arch in PIM_ARCHS {
            for fid in [NocFidelity::Analytic, NocFidelity::Calibrated] {
                let rc = rc_for(arch, fid);
                let res = search_phase(&rc, phase, batch, seq, &SearchConfig::default());
                assert!(
                    res.cost_ns <= res.static_cost_ns,
                    "{arch:?}/{fid:?} lost: {} > {}",
                    res.cost_ns,
                    res.static_cost_ns
                );
                assert!(res.mapping.is_valid_for(arch), "{arch:?}/{fid:?}");
                let sys = System::new(rc);
                let replay = sys.run_shape_mapped(phase, batch, seq, &res.mapping).latency_ns;
                assert_eq!(replay.to_bits(), res.cost_ns.to_bits(), "{arch:?}/{fid:?}");
            }
        }
    });
}

/// (a') Never-lose holds at the flit-level fidelity too. One fixed small
/// shape per arch and a narrow beam keep the mesh-simulation cost
/// bounded — the clamp is structural, not fidelity-dependent.
#[test]
fn simulated_fidelity_never_loses() {
    let cfg = SearchConfig { beam_width: 2, exhaustive_limit: 1, jobs: 1 };
    for arch in PIM_ARCHS {
        let rc = rc_for(arch, NocFidelity::Simulated);
        let res = search_phase(&rc, Phase::Decode, 2, 128, &cfg);
        assert!(
            res.cost_ns <= res.static_cost_ns,
            "{arch:?} simulated lost: {} > {}",
            res.cost_ns,
            res.static_cost_ns
        );
        assert!(res.mapping.is_valid_for(arch), "{arch:?}");
    }
}

/// (b) Validity: softmax/exp-style non-linear ops can never land on a
/// PIM bank — neither in any arch's option lists nor in any searched
/// winner.
#[test]
fn prop_nonlinear_ops_never_land_on_pim_banks() {
    let nonlinear = [Slot::Softmax, Slot::Rope, Slot::RmsNorm, Slot::Activation];
    for arch in PIM_ARCHS {
        for slot in nonlinear {
            for p in supported_placements(slot, arch) {
                assert!(
                    matches!(p, Placement::NocAlu | Placement::Host),
                    "{arch:?} offers {p:?} for {slot:?}"
                );
            }
        }
    }
    check("searched winners keep non-linears off PIM", 6, |g| {
        let arch = *g.pick(&PIM_ARCHS);
        let batch = *g.pick(&[1usize, 4, 16, 64]);
        let seq = g.usize_in(64, 4096);
        let rc = rc_for(arch, NocFidelity::Analytic);
        let res = search_phase(&rc, Phase::Decode, batch, seq, &SearchConfig::default());
        for m in [res.mapping, res.static_mapping] {
            assert!(m.is_valid_for(arch), "{arch:?}");
            for slot in nonlinear {
                assert!(
                    matches!(m.get(slot), Placement::NocAlu | Placement::Host),
                    "{arch:?} mapped {slot:?} onto a PIM engine: {}",
                    m.summary()
                );
            }
        }
    });
}

/// (c) Determinism: the same (config, shape) searches to a bit-identical
/// (mapping, score) on repeat runs and across worker counts.
#[test]
fn prop_search_is_deterministic_across_jobs() {
    check("search determinism across jobs", 4, |g| {
        let arch =
            *g.pick(&[ArchKind::CentCurry, ArchKind::CompAirBase, ArchKind::SramStack]);
        let batch = *g.pick(&[1usize, 8, 32]);
        let seq = g.usize_in(64, 2048);
        let rc = rc_for(arch, NocFidelity::Analytic);
        let run = |jobs| {
            search_phase(
                &rc,
                Phase::Decode,
                batch,
                seq,
                &SearchConfig { jobs, ..SearchConfig::default() },
            )
        };
        let a = run(1);
        let b = run(1);
        let c = run(4);
        for (other, tag) in [(&b, "repeat"), (&c, "jobs=4")] {
            assert_eq!(a.mapping, other.mapping, "{arch:?} {tag}");
            assert_eq!(a.cost_ns.to_bits(), other.cost_ns.to_bits(), "{arch:?} {tag}");
            assert_eq!(
                a.static_cost_ns.to_bits(),
                other.static_cost_ns.to_bits(),
                "{arch:?} {tag}"
            );
            assert_eq!(a.candidates_scored, other.candidates_scored, "{arch:?} {tag}");
        }
    });
}

/// (c') Engine-level determinism: `--mapping auto` one-shot reports are
/// bit-identical between `--jobs 1` and `--jobs 4`.
#[test]
fn auto_engine_reports_are_jobs_invariant() {
    for arch in [ArchKind::CompAirOpt, ArchKind::SramStack] {
        let mk = |jobs: usize| {
            let mut rc = rc_for(arch, NocFidelity::Analytic);
            rc.mapping = MappingMode::Auto;
            rc.batch = 16;
            rc.seq_len = 1024;
            rc.jobs = jobs;
            Engine::new(rc).simulate()
        };
        let r1 = mk(1);
        let r4 = mk(4);
        assert_eq!(r1.latency_ns.to_bits(), r4.latency_ns.to_bits(), "{arch:?}");
        assert_eq!(
            r1.energy.total_pj().to_bits(),
            r4.energy.total_pj().to_bits(),
            "{arch:?}"
        );
    }
}

/// The serving-facing model keeps the guarantee per iteration: the
/// shape-adaptive auto model never prices a batching iteration above the
/// static cached model, at random iteration shapes.
#[test]
fn prop_auto_iteration_cost_never_loses() {
    // the models hold interior caches, so build them inside the property
    // (the harness needs `RefUnwindSafe` captures) — a few iteration
    // shapes per case amortize the construction
    check("auto iteration <= static iteration", 6, |g| {
        let arch = *g.pick(&[ArchKind::CentCurry, ArchKind::CompAirOpt, ArchKind::SramStack]);
        let auto = AutoMappedCostModel::new(rc_for(arch, NocFidelity::Analytic));
        let stat = CachedCostModel::new(System::new(rc_for(arch, NocFidelity::Analytic)));
        for _ in 0..3 {
            let prefill = *g.pick(&[0usize, 64, 256, 1024]);
            let decode = *g.pick(&[0usize, 1, 8, 32]);
            let kv = g.usize_in(64, 4096);
            let a = auto.iteration_cost(prefill, decode, kv).latency_ns;
            let s = stat.iteration_cost(prefill, decode, kv).latency_ns;
            assert!(
                a <= s,
                "{arch:?} auto iteration lost at ({prefill},{decode},{kv}): {a} > {s}"
            );
        }
    });
}

/// A one-candidate space (Cent) must be *verbatim* static — same bits,
/// no search detour — so turning `--mapping auto` on for a searchless
/// arch is provably free.
#[test]
fn searchless_arch_auto_equals_static_bitwise() {
    let rc = rc_for(ArchKind::Cent, NocFidelity::Analytic);
    assert_eq!(search_space_size(&rc), 1);
    let stat = {
        let mut r = rc.clone();
        r.mapping = MappingMode::Static;
        Engine::new(r).simulate()
    };
    let auto = {
        let mut r = rc;
        r.mapping = MappingMode::Auto;
        Engine::new(r).simulate()
    };
    assert_eq!(stat.latency_ns.to_bits(), auto.latency_ns.to_bits());
    assert_eq!(stat.energy.total_pj().to_bits(), auto.energy.total_pj().to_bits());
}

/// The static mapping itself is what `Mapping::static_for` says it is:
/// rebinding any single decided slot changes the mapping, and the static
/// summary round-trips through the capability flags.
#[test]
fn static_mapping_matches_capability_flags() {
    for arch in PIM_ARCHS {
        let m = Mapping::static_for(arch);
        for slot in Slot::all() {
            let opts = supported_placements(slot, arch);
            assert_eq!(m.get(slot), opts[0], "{arch:?} {slot:?}");
        }
        let fc_expect = if arch.has_sram() { Placement::SramPim } else { Placement::DramPim };
        let nl_expect = if arch.has_curry() { Placement::NocAlu } else { Placement::Host };
        assert_eq!(m.get(Slot::FcQ), fc_expect, "{arch:?}");
        assert_eq!(m.get(Slot::Softmax), nl_expect, "{arch:?}");
    }
}
