//! The semantic-audit contract, from both sides.
//!
//! Positive: everything the repo ships audits clean — every default
//! lattice point on the test-sized model, the arch-independent global
//! slice (collective identities, calibration anchors/factors, serving +
//! cluster samples), and the `Engine::audit` facade. The per-point pass
//! is also jobs-invariant: fanning the lattice across the pool produces
//! the identical reports in the identical order.
//!
//! Negative: a seeded-defect corpus proves every `aud.*` code in
//! `analysis::ALL_CODES` can actually fire. Each defect is one doctored
//! artifact — a tampered report field, a `Defect` cost-model wrapper
//! injecting one wrong answer, a fabricated calibration row — fed to the
//! same check the real audit runs.

use std::collections::BTreeSet;

use compair::analysis::audit::{self, AuditOptions};
use compair::analysis::audit_lattice as lattice;
use compair::analysis::{self, CheckReport, ALL_CODES};
use compair::arch::{CostModel, PhaseReport, System};
use compair::config::{ArchKind, ModelConfig, Phase, RunConfig};
use compair::coordinator::{Cluster, ClusterConfig, RouterPolicy, ServeConfig, Server};
use compair::noc::CalibAnchor;
use compair::util::pool;
use compair::Engine;

fn tiny_rc(arch: ArchKind) -> RunConfig {
    let mut rc = RunConfig::new(arch, ModelConfig::tiny());
    rc.jobs = 1;
    rc
}

fn tiny_system() -> System {
    System::new(tiny_rc(ArchKind::CompAirOpt))
}

fn real_report() -> (PhaseReport, RunConfig) {
    let rc = tiny_rc(ArchKind::CompAirOpt);
    (System::new(rc.clone()).run_shape(Phase::Decode, 4, 512), rc)
}

/// How a [`Defect`] wrapper corrupts its inner model's answers.
enum DefectKind {
    /// Latency shrinks as batch grows — breaks monotonicity.
    ShrinkWithBatch,
    /// Every latency is 1 ns high — diverges from any reference.
    InflateLatency,
}

/// Test-only cost-model wrapper injecting exactly one violation.
struct Defect<M: CostModel> {
    inner: M,
    kind: DefectKind,
}

impl<M: CostModel> CostModel for Defect<M> {
    fn base(&self) -> &RunConfig {
        self.inner.base()
    }

    fn phase_report(&self, phase: Phase, batch: usize, seq_len: usize) -> PhaseReport {
        let mut r = self.inner.phase_report(phase, batch, seq_len);
        match self.kind {
            DefectKind::ShrinkWithBatch => r.latency_ns = 1000.0 / batch as f64,
            DefectKind::InflateLatency => r.latency_ns += 1.0,
        }
        r
    }
}

/// One seeded defect per audit code: `(code, report the defect produces)`.
fn corpus() -> Vec<(&'static str, CheckReport)> {
    let anchors = lattice::shape_anchors(false);
    let sys = tiny_system();

    let nan = {
        let (mut r, _) = real_report();
        r.latency_ns = f64::NAN;
        audit::check_phase_sanity("nan", &r)
    };
    let negative = {
        let (mut r, _) = real_report();
        r.energy.dram_pj = -1.0;
        audit::check_phase_sanity("negative", &r)
    };
    let unit = {
        let (mut r, _) = real_report();
        r.nonlinear_frac = 1.5;
        audit::check_phase_sanity("unit", &r)
    };
    let op_cons = {
        let (mut r, rc) = real_report();
        r.latency_ns *= 2.0; // ops no longer compose to the claimed total
        audit::check_phase_conservation("op-cons", &r, &rc, Phase::Decode, 4, 512)
    };
    let energy_cons = {
        let (mut r, rc) = real_report();
        r.energy.dram_pj += 1.0; // breakdown drifts from the re-priced counts
        audit::check_phase_conservation("energy-cons", &r, &rc, Phase::Decode, 4, 512)
    };
    let bytes = {
        let mut rep = CheckReport::default();
        audit::check_counter(&mut rep, "fabricated cxl_p2p", "cxl_bytes", 4095, 4096);
        rep.normalize();
        rep
    };
    let migration = {
        // a real cluster run with its migration energy tampered after the fact
        let rc = tiny_rc(ArchKind::CompAirOpt);
        let serve = ServeConfig { n_requests: 8, prompt_len: 64, gen_len: 4, ..Default::default() };
        let ccfg =
            ClusterConfig { replicas: 2, disagg: Some((1, 1)), router: RouterPolicy::RoundRobin };
        let mut cr = Cluster::new(rc.clone(), serve, ccfg).run();
        cr.migration_energy_pj += 123.0;
        audit::check_cluster_migration("tampered", &cr, &rc)
    };
    let monotonic = {
        let m = Defect { inner: tiny_system(), kind: DefectKind::ShrinkWithBatch };
        audit::check_monotonic("defect", &m, false)
    };
    let coherence = {
        let m = Defect { inner: tiny_system(), kind: DefectKind::InflateLatency };
        audit::check_model_coherence("defect", &sys, &m, &anchors)
    };
    let never_lose = {
        let m = Defect { inner: tiny_system(), kind: DefectKind::InflateLatency };
        audit::check_never_lose("defect", &m, &sys, &anchors)
    };
    let fidelity = {
        let a = CalibAnchor {
            collective: "reduce",
            shape: "elems=32 banks=16".to_string(),
            analytic_ns: 100.0,
            simulated_ns: 100.0,
            calibrated_ns: 160.0, // 60% residual, far outside the 20% gate
        };
        audit::check_fidelity_anchors(&[a])
    };
    let factor = {
        let mut rep = CheckReport::default();
        audit::check_factor(&mut rep, "reduce", 16, 100.0);
        rep.normalize();
        rep
    };

    vec![
        ("aud.non-finite", nan),
        ("aud.negative", negative),
        ("aud.unit-range", unit),
        ("aud.op-conservation", op_cons),
        ("aud.energy-conservation", energy_cons),
        ("aud.bytes-conservation", bytes),
        ("aud.bytes-conservation", migration),
        ("aud.monotonic", monotonic),
        ("aud.cache-coherence", coherence),
        ("aud.never-lose", never_lose),
        ("aud.fidelity-band", fidelity),
        ("aud.calibration-bounds", factor),
    ]
}

#[test]
fn every_seeded_defect_fires_its_code() {
    for (code, rep) in corpus() {
        assert!(rep.has_code(code), "defect for {code} did not fire:\n{}", rep.render_brief());
    }
}

#[test]
fn corpus_covers_every_registered_audit_code() {
    let covered: BTreeSet<&str> = corpus().iter().map(|(c, _)| *c).collect();
    let registered: BTreeSet<&str> =
        ALL_CODES.iter().copied().filter(|c| c.starts_with("aud.")).collect();
    assert_eq!(covered, registered, "negative corpus out of sync with ALL_CODES");
}

#[test]
fn descriptions_cover_every_registered_code() {
    for &code in ALL_CODES {
        assert!(
            analysis::code_description(code).is_some(),
            "code {code} has no --list-codes description"
        );
    }
    assert!(analysis::code_description("aud.no-such-code").is_none());
    // the registry spans all four families, prover codes included
    for prefix in ["isa.", "map.", "cfg.", "aud.", "prv."] {
        assert!(
            ALL_CODES.iter().any(|c| c.starts_with(prefix)),
            "no {prefix}* codes registered"
        );
    }
    for code in ["prv.unit-mismatch", "prv.non-monotone", "prv.whitelist-escape",
                 "prv.guard-unstable", "prv.overflow", "prv.unpriced-counter",
                 "prv.double-priced", "prv.eval-drift"] {
        assert!(ALL_CODES.contains(&code), "{code} not registered");
        assert!(analysis::code_description(code).is_some(), "{code} undescribed");
    }
}

#[test]
fn defects_only_fire_their_own_codes() {
    // each defect is one violation; its report must not drag in sanity
    // errors from unrelated invariants
    for (code, rep) in corpus() {
        for d in &rep.diags {
            assert_eq!(d.code, code, "defect for {code} also fired {}: {}", d.code, d.render());
        }
    }
}

#[test]
fn shipped_lattice_audits_clean_on_tiny() {
    let opts = AuditOptions::default();
    for p in lattice::points(&ArchKind::all(), &[ModelConfig::tiny()], false) {
        let rep = audit::audit_point(&p, &opts);
        assert!(rep.is_clean(), "{}:\n{}", p.label(), rep.render_brief());
    }
}

#[test]
fn global_audit_slice_is_clean() {
    let rep = audit::check_global(&AuditOptions::default());
    assert!(rep.is_clean(), "{}", rep.render_brief());
}

#[test]
fn engine_audit_facade_is_clean_and_matches_direct_call() {
    let rc = tiny_rc(ArchKind::CompAirOpt);
    let rep = Engine::new(rc.clone()).audit();
    assert!(rep.is_clean(), "{}", rep.render_brief());
    let p = lattice::AuditPoint {
        arch: rc.arch,
        model: rc.model.clone(),
        fidelity: rc.noc_fidelity,
        mapping: rc.mapping,
    };
    assert_eq!(rep, audit::audit_point(&p, &AuditOptions::default()));
}

#[test]
fn lattice_fanout_is_jobs_invariant() {
    let opts = AuditOptions::default();
    let points = lattice::points(
        &[ArchKind::Cent, ArchKind::CompAirOpt, ArchKind::AttAcc],
        &[ModelConfig::tiny()],
        false,
    );
    let serial: Vec<CheckReport> =
        points.iter().map(|p| audit::audit_point(p, &opts)).collect();
    let fanned = pool::par_map_indexed(4, points, |_, p| audit::audit_point(&p, &opts));
    assert_eq!(serial, fanned, "--jobs must not change audit output");
}

#[test]
fn serve_report_validator_accepts_a_real_run() {
    let rc = tiny_rc(ArchKind::CompAirOpt);
    let cfg = ServeConfig { n_requests: 8, prompt_len: 64, gen_len: 4, ..Default::default() };
    let r = Server::new(rc, cfg).run();
    let rep = audit::check_serve_report("real", &r);
    assert!(rep.is_clean(), "{}", rep.render_brief());
}
