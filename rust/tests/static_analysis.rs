//! The static-analysis contract, from both sides.
//!
//! Positive: everything the repo ships — Row-Level programs, static
//! mappings, the config zoo, the scenario SLOs — passes `compair check`
//! with zero errors, and the statically derived flit/op counts agree
//! exactly with the analytic closed forms at the calibration anchors.
//!
//! Negative: a seeded-defect corpus proves every code in
//! `analysis::ALL_CODES` can actually fire, so no lint rots into dead
//! configuration.

use std::collections::BTreeSet;

use compair::analysis::{
    self, config_check,
    isa_lint::{self, LintOptions},
    map_check, CheckReport, Severity, ALL_CODES,
};
use compair::config::{ArchKind, HwConfig, ModelConfig, RunConfig, SramGang, Voltage};
use compair::coordinator::{ClusterConfig, RouterPolicy};
use compair::isa::interp::BANK_MEM_ELEMS;
use compair::isa::{ExchangeMode, Machine, RowInst, RowProgram, ALL_BANKS};
use compair::mapper::{Mapping, Placement, Slot};
use compair::noc::StepOp;
use compair::workload::Slo;
use compair::Engine;

fn lint_with(insts: Vec<RowInst>, hw: &HwConfig, opts: &LintOptions) -> CheckReport {
    let prog = RowProgram { insts };
    isa_lint::lint(&prog, hw, SramGang::In256Out16, opts)
}

/// Structural lint only (flow facts about initial memory skipped).
fn lint_structural(insts: Vec<RowInst>) -> CheckReport {
    lint_with(insts, &HwConfig::paper(), &LintOptions::assume_initialized())
}

/// Full lint with no declared inputs (every read of fresh memory flags).
fn lint_flow(insts: Vec<RowInst>) -> CheckReport {
    lint_with(insts, &HwConfig::paper(), &LintOptions::with_inputs(vec![]))
}

fn fill(dst: usize, mask: u64, len: usize) -> RowInst {
    RowInst::Fill { dst, mask, len, value: 0.0 }
}

/// One seeded defect per lint code: `(code, report the defect produces)`.
fn corpus() -> Vec<(&'static str, CheckReport)> {
    let paper = HwConfig::paper();
    let mut narrow = HwConfig::paper();
    narrow.noc.mesh_cols = 2;

    let llama = || ModelConfig::by_name("llama2-7b").unwrap();
    let rc_cent = RunConfig::new(ArchKind::Cent, llama());
    let rc_opt = RunConfig::new(ArchKind::CompAirOpt, llama());
    let mut rc_big_kv = rc_opt.clone();
    rc_big_kv.batch = 512;
    rc_big_kv.seq_len = 32768;
    let rc_gpt =
        RunConfig::new(ArchKind::CompAirOpt, ModelConfig::by_name("gpt3-175b").unwrap());

    let cfg = |f: &dyn Fn(&mut RunConfig)| {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, llama());
        f(&mut rc);
        config_check::check_run(&rc)
    };

    vec![
        // --- ISA program linter ---
        ("isa.addr-bounds", lint_structural(vec![fill(BANK_MEM_ELEMS - 1, ALL_BANKS, 2)])),
        ("isa.mask-range", lint_structural(vec![fill(0, 1 << 16, 1)])),
        ("isa.mask-empty", lint_structural(vec![fill(0, 0, 1)])),
        ("isa.len-zero", lint_structural(vec![fill(0, ALL_BANKS, 0)])),
        (
            "isa.exchange-shape",
            lint_structural(vec![RowInst::NocExchange {
                mode: ExchangeMode::RPlus,
                src: 0,
                dst: 16,
                mask: ALL_BANKS,
                offset: 2,
                group: 3,
                len: 4,
            }]),
        ),
        ("isa.use-before-def", lint_flow(vec![RowInst::scalar(StepOp::Add, 0, 16, 4, 1.0)])),
        (
            "isa.dead-store",
            lint_flow(vec![fill(0, ALL_BANKS, 4), fill(0, ALL_BANKS, 4)]),
        ),
        (
            // three same-ALU steps with distinct args need three router
            // columns; a 2-column mesh can't schedule the chain
            "isa.lane-overflow",
            lint_with(
                vec![
                    RowInst::scalar(StepOp::Mul, 0, 16, 4, 1.0),
                    RowInst::scalar(StepOp::Mul, 16, 32, 4, 2.0),
                    RowInst::scalar(StepOp::Mul, 32, 48, 4, 3.0),
                ],
                &narrow,
                &LintOptions::assume_initialized(),
            ),
        ),
        (
            "isa.alu-conflict",
            lint_structural(vec![
                RowInst::scalar(StepOp::Add, 0, 16, 4, 1.0),
                RowInst::scalar(StepOp::Add, 16, 32, 4, 2.0),
            ]),
        ),
        (
            "isa.div-occupancy",
            lint_structural(vec![
                RowInst::scalar(StepOp::Div, 0, 16, 4, 2.0),
                RowInst::scalar(StepOp::Div, 16, 32, 4, 3.0),
            ]),
        ),
        (
            "isa.sram-order",
            lint_structural(vec![RowInst::SramCompute { src: 0, dst: 16, mask: ALL_BANKS, len: 4 }]),
        ),
        (
            "isa.sram-capacity",
            lint_structural(vec![RowInst::SramWrite { addr: 0, mask: ALL_BANKS, len: 4097 }]),
        ),
        (
            // rounds > 15 saturate IterNum: the greedy fallback windows
            // inflate per-element hops well past the 2r+2 closed form
            "isa.count-drift",
            isa_lint::exp_count_crosscheck(4, 20, &paper, 0.25),
        ),
        // --- mapping validator ---
        (
            "map.illegal-placement",
            map_check::check_mapping(
                &rc_cent,
                &Mapping::static_for(ArchKind::Cent).with(Slot::FcQ, Placement::SramPim),
            ),
        ),
        (
            "map.nonlinear-on-pim",
            map_check::check_mapping(
                &rc_cent,
                &Mapping::static_for(ArchKind::Cent).with(Slot::Softmax, Placement::DramPim),
            ),
        ),
        (
            // llama2-7b's up-projection share per bank exceeds the gang's
            // resident weights, so the static SRAM placement streams
            "map.sram-capacity",
            map_check::check_mapping(&rc_opt, &Mapping::static_for(ArchKind::CompAirOpt)),
        ),
        (
            "map.kv-capacity",
            map_check::check_mapping(&rc_big_kv, &Mapping::static_for(ArchKind::CompAirOpt)),
        ),
        (
            "map.weight-capacity",
            map_check::check_mapping(&rc_gpt, &Mapping::static_for(ArchKind::CompAirOpt)),
        ),
        // --- config consistency ---
        ("cfg.mesh-banks", cfg(&|rc| rc.hw.noc.mesh_rows = 8)),
        ("cfg.head-divisibility", cfg(&|rc| rc.model.n_heads = 3)),
        ("cfg.kv-dtype", cfg(&|rc| rc.model.n_heads = 3)),
        ("cfg.shape-positive", cfg(&|rc| rc.batch = 0)),
        ("cfg.tp-devices", cfg(&|rc| rc.tp = 64)),
        ("cfg.tp-remainder", cfg(&|rc| rc.devices = 12)),
        (
            "cfg.fabric-devices",
            cfg(&|rc| {
                rc.tp = 8;
                rc.devices = 64;
            }),
        ),
        ("cfg.gang-macros", cfg(&|rc| rc.hw.sram.macros_per_bank = 2)),
        ("cfg.voltage-corner", cfg(&|rc| rc.hw.sram.voltage = Voltage(1.2))),
        ("cfg.flit-capacity", cfg(&|rc| rc.hw.noc.flit_bits = 32)),
        ("cfg.slo-sanity", config_check::check_slo(&Slo { ttft_ns: 0, tpot_ns: 1 }, "corpus")),
        (
            "cfg.disagg-split",
            config_check::check_cluster(&ClusterConfig {
                replicas: 4,
                disagg: Some((0, 4)),
                router: RouterPolicy::RoundRobin,
            }),
        ),
    ]
}

#[test]
fn every_lint_code_fires_on_its_seeded_defect() {
    let mut fired: BTreeSet<&'static str> = BTreeSet::new();
    for (code, rep) in corpus() {
        assert!(
            rep.has_code(code),
            "seeded defect for {code} did not fire; report:\n{}",
            rep.render_brief()
        );
        for d in &rep.diags {
            fired.insert(d.code);
        }
    }
    for code in ALL_CODES {
        assert!(fired.contains(code), "no corpus defect triggers {code}");
    }
}

#[test]
fn corpus_codes_are_registered_exhaustively() {
    // the corpus keys must themselves be registered codes, one per code
    let keys: BTreeSet<&'static str> = corpus().into_iter().map(|(c, _)| c).collect();
    let all: BTreeSet<&'static str> = ALL_CODES.iter().copied().collect();
    assert_eq!(keys, all);
}

#[test]
fn shipped_configs_are_error_free_on_every_arch_and_model() {
    for arch in ArchKind::all() {
        for model in ModelConfig::zoo() {
            let name = model.name;
            let rep = Engine::new(RunConfig::new(arch, model)).check();
            assert!(rep.is_clean(), "{arch:?}/{name} fails check:\n{}", rep.render_brief());
        }
    }
}

#[test]
fn shipped_isa_programs_lint_clean() {
    let rep = analysis::check_isa_programs(&HwConfig::paper());
    assert!(rep.diags.is_empty(), "paper hw:\n{}", rep.render_brief());
    let rep = analysis::check_isa_programs(&HwConfig::paper_opt());
    assert!(rep.is_clean(), "paper_opt hw:\n{}", rep.render_brief());
}

#[test]
fn scenario_slos_are_sane() {
    let rep = config_check::check_scenarios();
    assert!(rep.is_clean(), "{}", rep.render_brief());
}

#[test]
fn static_counts_match_the_analytic_forms_exactly_at_anchors() {
    // zero tolerance: at the calibration anchors the plan-derived flit/op
    // totals must equal the arch/collective closed forms bit for bit
    for (len, rounds) in [(2usize, 8u32), (16, 8), (16, 4), (8, 6)] {
        let rep = isa_lint::exp_count_crosscheck(len, rounds, &HwConfig::paper(), 0.0);
        assert!(rep.diags.is_empty(), "len {len} rounds {rounds}:\n{}", rep.render_brief());
    }
}

#[test]
fn reports_are_normalized_and_deterministic() {
    let build = || {
        lint_flow(vec![
            fill(BANK_MEM_ELEMS, ALL_BANKS, 1),
            RowInst::scalar(StepOp::Add, 0, 16, 4, 1.0),
            fill(32, 0, 0),
        ])
    };
    let a = build();
    let b = build();
    assert_eq!(a, b, "lint is not deterministic");
    assert!(!a.is_clean());
    assert!(a.warnings() >= 2);
    assert!(a.diags.windows(2).all(|w| w[0] <= w[1]), "not sorted:\n{}", a.render_brief());
    assert_eq!(a.diags[0].severity, Severity::Error, "errors must sort first");
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "static ISA lint")]
fn machine_run_rejects_a_structurally_invalid_program_in_debug() {
    let hw = HwConfig::paper();
    let mut m = Machine::new(&hw, SramGang::In256Out16);
    let prog = RowProgram { insts: vec![fill(BANK_MEM_ELEMS, ALL_BANKS, 4)] };
    let _ = m.run(&prog, true);
}
