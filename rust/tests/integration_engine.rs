//! Golden API-equivalence tests for the `Engine` + `CostModel` redesign:
//! the new facade must reproduce the exact pre-refactor numbers, and the
//! memoizing `CachedCostModel` must be bit-for-bit identical to driving
//! the `System` simulator uncached.

use compair::arch::{attacc, simulate, AttAccConfig, CachedCostModel, CostModel, System};
use compair::config::{ArchKind, ModelConfig, NocFidelity, Phase, RunConfig};
use compair::coordinator::{Cluster, ClusterConfig, RouterPolicy, ServeConfig, Server};
use compair::util::json::ToJson;
use compair::workload::Scenario;
use compair::Engine;

fn rc(arch: ArchKind) -> RunConfig {
    let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
    rc.batch = 16;
    rc.seq_len = 4096;
    rc.tp = 8;
    rc.devices = 32;
    rc
}

fn assert_phase_reports_identical(a: &compair::arch::PhaseReport, b: &compair::arch::PhaseReport) {
    assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
    assert_eq!(a.throughput_tok_s.to_bits(), b.throughput_tok_s.to_bits());
    assert_eq!(a.nonlinear_frac.to_bits(), b.nonlinear_frac.to_bits());
    assert_eq!(a.collective_frac.to_bits(), b.collective_frac.to_bits());
    assert_eq!(a.bank_util.to_bits(), b.bank_util.to_bits());
    assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
    assert_eq!(a.layer_cost, b.layer_cost);
    assert_eq!(a.ops.len(), b.ops.len());
    for (x, y) in a.ops.iter().zip(&b.ops) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.cost, y.cost);
    }
}

#[test]
fn engine_simulate_reproduces_legacy_numbers_for_all_five_pim_archs() {
    for arch in [
        ArchKind::Cent,
        ArchKind::CentCurry,
        ArchKind::CompAirBase,
        ArchKind::CompAirOpt,
        ArchKind::SramStack,
    ] {
        let legacy = simulate(rc(arch));
        let engine = Engine::new(rc(arch)).simulate();
        assert_phase_reports_identical(&legacy, &engine);
    }
}

#[test]
fn engine_simulate_reproduces_attacc_roofline() {
    let c = rc(ArchKind::AttAcc);
    let legacy = attacc::simulate(&c, &AttAccConfig::default());
    let engine = Engine::new(c).simulate();
    assert_phase_reports_identical(&legacy, &engine);
}

#[test]
fn cached_cost_model_is_bit_identical_and_actually_caches() {
    let sys = System::new(rc(ArchKind::CompAirOpt));
    let cached = CachedCostModel::new(System::new(rc(ArchKind::CompAirOpt)));
    let shapes = [
        (Phase::Decode, 16usize, 4096usize),
        (Phase::Prefill, 1, 512),
        (Phase::Decode, 16, 4096), // repeat → hit
        (Phase::Decode, 1, 1),
    ];
    for (phase, batch, seq) in shapes {
        let a = sys.phase_report(phase, batch, seq);
        let b = cached.phase_report(phase, batch, seq);
        assert_phase_reports_identical(&a, &b);
    }
    let st = cached.stats();
    assert!(st.hits >= 1, "repeated shape must hit the cache");
    assert_eq!(st.misses, 3, "three distinct shapes were priced");
    // iteration-level cache too
    let i1 = cached.iteration_cost(256, 8, 2048);
    let i2 = cached.iteration_cost(256, 8, 2048);
    assert_eq!(i1, i2);
    assert_eq!(sys.iteration_cost(256, 8, 2048), i1);
}

#[test]
fn serve_scenario_golden_cached_equals_uncached() {
    // one `serve --scenario` run: same seed → identical report fields
    let cfg = ServeConfig {
        n_requests: 16,
        seed: 42,
        scenario: Some(Scenario::by_name("chat").unwrap()),
        ..Default::default()
    };
    let server = Server::new(rc(ArchKind::CompAirOpt), cfg.clone());
    let uncached = server.run_with_model(&System::new(rc(ArchKind::CompAirOpt)));
    let cached = server.run();
    let engine = Engine::new(rc(ArchKind::CompAirOpt)).serve(cfg);

    for r in [&cached, &engine] {
        assert_eq!(uncached.completed, r.completed);
        assert_eq!(uncached.rejected, r.rejected);
        assert_eq!(uncached.preempted, r.preempted);
        assert_eq!(uncached.unserved, r.unserved);
        assert_eq!(uncached.makespan_ns, r.makespan_ns);
        assert_eq!(uncached.tokens_out, r.tokens_out);
        assert_eq!(uncached.decode_iters, r.decode_iters);
        assert_eq!(uncached.throughput_tok_s.to_bits(), r.throughput_tok_s.to_bits());
        assert_eq!(uncached.ttft_p50_ns.to_bits(), r.ttft_p50_ns.to_bits());
        assert_eq!(uncached.ttft_p99_ns.to_bits(), r.ttft_p99_ns.to_bits());
        assert_eq!(uncached.tpot_p50_ns.to_bits(), r.tpot_p50_ns.to_bits());
        assert_eq!(uncached.tpot_p99_ns.to_bits(), r.tpot_p99_ns.to_bits());
        assert_eq!(uncached.slo_attainment.to_bits(), r.slo_attainment.to_bits());
        assert_eq!(uncached.energy.total_pj().to_bits(), r.energy.total_pj().to_bits());
        assert_eq!(uncached.energy_per_token_pj.to_bits(), r.energy_per_token_pj.to_bits());
        assert_eq!(uncached.per_class.len(), r.per_class.len());
        for (a, b) in uncached.per_class.iter().zip(&r.per_class) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.ttft_p99_ns.to_bits(), b.ttft_p99_ns.to_bits());
            assert_eq!(a.slo_attainment.to_bits(), b.slo_attainment.to_bits());
        }
    }
}

#[test]
fn serve_is_bit_reproducible_per_noc_fidelity_tier() {
    // the acceptance contract: `serve` accepts every fidelity tier, and
    // cached ≡ uncached results are preserved bit-for-bit per tier
    let cfg = ServeConfig {
        n_requests: 8,
        seed: 7,
        scenario: Some(Scenario::by_name("chat").unwrap()),
        ..Default::default()
    };
    for f in NocFidelity::all() {
        let mut c = rc(ArchKind::CompAirOpt);
        c.noc_fidelity = f;
        let server = Server::new(c.clone(), cfg.clone());
        let uncached = server.run_with_model(&System::new(c.clone()));
        let cached = server.run();
        assert_eq!(uncached.completed, cached.completed, "{f:?}");
        assert_eq!(uncached.makespan_ns, cached.makespan_ns, "{f:?}");
        assert_eq!(uncached.tokens_out, cached.tokens_out, "{f:?}");
        assert_eq!(
            uncached.throughput_tok_s.to_bits(),
            cached.throughput_tok_s.to_bits(),
            "{f:?}"
        );
        assert_eq!(uncached.ttft_p99_ns.to_bits(), cached.ttft_p99_ns.to_bits(), "{f:?}");
        assert_eq!(
            uncached.energy.total_pj().to_bits(),
            cached.energy.total_pj().to_bits(),
            "{f:?}"
        );
    }
    // the tiers are genuinely distinct models: the fidelity knob must
    // reach the costing (calibrated == analytic would mean it is ignored
    // — the correction factors come from real mesh runs)
    let lat = |f: NocFidelity| {
        let mut c = rc(ArchKind::CompAirOpt);
        c.noc_fidelity = f;
        System::new(c).phase_report(Phase::Decode, 16, 4096).latency_ns
    };
    let (a, cal, sim) = (
        lat(NocFidelity::Analytic),
        lat(NocFidelity::Calibrated),
        lat(NocFidelity::Simulated),
    );
    assert!(a > 0.0 && cal > 0.0 && sim > 0.0);
    // calibrated tracks the simulator exactly at the granule level
    assert!((cal - sim).abs() / sim < 1e-6, "calibrated {cal} vs simulated {sim}");
}

#[test]
fn cluster_golden_two_replicas_cached_equals_uncached() {
    // one 2-replica cluster run: same seed → identical report fields
    let serve = ServeConfig {
        n_requests: 12,
        seed: 42,
        scenario: Some(Scenario::by_name("mixed").unwrap()),
        ..Default::default()
    };
    let ccfg = ClusterConfig { replicas: 2, disagg: None, router: RouterPolicy::LeastLoadedKv };
    let cluster = Cluster::new(rc(ArchKind::CompAirOpt), serve.clone(), ccfg.clone());
    let uncached = cluster.run_with_model(&System::new(rc(ArchKind::CompAirOpt)));
    let cached = cluster.run();
    let engine = Engine::new(rc(ArchKind::CompAirOpt)).cluster(serve, ccfg);

    for r in [&cached, &engine] {
        assert_eq!(uncached.replicas, r.replicas);
        assert_eq!(uncached.migrations, r.migrations);
        assert_eq!(uncached.migration_bytes, r.migration_bytes);
        assert_eq!(uncached.report.completed, r.report.completed);
        assert_eq!(uncached.report.makespan_ns, r.report.makespan_ns);
        assert_eq!(uncached.report.tokens_out, r.report.tokens_out);
        assert_eq!(
            uncached.report.energy.total_pj().to_bits(),
            r.report.energy.total_pj().to_bits()
        );
        assert_eq!(uncached.per_replica.len(), r.per_replica.len());
        for (a, b) in uncached.per_replica.iter().zip(&r.per_replica) {
            assert_eq!(a.routed, b.routed);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.busy_ns, b.busy_ns);
            assert_eq!(a.tokens_out, b.tokens_out);
            assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        }
    }
}

#[test]
fn static_mapping_golden_all_five_pim_archs() {
    // the auto-mapper PR's regression contract: `mapping = static` (the
    // default) reproduces the pre-mapper numbers exactly — legacy
    // `simulate` ≡ Engine ≡ pinning the explicit static `Mapping`
    use compair::config::MappingMode;
    use compair::mapper::Mapping;
    for arch in [
        ArchKind::Cent,
        ArchKind::CentCurry,
        ArchKind::CompAirBase,
        ArchKind::CompAirOpt,
        ArchKind::SramStack,
    ] {
        let c = rc(arch);
        assert_eq!(c.mapping, MappingMode::Static, "static must stay the default");
        let legacy = simulate(c.clone());
        let engine = Engine::new(c).simulate();
        let pinned = Engine::new(rc(arch)).simulate_mapped(&Mapping::static_for(arch));
        assert_phase_reports_identical(&legacy, &engine);
        assert_phase_reports_identical(&legacy, &pinned);
    }
}

#[test]
fn serve_static_mapping_golden_and_searchless_auto() {
    use compair::config::MappingMode;
    let cfg = ServeConfig {
        n_requests: 10,
        seed: 42,
        scenario: Some(Scenario::by_name("chat").unwrap()),
        ..Default::default()
    };
    // serving with the knob explicitly at `static` is the pre-PR path
    let base = Server::new(rc(ArchKind::CompAirOpt), cfg.clone()).run();
    let mut st = rc(ArchKind::CompAirOpt);
    st.mapping = MappingMode::Static;
    let explicit = Server::new(st, cfg.clone()).run();
    assert_eq!(base.completed, explicit.completed);
    assert_eq!(base.makespan_ns, explicit.makespan_ns);
    assert_eq!(base.tokens_out, explicit.tokens_out);
    assert_eq!(base.throughput_tok_s.to_bits(), explicit.throughput_tok_s.to_bits());
    assert_eq!(base.energy_per_token_pj.to_bits(), explicit.energy_per_token_pj.to_bits());

    // a searchless arch (Cent: one-candidate space) under `auto` must be
    // the static run verbatim — the knob is provably free there
    let run_cent = |mode: MappingMode| {
        let mut c = rc(ArchKind::Cent);
        c.mapping = mode;
        Server::new(c, cfg.clone()).run()
    };
    let cs = run_cent(MappingMode::Static);
    let ca = run_cent(MappingMode::Auto);
    assert_eq!(cs.completed, ca.completed);
    assert_eq!(cs.makespan_ns, ca.makespan_ns);
    assert_eq!(cs.tokens_out, ca.tokens_out);
    assert_eq!(cs.throughput_tok_s.to_bits(), ca.throughput_tok_s.to_bits());
    assert_eq!(cs.energy_per_token_pj.to_bits(), ca.energy_per_token_pj.to_bits());
}

// ---- JSON well-formedness (no external parser offline, so a minimal
// recursive-descent validator lives in the test) ----

fn skip_ws(s: &[u8], mut i: usize) -> usize {
    while i < s.len() && (s[i] as char).is_ascii_whitespace() {
        i += 1;
    }
    i
}

fn validate_value(s: &[u8], i: usize) -> Result<usize, String> {
    let i = skip_ws(s, i);
    let err = |msg: &str, at: usize| Err(format!("{msg} at byte {at}"));
    match s.get(i) {
        None => err("unexpected end", i),
        Some(b'{') => {
            let mut i = skip_ws(s, i + 1);
            if s.get(i) == Some(&b'}') {
                return Ok(i + 1);
            }
            loop {
                i = validate_string(s, skip_ws(s, i))?;
                i = skip_ws(s, i);
                if s.get(i) != Some(&b':') {
                    return err("expected ':'", i);
                }
                i = validate_value(s, i + 1)?;
                i = skip_ws(s, i);
                match s.get(i) {
                    Some(b',') => i += 1,
                    Some(b'}') => return Ok(i + 1),
                    _ => return err("expected ',' or '}'", i),
                }
            }
        }
        Some(b'[') => {
            let mut i = skip_ws(s, i + 1);
            if s.get(i) == Some(&b']') {
                return Ok(i + 1);
            }
            loop {
                i = validate_value(s, i)?;
                i = skip_ws(s, i);
                match s.get(i) {
                    Some(b',') => i += 1,
                    Some(b']') => return Ok(i + 1),
                    _ => return err("expected ',' or ']'", i),
                }
            }
        }
        Some(b'"') => validate_string(s, i),
        Some(b't') if s[i..].starts_with(b"true") => Ok(i + 4),
        Some(b'f') if s[i..].starts_with(b"false") => Ok(i + 5),
        Some(b'n') if s[i..].starts_with(b"null") => Ok(i + 4),
        Some(c) if c.is_ascii_digit() || *c == b'-' => {
            let mut j = i + 1;
            while j < s.len()
                && (s[j].is_ascii_digit() || matches!(s[j], b'.' | b'e' | b'E' | b'+' | b'-'))
            {
                j += 1;
            }
            Ok(j)
        }
        Some(_) => err("unexpected token", i),
    }
}

fn validate_string(s: &[u8], i: usize) -> Result<usize, String> {
    if s.get(i) != Some(&b'"') {
        return Err(format!("expected string at byte {i}"));
    }
    let mut i = i + 1;
    while i < s.len() {
        match s[i] {
            b'\\' => i += 2,
            b'"' => return Ok(i + 1),
            _ => i += 1,
        }
    }
    Err("unterminated string".into())
}

fn assert_valid_json(s: &str) {
    let bytes = s.as_bytes();
    let end = validate_value(bytes, 0).unwrap_or_else(|e| panic!("invalid JSON ({e}): {s}"));
    assert_eq!(skip_ws(bytes, end), bytes.len(), "trailing garbage in JSON: {s}");
}

#[test]
fn every_report_type_serializes_to_well_formed_json() {
    let engine = Engine::new(rc(ArchKind::CompAirOpt));
    assert_valid_json(&engine.rc().to_json_string());
    assert_valid_json(&engine.simulate().to_json_string());

    let cfg = ServeConfig {
        n_requests: 6,
        seed: 42,
        scenario: Some(Scenario::by_name("mixed").unwrap()),
        ..Default::default()
    };
    assert_valid_json(&cfg.to_json_string());
    let serve = engine.serve(cfg.clone());
    let serve_json = serve.to_json_string();
    assert_valid_json(&serve_json);
    assert!(serve_json.contains("\"per_class\""));
    assert!(serve_json.contains("\"slo_attainment\""));

    let sc = engine.serve_scenario(Scenario::by_name("chat").unwrap(), 4, 42);
    assert_valid_json(&sc.to_json_string());

    let cluster = engine.cluster(
        cfg,
        ClusterConfig { disagg: Some((1, 1)), router: RouterPolicy::DeadlineAware, replicas: 2 },
    );
    let cluster_json = cluster.to_json_string();
    assert_valid_json(&cluster_json);
    assert!(cluster_json.contains("\"per_replica\""));
    assert!(cluster_json.contains("\"migration_bytes\""));
}
