//! Cross-layer numeric validation: the AOT-compiled JAX/Pallas artifacts
//! executed through the PJRT runtime must agree with the rust-side
//! reference implementations (the ISA interpreter's math and the
//! substrate models' BF16 datapaths).
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use compair::dram::PimBank;
use compair::noc::{curry_exp, exchange};
use compair::runtime::{Runtime, Tensor};
use compair::util::bf16::bf16_round;
use compair::util::XorShiftRng;

fn runtime() -> Option<Runtime> {
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping PJRT cross-layer tests: {e}");
            return None;
        }
    };
    if !rt.artifact_path("curry_exp").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(rt)
}

#[test]
fn curry_exp_artifact_matches_rust_exactly() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShiftRng::new(5);
    let xs: Vec<f32> = (0..64).map(|_| rng.next_f32_in(-1.5, 1.5)).collect();
    let model = rt.load("curry_exp").unwrap();
    let out = model.run(&[Tensor::new(xs.clone(), &[64])]).unwrap();
    assert_eq!(out.len(), 1);
    for (i, (&got, &x)) in out[0].data.iter().zip(&xs).enumerate() {
        let want = curry_exp(bf16_round(x), 6);
        assert!(
            (got - want).abs() < 1e-6,
            "elem {i}: hlo={got} rust={want} (x={x})"
        );
    }
}

#[test]
fn gemv_artifact_matches_bank_datapath() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShiftRng::new(7);
    let w = rng.vec_f32(64 * 64, -0.5, 0.5);
    let x = rng.vec_f32(64, -0.5, 0.5);
    let model = rt.load("gemv_bank").unwrap();
    let out = model
        .run(&[Tensor::new(w.clone(), &[64, 64]), Tensor::new(x.clone(), &[64])])
        .unwrap();
    let want = PimBank::gemv_f32(&w, &x, 64, 64);
    for (i, (&got, &want)) in out[0].data.iter().zip(&want).enumerate() {
        // same BF16 inputs; accumulation order differs (dot vs serial MAC)
        assert!(
            (got - want).abs() < 0.05,
            "elem {i}: hlo={got} rust={want}"
        );
    }
}

#[test]
fn rope_artifact_matches_exchange_semantics() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShiftRng::new(9);
    let n = 16usize;
    let d = 16usize;
    let x = rng.vec_f32(n * d, -1.0, 1.0);
    let cos = vec![0.6f32; n * d];
    let sin = vec![0.8f32; n * d];
    let model = rt.load("rope").unwrap();
    let out = model
        .run(&[
            Tensor::new(x.clone(), &[n, d]),
            Tensor::new(cos.clone(), &[n, d]),
            Tensor::new(sin.clone(), &[n, d]),
        ])
        .unwrap();
    for row in 0..n {
        let xr = &x[row * d..(row + 1) * d];
        let want = exchange::rope_apply(xr, &cos[..d], &sin[..d]);
        for i in 0..d {
            let got = out[0].data[row * d + i];
            assert!(
                (got - want[i]).abs() < 0.01,
                "row {row} elem {i}: hlo={got} rust={}",
                want[i]
            );
        }
    }
}

#[test]
fn softmax_artifact_rows_sum_to_one_and_match_rust() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = XorShiftRng::new(11);
    let (rows, seq) = (8usize, 128usize);
    let x = rng.vec_f32(rows * seq, -4.0, 4.0);
    let model = rt.load("curry_softmax").unwrap();
    let out = model.run(&[Tensor::new(x.clone(), &[rows, seq])]).unwrap();
    for r in 0..rows {
        let row_in = &x[r * seq..(r + 1) * seq];
        let row_out = &out[0].data[r * seq..(r + 1) * seq];
        let sum: f32 = row_out.iter().sum();
        assert!((sum - 1.0).abs() < 0.05, "row {r} sums to {sum}");
        // rust-side curry softmax reference
        let m = row_in.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = row_in
            .iter()
            .map(|&v| compair::noc::curry_exp_rr(bf16_round((v - m).clamp(-8.0, 0.0)), 8, 2))
            .collect();
        let s: f32 = e.iter().sum();
        for i in 0..seq {
            let want = bf16_round(e[i] / bf16_round(s));
            assert!(
                (row_out[i] - want).abs() < 0.02,
                "row {r} elem {i}: hlo={} rust={want}",
                row_out[i]
            );
        }
    }
}

#[test]
fn decode_step_runs_and_updates_cache() {
    let Some(mut rt) = runtime() else { return };
    // TINY config: 2 layers, batch 2, 4 heads, max_seq 64, d_head 16
    let (l, b, h, s, dh, d) = (2usize, 2usize, 4usize, 64usize, 16usize, 64usize);
    let mut rng = XorShiftRng::new(13);
    let x = rng.vec_f32(b * d, -0.5, 0.5);
    let kc = vec![0.0f32; l * b * h * s * dh];
    let vc = vec![0.0f32; l * b * h * s * dh];
    let model = rt.load("decode_step").unwrap();
    let run_once = |model: &compair::runtime::LoadedModel| {
        model
            .run(&[
                Tensor::new(x.clone(), &[b, 1, d]),
                Tensor::new(kc.clone(), &[l, b, h, s, dh]),
                Tensor::new(vc.clone(), &[l, b, h, s, dh]),
                Tensor { data: vec![0.0], dims: vec![] }, // pos=0 (i32 cast below)
            ])
            .unwrap()
    };
    // pos is i32 — craft literal manually
    let out = {
        let x_t = Tensor::new(x.clone(), &[b, 1, d]);
        let kc_t = Tensor::new(kc.clone(), &[l, b, h, s, dh]);
        let vc_t = Tensor::new(vc.clone(), &[l, b, h, s, dh]);
        let _ = run_once; // path above handles f32; pos needs i32:
        model.run_with_i32_scalar(&[x_t, kc_t, vc_t], 0).unwrap()
    };
    assert_eq!(out[0].dims, vec![b, 1, d]);
    assert_eq!(out[1].dims, vec![l, b, h, s, dh]);
    // the cache row at pos 0 must now be non-zero for every layer/head
    let k_new = &out[1];
    let mut nonzero = 0;
    for li in 0..l {
        for bi in 0..b {
            for hi in 0..h {
                let base = (((li * b + bi) * h + hi) * s) * dh;
                if k_new.data[base..base + dh].iter().any(|&v| v != 0.0) {
                    nonzero += 1;
                }
            }
        }
    }
    assert_eq!(nonzero, l * b * h, "every (layer,batch,head) must write pos 0");
    // outputs must be finite and non-trivial
    assert!(out[0].data.iter().all(|v| v.is_finite()));
    assert!(out[0].data.iter().any(|&v| v != 0.0));
}
