//! Integration tests for `compair prove` — the static prover over the
//! captured cost-expression IR (`analysis/cost_ir.rs` + `analysis/prove.rs`).
//!
//! Positive: every shipped (arch, model, fidelity, phase) point in the
//! default prove lattice certifies with zero errors, the capture-mode run
//! is bit-identical to the plain run in both directions (the soundness
//! anchor), and fanning the lattice over the worker pool is invariant in
//! the job count. Negative: seeded defects (a doctored budget, doctored
//! totals) fire exactly their own `prv.*` codes — the per-pass doctored-IR
//! corpus lives next to the passes in `analysis/prove.rs`.

use compair::analysis::prove::{
    self, active_vars, prove_point, prove_point_budget, shape_box, ProvePoint,
};
use compair::arch::System;
use compair::config::{ArchKind, ModelConfig, NocFidelity, Phase};
use compair::util::pool;
use compair::Engine;

fn point(arch: ArchKind, model: ModelConfig, fidelity: NocFidelity, phase: Phase) -> ProvePoint {
    ProvePoint { arch, model, fidelity, phase }
}

#[test]
fn the_default_lattice_proves_clean() {
    // the exact set ci.sh gates on: every non-roofline arch, tiny +
    // llama2-7b, both closed-form NoC tiers, both phases
    let pts = prove::points(&ArchKind::all(), &prove::default_models());
    assert!(pts.len() >= 16, "lattice unexpectedly small: {}", pts.len());
    for p in pts {
        let label = p.label();
        let (rep, sum) = prove_point(&p);
        assert_eq!(rep.errors(), 0, "{label}:\n{}", rep.render_brief());
        assert!(sum.complete, "{label}: budget exhausted");
        assert!(sum.certified > 0, "{label}: nothing certified");
        assert!(sum.lat_lo_ns > 0.0 && sum.lat_lo_ns <= sum.lat_hi_ns, "{label}");
        assert!(sum.pj_lo > 0.0 && sum.pj_lo <= sum.pj_hi, "{label}");
        assert!(sum.events_hi > 0, "{label}");
    }
}

#[test]
fn global_pricing_coverage_proves_clean() {
    let rep = prove::check_global();
    assert!(rep.is_clean(), "{}", rep.render_brief());
}

#[test]
fn prove_results_are_invariant_in_the_job_count() {
    let pts = prove::points(
        &[ArchKind::CompAirOpt, ArchKind::Cent],
        &[ModelConfig::tiny()],
    );
    let run = |jobs: usize| -> Vec<String> {
        pool::par_map_indexed(jobs, pts.clone(), |_, p| {
            let (rep, sum) = prove_point(&p);
            format!(
                "{} e={} w={} cells={} cert={} corners={} lat={:x}..{:x} pj={:x}..{:x} ev={}",
                sum.label,
                rep.errors(),
                rep.warnings(),
                sum.cells,
                sum.certified,
                sum.corners,
                sum.lat_lo_ns.to_bits(),
                sum.lat_hi_ns.to_bits(),
                sum.pj_lo.to_bits(),
                sum.pj_hi.to_bits(),
                sum.events_hi,
            )
        })
    };
    assert_eq!(run(1), run(4));
}

#[test]
fn starved_budget_fires_only_guard_unstable() {
    // calibrated decode on llama2-7b needs subdivision (the NoC factor
    // key bands the batch axis); one cell cannot certify the whole box
    let p = point(
        ArchKind::CompAirOpt,
        ModelConfig::by_name("llama2-7b").unwrap(),
        NocFidelity::Calibrated,
        Phase::Decode,
    );
    let (rep, sum) = prove_point_budget(&p, 1);
    assert!(!sum.complete);
    assert!(rep.has_code("prv.guard-unstable"), "{}", rep.render_brief());
    assert_eq!(rep.errors(), 0, "starvation must degrade, not fail:\n{}", rep.render_brief());
    for d in &rep.diags {
        assert_eq!(d.code, "prv.guard-unstable", "stray code: {}", d.code);
    }
    // the same point certifies under the default budget
    let (rep, sum) = prove_point(&p);
    assert_eq!(rep.errors(), 0, "{}", rep.render_brief());
    assert!(sum.complete);
}

#[test]
fn captured_totals_are_monotone_over_the_corner_grid() {
    // independent restatement of the certificate at the lib level: walk a
    // concrete (batch, kv) grid and require componentwise dominance of
    // the captured pre-epilogue totals
    let p = point(
        ArchKind::CompAirOpt,
        ModelConfig::tiny(),
        NocFidelity::Analytic,
        Phase::Decode,
    );
    let sys = System::new(p.rc());
    let m = sys.static_mapping();
    let grid: Vec<(usize, usize)> = [1usize, 4, 16, 64]
        .iter()
        .flat_map(|&b| [128usize, 1024, 8192].iter().map(move |&kv| (b, kv)))
        .collect();
    let evals: Vec<((usize, usize), f64, f64)> = grid
        .iter()
        .map(|&(b, kv)| {
            let (_, cap) = sys.run_shape_captured(Phase::Decode, b, kv, &m);
            ((b, kv), cap.total.latency_ns, cap.dynamic_pj)
        })
        .collect();
    for (pa, la, ea) in &evals {
        for (pb, lb, eb) in &evals {
            if pa.0 <= pb.0 && pa.1 <= pb.1 {
                assert!(la <= lb, "latency dropped {pa:?} -> {pb:?}: {la} > {lb}");
                assert!(ea <= eb, "energy dropped {pa:?} -> {pb:?}: {ea} > {eb}");
            }
        }
    }
}

#[test]
fn shape_boxes_match_their_active_vars() {
    for phase in [Phase::Decode, Phase::Prefill] {
        let bx = shape_box(phase);
        let vars = active_vars(phase);
        for v in vars {
            let i = v.index();
            assert!(bx.lo[i] < bx.hi[i], "{phase:?}: {v:?} axis is degenerate");
        }
        // inactive axes are singleton so corners only vary active vars
        let active: Vec<usize> = vars.iter().map(|v| v.index()).collect();
        for i in 0..3 {
            if !active.contains(&i) {
                assert_eq!(bx.lo[i], bx.hi[i], "{phase:?}: axis {i} should be pinned");
            }
        }
    }
}

#[test]
fn engine_facade_proves_both_phases() {
    let rep = Engine::new(ProvePoint {
        arch: ArchKind::CompAirOpt,
        model: ModelConfig::tiny(),
        fidelity: NocFidelity::Calibrated,
        phase: Phase::Decode, // facade proves both phases regardless
    }
    .rc())
    .prove();
    assert_eq!(rep.errors(), 0, "{}", rep.render_brief());
}
