//! Property-based invariants over the simulators, using the in-crate
//! deterministic property harness (no proptest vendored offline).

use compair::arch::{collective as coll, simulate};
use compair::config::{
    ArchKind, DramConfig, HwConfig, ModelConfig, NocConfig, RunConfig, SramGang,
};
use compair::dram::PimBank;
use compair::isa::{Machine, RowInst, RowProgram};
use compair::noc::packet::{Packet, PacketType, PathStep, RouterId, StepOp};
use compair::noc::{curry_exp, trees, Mesh};
use compair::sram::bank::{SramBank, WeightPolicy};
use compair::util::prop::check;

#[test]
fn prop_mesh_delivers_every_packet_exactly_once() {
    check("mesh delivery", 40, |g| {
        let cfg = NocConfig::default();
        let mut m = Mesh::new(&cfg);
        let n = g.usize_in(1, 40);
        let mut ids = Vec::new();
        for _ in 0..n {
            let src = RouterId::new(g.usize_in(0, 3), g.usize_in(0, 15));
            let dst = RouterId::new(g.usize_in(0, 3), g.usize_in(0, 15));
            let p = Packet::new(
                PacketType::Write,
                src,
                g.f32_in(-10.0, 10.0),
                vec![PathStep::relay(dst)],
            );
            ids.push(m.inject(p));
        }
        m.run(1_000_000);
        let mut delivered: Vec<u64> = m.take_deliveries().iter().map(|d| d.packet_id).collect();
        delivered.sort_unstable();
        ids.sort_unstable();
        assert_eq!(delivered, ids, "every injected packet delivered exactly once");
    });
}

#[test]
fn prop_tree_reduce_equals_serial_fold() {
    check("tree reduce == serial fold (bf16)", 25, |g| {
        let banks = *g.pick(&[2usize, 4, 8, 16]);
        let root = g.usize_in(0, banks - 1);
        let vals = g.vec_f32(banks, -4.0, 4.0);
        let mut m = Mesh::new(&NocConfig::default());
        let r = trees::reduce(&mut m, &[vals.clone()], StepOp::Add, root, banks);
        // the tree folds in a fixed pairing order; recompute the same order
        let expect = {
            use compair::util::bf16::bf16_round;
            let mut p: Vec<f32> = vals.iter().map(|&v| bf16_round(v)).collect();
            // logical relabel: node l holds vals[l ^ root]
            let mut logical: Vec<f32> = (0..banks).map(|l| p[l ^ root]).collect();
            let mut stride = 1;
            while stride < banks {
                for i in (0..banks).step_by(2 * stride) {
                    logical[i] = StepOp::Add.apply(logical[i + stride], logical[i]);
                }
                stride *= 2;
            }
            p.clear();
            logical[0]
        };
        assert_eq!(r.values[0], expect);
    });
}

#[test]
fn prop_isa_fusion_never_changes_results() {
    check("fusion preserves semantics", 12, |g| {
        let hw = HwConfig::paper();
        let len = g.usize_in(1, 6);
        let rounds = g.usize_in(2, 6) as u32;
        let bank = g.usize_in(0, 15);
        let xs = g.vec_f32(len, -1.2, 1.2);
        let run = |fuse: bool| {
            let mut m = Machine::new(&hw, SramGang::In256Out16);
            m.write_row(bank, 0, &xs);
            let p = RowProgram::exp_program(0, 3000, len, rounds, 1 << bank);
            m.run(&p, fuse);
            m.read_row(bank, 3000, len)
        };
        let fused = run(true);
        let unfused = run(false);
        assert_eq!(fused, unfused);
        for (i, v) in fused.iter().enumerate() {
            use compair::util::bf16::bf16_round;
            assert_eq!(*v, curry_exp(bf16_round(xs[i]), rounds), "elem {i}");
        }
    });
}

#[test]
fn prop_dram_latency_monotone_in_work() {
    check("dram gemv latency monotone", 50, |g| {
        let bank = PimBank::new(&DramConfig::default());
        let o = g.usize_in(1, 64);
        let i = g.usize_in(1, 4096);
        let b = g.usize_in(1, 32);
        let base = bank.gemv(o, i, b).latency_ns;
        assert!(bank.gemv(o + 1, i, b).latency_ns >= base);
        assert!(bank.gemv(o, i + 64, b).latency_ns >= base);
        assert!(bank.gemv(o, i, b + 1).latency_ns > base);
    });
}

#[test]
fn prop_sram_batch_amortization_monotone() {
    check("sram per-token cost falls with batch", 30, |g| {
        let hw = HwConfig::paper();
        let s = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
        let o = g.usize_in(8, 64);
        let i = g.usize_in(256, 4096);
        let b = g.usize_in(1, 32);
        let t1 = s.gemm(o, i, b, WeightPolicy::Reload).latency_ns / b as f64;
        let t2 = s.gemm(o, i, b * 4, WeightPolicy::Reload).latency_ns / (b * 4) as f64;
        assert!(t2 <= t1 * 1.01, "per-token cost must not grow: {t1} -> {t2}");
    });
}

#[test]
fn prop_costs_and_energy_nonnegative_and_finite() {
    check("simulate is finite & positive", 20, |g| {
        let arch = *g.pick(&[
            ArchKind::Cent,
            ArchKind::CentCurry,
            ArchKind::CompAirBase,
            ArchKind::CompAirOpt,
        ]);
        let model = ModelConfig::by_name(*g.pick(&[
            "llama2-7b",
            "llama2-13b",
            "llama2-70b",
            "qwen-72b",
            "gpt3-175b",
        ]))
        .unwrap();
        let mut rc = RunConfig::new(arch, model);
        rc.batch = *g.pick(&[1usize, 8, 64]);
        rc.seq_len = *g.pick(&[128usize, 4096, 65536]);
        rc.tp = *g.pick(&[1usize, 4, 8]);
        rc.devices = 32;
        let r = simulate(rc);
        assert!(r.latency_ns.is_finite() && r.latency_ns > 0.0);
        assert!(r.throughput_tok_s.is_finite() && r.throughput_tok_s > 0.0);
        assert!(r.energy.total_pj().is_finite() && r.energy.total_pj() > 0.0);
        assert!((0.0..=1.0 + 1e-9).contains(&r.nonlinear_frac));
        assert!((0.0..=1.0 + 1e-9).contains(&r.bank_util));
    });
}

#[test]
fn prop_collective_costs_scale_sanely() {
    check("collectives monotone in elems", 40, |g| {
        let cfg = NocConfig::default();
        let e = g.usize_in(1, 10_000) as u64;
        let r1 = coll::noc_reduce(e, 16, &cfg).latency_ns;
        let r2 = coll::noc_reduce(e * 2, 16, &cfg).latency_ns;
        assert!(r2 >= r1);
        let b1 = coll::noc_broadcast(e, 16, &cfg).latency_ns;
        assert!(coll::noc_broadcast(e * 2, 16, &cfg).latency_ns >= b1);
    });
}

#[test]
fn prop_machine_memory_isolation_between_banks() {
    check("bank memory isolation", 15, |g| {
        let hw = HwConfig::paper();
        let mut m = Machine::new(&hw, SramGang::In256Out16);
        let a = g.usize_in(0, 15);
        let b = (a + g.usize_in(1, 15)) % 16;
        let data = g.vec_f32(8, -2.0, 2.0);
        m.write_row(a, 64, &data);
        let mut p = RowProgram::new();
        p.push(RowInst::scalar(StepOp::Add, 64, 128, 8, 1.0));
        // only bank a is masked
        if let RowInst::NocScalar { mask, .. } = &mut p.insts[0] {
            *mask = 1 << a;
        }
        m.run(&p, true);
        assert_eq!(m.read_row(b, 128, 8), vec![0.0; 8], "bank {b} must be untouched");
        let expect: Vec<f32> = data
            .iter()
            .map(|&v| StepOp::Add.apply(v, 1.0))
            .collect();
        assert_eq!(m.read_row(a, 128, 8), expect);
    });
}

#[test]
fn prop_analytic_and_simulated_noc_agree_in_ordering() {
    // The calibration contract's foundation: across random shapes of one
    // collective (same structural parameter, varying volume), the closed
    // forms and the flit-level simulator must rank costs identically —
    // a cheaper shape under one tier is never pricier under the other.
    use compair::noc::model::{collective_cost, AnalyticNoc, NocCollective, SimulatedNoc};
    let hw = HwConfig::paper();
    check("analytic vs simulated NoC ordering", 30, |g| {
        let analytic = AnalyticNoc::new(hw.noc.clone());
        let sim = SimulatedNoc::new(&hw);
        let kind = *g.pick(&[NocCollective::Reduce, NocCollective::Broadcast, NocCollective::Exp]);
        let param = match kind {
            NocCollective::Exp => *g.pick(&[4u64, 6, 8]),
            _ => 1 << g.usize_in(1, 4) as u64, // banks in {2,4,8,16}
        };
        let e1 = g.usize_in(1, 4096) as u64;
        let e2 = g.usize_in(1, 4096) as u64;
        let a1 = collective_cost(&analytic, kind, e1, param).latency_ns;
        let a2 = collective_cost(&analytic, kind, e2, param).latency_ns;
        let s1 = collective_cost(&sim, kind, e1, param).latency_ns;
        let s2 = collective_cost(&sim, kind, e2, param).latency_ns;
        if a1 < a2 {
            assert!(s1 <= s2, "{kind:?} p={param}: analytic {e1}<{e2} but sim {s1}>{s2}");
        } else if a1 > a2 {
            assert!(s1 >= s2, "{kind:?} p={param}: analytic {e1}>{e2} but sim {s1}<{s2}");
        } else {
            assert_eq!(s1, s2, "{kind:?} p={param}: analytic tie must be a sim tie");
        }
        // and across tree heights at fixed volume, both grow with banks
        if matches!(kind, NocCollective::Reduce | NocCollective::Broadcast) {
            let taller = (param * 2).min(16);
            let at = collective_cost(&analytic, kind, e1, taller).latency_ns;
            let st = collective_cost(&sim, kind, e1, taller).latency_ns;
            assert!(at >= a1, "{kind:?}: analytic must grow with banks");
            assert!(st >= s1, "{kind:?}: simulated must grow with banks");
        }
    });
}
