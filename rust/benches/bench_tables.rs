//! End-to-end benchmarks: one timed entry per paper table/figure (how long
//! the full regeneration of each experiment takes), plus the headline
//! system simulations. Uses the in-crate bench harness (criterion is not
//! vendored offline); honors COMPAIR_BENCH_FAST=1.
//!
//! Run: `cargo bench --bench bench_tables`

use compair::arch::simulate;
use compair::config::{ArchKind, ModelConfig, RunConfig};
use compair::figures;
use compair::util::bench::Bencher;

fn main() {
    let mut b = Bencher::from_env();
    println!("== per-figure regeneration (end-to-end) ==");
    let cx = figures::FigCtx::default();
    for (name, f) in figures::registry() {
        b.bench(&format!("figures/{name}"), || f(&cx));
    }

    println!("\n== headline simulations ==");
    b.bench("simulate/cent-7b-decode-b64-4k", || {
        let mut rc = RunConfig::new(ArchKind::Cent, ModelConfig::llama2_7b());
        rc.batch = 64;
        rc.seq_len = 4096;
        simulate(rc).latency_ns
    });
    b.bench("simulate/compair-7b-decode-b64-4k", || {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.batch = 64;
        rc.seq_len = 4096;
        simulate(rc).latency_ns
    });
    b.bench("simulate/compair-175b-decode-b64-128k", || {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::gpt3_175b());
        rc.batch = 64;
        rc.seq_len = 128 * 1024;
        simulate(rc).latency_ns
    });
    b.bench("simulate/compair-13b-prefill-2k", || {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_13b());
        rc.phase = compair::config::Phase::Prefill;
        rc.batch = 1;
        rc.seq_len = 2048;
        simulate(rc).latency_ns
    });
}
