//! Hot-path micro-benchmarks for the §Perf optimization loop: the pieces
//! profiling shows dominate figure regeneration and serving simulation.
//!
//! Run: `cargo bench --bench bench_hotpath`

use compair::arch::collective as coll;
use compair::config::{HwConfig, NocConfig, SramGang};
use compair::dram::{stream_latency_ns, PimBank};
use compair::isa::{Machine, RowProgram};
use compair::noc::packet::{Packet, PacketType, PathStep, RouterId, StepOp};
use compair::noc::{trees, Mesh};
use compair::sram::bank::{SramBank, WeightPolicy};
use compair::util::bench::Bencher;

fn main() {
    let hw = HwConfig::paper();
    let mut b = Bencher::from_env();

    println!("== substrate closed forms ==");
    let bank = PimBank::new(&hw.dram);
    b.bench("dram/gemv-closed-form-10x5120xb64", || bank.gemv(10, 5120, 64).latency_ns);
    b.bench("dram/stream-latency", || stream_latency_ns(&hw.dram, 1000, 32));
    let sram = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
    b.bench("sram/gemm-10x5120xb64", || {
        sram.gemm(10, 5120, 64, WeightPolicy::Reload).latency_ns
    });
    b.bench("collective/noc-reduce-4096x16", || {
        coll::noc_reduce(4096, 16, &hw.noc).latency_ns
    });

    println!("\n== flit-level mesh simulation ==");
    b.bench("mesh/cross-traffic-64-packets", || {
        let mut m = Mesh::new(&NocConfig::default());
        for y in 0..16usize {
            for x in 0..4usize {
                m.inject(Packet::new(
                    PacketType::Write,
                    RouterId::new(x, y),
                    1.0,
                    vec![PathStep::relay(RouterId::new(3 - x, 15 - y))],
                ));
            }
        }
        m.run(1_000_000).latency_ns
    });
    b.bench("mesh/tree-reduce-16", || {
        let mut m = Mesh::new(&NocConfig::default());
        let vals: Vec<Vec<f32>> =
            (0..4).map(|c| (0..16).map(|i| (c * i) as f32).collect()).collect();
        trees::reduce(&mut m, &vals, StepOp::Add, 0, 16).cost.latency_ns
    });

    println!("\n== ISA machine ==");
    b.bench("isa/exp-program-fused-16", || {
        let mut m = Machine::new(&hw, SramGang::In256Out16);
        let xs: Vec<f32> = (0..16).map(|i| 0.05 * i as f32 - 0.4).collect();
        m.write_row(0, 0, &xs);
        let p = RowProgram::exp_program(0, 2000, 16, 6, 1);
        m.run(&p, true).latency_ns
    });

    println!("\n== system-level ==");
    b.bench("system/llama7b-layer-cost", || {
        let mut rc = compair::config::RunConfig::new(
            compair::config::ArchKind::CompAirOpt,
            compair::config::ModelConfig::llama2_7b(),
        );
        rc.batch = 64;
        rc.seq_len = 4096;
        compair::arch::simulate(rc).latency_ns
    });
}
