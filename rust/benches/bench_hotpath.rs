//! Hot-path micro-benchmarks for the §Perf optimization loop: the pieces
//! profiling shows dominate figure regeneration and serving simulation,
//! plus the serving-loop face-off between the uncached `System` cost model
//! and the memoizing `CachedCostModel` (the result seeds the
//! `BENCH_serving.json` perf trajectory at the repository root).
//!
//! Run: `cargo bench --bench bench_hotpath`

use compair::arch::collective as coll;
use compair::arch::{CachedCostModel, System};
use compair::config::{ArchKind, HwConfig, ModelConfig, NocConfig, RunConfig, SramGang};
use compair::coordinator::{ServeConfig, Server};
use compair::dram::{stream_latency_ns, PimBank};
use compair::figures::{self, FigCtx};
use compair::isa::{Machine, RowProgram};
use compair::noc::packet::{Packet, PacketType, PathStep, RouterId, StepOp};
use compair::noc::{trees, Mesh};
use compair::sram::bank::{SramBank, WeightPolicy};
use compair::util::bench::Bencher;
use compair::util::json::{write_json_file, Json, ToJson};
use compair::util::pool;
use compair::workload::Scenario;
use compair::Engine;

/// Wall-clock one run of `f` (the pool cases are second-scale sweeps, so
/// single timed runs — not `Bencher` batches — are the honest measure).
fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = std::time::Instant::now();
    let r = f();
    (r, t0.elapsed().as_nanos() as f64)
}

fn main() {
    let hw = HwConfig::paper();
    let mut b = Bencher::from_env();

    println!("== substrate closed forms ==");
    let bank = PimBank::new(&hw.dram);
    b.bench("dram/gemv-closed-form-10x5120xb64", || bank.gemv(10, 5120, 64).latency_ns);
    b.bench("dram/stream-latency", || stream_latency_ns(&hw.dram, 1000, 32));
    let sram = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
    b.bench("sram/gemm-10x5120xb64", || {
        sram.gemm(10, 5120, 64, WeightPolicy::Reload).latency_ns
    });
    b.bench("collective/noc-reduce-4096x16", || {
        coll::noc_reduce(4096, 16, &hw.noc).latency_ns
    });

    println!("\n== flit-level mesh simulation ==");
    b.bench("mesh/cross-traffic-64-packets", || {
        let mut m = Mesh::new(&NocConfig::default());
        for y in 0..16usize {
            for x in 0..4usize {
                m.inject(Packet::new(
                    PacketType::Write,
                    RouterId::new(x, y),
                    1.0,
                    vec![PathStep::relay(RouterId::new(3 - x, 15 - y))],
                ));
            }
        }
        m.run(1_000_000).latency_ns
    });
    b.bench("mesh/tree-reduce-16", || {
        let mut m = Mesh::new(&NocConfig::default());
        let vals: Vec<Vec<f32>> =
            (0..4).map(|c| (0..16).map(|i| (c * i) as f32).collect()).collect();
        trees::reduce(&mut m, &vals, StepOp::Add, 0, 16).cost.latency_ns
    });

    println!("\n== ISA machine ==");
    b.bench("isa/exp-program-fused-16", || {
        let mut m = Machine::new(&hw, SramGang::In256Out16);
        let xs: Vec<f32> = (0..16).map(|i| 0.05 * i as f32 - 0.4).collect();
        m.write_row(0, 0, &xs);
        let p = RowProgram::exp_program(0, 2000, 16, 6, 1);
        m.run(&p, true).latency_ns
    });

    println!("\n== system-level ==");
    b.bench("system/llama7b-layer-cost", || {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.batch = 64;
        rc.seq_len = 4096;
        compair::arch::simulate(rc).latency_ns
    });

    // ---- serving loop: uncached System vs memoizing CachedCostModel ----
    // The fixed scenario keeps the trace identical across both models
    // (seeded), so the face-off isolates the costing path. `rag` is the
    // cache's home turf: its 2K-16K prompts are chunked-prefilled, so the
    // same (Prefill, 1, chunk) shape is re-priced on every iteration of a
    // long prompt. Results land in BENCH_serving.json at the repository
    // root (the perf trajectory).
    println!("\n== serving loop: cached vs uncached cost model ==");
    let scenario = "rag";
    let n_requests = 12;
    let serving_rc = || {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        rc
    };
    let server = Server::new(
        serving_rc(),
        ServeConfig {
            n_requests,
            seed: 42,
            scenario: Some(Scenario::by_name(scenario).expect("rag scenario registered")),
            ..Default::default()
        },
    );
    let uncached_model = System::new(serving_rc());
    let uncached = b
        .bench("serve/rag-12req-uncached-system", || {
            server.run_with_model(&uncached_model).tokens_out
        })
        .clone();
    let cached = b
        .bench("serve/rag-12req-cached-costmodel", || {
            // a fresh cache per run: the measurement includes cold misses,
            // exactly what one serving run pays
            let cm = CachedCostModel::new(System::new(serving_rc()));
            server.run_with_model(&cm).tokens_out
        })
        .clone();
    let speedup = uncached.mean_ns / cached.mean_ns.max(1e-9);
    println!("cached speedup over uncached: {speedup:.2}x");

    // one instrumented run outside the timers: the memo counters for the
    // exact trace the face-off prices (hits / misses / evictions)
    let cm = CachedCostModel::new(System::new(serving_rc()));
    server.run_with_model(&cm);
    let cache_stats = cm.stats();
    println!(
        "cache: {} hits, {} misses, {} evictions ({:.0}% hit rate)",
        cache_stats.hits,
        cache_stats.misses,
        cache_stats.evictions,
        cache_stats.hit_rate() * 100.0
    );

    let doc = Json::obj()
        .field("bench", "serving_hotpath")
        .field("scenario", scenario)
        .field("requests", n_requests)
        .field("arch", "compair-opt")
        .field("model", "llama2-7b")
        .field("uncached", uncached.to_json())
        .field("cached", cached.to_json())
        .field("cached_speedup", speedup)
        .field("cache_stats", cache_stats.to_json())
        .field("all_results", b.results_json());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serving.json");
    match write_json_file(&path, &doc) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }

    // ---- worker pool: serial vs pooled figure/sweep wall time ----
    // The determinism contract is part of the measurement: every case also
    // asserts its pooled output is bit-identical to the serial run, so a
    // regression in either speed or determinism shows up in the artifact
    // (BENCH_parallel.json at the repository root).
    println!("\n== worker pool: serial vs pooled (jobs={}) ==", pool::default_jobs());
    let jobs = pool::default_jobs().max(2);
    let serial_cx = FigCtx { jobs: 1, ..FigCtx::default() };
    let pooled_cx = FigCtx { jobs, ..FigCtx::default() };
    let mut cases: Vec<Json> = Vec::new();
    let mut record = |name: &str, serial_ns: f64, parallel_ns: f64, identical: bool| {
        let sp = serial_ns / parallel_ns.max(1.0);
        println!(
            "{:<32} serial {:>10.1}ms  pooled {:>10.1}ms  speedup {sp:.2}x  identical={identical}",
            name,
            serial_ns / 1e6,
            parallel_ns / 1e6
        );
        cases.push(
            Json::obj()
                .field("name", name)
                .field("serial_ns", serial_ns)
                .field("parallel_ns", parallel_ns)
                .field("speedup", sp)
                .field("identical", identical),
        );
        identical
    };

    // a cell-sweep figure: 9 (batch, seqlen) cells x 4 archs per cell
    let (s_out, s_ns) = timed(|| figures::run("fig16", &serial_cx).expect("fig16 registered"));
    let (p_out, p_ns) = timed(|| figures::run("fig16", &pooled_cx).expect("fig16 registered"));
    let mut all_identical = record("figures/fig16", s_ns, p_ns, s_out == p_out);

    // the CalibratedNoc anchor fit: prefit warms granules on the pool
    let (s_out, s_ns) =
        timed(|| figures::run("noc-calibration", &serial_cx).expect("registered"));
    let (p_out, p_ns) =
        timed(|| figures::run("noc-calibration", &pooled_cx).expect("registered"));
    all_identical &= record("figures/noc-calibration", s_ns, p_ns, s_out == p_out);

    // the batch facade: an arch x batch grid through Engine::sweep
    let grid = || {
        let mut configs = Vec::new();
        for arch in [ArchKind::Cent, ArchKind::CompAirBase, ArchKind::CompAirOpt] {
            for batch in [1usize, 16, 64] {
                let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
                rc.batch = batch;
                rc.seq_len = 4096;
                configs.push(rc);
            }
        }
        configs
    };
    let (s_reports, s_ns) = timed(|| Engine::sweep(grid(), 1));
    let (p_reports, p_ns) = timed(|| Engine::sweep(grid(), jobs));
    let bits = |rs: &[compair::arch::PhaseReport]| -> Vec<u64> {
        rs.iter().map(|r| r.latency_ns.to_bits()).collect()
    };
    all_identical &= record("engine/sweep-3x3-grid", s_ns, p_ns, bits(&s_reports) == bits(&p_reports));

    let doc = Json::obj()
        .field("bench", "parallel_pool")
        .field("jobs", jobs)
        .field("all_identical", all_identical)
        .field("cases", Json::arr(cases.into_iter()));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_parallel.json");
    match write_json_file(&path, &doc) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
    assert!(all_identical, "pooled output diverged from serial — determinism contract broken");
}
