//! Simulation core: the cost/counts algebra every substrate reports in, and
//! the discrete-event engine behind the serving coordinator.
pub mod cost;
pub mod engine;

pub use cost::{CostCounts, OpCost};
pub use engine::{EventQueue, SimTime};
