//! Unified latency + event-count accounting for every simulated operation.
//!
//! Substrate models (DRAM, SRAM, HB, NoC, CXL, NLU) report *what happened*
//! (`CostCounts`) and *how long it took* (`latency_ns`); the energy model
//! prices counts into pJ separately. Costs compose with serial/parallel
//! combinators, mirroring how the mapper composes hardware phases.

use crate::util::json::{Json, ToJson};

/// Raw event counts accumulated during an operation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostCounts {
    /// DRAM row activations.
    pub dram_act: u64,
    /// DRAM column reads (32B-class accesses).
    pub dram_col_rd: u64,
    /// DRAM column writes.
    pub dram_col_wr: u64,
    /// BF16 MAC operations performed by DRAM-PIM lanes.
    pub dram_mac: u64,
    /// SRAM-PIM macro accesses (each = inputs×outputs MACs).
    pub sram_access: u64,
    /// BF16 MAC operations performed inside SRAM-PIM macros.
    pub sram_mac: u64,
    /// SRAM-PIM weight-row writes (reload traffic).
    pub sram_row_write: u64,
    /// Bytes crossing the hybrid-bonding die-to-die interface.
    pub hb_bytes: u64,
    /// Flit-hops traversed in the CompAir-NoC (1 flit over 1 link).
    pub noc_flit_hops: u64,
    /// Curry-ALU operations executed in routers.
    pub noc_alu_ops: u64,
    /// Bytes moved through a channel's global buffer (baseline collectives).
    pub gb_bytes: u64,
    /// Bytes over the CXL fabric.
    pub cxl_bytes: u64,
    /// Scalar non-linear ops executed on a centralized NLU/CPU (baselines).
    pub nlu_ops: u64,
    /// FLOPs executed on a GPU (AttAcc baseline).
    pub gpu_flop: u64,
    /// Bytes moved over GPU HBM (AttAcc baseline).
    pub gpu_hbm_bytes: u64,
}

macro_rules! for_each_count {
    ($self:ident, $other:ident, $f:ident) => {{
        CostCounts {
            dram_act: $f($self.dram_act, $other.dram_act, "dram_act"),
            dram_col_rd: $f($self.dram_col_rd, $other.dram_col_rd, "dram_col_rd"),
            dram_col_wr: $f($self.dram_col_wr, $other.dram_col_wr, "dram_col_wr"),
            dram_mac: $f($self.dram_mac, $other.dram_mac, "dram_mac"),
            sram_access: $f($self.sram_access, $other.sram_access, "sram_access"),
            sram_mac: $f($self.sram_mac, $other.sram_mac, "sram_mac"),
            sram_row_write: $f($self.sram_row_write, $other.sram_row_write, "sram_row_write"),
            hb_bytes: $f($self.hb_bytes, $other.hb_bytes, "hb_bytes"),
            noc_flit_hops: $f($self.noc_flit_hops, $other.noc_flit_hops, "noc_flit_hops"),
            noc_alu_ops: $f($self.noc_alu_ops, $other.noc_alu_ops, "noc_alu_ops"),
            gb_bytes: $f($self.gb_bytes, $other.gb_bytes, "gb_bytes"),
            cxl_bytes: $f($self.cxl_bytes, $other.cxl_bytes, "cxl_bytes"),
            nlu_ops: $f($self.nlu_ops, $other.nlu_ops, "nlu_ops"),
            gpu_flop: $f($self.gpu_flop, $other.gpu_flop, "gpu_flop"),
            gpu_hbm_bytes: $f($self.gpu_hbm_bytes, $other.gpu_hbm_bytes, "gpu_hbm_bytes"),
        }
    }};
}

// Overflow policy for the u64 event counters: saturate in release (a
// pinned counter is visibly wrong but never wraps to a tiny plausible
// value that would silently invert a cost comparison), debug-assert in
// debug so tests catch the defect at its source. The static side of the
// same defect class is `prove`'s headroom pass (`prv.overflow`), which
// rejects configurations that could get anywhere near saturation.
#[inline]
fn sat_add(a: u64, b: u64, field: &str) -> u64 {
    let (v, wrapped) = a.overflowing_add(b);
    debug_assert!(!wrapped, "CostCounts::{field} add overflowed u64 ({a} + {b})");
    if wrapped { u64::MAX } else { v }
}

#[inline]
fn sat_mul(a: u64, k: u64, field: &str) -> u64 {
    let (v, wrapped) = a.overflowing_mul(k);
    debug_assert!(!wrapped, "CostCounts::{field} scale overflowed u64 ({a} * {k})");
    if wrapped { u64::MAX } else { v }
}

impl CostCounts {
    pub fn add(&self, o: &CostCounts) -> CostCounts {
        for_each_count!(self, o, sat_add)
    }

    pub fn scale(&self, k: u64) -> CostCounts {
        let o = CostCounts {
            dram_act: k,
            dram_col_rd: k,
            dram_col_wr: k,
            dram_mac: k,
            sram_access: k,
            sram_mac: k,
            sram_row_write: k,
            hb_bytes: k,
            noc_flit_hops: k,
            noc_alu_ops: k,
            gb_bytes: k,
            cxl_bytes: k,
            nlu_ops: k,
            gpu_flop: k,
            gpu_hbm_bytes: k,
        };
        for_each_count!(self, o, sat_mul)
    }

    /// Every counter as a `(name, value)` pair, in declaration order — the
    /// one field registry behind `total_events`, the JSON rendering, and
    /// the semantic auditor's per-counter sweeps (`analysis/audit.rs`), so
    /// a new counter cannot silently escape any of them.
    pub fn fields(&self) -> [(&'static str, u64); 15] {
        [
            ("dram_act", self.dram_act),
            ("dram_col_rd", self.dram_col_rd),
            ("dram_col_wr", self.dram_col_wr),
            ("dram_mac", self.dram_mac),
            ("sram_access", self.sram_access),
            ("sram_mac", self.sram_mac),
            ("sram_row_write", self.sram_row_write),
            ("hb_bytes", self.hb_bytes),
            ("noc_flit_hops", self.noc_flit_hops),
            ("noc_alu_ops", self.noc_alu_ops),
            ("gb_bytes", self.gb_bytes),
            ("cxl_bytes", self.cxl_bytes),
            ("nlu_ops", self.nlu_ops),
            ("gpu_flop", self.gpu_flop),
            ("gpu_hbm_bytes", self.gpu_hbm_bytes),
        ]
    }

    pub fn total_events(&self) -> u64 {
        self.fields().iter().map(|(_, v)| v).sum()
    }
}

impl ToJson for CostCounts {
    fn to_json(&self) -> Json {
        self.fields().iter().fold(Json::obj(), |j, (name, v)| j.field(name, *v))
    }
}

/// Latency + counts of one operation (or composed phase).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCost {
    pub latency_ns: f64,
    pub counts: CostCounts,
}

impl OpCost {
    pub fn zero() -> OpCost {
        OpCost::default()
    }

    pub fn latency(ns: f64) -> OpCost {
        OpCost { latency_ns: ns, counts: CostCounts::default() }
    }

    /// Sequential composition: latencies add, counts add.
    pub fn then(&self, o: &OpCost) -> OpCost {
        OpCost { latency_ns: self.latency_ns + o.latency_ns, counts: self.counts.add(&o.counts) }
    }

    /// Parallel composition: latency is the max, counts add.
    ///
    /// NaN note: `f64::max` *ignores* a NaN operand — `join` with one
    /// NaN latency returns the finite side, and only NaN-join-NaN stays
    /// NaN. The pipeline never produces NaN latencies (the `aud.non-finite`
    /// auditor gate enforces this), so join quietly preferring the finite
    /// side is acceptable; the behavior is pinned by a test so a change
    /// of `max` semantics cannot slip in silently.
    pub fn join(&self, o: &OpCost) -> OpCost {
        OpCost {
            latency_ns: self.latency_ns.max(o.latency_ns),
            counts: self.counts.add(&o.counts),
        }
    }

    /// Repeat serially k times. Counts saturate at u64::MAX instead of
    /// wrapping (see `CostCounts::scale`).
    pub fn repeat(&self, k: u64) -> OpCost {
        OpCost { latency_ns: self.latency_ns * k as f64, counts: self.counts.scale(k) }
    }

    /// k identical units running in parallel: same latency, k× the
    /// events. Counts saturate at u64::MAX instead of wrapping.
    pub fn replicate(&self, k: u64) -> OpCost {
        OpCost { latency_ns: self.latency_ns, counts: self.counts.scale(k) }
    }

    pub fn serial_all<I: IntoIterator<Item = OpCost>>(items: I) -> OpCost {
        items.into_iter().fold(OpCost::zero(), |a, b| a.then(&b))
    }

    pub fn parallel_all<I: IntoIterator<Item = OpCost>>(items: I) -> OpCost {
        items.into_iter().fold(OpCost::zero(), |a, b| a.join(&b))
    }
}

impl ToJson for OpCost {
    fn to_json(&self) -> Json {
        Json::obj().field("latency_ns", self.latency_ns).field("counts", self.counts.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(act: u64, mac: u64) -> OpCost {
        OpCost {
            latency_ns: 10.0,
            counts: CostCounts { dram_act: act, dram_mac: mac, ..Default::default() },
        }
    }

    #[test]
    fn serial_adds() {
        let r = c(1, 100).then(&c(2, 200));
        assert_eq!(r.latency_ns, 20.0);
        assert_eq!(r.counts.dram_act, 3);
        assert_eq!(r.counts.dram_mac, 300);
    }

    #[test]
    fn parallel_maxes_latency_adds_counts() {
        let a = OpCost { latency_ns: 5.0, ..c(1, 10) };
        let b = OpCost { latency_ns: 9.0, ..c(1, 10) };
        let r = a.join(&b);
        assert_eq!(r.latency_ns, 9.0);
        assert_eq!(r.counts.dram_act, 2);
    }

    #[test]
    fn repeat_and_replicate() {
        let r = c(1, 10).repeat(4);
        assert_eq!(r.latency_ns, 40.0);
        assert_eq!(r.counts.dram_mac, 40);
        let p = c(1, 10).replicate(16);
        assert_eq!(p.latency_ns, 10.0);
        assert_eq!(p.counts.dram_mac, 160);
    }

    #[test]
    fn fold_helpers() {
        let s = OpCost::serial_all((0..3).map(|_| c(1, 1)));
        assert_eq!(s.latency_ns, 30.0);
        assert_eq!(s.counts.dram_act, 3);
        let p = OpCost::parallel_all((0..3).map(|_| c(1, 1)));
        assert_eq!(p.latency_ns, 10.0);
        assert_eq!(p.counts.dram_act, 3);
    }

    // --- combinator algebra (satellite: property tests) ---

    fn cases() -> Vec<OpCost> {
        vec![
            OpCost::zero(),
            OpCost::latency(1.5),
            c(1, 10),
            OpCost { latency_ns: 9.25, ..c(3, 7) },
            OpCost {
                latency_ns: 0.125,
                counts: CostCounts { hb_bytes: 11, noc_flit_hops: 5, ..Default::default() },
            },
        ]
    }

    fn eq_bits(a: &OpCost, b: &OpCost) {
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits(), "{a:?} vs {b:?}");
        assert_eq!(a.counts, b.counts);
    }

    #[test]
    fn join_is_commutative_and_associative() {
        for a in cases() {
            for b in cases() {
                eq_bits(&a.join(&b), &b.join(&a));
                for x in cases() {
                    eq_bits(&a.join(&b).join(&x), &a.join(&b.join(&x)));
                }
            }
        }
    }

    #[test]
    fn then_is_associative_with_zero_identity() {
        for a in cases() {
            eq_bits(&a.then(&OpCost::zero()), &a);
            eq_bits(&OpCost::zero().then(&a), &a);
            for b in cases() {
                for x in cases() {
                    eq_bits(&a.then(&b).then(&x), &a.then(&b.then(&x)));
                }
            }
        }
    }

    #[test]
    fn repeat_splits_additively() {
        // repeat(a+b) == repeat(a).then(repeat(b)); latencies here are
        // exactly representable so even the float side is bit-equal
        for cost in cases() {
            for (a, b) in [(0u64, 1u64), (1, 1), (3, 5), (4, 4), (7, 9)] {
                eq_bits(&cost.repeat(a + b), &cost.repeat(a).then(&cost.repeat(b)));
            }
        }
    }

    #[test]
    fn replicate_composes_multiplicatively() {
        for cost in cases() {
            let r = cost.replicate(3).replicate(4);
            eq_bits(&r, &cost.replicate(12));
        }
    }

    #[test]
    fn join_nan_prefers_the_finite_side() {
        let nan = OpCost { latency_ns: f64::NAN, ..c(1, 1) };
        let fin = OpCost { latency_ns: 7.0, ..c(2, 2) };
        // f64::max ignores a single NaN operand, in both positions
        assert_eq!(nan.join(&fin).latency_ns, 7.0);
        assert_eq!(fin.join(&nan).latency_ns, 7.0);
        assert_eq!(nan.join(&fin).counts.dram_act, 3);
        // only NaN-join-NaN stays NaN
        assert!(nan.join(&nan).latency_ns.is_nan());
    }

    // --- overflow boundary (satellite: saturate + debug-assert policy) ---

    #[test]
    #[cfg(not(debug_assertions))]
    fn counts_saturate_instead_of_wrapping() {
        let near = CostCounts { dram_mac: u64::MAX - 1, ..Default::default() };
        assert_eq!(near.add(&near).dram_mac, u64::MAX);
        assert_eq!(near.scale(3).dram_mac, u64::MAX);
        let oc = OpCost { latency_ns: 1.0, counts: near };
        assert_eq!(oc.repeat(2).counts.dram_mac, u64::MAX);
        assert_eq!(oc.replicate(u64::MAX).counts.dram_mac, u64::MAX);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "dram_mac add overflowed")]
    fn counts_add_overflow_panics_in_debug() {
        let near = CostCounts { dram_mac: u64::MAX - 1, ..Default::default() };
        let _ = near.add(&near);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "noc_flit_hops scale overflowed")]
    fn counts_scale_overflow_panics_in_debug() {
        let near = CostCounts { noc_flit_hops: u64::MAX / 2 + 1, ..Default::default() };
        let _ = near.scale(2);
    }

    #[test]
    fn counts_at_the_boundary_stay_exact() {
        // the largest non-overflowing cases must be untouched by hardening
        let half = CostCounts { gpu_flop: u64::MAX / 2, ..Default::default() };
        assert_eq!(half.scale(2).gpu_flop, u64::MAX - 1);
        let a = CostCounts { cxl_bytes: u64::MAX - 5, ..Default::default() };
        let b = CostCounts { cxl_bytes: 5, ..Default::default() };
        assert_eq!(a.add(&b).cxl_bytes, u64::MAX);
    }

    #[test]
    fn scale_covers_every_field() {
        let all_ones = CostCounts {
            dram_act: 1,
            dram_col_rd: 1,
            dram_col_wr: 1,
            dram_mac: 1,
            sram_access: 1,
            sram_mac: 1,
            sram_row_write: 1,
            hb_bytes: 1,
            noc_flit_hops: 1,
            noc_alu_ops: 1,
            gb_bytes: 1,
            cxl_bytes: 1,
            nlu_ops: 1,
            gpu_flop: 1,
            gpu_hbm_bytes: 1,
        };
        assert_eq!(all_ones.total_events(), 15);
        let s = all_ones.scale(3);
        assert_eq!(s.total_events(), 45);
    }
}
