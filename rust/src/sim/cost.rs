//! Unified latency + event-count accounting for every simulated operation.
//!
//! Substrate models (DRAM, SRAM, HB, NoC, CXL, NLU) report *what happened*
//! (`CostCounts`) and *how long it took* (`latency_ns`); the energy model
//! prices counts into pJ separately. Costs compose with serial/parallel
//! combinators, mirroring how the mapper composes hardware phases.

use crate::util::json::{Json, ToJson};

/// Raw event counts accumulated during an operation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CostCounts {
    /// DRAM row activations.
    pub dram_act: u64,
    /// DRAM column reads (32B-class accesses).
    pub dram_col_rd: u64,
    /// DRAM column writes.
    pub dram_col_wr: u64,
    /// BF16 MAC operations performed by DRAM-PIM lanes.
    pub dram_mac: u64,
    /// SRAM-PIM macro accesses (each = inputs×outputs MACs).
    pub sram_access: u64,
    /// BF16 MAC operations performed inside SRAM-PIM macros.
    pub sram_mac: u64,
    /// SRAM-PIM weight-row writes (reload traffic).
    pub sram_row_write: u64,
    /// Bytes crossing the hybrid-bonding die-to-die interface.
    pub hb_bytes: u64,
    /// Flit-hops traversed in the CompAir-NoC (1 flit over 1 link).
    pub noc_flit_hops: u64,
    /// Curry-ALU operations executed in routers.
    pub noc_alu_ops: u64,
    /// Bytes moved through a channel's global buffer (baseline collectives).
    pub gb_bytes: u64,
    /// Bytes over the CXL fabric.
    pub cxl_bytes: u64,
    /// Scalar non-linear ops executed on a centralized NLU/CPU (baselines).
    pub nlu_ops: u64,
    /// FLOPs executed on a GPU (AttAcc baseline).
    pub gpu_flop: u64,
    /// Bytes moved over GPU HBM (AttAcc baseline).
    pub gpu_hbm_bytes: u64,
}

macro_rules! for_each_count {
    ($self:ident, $other:ident, $op:tt) => {{
        CostCounts {
            dram_act: $self.dram_act $op $other.dram_act,
            dram_col_rd: $self.dram_col_rd $op $other.dram_col_rd,
            dram_col_wr: $self.dram_col_wr $op $other.dram_col_wr,
            dram_mac: $self.dram_mac $op $other.dram_mac,
            sram_access: $self.sram_access $op $other.sram_access,
            sram_mac: $self.sram_mac $op $other.sram_mac,
            sram_row_write: $self.sram_row_write $op $other.sram_row_write,
            hb_bytes: $self.hb_bytes $op $other.hb_bytes,
            noc_flit_hops: $self.noc_flit_hops $op $other.noc_flit_hops,
            noc_alu_ops: $self.noc_alu_ops $op $other.noc_alu_ops,
            gb_bytes: $self.gb_bytes $op $other.gb_bytes,
            cxl_bytes: $self.cxl_bytes $op $other.cxl_bytes,
            nlu_ops: $self.nlu_ops $op $other.nlu_ops,
            gpu_flop: $self.gpu_flop $op $other.gpu_flop,
            gpu_hbm_bytes: $self.gpu_hbm_bytes $op $other.gpu_hbm_bytes,
        }
    }};
}

impl CostCounts {
    pub fn add(&self, o: &CostCounts) -> CostCounts {
        for_each_count!(self, o, +)
    }

    pub fn scale(&self, k: u64) -> CostCounts {
        let o = CostCounts {
            dram_act: k,
            dram_col_rd: k,
            dram_col_wr: k,
            dram_mac: k,
            sram_access: k,
            sram_mac: k,
            sram_row_write: k,
            hb_bytes: k,
            noc_flit_hops: k,
            noc_alu_ops: k,
            gb_bytes: k,
            cxl_bytes: k,
            nlu_ops: k,
            gpu_flop: k,
            gpu_hbm_bytes: k,
        };
        for_each_count!(self, o, *)
    }

    /// Every counter as a `(name, value)` pair, in declaration order — the
    /// one field registry behind `total_events`, the JSON rendering, and
    /// the semantic auditor's per-counter sweeps (`analysis/audit.rs`), so
    /// a new counter cannot silently escape any of them.
    pub fn fields(&self) -> [(&'static str, u64); 15] {
        [
            ("dram_act", self.dram_act),
            ("dram_col_rd", self.dram_col_rd),
            ("dram_col_wr", self.dram_col_wr),
            ("dram_mac", self.dram_mac),
            ("sram_access", self.sram_access),
            ("sram_mac", self.sram_mac),
            ("sram_row_write", self.sram_row_write),
            ("hb_bytes", self.hb_bytes),
            ("noc_flit_hops", self.noc_flit_hops),
            ("noc_alu_ops", self.noc_alu_ops),
            ("gb_bytes", self.gb_bytes),
            ("cxl_bytes", self.cxl_bytes),
            ("nlu_ops", self.nlu_ops),
            ("gpu_flop", self.gpu_flop),
            ("gpu_hbm_bytes", self.gpu_hbm_bytes),
        ]
    }

    pub fn total_events(&self) -> u64 {
        self.fields().iter().map(|(_, v)| v).sum()
    }
}

impl ToJson for CostCounts {
    fn to_json(&self) -> Json {
        self.fields().iter().fold(Json::obj(), |j, (name, v)| j.field(name, *v))
    }
}

/// Latency + counts of one operation (or composed phase).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCost {
    pub latency_ns: f64,
    pub counts: CostCounts,
}

impl OpCost {
    pub fn zero() -> OpCost {
        OpCost::default()
    }

    pub fn latency(ns: f64) -> OpCost {
        OpCost { latency_ns: ns, counts: CostCounts::default() }
    }

    /// Sequential composition: latencies add, counts add.
    pub fn then(&self, o: &OpCost) -> OpCost {
        OpCost { latency_ns: self.latency_ns + o.latency_ns, counts: self.counts.add(&o.counts) }
    }

    /// Parallel composition: latency is the max, counts add.
    pub fn join(&self, o: &OpCost) -> OpCost {
        OpCost {
            latency_ns: self.latency_ns.max(o.latency_ns),
            counts: self.counts.add(&o.counts),
        }
    }

    /// Repeat serially k times.
    pub fn repeat(&self, k: u64) -> OpCost {
        OpCost { latency_ns: self.latency_ns * k as f64, counts: self.counts.scale(k) }
    }

    /// k identical units running in parallel: same latency, k× the events.
    pub fn replicate(&self, k: u64) -> OpCost {
        OpCost { latency_ns: self.latency_ns, counts: self.counts.scale(k) }
    }

    pub fn serial_all<I: IntoIterator<Item = OpCost>>(items: I) -> OpCost {
        items.into_iter().fold(OpCost::zero(), |a, b| a.then(&b))
    }

    pub fn parallel_all<I: IntoIterator<Item = OpCost>>(items: I) -> OpCost {
        items.into_iter().fold(OpCost::zero(), |a, b| a.join(&b))
    }
}

impl ToJson for OpCost {
    fn to_json(&self) -> Json {
        Json::obj().field("latency_ns", self.latency_ns).field("counts", self.counts.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(act: u64, mac: u64) -> OpCost {
        OpCost {
            latency_ns: 10.0,
            counts: CostCounts { dram_act: act, dram_mac: mac, ..Default::default() },
        }
    }

    #[test]
    fn serial_adds() {
        let r = c(1, 100).then(&c(2, 200));
        assert_eq!(r.latency_ns, 20.0);
        assert_eq!(r.counts.dram_act, 3);
        assert_eq!(r.counts.dram_mac, 300);
    }

    #[test]
    fn parallel_maxes_latency_adds_counts() {
        let a = OpCost { latency_ns: 5.0, ..c(1, 10) };
        let b = OpCost { latency_ns: 9.0, ..c(1, 10) };
        let r = a.join(&b);
        assert_eq!(r.latency_ns, 9.0);
        assert_eq!(r.counts.dram_act, 2);
    }

    #[test]
    fn repeat_and_replicate() {
        let r = c(1, 10).repeat(4);
        assert_eq!(r.latency_ns, 40.0);
        assert_eq!(r.counts.dram_mac, 40);
        let p = c(1, 10).replicate(16);
        assert_eq!(p.latency_ns, 10.0);
        assert_eq!(p.counts.dram_mac, 160);
    }

    #[test]
    fn fold_helpers() {
        let s = OpCost::serial_all((0..3).map(|_| c(1, 1)));
        assert_eq!(s.latency_ns, 30.0);
        assert_eq!(s.counts.dram_act, 3);
        let p = OpCost::parallel_all((0..3).map(|_| c(1, 1)));
        assert_eq!(p.latency_ns, 10.0);
        assert_eq!(p.counts.dram_act, 3);
    }

    #[test]
    fn scale_covers_every_field() {
        let all_ones = CostCounts {
            dram_act: 1,
            dram_col_rd: 1,
            dram_col_wr: 1,
            dram_mac: 1,
            sram_access: 1,
            sram_mac: 1,
            sram_row_write: 1,
            hb_bytes: 1,
            noc_flit_hops: 1,
            noc_alu_ops: 1,
            gb_bytes: 1,
            cxl_bytes: 1,
            nlu_ops: 1,
            gpu_flop: 1,
            gpu_hbm_bytes: 1,
        };
        assert_eq!(all_ones.total_events(), 15);
        let s = all_ones.scale(3);
        assert_eq!(s.total_events(), 45);
    }
}
