//! Discrete-event engine used by the serving coordinator (request arrivals,
//! batch completions) and by failure-injection tests.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation time in nanoseconds (integer picosecond-free; ns resolution is
/// sufficient at the serving level).
pub type SimTime = u64;

/// An event scheduled at a time with a deterministic tiebreak sequence.
#[derive(Debug)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap: earlier time (then lower seq) = greater priority
        other.at.cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// A deterministic discrete-event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0, seq: 0, processed: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule an event `delay` ns after the current time.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Schedule an event at an absolute time (must not be in the past).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(at >= self.now, "scheduling into the past: {} < {}", at, self.now);
        self.heap.push(Scheduled { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|s| {
            self.now = s.at;
            self.processed += 1;
            (s.at, s.event)
        })
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), 30);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn fifo_tiebreak_at_same_time() {
        let mut q = EventQueue::new();
        q.schedule_at(5, 1);
        q.schedule_at(5, 2);
        q.schedule_at(5, 3);
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, "x");
        q.pop();
        q.schedule_in(50, "y");
        assert_eq!(q.peek_time(), Some(150));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_panics() {
        let mut q = EventQueue::new();
        q.schedule_at(100, ());
        q.pop();
        q.schedule_at(50, ());
    }
}
