//! Architecture compositions: CompAir and the paper's baselines, plus the
//! analytic collective/non-linear cost library they share.
pub mod attacc;
pub mod collective;
pub mod system;

pub use attacc::{pure_sram_requirements, AttAccConfig};
pub use system::{simulate, OpReport, PhaseReport, System};
