//! Architecture compositions: CompAir and the paper's baselines, the
//! analytic collective/non-linear cost library they share, and the
//! [`CostModel`] interface every harness drives them through.
pub mod attacc;
pub mod collective;
pub mod cost_model;
pub mod system;

pub use attacc::{pure_sram_requirements, AttAccConfig};
pub use cost_model::{CacheStats, CachedCostModel, CostModel, IterKey, ShapeKey};
pub use system::{fc_tiles, simulate, OpReport, PhaseReport, System};
