//! System-level composition: lower the transformer op-graph onto an
//! architecture variant (CENT / CENT+Curry / CompAir_Base / CompAir_Opt /
//! SRAM-stack) and report per-token latency, throughput, and energy.
//!
//! Topology model (paper §3, §7.1): `devices` PIM devices on a CXL switch;
//! a model replica is tensor-parallel over `tp` devices; `devices/tp`
//! replicas form pipeline stages over the layers, so decode throughput at a
//! full pipeline is `batch · pp / (n_layers · layer_latency)` while
//! per-token latency is `n_layers · layer_latency` plus stage handoffs.

use crate::config::hw::DramConfig;
use crate::config::{ArchKind, FcMapping, Phase, RunConfig};
use crate::dram::{Channel, PimBank};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::mapper::{supported_placements, Mapping, Placement, Slot};
use crate::noc::model::NocModel;
use crate::noc::{exchange, model as noc_model};
use crate::sim::OpCost;
use crate::sram::bank::{SramBank, WeightPolicy};
use crate::util::json::{Json, ToJson};
use crate::workload::{layer_ops, LlmOp, OpClass};

use super::collective as coll;

/// Per-op cost entry in the report.
#[derive(Debug, Clone)]
pub struct OpReport {
    pub name: String,
    pub class: OpClass,
    pub cost: OpCost,
}

/// Full phase report.
#[derive(Debug, Clone)]
pub struct PhaseReport {
    /// Decode: latency per generated token (all layers). Prefill: latency of
    /// the full prompt pass.
    pub latency_ns: f64,
    /// Decode: aggregate tokens/s over the whole fabric.
    pub throughput_tok_s: f64,
    /// Energy per generated token (decode) or per prompt (prefill).
    pub energy: EnergyBreakdown,
    pub ops: Vec<OpReport>,
    /// Fraction of layer latency spent in non-linear ops.
    pub nonlinear_frac: f64,
    /// Fraction of layer latency spent in collectives.
    pub collective_frac: f64,
    /// Average FC bank utilization (Fig 18A).
    pub bank_util: f64,
    /// One layer's composed cost (per device; counts cover all tp devices).
    pub layer_cost: OpCost,
}

impl PhaseReport {
    /// Whole-pass cost reconstructed from the report: the full-pass latency
    /// with one layer's event counts, exactly as the serving iteration
    /// costing has always billed it.
    pub fn layer_cost_total(&self) -> OpCost {
        OpCost { latency_ns: self.latency_ns, counts: self.layer_cost.counts }
    }
}

impl ToJson for OpReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("name", self.name.as_str())
            .field("class", self.class.label())
            .field("cost", self.cost.to_json())
    }
}

impl ToJson for PhaseReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("latency_ns", self.latency_ns)
            .field("throughput_tok_s", self.throughput_tok_s)
            .field("nonlinear_frac", self.nonlinear_frac)
            .field("collective_frac", self.collective_frac)
            .field("bank_util", self.bank_util)
            .field("energy", self.energy.to_json())
            .field("layer_cost", self.layer_cost.to_json())
            .field("ops", Json::arr(self.ops.iter().map(|o| o.to_json())))
    }
}

/// The per-bank tile shape the FC lowering assigns: `(out_tile, in_tile,
/// active_banks)` for a device-local `d_in × d_out` projection. Single
/// source for `System::fc_cost` *and* the Fig 8 per-bank tables — the two
/// used to hand-code these splits independently and had drifted apart.
pub fn fc_tiles(mapping: FcMapping, d_in: usize, d_out: usize, dram: &DramConfig) -> (usize, usize, usize) {
    let banks = dram.banks_per_device();
    match mapping {
        FcMapping::OutputSplit => {
            let out_tile = d_out.div_ceil(banks).max(1);
            let active = d_out.div_ceil(out_tile).min(banks);
            (out_tile, d_in, active)
        }
        FcMapping::InputSplit => {
            // input split across the banks of a channel, output split
            // across channels
            let out_tile = d_out.div_ceil(dram.channels_per_device).max(1);
            let in_tile = d_in.div_ceil(dram.banks_per_channel).max(1);
            (out_tile, in_tile, banks)
        }
    }
}

/// The simulator facade.
pub struct System {
    pub rc: RunConfig,
    pub em: EnergyModel,
    bank: PimBank,
    sram: SramBank,
    channel: Channel,
    /// NoC collective costing at the fidelity `rc.noc_fidelity` selects:
    /// analytic closed forms, simulator-calibrated forms, or the
    /// flit-level simulator (see `noc::model`).
    noc: Box<dyn NocModel>,
    /// The hard-coded placement this variant has always used; the default
    /// lowering path ([`System::run_shape`]) goes through it, so
    /// `mapping=static` is the pre-mapper behavior by construction.
    static_map: Mapping,
}

impl System {
    pub fn new(rc: RunConfig) -> Self {
        let em = EnergyModel::new(&rc.hw.sram, rc.hw.hb.pj_per_bit);
        let bank = PimBank::new(&rc.hw.dram);
        let sram = SramBank::new(&rc.hw.sram, rc.sram_gang, &rc.hw.dram);
        let channel = Channel::new(&rc.hw.dram);
        let noc = noc_model::build(rc.noc_fidelity, &rc.hw);
        if rc.jobs > 1 {
            // fan the calibration anchor simulations out over the run's
            // worker budget (a no-op for the stateless analytic tier and
            // the lazily-memoizing simulated tier); the fitted state is
            // bit-identical to the lazy serial fit
            noc.prefit(rc.jobs);
        }
        let static_map = Mapping::static_for(rc.arch);
        Self { rc, em, bank, sram, channel, noc, static_map }
    }

    /// The hard-coded placement baseline for this variant.
    pub fn static_mapping(&self) -> Mapping {
        self.static_map
    }

    fn banks_per_device(&self) -> usize {
        self.rc.hw.dram.banks_per_device()
    }

    /// Cost of one FC op (per device; single layer) on the engine
    /// `use_sram` selects. Returns (cost, active-bank fraction).
    fn fc_cost(&self, name: &str, d_in: usize, d_out: usize, tokens: usize, use_sram: bool) -> (OpCost, f64) {
        let tp = self.rc.tp;
        let row_parallel = matches!(name, "o" | "down");
        let (din_dev, dout_dev) = if row_parallel {
            (d_in.div_ceil(tp), d_out)
        } else {
            (d_in, d_out.div_ceil(tp))
        };
        let banks = self.banks_per_device();
        let channels = self.rc.hw.dram.channels_per_device;
        let banks_pc = self.rc.hw.dram.banks_per_channel;

        // Input distribution: the activation vector reaches every channel's
        // global buffer (channels stream in parallel over the device bus).
        let in_bytes = (tokens * din_dev * 2) as u64;
        let bcast = self.channel.gb_broadcast(in_bytes).replicate(channels as u64);

        let (compute, active_banks, reduce) = match self.rc.fc_mapping {
            FcMapping::OutputSplit => {
                let (out_tile, in_tile, active) =
                    fc_tiles(FcMapping::OutputSplit, din_dev, dout_dev, &self.rc.hw.dram);
                let per_bank = if use_sram {
                    self.sram.gemm(out_tile, in_tile, tokens, WeightPolicy::Reload)
                } else {
                    self.bank.gemv(out_tile, in_tile, tokens)
                };
                (per_bank.replicate(active as u64), active, OpCost::zero())
            }
            FcMapping::InputSplit => {
                let (out_tile, in_tile, active) =
                    fc_tiles(FcMapping::InputSplit, din_dev, dout_dev, &self.rc.hw.dram);
                let per_bank = if use_sram {
                    self.sram.gemm(out_tile, in_tile, tokens, WeightPolicy::Reload)
                } else {
                    self.bank.gemv(out_tile, in_tile, tokens)
                };
                // partial sums reduced across the channel's banks
                let elems = (tokens * out_tile) as u64;
                let red = if self.rc.arch.has_curry() {
                    self.noc.reduce(elems, banks_pc as u64).replicate(channels as u64)
                } else {
                    self.channel
                        .gb_reduce(elems as usize, banks_pc)
                        .replicate(channels as u64)
                };
                (per_bank.replicate(active as u64), active, red)
            }
        };
        let util = active_banks as f64 / banks as f64;
        (bcast.then(&compute).then(&reduce), util)
    }

    /// Attention score / value matmuls (always DRAM-PIM in the default
    /// CompAir mapping — K/V are input-dependent, §8).
    fn attn_cost(&self, qk: bool, batch: usize, heads: usize, rows_q: usize, seq: usize, d_head: usize) -> OpCost {
        let tp = self.rc.tp;
        let heads_dev = heads.div_ceil(tp).max(1);
        let banks = self.banks_per_device();
        let pairs = batch * heads_dev;
        if pairs >= banks {
            let per_bank_pairs = pairs.div_ceil(banks);
            let per_pair = if qk {
                self.bank.gemv(seq, d_head, rows_q)
            } else {
                self.bank.gemv(d_head, seq, rows_q)
            };
            per_pair.repeat(per_bank_pairs as u64).replicate(banks as u64)
        } else {
            let banks_per_pair = (banks / pairs).max(1);
            if qk {
                // output-split along seq: no reduction needed
                let seq_tile = seq.div_ceil(banks_per_pair).max(1);
                self.bank.gemv(seq_tile, d_head, rows_q).replicate(pairs as u64 * banks_per_pair as u64)
            } else {
                // input-split along seq: partial Dh sums reduced per pair
                let in_tile = seq.div_ceil(banks_per_pair).max(1);
                let gemv = self
                    .bank
                    .gemv(d_head, in_tile, rows_q)
                    .replicate(pairs as u64 * banks_per_pair as u64);
                let elems = (d_head * rows_q) as u64;
                let red = if self.rc.arch.has_curry() {
                    self.noc.reduce(elems, banks_per_pair.min(16) as u64).replicate(pairs as u64)
                } else {
                    self.channel
                        .gb_reduce(elems as usize, banks_per_pair.min(16))
                        .replicate(pairs as u64)
                };
                gemv.then(&red)
            }
        }
    }

    fn softmax_cost(&self, rows: usize, seq: usize, on_noc: bool) -> OpCost {
        let tp = self.rc.tp;
        let rows_dev = rows.div_ceil(tp).max(1);
        let banks = self.banks_per_device() as u64;
        let elems = rows_dev as u64 * seq as u64;
        if on_noc {
            // distributed: exp bank-locally, per-row partial sums on the MAC
            // lanes, scalar tree reduce + broadcast, divide in transit
            let per_bank = elems.div_ceil(banks);
            let exp = self.noc.exp(per_bank, 8).replicate(banks);
            let partial_ns = per_bank as f64 / 16.0 * self.rc.hw.dram.t_ccd_ns;
            let partial = OpCost::latency(partial_ns);
            let banks_pc = self.rc.hw.dram.banks_per_channel as u64;
            let channels = self.rc.hw.dram.channels_per_device as u64;
            let rows_pc = (rows_dev as u64).div_ceil(channels).max(1);
            let red = self.noc.reduce(rows_pc, banks_pc).replicate(channels);
            let bc = self.noc.broadcast(rows_pc, banks_pc).replicate(channels);
            let div = self.noc.scalar_stream(per_bank).replicate(banks);
            exp.then(&partial).then(&red).then(&bc).then(&div)
        } else {
            // centralized NLU: scores cross the channel I/O both ways
            let bytes = elems * 2;
            coll::nlu_roundtrip(
                bytes,
                bytes,
                5 * elems,
                self.rc.hw.dram.channels_per_device as u64,
                &self.rc.hw.dram,
            )
        }
    }

    fn rope_cost(&self, tokens: usize, heads: usize, d_head: usize, on_noc: bool) -> OpCost {
        let tp = self.rc.tp;
        let vecs_dev = (tokens * heads.div_ceil(tp)).max(1);
        let banks = self.banks_per_device();
        if on_noc {
            let per_bank_vecs = vecs_dev.div_ceil(banks).max(1);
            let ex = exchange::exchange_cost(d_head, &self.rc.hw.noc)
                .repeat(per_bank_vecs as u64)
                .replicate(banks as u64);
            // cos/sin EWMULs on the bank lanes: 2 muls + 1 add per element
            let ew = coll::dram_ewmul((per_bank_vecs * d_head * 2) as u64, &self.rc.hw)
                .replicate(banks as u64);
            ex.then(&ew)
        } else {
            let bytes = (vecs_dev * d_head * 2) as u64;
            coll::nlu_roundtrip(
                bytes,
                bytes,
                3 * (vecs_dev * d_head) as u64,
                self.rc.hw.dram.channels_per_device as u64,
                &self.rc.hw.dram,
            )
        }
    }

    fn rmsnorm_cost(&self, tokens: usize, d_model: usize, on_noc: bool) -> OpCost {
        let banks = self.banks_per_device() as u64;
        let elems = (tokens * d_model) as u64;
        if on_noc {
            let per_bank = elems.div_ceil(banks);
            // square-accumulate on MAC lanes (x·x into the accumulator)
            let sq = OpCost::latency(per_bank as f64 / 16.0 * self.rc.hw.dram.t_ccd_ns)
                .replicate(banks);
            let banks_pc = self.rc.hw.dram.banks_per_channel as u64;
            let channels = self.rc.hw.dram.channels_per_device as u64;
            let rows_pc = (tokens as u64).div_ceil(channels).max(1);
            let red = self.noc.reduce(rows_pc, banks_pc).replicate(channels);
            let rsqrt = self.noc.sqrt(rows_pc, 4).replicate(channels);
            let bc = self.noc.broadcast(rows_pc, banks_pc).replicate(channels);
            let scale = coll::dram_ewmul(per_bank, &self.rc.hw).replicate(banks);
            sq.then(&red).then(&rsqrt).then(&bc).then(&scale)
        } else {
            let bytes = elems * 2;
            coll::nlu_roundtrip(
                bytes,
                bytes,
                3 * elems,
                self.rc.hw.dram.channels_per_device as u64,
                &self.rc.hw.dram,
            )
        }
    }

    fn activation_cost(&self, tokens: usize, width: usize, on_noc: bool) -> OpCost {
        let tp = self.rc.tp;
        let elems = (tokens * width.div_ceil(tp)) as u64;
        let banks = self.banks_per_device() as u64;
        if on_noc {
            let per_bank = elems.div_ceil(banks);
            // sigmoid: exp + 1/(1+e); gating: EWMUL on the lanes
            let exp = self.noc.exp(per_bank, 8).replicate(banks);
            let post = self.noc.scalar_stream(per_bank).replicate(banks);
            let gate = coll::dram_ewmul(per_bank, &self.rc.hw).replicate(banks);
            exp.then(&post).then(&gate)
        } else {
            let bytes = elems * 2;
            coll::nlu_roundtrip(
                bytes * 2, // x and gate move out
                bytes,
                4 * elems,
                self.rc.hw.dram.channels_per_device as u64,
                &self.rc.hw.dram,
            )
        }
    }

    /// Lower one op under the static mapping; counts are per tp-group
    /// (all devices of the replica).
    pub fn op_cost(&self, op: &LlmOp) -> (OpCost, f64) {
        self.op_cost_mapped(op, &self.static_map)
    }

    /// Lower one op on the engine the mapping assigns its slot. The
    /// placement must be legal for this variant (`supported_placements`);
    /// the search only emits legal mappings, so this is a debug assert,
    /// not a runtime gate.
    pub fn op_cost_mapped(&self, op: &LlmOp, m: &Mapping) -> (OpCost, f64) {
        let place = m.placement_of(op);
        debug_assert!(
            supported_placements(Slot::of_op(op), self.rc.arch).contains(&place),
            "{:?} cannot run on {} under {:?}",
            Slot::of_op(op),
            place.label(),
            self.rc.arch
        );
        let use_sram = place == Placement::SramPim;
        let on_noc = place == Placement::NocAlu;
        let tp = self.rc.tp as u64;
        let (c, util) = match op {
            LlmOp::Fc { name, d_in, d_out, tokens } => {
                self.fc_cost(name, *d_in, *d_out, *tokens, use_sram)
            }
            LlmOp::AttnQK { batch, heads, rows_q, seq, d_head } => {
                (self.attn_cost(true, *batch, *heads, *rows_q, *seq, *d_head), 1.0)
            }
            LlmOp::AttnSV { batch, heads, rows_q, seq, d_head } => {
                (self.attn_cost(false, *batch, *heads, *rows_q, *seq, *d_head), 1.0)
            }
            LlmOp::Softmax { rows, seq } => (self.softmax_cost(*rows, *seq, on_noc), 1.0),
            LlmOp::Rope { tokens, heads, d_head } => {
                (self.rope_cost(*tokens, *heads, *d_head, on_noc), 1.0)
            }
            LlmOp::RmsNorm { tokens, d_model } => {
                (self.rmsnorm_cost(*tokens, *d_model, on_noc), 1.0)
            }
            LlmOp::Activation { tokens, width, .. } => {
                (self.activation_cost(*tokens, *width, on_noc), 1.0)
            }
            LlmOp::AllReduce { tokens, d_model } => (
                coll::cxl_allreduce(
                    (*tokens * *d_model * 2) as u64,
                    self.rc.tp as u64,
                    &self.rc.hw.cxl,
                ),
                1.0,
            ),
        };
        // events happen on every device of the tp group
        (c.replicate(tp), util)
    }

    /// Simulate the configured phase (`rc.phase` / `rc.batch` /
    /// `rc.seq_len`).
    pub fn run(&self) -> PhaseReport {
        self.run_shape(self.rc.phase, self.rc.batch, self.rc.seq_len)
    }

    /// Simulate one phase at an explicit workload shape, leaving the base
    /// configuration (arch/model/hardware/tp/devices) untouched. This is
    /// the [`super::CostModel`] entry: callers that sweep shapes (the
    /// serving loop, the cached model) avoid cloning a `RunConfig` per
    /// call.
    pub fn run_shape(&self, phase: Phase, batch: usize, seq_len: usize) -> PhaseReport {
        self.run_shape_mapped(phase, batch, seq_len, &self.static_map)
    }

    /// Simulate one phase shape under an explicit operator mapping. The
    /// default path is `run_shape_mapped(.., &self.static_mapping())`, so
    /// the static mapping reproduces the pre-mapper numbers bit-for-bit;
    /// the mapping search scores its candidates through this entry.
    pub fn run_shape_mapped(
        &self,
        phase: Phase,
        batch: usize,
        seq_len: usize,
        m: &Mapping,
    ) -> PhaseReport {
        let rc = &self.rc;
        let ops = layer_ops(&rc.model, phase, batch, seq_len);
        let mut layer = OpCost::zero();
        let mut reports = Vec::new();
        let mut nl_ns = 0.0;
        let mut coll_ns = 0.0;
        let mut utils = Vec::new();
        for op in &ops {
            let (c, util) = self.op_cost_mapped(op, m);
            match op.class() {
                OpClass::NonLinear => nl_ns += c.latency_ns,
                OpClass::Collective => coll_ns += c.latency_ns,
                OpClass::Fc => utils.push(util),
                _ => {}
            }
            reports.push(OpReport { name: op.name(), class: op.class(), cost: c });
            layer = layer.then(&c);
        }
        let layers = rc.model.n_layers as u64;
        let pp = (rc.devices / rc.tp).max(1) as u64;
        // stage handoff between pipeline stages (activations move once per
        // stage boundary)
        let handoff = coll::cxl_p2p((batch * rc.model.d_model * 2) as u64, &rc.hw.cxl);
        let total = layer.repeat(layers).then(&handoff.repeat(pp.saturating_sub(1)));

        let (latency_ns, tokens_per_pass) = match phase {
            Phase::Decode => (total.latency_ns, batch as f64),
            Phase::Prefill => (total.latency_ns, (batch * seq_len) as f64),
        };
        // pipeline-full throughput
        let stage_ns = latency_ns / pp as f64;
        let throughput = tokens_per_pass / (stage_ns / 1e9);

        // energy per token: dynamic of all layers / tokens + static share
        let dyn_e = self.em.dynamic(&total.counts);
        let static_pj =
            rc.devices as f64 * self.em.pim_device_static_w * (latency_ns / pp as f64)
                / tokens_per_pass;
        let mut energy = dyn_e.scale(1.0 / tokens_per_pass);
        energy.static_pj = static_pj;

        let layer_ns = layer.latency_ns.max(1e-9);
        PhaseReport {
            latency_ns,
            throughput_tok_s: throughput,
            energy,
            ops: reports,
            nonlinear_frac: nl_ns / layer_ns,
            collective_frac: coll_ns / layer_ns,
            bank_util: if utils.is_empty() {
                0.0
            } else {
                utils.iter().sum::<f64>() / utils.len() as f64
            },
            layer_cost: layer,
        }
    }
}

/// Convenience: build + run.
pub fn simulate(rc: RunConfig) -> PhaseReport {
    assert_ne!(rc.arch, ArchKind::AttAcc, "use arch::attacc::simulate for AttAcc");
    System::new(rc).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchKind, ModelConfig, Phase, RunConfig};

    fn rc(arch: ArchKind) -> RunConfig {
        RunConfig::new(arch, ModelConfig::llama2_7b())
    }

    #[test]
    fn compair_beats_cent_at_large_batch_decode() {
        // headline: 1.95-6.28x decode improvement
        let mut base = rc(ArchKind::Cent);
        base.batch = 64;
        base.seq_len = 4096;
        let mut ca = rc(ArchKind::CompAirOpt);
        ca.batch = 64;
        ca.seq_len = 4096;
        let t_cent = simulate(base).throughput_tok_s;
        let t_ca = simulate(ca).throughput_tok_s;
        let speedup = t_ca / t_cent;
        assert!(
            (1.5..12.0).contains(&speedup),
            "decode speedup out of plausible band: {speedup:.2}"
        );
    }

    #[test]
    fn batch_1_speedup_is_marginal() {
        let mut base = rc(ArchKind::Cent);
        base.batch = 1;
        let mut ca = rc(ArchKind::CompAirOpt);
        ca.batch = 1;
        let s = simulate(ca).throughput_tok_s / simulate(base).throughput_tok_s;
        assert!(s < 2.0, "batch=1 speedup should be small, got {s:.2}");
    }

    #[test]
    fn prefill_speedup_in_paper_band() {
        // Fig 17: 3.29-5.46x (Base) to 4.1-7.89x (Opt) across models
        for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
            let mut base = RunConfig::new(ArchKind::Cent, m.clone());
            base.phase = Phase::Prefill;
            base.batch = 1;
            base.seq_len = 512;
            let mut ca = base.clone();
            ca.arch = ArchKind::CompAirOpt;
            ca.hw = crate::config::HwConfig::paper_opt();
            let s = simulate(base).latency_ns / simulate(ca).latency_ns;
            assert!((2.0..10.0).contains(&s), "{}: prefill speedup {s:.2}", m.name);
        }
    }

    #[test]
    fn opt_decoder_beats_base() {
        let mut a = rc(ArchKind::CompAirBase);
        a.batch = 32;
        let mut b = rc(ArchKind::CompAirOpt);
        b.batch = 32;
        let ta = simulate(a).latency_ns;
        let tb = simulate(b).latency_ns;
        assert!(tb < ta, "decoupled decoder must help: {tb} vs {ta}");
    }

    #[test]
    fn nonlinear_fraction_grows_with_context_on_cent() {
        // Fig 5C: ~20% at 4K
        let frac = |seq: usize| {
            let mut c = rc(ArchKind::Cent);
            c.batch = 16;
            c.seq_len = seq;
            simulate(c).nonlinear_frac
        };
        let f_short = frac(512);
        let f_long = frac(32768);
        assert!(f_long > f_short, "nl fraction must grow: {f_short} -> {f_long}");
        let f_4k = frac(4096);
        assert!((0.03..0.6).contains(&f_4k), "4K nl fraction {f_4k}");
    }

    #[test]
    fn curry_alu_cuts_nonlinear_latency() {
        // Fig 22: ~30% of total non-linear latency compressed
        let mut cent = rc(ArchKind::Cent);
        cent.batch = 32;
        cent.seq_len = 16384;
        let mut curry = rc(ArchKind::CentCurry);
        curry.batch = 32;
        curry.seq_len = 16384;
        let nl = |r: &PhaseReport| -> f64 {
            r.ops
                .iter()
                .filter(|o| o.class == OpClass::NonLinear)
                .map(|o| o.cost.latency_ns)
                .sum()
        };
        let a = simulate(cent);
        let b = simulate(curry);
        assert!(nl(&b) < 0.8 * nl(&a), "curry nl {} vs cent nl {}", nl(&b), nl(&a));
    }

    #[test]
    fn tp_reduces_latency_with_diminishing_returns() {
        // Fig 18: latency drops with TP then converges
        let lat = |tp: usize| {
            let mut c = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_13b());
            c.batch = 64;
            c.seq_len = 4096;
            c.tp = tp;
            c.devices = 32;
            simulate(c).latency_ns
        };
        let l1 = lat(1);
        let l8 = lat(8);
        let l32 = lat(32);
        assert!(l8 < l1);
        let gain_1_8 = l1 / l8;
        let gain_8_32 = l8 / l32;
        assert!(gain_1_8 > gain_8_32, "diminishing returns: {gain_1_8} then {gain_8_32}");
    }

    #[test]
    fn bank_utilization_drops_with_tp() {
        let util = |tp: usize| {
            let mut c = RunConfig::new(ArchKind::Cent, ModelConfig::llama2_13b());
            c.tp = tp;
            simulate(c).bank_util
        };
        assert!(util(32) <= util(1));
    }

    #[test]
    fn energy_sram_overhead_is_bounded() {
        // Fig 15B: CompAir increases energy vs pure DRAM-PIM due to
        // cross-die traffic, but within a modest factor.
        let mut cent = rc(ArchKind::Cent);
        cent.batch = 64;
        let mut ca = rc(ArchKind::CompAirOpt);
        ca.batch = 64;
        let e_cent = simulate(cent).energy.total_pj();
        let e_ca = simulate(ca).energy.total_pj();
        let ratio = e_ca / e_cent;
        assert!((0.3..3.0).contains(&ratio), "energy ratio {ratio:.2}");
    }

    #[test]
    fn noc_fidelity_tiers_agree_to_first_order() {
        use crate::config::NocFidelity;
        let mk = |f: NocFidelity| {
            let mut c = rc(ArchKind::CompAirOpt);
            c.batch = 8;
            c.seq_len = 2048;
            c.noc_fidelity = f;
            simulate(c)
        };
        let a = mk(NocFidelity::Analytic);
        let c = mk(NocFidelity::Calibrated);
        let s = mk(NocFidelity::Simulated);
        for (name, r) in [("analytic", &a), ("calibrated", &c), ("simulated", &s)] {
            assert!(
                r.latency_ns > 0.0 && r.latency_ns.is_finite(),
                "{name} latency {}",
                r.latency_ns
            );
            assert!(r.throughput_tok_s > 0.0, "{name}");
        }
        // the tiers price the same hardware: they must agree within the
        // raw 0.5–2.0x NoC validation band (NoC ops are a fraction of the
        // layer, so the end-to-end spread is tighter still)
        for (name, r) in [("calibrated", &c), ("simulated", &s)] {
            let ratio = r.latency_ns / a.latency_ns;
            assert!((0.4..2.5).contains(&ratio), "{name} vs analytic: {ratio}");
        }
        // calibrated and simulated price identical NoC latencies (the
        // correction factor is exact at the granule level), so the full
        // pass agrees to float accumulation noise
        let rel = (c.latency_ns - s.latency_ns).abs() / s.latency_ns;
        assert!(rel < 1e-6, "calibrated vs simulated latency drift: {rel}");
    }

    #[test]
    fn static_mapped_run_is_bit_identical_to_run_shape() {
        use crate::mapper::Mapping;
        for arch in [
            ArchKind::Cent,
            ArchKind::CentCurry,
            ArchKind::CompAirBase,
            ArchKind::CompAirOpt,
            ArchKind::SramStack,
        ] {
            let sys = System::new(rc(arch));
            let m = Mapping::static_for(arch);
            assert_eq!(sys.static_mapping(), m);
            for (phase, batch, seq) in [(Phase::Decode, 16, 4096), (Phase::Prefill, 1, 512)] {
                let a = sys.run_shape(phase, batch, seq);
                let b = sys.run_shape_mapped(phase, batch, seq, &m);
                assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits(), "{arch:?}");
                assert_eq!(a.layer_cost, b.layer_cost, "{arch:?}");
                assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
            }
        }
    }

    #[test]
    fn remapping_an_op_changes_its_cost() {
        use crate::mapper::{Mapping, Placement, Slot};
        // moving the FFN down-projection off the SRAM arrays onto the
        // DRAM banks must re-price it (either direction — the point is
        // the mapping knob is live, not decorative)
        let sys = System::new(rc(ArchKind::CompAirOpt));
        let m = Mapping::static_for(ArchKind::CompAirOpt);
        let remapped = m.with(Slot::FcDown, Placement::DramPim);
        let a = sys.run_shape_mapped(Phase::Decode, 32, 4096, &m);
        let b = sys.run_shape_mapped(Phase::Decode, 32, 4096, &remapped);
        assert_ne!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        // and softmax host-vs-noc likewise
        let host_sm = m.with(Slot::Softmax, Placement::Host);
        let c = sys.run_shape_mapped(Phase::Decode, 32, 4096, &host_sm);
        assert_ne!(a.latency_ns.to_bits(), c.latency_ns.to_bits());
    }

    #[test]
    fn fc_tiles_match_paper_splits() {
        use crate::config::HwConfig;
        let hw = HwConfig::paper();
        let banks = hw.dram.banks_per_device();
        assert_eq!(banks, 512);
        // Llama2-13B Q/K/V (§3.3): output-split hands each bank a
        // 5120×30 tile (3·5120 outputs over 512 banks)
        let (out_t, in_t, active) = fc_tiles(FcMapping::OutputSplit, 5120, 3 * 5120, &hw.dram);
        assert_eq!((out_t, in_t), (30, 5120));
        assert_eq!(active, 512);
        // input-split: outputs over the 32 channels, inputs over the 16
        // banks of each channel
        let (out_t, in_t, active) = fc_tiles(FcMapping::InputSplit, 5120, 3 * 5120, &hw.dram);
        assert_eq!(out_t, (3 * 5120usize).div_ceil(hw.dram.channels_per_device));
        assert_eq!(in_t, 5120usize.div_ceil(hw.dram.banks_per_channel));
        assert_eq!(active, banks);
        // degenerate projections clamp to one column, not zero
        let (out_t, _, active) = fc_tiles(FcMapping::OutputSplit, 64, 8, &hw.dram);
        assert_eq!(out_t, 1);
        assert_eq!(active, 8);
    }

    #[test]
    fn throughput_scales_with_devices() {
        let thru = |devices: usize| {
            let mut c = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::gpt3_175b());
            c.batch = 8;
            c.seq_len = 1024;
            c.tp = 8;
            c.devices = devices;
            simulate(c).throughput_tok_s
        };
        let t32 = thru(32);
        let t96 = thru(96);
        assert!((2.5..3.5).contains(&(t96 / t32)), "96/32 device scaling {}", t96 / t32);
    }
}
