//! System-level composition: lower the transformer op-graph onto an
//! architecture variant (CENT / CENT+Curry / CompAir_Base / CompAir_Opt /
//! SRAM-stack) and report per-token latency, throughput, and energy.
//!
//! Topology model (paper §3, §7.1): `devices` PIM devices on a CXL switch;
//! a model replica is tensor-parallel over `tp` devices; `devices/tp`
//! replicas form pipeline stages over the layers, so decode throughput at a
//! full pipeline is `batch · pp / (n_layers · layer_latency)` while
//! per-token latency is `n_layers · layer_latency` plus stage handoffs.

use crate::analysis::cost_ir::{Cap, CaptureCtx, Captured, Mono, Sh, ShapeVar, TC};
use crate::config::hw::DramConfig;
use crate::config::{ArchKind, FcMapping, NocFidelity, Phase, RunConfig};
use crate::dram::{Channel, PimBank};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::mapper::{supported_placements, Mapping, Placement, Slot};
use crate::noc::model::NocModel;
use crate::noc::{exchange, model as noc_model};
use crate::sim::OpCost;
use crate::sram::bank::{SramBank, WeightPolicy};
use crate::util::json::{Json, ToJson};
use crate::workload::{layer_ops, LlmOp, OpClass};

use super::collective as coll;

/// Per-op cost entry in the report.
#[derive(Debug, Clone)]
pub struct OpReport {
    pub name: String,
    pub class: OpClass,
    pub cost: OpCost,
}

/// Full phase report.
#[derive(Debug, Clone)]
pub struct PhaseReport {
    /// Decode: latency per generated token (all layers). Prefill: latency of
    /// the full prompt pass.
    pub latency_ns: f64,
    /// Decode: aggregate tokens/s over the whole fabric.
    pub throughput_tok_s: f64,
    /// Energy per generated token (decode) or per prompt (prefill).
    pub energy: EnergyBreakdown,
    pub ops: Vec<OpReport>,
    /// Fraction of layer latency spent in non-linear ops.
    pub nonlinear_frac: f64,
    /// Fraction of layer latency spent in collectives.
    pub collective_frac: f64,
    /// Average FC bank utilization (Fig 18A).
    pub bank_util: f64,
    /// One layer's composed cost (per device; counts cover all tp devices).
    pub layer_cost: OpCost,
}

impl PhaseReport {
    /// Whole-pass cost reconstructed from the report: the full-pass latency
    /// with one layer's event counts, exactly as the serving iteration
    /// costing has always billed it.
    pub fn layer_cost_total(&self) -> OpCost {
        OpCost { latency_ns: self.latency_ns, counts: self.layer_cost.counts }
    }
}

impl ToJson for OpReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("name", self.name.as_str())
            .field("class", self.class.label())
            .field("cost", self.cost.to_json())
    }
}

impl ToJson for PhaseReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("latency_ns", self.latency_ns)
            .field("throughput_tok_s", self.throughput_tok_s)
            .field("nonlinear_frac", self.nonlinear_frac)
            .field("collective_frac", self.collective_frac)
            .field("bank_util", self.bank_util)
            .field("energy", self.energy.to_json())
            .field("layer_cost", self.layer_cost.to_json())
            .field("ops", Json::arr(self.ops.iter().map(|o| o.to_json())))
    }
}

/// The per-bank tile shape the FC lowering assigns: `(out_tile, in_tile,
/// active_banks)` for a device-local `d_in × d_out` projection. Single
/// source for `System::fc_cost` *and* the Fig 8 per-bank tables — the two
/// used to hand-code these splits independently and had drifted apart.
pub fn fc_tiles(mapping: FcMapping, d_in: usize, d_out: usize, dram: &DramConfig) -> (usize, usize, usize) {
    let banks = dram.banks_per_device();
    match mapping {
        FcMapping::OutputSplit => {
            let out_tile = d_out.div_ceil(banks).max(1);
            let active = d_out.div_ceil(out_tile).min(banks);
            (out_tile, d_in, active)
        }
        FcMapping::InputSplit => {
            // input split across the banks of a channel, output split
            // across channels
            let out_tile = d_out.div_ceil(dram.channels_per_device).max(1);
            let in_tile = d_in.div_ceil(dram.banks_per_channel).max(1);
            (out_tile, in_tile, banks)
        }
    }
}

/// Symbolic mirrors of the shape-variable-dependent op fields the traced
/// lowering consumes. `run_shape_traced` builds them once per phase from
/// the symbolic (batch, seq) inputs, mirroring `layer_ops`; the plain
/// `op_cost_mapped` path builds literal mirrors straight from the op.
struct OpShapes {
    tokens: Sh,
    batch: Sh,
    rows_q: Sh,
    eff_seq: Sh,
    /// Softmax row count (`batch · n_heads · rows_q`).
    sm_rows: Sh,
}

/// The simulator facade.
pub struct System {
    pub rc: RunConfig,
    pub em: EnergyModel,
    bank: PimBank,
    sram: SramBank,
    channel: Channel,
    /// NoC collective costing at the fidelity `rc.noc_fidelity` selects:
    /// analytic closed forms, simulator-calibrated forms, or the
    /// flit-level simulator (see `noc::model`).
    noc: Box<dyn NocModel>,
    /// The hard-coded placement this variant has always used; the default
    /// lowering path ([`System::run_shape`]) goes through it, so
    /// `mapping=static` is the pre-mapper behavior by construction.
    static_map: Mapping,
}

impl System {
    pub fn new(rc: RunConfig) -> Self {
        let em = EnergyModel::new(&rc.hw.sram, rc.hw.hb.pj_per_bit);
        let bank = PimBank::new(&rc.hw.dram);
        let sram = SramBank::new(&rc.hw.sram, rc.sram_gang, &rc.hw.dram);
        let channel = Channel::new(&rc.hw.dram);
        let noc = noc_model::build(rc.noc_fidelity, &rc.hw);
        if rc.jobs > 1 {
            // fan the calibration anchor simulations out over the run's
            // worker budget (a no-op for the stateless analytic tier and
            // the lazily-memoizing simulated tier); the fitted state is
            // bit-identical to the lazy serial fit
            noc.prefit(rc.jobs);
        }
        let static_map = Mapping::static_for(rc.arch);
        Self { rc, em, bank, sram, channel, noc, static_map }
    }

    /// The hard-coded placement baseline for this variant.
    pub fn static_mapping(&self) -> Mapping {
        self.static_map
    }

    fn banks_per_device(&self) -> usize {
        self.rc.hw.dram.banks_per_device()
    }

    /// Monotonicity axiom for NoC-tier leaves: the analytic closed forms
    /// are non-decreasing in every argument by construction, and the
    /// calibrated tier multiplies them by a per-key constant (key
    /// stability over a proof cell is guard-recorded via [`Self::noc_guard`]),
    /// but the flit-level simulator carries no axiom the prover accepts.
    fn noc_mono(&self) -> Mono {
        if self.noc.fidelity() == NocFidelity::Simulated { Mono::Opaque } else { Mono::IncAll }
    }

    /// Record the calibrated correction-factor key for a NoC collective
    /// whose banks/param argument is shape-dependent: within a proof cell
    /// the key must stay constant for the correction to be a constant
    /// factor (and the leaf's `IncAll` axiom to hold).
    fn noc_guard(&self, cap: Cap, kind: noc_model::NocCollective, param: &Sh) {
        if let Some(ctx) = cap {
            if self.noc.fidelity() == NocFidelity::Calibrated && param.e.is_some() {
                ctx.guard(
                    kind.label(),
                    noc_model::factor_key(kind, param.u64(), self.rc.hw.noc.mesh_rows),
                );
            }
        }
    }

    /// Cost of one FC op (per device; single layer) on the engine
    /// `use_sram` selects. Returns (cost, active-bank fraction).
    fn fc_cost(
        &self,
        cap: Cap,
        name: &str,
        d_in: usize,
        d_out: usize,
        tokens: &Sh,
        use_sram: bool,
    ) -> (TC, f64) {
        let tp = self.rc.tp;
        let row_parallel = matches!(name, "o" | "down");
        let (din_dev, dout_dev) = if row_parallel {
            (d_in.div_ceil(tp), d_out)
        } else {
            (d_in, d_out.div_ceil(tp))
        };
        let banks = self.banks_per_device();
        let channels = self.rc.hw.dram.channels_per_device;
        let banks_pc = self.rc.hw.dram.banks_per_channel;

        // Input distribution: the activation vector reaches every channel's
        // global buffer (channels stream in parallel over the device bus).
        let in_bytes = tokens.mulc(din_dev * 2);
        let bcast =
            TC::leaf(cap, "gb.broadcast", &[&in_bytes], self.channel.gb_broadcast(in_bytes.u64()))
                .replicate(&Sh::lit(channels));

        let gemm_leaf = |out_tile: usize, in_tile: usize| {
            if use_sram {
                TC::leaf(
                    cap,
                    "sram.gemm",
                    &[&Sh::lit(out_tile), &Sh::lit(in_tile), tokens],
                    self.sram.gemm(out_tile, in_tile, tokens.v, WeightPolicy::Reload),
                )
            } else {
                TC::leaf(
                    cap,
                    "dram.gemv",
                    &[&Sh::lit(out_tile), &Sh::lit(in_tile), tokens],
                    self.bank.gemv(out_tile, in_tile, tokens.v),
                )
            }
        };
        let (compute, active_banks, reduce) = match self.rc.fc_mapping {
            FcMapping::OutputSplit => {
                let (out_tile, in_tile, active) =
                    fc_tiles(FcMapping::OutputSplit, din_dev, dout_dev, &self.rc.hw.dram);
                (gemm_leaf(out_tile, in_tile).replicate(&Sh::lit(active)), active, TC::zero(cap))
            }
            FcMapping::InputSplit => {
                let (out_tile, in_tile, active) =
                    fc_tiles(FcMapping::InputSplit, din_dev, dout_dev, &self.rc.hw.dram);
                // partial sums reduced across the channel's banks
                let elems = tokens.mulc(out_tile);
                let red = if self.rc.arch.has_curry() {
                    TC::leaf_m(
                        cap,
                        "noc.reduce",
                        &[&elems, &Sh::lit(banks_pc)],
                        self.noc_mono(),
                        self.noc.reduce(elems.u64(), banks_pc as u64),
                    )
                    .replicate(&Sh::lit(channels))
                } else {
                    TC::leaf(
                        cap,
                        "gb.reduce",
                        &[&elems, &Sh::lit(banks_pc)],
                        self.channel.gb_reduce(elems.v, banks_pc),
                    )
                    .replicate(&Sh::lit(channels))
                };
                (gemm_leaf(out_tile, in_tile).replicate(&Sh::lit(active)), active, red)
            }
        };
        let util = active_banks as f64 / banks as f64;
        (bcast.then(&compute).then(&reduce), util)
    }

    /// Attention score / value matmuls (always DRAM-PIM in the default
    /// CompAir mapping — K/V are input-dependent, §8). The
    /// `pairs >= banks` branch is the one shape-dependent control decision
    /// in the lowering: capture records it as a guard so the prover
    /// subdivides the shape box into branch-stable cells (the predicate is
    /// monotone in batch, so corner agreement implies cell agreement).
    fn attn_cost(
        &self,
        cap: Cap,
        qk: bool,
        batch: &Sh,
        heads: usize,
        rows_q: &Sh,
        seq: &Sh,
        d_head: usize,
    ) -> TC {
        let tp = self.rc.tp;
        let heads_dev = heads.div_ceil(tp).max(1);
        let banks = self.banks_per_device();
        let pairs = batch.mulc(heads_dev);
        if let Some(ctx) = cap {
            ctx.guard("attn.pairs>=banks", (pairs.v >= banks) as u64);
        }
        if pairs.v >= banks {
            let per_bank_pairs = pairs.div_ceilc(banks);
            let per_pair = if qk {
                TC::leaf(
                    cap,
                    "dram.gemv",
                    &[seq, &Sh::lit(d_head), rows_q],
                    self.bank.gemv(seq.v, d_head, rows_q.v),
                )
            } else {
                TC::leaf(
                    cap,
                    "dram.gemv",
                    &[&Sh::lit(d_head), seq, rows_q],
                    self.bank.gemv(d_head, seq.v, rows_q.v),
                )
            };
            per_pair.repeat(&per_bank_pairs).replicate(&Sh::lit(banks))
        } else {
            let banks_per_pair = Sh::lit(banks).floor_div(&pairs).maxc(1);
            if qk {
                // output-split along seq: no reduction needed
                let seq_tile = seq.div_ceil(&banks_per_pair).maxc(1);
                TC::leaf(
                    cap,
                    "dram.gemv",
                    &[&seq_tile, &Sh::lit(d_head), rows_q],
                    self.bank.gemv(seq_tile.v, d_head, rows_q.v),
                )
                .replicate(&pairs.mul(&banks_per_pair))
            } else {
                // input-split along seq: partial Dh sums reduced per pair
                let in_tile = seq.div_ceil(&banks_per_pair).maxc(1);
                let gemv = TC::leaf(
                    cap,
                    "dram.gemv",
                    &[&Sh::lit(d_head), &in_tile, rows_q],
                    self.bank.gemv(d_head, in_tile.v, rows_q.v),
                )
                .replicate(&pairs.mul(&banks_per_pair));
                let elems = rows_q.mulc(d_head);
                let bpp16 = banks_per_pair.minc(16);
                let red = if self.rc.arch.has_curry() {
                    self.noc_guard(cap, noc_model::NocCollective::Reduce, &bpp16);
                    TC::leaf_m(
                        cap,
                        "noc.reduce",
                        &[&elems, &bpp16],
                        self.noc_mono(),
                        self.noc.reduce(elems.u64(), bpp16.u64()),
                    )
                    .replicate(&pairs)
                } else {
                    TC::leaf(
                        cap,
                        "gb.reduce",
                        &[&elems, &bpp16],
                        self.channel.gb_reduce(elems.v, bpp16.v),
                    )
                    .replicate(&pairs)
                };
                gemv.then(&red)
            }
        }
    }

    fn softmax_cost(&self, cap: Cap, rows: &Sh, seq: &Sh, on_noc: bool) -> TC {
        let tp = self.rc.tp;
        let rows_dev = rows.div_ceilc(tp).maxc(1);
        let banks = self.banks_per_device();
        let elems = rows_dev.mul(seq);
        if on_noc {
            // distributed: exp bank-locally, per-row partial sums on the MAC
            // lanes, scalar tree reduce + broadcast, divide in transit
            let per_bank = elems.div_ceilc(banks);
            let exp = TC::leaf_m(
                cap,
                "noc.exp",
                &[&per_bank, &Sh::lit(8)],
                self.noc_mono(),
                self.noc.exp(per_bank.u64(), 8),
            )
            .replicate(&Sh::lit(banks));
            let partial_ns = per_bank.v as f64 / 16.0 * self.rc.hw.dram.t_ccd_ns;
            let partial = TC::leaf(cap, "dram.mac-partial", &[&per_bank], OpCost::latency(partial_ns));
            let banks_pc = self.rc.hw.dram.banks_per_channel;
            let channels = self.rc.hw.dram.channels_per_device;
            let rows_pc = rows_dev.div_ceilc(channels).maxc(1);
            let red = TC::leaf_m(
                cap,
                "noc.reduce",
                &[&rows_pc, &Sh::lit(banks_pc)],
                self.noc_mono(),
                self.noc.reduce(rows_pc.u64(), banks_pc as u64),
            )
            .replicate(&Sh::lit(channels));
            let bc = TC::leaf_m(
                cap,
                "noc.broadcast",
                &[&rows_pc, &Sh::lit(banks_pc)],
                self.noc_mono(),
                self.noc.broadcast(rows_pc.u64(), banks_pc as u64),
            )
            .replicate(&Sh::lit(channels));
            let div = TC::leaf_m(
                cap,
                "noc.scalar-stream",
                &[&per_bank],
                self.noc_mono(),
                self.noc.scalar_stream(per_bank.u64()),
            )
            .replicate(&Sh::lit(banks));
            exp.then(&partial).then(&red).then(&bc).then(&div)
        } else {
            // centralized NLU: scores cross the channel I/O both ways
            let bytes = elems.mulc(2);
            TC::leaf(
                cap,
                "nlu.roundtrip",
                &[&bytes, &bytes, &elems.mulc(5)],
                coll::nlu_roundtrip(
                    bytes.u64(),
                    bytes.u64(),
                    5 * elems.u64(),
                    self.rc.hw.dram.channels_per_device as u64,
                    &self.rc.hw.dram,
                ),
            )
        }
    }

    fn rope_cost(&self, cap: Cap, tokens: &Sh, heads: usize, d_head: usize, on_noc: bool) -> TC {
        let tp = self.rc.tp;
        let vecs_dev = tokens.mulc(heads.div_ceil(tp)).maxc(1);
        let banks = self.banks_per_device();
        if on_noc {
            let per_bank_vecs = vecs_dev.div_ceilc(banks).maxc(1);
            let ex = TC::leaf(
                cap,
                "noc.exchange",
                &[&Sh::lit(d_head)],
                exchange::exchange_cost(d_head, &self.rc.hw.noc),
            )
            .repeat(&per_bank_vecs)
            .replicate(&Sh::lit(banks));
            // cos/sin EWMULs on the bank lanes: 2 muls + 1 add per element
            let ew_elems = per_bank_vecs.mulc(d_head * 2);
            let ew = TC::leaf(
                cap,
                "dram.ewmul",
                &[&ew_elems],
                coll::dram_ewmul(ew_elems.u64(), &self.rc.hw),
            )
            .replicate(&Sh::lit(banks));
            ex.then(&ew)
        } else {
            let bytes = vecs_dev.mulc(d_head * 2);
            TC::leaf(
                cap,
                "nlu.roundtrip",
                &[&bytes, &bytes, &vecs_dev.mulc(d_head).mulc(3)],
                coll::nlu_roundtrip(
                    bytes.u64(),
                    bytes.u64(),
                    3 * vecs_dev.mulc(d_head).u64(),
                    self.rc.hw.dram.channels_per_device as u64,
                    &self.rc.hw.dram,
                ),
            )
        }
    }

    fn rmsnorm_cost(&self, cap: Cap, tokens: &Sh, d_model: usize, on_noc: bool) -> TC {
        let banks = self.banks_per_device();
        let elems = tokens.mulc(d_model);
        if on_noc {
            let per_bank = elems.div_ceilc(banks);
            // square-accumulate on MAC lanes (x·x into the accumulator)
            let sq = TC::leaf(
                cap,
                "dram.mac-square",
                &[&per_bank],
                OpCost::latency(per_bank.v as f64 / 16.0 * self.rc.hw.dram.t_ccd_ns),
            )
            .replicate(&Sh::lit(banks));
            let banks_pc = self.rc.hw.dram.banks_per_channel;
            let channels = self.rc.hw.dram.channels_per_device;
            let rows_pc = tokens.div_ceilc(channels).maxc(1);
            let red = TC::leaf_m(
                cap,
                "noc.reduce",
                &[&rows_pc, &Sh::lit(banks_pc)],
                self.noc_mono(),
                self.noc.reduce(rows_pc.u64(), banks_pc as u64),
            )
            .replicate(&Sh::lit(channels));
            let rsqrt = TC::leaf_m(
                cap,
                "noc.sqrt",
                &[&rows_pc, &Sh::lit(4)],
                self.noc_mono(),
                self.noc.sqrt(rows_pc.u64(), 4),
            )
            .replicate(&Sh::lit(channels));
            let bc = TC::leaf_m(
                cap,
                "noc.broadcast",
                &[&rows_pc, &Sh::lit(banks_pc)],
                self.noc_mono(),
                self.noc.broadcast(rows_pc.u64(), banks_pc as u64),
            )
            .replicate(&Sh::lit(channels));
            let scale = TC::leaf(
                cap,
                "dram.ewmul",
                &[&per_bank],
                coll::dram_ewmul(per_bank.u64(), &self.rc.hw),
            )
            .replicate(&Sh::lit(banks));
            sq.then(&red).then(&rsqrt).then(&bc).then(&scale)
        } else {
            let bytes = elems.mulc(2);
            TC::leaf(
                cap,
                "nlu.roundtrip",
                &[&bytes, &bytes, &elems.mulc(3)],
                coll::nlu_roundtrip(
                    bytes.u64(),
                    bytes.u64(),
                    3 * elems.u64(),
                    self.rc.hw.dram.channels_per_device as u64,
                    &self.rc.hw.dram,
                ),
            )
        }
    }

    fn activation_cost(&self, cap: Cap, tokens: &Sh, width: usize, on_noc: bool) -> TC {
        let tp = self.rc.tp;
        let elems = tokens.mulc(width.div_ceil(tp));
        let banks = self.banks_per_device();
        if on_noc {
            let per_bank = elems.div_ceilc(banks);
            // sigmoid: exp + 1/(1+e); gating: EWMUL on the lanes
            let exp = TC::leaf_m(
                cap,
                "noc.exp",
                &[&per_bank, &Sh::lit(8)],
                self.noc_mono(),
                self.noc.exp(per_bank.u64(), 8),
            )
            .replicate(&Sh::lit(banks));
            let post = TC::leaf_m(
                cap,
                "noc.scalar-stream",
                &[&per_bank],
                self.noc_mono(),
                self.noc.scalar_stream(per_bank.u64()),
            )
            .replicate(&Sh::lit(banks));
            let gate = TC::leaf(
                cap,
                "dram.ewmul",
                &[&per_bank],
                coll::dram_ewmul(per_bank.u64(), &self.rc.hw),
            )
            .replicate(&Sh::lit(banks));
            exp.then(&post).then(&gate)
        } else {
            let bytes = elems.mulc(2);
            TC::leaf(
                cap,
                "nlu.roundtrip",
                &[&bytes.mulc(2), &bytes, &elems.mulc(4)], // x and gate move out
                coll::nlu_roundtrip(
                    bytes.u64() * 2,
                    bytes.u64(),
                    4 * elems.u64(),
                    self.rc.hw.dram.channels_per_device as u64,
                    &self.rc.hw.dram,
                ),
            )
        }
    }

    /// Lower one op under the static mapping; counts are per tp-group
    /// (all devices of the replica).
    pub fn op_cost(&self, op: &LlmOp) -> (OpCost, f64) {
        self.op_cost_mapped(op, &self.static_map)
    }

    /// Lower one op on the engine the mapping assigns its slot. The
    /// placement must be legal for this variant (`supported_placements`);
    /// the search only emits legal mappings, so this is a debug assert,
    /// not a runtime gate.
    pub fn op_cost_mapped(&self, op: &LlmOp, m: &Mapping) -> (OpCost, f64) {
        // literal shape mirrors straight from the op's own fields: no
        // capture, no symbols — the traced lowering degenerates to the
        // plain arithmetic
        let lit = Sh::lit;
        let sh = match op {
            LlmOp::AttnQK { batch, rows_q, seq, .. } | LlmOp::AttnSV { batch, rows_q, seq, .. } => {
                OpShapes {
                    tokens: lit(0),
                    batch: lit(*batch),
                    rows_q: lit(*rows_q),
                    eff_seq: lit(*seq),
                    sm_rows: lit(0),
                }
            }
            LlmOp::Softmax { rows, seq } => OpShapes {
                tokens: lit(0),
                batch: lit(1),
                rows_q: lit(1),
                eff_seq: lit(*seq),
                sm_rows: lit(*rows),
            },
            LlmOp::Fc { tokens, .. }
            | LlmOp::Rope { tokens, .. }
            | LlmOp::RmsNorm { tokens, .. }
            | LlmOp::Activation { tokens, .. }
            | LlmOp::AllReduce { tokens, .. } => OpShapes {
                tokens: lit(*tokens),
                batch: lit(0),
                rows_q: lit(0),
                eff_seq: lit(0),
                sm_rows: lit(0),
            },
        };
        let (c, util) = self.op_cost_traced(None, op, m, &sh);
        (c.c, util)
    }

    /// The one lowering path, shared by the plain and capture entries.
    /// `sh` carries the symbolic mirrors of every shape-variable-dependent
    /// op field; their concrete values are debug-asserted against the op's
    /// own fields (the `prv.eval-drift` pass is the release-mode backstop
    /// against the mirrors drifting from `layer_ops`).
    fn op_cost_traced(&self, cap: Cap, op: &LlmOp, m: &Mapping, sh: &OpShapes) -> (TC, f64) {
        let place = m.placement_of(op);
        debug_assert!(
            supported_placements(Slot::of_op(op), self.rc.arch).contains(&place),
            "{:?} cannot run on {} under {:?}",
            Slot::of_op(op),
            place.label(),
            self.rc.arch
        );
        let use_sram = place == Placement::SramPim;
        let on_noc = place == Placement::NocAlu;
        let tp = self.rc.tp;
        let (c, util) = match op {
            LlmOp::Fc { name, d_in, d_out, tokens } => {
                debug_assert_eq!(sh.tokens.v, *tokens);
                self.fc_cost(cap, name, *d_in, *d_out, &sh.tokens, use_sram)
            }
            LlmOp::AttnQK { batch, heads, rows_q, seq, d_head } => {
                debug_assert_eq!((sh.batch.v, sh.rows_q.v, sh.eff_seq.v), (*batch, *rows_q, *seq));
                (self.attn_cost(cap, true, &sh.batch, *heads, &sh.rows_q, &sh.eff_seq, *d_head), 1.0)
            }
            LlmOp::AttnSV { batch, heads, rows_q, seq, d_head } => {
                debug_assert_eq!((sh.batch.v, sh.rows_q.v, sh.eff_seq.v), (*batch, *rows_q, *seq));
                (self.attn_cost(cap, false, &sh.batch, *heads, &sh.rows_q, &sh.eff_seq, *d_head), 1.0)
            }
            LlmOp::Softmax { rows, seq } => {
                debug_assert_eq!((sh.sm_rows.v, sh.eff_seq.v), (*rows, *seq));
                (self.softmax_cost(cap, &sh.sm_rows, &sh.eff_seq, on_noc), 1.0)
            }
            LlmOp::Rope { tokens, heads, d_head } => {
                debug_assert_eq!(sh.tokens.v, *tokens);
                (self.rope_cost(cap, &sh.tokens, *heads, *d_head, on_noc), 1.0)
            }
            LlmOp::RmsNorm { tokens, d_model } => {
                debug_assert_eq!(sh.tokens.v, *tokens);
                (self.rmsnorm_cost(cap, &sh.tokens, *d_model, on_noc), 1.0)
            }
            LlmOp::Activation { tokens, width, .. } => {
                debug_assert_eq!(sh.tokens.v, *tokens);
                (self.activation_cost(cap, &sh.tokens, *width, on_noc), 1.0)
            }
            LlmOp::AllReduce { tokens, d_model } => {
                debug_assert_eq!(sh.tokens.v, *tokens);
                let bytes = sh.tokens.mulc(*d_model * 2);
                (
                    TC::leaf(
                        cap,
                        "cxl.allreduce",
                        &[&bytes, &Sh::lit(self.rc.tp)],
                        coll::cxl_allreduce(bytes.u64(), self.rc.tp as u64, &self.rc.hw.cxl),
                    ),
                    1.0,
                )
            }
        };
        // events happen on every device of the tp group
        (c.replicate(&Sh::lit(tp)), util)
    }

    /// Simulate the configured phase (`rc.phase` / `rc.batch` /
    /// `rc.seq_len`).
    pub fn run(&self) -> PhaseReport {
        self.run_shape(self.rc.phase, self.rc.batch, self.rc.seq_len)
    }

    /// Simulate one phase at an explicit workload shape, leaving the base
    /// configuration (arch/model/hardware/tp/devices) untouched. This is
    /// the [`super::CostModel`] entry: callers that sweep shapes (the
    /// serving loop, the cached model) avoid cloning a `RunConfig` per
    /// call.
    pub fn run_shape(&self, phase: Phase, batch: usize, seq_len: usize) -> PhaseReport {
        self.run_shape_mapped(phase, batch, seq_len, &self.static_map)
    }

    /// Simulate one phase shape under an explicit operator mapping. The
    /// default path is `run_shape_mapped(.., &self.static_mapping())`, so
    /// the static mapping reproduces the pre-mapper numbers bit-for-bit;
    /// the mapping search scores its candidates through this entry.
    /// Capture stays off: no IR is allocated and the arithmetic is the
    /// plain `OpCost` fold.
    pub fn run_shape_mapped(
        &self,
        phase: Phase,
        batch: usize,
        seq_len: usize,
        m: &Mapping,
    ) -> PhaseReport {
        self.run_shape_traced(None, phase, batch, seq_len, m).0
    }

    /// Capture-mode entry: run one phase shape with cost-expression IR
    /// recording enabled (`analysis/cost_ir.rs`). The report is
    /// numerically identical to [`Self::run_shape_mapped`]; the second
    /// return value carries the captured DAG for the phase total
    /// (pre-epilogue: all layers plus pipeline handoffs), the guard
    /// vector, and the concrete totals the IR must replay to bit-for-bit.
    pub fn run_shape_captured(
        &self,
        phase: Phase,
        batch: usize,
        seq_len: usize,
        m: &Mapping,
    ) -> (PhaseReport, Captured) {
        let ctx = CaptureCtx::new();
        let (report, total) = self.run_shape_traced(Some(&ctx), phase, batch, seq_len, m);
        let captured = Captured {
            root: total.n.clone().expect("capture was enabled"),
            guards: ctx.take_guards(),
            total: total.c,
            dynamic_pj: self.em.dynamic(&total.c.counts).total_pj(),
        };
        (report, captured)
    }

    fn run_shape_traced(
        &self,
        cap: Cap,
        phase: Phase,
        batch: usize,
        seq_len: usize,
        m: &Mapping,
    ) -> (PhaseReport, TC) {
        let rc = &self.rc;
        let ops = layer_ops(&rc.model, phase, batch, seq_len);
        // symbolic mirrors of layer_ops' shape decomposition: decode
        // ranges over (batch, kv), prefill over (batch, seq)
        let b = Sh::input(cap, batch, ShapeVar::Batch);
        let (tokens, rows_q, eff_seq) = match phase {
            Phase::Decode => {
                let s = Sh::input(cap, seq_len, ShapeVar::Kv);
                (b.clone(), Sh::lit(1), s)
            }
            Phase::Prefill => {
                let s = Sh::input(cap, seq_len, ShapeVar::Seq);
                (b.mul(&s), s.clone(), s.div_ceilc(2).maxc(1))
            }
        };
        let sm_rows = b.mulc(rc.model.n_heads).mul(&rows_q);
        let sh = OpShapes { tokens, batch: b, rows_q, eff_seq, sm_rows };
        let mut layer = TC::zero(cap);
        let mut reports = Vec::new();
        let mut nl_ns = 0.0;
        let mut coll_ns = 0.0;
        let mut utils = Vec::new();
        for op in &ops {
            let (c, util) = self.op_cost_traced(cap, op, m, &sh);
            match op.class() {
                OpClass::NonLinear => nl_ns += c.c.latency_ns,
                OpClass::Collective => coll_ns += c.c.latency_ns,
                OpClass::Fc => utils.push(util),
                _ => {}
            }
            reports.push(OpReport { name: op.name(), class: op.class(), cost: c.c });
            layer = layer.then(&c);
        }
        let layers = rc.model.n_layers;
        let pp = (rc.devices / rc.tp).max(1) as u64;
        // stage handoff between pipeline stages (activations move once per
        // stage boundary)
        let hbytes = sh.batch.mulc(rc.model.d_model * 2);
        let handoff =
            TC::leaf(cap, "cxl.p2p", &[&hbytes], coll::cxl_p2p(hbytes.u64(), &rc.hw.cxl));
        let total = layer
            .repeat(&Sh::lit(layers))
            .then(&handoff.repeat(&Sh::lit(pp.saturating_sub(1) as usize)));

        let (latency_ns, tokens_per_pass) = match phase {
            Phase::Decode => (total.c.latency_ns, batch as f64),
            Phase::Prefill => (total.c.latency_ns, (batch * seq_len) as f64),
        };
        // pipeline-full throughput
        let stage_ns = latency_ns / pp as f64;
        let throughput = tokens_per_pass / (stage_ns / 1e9);

        // energy per token: dynamic of all layers / tokens + static share
        let dyn_e = self.em.dynamic(&total.c.counts);
        let static_pj =
            rc.devices as f64 * self.em.pim_device_static_w * (latency_ns / pp as f64)
                / tokens_per_pass;
        let mut energy = dyn_e.scale(1.0 / tokens_per_pass);
        energy.static_pj = static_pj;

        let layer_ns = layer.c.latency_ns.max(1e-9);
        let report = PhaseReport {
            latency_ns,
            throughput_tok_s: throughput,
            energy,
            ops: reports,
            nonlinear_frac: nl_ns / layer_ns,
            collective_frac: coll_ns / layer_ns,
            bank_util: if utils.is_empty() {
                0.0
            } else {
                utils.iter().sum::<f64>() / utils.len() as f64
            },
            layer_cost: layer.c,
        };
        (report, total)
    }
}

/// Convenience: build + run.
pub fn simulate(rc: RunConfig) -> PhaseReport {
    assert_ne!(rc.arch, ArchKind::AttAcc, "use arch::attacc::simulate for AttAcc");
    System::new(rc).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchKind, ModelConfig, Phase, RunConfig};

    fn rc(arch: ArchKind) -> RunConfig {
        RunConfig::new(arch, ModelConfig::llama2_7b())
    }

    #[test]
    fn compair_beats_cent_at_large_batch_decode() {
        // headline: 1.95-6.28x decode improvement
        let mut base = rc(ArchKind::Cent);
        base.batch = 64;
        base.seq_len = 4096;
        let mut ca = rc(ArchKind::CompAirOpt);
        ca.batch = 64;
        ca.seq_len = 4096;
        let t_cent = simulate(base).throughput_tok_s;
        let t_ca = simulate(ca).throughput_tok_s;
        let speedup = t_ca / t_cent;
        assert!(
            (1.5..12.0).contains(&speedup),
            "decode speedup out of plausible band: {speedup:.2}"
        );
    }

    #[test]
    fn batch_1_speedup_is_marginal() {
        let mut base = rc(ArchKind::Cent);
        base.batch = 1;
        let mut ca = rc(ArchKind::CompAirOpt);
        ca.batch = 1;
        let s = simulate(ca).throughput_tok_s / simulate(base).throughput_tok_s;
        assert!(s < 2.0, "batch=1 speedup should be small, got {s:.2}");
    }

    #[test]
    fn prefill_speedup_in_paper_band() {
        // Fig 17: 3.29-5.46x (Base) to 4.1-7.89x (Opt) across models
        for m in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
            let mut base = RunConfig::new(ArchKind::Cent, m.clone());
            base.phase = Phase::Prefill;
            base.batch = 1;
            base.seq_len = 512;
            let mut ca = base.clone();
            ca.arch = ArchKind::CompAirOpt;
            ca.hw = crate::config::HwConfig::paper_opt();
            let s = simulate(base).latency_ns / simulate(ca).latency_ns;
            assert!((2.0..10.0).contains(&s), "{}: prefill speedup {s:.2}", m.name);
        }
    }

    #[test]
    fn opt_decoder_beats_base() {
        let mut a = rc(ArchKind::CompAirBase);
        a.batch = 32;
        let mut b = rc(ArchKind::CompAirOpt);
        b.batch = 32;
        let ta = simulate(a).latency_ns;
        let tb = simulate(b).latency_ns;
        assert!(tb < ta, "decoupled decoder must help: {tb} vs {ta}");
    }

    #[test]
    fn nonlinear_fraction_grows_with_context_on_cent() {
        // Fig 5C: ~20% at 4K
        let frac = |seq: usize| {
            let mut c = rc(ArchKind::Cent);
            c.batch = 16;
            c.seq_len = seq;
            simulate(c).nonlinear_frac
        };
        let f_short = frac(512);
        let f_long = frac(32768);
        assert!(f_long > f_short, "nl fraction must grow: {f_short} -> {f_long}");
        let f_4k = frac(4096);
        assert!((0.03..0.6).contains(&f_4k), "4K nl fraction {f_4k}");
    }

    #[test]
    fn curry_alu_cuts_nonlinear_latency() {
        // Fig 22: ~30% of total non-linear latency compressed
        let mut cent = rc(ArchKind::Cent);
        cent.batch = 32;
        cent.seq_len = 16384;
        let mut curry = rc(ArchKind::CentCurry);
        curry.batch = 32;
        curry.seq_len = 16384;
        let nl = |r: &PhaseReport| -> f64 {
            r.ops
                .iter()
                .filter(|o| o.class == OpClass::NonLinear)
                .map(|o| o.cost.latency_ns)
                .sum()
        };
        let a = simulate(cent);
        let b = simulate(curry);
        assert!(nl(&b) < 0.8 * nl(&a), "curry nl {} vs cent nl {}", nl(&b), nl(&a));
    }

    #[test]
    fn tp_reduces_latency_with_diminishing_returns() {
        // Fig 18: latency drops with TP then converges
        let lat = |tp: usize| {
            let mut c = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_13b());
            c.batch = 64;
            c.seq_len = 4096;
            c.tp = tp;
            c.devices = 32;
            simulate(c).latency_ns
        };
        let l1 = lat(1);
        let l8 = lat(8);
        let l32 = lat(32);
        assert!(l8 < l1);
        let gain_1_8 = l1 / l8;
        let gain_8_32 = l8 / l32;
        assert!(gain_1_8 > gain_8_32, "diminishing returns: {gain_1_8} then {gain_8_32}");
    }

    #[test]
    fn bank_utilization_drops_with_tp() {
        let util = |tp: usize| {
            let mut c = RunConfig::new(ArchKind::Cent, ModelConfig::llama2_13b());
            c.tp = tp;
            simulate(c).bank_util
        };
        assert!(util(32) <= util(1));
    }

    #[test]
    fn energy_sram_overhead_is_bounded() {
        // Fig 15B: CompAir increases energy vs pure DRAM-PIM due to
        // cross-die traffic, but within a modest factor.
        let mut cent = rc(ArchKind::Cent);
        cent.batch = 64;
        let mut ca = rc(ArchKind::CompAirOpt);
        ca.batch = 64;
        let e_cent = simulate(cent).energy.total_pj();
        let e_ca = simulate(ca).energy.total_pj();
        let ratio = e_ca / e_cent;
        assert!((0.3..3.0).contains(&ratio), "energy ratio {ratio:.2}");
    }

    #[test]
    fn noc_fidelity_tiers_agree_to_first_order() {
        use crate::config::NocFidelity;
        let mk = |f: NocFidelity| {
            let mut c = rc(ArchKind::CompAirOpt);
            c.batch = 8;
            c.seq_len = 2048;
            c.noc_fidelity = f;
            simulate(c)
        };
        let a = mk(NocFidelity::Analytic);
        let c = mk(NocFidelity::Calibrated);
        let s = mk(NocFidelity::Simulated);
        for (name, r) in [("analytic", &a), ("calibrated", &c), ("simulated", &s)] {
            assert!(
                r.latency_ns > 0.0 && r.latency_ns.is_finite(),
                "{name} latency {}",
                r.latency_ns
            );
            assert!(r.throughput_tok_s > 0.0, "{name}");
        }
        // the tiers price the same hardware: they must agree within the
        // raw 0.5–2.0x NoC validation band (NoC ops are a fraction of the
        // layer, so the end-to-end spread is tighter still)
        for (name, r) in [("calibrated", &c), ("simulated", &s)] {
            let ratio = r.latency_ns / a.latency_ns;
            assert!((0.4..2.5).contains(&ratio), "{name} vs analytic: {ratio}");
        }
        // calibrated and simulated price identical NoC latencies (the
        // correction factor is exact at the granule level), so the full
        // pass agrees to float accumulation noise
        let rel = (c.latency_ns - s.latency_ns).abs() / s.latency_ns;
        assert!(rel < 1e-6, "calibrated vs simulated latency drift: {rel}");
    }

    #[test]
    fn static_mapped_run_is_bit_identical_to_run_shape() {
        use crate::mapper::Mapping;
        for arch in [
            ArchKind::Cent,
            ArchKind::CentCurry,
            ArchKind::CompAirBase,
            ArchKind::CompAirOpt,
            ArchKind::SramStack,
        ] {
            let sys = System::new(rc(arch));
            let m = Mapping::static_for(arch);
            assert_eq!(sys.static_mapping(), m);
            for (phase, batch, seq) in [(Phase::Decode, 16, 4096), (Phase::Prefill, 1, 512)] {
                let a = sys.run_shape(phase, batch, seq);
                let b = sys.run_shape_mapped(phase, batch, seq, &m);
                assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits(), "{arch:?}");
                assert_eq!(a.layer_cost, b.layer_cost, "{arch:?}");
                assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
            }
        }
    }

    #[test]
    fn captured_run_matches_plain_run_bit_for_bit() {
        use crate::analysis::cost_ir::replay;
        // the soundness anchor, both directions: capture-off is the plain
        // fold (same entry), and the captured IR replays to the same bits
        for arch in [ArchKind::Cent, ArchKind::CompAirOpt, ArchKind::SramStack] {
            let sys = System::new(rc(arch));
            let m = sys.static_mapping();
            for (phase, batch, seq) in [(Phase::Decode, 16, 4096), (Phase::Prefill, 2, 512)] {
                let plain = sys.run_shape_mapped(phase, batch, seq, &m);
                let (traced, cap) = sys.run_shape_captured(phase, batch, seq, &m);
                assert_eq!(plain.latency_ns.to_bits(), traced.latency_ns.to_bits(), "{arch:?}");
                assert_eq!(plain.layer_cost, traced.layer_cost, "{arch:?}");
                assert_eq!(
                    plain.energy.total_pj().to_bits(),
                    traced.energy.total_pj().to_bits()
                );
                let r = replay(&cap.root);
                assert_eq!(r.latency_ns.to_bits(), cap.total.latency_ns.to_bits(), "{arch:?}");
                assert_eq!(r.counts, cap.total.counts, "{arch:?}");
                assert_eq!(
                    sys.em.dynamic(&r.counts).total_pj().to_bits(),
                    cap.dynamic_pj.to_bits()
                );
            }
        }
    }

    #[test]
    fn remapping_an_op_changes_its_cost() {
        use crate::mapper::{Mapping, Placement, Slot};
        // moving the FFN down-projection off the SRAM arrays onto the
        // DRAM banks must re-price it (either direction — the point is
        // the mapping knob is live, not decorative)
        let sys = System::new(rc(ArchKind::CompAirOpt));
        let m = Mapping::static_for(ArchKind::CompAirOpt);
        let remapped = m.with(Slot::FcDown, Placement::DramPim);
        let a = sys.run_shape_mapped(Phase::Decode, 32, 4096, &m);
        let b = sys.run_shape_mapped(Phase::Decode, 32, 4096, &remapped);
        assert_ne!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        // and softmax host-vs-noc likewise
        let host_sm = m.with(Slot::Softmax, Placement::Host);
        let c = sys.run_shape_mapped(Phase::Decode, 32, 4096, &host_sm);
        assert_ne!(a.latency_ns.to_bits(), c.latency_ns.to_bits());
    }

    #[test]
    fn fc_tiles_match_paper_splits() {
        use crate::config::HwConfig;
        let hw = HwConfig::paper();
        let banks = hw.dram.banks_per_device();
        assert_eq!(banks, 512);
        // Llama2-13B Q/K/V (§3.3): output-split hands each bank a
        // 5120×30 tile (3·5120 outputs over 512 banks)
        let (out_t, in_t, active) = fc_tiles(FcMapping::OutputSplit, 5120, 3 * 5120, &hw.dram);
        assert_eq!((out_t, in_t), (30, 5120));
        assert_eq!(active, 512);
        // input-split: outputs over the 32 channels, inputs over the 16
        // banks of each channel
        let (out_t, in_t, active) = fc_tiles(FcMapping::InputSplit, 5120, 3 * 5120, &hw.dram);
        assert_eq!(out_t, (3 * 5120usize).div_ceil(hw.dram.channels_per_device));
        assert_eq!(in_t, 5120usize.div_ceil(hw.dram.banks_per_channel));
        assert_eq!(active, banks);
        // degenerate projections clamp to one column, not zero
        let (out_t, _, active) = fc_tiles(FcMapping::OutputSplit, 64, 8, &hw.dram);
        assert_eq!(out_t, 1);
        assert_eq!(active, 8);
    }

    #[test]
    fn throughput_scales_with_devices() {
        let thru = |devices: usize| {
            let mut c = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::gpt3_175b());
            c.batch = 8;
            c.seq_len = 1024;
            c.tp = 8;
            c.devices = devices;
            simulate(c).throughput_tok_s
        };
        let t32 = thru(32);
        let t96 = thru(96);
        assert!((2.5..3.5).contains(&(t96 / t32)), "96/32 device scaling {}", t96 / t32);
    }
}
