//! The costing interface every entry point drives.
//!
//! The paper's value is one coherent hardware model evaluated under many
//! lenses; this module gives that model one stable API. [`CostModel`]
//! exposes the two questions every harness asks — "what does a phase cost
//! at this shape?" ([`CostModel::phase_report`]) and "what does one serving
//! iteration cost?" ([`CostModel::iteration_cost`]) — with the base
//! architecture/model/fabric fixed at construction and only the workload
//! shape varying per call.
//!
//! [`System`] implements the trait directly (uncached: every call re-lowers
//! the transformer op-graph). [`CachedCostModel`] wraps any model and
//! memoizes both levels: full [`PhaseReport`]s by `(arch, noc_fidelity,
//! phase, batch, seq_len)` and composed iteration [`OpCost`]s by `(prefill_tokens,
//! decode_batch, max_kv)` — with the iteration key normalized to the cost
//! function's true arguments (no decode half ⇒ `max_kv` is irrelevant and
//! must not fragment the cache).
//!
//! What actually repeats: chunked prefill re-prices the same
//! `(Prefill, 1, chunk)` shape on every iteration of a long prompt — the
//! dominant cost of the rag/long-context scenarios — and cluster replicas
//! retrace each other's shapes through the shared cache. Decode shapes
//! drift as the KV cache grows (`max_kv` rises every decode step), so the
//! iteration path deliberately retains only the `Copy` whole-pass
//! [`OpCost`] per shape, never the full per-op report, and every map is
//! capped so a long run's memory stays bounded: at the cap the *oldest
//! half* of the entries (insertion order) is evicted, which keeps the
//! recent working set — the shapes a sweep is currently retracing — warm
//! instead of cold-starting the whole cache.
//! Memoization is sound because the simulator is a pure function of
//! `(base config, shape)`; the golden tests in
//! `tests/integration_engine.rs` assert cached ≡ uncached bit-for-bit.

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};

use crate::config::{ArchKind, NocFidelity, Phase, RunConfig};
use crate::mapper::Mapping;
use crate::sim::OpCost;
use crate::util::json::{Json, ToJson};

use super::system::{PhaseReport, System};

/// Memoization key for a phase-level costing call. The wrapped model's
/// hardware/model config is fixed, so the shape (plus the arch and NoC
/// fidelity, for defense against key reuse across models — two runs that
/// differ only in fidelity tier price the same shape differently and must
/// never share an entry) identifies the result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    pub arch: ArchKind,
    pub fidelity: NocFidelity,
    pub phase: Phase,
    pub batch: usize,
    pub seq_len: usize,
}

/// Memoization key for one serving iteration (a chunk of prefill tokens
/// composed with one decode step).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct IterKey {
    pub prefill_tokens: usize,
    pub decode_batch: usize,
    pub max_kv: usize,
}

/// Cache effectiveness counters (see [`CachedCostModel::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped by the capped maps' oldest-half eviction. Zero on
    /// every workload whose distinct-shape count stays under the caps.
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when nothing was asked).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl ToJson for CacheStats {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("hits", self.hits)
            .field("misses", self.misses)
            .field("evictions", self.evictions)
            .field("hit_rate", self.hit_rate())
    }
}

/// One architecture point's costing interface: the base configuration is
/// fixed, the workload shape varies per call. Object-safe, so harness
/// loops take `&dyn CostModel` and run cached or uncached transparently.
pub trait CostModel {
    /// The base run configuration (arch / model / hardware / tp / devices).
    fn base(&self) -> &RunConfig;

    /// Full phase report for the base configuration at the given shape.
    /// For decode, `seq_len` is the KV length; for prefill, the prompt
    /// length.
    fn phase_report(&self, phase: Phase, batch: usize, seq_len: usize) -> PhaseReport;

    /// Cost of one batching iteration: a chunk of prefill tokens
    /// (batch-of-1 prefill pass) composed with one decode step over
    /// `decode_batch` requests at KV length `max_kv`. Shared by the
    /// single-replica server and every cluster replica.
    fn iteration_cost(&self, prefill_tokens: usize, decode_batch: usize, max_kv: usize) -> OpCost {
        compose_iteration(
            &|phase, batch, seq| self.phase_report(phase, batch, seq).layer_cost_total(),
            prefill_tokens,
            decode_batch,
            max_kv,
        )
    }
}

/// The one composition rule for a serving iteration — the trait default,
/// the cached override, and the auto-mapping model (`mapper`) all call it
/// (with their own way of producing a phase total), so the paths cannot
/// drift apart.
pub(crate) fn compose_iteration(
    phase_total: &dyn Fn(Phase, usize, usize) -> OpCost,
    prefill_tokens: usize,
    decode_batch: usize,
    max_kv: usize,
) -> OpCost {
    let mut cost = OpCost::zero();
    if prefill_tokens > 0 {
        cost = cost.then(&phase_total(Phase::Prefill, 1, prefill_tokens));
    }
    if decode_batch > 0 {
        cost = cost.then(&phase_total(Phase::Decode, decode_batch, max_kv.max(1)));
    }
    cost
}

impl CostModel for System {
    fn base(&self) -> &RunConfig {
        &self.rc
    }

    fn phase_report(&self, phase: Phase, batch: usize, seq_len: usize) -> PhaseReport {
        self.run_shape(phase, batch, seq_len)
    }
}

/// Full per-op reports are heavyweight (a `Vec<OpReport>` with a `String`
/// per op), so their map stays small; the `Copy` total/iteration maps can
/// afford far more entries before eviction.
const PHASE_CAP: usize = 1024;
const TOTAL_CAP: usize = 1 << 16;
const ITER_CAP: usize = 1 << 16;

/// A hash map bounded at `cap` entries with oldest-half eviction: when a
/// fresh insert would exceed the cap, the oldest half of the entries (by
/// first-insertion order) is dropped in one sweep. Decode shapes drift
/// monotonically (the KV length rises every step), so per-entry LRU would
/// buy little over this — but keeping the *recent* half warm matters: the
/// old drop-all eviction cold-started every map at the cap, re-lowering
/// shapes a sweep was actively retracing. Re-inserting an existing key
/// refreshes the value without touching the insertion order (so a
/// `phase_report` re-seeding an already-held total cannot double-count the
/// key) and every eviction is counted for [`CacheStats`].
struct CappedMap<K, V> {
    cap: usize,
    map: HashMap<K, V>,
    /// First-insertion order of the keys currently held; in sync with
    /// `map` (push on fresh insert, pop-front on eviction only).
    order: VecDeque<K>,
    evictions: u64,
}

impl<K: std::hash::Hash + Eq + Copy, V> CappedMap<K, V> {
    fn new(cap: usize) -> Self {
        assert!(cap >= 2, "a capped map needs room to keep a newest half");
        Self { cap, map: HashMap::new(), order: VecDeque::new(), evictions: 0 }
    }

    fn get(&self, k: &K) -> Option<&V> {
        self.map.get(k)
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn insert(&mut self, k: K, v: V) {
        if self.map.insert(k, v).is_some() {
            return; // value refresh; the key keeps its original position
        }
        self.order.push_back(k);
        if self.map.len() > self.cap {
            let drop = self.order.len() / 2;
            for _ in 0..drop {
                let old = self.order.pop_front().expect("order deque in sync with map");
                self.map.remove(&old).expect("order deque in sync with map");
                self.evictions += 1;
            }
        }
    }
}

/// Memoizing wrapper around any [`CostModel`]. Interior mutability keeps
/// the trait's `&self` signature, so the serving/cluster loops stay
/// borrow-friendly; the simulators are single-threaded, so `RefCell` is
/// sufficient.
pub struct CachedCostModel<M: CostModel> {
    inner: M,
    /// Full reports, for direct [`CostModel::phase_report`] callers.
    phases: RefCell<CappedMap<ShapeKey, PhaseReport>>,
    /// Whole-pass totals only (`Copy`), for the iteration hot path — a
    /// drifting decode shape costs one small entry here, not a report.
    totals: RefCell<CappedMap<ShapeKey, OpCost>>,
    iters: RefCell<CappedMap<IterKey, OpCost>>,
    /// Reports priced under an explicit non-static operator mapping (the
    /// auto-mapper's searched winners); keyed by shape *and* mapping so a
    /// remapped result can never answer a static query or vice versa.
    mapped_phases: RefCell<CappedMap<(ShapeKey, Mapping), PhaseReport>>,
    /// Whole-pass totals under an explicit mapping (`Copy`, like `totals`).
    mapped_totals: RefCell<CappedMap<(ShapeKey, Mapping), OpCost>>,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

impl<M: CostModel> CachedCostModel<M> {
    pub fn new(inner: M) -> Self {
        Self {
            inner,
            phases: RefCell::new(CappedMap::new(PHASE_CAP)),
            totals: RefCell::new(CappedMap::new(TOTAL_CAP)),
            iters: RefCell::new(CappedMap::new(ITER_CAP)),
            mapped_phases: RefCell::new(CappedMap::new(PHASE_CAP)),
            mapped_totals: RefCell::new(CappedMap::new(TOTAL_CAP)),
            hits: Cell::new(0),
            misses: Cell::new(0),
        }
    }

    pub fn inner(&self) -> &M {
        &self.inner
    }

    /// Lookup counters over all cache levels.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.phases.borrow().evictions
                + self.totals.borrow().evictions
                + self.iters.borrow().evictions
                + self.mapped_phases.borrow().evictions
                + self.mapped_totals.borrow().evictions,
        }
    }

    /// Distinct memoized entries (phase reports + totals + iteration
    /// costs, static- and explicit-mapping levels).
    pub fn entries(&self) -> usize {
        self.phases.borrow().len()
            + self.totals.borrow().len()
            + self.iters.borrow().len()
            + self.mapped_phases.borrow().len()
            + self.mapped_totals.borrow().len()
    }

    fn hit(&self) {
        self.hits.set(self.hits.get() + 1);
    }

    fn miss(&self) {
        self.misses.set(self.misses.get() + 1);
    }

    fn shape_key(&self, phase: Phase, batch: usize, seq_len: usize) -> ShapeKey {
        let base = self.inner.base();
        ShapeKey { arch: base.arch, fidelity: base.noc_fidelity, phase, batch, seq_len }
    }

    /// Whole-pass cost of one phase shape, retaining only the `Copy`
    /// total. A full report priced earlier through `phase_report` already
    /// carries the total, so that map is consulted before re-lowering.
    /// Public because the auto-mapping model (`mapper`) composes its
    /// never-lose floor from exactly this static total.
    pub fn phase_total(&self, phase: Phase, batch: usize, seq_len: usize) -> OpCost {
        let key = self.shape_key(phase, batch, seq_len);
        if let Some(c) = self.totals.borrow().get(&key) {
            self.hit();
            return *c;
        }
        let from_report = self.phases.borrow().get(&key).map(|r| r.layer_cost_total());
        let total = match from_report {
            Some(t) => {
                self.hit();
                t
            }
            None => {
                self.miss();
                self.inner.phase_report(phase, batch, seq_len).layer_cost_total()
            }
        };
        self.totals.borrow_mut().insert(key, total);
        total
    }
}

/// Explicit-mapping pricing, memoized. Only `System` can lower an
/// arbitrary [`Mapping`], so these live on the concrete wrapper rather
/// than widening the object-safe [`CostModel`] trait that every harness
/// loop consumes. A query for the variant's *static* mapping is routed to
/// the unmapped path — same cache entries, no duplicate pricing.
impl CachedCostModel<System> {
    /// Full report under an explicit operator mapping.
    pub fn phase_report_mapped(
        &self,
        m: &Mapping,
        phase: Phase,
        batch: usize,
        seq_len: usize,
    ) -> PhaseReport {
        if *m == self.inner.static_mapping() {
            return self.phase_report(phase, batch, seq_len);
        }
        let key = (self.shape_key(phase, batch, seq_len), *m);
        if let Some(r) = self.mapped_phases.borrow().get(&key) {
            self.hit();
            return r.clone();
        }
        self.miss();
        let r = self.inner.run_shape_mapped(phase, batch, seq_len, m);
        self.mapped_phases.borrow_mut().insert(key, r.clone());
        self.mapped_totals.borrow_mut().insert(key, r.layer_cost_total());
        r
    }

    /// Whole-pass total under an explicit mapping (`Copy`-only retention,
    /// mirroring [`CachedCostModel::phase_total`]).
    pub fn phase_total_mapped(
        &self,
        m: &Mapping,
        phase: Phase,
        batch: usize,
        seq_len: usize,
    ) -> OpCost {
        if *m == self.inner.static_mapping() {
            return self.phase_total(phase, batch, seq_len);
        }
        let key = (self.shape_key(phase, batch, seq_len), *m);
        if let Some(c) = self.mapped_totals.borrow().get(&key) {
            self.hit();
            return *c;
        }
        let from_report = self.mapped_phases.borrow().get(&key).map(|r| r.layer_cost_total());
        let total = match from_report {
            Some(t) => {
                self.hit();
                t
            }
            None => {
                self.miss();
                self.inner.run_shape_mapped(phase, batch, seq_len, m).layer_cost_total()
            }
        };
        self.mapped_totals.borrow_mut().insert(key, total);
        total
    }
}

impl<M: CostModel> CostModel for CachedCostModel<M> {
    fn base(&self) -> &RunConfig {
        self.inner.base()
    }

    fn phase_report(&self, phase: Phase, batch: usize, seq_len: usize) -> PhaseReport {
        let key = self.shape_key(phase, batch, seq_len);
        // A hit clones the stored report (per-op vec included) — far
        // cheaper than re-lowering, and the serving/cluster hot loops
        // never pay it: they go through `iteration_cost`, whose memoized
        // `OpCost` is `Copy`.
        if let Some(r) = self.phases.borrow().get(&key) {
            self.hit();
            return r.clone();
        }
        self.miss();
        let r = self.inner.phase_report(phase, batch, seq_len);
        self.phases.borrow_mut().insert(key, r.clone());
        // the total is a free by-product — seed the iteration path's map
        // (a refresh if `phase_total` already holds this shape)
        self.totals.borrow_mut().insert(key, r.layer_cost_total());
        r
    }

    fn iteration_cost(&self, prefill_tokens: usize, decode_batch: usize, max_kv: usize) -> OpCost {
        // Key on the cost function's true arguments: with no decode half
        // the cost is independent of `max_kv` (and a decode half clamps it
        // to ≥ 1), so kv-irrelevant variation — e.g. the growing prefill
        // progress of a chunked long prompt — must not fragment the cache.
        let kv = if decode_batch == 0 { 0 } else { max_kv.max(1) };
        let key = IterKey { prefill_tokens, decode_batch, max_kv: kv };
        if let Some(c) = self.iters.borrow().get(&key) {
            self.hit();
            return *c;
        }
        // Composed-entry miss; the totals cache underneath still serves
        // repeated prefill/decode halves of novel combinations, without
        // retaining a full report per drifting decode shape.
        let cost = compose_iteration(
            &|phase, batch, seq| self.phase_total(phase, batch, seq),
            prefill_tokens,
            decode_batch,
            max_kv,
        );
        self.iters.borrow_mut().insert(key, cost);
        cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchKind, ModelConfig};

    fn rc() -> RunConfig {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        rc
    }

    #[test]
    fn cached_phase_report_is_bit_identical() {
        let sys = System::new(rc());
        let cached = CachedCostModel::new(System::new(rc()));
        for (phase, batch, seq) in
            [(Phase::Decode, 16, 4096), (Phase::Prefill, 1, 512), (Phase::Decode, 16, 4096)]
        {
            let a = sys.phase_report(phase, batch, seq);
            let b = cached.phase_report(phase, batch, seq);
            assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
            assert_eq!(a.throughput_tok_s.to_bits(), b.throughput_tok_s.to_bits());
            assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
            assert_eq!(a.layer_cost, b.layer_cost);
            assert_eq!(a.ops.len(), b.ops.len());
        }
    }

    #[test]
    fn repeated_shapes_hit_the_cache() {
        let cached = CachedCostModel::new(System::new(rc()));
        let a = cached.iteration_cost(0, 16, 4096);
        assert_eq!(cached.stats().hits, 0);
        let misses_after_first = cached.stats().misses;
        assert!(misses_after_first >= 1);
        let b = cached.iteration_cost(0, 16, 4096);
        assert_eq!(a, b);
        assert_eq!(cached.stats().hits, 1, "second identical iteration must be a hit");
        assert_eq!(cached.stats().misses, misses_after_first);
        // a different shape misses again
        let _ = cached.iteration_cost(0, 16, 4097);
        assert!(cached.stats().misses > misses_after_first);
        assert!(cached.stats().hit_rate() > 0.0);
        assert!(cached.entries() >= 2);
    }

    #[test]
    fn iteration_cost_matches_manual_composition() {
        let sys = System::new(rc());
        let cached = CachedCostModel::new(System::new(rc()));
        for (pf, db, kv) in [(256usize, 8usize, 2048usize), (0, 4, 512), (128, 0, 1), (0, 0, 0)] {
            let mut want = OpCost::zero();
            if pf > 0 {
                want = want.then(&sys.phase_report(Phase::Prefill, 1, pf).layer_cost_total());
            }
            if db > 0 {
                let d = sys.phase_report(Phase::Decode, db, kv.max(1));
                want = want.then(&d.layer_cost_total());
            }
            assert_eq!(sys.iteration_cost(pf, db, kv), want);
            assert_eq!(cached.iteration_cost(pf, db, kv), want);
        }
    }

    #[test]
    fn phase_report_seeds_the_iteration_path() {
        let cached = CachedCostModel::new(System::new(rc()));
        let r = cached.phase_report(Phase::Decode, 16, 4096); // miss, seeds totals
        let misses = cached.stats().misses;
        let c = cached.iteration_cost(0, 16, 4096); // totals hit — no re-lowering
        assert_eq!(cached.stats().misses, misses, "already-priced shape must not re-lower");
        assert!(cached.stats().hits >= 1);
        assert_eq!(c, r.layer_cost_total());
    }

    #[test]
    fn prefill_only_iterations_share_one_key_regardless_of_kv() {
        // a chunked long prompt advances `max_kv` every pure-prefill
        // iteration, but the cost is kv-independent when nothing decodes —
        // the normalized key must turn those into hits
        let cached = CachedCostModel::new(System::new(rc()));
        let a = cached.iteration_cost(4096, 0, 5);
        let hits_before = cached.stats().hits;
        let b = cached.iteration_cost(4096, 0, 9999);
        assert_eq!(a, b);
        assert_eq!(cached.stats().hits, hits_before + 1, "kv-irrelevant variation must hit");
        // kv=0 and kv=1 with a decode half are the same clamped shape
        let c = cached.iteration_cost(0, 4, 0);
        let d = cached.iteration_cost(0, 4, 1);
        assert_eq!(c, d);
    }

    #[test]
    fn shape_keys_are_fidelity_aware() {
        use crate::config::NocFidelity;
        // the same shape priced under two fidelity tiers must occupy two
        // distinct cache entries — a shared key would let an analytic
        // result answer a calibrated query
        let mut calibrated = rc();
        calibrated.noc_fidelity = NocFidelity::Calibrated;
        let a = CachedCostModel::new(System::new(rc()));
        let c = CachedCostModel::new(System::new(calibrated));
        assert_ne!(
            a.shape_key(Phase::Decode, 16, 4096),
            c.shape_key(Phase::Decode, 16, 4096)
        );
        assert_eq!(
            a.shape_key(Phase::Decode, 16, 4096),
            CachedCostModel::new(System::new(rc())).shape_key(Phase::Decode, 16, 4096)
        );
    }

    #[test]
    fn cached_is_bit_identical_per_fidelity_tier() {
        use crate::config::NocFidelity;
        for f in NocFidelity::all() {
            let mut cfg = rc();
            cfg.noc_fidelity = f;
            let sys = System::new(cfg.clone());
            let cached = CachedCostModel::new(System::new(cfg));
            for _ in 0..2 {
                // second pass hits the cache; both must equal the uncached run
                let a = sys.phase_report(Phase::Decode, 8, 2048);
                let b = cached.phase_report(Phase::Decode, 8, 2048);
                assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits(), "{f:?}");
                assert_eq!(a.layer_cost, b.layer_cost, "{f:?}");
                assert_eq!(
                    sys.iteration_cost(128, 4, 1024),
                    cached.iteration_cost(128, 4, 1024),
                    "{f:?}"
                );
            }
        }
    }

    #[test]
    fn capped_map_bounds_the_map_and_keeps_the_newest_half() {
        let mut map: CappedMap<usize, usize> = CappedMap::new(4);
        for i in 0..10 {
            map.insert(i, i * 10);
            assert!(map.len() <= 4, "cap breached after inserting {i}");
        }
        // the most recent insert always survives eviction...
        assert_eq!(map.get(&9), Some(&90));
        // ...and so does the newest *half*, not just the newest entry:
        // inserts 0..10 over cap 4 evict two entries at each of i = 4, 6
        // and 8, so the survivors are exactly {6, 7, 8, 9}
        for k in 6..10 {
            assert_eq!(map.get(&k), Some(&(k * 10)));
        }
        assert_eq!(map.get(&0), None);
        assert_eq!(map.get(&5), None);
        assert_eq!(map.evictions, 6, "every dropped entry is counted");
    }

    #[test]
    fn capped_map_refresh_keeps_insertion_order_honest() {
        let mut map: CappedMap<usize, usize> = CappedMap::new(4);
        for i in 0..4 {
            map.insert(i, i);
        }
        // refreshing an existing key must not re-enter the order deque —
        // a duplicate would later desync eviction from the map
        map.insert(0, 100);
        assert_eq!(map.get(&0), Some(&100));
        assert_eq!(map.len(), 4);
        assert_eq!(map.evictions, 0);
        map.insert(4, 4); // fresh insert over cap: evict the oldest half
        assert_eq!(map.len(), 3);
        assert_eq!(map.get(&0), None, "refreshed key keeps its original (oldest) position");
        assert_eq!(map.get(&1), None);
        assert_eq!(map.get(&4), Some(&4));
        assert_eq!(map.evictions, 2);
    }

    #[test]
    fn eviction_surfaces_in_stats() {
        let mut map: CappedMap<usize, usize> = CappedMap::new(2);
        for i in 0..3 {
            map.insert(i, i);
        }
        assert!(map.evictions > 0);
        // and the struct-level counter reaches CacheStats/JSON
        let st = CacheStats { hits: 3, misses: 1, evictions: map.evictions };
        let j = st.to_json().render();
        assert!(j.contains("\"evictions\":1"), "{j}");
        assert!(j.contains("\"hit_rate\":0.75"), "{j}");
    }

    #[test]
    fn mapped_pricing_is_cached_and_bit_identical() {
        use crate::mapper::{Mapping, Placement, Slot};
        let sys = System::new(rc());
        let cached = CachedCostModel::new(System::new(rc()));
        let m = Mapping::static_for(ArchKind::CompAirOpt).with(Slot::FcDown, Placement::DramPim);
        let want = sys.run_shape_mapped(Phase::Decode, 16, 4096, &m);
        let a = cached.phase_report_mapped(&m, Phase::Decode, 16, 4096); // miss
        let misses = cached.stats().misses;
        let b = cached.phase_report_mapped(&m, Phase::Decode, 16, 4096); // hit
        assert_eq!(cached.stats().misses, misses);
        assert!(cached.stats().hits >= 1);
        for r in [&a, &b] {
            assert_eq!(r.latency_ns.to_bits(), want.latency_ns.to_bits());
            assert_eq!(r.layer_cost, want.layer_cost);
        }
        // the report seeded the mapped-total map: no re-lowering
        let t = cached.phase_total_mapped(&m, Phase::Decode, 16, 4096);
        assert_eq!(cached.stats().misses, misses);
        assert_eq!(t, want.layer_cost_total());
    }

    #[test]
    fn static_mapping_query_shares_the_unmapped_cache() {
        let cached = CachedCostModel::new(System::new(rc()));
        let m = crate::mapper::Mapping::static_for(ArchKind::CompAirOpt);
        let a = cached.phase_report(Phase::Decode, 8, 2048); // seeds phases/totals
        let entries = cached.entries();
        let b = cached.phase_report_mapped(&m, Phase::Decode, 8, 2048);
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        assert_eq!(cached.entries(), entries, "static mapping must not duplicate entries");
        let t = cached.phase_total_mapped(&m, Phase::Decode, 8, 2048);
        assert_eq!(t, a.layer_cost_total());
        assert_eq!(cached.entries(), entries);
    }

    #[test]
    fn two_mappings_of_one_shape_occupy_distinct_entries() {
        use crate::mapper::{Mapping, Placement, Slot};
        let cached = CachedCostModel::new(System::new(rc()));
        let s = Mapping::static_for(ArchKind::CompAirOpt);
        let m1 = s.with(Slot::FcDown, Placement::DramPim);
        let m2 = s.with(Slot::Softmax, Placement::Host);
        let a = cached.phase_total_mapped(&m1, Phase::Decode, 16, 4096);
        let b = cached.phase_total_mapped(&m2, Phase::Decode, 16, 4096);
        assert_ne!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        // both keys live side by side; re-queries hit
        let hits = cached.stats().hits;
        assert_eq!(cached.phase_total_mapped(&m1, Phase::Decode, 16, 4096), a);
        assert_eq!(cached.phase_total_mapped(&m2, Phase::Decode, 16, 4096), b);
        assert_eq!(cached.stats().hits, hits + 2);
    }

    #[test]
    fn capped_map_evicts_strictly_oldest_first() {
        // step through each overflow and pin the exact survivor set — the
        // coarser bounds test above can pass with a subtly wrong eviction
        // order, this one cannot
        let mut map: CappedMap<usize, usize> = CappedMap::new(6);
        for i in 0..7 {
            map.insert(i, i);
        }
        // overflow at i=6 dropped the oldest half: 0, 1, 2
        for gone in 0..3 {
            assert_eq!(map.get(&gone), None, "{gone} should be evicted");
        }
        for kept in 3..7 {
            assert_eq!(map.get(&kept), Some(&kept), "{kept} should survive");
        }
        assert_eq!(map.evictions, 3);
        // survivors keep their original relative order for the next sweep
        map.insert(7, 7);
        map.insert(8, 8); // len 6 -> no eviction yet
        map.insert(9, 9); // overflow: drops 3, 4, 5
        assert_eq!(map.get(&3), None);
        assert_eq!(map.get(&5), None);
        assert_eq!(map.get(&6), Some(&6));
        assert_eq!(map.get(&9), Some(&9));
        assert_eq!(map.evictions, 6);
    }

    #[test]
    fn system_run_is_phase_report_at_configured_shape() {
        let sys = System::new(rc());
        let a = sys.run();
        let b = sys.phase_report(sys.rc.phase, sys.rc.batch, sys.rc.seq_len);
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        assert_eq!(a.layer_cost, b.layer_cost);
    }
}
