//! AttAcc baseline [Park+ ASPLOS'24]: a hybrid of A100 GPUs (FC layers +
//! prefill) and HBM-PIM devices (decode attention). Modelled as a roofline —
//! the paper's AttAcc comparisons are throughput/energy ratios, which a
//! calibrated roofline preserves.

use crate::config::{ModelConfig, Phase, RunConfig};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::sim::{CostCounts, OpCost};
use crate::workload::{layer_ops, LlmOp, OpClass};

use super::system::PhaseReport;

/// AttAcc hardware point: 4× A100-80GB + 4× HBM3-PIM (Fig 15's
/// "AttAcc-4-A100-HBM").
#[derive(Debug, Clone)]
pub struct AttAccConfig {
    pub gpus: usize,
    pub hbm_pim_devices: usize,
    /// A100 dense BF16 throughput per GPU (FLOP/s).
    pub gpu_flops: f64,
    /// A100 HBM bandwidth per GPU (B/s → B/ns = GB/s·1e-?) in GB/s.
    pub gpu_hbm_gbs: f64,
    /// HBM-PIM internal bandwidth per device (GB/s) — bank-level parallel.
    pub pim_internal_gbs: f64,
    /// HBM-PIM MAC throughput per device (MAC/s).
    pub pim_macs_per_s: f64,
}

impl Default for AttAccConfig {
    fn default() -> Self {
        Self {
            gpus: 4,
            hbm_pim_devices: 4,
            gpu_flops: 312e12,
            gpu_hbm_gbs: 2039.0,
            pim_internal_gbs: 12_288.0, // 16 pCH × 768 GB/s class
            pim_macs_per_s: 6.144e12,
        }
    }
}

/// Simulate AttAcc on the same workload shapes.
pub fn simulate(rc: &RunConfig, cfg: &AttAccConfig) -> PhaseReport {
    let ops = layer_ops(&rc.model, rc.phase, rc.batch, rc.seq_len);
    let mut layer = OpCost::zero();
    let mut reports = Vec::new();
    let mut nl_ns = 0.0;
    for op in &ops {
        let c = op_cost(op, rc, cfg);
        if op.class() == OpClass::NonLinear {
            nl_ns += c.latency_ns;
        }
        reports.push(super::system::OpReport { name: op.name(), class: op.class(), cost: c });
        layer = layer.then(&c);
    }
    let total = layer.repeat(rc.model.n_layers as u64);
    let tokens = match rc.phase {
        Phase::Decode => rc.batch as f64,
        Phase::Prefill => (rc.batch * rc.seq_len) as f64,
    };
    let throughput = tokens / (total.latency_ns / 1e9);

    let em = EnergyModel::new(&rc.hw.sram, rc.hw.hb.pj_per_bit);
    let dyn_e = em.dynamic(&total.counts);
    let mut energy: EnergyBreakdown = dyn_e.scale(1.0 / tokens);
    // static: GPU boards + HBM-PIM devices for the token's duration
    energy.static_pj = (cfg.gpus as f64 * em.gpu_static_w
        + cfg.hbm_pim_devices as f64 * em.pim_device_static_w)
        * total.latency_ns
        / tokens;

    PhaseReport {
        latency_ns: total.latency_ns,
        throughput_tok_s: throughput,
        energy,
        ops: reports,
        nonlinear_frac: nl_ns / layer.latency_ns.max(1e-9),
        collective_frac: 0.0,
        bank_util: 1.0,
        layer_cost: layer,
    }
}

fn op_cost(op: &LlmOp, rc: &RunConfig, cfg: &AttAccConfig) -> OpCost {
    let gpu_flops_ns = cfg.gpus as f64 * cfg.gpu_flops / 1e9; // FLOP per ns
    let gpu_bw_ns = cfg.gpus as f64 * cfg.gpu_hbm_gbs; // B per ns... GB/s = B/ns
    match op {
        LlmOp::Fc { d_in, d_out, tokens, .. } => {
            let flops = 2.0 * (*d_in as f64) * (*d_out as f64) * (*tokens as f64);
            let bytes = (*d_in as f64) * (*d_out as f64) * 2.0; // weights dominate
            let t = (flops / gpu_flops_ns).max(bytes / gpu_bw_ns);
            OpCost {
                latency_ns: t,
                counts: CostCounts {
                    gpu_flop: flops as u64,
                    gpu_hbm_bytes: bytes as u64,
                    ..Default::default()
                },
            }
        }
        LlmOp::AttnQK { batch, heads, rows_q, seq, d_head }
        | LlmOp::AttnSV { batch, heads, rows_q, seq, d_head } => {
            let macs = (*batch * *heads * *rows_q * *seq * *d_head) as f64;
            let bytes = (*batch * *heads * *seq * *d_head * 2) as f64; // KV stream
            if rc.phase == Phase::Decode {
                // attention offloaded to HBM-PIM: internal-bandwidth bound
                let pim_bw = cfg.hbm_pim_devices as f64 * cfg.pim_internal_gbs;
                let pim_mac = cfg.hbm_pim_devices as f64 * cfg.pim_macs_per_s / 1e9;
                let t = (bytes / pim_bw).max(macs / pim_mac);
                OpCost {
                    latency_ns: t,
                    counts: CostCounts {
                        dram_mac: macs as u64,
                        dram_col_rd: (bytes / 32.0) as u64,
                        ..Default::default()
                    },
                }
            } else {
                let t = (2.0 * macs / gpu_flops_ns).max(bytes / gpu_bw_ns);
                OpCost {
                    latency_ns: t,
                    counts: CostCounts {
                        gpu_flop: (2.0 * macs) as u64,
                        gpu_hbm_bytes: bytes as u64,
                        ..Default::default()
                    },
                }
            }
        }
        LlmOp::Softmax { rows, seq } => gpu_elementwise((rows * seq) as f64, 5.0, gpu_bw_ns),
        LlmOp::Rope { tokens, heads, d_head } => {
            gpu_elementwise((tokens * heads * d_head) as f64, 3.0, gpu_bw_ns)
        }
        LlmOp::RmsNorm { tokens, d_model } => {
            gpu_elementwise((tokens * d_model) as f64, 3.0, gpu_bw_ns)
        }
        LlmOp::Activation { tokens, width, .. } => {
            gpu_elementwise((tokens * width) as f64, 4.0, gpu_bw_ns)
        }
        LlmOp::AllReduce { tokens, d_model } => {
            // NVLink-class all-reduce between the 4 GPUs: 300 GB/s eff.
            let bytes = (*tokens * *d_model * 2) as f64;
            OpCost {
                latency_ns: 2.0 * bytes / 300.0,
                counts: CostCounts { cxl_bytes: (2.0 * bytes) as u64, ..Default::default() },
            }
        }
    }
}

fn gpu_elementwise(elems: f64, flops_per: f64, gpu_bw_ns: f64) -> OpCost {
    // element-wise kernels are HBM-bound on GPUs: read+write 2 B each
    let bytes = elems * 4.0;
    OpCost {
        latency_ns: bytes / gpu_bw_ns,
        counts: CostCounts {
            gpu_flop: (elems * flops_per) as u64,
            gpu_hbm_bytes: bytes as u64,
            ..Default::default()
        },
    }
}

/// Fig 4A: pure SRAM-PIM infeasibility — macros and power needed to hold
/// ALL FC layers of a model without reloading.
pub fn pure_sram_requirements(m: &ModelConfig, sram: &crate::config::SramConfig) -> (u64, f64) {
    let weights = m.total_fc_params();
    let per_macro = (sram.macro_inputs * sram.macro_outputs) as u64;
    let macros = weights.div_ceil(per_macro);
    let power_w = macros as f64 * {
        let mac = crate::sram::SramMacro::new(sram);
        mac.active_power_w() * 0.1 // 10% duty — even derated it explodes
    };
    (macros, power_w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchKind, SramConfig};

    #[test]
    fn pure_sram_is_infeasible_for_gpt3() {
        // Fig 4A: power orders of magnitude above an A100's 300 W
        let (macros, power) = pure_sram_requirements(&ModelConfig::gpt3_175b(), &SramConfig::default());
        assert!(macros > 100_000_000, "macros={macros}");
        assert!(power > 3000.0, "power={power} W should far exceed a GPU");
    }

    #[test]
    fn attacc_decode_attention_is_pim_bound() {
        let mut rc = RunConfig::new(ArchKind::AttAcc, ModelConfig::gpt3_175b());
        rc.batch = 64;
        rc.seq_len = 8192;
        let r = simulate(&rc, &AttAccConfig::default());
        assert!(r.latency_ns > 0.0);
        assert!(r.throughput_tok_s > 0.0);
    }

    #[test]
    fn attacc_prefill_uses_gpu_flops() {
        let mut rc = RunConfig::new(ArchKind::AttAcc, ModelConfig::llama2_7b());
        rc.phase = Phase::Prefill;
        rc.batch = 1;
        rc.seq_len = 2048;
        let r = simulate(&rc, &AttAccConfig::default());
        let total_flop: u64 = r.layer_cost.counts.gpu_flop;
        assert!(total_flop > 0);
    }
}
