//! Analytic costs for collectives and distributed non-linear execution.
//!
//! The mesh simulator is exact but cycle-stepped; system-level figure sweeps
//! (GPT3-175B at 128K context) need closed forms. Each formula here is
//! calibrated against the flit-level simulator in this module's tests — the
//! §Perf memoization lever is "analytic where validated, simulate where
//! novel".
//!
//! These closed forms are the `Analytic` tier of [`crate::noc::model`];
//! the `Calibrated` tier multiplies their latencies by per-collective
//! correction factors fitted against the flit-level simulator, and the
//! `Simulated` tier bypasses them entirely. The NoC formulas are therefore
//! kept strictly chunk/wave-linear (cost = granules × per-granule cost,
//! no fill/drain intercepts), so one multiplicative factor corrects them
//! exactly at every anchor volume.

use crate::config::{CxlConfig, DramConfig, HwConfig, NocConfig};
use crate::sim::{CostCounts, OpCost};

/// Element-wise reduction of `elems` scalars across `banks` banks through
/// the column trees (4 parallel trees, stage-synchronized).
pub fn noc_reduce(elems: u64, banks: u64, cfg: &NocConfig) -> OpCost {
    if elems == 0 || banks <= 1 {
        // a single bank already holds its value; banks=0 must not drive the
        // `banks - 1` edge count below zero
        return OpCost::zero();
    }
    let cols = cfg.mesh_cols as u64;
    let chunks = elems.div_ceil(cols);
    // Per chunk, one ladder of log2⌈banks⌉ stages: hop distance 2^s plus
    // ~3 cycles of inject / execute / stage-sync drain per stage (the tree
    // schedule runs the mesh to idle between dependency-ordered stages, so
    // the log-depth synchronization is priced here, per stage).
    let mut per_chunk = 0u64;
    let mut stride = 1u64;
    while stride < banks {
        per_chunk += stride + 3;
        stride <<= 1;
    }
    OpCost {
        latency_ns: (chunks * per_chunk) as f64 * cfg.cycle_ns,
        counts: CostCounts {
            noc_flit_hops: elems * (banks - 1), // tree edges ≈ banks-1 per element, ~1 hop avg amortized
            noc_alu_ops: elems * (banks - 1),
            ..Default::default()
        },
    }
}

/// Element-wise broadcast of `elems` scalars from one bank to `banks`.
pub fn noc_broadcast(elems: u64, banks: u64, cfg: &NocConfig) -> OpCost {
    if elems == 0 || banks <= 1 {
        // no other bank to reach; same `banks - 1` underflow guard as reduce
        return OpCost::zero();
    }
    let cols = cfg.mesh_cols as u64;
    let chunks = elems.div_ceil(cols);
    let mut per_chunk = 0u64;
    let mut stride = 1u64;
    while stride < banks {
        per_chunk += stride + 2;
        stride <<= 1;
    }
    OpCost {
        latency_ns: (chunks * per_chunk) as f64 * cfg.cycle_ns,
        counts: CostCounts {
            noc_flit_hops: elems * (banks - 1),
            ..Default::default()
        },
    }
}

/// `elems` exponentials computed bank-locally in the NoC (Fig 13): each bank
/// runs 2 parallel Horner lanes; one exponential occupies its lane for
/// `3·rounds + overhead` cycles (3 ops/iteration + per-element WrReg).
pub fn noc_exp(elems_per_bank: u64, rounds: u64, cfg: &NocConfig) -> OpCost {
    if elems_per_bank == 0 || rounds == 0 {
        // a zero-round Horner chain computes nothing (same guard as sqrt,
        // keeping all fidelity tiers structurally identical at rounds=0)
        return OpCost::zero();
    }
    let lanes = 2u64;
    let per_elem_cycles = 3 * rounds + 4 + (rounds * cfg.div_cycles);
    let cycles = elems_per_bank.div_ceil(lanes) * per_elem_cycles;
    OpCost {
        latency_ns: cycles as f64 * cfg.cycle_ns,
        counts: CostCounts {
            noc_alu_ops: elems_per_bank * (3 * rounds + rounds),
            noc_flit_hops: elems_per_bank * (2 * rounds + 2),
            ..Default::default()
        },
    }
}

/// `elems` square roots via Newton (Heron) iteration in the NoC (RMSNorm's
/// rsqrt): per round `y ← (y + x/y) / 2` — one divide occupying the
/// iterative divider for `div_cycles`, one add, one halve. Same 2-lane
/// structure as exp, but its own op mix: 3 ALU ops per round (exp's Horner
/// also updates the iterated `k` ArgReg, a 4th op), a seed write and a
/// result eject instead of exp's per-element WrReg+const setup.
pub fn noc_sqrt(elems_per_bank: u64, rounds: u64, cfg: &NocConfig) -> OpCost {
    if elems_per_bank == 0 || rounds == 0 {
        return OpCost::zero();
    }
    let lanes = 2u64;
    let per_elem_cycles = 3 * rounds + 3 + rounds * cfg.div_cycles;
    let cycles = elems_per_bank.div_ceil(lanes) * per_elem_cycles;
    OpCost {
        latency_ns: cycles as f64 * cfg.cycle_ns,
        counts: CostCounts {
            noc_alu_ops: elems_per_bank * 3 * rounds,
            noc_flit_hops: elems_per_bank * (2 * rounds + 3),
            ..Default::default()
        },
    }
}

/// Element-wise scalar op (e.g. the softmax divide) streamed through the
/// bank's 4 routers: ~1 elem/2 cycles/router once pipelined. Kept purely
/// chunk-linear (no fill/drain constant — it is below the model's noise
/// floor) so the calibrated tier's multiplicative correction is exact.
pub fn noc_scalar_stream(elems_per_bank: u64, cfg: &NocConfig) -> OpCost {
    if elems_per_bank == 0 {
        return OpCost::zero();
    }
    let cycles = elems_per_bank.div_ceil(cfg.mesh_cols as u64) * 2;
    OpCost {
        latency_ns: cycles as f64 * cfg.cycle_ns,
        counts: CostCounts {
            noc_alu_ops: elems_per_bank,
            noc_flit_hops: 2 * elems_per_bank,
            ..Default::default()
        },
    }
}

/// Centralized-NLU round trip (the CENT baseline's non-linear path):
/// move `bytes` from the banks to the device controller over the channel
/// I/O, run `ops` scalar operations on the NLU (vector unit, `nlu_lanes`
/// at 1 GHz), and move `bytes_back` back. `channels_parallel` channels
/// stream concurrently.
pub fn nlu_roundtrip(
    bytes: u64,
    bytes_back: u64,
    ops: u64,
    channels_parallel: u64,
    dram: &DramConfig,
) -> OpCost {
    let nlu_lanes = 32.0; // controller vector NLU width
    let io_ns =
        (bytes + bytes_back) as f64 / (dram.external_gbs_per_channel * channels_parallel as f64);
    let compute_ns = ops as f64 / nlu_lanes;
    OpCost {
        latency_ns: io_ns + compute_ns,
        counts: CostCounts {
            gb_bytes: bytes + bytes_back,
            nlu_ops: ops,
            ..Default::default()
        },
    }
}

/// Tensor-parallel all-reduce of `bytes` (per device) across `tp` devices
/// over the CXL fabric (reduce + broadcast trees through the switch).
pub fn cxl_allreduce(bytes: u64, tp: u64, cxl: &CxlConfig) -> OpCost {
    if tp <= 1 || bytes == 0 {
        return OpCost::zero();
    }
    let steps = 2.0 * (tp as f64).log2().ceil();
    let wire_ns = 2.0 * bytes as f64 / cxl.collective_gbs;
    OpCost {
        latency_ns: wire_ns + steps * cxl.hop_latency_ns,
        counts: CostCounts {
            cxl_bytes: 2 * bytes * (tp - 1) / tp,
            ..Default::default()
        },
    }
}

/// Inter-device point-to-point transfer (pipeline-parallel stage handoff).
pub fn cxl_p2p(bytes: u64, cxl: &CxlConfig) -> OpCost {
    OpCost {
        latency_ns: bytes as f64 / cxl.p2p_gbs + cxl.hop_latency_ns,
        counts: CostCounts { cxl_bytes: bytes, ..Default::default() },
    }
}

/// DRAM EWMUL streamed through the bank MAC lanes (RoPE's cos/sin multiply,
/// SiLU's gating multiply): bank-local, `elems` per bank.
pub fn dram_ewmul(elems_per_bank: u64, hw: &HwConfig) -> OpCost {
    crate::dram::PimBank::new(&hw.dram).ewmul(elems_per_bank as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{trees, CalibratedNoc, Mesh, NocModel, SimulatedNoc, StepOp};

    #[test]
    fn analytic_reduce_calibrated_against_mesh() {
        let cfg = NocConfig::default();
        for elems in [4u64, 16, 64] {
            let analytic = noc_reduce(elems, 16, &cfg).latency_ns;
            let mut mesh = Mesh::new(&cfg);
            let mut total = 0.0;
            for chunk in 0..elems.div_ceil(4) {
                let vals: Vec<Vec<f32>> =
                    (0..4).map(|c| (0..16).map(|b| (chunk + c + b as u64) as f32).collect()).collect();
                total += trees::reduce(&mut mesh, &vals, StepOp::Add, 0, 16).cost.latency_ns;
            }
            let ratio = total / analytic;
            assert!(
                (0.5..2.0).contains(&ratio),
                "elems={elems}: sim={total} analytic={analytic} ratio={ratio}"
            );
        }
    }

    #[test]
    fn analytic_broadcast_calibrated_against_mesh() {
        let cfg = NocConfig::default();
        let analytic = noc_broadcast(16, 16, &cfg).latency_ns;
        let mut mesh = Mesh::new(&cfg);
        let mut total = 0.0;
        for _ in 0..4 {
            total += trees::broadcast(&mut mesh, &[1.0, 2.0, 3.0, 4.0], 0, 16).cost.latency_ns;
        }
        let ratio = total / analytic;
        assert!((0.5..2.0).contains(&ratio), "sim={total} analytic={analytic}");
    }

    #[test]
    fn reduce_guards_degenerate_bank_counts() {
        let cfg = NocConfig::default();
        // regression: banks=0 used to underflow `banks - 1`; banks=1 has
        // nothing to reduce across; and the dead `log2 * 0.0` latency term
        // would have panicked on banks=0's leading_zeros arithmetic
        assert_eq!(noc_reduce(64, 0, &cfg), OpCost::zero());
        assert_eq!(noc_reduce(64, 1, &cfg), OpCost::zero());
        assert_eq!(noc_reduce(0, 16, &cfg), OpCost::zero());
        assert_eq!(noc_broadcast(64, 0, &cfg), OpCost::zero());
        assert_eq!(noc_broadcast(64, 1, &cfg), OpCost::zero());
    }

    #[test]
    fn reduce_non_power_of_two_banks() {
        let cfg = NocConfig::default();
        let c12 = noc_reduce(16, 12, &cfg);
        assert!(c12.latency_ns > 0.0 && c12.latency_ns.is_finite());
        // tree edges: one per non-root bank
        assert_eq!(c12.counts.noc_flit_hops, 16 * 11);
        assert_eq!(c12.counts.noc_alu_ops, 16 * 11);
        // the stage ladder climbs to the power-of-two ceiling (strides
        // 1,2,4,8 for both 12 and 16 banks), so latency matches banks=16
        // while the event counts stay proportional to the real bank count
        let c16 = noc_reduce(16, 16, &cfg);
        assert_eq!(c12.latency_ns, c16.latency_ns);
        assert!(c12.counts.noc_flit_hops < c16.counts.noc_flit_hops);
        // monotone in banks across the non-pow2 range
        let c5 = noc_reduce(16, 5, &cfg);
        let c9 = noc_reduce(16, 9, &cfg);
        assert!(c5.latency_ns <= c9.latency_ns);
        assert!(c5.counts.noc_flit_hops < c9.counts.noc_flit_hops);
    }

    #[test]
    fn sqrt_models_its_own_op_mix_not_exps() {
        // regression: noc_sqrt was a verbatim alias of noc_exp, inheriting
        // Horner's flit-hop/ALU counts; Newton-rsqrt must price its own mix
        let cfg = NocConfig::default();
        let e = noc_exp(64, 4, &cfg);
        let s = noc_sqrt(64, 4, &cfg);
        assert_ne!(s.counts, e.counts, "sqrt must not alias exp's energy counts");
        // Heron has 3 ALU ops/round; Horner adds the iterated-k update (4)
        assert!(s.counts.noc_alu_ops < e.counts.noc_alu_ops);
        assert_ne!(s.counts.noc_flit_hops, e.counts.noc_flit_hops);
        assert!(s.latency_ns > 0.0);
        // both still pay the iterative divider every round
        let mut fast = cfg.clone();
        fast.div_cycles = 0;
        assert!(noc_sqrt(64, 4, &cfg).latency_ns > noc_sqrt(64, 4, &fast).latency_ns);
        assert_eq!(noc_sqrt(0, 4, &cfg), OpCost::zero());
        assert_eq!(noc_sqrt(64, 0, &cfg), OpCost::zero());
    }

    #[test]
    fn calibrated_reduce_within_1p2x_of_mesh() {
        // the 0.5–2.0x raw band above, tightened through the Calibrated
        // tier: correction factors fitted against the same simulator bring
        // every anchor-shaped reduce within 1.2x
        let hw = HwConfig::paper();
        let cal = CalibratedNoc::new(&hw);
        let sim = SimulatedNoc::new(&hw);
        for elems in [4u64, 16, 64] {
            for banks in [4u64, 16] {
                let c = cal.reduce(elems, banks).latency_ns;
                let s = sim.reduce(elems, banks).latency_ns;
                let ratio = s / c;
                assert!(
                    (1.0 / 1.2..1.2).contains(&ratio),
                    "elems={elems} banks={banks}: sim={s} calibrated={c} ratio={ratio}"
                );
            }
        }
    }

    #[test]
    fn calibrated_broadcast_and_exp_within_1p2x_of_mesh() {
        let hw = HwConfig::paper();
        let cal = CalibratedNoc::new(&hw);
        let sim = SimulatedNoc::new(&hw);
        for elems in [4u64, 32] {
            let ratio = sim.broadcast(elems, 16).latency_ns / cal.broadcast(elems, 16).latency_ns;
            assert!((1.0 / 1.2..1.2).contains(&ratio), "broadcast elems={elems}: {ratio}");
        }
        for (elems, rounds) in [(2u64, 8u64), (16, 8), (16, 4)] {
            let ratio = sim.exp(elems, rounds).latency_ns / cal.exp(elems, rounds).latency_ns;
            assert!((1.0 / 1.2..1.2).contains(&ratio), "exp {elems}x{rounds}: {ratio}");
            let ratio = sim.sqrt(elems, rounds).latency_ns / cal.sqrt(elems, rounds).latency_ns;
            assert!((1.0 / 1.2..1.2).contains(&ratio), "sqrt {elems}x{rounds}: {ratio}");
        }
    }

    #[test]
    fn analytic_exp_close_to_isa_machine() {
        // The machine executes waves of 2 lanes/bank; the closed form should
        // land within 2x.
        use crate::config::{HwConfig, SramGang};
        use crate::isa::{Machine, RowProgram};
        let hw = HwConfig::paper();
        let mut m = Machine::new(&hw, SramGang::In256Out16);
        let xs: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        m.write_row(0, 0, &xs);
        let p = RowProgram::exp_program(0, 500, xs.len(), 6, 1);
        let sim = m.run(&p, true).latency_ns;
        let analytic = noc_exp(xs.len() as u64, 6, &hw.noc).latency_ns;
        let ratio = sim / analytic;
        assert!((0.3..4.0).contains(&ratio), "sim={sim} analytic={analytic}");
    }

    #[test]
    fn nlu_roundtrip_dominated_by_io_for_long_rows() {
        let dram = DramConfig::default();
        let c = nlu_roundtrip(128 * 1024, 128 * 1024, 5 * 64 * 1024, 1, &dram);
        let io_only = nlu_roundtrip(128 * 1024, 128 * 1024, 0, 1, &dram);
        // the I/O round trip must be a first-order component (Fig 5D's
        // "extra data movement" claim), not an epsilon on top of compute
        assert!(io_only.latency_ns > 0.3 * c.latency_ns, "I/O must be first-order");
    }

    #[test]
    fn cxl_allreduce_scales_with_bytes_not_tp() {
        let cxl = CxlConfig::default();
        let a = cxl_allreduce(1 << 20, 8, &cxl);
        let b = cxl_allreduce(1 << 21, 8, &cxl);
        assert!(b.latency_ns > 1.8 * a.latency_ns);
        assert_eq!(cxl_allreduce(0, 8, &cxl), OpCost::zero());
        assert_eq!(cxl_allreduce(1 << 20, 1, &cxl), OpCost::zero());
    }

    #[test]
    fn noc_exp_throughput_beats_nlu_at_scale() {
        // Distributed exps across 512 banks × 2 lanes vs a 32-lane NLU with
        // an I/O round trip: the distributed path must win on long rows.
        let hw = HwConfig::paper();
        let elems_total: u64 = 512 * 1024;
        let banks: u64 = 512;
        let per_bank = elems_total / banks;
        let noc = noc_exp(per_bank, 6, &hw.noc);
        let nlu = nlu_roundtrip(elems_total * 2, elems_total * 2, elems_total * 5, 32, &hw.dram);
        assert!(
            noc.latency_ns < nlu.latency_ns,
            "noc={} nlu={}",
            noc.latency_ns,
            nlu.latency_ns
        );
    }
}
