//! Deterministic xorshift64* PRNG.
//!
//! No external `rand` crate is vendored in this environment, so the library
//! carries its own small, seedable generator. It is used by the workload
//! generators, the property-test harness, and the serving-traffic models —
//! everywhere determinism per seed matters for reproducibility.

/// xorshift64* generator (Marsaglia / Vigna). Passes BigCrush for our needs.
#[derive(Debug, Clone)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Create a generator from a non-zero seed (0 is remapped).
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn next_f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_f64() as f32) * (hi - lo)
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn next_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.next_below(hi - lo + 1)
    }

    /// Random bool with probability p of true.
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a vector with n uniform f32 values in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_f32_in(lo, hi)).collect()
    }

    /// Sample an exponential inter-arrival time with the given rate (events/s).
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        let u = self.next_f64().max(1e-12);
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShiftRng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_in_bounds() {
        let mut r = XorShiftRng::new(9);
        for _ in 0..10_000 {
            let v = r.next_in(3, 17);
            assert!((3..=17).contains(&v));
        }
    }

    #[test]
    fn zero_seed_remapped() {
        let mut r = XorShiftRng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = XorShiftRng::new(1234);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean={mean}");
    }
}
