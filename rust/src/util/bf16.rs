//! BF16 rounding helpers.
//!
//! The paper's datapaths are BF16 end-to-end (DRAM-PIM MACs, SRAM-PIM macros,
//! Curry ALUs, 16-bit flit payloads). The simulator computes in f32 but
//! rounds through BF16 at the same points the hardware would, so that the
//! functional results seen by the ISA interpreter carry hardware-faithful
//! precision.

/// Round an f32 to the nearest BF16 (round-to-nearest-even), returned as f32.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    if x.is_nan() {
        return f32::from_bits(0x7FC0_0000); // canonical quiet NaN, bf16-representable
    }
    let bits = x.to_bits();
    // round-to-nearest-even on the low 16 bits
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000)
}

/// Round every element of a slice through BF16.
pub fn bf16_round_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = bf16_round(*x);
    }
}

/// BF16 fused multiply-accumulate as the PIM MAC units perform it:
/// inputs are BF16, the product/accumulate is kept in f32 (hardware keeps a
/// wider accumulator), callers round the final result.
#[inline]
pub fn bf16_mac(acc: f32, a: f32, b: f32) -> f32 {
    acc + bf16_round(a) * bf16_round(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_unchanged() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0] {
            assert_eq!(bf16_round(v), v);
        }
    }

    #[test]
    fn rounds_to_16_bit_mantissa() {
        let x = 1.0f32 + f32::EPSILON; // not representable in bf16
        let r = bf16_round(x);
        assert_eq!(r.to_bits() & 0xFFFF, 0);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-8 is exactly halfway between two bf16 values around 1.0.
        let x = f32::from_bits(0x3F80_8000);
        let r = bf16_round(x);
        // ties to even → mantissa low bit of the bf16 result is 0
        assert_eq!((r.to_bits() >> 16) & 1, 0);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(bf16_round(f32::NAN).is_nan());
    }

    #[test]
    fn relative_error_bounded() {
        let mut worst = 0.0f32;
        for i in 1..10_000 {
            let v = i as f32 * 0.37;
            let e = ((bf16_round(v) - v) / v).abs();
            worst = worst.max(e);
        }
        // bf16 has 7 mantissa bits → rel err ≤ 2^-8 (matches jnp.bfloat16:
        // worst case on this sweep is 64.75 → 65.0, rel err 0.00386)
        assert!(worst <= 1.0 / 256.0 + 1e-7, "worst={worst}");
    }

    #[test]
    fn infinity_preserved() {
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_round(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }
}
