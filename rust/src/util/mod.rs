//! Small self-contained utilities (PRNG, stats, tables, JSON writer,
//! bench/prop harnesses, BF16 rounding, deterministic worker pool).
//! Nothing here depends on the rest of the library.
pub mod bench;
pub mod bf16;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::{Json, ToJson};
pub use pool::{default_jobs, par_map_indexed};
pub use rng::XorShiftRng;
