//! Small self-contained utilities (PRNG, stats, tables, bench/prop harnesses,
//! BF16 rounding). Nothing here depends on the rest of the library.
pub mod bench;
pub mod bf16;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::XorShiftRng;
