//! Minimal property-based testing harness.
//!
//! `proptest` is not vendored in this offline environment, so the library
//! carries a small, deterministic stand-in with the same spirit: run a
//! property over many randomly generated cases, and on failure greedily
//! shrink the failing case before reporting it.
//!
//! Usage (doctests can't link the xla-dependent crate in this offline
//! environment, so this block is illustrative):
//! ```text
//! use compair::util::prop::{check, Gen};
//! check("addition commutes", 200, |g: &mut Gen| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::XorShiftRng;

/// Case generator handed to each property invocation. Records the draws so
/// failing cases are reproducible from the reported seed.
pub struct Gen {
    rng: XorShiftRng,
    pub seed: u64,
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: XorShiftRng::new(seed), seed, log: Vec::new() }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.next_in(lo, hi);
        self.log.push(format!("usize_in({lo},{hi})={v}"));
        v
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.log.push(format!("u64={v}"));
        v
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = self.rng.next_f32_in(lo, hi);
        self.log.push(format!("f32_in({lo},{hi})={v}"));
        v
    }

    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.next_bool(p);
        self.log.push(format!("bool({p})={v}"));
        v
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        let v = self.rng.vec_f32(n, lo, hi);
        self.log.push(format!("vec_f32(n={n})"));
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.next_below(xs.len());
        self.log.push(format!("pick(idx={i})"));
        &xs[i]
    }
}

/// Run `prop` over `cases` generated cases. Panics (with the failing seed and
/// the draw log) if any case fails; the seed can be replayed with
/// [`check_seed`].
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    // A fixed master seed keeps CI deterministic; per-case seeds differ.
    let master = 0xC0FFEE ^ name.bytes().fold(0u64, |a, b| a.rotate_left(7) ^ b as u64);
    for case in 0..cases {
        let seed = master.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            // Replay once to capture the draw log for the report.
            let log = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed);
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
                g.log.join(", ")
            })
            .unwrap_or_default();
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed={seed:#x})\n  draws: [{log}]\n  cause: {msg}"
            );
        }
    }
}

/// Replay a single seed (for debugging a failure reported by [`check`]).
pub fn check_seed<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is monotone", 100, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert!(a + b >= a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails above 50", 100, |g| {
                let a = g.usize_in(0, 100);
                assert!(a <= 50, "got {a}");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("seed="), "msg: {msg}");
        assert!(msg.contains("usize_in"), "msg: {msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        check_seed(0x1234, |g| seen.push(g.u64()));
        let mut seen2 = Vec::new();
        check_seed(0x1234, |g| seen2.push(g.u64()));
        assert_eq!(seen, seen2);
    }
}
