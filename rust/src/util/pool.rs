//! Deterministic worker pool for embarrassingly parallel sweeps.
//!
//! Every layer of this repo that fans out independent jobs — figure cells,
//! scenario sweeps, `Engine::sweep` batches, NoC calibration anchor fits —
//! funnels through [`par_map_indexed`]: jobs are handed to `--jobs N`
//! workers (plain `std::thread::scope` threads, no dependencies) and the
//! results are merged **in submission order**, so the output is
//! bit-identical to a serial walk of the same job list. The determinism
//! contract (see docs/ARCHITECTURE.md §"Parallel execution") is therefore
//! structural, not statistical: parallelism only reorders *when* a job
//! runs, never what it computes or where its result lands.
//!
//! The job closure must be `Sync` (shared by every worker) and the jobs
//! must be independent — in particular, the memoizing cost models
//! (`CachedCostModel`, the `SimulatedNoc`/`CalibratedNoc` tiers) use
//! `RefCell` interior mutability and are deliberately `!Sync`; parallel
//! callers give each job its own model instance seeded from the shared
//! config (per-worker caches), which the type system enforces rather than
//! trusts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The default worker count: one per available hardware thread, falling
/// back to 1 when the parallelism cannot be queried (exotic platforms,
/// restricted sandboxes).
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to `jobs` worker threads, returning the
/// results **in submission order** (result `i` is `f(i, items[i])`,
/// wherever and whenever it ran).
///
/// * `jobs <= 1`, an empty list, or a single item runs inline on the
///   caller's thread — the serial path is not merely equivalent to the
///   parallel one, for these shapes it *is* the same code.
/// * Workers pull jobs from a shared cursor (no static partitioning), so
///   ragged job costs — one slow scenario cell among cheap ones — cannot
///   idle a worker while work remains.
/// * A panicking job propagates: the scope joins every worker first, then
///   re-raises, so no result built from a poisoned run can escape.
pub fn par_map_indexed<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if jobs <= 1 || n <= 1 {
        return items.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // Jobs are claimed by index from an atomic cursor; each item is moved
    // out of its slot exactly once (the cursor hands an index to exactly
    // one worker). Results land in their submission-order slot.
    let slots: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs.min(n) {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i]
                    .lock()
                    .expect("job slot poisoned")
                    .take()
                    .expect("job claimed twice");
                let r = f(i, item);
                *results[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("result slot poisoned")
                .expect("worker completed without writing its slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_submission_order() {
        let items: Vec<usize> = (0..64).collect();
        let out = par_map_indexed(4, items, |i, x| {
            // stagger the fast/slow jobs so completion order scrambles
            if x % 3 == 0 {
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
            (i, x * 2)
        });
        for (i, (idx, v)) in out.iter().enumerate() {
            assert_eq!(*idx, i);
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let work = |i: usize, x: u64| -> u64 {
            // a pure but non-trivial function of (index, item)
            let mut h = x.wrapping_mul(0x9e3779b97f4a7c15) ^ i as u64;
            for _ in 0..10 {
                h = h.rotate_left(13).wrapping_mul(31).wrapping_add(7);
            }
            h
        };
        let items: Vec<u64> = (0..257).map(|i| i * 3 + 1).collect();
        let serial = par_map_indexed(1, items.clone(), work);
        for jobs in [2usize, 4, 8] {
            assert_eq!(par_map_indexed(jobs, items.clone(), work), serial, "jobs={jobs}");
        }
    }

    #[test]
    fn degenerate_shapes_run_inline() {
        assert_eq!(par_map_indexed::<u32, u32, _>(8, vec![], |_, x| x), Vec::<u32>::new());
        assert_eq!(par_map_indexed(8, vec![41], |_, x| x + 1), vec![42]);
        assert_eq!(par_map_indexed(0, vec![1, 2, 3], |_, x| x * 10), vec![10, 20, 30]);
    }

    #[test]
    fn more_jobs_than_items_is_fine() {
        assert_eq!(par_map_indexed(64, vec![1, 2, 3], |_, x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn items_are_moved_not_cloned() {
        // non-Clone items must pass through the pool by move
        struct NoClone(usize);
        let items = vec![NoClone(1), NoClone(2), NoClone(3), NoClone(4)];
        let out = par_map_indexed(2, items, |_, t| t.0 * 10);
        assert_eq!(out, vec![10, 20, 30, 40]);
    }

    #[test]
    #[should_panic(expected = "job 2 exploded")]
    fn a_panicking_job_propagates() {
        let _ = par_map_indexed(4, vec![0usize, 1, 2, 3], |i, _| {
            if i == 2 {
                panic!("job 2 exploded");
            }
            i
        });
    }

    #[test]
    #[should_panic(expected = "inline job exploded")]
    fn a_panicking_job_propagates_on_the_inline_path_too() {
        // jobs <= 1 runs on the caller's thread — the panic must surface
        // there exactly as it does from a worker
        let _ = par_map_indexed(1, vec![0usize, 1, 2], |i, _| {
            if i == 1 {
                panic!("inline job exploded");
            }
            i
        });
    }

    #[test]
    fn every_job_runs_exactly_once() {
        // the shared-cursor claim must hand each index to one worker: a
        // dropped or double-run job would show up in the per-index tally
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        let out = par_map_indexed(8, (0..100usize).collect(), |i, x| {
            hits[i].fetch_add(1, Ordering::SeqCst);
            x
        });
        assert_eq!(out, (0..100).collect::<Vec<_>>());
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "job {i} ran a wrong number of times");
        }
    }

    #[test]
    fn default_jobs_is_positive() {
        assert!(default_jobs() >= 1);
    }
}
