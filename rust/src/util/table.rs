//! ASCII table / series printers used by the figure-regeneration harness.
//!
//! Every paper table/figure is re-emitted as text rows so that runs are
//! diffable and greppable in CI. `Table` renders aligned columns; `Series`
//! renders (x, y...) sweeps the way the paper's line plots read.

use std::fmt::Write as _;

/// A simple aligned ASCII table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch in table '{}'", self.title);
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-able values.
    pub fn rowv(&mut self, cells: Vec<String>) -> &mut Self {
        self.row(&cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float compactly (3 significant-ish digits, engineering-friendly).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 1e6 || a < 1e-3 {
        format!("{x:.3e}")
    } else if a >= 100.0 {
        format!("{x:.1}")
    } else if a >= 1.0 {
        format!("{x:.3}")
    } else {
        format!("{x:.4}")
    }
}

/// Format a ratio as "1.83x".
pub fn fx(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format nanoseconds with an adaptive unit.
pub fn ftime_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.1}ns")
    }
}

/// Format a byte count with an adaptive unit (decimal prefixes).
pub fn fbytes(b: u64) -> String {
    let b = b as f64;
    if b >= 1e12 {
        format!("{:.2}TB", b / 1e12)
    } else if b >= 1e9 {
        format!("{:.2}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2}KB", b / 1e3)
    } else {
        format!("{b:.0}B")
    }
}

/// Format picojoules with an adaptive unit.
pub fn fenergy_pj(pj: f64) -> String {
    if pj >= 1e12 {
        format!("{:.3}J", pj / 1e12)
    } else if pj >= 1e9 {
        format!("{:.3}mJ", pj / 1e9)
    } else if pj >= 1e6 {
        format!("{:.3}uJ", pj / 1e6)
    } else if pj >= 1e3 {
        format!("{:.3}nJ", pj / 1e3)
    } else {
        format!("{pj:.1}pJ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long-col"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-col"));
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn num_formats() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fx(1.834), "1.83x");
        assert_eq!(ftime_ns(1500.0), "1.500us");
        assert_eq!(ftime_ns(2.5e9), "2.500s");
        assert_eq!(fenergy_pj(2.0e9), "2.000mJ");
    }
}
