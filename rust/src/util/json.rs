//! A small hand-rolled JSON writer for machine-readable reports.
//!
//! No serde is vendored offline, so — mirroring the TOML-subset reader in
//! `config/toml.rs` — the crate carries its own writer. It is write-only:
//! every report type implements [`ToJson`] and the CLI's `--format json`
//! path renders the resulting [`Json`] tree. Output is compact (single
//! line), strings are escaped per RFC 8259, object keys keep insertion
//! order so reports diff stably, and non-finite floats serialize as
//! `null` (JSON has no NaN/Infinity).

use std::fmt::Write as _;

/// A JSON value tree, built bottom-up by [`ToJson`] implementations.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integral numbers (counters, ids, token counts).
    Int(i64),
    /// Floating-point numbers; non-finite values render as `null`.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Fields in insertion order (stable, diffable output).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An empty object, to be extended with [`Json::field`].
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Collect an iterator of values into a JSON array.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Builder-style field append; panics when called on a non-object
    /// (that is a programming error, not an input error).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            other => panic!("Json::field on non-object {other:?}"),
        }
        self
    }

    /// Render the tree to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            // Rust's f64 Display never emits exponent notation or locale
            // separators, so the digits are valid JSON as-is.
            Json::Num(x) if x.is_finite() => {
                let _ = write!(out, "{x}");
            }
            Json::Num(_) => out.push_str("null"),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}

// Counters in this crate (ns timestamps, token/byte counts) stay far below
// i64::MAX; the cast is lossless for every value the simulators produce.
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Int(v as i64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i64)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl<T: Into<Json>> From<Option<T>> for Json {
    fn from(v: Option<T>) -> Json {
        match v {
            Some(x) => x.into(),
            None => Json::Null,
        }
    }
}

/// Machine-readable serialization: every report struct the `Engine`
/// returns implements this, and the CLI's `--format json` renders it.
pub trait ToJson {
    fn to_json(&self) -> Json;

    /// Convenience: render directly to a compact JSON string.
    fn to_json_string(&self) -> String {
        self.to_json().render()
    }
}

impl ToJson for Json {
    fn to_json(&self) -> Json {
        self.clone()
    }
}

/// Write a JSON tree to a file with a trailing newline (used for the
/// `BENCH_*.json` perf-trajectory artifacts).
pub fn write_json_file(path: &std::path::Path, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.render() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::Bool(true).render(), "true");
        assert_eq!(Json::Int(-3).render(), "-3");
        assert_eq!(Json::Num(1.5).render(), "1.5");
        assert_eq!(Json::Num(2.0).render(), "2");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn non_finite_floats_become_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).render(), "null");
    }

    #[test]
    fn strings_are_escaped() {
        let s = Json::from("a\"b\\c\nd\te\u{1}").render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\te\\u0001\"");
    }

    #[test]
    fn objects_keep_insertion_order() {
        let j = Json::obj().field("z", 1u64).field("a", "x").field("n", Json::Null);
        assert_eq!(j.render(), "{\"z\":1,\"a\":\"x\",\"n\":null}");
    }

    #[test]
    fn arrays_and_nesting() {
        let j = Json::arr([Json::Int(1), Json::obj().field("k", Json::arr([Json::Bool(false)]))]);
        assert_eq!(j.render(), "[1,{\"k\":[false]}]");
    }

    #[test]
    fn option_maps_to_null() {
        let none: Option<&str> = None;
        assert_eq!(Json::obj().field("v", none).render(), "{\"v\":null}");
        assert_eq!(Json::obj().field("v", Some("x")).render(), "{\"v\":\"x\"}");
    }

    #[test]
    #[should_panic(expected = "non-object")]
    fn field_on_scalar_panics() {
        let _ = Json::Int(1).field("k", 2u64);
    }
}
