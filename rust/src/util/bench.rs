//! Minimal criterion-style benchmark harness.
//!
//! criterion is not vendored offline, so `cargo bench` targets use this:
//! warmup, then timed batches until a wall-clock budget is spent, reporting
//! mean ± stddev and throughput. Deliberately simple but honest: it measures
//! whole-batch wall time and never reuses results across iterations.

use std::time::{Duration, Instant};

use super::json::{Json, ToJson};
use super::stats::Stream;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<48} {:>12}/iter  (± {:>10}, min {}, max {}, n={})",
            self.name,
            super::table::ftime_ns(self.mean_ns),
            super::table::ftime_ns(self.stddev_ns),
            super::table::ftime_ns(self.min_ns),
            super::table::ftime_ns(self.max_ns),
            self.iters,
        )
    }

    /// Mean iterations per second (the `BENCH_*.json` trajectory metric).
    pub fn iters_per_s(&self) -> f64 {
        if self.mean_ns > 0.0 {
            1e9 / self.mean_ns
        } else {
            0.0
        }
    }
}

impl ToJson for BenchResult {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("name", self.name.as_str())
            .field("iters", self.iters)
            .field("mean_ns", self.mean_ns)
            .field("stddev_ns", self.stddev_ns)
            .field("min_ns", self.min_ns)
            .field("max_ns", self.max_ns)
            .field("iters_per_s", self.iters_per_s())
    }
}

/// Benchmark runner with a per-benchmark time budget.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(Duration::from_millis(200), Duration::from_secs(2))
    }
}

impl Bencher {
    pub fn new(warmup: Duration, budget: Duration) -> Self {
        Self { warmup, budget, results: Vec::new() }
    }

    /// Quick-mode bencher honoring COMPAIR_BENCH_FAST=1 (used in CI).
    pub fn from_env() -> Self {
        if std::env::var("COMPAIR_BENCH_FAST").as_deref() == Ok("1") {
            Self::new(Duration::from_millis(20), Duration::from_millis(200))
        } else {
            Self::default()
        }
    }

    /// Time `f`, which must return a value (consumed via `black_box`-like
    /// volatile read) so the compiler cannot elide the work.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup and batch-size calibration.
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.warmup {
            sink(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        // Aim for ~50 samples over the budget, at least 1 iter per sample.
        let batch = ((self.budget.as_secs_f64() / 50.0 / per_iter.max(1e-9)) as u64).max(1);

        let mut s = Stream::new();
        let mut iters = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < self.budget {
            let b0 = Instant::now();
            for _ in 0..batch {
                sink(f());
            }
            let dt = b0.elapsed().as_nanos() as f64 / batch as f64;
            s.push(dt);
            iters += batch;
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
            max_ns: s.max(),
        });
        let r = self.results.last().unwrap();
        println!("{}", r.report());
        r
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// All results accumulated so far as a JSON array (for the
    /// `BENCH_*.json` perf-trajectory artifacts).
    pub fn results_json(&self) -> Json {
        Json::arr(self.results.iter().map(|r| r.to_json()))
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn sink<T>(x: T) -> T {
    // `black_box` is the stable, safe anchor (the crate forbids unsafe
    // code; this was its last unsafe block).
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bencher::new(Duration::from_millis(5), Duration::from_millis(30));
        let r = b.bench("noop-ish", || 1u64 + 1).clone();
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.report().contains("noop-ish"));
    }

    #[test]
    fn sink_returns_value() {
        assert_eq!(sink(42), 42);
    }

    #[test]
    fn results_serialize_to_json() {
        let mut b = Bencher::new(Duration::from_millis(5), Duration::from_millis(20));
        b.bench("j", || 2u64 * 3);
        let s = b.results_json().render();
        assert!(s.starts_with('['));
        assert!(s.contains("\"name\":\"j\""));
        assert!(s.contains("\"iters_per_s\":"));
    }

    #[test]
    fn iters_per_s_inverts_mean() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 2e9,
            stddev_ns: 0.0,
            min_ns: 0.0,
            max_ns: 0.0,
        };
        assert!((r.iters_per_s() - 0.5).abs() < 1e-12);
    }
}
