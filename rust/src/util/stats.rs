//! Streaming statistics and simple distribution summaries.

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Stream {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stream {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a (copied, sorted) sample. p in [0, 100].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = samples.to_vec();
    // total_cmp: NaNs sort to the end instead of panicking mid-report; a
    // stray NaN in a latency vector must never take the whole run down.
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Geometric mean over the strictly positive, finite samples.
///
/// Zero, negative, NaN and infinite entries are skipped rather than folded
/// in — `ln(0) = -inf` would silently turn the whole mean into 0/NaN, so a
/// single zero-latency sample must not poison a report (regression: the
/// old version trusted its "strictly positive" doc and returned NaN/-inf
/// garbage). Returns NaN when no usable sample remains, matching
/// [`percentile`]'s empty-input convention.
pub fn geomean(samples: &[f64]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0u64;
    for &x in samples {
        if x > 0.0 && x.is_finite() {
            sum += x.ln();
            n += 1;
        }
    }
    if n == 0 {
        return f64::NAN;
    }
    (sum / n as f64).exp()
}

/// Relative error |a-b| / max(|b|, eps).
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

/// Max absolute element-wise difference of two equal-length slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_mean_var() {
        let mut s = Stream::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn percentile_basics() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&v, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&v, 50.0) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression: partial_cmp(..).unwrap() panicked on NaN input.
        // total_cmp orders (positive) NaN last, so the sorted sample is
        // [1, 2, 3, NaN] and the finite percentiles are well-defined.
        let v = vec![3.0, f64::NAN, 1.0, 2.0];
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&v, 25.0) - 1.75).abs() < 1e-9);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-9);
        assert!(percentile(&v, 100.0).is_nan(), "the NaN sorts to the top");
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_skips_nonpositive_and_nonfinite_samples() {
        // regression: a single zero-latency sample used to yield 0-or-NaN
        // via ln(0) = -inf and poison whole speedup reports
        assert!((geomean(&[1.0, 0.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, -3.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[f64::NAN, 2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[f64::INFINITY, 2.0, 8.0]) - 4.0).abs() < 1e-12);
        // nothing usable left → NaN, same convention as percentile(&[])
        assert!(geomean(&[]).is_nan());
        assert!(geomean(&[0.0, -1.0]).is_nan());
    }

    #[test]
    fn rel_err_zero_for_equal() {
        assert_eq!(rel_err(3.5, 3.5), 0.0);
    }

    #[test]
    fn rel_err_guards_zero_and_tiny_denominators() {
        // b = 0 hits the 1e-12 floor instead of dividing by zero: the
        // result is huge but finite, so tolerance comparisons stay usable
        let e = rel_err(1.0, 0.0);
        assert!(e.is_finite());
        assert!((e - 1e12).abs() / 1e12 < 1e-9);
        assert_eq!(rel_err(0.0, 0.0), 0.0);
        // a denominator below the floor is clamped up to it
        assert_eq!(rel_err(1e-13, 1e-14), (1e-13 - 1e-14) / 1e-12);
        // sign of the reference does not matter
        assert_eq!(rel_err(9.0, -10.0), 1.9);
    }

    #[test]
    fn rel_err_propagates_nan() {
        // the audit checks `rel_err(..) > tol`, which is false for NaN —
        // that is why its finiteness pass runs first; pin the behaviour
        assert!(rel_err(f64::NAN, 1.0).is_nan());
        assert!(rel_err(1.0, f64::NAN).is_nan());
        assert!(!(rel_err(f64::NAN, 1.0) > 1e-9));
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        // empty slices agree perfectly
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
        // direction of the difference is irrelevant
        assert_eq!(max_abs_diff(&[5.0], &[2.0]), 3.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn max_abs_diff_rejects_length_mismatch() {
        let _ = max_abs_diff(&[1.0, 2.0], &[1.0]);
    }

    #[test]
    fn stream_empty_and_one_sample_edges() {
        // empty stream: variance/stddev are 0.0 (not NaN), mean 0.0, and
        // the explicit-constructor sentinels are the identity elements
        let s = Stream::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.min(), f64::INFINITY);
        assert_eq!(s.max(), f64::NEG_INFINITY);
        // one sample: n-1 would divide by zero; var() guards to 0.0
        let mut s = Stream::new();
        s.push(7.5);
        assert_eq!(s.count(), 1);
        assert_eq!(s.mean(), 7.5);
        assert_eq!(s.var(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert_eq!(s.min(), 7.5);
        assert_eq!(s.max(), 7.5);
    }

    #[test]
    fn stream_default_differs_from_new_on_sentinels() {
        // #[derive(Default)] zeroes min/max; Stream::new() uses the proper
        // ±inf identities. Pin the difference so pushes through `new()`
        // always land the true extrema.
        let d = Stream::default();
        assert_eq!(d.min(), 0.0);
        assert_eq!(d.max(), 0.0);
        let mut s = Stream::new();
        s.push(3.0);
        s.push(-2.0);
        assert_eq!(s.min(), -2.0);
        assert_eq!(s.max(), 3.0);
    }
}
