//! DRAM-PIM substrate: GDDR6 command-level timing, the AiM-style compute
//! bank, and the channel (SIMD issue unit + global buffer).
pub mod bank;
pub mod channel;
pub mod timing;

pub use bank::{PimBank, MAC_BYTES_PER_CCD};
pub use channel::Channel;
pub use timing::{stream_latency_ns, write_latency_ns, BankTimer, Cmd};
