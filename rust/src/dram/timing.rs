//! GDDR6 bank command-level timing state machine.
//!
//! Models the constraints that dominate PIM GeMV latency: row
//! activate-to-column delay (tRCDRD/tRCDWR), row cycle (tRAS+tRP), and the
//! column-to-column (MAC issue) interval tCCD. This is the same level of
//! abstraction ramulator2 enforces for the command streams our mapper
//! generates (open-row streaming reads, no refresh modelled — PIM bursts are
//! far shorter than tREFI and AiM suspends refresh during MAC bursts).

use crate::config::DramConfig;

/// Commands the PIM bank sequencer issues.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmd {
    /// Activate a row.
    Act(u32),
    /// Column read (feeds MAC lanes or the HB/SRAM path).
    Rd,
    /// Column write.
    Wr,
    /// Precharge the open row.
    Pre,
}

/// Per-bank timing state. All times in ns, monotonically increasing.
#[derive(Debug, Clone)]
pub struct BankTimer {
    cfg: DramConfig,
    now: f64,
    open_row: Option<u32>,
    last_act: f64,
    last_col: f64,
    ready_for_act: f64,
    /// Statistics.
    pub n_act: u64,
    pub n_rd: u64,
    pub n_wr: u64,
    pub n_pre: u64,
}

impl BankTimer {
    pub fn new(cfg: &DramConfig) -> Self {
        Self {
            cfg: cfg.clone(),
            now: 0.0,
            open_row: None,
            last_act: f64::NEG_INFINITY,
            last_col: f64::NEG_INFINITY,
            ready_for_act: 0.0,
            n_act: 0,
            n_rd: 0,
            n_wr: 0,
            n_pre: 0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn open_row(&self) -> Option<u32> {
        self.open_row
    }

    /// Issue a command at the earliest legal time; returns completion time.
    pub fn issue(&mut self, cmd: Cmd) -> f64 {
        match cmd {
            Cmd::Act(row) => {
                assert!(self.open_row.is_none(), "ACT with row {:?} still open", self.open_row);
                self.now = self.now.max(self.ready_for_act);
                self.last_act = self.now;
                self.open_row = Some(row);
                self.n_act += 1;
            }
            Cmd::Rd | Cmd::Wr => {
                let row_ready = self.last_act
                    + if cmd == Cmd::Rd { self.cfg.t_rcdrd_ns } else { self.cfg.t_rcdwr_ns };
                let col_ready = self.last_col + self.cfg.t_ccd_ns;
                assert!(self.open_row.is_some(), "column command with no open row");
                self.now = self.now.max(row_ready).max(col_ready);
                self.last_col = self.now;
                if cmd == Cmd::Rd {
                    self.n_rd += 1;
                } else {
                    self.n_wr += 1;
                }
            }
            Cmd::Pre => {
                assert!(self.open_row.is_some(), "PRE with no open row");
                self.now = self.now.max(self.last_act + self.cfg.t_ras_ns);
                self.ready_for_act = self.now + self.cfg.t_rp_ns;
                self.open_row = None;
                self.n_pre += 1;
            }
        }
        self.now
    }

    /// Stream `reads` column reads from a single (closed) row: ACT → RD×n →
    /// PRE. Returns the elapsed time of the burst.
    pub fn stream_row(&mut self, row: u32, reads: usize) -> f64 {
        let t0 = self.now.max(self.ready_for_act);
        self.issue(Cmd::Act(row));
        for _ in 0..reads {
            self.issue(Cmd::Rd);
        }
        self.issue(Cmd::Pre);
        self.now - t0
    }
}

/// Closed-form latency of streaming `rows` rows with `reads_per_row` column
/// reads each (the inner loop of PIM GeMV). This is the *bank occupancy*
/// including the trailing tRP recovery (steady-state throughput cost), so it
/// equals the BankTimer's final PRE time plus one tRP. The hot paths use
/// this instead of issuing per-command (see §Perf).
pub fn stream_latency_ns(cfg: &DramConfig, rows: u64, reads_per_row: u64) -> f64 {
    if rows == 0 {
        return 0.0;
    }
    // Per row: ACT → (tRCDRD, then reads at tCCD) → PRE (respecting tRAS) →
    // tRP before the next ACT.
    let col_time = cfg.t_rcdrd_ns + reads_per_row.saturating_sub(1) as f64 * cfg.t_ccd_ns;
    let act_to_pre = col_time.max(cfg.t_ras_ns);
    let row_cycle = act_to_pre + cfg.t_rp_ns;
    rows as f64 * row_cycle
}

/// Latency of writing `rows` rows with `writes_per_row` column writes each.
pub fn write_latency_ns(cfg: &DramConfig, rows: u64, writes_per_row: u64) -> f64 {
    if rows == 0 {
        return 0.0;
    }
    let col_time = cfg.t_rcdwr_ns + writes_per_row.saturating_sub(1) as f64 * cfg.t_ccd_ns;
    let act_to_pre = col_time.max(cfg.t_ras_ns);
    rows as f64 * (act_to_pre + cfg.t_rp_ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DramConfig {
        DramConfig::default()
    }

    #[test]
    fn act_to_read_respects_trcd() {
        let c = cfg();
        let mut b = BankTimer::new(&c);
        b.issue(Cmd::Act(0));
        let t = b.issue(Cmd::Rd);
        assert_eq!(t, c.t_rcdrd_ns);
    }

    #[test]
    fn reads_spaced_by_tccd() {
        let c = cfg();
        let mut b = BankTimer::new(&c);
        b.issue(Cmd::Act(0));
        let t1 = b.issue(Cmd::Rd);
        let t2 = b.issue(Cmd::Rd);
        assert_eq!(t2 - t1, c.t_ccd_ns);
    }

    #[test]
    fn pre_respects_tras_and_trp() {
        let c = cfg();
        let mut b = BankTimer::new(&c);
        b.issue(Cmd::Act(0));
        b.issue(Cmd::Rd);
        let t_pre = b.issue(Cmd::Pre);
        assert_eq!(t_pre, c.t_ras_ns); // RD at 18ns < tRAS=27ns
        b.issue(Cmd::Act(1));
        assert_eq!(b.now(), c.t_ras_ns + c.t_rp_ns);
    }

    #[test]
    fn write_uses_trcdwr() {
        let c = cfg();
        let mut b = BankTimer::new(&c);
        b.issue(Cmd::Act(0));
        let t = b.issue(Cmd::Wr);
        assert_eq!(t, c.t_rcdwr_ns);
    }

    #[test]
    #[should_panic(expected = "no open row")]
    fn column_without_act_panics() {
        let mut b = BankTimer::new(&cfg());
        b.issue(Cmd::Rd);
    }

    #[test]
    fn closed_form_matches_state_machine() {
        let c = cfg();
        for (rows, reads) in [(1u64, 4u64), (3, 32), (10, 1), (5, 100)] {
            let mut b = BankTimer::new(&c);
            let mut total = 0.0;
            for r in 0..rows {
                total += b.stream_row(r as u32, reads as usize);
                // stream_row measures from ready time; add the tRP gap that
                // the closed form accounts for between rows.
            }
            let _ = total;
            let analytic = stream_latency_ns(&c, rows, reads);
            // closed form = state-machine end time + trailing tRP recovery
            assert!(
                (b.now() + c.t_rp_ns - analytic).abs() < 1e-6,
                "rows={rows} reads={reads}: sm={} cf={analytic}",
                b.now()
            );
        }
    }

    #[test]
    fn long_burst_dominated_by_tccd() {
        let c = cfg();
        // 1000 reads from one row: tRCDRD + 999*tCCD + tRP ≈ 1033ns
        let t = stream_latency_ns(&c, 1, 1000);
        assert!((t - (18.0 + 999.0 + 16.0)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn stats_counted() {
        let c = cfg();
        let mut b = BankTimer::new(&c);
        b.stream_row(0, 8);
        assert_eq!(b.n_act, 1);
        assert_eq!(b.n_rd, 8);
        assert_eq!(b.n_pre, 1);
    }
}
