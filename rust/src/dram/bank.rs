//! DRAM-PIM bank: the AiM-style compute bank (16 BF16 MAC lanes behind the
//! column decoder) plus its read-out path toward the hybrid-bonded SRAM-PIM.
//!
//! Latency comes from the command-level timing model (`timing`); this module
//! translates matrix/vector operations into command streams and reports
//! `OpCost`s. It also provides *functional* BF16 execution of the same
//! operations for numeric cross-validation.

use crate::config::{DramConfig, SramGang};
use crate::sim::{CostCounts, OpCost};
use crate::util::bf16::{bf16_mac, bf16_round};

use super::timing::{stream_latency_ns, write_latency_ns};

/// MAC-lane consumption granularity: 16 BF16 lanes × 2 B = 32 B per tCCD.
pub const MAC_BYTES_PER_CCD: usize = 32;

/// The PIM bank model.
#[derive(Debug, Clone)]
pub struct PimBank {
    pub cfg: DramConfig,
}

impl PimBank {
    pub fn new(cfg: &DramConfig) -> Self {
        Self { cfg: cfg.clone() }
    }

    fn rows_for(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.cfg.row_bytes as u64)
    }

    /// GeMV over a weight tile resident in this bank: `out_tile × in_dim`
    /// BF16 weights, streamed through the 16 MAC lanes once per batch
    /// element (DRAM-PIM has no weight reuse across the batch — §2.2).
    /// The input vector is assumed latched bank-locally (broadcast cost is
    /// accounted at channel level).
    pub fn gemv(&self, out_tile: usize, in_dim: usize, batch: usize) -> OpCost {
        if out_tile == 0 || in_dim == 0 || batch == 0 {
            return OpCost::zero();
        }
        let weight_bytes = (out_tile * in_dim * 2) as u64;
        let rows = self.rows_for(weight_bytes);
        let reads_per_row = (self.cfg.row_bytes / MAC_BYTES_PER_CCD) as u64;
        // Last row may be partial; model full rows for the first (rows-1)
        // and the remainder for the last.
        let full_rows = rows.saturating_sub(1);
        let rem_bytes = weight_bytes - full_rows * self.cfg.row_bytes as u64;
        let rem_reads = rem_bytes.div_ceil(MAC_BYTES_PER_CCD as u64);
        let once = stream_latency_ns(&self.cfg, full_rows, reads_per_row)
            + stream_latency_ns(&self.cfg, 1, rem_reads);
        let n_rd = full_rows * reads_per_row + rem_reads;
        let per_batch = OpCost {
            latency_ns: once,
            counts: CostCounts {
                dram_act: rows,
                dram_col_rd: n_rd,
                dram_mac: (out_tile * in_dim) as u64,
                ..Default::default()
            },
        };
        per_batch.repeat(batch as u64)
    }

    /// Stream `bytes` of data from the DRAM array to the hybrid-bonded
    /// SRAM-PIM through the column decoder's SRAM path. The decoder width is
    /// the §3.4 lever: 32 B/access coupled vs 128 B/access decoupled.
    pub fn read_to_sram(&self, bytes: u64) -> OpCost {
        if bytes == 0 {
            return OpCost::zero();
        }
        let access = self.cfg.column_decoder.sram_access_bytes(self.cfg.row_bytes) as u64;
        let rows = self.rows_for(bytes);
        let full_rows = rows.saturating_sub(1);
        let reads_per_row = (self.cfg.row_bytes as u64).div_ceil(access);
        let rem_bytes = bytes - full_rows * self.cfg.row_bytes as u64;
        let rem_reads = rem_bytes.div_ceil(access);
        let lat = stream_latency_ns(&self.cfg, full_rows, reads_per_row)
            + stream_latency_ns(&self.cfg, 1, rem_reads);
        OpCost {
            latency_ns: lat,
            counts: CostCounts {
                dram_act: rows,
                dram_col_rd: full_rows * reads_per_row + rem_reads,
                hb_bytes: bytes,
                ..Default::default()
            },
        }
    }

    /// Effective DRAM→SRAM read-out bandwidth (GB/s) of this bank, the green
    /// line in the Fig 20 DSE.
    pub fn sram_feed_gbs(&self) -> f64 {
        let bytes = 4 * self.cfg.row_bytes as u64; // steady-state over 4 rows
        let cost = self.read_to_sram(bytes);
        bytes as f64 / cost.latency_ns
    }

    /// Write `bytes` into the bank (e.g. SRAM results landing back in DRAM).
    pub fn write(&self, bytes: u64) -> OpCost {
        if bytes == 0 {
            return OpCost::zero();
        }
        let rows = self.rows_for(bytes);
        let writes_per_row = (self.cfg.row_bytes / MAC_BYTES_PER_CCD) as u64;
        let full_rows = rows.saturating_sub(1);
        let rem_bytes = bytes - full_rows * self.cfg.row_bytes as u64;
        let rem_writes = rem_bytes.div_ceil(MAC_BYTES_PER_CCD as u64);
        OpCost {
            latency_ns: write_latency_ns(&self.cfg, full_rows, writes_per_row)
                + write_latency_ns(&self.cfg, 1, rem_writes),
            counts: CostCounts {
                dram_act: rows,
                dram_col_wr: full_rows * writes_per_row + rem_writes,
                ..Default::default()
            },
        }
    }

    /// Read `bytes` for general consumption (row-granular stream).
    pub fn read(&self, bytes: u64) -> OpCost {
        if bytes == 0 {
            return OpCost::zero();
        }
        let rows = self.rows_for(bytes);
        let reads_per_row = (self.cfg.row_bytes / MAC_BYTES_PER_CCD) as u64;
        let full_rows = rows.saturating_sub(1);
        let rem_bytes = bytes - full_rows * self.cfg.row_bytes as u64;
        let rem_reads = rem_bytes.div_ceil(MAC_BYTES_PER_CCD as u64);
        OpCost {
            latency_ns: stream_latency_ns(&self.cfg, full_rows, reads_per_row)
                + stream_latency_ns(&self.cfg, 1, rem_reads),
            counts: CostCounts {
                dram_act: rows,
                dram_col_rd: full_rows * reads_per_row + rem_reads,
                ..Default::default()
            },
        }
    }

    /// Element-wise multiply (RoPE's EWMUL, SiLU gating): read two operands,
    /// write one result, MAC lanes do the multiplies.
    pub fn ewmul(&self, n_elems: usize) -> OpCost {
        let bytes = (n_elems * 2) as u64;
        let rd = self.read(bytes).then(&self.read(bytes));
        let wr = self.write(bytes);
        let mut c = rd.then(&wr);
        c.counts.dram_mac += n_elems as u64;
        c
    }

    /// Functional BF16 GeMV: `w` is row-major `out×in`, returns `w @ x`.
    /// Accumulates in f32, rounds through BF16 on input and output exactly
    /// as the 16-lane MAC datapath does.
    pub fn gemv_f32(w: &[f32], x: &[f32], out_dim: usize, in_dim: usize) -> Vec<f32> {
        assert_eq!(w.len(), out_dim * in_dim);
        assert_eq!(x.len(), in_dim);
        (0..out_dim)
            .map(|o| {
                let mut acc = 0.0f32;
                for i in 0..in_dim {
                    acc = bf16_mac(acc, w[o * in_dim + i], x[i]);
                }
                bf16_round(acc)
            })
            .collect()
    }

    /// How many weight bytes fit in this bank.
    pub fn capacity_bytes(&self) -> u64 {
        (self.cfg.bank_mb as u64) << 20
    }

    /// SRAM weight-reload helper: time to pull one ganged weight tile
    /// (shape per `gang`) out of DRAM into the macros via HB.
    pub fn reload_sram_weights(&self, gang: SramGang, sram: &crate::config::SramConfig) -> OpCost {
        let (i, o) = gang.shape(sram);
        self.read_to_sram((i * o * 2) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ColumnDecoder;

    fn bank() -> PimBank {
        PimBank::new(&DramConfig::default())
    }

    #[test]
    fn gemv_counts_exact_macs() {
        let c = bank().gemv(10, 5120, 1);
        assert_eq!(c.counts.dram_mac, 51_200);
        // 10×5120×2 B = 100 KiB = 100 rows of 1 KiB
        assert_eq!(c.counts.dram_act, 100);
        assert_eq!(c.counts.dram_col_rd, 3200);
    }

    #[test]
    fn gemv_scales_linearly_with_batch() {
        let b = bank();
        let c1 = b.gemv(16, 4096, 1);
        let c8 = b.gemv(16, 4096, 8);
        assert!((c8.latency_ns - 8.0 * c1.latency_ns).abs() < 1e-6);
        assert_eq!(c8.counts.dram_mac, 8 * c1.counts.dram_mac);
    }

    #[test]
    fn gemv_zero_edge_cases() {
        assert_eq!(bank().gemv(0, 100, 1), OpCost::zero());
        assert_eq!(bank().gemv(100, 0, 1), OpCost::zero());
        assert_eq!(bank().gemv(100, 100, 0), OpCost::zero());
    }

    #[test]
    fn decoupled_decoder_feeds_sram_faster() {
        let coupled = bank();
        let mut cfg = DramConfig::default();
        cfg.column_decoder = ColumnDecoder::Decoupled8and4;
        let decoupled = PimBank::new(&cfg);
        let b = 1 << 20;
        let t_c = coupled.read_to_sram(b).latency_ns;
        let t_d = decoupled.read_to_sram(b).latency_ns;
        let speedup = t_c / t_d;
        // §3.4: the decoupled decoder should help by a meaningful factor
        // (bounded by row overheads — e2e gain is 1.15–1.5×).
        assert!(speedup > 1.3 && speedup < 2.0, "speedup={speedup}");
        assert_eq!(coupled.read_to_sram(b).counts.hb_bytes, b);
    }

    #[test]
    fn feed_bandwidth_under_per_bank_ceiling() {
        // Coupled read-out must be well below the 32 GB/s per-bank internal
        // bandwidth (Newton's sacrificed read-out width).
        let f = bank().sram_feed_gbs();
        assert!(f < 32.0, "feed={f}");
        let mut cfg = DramConfig::default();
        cfg.column_decoder = ColumnDecoder::Decoupled8and4;
        let f2 = PimBank::new(&cfg).sram_feed_gbs();
        assert!(f2 > f);
    }

    #[test]
    fn partial_row_not_overcounted() {
        let b = bank();
        // 100 B read: 1 row, ceil(100/32)=4 column reads
        let c = b.read(100);
        assert_eq!(c.counts.dram_act, 1);
        assert_eq!(c.counts.dram_col_rd, 4);
    }

    #[test]
    fn functional_gemv_matches_naive_f32_closely() {
        use crate::util::XorShiftRng;
        let mut r = XorShiftRng::new(3);
        let (o, i) = (8, 64);
        let w = r.vec_f32(o * i, -1.0, 1.0);
        let x = r.vec_f32(i, -1.0, 1.0);
        let got = PimBank::gemv_f32(&w, &x, o, i);
        for oo in 0..o {
            let exact: f32 = (0..i).map(|ii| w[oo * i + ii] * x[ii]).sum();
            assert!(
                (got[oo] - exact).abs() < 0.15,
                "bf16 deviation too large: {} vs {exact}",
                got[oo]
            );
        }
    }

    #[test]
    fn ewmul_counts() {
        let c = bank().ewmul(512);
        assert_eq!(c.counts.dram_mac, 512);
        assert!(c.counts.dram_col_rd >= 2 * 512 * 2 / 32);
        assert!(c.counts.dram_col_wr >= 512 * 2 / 32);
    }

    #[test]
    fn capacity_is_32mb() {
        assert_eq!(bank().capacity_bytes(), 32 << 20);
    }
}
