//! DRAM-PIM channel: 16 banks sharing a global buffer and (in CompAir) the
//! per-channel CompAir-NoC. The channel is the SIMD issue unit — all banks
//! receive the same row-level instruction.

use crate::config::DramConfig;
use crate::sim::{CostCounts, OpCost};

use super::bank::PimBank;

/// A channel of `banks_per_channel` PIM banks.
#[derive(Debug, Clone)]
pub struct Channel {
    pub cfg: DramConfig,
    pub bank: PimBank,
}

impl Channel {
    pub fn new(cfg: &DramConfig) -> Self {
        Self { cfg: cfg.clone(), bank: PimBank::new(cfg) }
    }

    pub fn n_banks(&self) -> usize {
        self.cfg.banks_per_channel
    }

    /// All banks execute the same per-bank op in lockstep (SIMD): channel
    /// latency is the bank latency; events multiply by the bank count.
    pub fn simd(&self, per_bank: OpCost) -> OpCost {
        per_bank.replicate(self.n_banks() as u64)
    }

    /// Like [`simd`] but only `active` banks participate (mask).
    pub fn simd_masked(&self, per_bank: OpCost, active: usize) -> OpCost {
        assert!(active <= self.n_banks());
        per_bank.replicate(active as u64)
    }

    /// Broadcast `bytes` from the channel controller to every bank through
    /// the global buffer. AiM's GB drives a shared bus: a single serialized
    /// pass of the payload reaches all banks.
    pub fn gb_broadcast(&self, bytes: u64) -> OpCost {
        let lat = bytes as f64 / self.cfg.global_buffer_gbs; // GB/s == B/ns
        OpCost { latency_ns: lat, counts: CostCounts { gb_bytes: bytes, ..Default::default() } }
    }

    /// Gather per-bank payloads (`bytes_per_bank` from each of `banks`)
    /// through the global buffer — serialized bank by bank (§3.3: "requires
    /// serializing the access of the DRAM banks").
    pub fn gb_gather(&self, bytes_per_bank: u64, banks: usize) -> OpCost {
        let total = bytes_per_bank * banks as u64;
        OpCost {
            latency_ns: total as f64 / self.cfg.global_buffer_gbs,
            counts: CostCounts { gb_bytes: total, ..Default::default() },
        }
    }

    /// Baseline inter-bank reduction through the global buffer: gather all
    /// partials to one bank, which then accumulates them with its MAC lanes.
    pub fn gb_reduce(&self, elems: usize, banks: usize) -> OpCost {
        let bytes_per_bank = (elems * 2) as u64;
        let gather = self.gb_gather(bytes_per_bank, banks.saturating_sub(1));
        // Accumulation: (banks-1) passes of `elems` adds on the target bank's
        // MAC lanes at 16 lanes / tCCD.
        let adds = (banks.saturating_sub(1) * elems) as u64;
        let acc_lat = adds as f64 / 16.0 * self.cfg.t_ccd_ns;
        let acc = OpCost {
            latency_ns: acc_lat,
            counts: CostCounts { dram_mac: adds, ..Default::default() },
        };
        gather.then(&acc)
    }

    /// Move `bytes` from this channel to the device controller (external
    /// I/O), e.g. for centralized-NLU processing in the CENT baseline.
    pub fn to_controller(&self, bytes: u64) -> OpCost {
        let per_ch = self.cfg.external_gbs_per_channel;
        OpCost {
            latency_ns: bytes as f64 / per_ch,
            counts: CostCounts { gb_bytes: bytes, ..Default::default() },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ch() -> Channel {
        Channel::new(&DramConfig::default())
    }

    #[test]
    fn simd_multiplies_counts_not_latency() {
        let c = ch();
        let per_bank = c.bank.gemv(10, 1024, 1);
        let all = c.simd(per_bank);
        assert_eq!(all.latency_ns, per_bank.latency_ns);
        assert_eq!(all.counts.dram_mac, 16 * per_bank.counts.dram_mac);
    }

    #[test]
    fn gb_broadcast_rate() {
        // 32 KB at 32 GB/s = 1024 ns
        let c = ch().gb_broadcast(32 << 10);
        assert!((c.latency_ns - 1024.0).abs() < 1e-9);
        assert_eq!(c.counts.gb_bytes, 32 << 10);
    }

    #[test]
    fn gb_reduce_serializes_banks() {
        let c = ch();
        let r2 = c.gb_reduce(4096, 2);
        let r16 = c.gb_reduce(4096, 16);
        // 15 gathers vs 1 gather → ~15x the gather time
        assert!(r16.latency_ns > 10.0 * r2.latency_ns);
        assert_eq!(r16.counts.dram_mac, 15 * 4096);
    }

    #[test]
    fn masked_simd_bounds() {
        let c = ch();
        let per_bank = c.bank.read(1024);
        let m = c.simd_masked(per_bank, 4);
        assert_eq!(m.counts.dram_act, 4 * per_bank.counts.dram_act);
    }

    #[test]
    #[should_panic]
    fn masked_simd_overflow_panics() {
        let c = ch();
        c.simd_masked(OpCost::zero(), 17);
    }
}
