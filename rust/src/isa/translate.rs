//! Autonomous Row-Level → Packet-Level translation (paper §5.2, Fig 14).
//!
//! Two mechanisms:
//! * **Reduce/BCast instantiation** (Fig 14A): one SIMD NoC_Reduce row
//!   instruction expands to per-bank packets following the fixed binary-tree
//!   pattern (handled by `noc::trees`).
//! * **Path generation** (Fig 14B): consecutive NoC_Scalar instructions
//!   forming a producer-consumer chain (dst of one = src of the next) are
//!   fused into a single packet whose path encodes the whole computation,
//!   eliminating the conservative DRAM write-back between steps. Periodic
//!   chains (the exponential's {*=x, /=k, +=1} blocks) compress further via
//!   the packet's IterNum field.

use crate::noc::packet::{PathStep, RouterId, StepOp};

use super::row::{ArgSrc, RowInst};

/// One fused (or single) scalar stage ready for packet emission.
#[derive(Debug, Clone)]
pub struct FusedChain {
    /// Per-traversal steps (≤ 4): the ops and their ArgReg sources.
    pub steps: Vec<(StepOp, ArgSrc, bool, StepOp, f32)>, // (op, arg, iter_tag, iter_op, iter_arg)
    /// Path traversals encoded in IterNum (1 = non-periodic chain).
    pub iter_num: u8,
    pub src: usize,
    pub dst: usize,
    pub mask: u64,
    pub len: usize,
    /// How many row instructions this chain absorbed.
    pub absorbed: usize,
}

/// Cheap `Eq`/`Copy` ALU-binding key for a chain step. Immediates compare
/// by bit pattern (same distinction `{arg:?}` drew, without the per-step
/// String allocation the old key paid on every lane_width/emit_path call).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ArgKey {
    Imm(u32),
    Row(usize),
}

impl ArgKey {
    fn of(arg: &ArgSrc) -> ArgKey {
        match arg {
            ArgSrc::Imm(v) => ArgKey::Imm(v.to_bits()),
            ArgSrc::Row(r) => ArgKey::Row(*r),
        }
    }
}

/// Which of the two per-column Curry ALUs an op binds to (Fig 13).
fn alu_of(op: StepOp) -> usize {
    match op {
        StepOp::Mul | StepOp::Div => 0,
        StepOp::Add | StepOp::Sub => 1,
    }
}

impl FusedChain {
    /// Column-slot assignment shared by `lane_width`, `emit_path` and
    /// `alu_configs` (they must agree, so the loop lives in one place).
    /// Per step: the column offset it lands on and whether it reuses an
    /// already-configured identical (op, arg) binding. Two steps share a
    /// column only if they bind different ALUs (Mul/Div → ALU0,
    /// Add/Sub → ALU1) or are the same (op, arg) assignment.
    fn assign_columns(&self) -> Vec<(usize, bool)> {
        let mut cols: Vec<[Option<(StepOp, ArgKey)>; 2]> = Vec::new();
        let mut out = Vec::with_capacity(self.steps.len());
        for (op, arg, _, _, _) in &self.steps {
            let alu = alu_of(*op);
            let key = (*op, ArgKey::of(arg));
            let mut found = None;
            for (ci, c) in cols.iter_mut().enumerate() {
                match &c[alu] {
                    Some(k) if *k == key => {
                        found = Some((ci, true));
                        break;
                    }
                    None => {
                        c[alu] = Some(key);
                        found = Some((ci, false));
                        break;
                    }
                    _ => {}
                }
            }
            out.push(found.unwrap_or_else(|| {
                let mut slot: [Option<(StepOp, ArgKey)>; 2] = [None, None];
                slot[alu] = Some(key);
                cols.push(slot);
                (cols.len() - 1, false)
            }));
        }
        out
    }

    /// Distinct router columns this chain's lane occupies under the
    /// ALU-binding rule.
    pub fn lane_width(&self) -> usize {
        self.assign_columns().iter().map(|(ci, _)| ci + 1).max().unwrap_or(1)
    }

    /// Emit the path steps for a given bank row, mapping chain steps onto
    /// router columns the same way `lane_width` does. `col_base` offsets the
    /// column allocation so multiple lanes coexist in one bank.
    pub fn emit_path(&self, bank: usize, col_base: usize, mesh_cols: usize) -> Vec<PathStep> {
        let cols = self.assign_columns();
        let mut path = Vec::with_capacity(self.steps.len());
        for ((op, _, iter_tag, _, _), (ci, _)) in self.steps.iter().zip(&cols) {
            let at = RouterId::new((col_base + ci) % mesh_cols, bank);
            let mut step =
                if *iter_tag { PathStep::compute_iter(at, *op) } else { PathStep::compute(at, *op) };
            step.at = at;
            path.push(step);
        }
        path
    }

    /// The ALU configurations this chain requires for a bank/lane, as
    /// (column offset, alu, arg-source, iter_op, iter_arg).
    pub fn alu_configs(&self) -> Vec<(usize, usize, ArgSrc, StepOp, f32)> {
        let cols = self.assign_columns();
        let mut out = Vec::new();
        for ((op, arg, _, iter_op, iter_arg), (ci, dup)) in self.steps.iter().zip(&cols) {
            if !*dup {
                out.push((*ci, alu_of(*op), arg.clone(), *iter_op, *iter_arg));
            }
        }
        out
    }

    /// How many steps bind the iterative divider (the lint's occupancy
    /// hazard: a second in-chain Div serializes on the same 4-cycle unit).
    pub fn div_steps(&self) -> usize {
        self.steps.iter().filter(|(op, ..)| *op == StepOp::Div).count()
    }

    /// Whether two steps carry the same op with *different* args — each such
    /// pair costs an extra column under the ALU-binding rule.
    pub fn has_alu_conflict(&self) -> bool {
        self.steps.iter().enumerate().any(|(i, (op_a, arg_a, ..))| {
            self.steps[..i]
                .iter()
                .any(|(op_b, arg_b, ..)| op_a == op_b && ArgKey::of(arg_a) != ArgKey::of(arg_b))
        })
    }
}

/// Split a row program into maximal fusable NoC_Scalar chains plus
/// pass-through instructions. `fuse=false` reproduces the Fig 23 "Base"
/// (every NoC_Scalar is its own chain with a DRAM round-trip).
pub fn plan(insts: &[RowInst], fuse: bool) -> Vec<Plan> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < insts.len() {
        match &insts[i] {
            RowInst::NocScalar { .. } => {
                let start = i;
                let mut end = i + 1;
                if fuse {
                    while end < insts.len() && chains(&insts[end - 1], &insts[end]) {
                        end += 1;
                    }
                }
                out.extend(fuse_run(&insts[start..end]));
                i = end;
            }
            other => {
                out.push(Plan::Other(other.clone()));
                i += 1;
            }
        }
    }
    out
}

/// Planned execution unit.
#[derive(Debug, Clone)]
pub enum Plan {
    Chain(FusedChain),
    Other(RowInst),
}

/// Can instruction `b` fuse behind `a`? (producer-consumer, same shape.)
fn chains(a: &RowInst, b: &RowInst) -> bool {
    match (a, b) {
        (
            RowInst::NocScalar { dst: d1, mask: m1, len: l1, .. },
            RowInst::NocScalar { src: s2, mask: m2, len: l2, .. },
        ) => d1 == s2 && m1 == m2 && l1 == l2,
        _ => false,
    }
}

fn scalar_parts(i: &RowInst) -> (StepOp, ArgSrc, bool, StepOp, f32, usize, usize, u64, usize) {
    match i {
        RowInst::NocScalar { op, src, dst, mask, len, arg, iter_tag, iter_op, iter_arg } => {
            (*op, arg.clone(), *iter_tag, *iter_op, *iter_arg, *src, *dst, *mask, *len)
        }
        _ => unreachable!(),
    }
}

/// Fuse one maximal chain run, detecting periodic blocks for IterNum
/// compression. Emits one or more chains, each with ≤ 4 path steps.
fn fuse_run(run: &[RowInst]) -> Vec<Plan> {
    // Try period detection over the whole run first: period p such that the
    // run is b identical-op blocks; args match the ArgReg recurrence.
    for p in 1..=4usize.min(run.len()) {
        if run.len() % p != 0 {
            continue;
        }
        let blocks = run.len() / p;
        if blocks < 2 || blocks > 15 {
            continue;
        }
        if period_matches(run, p) {
            let (_, _, _, _, _, src0, _, mask, len) = scalar_parts(&run[0]);
            let (.., dst_last, _, _) = last_dst(run);
            let steps = (0..p).map(|j| {
                let (op, arg, it, iop, ia, ..) = scalar_parts(&run[j]);
                (op, arg, it, iop, ia)
            });
            return vec![Plan::Chain(FusedChain {
                steps: steps.collect(),
                iter_num: blocks as u8,
                src: src0,
                dst: dst_last,
                mask,
                len,
                absorbed: run.len(),
            })];
        }
    }
    // No periodicity: greedy 4-step windows.
    run.chunks(4)
        .map(|w| {
            let (_, _, _, _, _, src0, _, mask, len) = scalar_parts(&w[0]);
            let (.., dst_last, _, _) = last_dst(w);
            Plan::Chain(FusedChain {
                steps: w
                    .iter()
                    .map(|i| {
                        let (op, arg, it, iop, ia, ..) = scalar_parts(i);
                        (op, arg, it, iop, ia)
                    })
                    .collect(),
                iter_num: 1,
                src: src0,
                dst: dst_last,
                mask,
                len,
                absorbed: w.len(),
            })
        })
        .collect()
}

fn last_dst(run: &[RowInst]) -> (StepOp, ArgSrc, usize, u64, usize) {
    let (op, arg, _, _, _, _, dst, mask, len) = scalar_parts(run.last().unwrap());
    (op, arg, dst, mask, len)
}

/// Does `run` consist of identical blocks of period `p`, where iterating
/// steps follow their declared ArgReg recurrence and static steps repeat
/// verbatim?
fn period_matches(run: &[RowInst], p: usize) -> bool {
    let blocks = run.len() / p;
    for j in 0..p {
        let (op0, arg0, it0, iop0, ia0, ..) = scalar_parts(&run[j]);
        let mut expect = arg0.clone();
        for b in 1..blocks {
            let (op, arg, it, iop, ia, ..) = scalar_parts(&run[b * p + j]);
            if op != op0 || it != it0 || iop != iop0 || ia != ia0 {
                return false;
            }
            match (&expect, &arg) {
                (ArgSrc::Row(r0), ArgSrc::Row(r)) if r0 == r => {}
                (ArgSrc::Imm(v0), ArgSrc::Imm(v)) => {
                    let want = if it0 { iop0.apply(*v0, ia0) } else { *v0 };
                    if (want - *v).abs() > 1e-6 {
                        return false;
                    }
                    expect = ArgSrc::Imm(*v);
                }
                _ => return false,
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::row::{RowProgram, ALL_BANKS};

    #[test]
    fn exp_program_fuses_to_one_iterated_packet() {
        let p = RowProgram::exp_program(0, 100, 4, 6, ALL_BANKS);
        let plans = plan(&p.insts, true);
        // Fill passes through; the 18 scalars fuse to one chain.
        assert_eq!(plans.len(), 2, "Fill + one fused chain expected");
        match &plans[1] {
            Plan::Chain(c) => {
                assert_eq!(c.steps.len(), 3);
                assert_eq!(c.iter_num, 6);
                assert_eq!(c.absorbed, 18);
                // Fig 13 layout: 2 router columns per lane
                assert_eq!(c.lane_width(), 2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn unfused_plan_is_one_chain_per_inst() {
        let p = RowProgram::exp_program(0, 100, 4, 6, ALL_BANKS);
        let plans = plan(&p.insts, false);
        assert_eq!(plans.len(), 19); // Fill + 18 single-step chains
        for pl in &plans[1..] {
            match pl {
                Plan::Chain(c) => {
                    assert_eq!(c.steps.len(), 1);
                    assert_eq!(c.iter_num, 1);
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn non_chained_scalars_do_not_fuse() {
        use crate::noc::StepOp;
        let mut p = RowProgram::new();
        p.push(RowInst::scalar(StepOp::Add, 0, 10, 4, 1.0));
        p.push(RowInst::scalar(StepOp::Add, 50, 60, 4, 1.0)); // src != prev dst
        let plans = plan(&p.insts, true);
        assert_eq!(plans.len(), 2);
    }

    #[test]
    fn long_aperiodic_chain_splits_at_4_steps() {
        use crate::noc::StepOp;
        let mut p = RowProgram::new();
        for k in 0..6 {
            p.push(RowInst::scalar(StepOp::Add, k * 10, (k + 1) * 10, 4, k as f32 * 3.0 + 1.0));
        }
        let plans = plan(&p.insts, true);
        assert_eq!(plans.len(), 2);
        match (&plans[0], &plans[1]) {
            (Plan::Chain(a), Plan::Chain(b)) => {
                assert_eq!(a.steps.len(), 4);
                assert_eq!(b.steps.len(), 2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn emit_path_respects_alu_binding() {
        let p = RowProgram::exp_program(0, 100, 1, 6, 1);
        let plans = plan(&p.insts, true);
        let c = match &plans[1] {
            Plan::Chain(c) => c,
            _ => panic!(),
        };
        let path = c.emit_path(3, 0, 4);
        assert_eq!(path.len(), 3);
        // Mul and Div are both ALU0-class with different args → different
        // columns; Add shares Mul's column on ALU1.
        assert_ne!(path[0].at, path[1].at);
        assert_eq!(path[2].at, path[0].at);
        assert!(path[1].iter_tag);
        assert!(path.iter().all(|s| s.at.y == 3));
    }

    /// Reference column-assignment with the old `format!("{arg:?}")` String
    /// key, kept verbatim so the ArgKey refactor is pinned to it.
    fn lane_width_reference(c: &FusedChain) -> usize {
        let mut cols: Vec<[Option<(StepOp, String)>; 2]> = Vec::new();
        for (op, arg, _, _, _) in &c.steps {
            let alu = match op {
                StepOp::Mul | StepOp::Div => 0usize,
                StepOp::Add | StepOp::Sub => 1,
            };
            let key = (*op, format!("{arg:?}"));
            let mut placed = false;
            for col in cols.iter_mut() {
                match &col[alu] {
                    Some(k) if *k == key => {
                        placed = true;
                        break;
                    }
                    None => {
                        col[alu] = Some(key.clone());
                        placed = true;
                        break;
                    }
                    _ => {}
                }
            }
            if !placed {
                let mut slot: [Option<(StepOp, String)>; 2] = [None, None];
                slot[alu] = Some(key);
                cols.push(slot);
            }
        }
        cols.len().max(1)
    }

    fn chain_of(steps: Vec<(StepOp, ArgSrc)>) -> FusedChain {
        FusedChain {
            steps: steps
                .into_iter()
                .map(|(op, arg)| (op, arg, false, StepOp::Sub, 0.0))
                .collect(),
            iter_num: 1,
            src: 0,
            dst: 0,
            mask: ALL_BANKS,
            len: 4,
            absorbed: 1,
        }
    }

    #[test]
    fn arg_key_matches_debug_string_reference() {
        use StepOp::*;
        let cases = vec![
            vec![],
            vec![(Mul, ArgSrc::Row(0)), (Div, ArgSrc::Imm(6.0)), (Add, ArgSrc::Imm(1.0))],
            vec![(Add, ArgSrc::Imm(1.0)), (Add, ArgSrc::Imm(1.0))], // dup binding
            vec![(Add, ArgSrc::Imm(1.0)), (Add, ArgSrc::Imm(1.5))], // conflict
            vec![(Mul, ArgSrc::Row(3)), (Mul, ArgSrc::Row(7)), (Mul, ArgSrc::Row(3))],
            vec![(Mul, ArgSrc::Imm(0.0)), (Mul, ArgSrc::Imm(-0.0))], // bit-distinct
            vec![(Sub, ArgSrc::Imm(2.0)), (Div, ArgSrc::Imm(2.0)), (Add, ArgSrc::Row(1))],
        ];
        for steps in cases {
            let c = chain_of(steps);
            assert_eq!(c.lane_width(), lane_width_reference(&c), "steps {:?}", c.steps);
        }
        // and the shipped exp chain keeps its Fig 13 width of 2
        let p = RowProgram::exp_program(0, 100, 4, 6, ALL_BANKS);
        match &plan(&p.insts, true)[1] {
            Plan::Chain(c) => {
                assert_eq!(c.lane_width(), 2);
                assert_eq!(c.lane_width(), lane_width_reference(c));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn empty_program_plans_to_nothing() {
        assert!(plan(&[], true).is_empty());
        assert!(plan(&[], false).is_empty());
    }

    #[test]
    fn single_non_fusable_inst_passes_through() {
        let insts = [RowInst::Fill { dst: 0, mask: ALL_BANKS, len: 4, value: 0.0 }];
        let plans = plan(&insts, true);
        assert_eq!(plans.len(), 1);
        assert!(matches!(plans[0], Plan::Other(RowInst::Fill { .. })));
    }

    #[test]
    fn non_adjacent_producer_breaks_the_chain() {
        use crate::noc::StepOp;
        // inst2's src is inst0's dst, not inst1's — only adjacent
        // producer-consumer pairs fuse, so the run splits after inst1
        let mut p = RowProgram::new();
        p.push(RowInst::scalar(StepOp::Add, 0, 10, 4, 1.0));
        p.push(RowInst::scalar(StepOp::Mul, 10, 20, 4, 2.0));
        p.push(RowInst::scalar(StepOp::Add, 10, 30, 4, 3.0));
        let plans = plan(&p.insts, true);
        assert_eq!(plans.len(), 2);
        match (&plans[0], &plans[1]) {
            (Plan::Chain(a), Plan::Chain(b)) => {
                assert_eq!(a.steps.len(), 2);
                assert_eq!(a.absorbed, 2);
                assert_eq!(b.steps.len(), 1);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn iter_num_saturation_falls_back_to_greedy_windows() {
        // 15 blocks is the last value the 4-bit IterNum encodes…
        let p = RowProgram::exp_program(0, 4096, 4, 15, ALL_BANKS);
        let plans = plan(&p.insts, true);
        assert_eq!(plans.len(), 2);
        match &plans[1] {
            Plan::Chain(c) => {
                assert_eq!(c.iter_num, 15);
                assert_eq!(c.absorbed, 45);
            }
            _ => panic!(),
        }
        // …16 saturates: the 48-scalar run degrades to greedy 4-step windows
        let p = RowProgram::exp_program(0, 4096, 4, 16, ALL_BANKS);
        let plans = plan(&p.insts, true);
        assert_eq!(plans.len(), 1 + 12, "Fill + 48/4 greedy chains");
        for pl in &plans[1..] {
            match pl {
                Plan::Chain(c) => {
                    assert_eq!(c.iter_num, 1);
                    assert_eq!(c.steps.len(), 4);
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn mixed_program_passthrough() {
        use crate::noc::StepOp;
        let mut p = RowProgram::new();
        p.push(RowInst::scalar(StepOp::Add, 0, 8, 4, 1.0));
        p.push(RowInst::rope_exchange(8, 16, 16));
        p.push(RowInst::scalar(StepOp::Mul, 16, 24, 4, 2.0));
        let plans = plan(&p.insts, true);
        assert_eq!(plans.len(), 3);
        assert!(matches!(plans[1], Plan::Other(RowInst::NocExchange { .. })));
    }
}
