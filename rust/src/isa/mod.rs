//! Hierarchical ISA (paper §5): the SIMD Row-Level programming interface
//! (Table 1), the Packet-Level execution format (Table 2, in `noc::packet`),
//! the autonomous translator with path-generation fusion (§5.2), and the
//! channel-level machine interpreting programs functionally + in time.
pub mod interp;
pub mod row;
pub mod translate;

pub use interp::Machine;
pub use row::{AccessDir, Addr, ArgSrc, ExchangeMode, Mask, RowInst, RowProgram, ALL_BANKS};
pub use translate::{plan, FusedChain, Plan};
