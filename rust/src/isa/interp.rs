//! The channel-level machine: a functional + timing interpreter for
//! Row-Level programs, executing NoC traffic on the real mesh simulator and
//! memory/matrix work through the substrate models.
//!
//! This is the reference semantics of the hierarchical ISA: integration
//! tests run the same computation here, through the Pallas kernels (via the
//! AOT HLO artifacts), and through the pure-jnp oracle, and require
//! agreement.

use crate::config::{HwConfig, SramGang};
use crate::dram::PimBank;
use crate::noc::packet::{Packet, PacketType, PathStep, RouterId, StepOp};
use crate::noc::{exchange, trees, Mesh};
use crate::sim::{CostCounts, OpCost};
use crate::sram::bank::{SramBank, WeightPolicy};
use crate::util::bf16::bf16_round;

use super::row::{AccessDir, Addr, ArgSrc, ExchangeMode, RowInst, RowProgram};
use super::translate::{plan, FusedChain, Plan};

/// Per-bank memory capacity ceiling in elements (the interpreter is for
/// validation-scale programs; storage grows lazily on first touch — §Perf:
/// eagerly zeroing 16 banks x 64K elements dominated Machine::new with page
/// faults).
pub const BANK_MEM_ELEMS: usize = 1 << 16;

/// The interpreter machine for one CompAir channel.
pub struct Machine {
    pub hw: HwConfig,
    pub gang: SramGang,
    pub n_banks: usize,
    /// Flat per-bank element memory.
    pub mem: Vec<Vec<f32>>,
    pub mesh: Mesh,
    /// Per-bank loaded SRAM gang weights: (out, in, row-major weights).
    sram_loaded: Vec<Option<(usize, usize, Vec<f32>)>>,
    dram: PimBank,
    sram: SramBank,
}

impl Machine {
    pub fn new(hw: &HwConfig, gang: SramGang) -> Self {
        let n_banks = hw.dram.banks_per_channel;
        Self {
            hw: hw.clone(),
            gang,
            n_banks,
            mem: vec![Vec::new(); n_banks],
            mesh: Mesh::new(&hw.noc),
            sram_loaded: vec![None; n_banks],
            dram: PimBank::new(&hw.dram),
            sram: SramBank::new(&hw.sram, gang, &hw.dram),
        }
    }

    fn ensure(&mut self, bank: usize, end: Addr) {
        assert!(end <= BANK_MEM_ELEMS, "address {end} beyond bank memory model");
        if self.mem[bank].len() < end {
            self.mem[bank].resize(end, 0.0);
        }
    }

    pub fn write_row(&mut self, bank: usize, addr: Addr, data: &[f32]) {
        self.ensure(bank, addr + data.len());
        for (i, &v) in data.iter().enumerate() {
            self.mem[bank][addr + i] = bf16_round(v);
        }
    }

    pub fn read_row(&self, bank: usize, addr: Addr, len: usize) -> Vec<f32> {
        // reads of never-written space see zeros (fresh DRAM model)
        let mem = &self.mem[bank];
        (addr..addr + len).map(|i| mem.get(i).copied().unwrap_or(0.0)).collect()
    }

    /// Read one element (hot path inside chain waves).
    #[inline]
    fn rd1(&self, bank: usize, addr: Addr) -> f32 {
        self.mem[bank].get(addr).copied().unwrap_or(0.0)
    }

    /// Write one element (hot path inside chain waves).
    #[inline]
    fn wr1(&mut self, bank: usize, addr: Addr, v: f32) {
        self.ensure(bank, addr + 1);
        self.mem[bank][addr] = v;
    }

    fn active_banks(&self, mask: u64) -> Vec<usize> {
        (0..self.n_banks).filter(|b| mask >> b & 1 == 1).collect()
    }

    /// Execute a program; `fuse` toggles path generation (Fig 23's levers).
    pub fn run(&mut self, prog: &RowProgram, fuse: bool) -> OpCost {
        // Debug builds front-load the static linter: a program the checker
        // rejects must not reach the interpreter's scattered asserts.
        // (Structural checks only — callers may have pre-written any row,
        // so def-use facts are unknowable here.)
        #[cfg(debug_assertions)]
        {
            let mut opts = crate::analysis::isa_lint::LintOptions::assume_initialized();
            opts.fuse = fuse;
            let lint = crate::analysis::isa_lint::lint(prog, &self.hw, self.gang, &opts);
            assert!(
                lint.is_clean(),
                "static ISA lint rejected the program:\n{}",
                lint.render_brief()
            );
        }
        let plans = plan(&prog.insts, fuse);
        let mut cost = OpCost::zero();
        for p in &plans {
            let c = match p {
                Plan::Chain(chain) => self.run_chain(chain),
                Plan::Other(inst) => self.run_other(inst),
            };
            cost = cost.then(&c);
        }
        cost
    }

    /// Execute one fused scalar chain on the mesh, wave by wave.
    fn run_chain(&mut self, chain: &FusedChain) -> OpCost {
        let banks = self.active_banks(chain.mask);
        if banks.is_empty() || chain.len == 0 {
            return OpCost::zero();
        }
        let cols = self.hw.noc.mesh_cols;
        let width = chain.lane_width();
        let lanes_per_bank = (cols / width).max(1);
        let configs = chain.alu_configs();

        // DRAM: read the source row once per bank (fused chains hit DRAM at
        // the endpoints only); per-element Row args are read in the same
        // streaming pass.
        let n_row_args =
            chain.steps.iter().filter(|(_, a, ..)| matches!(a, ArgSrc::Row(_))).count();
        let rd_bytes = (chain.len * 2 * (1 + n_row_args)) as u64;
        let mut cost = self.dram.read(rd_bytes).replicate(banks.len() as u64);

        // Static Imm configs: once per (bank, lane) over the local port.
        let mut config_flits = 0u64;
        for &b in &banks {
            for lane in 0..lanes_per_bank {
                let base = lane * width;
                for (ci, alu, arg, iter_op, iter_arg) in &configs {
                    if let ArgSrc::Imm(v) = arg {
                        self.mesh.configure_alu(
                            RouterId::new((base + ci) % cols, b),
                            *alu,
                            *v,
                            *iter_op,
                            *iter_arg,
                        );
                        config_flits += 1;
                    }
                }
            }
        }
        cost = cost.then(&OpCost {
            latency_ns: configs.len() as f64 * self.hw.noc.cycle_ns,
            counts: CostCounts { noc_flit_hops: config_flits, ..Default::default() },
        });

        // Waves: one element per (bank, lane) per wave.
        let needs_iter_reset = configs.iter().any(|(_, _, a, _, _)| {
            matches!(a, ArgSrc::Imm(_))
        }) && chain.steps.iter().any(|(_, _, it, _, _)| *it);
        let waves = chain.len.div_ceil(lanes_per_bank);
        for w in 0..waves {
            let mut tags: Vec<(u64, usize, usize)> = Vec::new(); // (pkt, bank, elem)
            for &b in &banks {
                for lane in 0..lanes_per_bank {
                    let e = w * lanes_per_bank + lane;
                    if e >= chain.len {
                        continue;
                    }
                    let base = lane * width;
                    // Reset iterating Imm ArgRegs for this element.
                    if w > 0 && needs_iter_reset {
                        for (ci, alu, arg, iter_op, iter_arg) in &configs {
                            if let ArgSrc::Imm(v) = arg {
                                self.mesh.configure_alu(
                                    RouterId::new((base + ci) % cols, b),
                                    *alu,
                                    *v,
                                    *iter_op,
                                    *iter_arg,
                                );
                            }
                        }
                    }
                    // Per-element Row args: WrReg packets ahead of compute.
                    for (ci, alu, arg, _, _) in &configs {
                        if let ArgSrc::Row(row) = arg {
                            let at = RouterId::new((base + ci) % cols, b);
                            let val = self.rd1(b, *row + e);
                            self.mesh.inject(Packet::new(
                                PacketType::Write,
                                at,
                                val,
                                vec![PathStep::write_reg(at, *alu as u8)],
                            ));
                        }
                    }
                    let path = chain.emit_path(b, base, cols);
                    let data = self.rd1(b, chain.src + e);
                    let pkt = Packet::new(PacketType::Scalar, path[0].at, data, path)
                        .with_iter(chain.iter_num);
                    tags.push((self.mesh.inject(pkt), b, e));
                }
            }
            cost = cost.then(&self.mesh.run(1_000_000));
            for d in self.mesh.take_deliveries() {
                if let Some((_, b, e)) = tags.iter().find(|(id, _, _)| *id == d.packet_id) {
                    self.wr1(*b, chain.dst + e, d.value);
                }
            }
        }

        // DRAM: write the destination row once per bank.
        cost.then(&self.dram.write((chain.len * 2) as u64).replicate(banks.len() as u64))
    }

    fn run_other(&mut self, inst: &RowInst) -> OpCost {
        match inst {
            RowInst::Fill { dst, mask, len, value } => {
                let banks = self.active_banks(*mask);
                for &b in &banks {
                    self.ensure(b, dst + *len);
                    for i in 0..*len {
                        self.mem[b][dst + i] = bf16_round(*value);
                    }
                }
                self.dram.write((*len * 2) as u64).replicate(banks.len() as u64)
            }
            RowInst::NocAccess { dir, addr, mask, alu, value } => {
                let banks = self.active_banks(*mask);
                match dir {
                    AccessDir::Wr => {
                        for &b in &banks {
                            for x in 0..self.hw.noc.mesh_cols {
                                self.mesh.configure_alu(
                                    RouterId::new(x, b),
                                    *alu as usize,
                                    *value,
                                    StepOp::Sub,
                                    0.0,
                                );
                            }
                        }
                    }
                    AccessDir::Rd => {
                        for &b in &banks {
                            let v = self.mesh.alu_arg(RouterId::new(0, b), *alu as usize);
                            self.wr1(b, *addr, v);
                        }
                    }
                }
                OpCost {
                    latency_ns: self.hw.noc.cycle_ns,
                    counts: CostCounts {
                        noc_flit_hops: banks.len() as u64,
                        ..Default::default()
                    },
                }
            }
            RowInst::NocBCast { src, dst, mask, src_bank, len } => {
                let banks = self.active_banks(*mask);
                let group = self.n_banks; // tree spans the channel
                let mut cost = self.dram.read((*len * 2) as u64);
                let cols = self.hw.noc.mesh_cols;
                for chunk in (0..*len).collect::<Vec<_>>().chunks(cols) {
                    let vals: Vec<f32> =
                        chunk.iter().map(|&e| self.rd1(*src_bank, src + e)).collect();
                    let r = trees::broadcast(&mut self.mesh, &vals, *src_bank, group);
                    for (col, bank, v) in &r.deliveries {
                        if banks.contains(bank) {
                            self.wr1(*bank, dst + chunk[*col], *v);
                        }
                    }
                    cost = cost.then(&r.cost);
                }
                // source bank keeps its own copy
                for e in 0..*len {
                    let v = self.rd1(*src_bank, src + e);
                    self.wr1(*src_bank, dst + e, v);
                }
                cost.then(&self.dram.write((*len * 2) as u64).replicate(banks.len() as u64))
            }
            RowInst::NocReduce { op, src, dst, mask, dst_bank, len } => {
                let banks = self.active_banks(*mask);
                let identity = match op {
                    StepOp::Add | StepOp::Sub => 0.0,
                    StepOp::Mul | StepOp::Div => 1.0,
                };
                let group = self.n_banks;
                let cols = self.hw.noc.mesh_cols;
                let mut cost = self.dram.read((*len * 2) as u64).replicate(banks.len() as u64);
                for chunk in (0..*len).collect::<Vec<_>>().chunks(cols) {
                    let per_col: Vec<Vec<f32>> = chunk
                        .iter()
                        .map(|&e| {
                            (0..group)
                                .map(|b| {
                                    if banks.contains(&b) {
                                        self.rd1(b, src + e)
                                    } else {
                                        identity
                                    }
                                })
                                .collect()
                        })
                        .collect();
                    let r = trees::reduce(&mut self.mesh, &per_col, *op, *dst_bank, group);
                    for (ci, &e) in chunk.iter().enumerate() {
                        self.wr1(*dst_bank, dst + e, r.values[ci]);
                    }
                    cost = cost.then(&r.cost);
                }
                cost.then(&self.dram.write((*len * 2) as u64))
            }
            RowInst::NocExchange { mode, src, dst, mask, offset, group, len } => {
                let banks = self.active_banks(*mask);
                match mode {
                    ExchangeMode::RMinus | ExchangeMode::RPlus => {
                        assert_eq!((*offset, *group), (1, 2), "row exchange supports pair swap");
                        for &b in &banks {
                            let x = self.read_row(b, *src, *len);
                            let out = if *mode == ExchangeMode::RMinus {
                                exchange::rope_rearrange(&x)
                            } else {
                                // plain pair swap
                                let mut o = x.clone();
                                for p in 0..*len / 2 {
                                    o.swap(2 * p, 2 * p + 1);
                                }
                                o
                            };
                            self.write_row(b, *dst, &out);
                        }
                        let per_bank = exchange::exchange_cost(*len, &self.hw.noc);
                        per_bank
                            .replicate(banks.len() as u64)
                            .then(&self.dram.read((*len * 2) as u64).replicate(banks.len() as u64))
                            .then(&self.dram.write((*len * 2) as u64).replicate(banks.len() as u64))
                    }
                    ExchangeMode::TMinus | ExchangeMode::TPlus => {
                        // Inter-bank exchange: bank b swaps its row with bank
                        // (b±offset) within groups of `group` banks.
                        let mut new_rows: Vec<(usize, Vec<f32>)> = Vec::new();
                        for &b in &banks {
                            let gbase = b / group * group;
                            let partner = gbase + (b - gbase + offset) % group;
                            let mut row = self.read_row(partner, *src, *len);
                            if *mode == ExchangeMode::TMinus && (b - gbase) % 2 == 0 {
                                for v in row.iter_mut() {
                                    *v = bf16_round(-*v);
                                }
                            }
                            new_rows.push((b, row));
                        }
                        for (b, row) in new_rows {
                            self.write_row(b, *dst, &row);
                        }
                        // cost: len scalars × hop distance `offset` through
                        // the column mesh, 4 columns wide
                        let hops = (*len as u64).div_ceil(4) * *offset as u64;
                        OpCost {
                            latency_ns: hops as f64 * self.hw.noc.cycle_ns,
                            counts: CostCounts {
                                noc_flit_hops: *len as u64 * *offset as u64 * banks.len() as u64,
                                ..Default::default()
                            },
                        }
                        .then(&self.dram.read((*len * 2) as u64).replicate(banks.len() as u64))
                        .then(&self.dram.write((*len * 2) as u64).replicate(banks.len() as u64))
                    }
                }
            }
            RowInst::SramWrite { addr, mask, len } => {
                let banks = self.active_banks(*mask);
                let (gi, go) = self.gang.shape(&self.hw.sram);
                assert!(*len <= gi * go, "gang holds {}x{} weights", go, gi);
                for &b in &banks {
                    let w = self.read_row(b, *addr, *len);
                    // shape resolved at SRAM_Compute (fixed gang dataflow:
                    // in = compute length, out = len / in)
                    self.sram_loaded[b] = Some((0, 0, w));
                }
                self.dram
                    .read_to_sram((*len * 2) as u64)
                    .replicate(banks.len() as u64)
            }
            RowInst::SramCompute { src, dst, mask, len } => {
                let banks = self.active_banks(*mask);
                let mut total = OpCost::zero();
                for &b in &banks {
                    let (_, _, w) =
                        self.sram_loaded[b].clone().expect("SRAM_Compute before SRAM_Write");
                    assert!(
                        w.len() % *len == 0,
                        "weight count {} not divisible by input length {len}",
                        w.len()
                    );
                    let (inp, out) = (*len, w.len() / *len);
                    let x = self.read_row(b, *src, *len);
                    let y = PimBank::gemv_f32(&w, &x, out, inp);
                    self.write_row(b, *dst, &y);
                    total = total.join(&self.sram.gemm(out, inp, 1, WeightPolicy::Resident));
                }
                total
            }
            RowInst::DramGemv { w, src, dst, mask, out_dim, in_dim } => {
                let banks = self.active_banks(*mask);
                let mut total = OpCost::zero();
                for &b in &banks {
                    let wm = self.read_row(b, *w, out_dim * in_dim);
                    let x = self.read_row(b, *src, *in_dim);
                    let y = PimBank::gemv_f32(&wm, &x, *out_dim, *in_dim);
                    self.write_row(b, *dst, &y);
                    total = total.join(&self.dram.gemv(*out_dim, *in_dim, 1));
                }
                total
            }
            RowInst::NocScalar { .. } => unreachable!("scalars are planned as chains"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::row::ALL_BANKS;
    use crate::noc::curry::curry_exp;

    fn machine() -> Machine {
        Machine::new(&HwConfig::paper(), SramGang::In256Out16)
    }

    #[test]
    fn fill_and_rows() {
        let mut m = machine();
        let c = m.run(
            &{
                let mut p = RowProgram::new();
                p.push(RowInst::Fill { dst: 4, mask: 0b11, len: 3, value: 2.5 });
                p
            },
            true,
        );
        assert_eq!(m.read_row(0, 4, 3), vec![2.5; 3]);
        assert_eq!(m.read_row(1, 4, 3), vec![2.5; 3]);
        assert_eq!(m.read_row(2, 4, 3), vec![0.0; 3]);
        assert!(c.latency_ns > 0.0);
    }

    #[test]
    fn scalar_add_applies_per_bank() {
        let mut m = machine();
        m.write_row(0, 0, &[1.0, 2.0, 3.0, 4.0]);
        m.write_row(5, 0, &[10.0, 20.0, 30.0, 40.0]);
        let mut p = RowProgram::new();
        p.push(RowInst::scalar(StepOp::Add, 0, 100, 4, 0.5));
        m.run(&p, true);
        assert_eq!(m.read_row(0, 100, 4), vec![1.5, 2.5, 3.5, 4.5]);
        assert_eq!(m.read_row(5, 100, 4), vec![10.5, 20.5, 30.5, 40.5]);
    }

    #[test]
    fn exp_program_matches_curry_reference() {
        let mut m = machine();
        let xs = [0.5f32, -0.25, 1.0, 0.125];
        m.write_row(2, 0, &xs);
        let p = RowProgram::exp_program(0, 500, xs.len(), 6, 1 << 2);
        m.run(&p, true);
        let got = m.read_row(2, 500, xs.len());
        for (i, &x) in xs.iter().enumerate() {
            let expect = curry_exp(x, 6);
            assert_eq!(got[i], expect, "elem {i}: x={x}");
        }
    }

    #[test]
    fn fused_and_unfused_agree_functionally() {
        let xs = [0.3f32, -0.6, 0.9, -1.2, 0.1, 0.7];
        let run = |fuse: bool| {
            let mut m = machine();
            m.write_row(1, 0, &xs);
            let p = RowProgram::exp_program(0, 500, xs.len(), 5, 1 << 1);
            let c = m.run(&p, fuse);
            (m.read_row(1, 500, xs.len()), c)
        };
        let (v_fused, c_fused) = run(true);
        let (v_base, c_base) = run(false);
        assert_eq!(v_fused, v_base, "fusion must not change results");
        // Fig 23: path generation saves 33-50% latency.
        let saving = 1.0 - c_fused.latency_ns / c_base.latency_ns;
        assert!(saving > 0.30, "path generation saving too small: {saving:.3}");
    }

    #[test]
    fn reduce_program() {
        let mut m = machine();
        for b in 0..16 {
            m.write_row(b, 0, &[b as f32, 1.0]);
        }
        let mut p = RowProgram::new();
        p.push(RowInst::NocReduce {
            op: StepOp::Add,
            src: 0,
            dst: 50,
            mask: ALL_BANKS,
            dst_bank: 3,
            len: 2,
        });
        m.run(&p, true);
        assert_eq!(m.read_row(3, 50, 2), vec![120.0, 16.0]);
    }

    #[test]
    fn broadcast_program() {
        let mut m = machine();
        m.write_row(7, 10, &[3.25, -1.5, 8.0]);
        let mut p = RowProgram::new();
        p.push(RowInst::NocBCast { src: 10, dst: 20, mask: ALL_BANKS, src_bank: 7, len: 3 });
        m.run(&p, true);
        for b in 0..16 {
            assert_eq!(m.read_row(b, 20, 3), vec![3.25, -1.5, 8.0], "bank {b}");
        }
    }

    #[test]
    fn rope_exchange_program() {
        let mut m = machine();
        let x: Vec<f32> = (1..=8).map(|i| i as f32).collect();
        m.write_row(4, 0, &x);
        let mut p = RowProgram::new();
        p.push(RowInst::rope_exchange(0, 64, 8));
        m.run(&p, true);
        assert_eq!(m.read_row(4, 64, 8), exchange::rope_rearrange(&x));
    }

    #[test]
    fn sram_write_then_compute() {
        let mut m = machine();
        // 4 outputs × 8 inputs weight tile in bank 0
        let w: Vec<f32> = (0..32).map(|i| (i % 5) as f32 * 0.25).collect();
        let x: Vec<f32> = (0..8).map(|i| i as f32 * 0.5).collect();
        m.write_row(0, 0, &w);
        m.write_row(0, 100, &x);
        let mut p = RowProgram::new();
        p.push(RowInst::SramWrite { addr: 0, mask: 1, len: 32 });
        p.push(RowInst::SramCompute { src: 100, dst: 200, mask: 1, len: 8 });
        m.run(&p, true);
        let got = m.read_row(0, 200, 4);
        let expect = PimBank::gemv_f32(&w, &x, 4, 8);
        assert_eq!(got, expect);
    }

    #[test]
    #[should_panic(expected = "SRAM_Compute before SRAM_Write")]
    fn sram_compute_requires_weights() {
        let mut m = machine();
        let mut p = RowProgram::new();
        p.push(RowInst::SramCompute { src: 0, dst: 8, mask: 1, len: 8 });
        m.run(&p, true);
    }

    #[test]
    fn dram_gemv_program() {
        let mut m = machine();
        let w = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]; // 3x2
        let x = vec![2.0, 3.0];
        m.write_row(0, 0, &w);
        m.write_row(0, 10, &x);
        let mut p = RowProgram::new();
        p.push(RowInst::DramGemv { w: 0, src: 10, dst: 20, mask: 1, out_dim: 3, in_dim: 2 });
        m.run(&p, true);
        assert_eq!(m.read_row(0, 20, 3), vec![2.0, 3.0, 5.0]);
    }

    #[test]
    fn inter_bank_exchange() {
        let mut m = machine();
        m.write_row(0, 0, &[1.0, 2.0]);
        m.write_row(1, 0, &[3.0, 4.0]);
        let mut p = RowProgram::new();
        p.push(RowInst::NocExchange {
            mode: ExchangeMode::TPlus,
            src: 0,
            dst: 32,
            mask: 0b11,
            offset: 1,
            group: 2,
            len: 2,
        });
        m.run(&p, true);
        assert_eq!(m.read_row(0, 32, 2), vec![3.0, 4.0]);
        assert_eq!(m.read_row(1, 32, 2), vec![1.0, 2.0]);
    }
}
