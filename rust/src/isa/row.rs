//! Row-Level ISA (paper Table 1) — the SIMD programming interface exposed to
//! the user. Instructions are issued at DRAM-bank granularity: every masked
//! bank executes the same instruction on its own rows.

use crate::noc::StepOp;

/// Where a NoC_Scalar's ArgReg value comes from: an immediate shared by all
/// elements (the Config/Const NUM2 field), or a per-element value loaded
/// from a bank row (the exponential's per-scalar `x`).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgSrc {
    Imm(f32),
    Row(usize),
}

/// A bank-relative scalar address (flattened DRAM row/column offset in
/// elements; the interpreter gives each bank a flat BF16 element space).
pub type Addr = usize;

/// Read/Write selector of NoC_Access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessDir {
    Rd,
    Wr,
}

/// NoC_Exchange mode: T = inter-bank, R = intra-row; +/- = whether the
/// value landing on the even slot is negated (RoPE needs '-').
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    TPlus,
    TMinus,
    RPlus,
    RMinus,
}

/// Bank participation mask (bit b = bank b of the channel; the paper's
/// 64-bit router mask at 4 routers/bank collapses to 16 bank bits here,
/// with router fan-out chosen by the translator).
pub type Mask = u64;

pub const ALL_BANKS: Mask = 0xFFFF;

/// One Row-Level instruction (Table 1).
#[derive(Debug, Clone, PartialEq)]
pub enum RowInst {
    /// One in-transit computation per masked bank:
    /// `dst[i] = src[i] (op) arg` for `len` scalars, through the bank's
    /// routers. `iter_tag` requests the ArgReg-update mode with
    /// (`iter_op`, `iter_arg`) — Fig 13's dynamic arguments.
    NocScalar {
        op: StepOp,
        src: Addr,
        dst: Addr,
        mask: Mask,
        len: usize,
        arg: ArgSrc,
        iter_tag: bool,
        iter_op: StepOp,
        iter_arg: f32,
    },
    /// Read or write Curry-ALU registers directly.
    NocAccess { dir: AccessDir, addr: Addr, mask: Mask, alu: u8, value: f32 },
    /// Broadcast `len` scalars from `src_bank`'s `src` to every masked
    /// bank's `dst` through the broadcast tree.
    NocBCast { src: Addr, dst: Addr, mask: Mask, src_bank: usize, len: usize },
    /// Reduce `len` scalars element-wise across masked banks into
    /// `dst_bank`'s `dst` through the reduce tree.
    NocReduce { op: StepOp, src: Addr, dst: Addr, mask: Mask, dst_bank: usize, len: usize },
    /// Data exchange: position x swaps with (x+offset)%group; '-' modes
    /// negate the value landing on the lower slot (RoPE: offset=1, group=2).
    NocExchange { mode: ExchangeMode, src: Addr, dst: Addr, mask: Mask, offset: usize, group: usize, len: usize },
    /// Load `len` BF16 weights from `addr` into the bank's SRAM-PIM gang
    /// (row-major `out × in` for the gang shape).
    SramWrite { addr: Addr, mask: Mask, len: usize },
    /// Feed `len` inputs from `src` through the gang, write the gang's
    /// outputs at `dst`.
    SramCompute { src: Addr, dst: Addr, mask: Mask, len: usize },
    /// DRAM-PIM bank-local GeMV (the baseline MAC path): weights at `w`
    /// (`out×in` row-major), input vector at `src`, result at `dst`.
    DramGemv { w: Addr, src: Addr, dst: Addr, mask: Mask, out_dim: usize, in_dim: usize },
    /// Fill `len` elements at `dst` with a constant (bank-local write).
    Fill { dst: Addr, mask: Mask, len: usize, value: f32 },
}

impl RowInst {
    /// Convenience: a simple NoC_Scalar with a static immediate ArgReg.
    pub fn scalar(op: StepOp, src: Addr, dst: Addr, len: usize, arg: f32) -> RowInst {
        RowInst::NocScalar {
            op,
            src,
            dst,
            mask: ALL_BANKS,
            len,
            arg: ArgSrc::Imm(arg),
            iter_tag: false,
            iter_op: StepOp::Sub,
            iter_arg: 0.0,
        }
    }

    /// The RoPE rearrangement as written in the paper (§5.1):
    /// `NoC_Exchange(R-, SrcRow, DstRow, 1, 2)`.
    pub fn rope_exchange(src: Addr, dst: Addr, len: usize) -> RowInst {
        RowInst::NocExchange {
            mode: ExchangeMode::RMinus,
            src,
            dst,
            mask: ALL_BANKS,
            offset: 1,
            group: 2,
            len,
        }
    }

    pub fn mask(&self) -> Mask {
        match self {
            RowInst::NocScalar { mask, .. }
            | RowInst::NocAccess { mask, .. }
            | RowInst::NocBCast { mask, .. }
            | RowInst::NocReduce { mask, .. }
            | RowInst::NocExchange { mask, .. }
            | RowInst::SramWrite { mask, .. }
            | RowInst::SramCompute { mask, .. }
            | RowInst::DramGemv { mask, .. }
            | RowInst::Fill { mask, .. } => *mask,
        }
    }

    pub fn is_noc_scalar(&self) -> bool {
        matches!(self, RowInst::NocScalar { .. })
    }
}

/// A row-level program.
#[derive(Debug, Clone, Default)]
pub struct RowProgram {
    pub insts: Vec<RowInst>,
}

impl RowProgram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, i: RowInst) -> &mut Self {
        self.insts.push(i);
        self
    }

    /// The Fig 13 / Fig 14B exponential over `len` scalars at `x_row`:
    /// result = exp(x) via `rounds` Horner iterations of
    /// {*=x → /=k (k−=1) → +=1}, written as 3×rounds chained NoC_Scalar
    /// instructions — the conservative SIMD form the user writes, which the
    /// translator's path generation fuses into one iterated packet.
    /// The running value `t` starts at 1.0 (a Fill) and ping-pongs through
    /// scratch rows; the Mul's ArgReg is loaded per element from `x_row`.
    pub fn exp_program(x_row: Addr, dst: Addr, len: usize, rounds: u32, mask: Mask) -> RowProgram {
        let mut p = RowProgram::new();
        let scratch = |i: usize| dst + 1024 + i * 16;
        p.push(RowInst::Fill { dst: scratch(0), mask, len, value: 1.0 });
        let mut cur = scratch(0);
        let mut k = rounds as f32;
        let mut idx = 1;
        for r in 0..rounds {
            let last = r + 1 == rounds;
            let nxt = scratch(idx);
            p.push(RowInst::NocScalar {
                op: StepOp::Mul,
                src: cur,
                dst: nxt,
                mask,
                len,
                arg: ArgSrc::Row(x_row),
                iter_tag: false,
                iter_op: StepOp::Sub,
                iter_arg: 0.0,
            });
            cur = nxt;
            idx += 1;
            let nxt = scratch(idx);
            p.push(RowInst::NocScalar {
                op: StepOp::Div,
                src: cur,
                dst: nxt,
                mask,
                len,
                arg: ArgSrc::Imm(k),
                iter_tag: true,
                iter_op: StepOp::Sub,
                iter_arg: 1.0,
            });
            cur = nxt;
            idx += 1;
            let nxt = if last { dst } else { scratch(idx) };
            p.push(RowInst::NocScalar {
                op: StepOp::Add,
                src: cur,
                dst: nxt,
                mask,
                len,
                arg: ArgSrc::Imm(1.0),
                iter_tag: false,
                iter_op: StepOp::Sub,
                iter_arg: 0.0,
            });
            cur = nxt;
            idx += 1;
            k -= 1.0;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_program_shape() {
        let p = RowProgram::exp_program(0, 100, 4, 6, ALL_BANKS);
        assert_eq!(p.insts.len(), 19); // Fill + 18 scalars
        assert!(p.insts[1..].iter().all(|i| i.is_noc_scalar()));
        // chain property: dst of i == src of i+1
        for w in p.insts[1..].windows(2) {
            let (d1, s2) = match (&w[0], &w[1]) {
                (RowInst::NocScalar { dst, .. }, RowInst::NocScalar { src, .. }) => (*dst, *src),
                _ => unreachable!(),
            };
            assert_eq!(d1, s2);
        }
    }

    #[test]
    fn rope_exchange_encoding() {
        let i = RowInst::rope_exchange(5, 9, 128);
        match i {
            RowInst::NocExchange { mode, offset, group, .. } => {
                assert_eq!(mode, ExchangeMode::RMinus);
                assert_eq!(offset, 1);
                assert_eq!(group, 2);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn masks_accessible() {
        let i = RowInst::scalar(StepOp::Add, 0, 1, 4, 2.0);
        assert_eq!(i.mask(), ALL_BANKS);
    }
}
