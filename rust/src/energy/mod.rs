//! Energy accounting: prices `CostCounts` into picojoules.
pub mod model;

pub use model::{EnergyBreakdown, EnergyModel};
