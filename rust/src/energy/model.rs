//! Per-event energy constants and the pricing model.
//!
//! Constants and provenance (all pJ):
//! * DRAM (GDDR6-PIM class): row ACT ≈ 2 nJ/1KB row; *near-array* column
//!   access to the bank's own MAC lanes ≈ 0.25 pJ/bit over 32 B = 64 pJ —
//!   the PIM datapath sits right behind the column decoder and skips the
//!   global I/O wires (this locality is where PIM's energy win comes from;
//!   movement beyond the bank is priced via gb/cxl bytes). BF16 MAC ≈
//!   0.6 pJ.
//! * SRAM-PIM: derived from the configured voltage's TFLOPS/W
//!   (14.4–31.6 ⇒ 0.063–0.139 pJ/flop); array row write ≈ 50 pJ.
//! * Hybrid bonding: 0.05–0.88 pJ/bit (we default 0.3) — the >200× vs
//!   off-chip HBM advantage the paper cites.
//! * NoC: ≈ 0.1 pJ/bit/hop at 28nm ⇒ 7.2 pJ per 72b flit-hop; Curry ALU op
//!   ≈ 2 pJ (BF16 datapath).
//! * Global buffer: shared-bus transfer ≈ 2 pJ/bit = 16 pJ/B.
//! * CXL/PCIe-class off-package link ≈ 7.5 pJ/bit = 60 pJ/B.
//! * Centralized NLU scalar op ≈ 50 pJ (includes instruction/control
//!   overhead of the controller CPU path).
//! * A100: 300 W / 312 TFLOPS BF16 ⇒ ~0.96 pJ/flop; HBM2e system-level
//!   access (array + TSV + PHY + controller) ≈ 10 pJ/bit = 80 pJ/B.
//! * Static power: per-device controller+periphery for PIM devices, full
//!   board power modelled on the GPU side of AttAcc.

use crate::config::SramConfig;
use crate::sim::{CostCounts, OpCost};
use crate::util::json::{Json, ToJson};

/// `CostCounts` fields that are deliberately *not* priced: pure
/// bookkeeping duplicates of events whose energy is billed elsewhere.
/// `sram_access` counts macro activations whose MACs are already priced
/// per-op through `sram_mac` (one access = inputs×outputs MACs); pricing
/// both would double-bill the array. The prove pricing-coverage pass
/// accepts exactly this list as unpriced.
pub const UNPRICED_BOOKKEEPING: &[&str] = &["sram_access"];

/// Energy broken down by component (pJ).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub dram_pj: f64,
    pub sram_pj: f64,
    pub hb_pj: f64,
    pub noc_pj: f64,
    pub gb_pj: f64,
    pub cxl_pj: f64,
    pub nlu_pj: f64,
    pub gpu_pj: f64,
    pub static_pj: f64,
}

impl EnergyBreakdown {
    /// Every component as a `(name, pJ)` pair, in declaration order — the
    /// one registry behind `total_pj`, the JSON rendering, and the semantic
    /// auditor's per-component sweeps (`analysis/audit.rs`), so a new
    /// component cannot silently escape any of them.
    pub fn components(&self) -> [(&'static str, f64); 9] {
        [
            ("dram_pj", self.dram_pj),
            ("sram_pj", self.sram_pj),
            ("hb_pj", self.hb_pj),
            ("noc_pj", self.noc_pj),
            ("gb_pj", self.gb_pj),
            ("cxl_pj", self.cxl_pj),
            ("nlu_pj", self.nlu_pj),
            ("gpu_pj", self.gpu_pj),
            ("static_pj", self.static_pj),
        ]
    }

    pub fn total_pj(&self) -> f64 {
        self.components().iter().map(|(_, pj)| pj).sum()
    }

    pub fn add(&self, o: &EnergyBreakdown) -> EnergyBreakdown {
        EnergyBreakdown {
            dram_pj: self.dram_pj + o.dram_pj,
            sram_pj: self.sram_pj + o.sram_pj,
            hb_pj: self.hb_pj + o.hb_pj,
            noc_pj: self.noc_pj + o.noc_pj,
            gb_pj: self.gb_pj + o.gb_pj,
            cxl_pj: self.cxl_pj + o.cxl_pj,
            nlu_pj: self.nlu_pj + o.nlu_pj,
            gpu_pj: self.gpu_pj + o.gpu_pj,
            static_pj: self.static_pj + o.static_pj,
        }
    }

    pub fn scale(&self, k: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            dram_pj: self.dram_pj * k,
            sram_pj: self.sram_pj * k,
            hb_pj: self.hb_pj * k,
            noc_pj: self.noc_pj * k,
            gb_pj: self.gb_pj * k,
            cxl_pj: self.cxl_pj * k,
            nlu_pj: self.nlu_pj * k,
            gpu_pj: self.gpu_pj * k,
            static_pj: self.static_pj * k,
        }
    }
}

impl ToJson for EnergyBreakdown {
    fn to_json(&self) -> Json {
        self.components()
            .iter()
            .fold(Json::obj(), |j, (name, pj)| j.field(name, *pj))
            .field("total_pj", self.total_pj())
    }
}

/// The pricing model.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub dram_act_pj: f64,
    pub dram_col_pj: f64,
    pub dram_mac_pj: f64,
    pub sram_mac_pj: f64,
    pub sram_row_write_pj: f64,
    pub hb_pj_per_bit: f64,
    pub noc_hop_pj: f64,
    pub noc_alu_pj: f64,
    pub gb_pj_per_byte: f64,
    pub cxl_pj_per_byte: f64,
    pub nlu_op_pj: f64,
    pub gpu_flop_pj: f64,
    pub gpu_hbm_pj_per_byte: f64,
    /// Static power of one PIM device (controller, clocking, periphery), W.
    pub pim_device_static_w: f64,
    /// Static power of one A100 board at inference load baseline, W.
    pub gpu_static_w: f64,
}

impl EnergyModel {
    /// Build from the SRAM voltage point and HB configuration.
    pub fn new(sram: &SramConfig, hb_pj_per_bit: f64) -> Self {
        Self {
            dram_act_pj: 2000.0,
            dram_col_pj: 64.0,
            dram_mac_pj: 0.6,
            sram_mac_pj: sram.pj_per_mac(),
            sram_row_write_pj: 50.0,
            hb_pj_per_bit,
            noc_hop_pj: 7.2,
            noc_alu_pj: 2.0,
            gb_pj_per_byte: 16.0,
            cxl_pj_per_byte: 60.0,
            nlu_op_pj: 50.0,
            gpu_flop_pj: 0.96,
            gpu_hbm_pj_per_byte: 80.0,
            pim_device_static_w: 4.0,
            // A100 board floor under inference load (HBM refresh, NVLink,
            // regulators, non-tensor logic) — the paper's AttAcc energy gap
            // comes largely from this fixed cost
            gpu_static_w: 180.0,
        }
    }

    /// Price dynamic events only.
    pub fn dynamic(&self, c: &CostCounts) -> EnergyBreakdown {
        EnergyBreakdown {
            dram_pj: c.dram_act as f64 * self.dram_act_pj
                + (c.dram_col_rd + c.dram_col_wr) as f64 * self.dram_col_pj
                + c.dram_mac as f64 * self.dram_mac_pj,
            sram_pj: c.sram_mac as f64 * self.sram_mac_pj
                + c.sram_row_write as f64 * self.sram_row_write_pj,
            hb_pj: c.hb_bytes as f64 * 8.0 * self.hb_pj_per_bit,
            noc_pj: c.noc_flit_hops as f64 * self.noc_hop_pj
                + c.noc_alu_ops as f64 * self.noc_alu_pj,
            gb_pj: c.gb_bytes as f64 * self.gb_pj_per_byte,
            cxl_pj: c.cxl_bytes as f64 * self.cxl_pj_per_byte,
            nlu_pj: c.nlu_ops as f64 * self.nlu_op_pj,
            gpu_pj: c.gpu_flop as f64 * self.gpu_flop_pj
                + c.gpu_hbm_bytes as f64 * self.gpu_hbm_pj_per_byte,
            static_pj: 0.0,
        }
    }

    /// The declarative mirror of [`Self::dynamic`]: which breakdown
    /// component prices each `CostCounts` field. `compair prove`'s
    /// pricing-coverage pass joins this against `CostCounts::fields()`
    /// and [`UNPRICED_BOOKKEEPING`] so a new counter cannot silently
    /// escape the energy model (`prv.unpriced-counter`) and no counter is
    /// billed twice (`prv.double-priced`); the liveness test below keeps
    /// this list from drifting away from the arithmetic in `dynamic`.
    pub fn pricing_rules() -> Vec<(&'static str, &'static str)> {
        vec![
            ("dram_act", "dram_pj"),
            ("dram_col_rd", "dram_pj"),
            ("dram_col_wr", "dram_pj"),
            ("dram_mac", "dram_pj"),
            ("sram_mac", "sram_pj"),
            ("sram_row_write", "sram_pj"),
            ("hb_bytes", "hb_pj"),
            ("noc_flit_hops", "noc_pj"),
            ("noc_alu_ops", "noc_pj"),
            ("gb_bytes", "gb_pj"),
            ("cxl_bytes", "cxl_pj"),
            ("nlu_ops", "nlu_pj"),
            ("gpu_flop", "gpu_pj"),
            ("gpu_hbm_bytes", "gpu_pj"),
        ]
    }

    /// Price a full phase: dynamic events + static power over the phase
    /// latency for the given device counts.
    pub fn phase(&self, cost: &OpCost, pim_devices: usize, gpus: usize) -> EnergyBreakdown {
        let mut e = self.dynamic(&cost.counts);
        e.static_pj = cost.latency_ns
            * (pim_devices as f64 * self.pim_device_static_w
                + gpus as f64 * self.gpu_static_w);
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SramConfig, Voltage};

    fn model() -> EnergyModel {
        EnergyModel::new(&SramConfig::default(), 0.3)
    }

    #[test]
    fn pricing_is_linear() {
        let m = model();
        let c = CostCounts { dram_act: 2, dram_mac: 1000, hb_bytes: 64, ..Default::default() };
        let e1 = m.dynamic(&c);
        let e2 = m.dynamic(&c.scale(3));
        assert!((e2.total_pj() - 3.0 * e1.total_pj()).abs() < 1e-9);
    }

    #[test]
    fn sram_mac_cheaper_than_dram_mac_path() {
        // The motivation: SRAM-PIM is an order of magnitude more efficient
        // per MAC than the DRAM path once col access energy is included.
        let m = model();
        let dram = CostCounts { dram_col_rd: 1, dram_mac: 16, ..Default::default() };
        let sram = CostCounts { sram_mac: 16, ..Default::default() };
        assert!(m.dynamic(&dram).total_pj() > 10.0 * m.dynamic(&sram).total_pj());
    }

    #[test]
    fn hb_far_cheaper_than_cxl() {
        let m = model();
        let hb = CostCounts { hb_bytes: 1024, ..Default::default() };
        let cxl = CostCounts { cxl_bytes: 1024, ..Default::default() };
        assert!(m.dynamic(&cxl).total_pj() > 20.0 * m.dynamic(&hb).total_pj());
    }

    #[test]
    fn low_voltage_sram_is_more_efficient() {
        let mut s = SramConfig::default();
        s.voltage = Voltage(0.6);
        let lo = EnergyModel::new(&s, 0.3);
        s.voltage = Voltage(0.9);
        let hi = EnergyModel::new(&s, 0.3);
        assert!(lo.sram_mac_pj < hi.sram_mac_pj);
    }

    #[test]
    fn static_energy_scales_with_time_and_devices() {
        let m = model();
        let c = OpCost::latency(1000.0);
        let e8 = m.phase(&c, 8, 0);
        let e32 = m.phase(&c, 32, 0);
        assert!((e32.static_pj / e8.static_pj - 4.0).abs() < 1e-9);
        // W × ns = pJ·1e0: 4 W × 1000 ns × 8 devices = 32000 pJ
        assert!((e8.static_pj - 32_000.0).abs() < 1e-9);
    }

    #[test]
    fn pricing_rules_mirror_dynamic_exactly() {
        // liveness: bumping a counter listed in pricing_rules must move
        // exactly the component the rule names (and only it); bumping a
        // bookkeeping counter must move nothing
        let m = model();
        let base = m.dynamic(&CostCounts::default());
        for (field, component) in EnergyModel::pricing_rules() {
            let mut c = CostCounts::default();
            match field {
                "dram_act" => c.dram_act = 1,
                "dram_col_rd" => c.dram_col_rd = 1,
                "dram_col_wr" => c.dram_col_wr = 1,
                "dram_mac" => c.dram_mac = 1,
                "sram_mac" => c.sram_mac = 1,
                "sram_row_write" => c.sram_row_write = 1,
                "hb_bytes" => c.hb_bytes = 1,
                "noc_flit_hops" => c.noc_flit_hops = 1,
                "noc_alu_ops" => c.noc_alu_ops = 1,
                "gb_bytes" => c.gb_bytes = 1,
                "cxl_bytes" => c.cxl_bytes = 1,
                "nlu_ops" => c.nlu_ops = 1,
                "gpu_flop" => c.gpu_flop = 1,
                "gpu_hbm_bytes" => c.gpu_hbm_bytes = 1,
                other => panic!("rule names unknown field {other}"),
            }
            assert!(
                c.fields().iter().any(|(n, v)| *n == field && *v == 1),
                "{field} is not a registered CostCounts field"
            );
            let e = m.dynamic(&c);
            for ((name, pj), (_, base_pj)) in e.components().iter().zip(base.components()) {
                if *name == component {
                    assert!(*pj > *base_pj, "{field} must move {component}");
                } else {
                    assert_eq!(*pj, base_pj, "{field} must not move {name}");
                }
            }
        }
        // bookkeeping counters price to zero
        for field in UNPRICED_BOOKKEEPING {
            assert_eq!(*field, "sram_access", "update this test with the new field");
            let c = CostCounts { sram_access: 1_000_000, ..Default::default() };
            assert_eq!(m.dynamic(&c).total_pj(), 0.0);
        }
    }

    #[test]
    fn breakdown_sums_to_total() {
        let m = model();
        let c = CostCounts {
            dram_act: 1,
            dram_col_rd: 2,
            dram_mac: 3,
            sram_mac: 4,
            sram_row_write: 5,
            hb_bytes: 6,
            noc_flit_hops: 7,
            noc_alu_ops: 8,
            gb_bytes: 9,
            cxl_bytes: 10,
            nlu_ops: 11,
            gpu_flop: 12,
            gpu_hbm_bytes: 13,
            dram_col_wr: 14,
            sram_access: 15,
        };
        let e = m.dynamic(&c);
        let manual = e.dram_pj + e.sram_pj + e.hb_pj + e.noc_pj + e.gb_pj + e.cxl_pj + e.nlu_pj + e.gpu_pj;
        assert!((e.total_pj() - manual).abs() < 1e-9);
    }
}
