//! The `compair` launcher: figure regeneration, one-shot simulation,
//! serving simulation, and the hierarchical-ISA demo — all through the
//! [`Engine`] facade, with `--format json` emitting machine-readable
//! reports on every subcommand.

use compair::analysis;
use compair::cli::{self, Args, OutputFormat, USAGE};
use compair::config::{ArchKind, MappingMode, ModelConfig, NocFidelity, Phase, RunConfig};
use compair::coordinator::{cluster, serving, ClusterConfig, RouterPolicy, ServeConfig};
use compair::figures;
use compair::figures::FigCtx;
use compair::isa::{Machine, RowProgram};
use compair::util::pool;
use compair::util::json::{Json, ToJson};
use compair::util::table::{fenergy_pj, fnum, ftime_ns, Table};
use compair::workload::Scenario;
use compair::Engine;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match args.command.as_str() {
        "figures" => cmd_figures(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "isa-demo" => cmd_isa_demo(&args),
        "check" => cmd_check(&args),
        "audit" => cmd_audit(&args),
        "prove" => cmd_prove(&args),
        "config" => cmd_config(&args),
        "list" => cmd_list(&args),
        "" | "help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Parse the shared `--noc-fidelity` flag; `None` when absent (callers
/// pick their own default: analytic everywhere except `serve`, which
/// defaults to calibrated).
fn parse_noc_fidelity(args: &Args) -> Result<Option<NocFidelity>, String> {
    match args.flag("noc-fidelity") {
        None => Ok(None),
        Some(s) => NocFidelity::by_name(s).map(Some).ok_or_else(|| {
            format!("unknown --noc-fidelity '{s}' (analytic | calibrated | simulated)")
        }),
    }
}

fn cmd_figures(args: &Args) -> Result<(), String> {
    let format = args.format()?;
    // figure generators build their RunConfigs internally; the flags
    // thread through the explicit context every generator receives
    // (there is deliberately no process-wide default to mutate)
    let mut cx = FigCtx { jobs: pool::default_jobs(), ..FigCtx::default() };
    if let Some(f) = parse_noc_fidelity(args)? {
        cx.noc_fidelity = f;
    }
    if let Some(j) = args.jobs()? {
        cx.jobs = j;
    }
    let registry = figures::registry();
    let names: Vec<String> = if args.has("all") || args.positional.is_empty() {
        registry.iter().map(|(n, _)| n.to_string()).collect()
    } else {
        args.positional.clone()
    };
    // resolve up front so a typo errors before any table is computed
    let selected: Vec<(&'static str, fn(&FigCtx) -> String)> = names
        .iter()
        .map(|n| {
            registry
                .iter()
                .find(|(id, _)| *id == n.as_str())
                .copied()
                .ok_or_else(|| format!("unknown figure '{n}' (see `compair list`)"))
        })
        .collect::<Result<_, _>>()?;
    // whole figures fan out as pool jobs; the submission-order merge keeps
    // the printed sequence (and every byte) identical to --jobs 1
    let outputs = pool::par_map_indexed(cx.jobs, selected, |_, (name, f)| (name, f(&cx)));
    match format {
        OutputFormat::Text => {
            for (_, table) in &outputs {
                println!("{table}");
            }
        }
        // figure tables are text artifacts by design (diffable in CI);
        // their JSON carries the id + rendered rows
        OutputFormat::Json => {
            let arr = Json::arr(outputs.iter().map(|(name, table)| {
                Json::obj().field("figure", *name).field("output", table.as_str())
            }));
            let doc = Json::obj().field("command", "figures").field("figures", arr);
            println!("{}", doc.render());
        }
    }
    Ok(())
}

/// Build the run config from flags. `default_fidelity` is the
/// subcommand's NoC-costing default (analytic for `simulate`, calibrated
/// for `serve`); a `--config` file may override it, and the explicit
/// `--noc-fidelity` flag wins over both.
fn build_rc(args: &Args, default_fidelity: NocFidelity) -> Result<RunConfig, String> {
    let arch = ArchKind::by_name(args.flag("arch").unwrap_or("compair-opt"))
        .ok_or("unknown --arch")?;
    let model = ModelConfig::by_name(args.flag("model").unwrap_or("llama2-7b"))
        .ok_or("unknown --model")?;
    let mut rc = RunConfig::new(arch, model);
    rc.noc_fidelity = default_fidelity;
    // CLI runs default to the machine's parallelism for the NoC-anchor
    // prefit; a config file may pin it, and the explicit flag wins
    rc.jobs = pool::default_jobs();
    rc.phase = match args.flag("phase").unwrap_or("decode") {
        "decode" => Phase::Decode,
        "prefill" => Phase::Prefill,
        p => return Err(format!("unknown --phase '{p}'")),
    };
    rc.batch = args.flag_usize_bounded("batch", 16, 1, 1 << 20)?;
    rc.seq_len = args.flag_usize_bounded("seqlen", 4096, 1, 1 << 24)?;
    rc.gen_len = args.flag_usize_bounded("genlen", 1, 1, 1 << 24)?;
    rc.tp = args.flag_usize_bounded("tp", 8, 1, 4096)?;
    rc.devices = args.flag_usize_bounded("devices", 32, 1, 1 << 16)?;
    if let Some(path) = args.flag("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        let doc = compair::config::toml::parse(&text).map_err(|e| e.to_string())?;
        rc.apply_doc(&doc)?;
    }
    // the explicit flags win over both the default and a config file
    if let Some(f) = parse_noc_fidelity(args)? {
        rc.noc_fidelity = f;
    }
    if let Some(j) = args.jobs()? {
        rc.jobs = j;
    }
    if let Some(m) = args.flag("mapping") {
        rc.mapping = MappingMode::by_name(m)
            .ok_or_else(|| format!("unknown --mapping '{m}' (static | auto)"))?;
    }
    Ok(rc)
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let format = args.format()?;
    let engine = Engine::new(build_rc(args, NocFidelity::Analytic)?);
    let r = engine.simulate();
    if format == OutputFormat::Json {
        let doc = Json::obj()
            .field("command", "simulate")
            .field("config", engine.rc().to_json())
            .field("report", r.to_json());
        println!("{}", doc.render());
        return Ok(());
    }
    let rc = engine.rc();
    let label = format!(
        "{} | {} | {:?} batch={} seqlen={} tp={} devices={}",
        rc.arch.label(),
        rc.model.name,
        rc.phase,
        rc.batch,
        rc.seq_len,
        rc.tp,
        rc.devices
    );
    println!("== simulate: {label} ==");
    println!("latency:            {}", ftime_ns(r.latency_ns));
    println!("throughput:         {} tok/s", fnum(r.throughput_tok_s));
    println!("energy/token:       {}", fenergy_pj(r.energy.total_pj()));
    println!("nonlinear fraction: {:.1}%", r.nonlinear_frac * 100.0);
    println!("collective fraction:{:.1}%", r.collective_frac * 100.0);
    println!("FC bank util:       {:.1}%", r.bank_util * 100.0);
    let mut t = Table::new("per-op (one layer)", &["op", "latency", "share"]);
    let total = r.layer_cost.latency_ns.max(1e-9);
    for op in &r.ops {
        t.rowv(vec![
            op.name.clone(),
            ftime_ns(op.cost.latency_ns),
            format!("{:.1}%", op.cost.latency_ns / total * 100.0),
        ]);
    }
    t.print();
    Ok(())
}

/// Parse the cluster flags; `None` means single-replica serving.
fn parse_cluster_flags(args: &Args) -> Result<Option<ClusterConfig>, String> {
    let replicas = args.flag_usize_bounded("replicas", 0, 0, 4096)?; // 0 = flag absent
    if args.flag("replicas").is_some() && replicas == 0 {
        return Err("--replicas must be positive".into());
    }
    let disagg = match args.flag("disagg") {
        None => None,
        Some(v) => {
            let parse = |s: &str| -> Result<usize, String> {
                s.trim().parse().map_err(|_| format!("--disagg expects P:D (e.g. 2:2), got '{v}'"))
            };
            let (p, d) = v
                .split_once(':')
                .ok_or_else(|| format!("--disagg expects P:D (e.g. 2:2), got '{v}'"))?;
            Some((parse(p)?, parse(d)?))
        }
    };
    let router = match args.flag("router") {
        None => RouterPolicy::RoundRobin,
        Some(r) => RouterPolicy::by_name(r)
            .ok_or_else(|| format!("unknown --router '{r}' (round-robin | least-kv | deadline)"))?,
    };
    if disagg.is_none() && replicas <= 1 {
        if args.flag("router").is_some() {
            return Err("--router needs --replicas N (>1) or --disagg P:D".into());
        }
        if replicas == 1 {
            // an explicit single replica still runs the cluster path so the
            // per-replica utilization table is available
            let cfg = ClusterConfig { replicas: 1, disagg: None, router };
            return Ok(Some(cfg));
        }
        return Ok(None);
    }
    if let Some((p, d)) = disagg {
        if replicas > 0 && replicas != p + d {
            return Err(format!(
                "--replicas {replicas} conflicts with --disagg {p}:{d} ({} replicas)",
                p + d
            ));
        }
    }
    let cfg = ClusterConfig { replicas: replicas.max(1), disagg, router };
    cfg.validate()?;
    Ok(Some(cfg))
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let format = args.format()?;
    // serving numbers are the ones the ROADMAP builds on: default to the
    // simulator-calibrated NoC costing unless the user picks a tier
    let engine = Engine::new(build_rc(args, NocFidelity::Calibrated)?);
    if engine.rc().arch == ArchKind::AttAcc {
        return Err(
            "serve does not support --arch attacc: the AttAcc roofline baseline has no \
             PIM-fabric serving model (use `simulate --arch attacc`)"
                .into(),
        );
    }
    let seed = args.flag_usize("seed", 42)? as u64;
    let cluster_cfg = parse_cluster_flags(args)?;

    let (cfg, label, desc) = if let Some(name) = args.flag("scenario") {
        let sc = Scenario::by_name(name)
            .ok_or_else(|| format!("unknown scenario '{name}' (see `compair list`)"))?;
        let n = args.flag_usize_bounded("requests", sc.default_requests, 1, 1 << 20)?;
        let label = format!("scenario={} n={} seed={}", sc.name, n, seed);
        let desc = Some(sc.description.to_string());
        (ServeConfig { n_requests: n, seed, scenario: Some(sc), ..Default::default() }, label, desc)
    } else {
        let cfg = ServeConfig {
            arrival_rate: args.flag_f64("rate", 32.0)?,
            n_requests: args.flag_usize_bounded("requests", 64, 1, 1 << 20)?,
            prompt_len: args.flag_usize_bounded("prompt", 512, 1, 1 << 24)?,
            gen_len: args.flag_usize_bounded("gen", 32, 1, 1 << 24)?,
            seed,
            ..Default::default()
        };
        let label = format!(
            "rate={}r/s n={} prompt={} gen={}",
            cfg.arrival_rate, cfg.n_requests, cfg.prompt_len, cfg.gen_len
        );
        (cfg, label, None)
    };

    if format == OutputFormat::Json {
        let doc = Json::obj()
            .field("command", "serve")
            .field("config", engine.rc().to_json())
            .field("serve", cfg.to_json());
        let doc = match cluster_cfg {
            Some(ccfg) => doc.field("cluster", engine.cluster(cfg, ccfg).to_json()),
            None => doc.field("report", engine.serve(cfg).to_json()),
        };
        println!("{}", doc.render());
        return Ok(());
    }

    let rc = engine.rc();
    println!("== serve: {} {} {} ==", rc.arch.label(), rc.model.name, label);
    if let Some(d) = desc {
        println!("   {d}");
    }
    match cluster_cfg {
        Some(ccfg) => {
            let r = engine.cluster(cfg, ccfg);
            print!("{}", cluster::render_cluster_summary(&r));
            r.replica_table().print();
            r.report.class_table("per-class SLO report").print();
        }
        None => {
            let scenario_mode = cfg.scenario.is_some();
            let r = engine.serve(cfg);
            print!("{}", serving::render_summary(&r));
            if scenario_mode {
                r.class_table("per-class SLO report").print();
            }
        }
    }
    Ok(())
}

fn cmd_isa_demo(args: &Args) -> Result<(), String> {
    let format = args.format()?;
    let len = args.flag_usize_bounded("len", 8, 1, 4096)?;
    let rounds = args.flag_usize_bounded("rounds", 6, 1, 64)? as u32;
    let hw = compair::config::HwConfig::paper();
    let xs: Vec<f32> = (0..len).map(|i| -1.0 + 2.0 * i as f32 / len as f32).collect();
    let run = |fuse: bool| {
        let mut m = Machine::new(&hw, compair::config::SramGang::In256Out16);
        m.write_row(0, 0, &xs);
        let p = RowProgram::exp_program(0, 4096, len, rounds, 1);
        let c = m.run(&p, fuse);
        (m.read_row(0, 4096, len), c)
    };
    let (vals, fused) = run(true);
    let (_, base) = run(false);
    let saving = 1.0 - fused.latency_ns / base.latency_ns;
    if format == OutputFormat::Json {
        let rows = Json::arr(xs.iter().enumerate().map(|(i, &x)| {
            Json::obj()
                .field("x", x as f64)
                .field("noc_exp", vals[i] as f64)
                .field("true_exp", (x as f64).exp())
        }));
        let doc = Json::obj()
            .field("command", "isa-demo")
            .field("len", len)
            .field("rounds", rounds as u64)
            .field("results", rows)
            .field("fused", fused.to_json())
            .field("unfused", base.to_json())
            .field("path_generation_saving", saving);
        println!("{}", doc.render());
        return Ok(());
    }
    println!("== hierarchical-ISA demo: exp over {len} scalars, {rounds} Horner rounds ==");
    let mut t = Table::new("results", &["x", "noc exp(x)", "true exp(x)"]);
    for (i, &x) in xs.iter().enumerate() {
        t.rowv(vec![fnum(x as f64), fnum(vals[i] as f64), fnum((x as f64).exp())]);
    }
    t.print();
    println!(
        "fused: {}   unfused: {}   path-generation saving: {:.0}%",
        ftime_ns(fused.latency_ns),
        ftime_ns(base.latency_ns),
        saving * 100.0
    );
    Ok(())
}

/// `check --list-codes` / `check --explain CODE`: the registered
/// diagnostic codes with their one-line meanings, straight from the
/// `ALL_CODES` × `code_description` registry.
fn cmd_check_codes(args: &Args, format: OutputFormat) -> Result<(), String> {
    if let Some(code) = args.flag("explain") {
        let desc = analysis::code_description(code)
            .ok_or_else(|| format!("unknown diagnostic code '{code}' (see --list-codes)"))?;
        match format {
            OutputFormat::Text => println!("{code}: {desc}"),
            OutputFormat::Json => {
                let out = Json::obj()
                    .field("command", "check")
                    .field("code", code)
                    .field("description", desc);
                println!("{}", out.render());
            }
        }
        return Ok(());
    }
    let rows: Vec<(&str, &str)> = analysis::ALL_CODES
        .iter()
        .map(|&c| (c, analysis::code_description(c).unwrap_or("(undocumented)")))
        .collect();
    match format {
        OutputFormat::Text => {
            let mut t = Table::new("diagnostic codes", &["code", "meaning"]);
            for (code, desc) in &rows {
                t.rowv(vec![code.to_string(), desc.to_string()]);
            }
            t.print();
        }
        OutputFormat::Json => {
            let codes = Json::arr(
                rows.iter()
                    .map(|(c, d)| Json::obj().field("code", *c).field("description", *d)),
            );
            let out = Json::obj().field("command", "check").field("codes", codes);
            println!("{}", out.render());
        }
    }
    Ok(())
}

fn cmd_check(args: &Args) -> Result<(), String> {
    let format = args.format()?;
    if args.has("list-codes") || args.flag("explain").is_some() {
        return cmd_check_codes(args, format);
    }
    let jobs = args.jobs()?.unwrap_or_else(pool::default_jobs);
    let archs = args.archs()?;
    let models = args.models(ModelConfig::zoo)?;
    let doc = match args.flag("config") {
        None => None,
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
            Some(compair::config::toml::parse(&text).map_err(|e| e.to_string())?)
        }
    };
    // the arch-independent passes run once: the shipped Row-Level
    // programs (+ static count cross-check) and the scenario SLO tables
    let isa = analysis::check_isa_programs(&compair::config::HwConfig::paper());
    let scenarios = analysis::config_check::check_scenarios();
    // per-(arch, model) points fan out across the pool; each point pins
    // rc.jobs = 1 and the submission-order merge keeps the output (and
    // the JSON document) byte-identical whatever --jobs is
    let mut points = Vec::new();
    for &arch in &archs {
        for m in &models {
            points.push((arch, m.clone()));
        }
    }
    let results = pool::par_map_indexed(jobs, points, |_, (arch, model)| {
        let name = model.name;
        let mut rc = RunConfig::new(arch, model);
        rc.jobs = 1;
        if let Some(d) = &doc {
            if let Err(e) = rc.apply_doc(d) {
                return Err(format!("{}/{name}: {e}", arch.cli_name()));
            }
        }
        Ok((arch.cli_name(), name, Engine::new(rc).check()))
    });
    let mut reports: Vec<(&'static str, &'static str, analysis::CheckReport)> = Vec::new();
    for r in results {
        reports.push(r?);
    }
    let point_errs: usize = reports.iter().map(|(_, _, r)| r.errors()).sum();
    let point_warns: usize = reports.iter().map(|(_, _, r)| r.warnings()).sum();
    let errors = isa.errors() + scenarios.errors() + point_errs;
    let warnings = isa.warnings() + scenarios.warnings() + point_warns;
    if format == OutputFormat::Json {
        let pts = Json::arr(reports.iter().map(|(arch, model, rep)| {
            Json::obj().field("arch", *arch).field("model", *model).field("report", rep.to_json())
        }));
        let out = Json::obj()
            .field("command", "check")
            .field("isa", isa.to_json())
            .field("scenarios", scenarios.to_json())
            .field("points", pts)
            .field("errors", errors)
            .field("warnings", warnings)
            .field("ok", errors == 0);
        println!("{}", out.render());
    } else {
        let mut t = Table::new("check summary", &["pass", "errors", "warnings"]);
        t.rowv(vec!["isa programs".into(), isa.errors().to_string(), isa.warnings().to_string()]);
        t.rowv(vec![
            "scenarios".into(),
            scenarios.errors().to_string(),
            scenarios.warnings().to_string(),
        ]);
        for (arch, model, rep) in &reports {
            t.rowv(vec![
                format!("{arch} / {model}"),
                rep.errors().to_string(),
                rep.warnings().to_string(),
            ]);
        }
        t.print();
        let named = std::iter::once(("isa programs".to_string(), &isa))
            .chain(std::iter::once(("scenarios".to_string(), &scenarios)))
            .chain(reports.iter().map(|(a, m, r)| (format!("{a} / {m}"), r)));
        for (title, rep) in named {
            if !rep.diags.is_empty() {
                println!("{}", rep.render_table(&title));
            }
        }
        println!("check: {} point(s), {errors} error(s), {warnings} warning(s)", reports.len());
    }
    cli::gate_errors("check", "error diagnostic", errors)
}

fn cmd_audit(args: &Args) -> Result<(), String> {
    use compair::analysis::audit::{self, AuditOptions};
    use compair::analysis::audit_lattice as lattice;
    let format = args.format()?;
    let jobs = args.jobs()?.unwrap_or_else(pool::default_jobs);
    let opts = AuditOptions { deep: args.has("deep") };
    let archs = args.archs()?;
    let models = args.models(|| lattice::default_models(opts.deep))?;
    // the arch-independent slice runs once: collective closed-form
    // identities, calibration anchors/factors, serving + cluster samples
    let global = audit::check_global(&opts);
    // lattice points fan out across the pool; each point pins rc.jobs = 1
    // (see AuditPoint::rc) and the submission-order merge keeps the output
    // byte-identical whatever --jobs is
    let points = lattice::points(&archs, &models, opts.deep);
    let reports: Vec<(String, analysis::CheckReport)> = pool::par_map_indexed(
        jobs,
        points,
        |_, p| (p.label(), audit::audit_point(&p, &opts)),
    );
    let point_errs: usize = reports.iter().map(|(_, r)| r.errors()).sum();
    let point_warns: usize = reports.iter().map(|(_, r)| r.warnings()).sum();
    let errors = global.errors() + point_errs;
    let warnings = global.warnings() + point_warns;
    if format == OutputFormat::Json {
        let pts = Json::arr(
            reports
                .iter()
                .map(|(label, rep)| {
                    Json::obj().field("point", label.as_str()).field("report", rep.to_json())
                }),
        );
        let out = Json::obj()
            .field("command", "audit")
            .field("deep", opts.deep)
            .field("global", global.to_json())
            .field("points", pts)
            .field("errors", errors)
            .field("warnings", warnings)
            .field("ok", errors == 0);
        println!("{}", out.render());
    } else {
        let mut t = Table::new("audit summary", &["point", "errors", "warnings"]);
        t.rowv(vec![
            "global".into(),
            global.errors().to_string(),
            global.warnings().to_string(),
        ]);
        for (label, rep) in &reports {
            t.rowv(vec![label.clone(), rep.errors().to_string(), rep.warnings().to_string()]);
        }
        t.print();
        let named = std::iter::once(("global".to_string(), &global))
            .chain(reports.iter().map(|(l, r)| (l.clone(), r)));
        for (title, rep) in named {
            if !rep.diags.is_empty() {
                println!("{}", rep.render_table(&title));
            }
        }
        println!("audit: {} point(s), {errors} error(s), {warnings} warning(s)", reports.len());
    }
    cli::gate_errors("audit", "invariant violation", errors)
}

fn cmd_prove(args: &Args) -> Result<(), String> {
    use compair::analysis::prove;
    let format = args.format()?;
    if args.has("list-codes") || args.flag("explain").is_some() {
        return cmd_check_codes(args, format);
    }
    let jobs = args.jobs()?.unwrap_or_else(pool::default_jobs);
    let archs = args.archs()?;
    let models = args.models(prove::default_models)?;
    let phase = match args.flag("phase") {
        None => None,
        Some("decode") => Some(Phase::Decode),
        Some("prefill") => Some(Phase::Prefill),
        Some(p) => return Err(format!("unknown --phase '{p}'")),
    };
    // the point-independent proofs run once (energy pricing coverage);
    // lattice points fan out across the pool with rc.jobs = 1 each, and
    // the submission-order merge keeps the output byte-identical
    // whatever --jobs is
    let global = prove::check_global();
    let mut points = prove::points(&archs, &models);
    if let Some(ph) = phase {
        points.retain(|p| p.phase == ph);
    }
    let results: Vec<(analysis::CheckReport, prove::ProveSummary)> =
        pool::par_map_indexed(jobs, points, |_, p| prove::prove_point(&p));
    let point_errs: usize = results.iter().map(|(r, _)| r.errors()).sum();
    let point_warns: usize = results.iter().map(|(r, _)| r.warnings()).sum();
    let errors = global.errors() + point_errs;
    let warnings = global.warnings() + point_warns;
    if format == OutputFormat::Json {
        let pts = Json::arr(results.iter().map(|(rep, sum)| {
            Json::obj()
                .field("point", sum.label.as_str())
                .field("summary", sum.to_json())
                .field("report", rep.to_json())
        }));
        let out = Json::obj()
            .field("command", "prove")
            .field("global", global.to_json())
            .field("points", pts)
            .field("errors", errors)
            .field("warnings", warnings)
            .field("ok", errors == 0);
        println!("{}", out.render());
    } else {
        let mut t = Table::new(
            "prove summary",
            &["point", "cells", "certified", "corners", "latency lo..hi", "energy lo..hi"],
        );
        for (_, s) in &results {
            t.rowv(vec![
                s.label.clone(),
                s.cells.to_string(),
                format!("{}{}", s.certified, if s.complete { "" } else { " (partial)" }),
                s.corners.to_string(),
                format!("{}..{}", ftime_ns(s.lat_lo_ns), ftime_ns(s.lat_hi_ns)),
                format!("{}..{}", fenergy_pj(s.pj_lo), fenergy_pj(s.pj_hi)),
            ]);
        }
        t.print();
        let named = std::iter::once(("global".to_string(), &global))
            .chain(results.iter().map(|(r, s)| (s.label.clone(), r)));
        for (title, rep) in named {
            if !rep.diags.is_empty() {
                println!("{}", rep.render_table(&title));
            }
        }
        println!("prove: {} point(s), {errors} error(s), {warnings} warning(s)", results.len());
    }
    cli::gate_errors("prove", "failed proof obligation", errors)
}

fn cmd_config(args: &Args) -> Result<(), String> {
    let table = figures::table3(&FigCtx::default());
    match args.format()? {
        OutputFormat::Text => println!("{table}"),
        OutputFormat::Json => {
            let doc = Json::obj().field("command", "config").field("output", table);
            println!("{}", doc.render());
        }
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<(), String> {
    let archs: Vec<&'static str> = ArchKind::all().iter().map(|a| a.cli_name()).collect();
    match args.format()? {
        OutputFormat::Text => {
            println!("figures:");
            for (n, _) in figures::registry() {
                println!("  {n}");
            }
            println!("models:");
            for m in ModelConfig::zoo() {
                println!("  {}", m.name);
            }
            println!("archs: {}", archs.join(" "));
            println!("scenarios:");
            for s in Scenario::all() {
                println!("  {:<13} {}", s.name, s.description);
            }
        }
        OutputFormat::Json => {
            let doc = Json::obj()
                .field("command", "list")
                .field(
                    "figures",
                    Json::arr(figures::registry().iter().map(|(n, _)| Json::from(*n))),
                )
                .field("models", Json::arr(ModelConfig::zoo().iter().map(|m| Json::from(m.name))))
                .field("archs", Json::arr(archs.iter().map(|a| Json::from(*a))))
                .field(
                    "scenarios",
                    Json::arr(Scenario::all().into_iter().map(|s| {
                        Json::obj().field("name", s.name).field("description", s.description)
                    })),
                );
            println!("{}", doc.render());
        }
    }
    Ok(())
}
