//! Hand-rolled CLI argument parsing (no clap is vendored offline).
//!
//! Grammar: `compair <command> [--flag value]... [positional]...`

use std::collections::BTreeMap;

use crate::config::{ArchKind, ModelConfig};
use crate::util::pool;

/// Flags that never take a value (resolves the `--all fig15` ambiguity).
const KNOWN_SWITCHES: &[&str] = &["all", "verbose", "quiet", "deep", "list-codes"];

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        out.command = it.next().unwrap_or_default();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bad flag '--'".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if KNOWN_SWITCHES.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    // every non-switch flag takes a value; a missing one is
                    // a parse error, not a silent switch (a trailing
                    // `--batch` used to be dropped without complaint)
                    match it.next() {
                        Some(v) if !v.starts_with("--") => {
                            out.flags.insert(name.to_string(), v);
                        }
                        Some(v) => {
                            return Err(format!(
                                "flag --{name} expects a value, found flag '{v}'"
                            ));
                        }
                        None => return Err(format!("flag --{name} expects a value")),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// A numeric flag with an inclusive `[min, max]` range. Shape and
    /// worker counts go through this so a zero or absurd value is a parse
    /// error here, not a div-by-zero or OOM-sized sweep downstream.
    pub fn flag_usize_bounded(
        &self,
        name: &str,
        default: usize,
        min: usize,
        max: usize,
    ) -> Result<usize, String> {
        let v = self.flag_usize(name, default)?;
        if v < min || v > max {
            return Err(format!("--{name} must be in {min}..={max}, got {v}"));
        }
        Ok(v)
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// The `--format` flag, shared by every subcommand.
    pub fn format(&self) -> Result<OutputFormat, String> {
        match self.flag("format") {
            None | Some("text") => Ok(OutputFormat::Text),
            Some("json") => Ok(OutputFormat::Json),
            Some(o) => Err(format!("unknown --format '{o}' (text | json)")),
        }
    }

    /// The shared `--jobs N|auto` flag; `None` when absent (callers pick
    /// their own default). `auto` resolves to the machine's available
    /// parallelism. Results never depend on N (submission-order merge).
    pub fn jobs(&self) -> Result<Option<usize>, String> {
        match self.flag("jobs") {
            None => Ok(None),
            Some("auto") => Ok(Some(pool::default_jobs())),
            Some(v) => {
                let n: usize = v.parse().map_err(|_| {
                    format!("--jobs expects a positive integer or 'auto', got '{v}'")
                })?;
                if n == 0 {
                    return Err("--jobs must be >= 1 (use 1 for serial)".into());
                }
                if n > 1024 {
                    return Err(format!("--jobs must be <= 1024, got {n}"));
                }
                Ok(Some(n))
            }
        }
    }

    /// The shared `--arch` point filter of the static-analysis family
    /// (`check` / `audit` / `prove`): one named arch, or all of them.
    pub fn archs(&self) -> Result<Vec<ArchKind>, String> {
        match self.flag("arch") {
            Some(a) => Ok(vec![
                ArchKind::by_name(a).ok_or_else(|| format!("unknown --arch '{a}'"))?
            ]),
            None => Ok(ArchKind::all().to_vec()),
        }
    }

    /// The shared `--model` point filter: one named zoo model, or the
    /// command's default lattice (`check` covers the zoo, `audit`/`prove`
    /// keep the gate fast with `tiny` + `llama2-7b`).
    pub fn models(
        &self,
        default: impl FnOnce() -> Vec<ModelConfig>,
    ) -> Result<Vec<ModelConfig>, String> {
        match self.flag("model") {
            Some(m) => Ok(vec![
                ModelConfig::by_name(m).ok_or_else(|| format!("unknown --model '{m}'"))?
            ]),
            None => Ok(default()),
        }
    }
}

/// Shared nonzero-exit epilogue of the static-analysis family: any
/// error-severity diagnostic fails the command (warnings pass).
pub fn gate_errors(command: &str, noun: &str, errors: usize) -> Result<(), String> {
    if errors > 0 {
        Err(format!("{command} found {errors} {noun}(s)"))
    } else {
        Ok(())
    }
}

/// How a subcommand renders its report.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputFormat {
    /// Human-readable tables (the default).
    #[default]
    Text,
    /// One machine-readable JSON document on stdout.
    Json,
}

pub const USAGE: &str = "\
compair — CompAir hybrid-PIM LLM inference simulator + coordinator

USAGE:
  compair figures [<id>...] [--all]       regenerate paper tables/figures
                                          (incl. noc-calibration: analytic
                                          vs flit-level NoC error table)
                   [--jobs N|auto]        fan figures + their sweep cells out
                                          to N pool workers (auto = all
                                          cores); output is bit-identical
                                          to --jobs 1, whatever N is
  compair simulate [--arch A] [--model M] [--phase decode|prefill]
                   [--batch N] [--seqlen N] [--tp N] [--devices N]
                   [--config file.toml]   run one simulation, print report
                   [--mapping static|auto] operator placement: the paper's
                                          hard-coded engine assignment, or
                                          a per-shape placement search that
                                          never scores worse than static
  compair serve    [--arch A] [--model M] [--rate R] [--requests N]
                   [--prompt N] [--gen N] [--seed S]
                   [--scenario NAME]      continuous-batching serving sim;
                                          --scenario serves a named request
                                          mix with per-class SLO reporting
                   [--replicas N]         serve across N replicas on the
                                          CXL fabric (cluster coordinator)
                   [--disagg P:D]         disaggregate into P prefill + D
                                          decode replicas w/ KV migration
                   [--router POLICY]      arrival routing policy
  compair isa-demo [--len N] [--rounds N] run the hierarchical-ISA exp demo
  compair check    [--arch A] [--model M] static verifier: lints the shipped
                   [--config file.toml]   ISA programs, validates operator
                   [--jobs N|auto]        placements and cross-checks configs
                                          over every (arch, model) point;
                                          exits nonzero on any error-severity
                                          diagnostic (warnings pass)
                   [--list-codes]         print every registered diagnostic
                                          code with its one-line meaning
                   [--explain CODE]       explain one diagnostic code
  compair audit    [--arch A] [--model M] semantic auditor: proves physical
                   [--deep]               invariants (finiteness, op/energy/
                   [--jobs N|auto]        bytes conservation, monotonicity,
                                          cache coherence, never-lose,
                                          fidelity bands, calibration bounds)
                                          over the pow2 point lattice; --deep
                                          widens to the full model zoo, the
                                          simulated NoC tier and longer
                                          chains; exits nonzero on any error
  compair prove    [--arch A] [--model M] static prover: captures the cost
                   [--phase decode|prefill] pipeline as a unit-checked
                   [--jobs N|auto]        expression IR and certifies unit
                                          consistency, monotonicity, interval
                                          bounds and energy-pricing coverage
                                          over the whole shape box (not
                                          sampled); exits nonzero on any
                                          failed proof obligation
                   [--list-codes]         print every registered diagnostic
                                          code with its one-line meaning
                   [--explain CODE]       explain one diagnostic code
  compair config show                     print the Table-3 hardware config
  compair list                            list figures/models/archs/scenarios

Every command accepts `--format text|json`; json emits one machine-readable
report document on stdout. `simulate`, `serve` and `figures` also accept
`--noc-fidelity analytic|calibrated|simulated` to pick how NoC collectives
are priced (closed forms, simulator-calibrated forms, or the flit-level
mesh itself); serve defaults to calibrated, everything else to analytic.
They likewise accept `--jobs N|auto` (default auto): on `figures` it sizes
the worker pool for the figure/cell fan-out, on `simulate`/`serve` it
parallelizes the NoC calibration prefit and (under `--mapping auto`) the
placement-search candidate scoring. Results never depend on N. `serve`
also accepts `--mapping static|auto`; auto re-searches per shape class
and falls back to the static placement whenever search cannot beat it.

ARCHS:     cent | cent-curry | compair-base | compair-opt | sram-stack | attacc
MODELS:    llama2-7b | llama2-13b | llama2-70b | qwen-72b | gpt3-175b | tiny
SCENARIOS: chat | rag | long-context | batch | bursty | mixed
ROUTERS:   round-robin | least-kv | deadline
FIDELITY:  analytic | calibrated | simulated
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse("simulate --batch 64 --model llama2-7b --all fig15");
        assert_eq!(a.command, "simulate");
        assert_eq!(a.flag("batch"), Some("64"));
        assert_eq!(a.flag("model"), Some("llama2-7b"));
        assert!(a.has("all"));
        assert_eq!(a.positional, vec!["fig15"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("simulate --batch=8");
        assert_eq!(a.flag_usize("batch", 1).unwrap(), 8);
    }

    #[test]
    fn typed_flag_errors() {
        let a = parse("simulate --batch nope");
        assert!(a.flag_usize("batch", 1).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("simulate");
        assert_eq!(a.flag_usize("batch", 7).unwrap(), 7);
        assert_eq!(a.flag_f64("rate", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn bounded_flag_accepts_range_and_default() {
        let a = parse("simulate --batch 64");
        assert_eq!(a.flag_usize_bounded("batch", 16, 1, 1024).unwrap(), 64);
        // default applies unvalidated input absent
        assert_eq!(a.flag_usize_bounded("seqlen", 4096, 1, 1 << 24).unwrap(), 4096);
    }

    #[test]
    fn bounded_flag_rejects_out_of_range() {
        let zero = parse("simulate --batch 0");
        let e = zero.flag_usize_bounded("batch", 16, 1, 1024).unwrap_err();
        assert!(e.contains("--batch must be in 1..=1024"), "{e}");
        let huge = parse("serve --replicas 9999");
        assert!(huge.flag_usize_bounded("replicas", 0, 0, 4096).is_err());
        // non-numeric still reports the integer parse error
        let nan = parse("simulate --batch lots");
        assert!(nan.flag_usize_bounded("batch", 16, 1, 1024).unwrap_err().contains("integer"));
    }

    #[test]
    fn trailing_flag_without_value_is_an_error() {
        // regression: `serve --scenario` used to silently become a switch
        // (and before that, risked a panic on the value pull)
        let e = Args::parse("serve --scenario".split_whitespace().map(String::from));
        assert!(e.is_err());
        assert!(e.unwrap_err().contains("--scenario expects a value"));
    }

    #[test]
    fn flag_followed_by_flag_is_an_error() {
        let e = Args::parse("serve --batch --model x".split_whitespace().map(String::from));
        assert!(e.is_err());
        assert!(e.unwrap_err().contains("--batch expects a value"));
    }

    #[test]
    fn trailing_known_switch_still_parses() {
        let a = parse("figures fig15 --all");
        assert!(a.has("all"));
        assert_eq!(a.positional, vec!["fig15"]);
    }

    #[test]
    fn audit_switches_parse_as_switches() {
        // --deep and --list-codes take no value; a following flag must not
        // be swallowed as one
        let a = parse("audit --deep --jobs 4");
        assert!(a.has("deep"));
        assert_eq!(a.flag("jobs"), Some("4"));
        let a = parse("check --list-codes");
        assert!(a.has("list-codes"));
    }

    #[test]
    fn jobs_flag_parses_and_bounds() {
        assert_eq!(parse("prove").jobs().unwrap(), None);
        assert_eq!(parse("prove --jobs 4").jobs().unwrap(), Some(4));
        assert!(parse("prove --jobs auto").jobs().unwrap().unwrap() >= 1);
        assert!(parse("prove --jobs 0").jobs().is_err());
        assert!(parse("prove --jobs 2048").jobs().is_err());
        assert!(parse("prove --jobs lots").jobs().is_err());
    }

    #[test]
    fn arch_filter_parses() {
        assert_eq!(parse("check").archs().unwrap().len(), ArchKind::all().len());
        let one = parse("check --arch compair-opt").archs().unwrap();
        assert_eq!(one, vec![ArchKind::CompAirOpt]);
        let e = parse("check --arch warp9").archs().unwrap_err();
        assert!(e.contains("unknown --arch 'warp9'"), "{e}");
    }

    #[test]
    fn model_filter_parses_with_command_default() {
        let def = parse("audit").models(|| vec![ModelConfig::tiny()]).unwrap();
        assert_eq!(def.len(), 1);
        assert_eq!(def[0].name, "tiny");
        let one = parse("audit --model llama2-7b").models(ModelConfig::zoo).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].name, "llama2-7b");
        assert!(parse("audit --model gpt5").models(ModelConfig::zoo).is_err());
    }

    #[test]
    fn gate_errors_epilogue() {
        assert!(gate_errors("check", "error diagnostic", 0).is_ok());
        let e = gate_errors("audit", "invariant violation", 3).unwrap_err();
        assert_eq!(e, "audit found 3 invariant violation(s)");
    }

    #[test]
    fn format_flag_parses() {
        assert_eq!(parse("simulate").format().unwrap(), OutputFormat::Text);
        assert_eq!(parse("simulate --format text").format().unwrap(), OutputFormat::Text);
        assert_eq!(parse("simulate --format json").format().unwrap(), OutputFormat::Json);
        assert!(parse("simulate --format yaml").format().is_err());
    }
}
