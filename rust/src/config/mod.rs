//! Configuration: hardware (Table 3), LLM model zoo, run configs, and the
//! TOML-subset parser used by the launcher.
pub mod hw;
pub mod model;
pub mod run;
pub mod toml;

pub use hw::{
    ColumnDecoder, CxlConfig, DramConfig, HbConfig, HwConfig, NocConfig, NocFidelity, SramConfig,
    SramGang, Voltage,
};
pub use model::ModelConfig;
pub use run::{ArchKind, FcMapping, MappingMode, Phase, RunConfig};
