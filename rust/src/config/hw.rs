//! Hardware configuration (paper Table 3) for all simulated components.
//!
//! All timing is in nanoseconds, bandwidth in GB/s (10^9 bytes/s), energy
//! constants live in `energy::model`. Values and their provenance:
//!
//! * DRAM-PIM: SK-Hynix AiM-style GDDR6 bank (32 MB, BF16, 16 MACs/bank),
//!   timings from Table 3 (tRCDWR=14, tRCDRD=18, tRAS=27, tCL=25, tRP=16 ns).
//! * SRAM-PIM: the fabricated 28nm macro of [Guo+, ISSCC'23]: 64 kb array,
//!   128-input × 8-output BF16 MAC, access 6.8–14.1 ns over 0.9–0.6 V,
//!   14.4–31.6 TOPS/W.
//! * Hybrid bonding: 256 bonds/bank at 6.4 Gbps/bond, 0.05–0.88 pJ/b.
//! * CompAir-NoC: 4×16 2D mesh per channel (4 routers per bank × 16 banks),
//!   72-bit flits, 2 Curry ALUs per router, DOR routing, SWIFT-style router.
//! * CXL fabric: 32 devices/switch, 29.44 GB/s collective, 53.5 GB/s p2p.

/// Column-decoder organization of the DRAM-PIM bank (paper §3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnDecoder {
    /// Baseline AiM/Newton organization: a single 32:1 mux. Each read-out
    /// delivers row_bytes/32 = 32 B regardless of the consumer.
    Coupled32to1,
    /// CompAir's decoupled organization: an 8:1 decoder feeds the
    /// hybrid-bonded SRAM-PIM (128 B/access) while a 4:1 decoder serves the
    /// bank's own MAC path (256 B/access).
    Decoupled8and4,
}

impl ColumnDecoder {
    /// Bytes delivered per column access toward the SRAM-PIM (via HB).
    pub fn sram_access_bytes(&self, row_bytes: usize) -> usize {
        match self {
            ColumnDecoder::Coupled32to1 => row_bytes / 32,
            ColumnDecoder::Decoupled8and4 => row_bytes / 8,
        }
    }

    /// Bytes delivered per column access toward the bank's own MAC units.
    pub fn mac_access_bytes(&self, row_bytes: usize) -> usize {
        match self {
            ColumnDecoder::Coupled32to1 => row_bytes / 32,
            ColumnDecoder::Decoupled8and4 => row_bytes / 4,
        }
    }
}

/// GDDR6-PIM timing and organization (one bank).
#[derive(Debug, Clone)]
pub struct DramConfig {
    pub t_rcdwr_ns: f64,
    pub t_rcdrd_ns: f64,
    pub t_ras_ns: f64,
    pub t_cl_ns: f64,
    pub t_rp_ns: f64,
    /// Column-to-column (MAC issue) interval; AiM issues one MAC command per
    /// column access at the GDDR6 core clock (1 GHz effective → 1 ns).
    pub t_ccd_ns: f64,
    /// DRAM array row width in bytes (1 KB per the paper's §3.4 discussion).
    pub row_bytes: usize,
    /// Per-bank capacity in MB (32 MB, Table 3).
    pub bank_mb: usize,
    /// BF16 MAC lanes per bank (16, Table 3).
    pub macs_per_bank: usize,
    pub banks_per_channel: usize,
    pub channels_per_device: usize,
    /// Aggregate internal bandwidth of one channel (AiM: 512 GB/s).
    pub internal_gbs_per_channel: f64,
    /// External I/O bandwidth of one channel (AiM: 32 GB/s).
    pub external_gbs_per_channel: f64,
    pub column_decoder: ColumnDecoder,
    /// Global-buffer bandwidth for inter-bank transfers within a channel
    /// (serializing resource in baseline DRAM-PIM; 32 GB/s).
    pub global_buffer_gbs: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self {
            t_rcdwr_ns: 14.0,
            t_rcdrd_ns: 18.0,
            t_ras_ns: 27.0,
            t_cl_ns: 25.0,
            t_rp_ns: 16.0,
            t_ccd_ns: 1.0,
            row_bytes: 1024,
            bank_mb: 32,
            macs_per_bank: 16,
            banks_per_channel: 16,
            channels_per_device: 32,
            internal_gbs_per_channel: 512.0,
            external_gbs_per_channel: 32.0,
            column_decoder: ColumnDecoder::Coupled32to1,
            global_buffer_gbs: 32.0,
        }
    }
}

impl DramConfig {
    /// Per-bank share of the channel's internal bandwidth (GB/s).
    pub fn per_bank_gbs(&self) -> f64 {
        self.internal_gbs_per_channel / self.banks_per_channel as f64
    }

    /// Total banks in one device.
    pub fn banks_per_device(&self) -> usize {
        self.banks_per_channel * self.channels_per_device
    }

    /// Per-device DRAM capacity in bytes.
    pub fn device_capacity_bytes(&self) -> u64 {
        // `<< 20 << 0 * banks` previously parsed as `(x << 20) << (0 * banks)`
        // and returned one bank's capacity, not the device's
        ((self.bank_mb as u64) << 20) * self.banks_per_device() as u64
    }
}

/// SRAM-PIM operating voltage point; scales latency and efficiency linearly
/// between the published 0.6 V and 0.9 V corners.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Voltage(pub f64);

impl Voltage {
    pub const MIN: f64 = 0.6;
    pub const MAX: f64 = 0.9;

    pub fn clamp(self) -> Voltage {
        Voltage(self.0.clamp(Self::MIN, Self::MAX))
    }

    /// Normalized position in [0,1]: 0 → 0.6 V (slow/efficient), 1 → 0.9 V.
    pub fn t(self) -> f64 {
        (self.clamp().0 - Self::MIN) / (Self::MAX - Self::MIN)
    }
}

/// SRAM-PIM macro specification (fabricated chip [12]).
#[derive(Debug, Clone)]
pub struct SramConfig {
    /// Inputs per macro MAC array (128).
    pub macro_inputs: usize,
    /// Outputs per macro (8).
    pub macro_outputs: usize,
    /// Macros stacked under each DRAM bank (4).
    pub macros_per_bank: usize,
    /// Array size in kilobits (64 kb).
    pub array_kb: usize,
    /// Access latency at the fast corner (0.9 V): 6.8 ns.
    pub t_access_fast_ns: f64,
    /// Access latency at the slow corner (0.6 V): 14.1 ns.
    pub t_access_slow_ns: f64,
    /// Efficiency at 0.9 V: 14.4 TFLOPS/W.
    pub tflops_w_fast: f64,
    /// Efficiency at 0.6 V: 31.6 TFLOPS/W.
    pub tflops_w_slow: f64,
    /// Weight-write latency per macro row (ns); one 128-input row of BF16
    /// weights per write port cycle.
    pub t_write_row_ns: f64,
    pub voltage: Voltage,
}

impl Default for SramConfig {
    fn default() -> Self {
        Self {
            macro_inputs: 128,
            macro_outputs: 8,
            macros_per_bank: 4,
            array_kb: 64,
            t_access_fast_ns: 6.8,
            t_access_slow_ns: 14.1,
            tflops_w_fast: 14.4,
            tflops_w_slow: 31.6,
            t_write_row_ns: 2.0,
            voltage: Voltage(0.9),
        }
    }
}

impl SramConfig {
    /// Access latency at the configured voltage (linear interpolation between
    /// published corners).
    pub fn t_access_ns(&self) -> f64 {
        let t = self.voltage.t();
        self.t_access_slow_ns + t * (self.t_access_fast_ns - self.t_access_slow_ns)
    }

    /// Efficiency (TFLOPS/W) at the configured voltage.
    pub fn tflops_w(&self) -> f64 {
        let t = self.voltage.t();
        self.tflops_w_slow + t * (self.tflops_w_fast - self.tflops_w_slow)
    }

    /// Energy per MAC operation (two flops) in pJ at the configured voltage.
    pub fn pj_per_mac(&self) -> f64 {
        2.0 / self.tflops_w()
    }

    /// MACs performed by one macro access (inputs × outputs).
    pub fn macs_per_access(&self) -> usize {
        self.macro_inputs * self.macro_outputs
    }

    /// Weight bytes held by one macro (BF16).
    pub fn macro_weight_bytes(&self) -> usize {
        self.macro_inputs * self.macro_outputs * 2
    }
}

/// How the bank's 4 macros are ganged into one logical matrix unit (§3.3).
/// `(512, 8)` extends the input dimension; `(256, 16)` balances both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SramGang {
    /// 4 macros along the input dim: logical 512-in × 8-out.
    In512Out8,
    /// 2×2: logical 256-in × 16-out.
    In256Out16,
}

impl SramGang {
    pub fn shape(&self, m: &SramConfig) -> (usize, usize) {
        match self {
            SramGang::In512Out8 => (m.macro_inputs * 4, m.macro_outputs),
            SramGang::In256Out16 => (m.macro_inputs * 2, m.macro_outputs * 2),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SramGang::In512Out8 => "(512,8)",
            SramGang::In256Out16 => "(256,16)",
        }
    }
}

/// Hybrid-bonding cross-die link (per bank).
#[derive(Debug, Clone)]
pub struct HbConfig {
    pub bonds_per_bank: usize,
    pub gbps_per_bond: f64,
    pub pj_per_bit: f64,
}

impl Default for HbConfig {
    fn default() -> Self {
        Self { bonds_per_bank: 256, gbps_per_bond: 6.4, pj_per_bit: 0.3 }
    }
}

impl HbConfig {
    /// Aggregate link bandwidth per bank in GB/s.
    pub fn gbs_per_bank(&self) -> f64 {
        self.bonds_per_bank as f64 * self.gbps_per_bond / 8.0
    }
}

/// How the NoC collectives (reduce / broadcast / exp / sqrt / scalar
/// stream) are priced by the cost model (see `noc::model`).
///
/// The flit-level mesh simulator is the ground truth but cycle-stepped;
/// the closed forms in `arch::collective` are fast but were only validated
/// to within 0.5–2.0× of it. The fidelity knob picks the trade-off per
/// run and is part of every memoization key, so cached results can never
/// mix tiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum NocFidelity {
    /// Closed-form analytic costs (fastest; the default, and essentially
    /// the historical behaviour — the forms were re-linearized slightly
    /// when the tiers were introduced, see `arch::collective`).
    #[default]
    Analytic,
    /// Closed forms corrected by per-collective factors fitted against the
    /// flit-level simulator at anchor shapes — fast like analytic,
    /// accurate like simulation. The CLI default for `serve`.
    Calibrated,
    /// Drive the flit-level mesh / tree schedules / ISA machine directly
    /// at the requested shape (chunk-replicated; see `noc::model`).
    Simulated,
}

impl NocFidelity {
    pub fn label(&self) -> &'static str {
        match self {
            NocFidelity::Analytic => "analytic",
            NocFidelity::Calibrated => "calibrated",
            NocFidelity::Simulated => "simulated",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "analytic" => Some(NocFidelity::Analytic),
            "calibrated" => Some(NocFidelity::Calibrated),
            "simulated" => Some(NocFidelity::Simulated),
            _ => None,
        }
    }

    /// Every tier, cheapest first.
    pub fn all() -> [NocFidelity; 3] {
        [NocFidelity::Analytic, NocFidelity::Calibrated, NocFidelity::Simulated]
    }
}

/// CompAir-NoC configuration (per channel).
#[derive(Debug, Clone)]
pub struct NocConfig {
    /// Mesh dimensions: 4 columns × 16 rows (4 routers per bank).
    pub mesh_cols: usize,
    pub mesh_rows: usize,
    pub flit_bits: usize,
    /// Router cycle time (1 GHz logic-die clock).
    pub cycle_ns: f64,
    /// Curry ALUs per router (2, Table 3).
    pub curry_alus_per_router: usize,
    /// Router traversal latency in cycles with SWIFT lookahead+bypass hit.
    pub bypass_cycles: u64,
    /// Router traversal latency in cycles on a bypass miss (arbitration).
    pub pipeline_cycles: u64,
    /// Input-queue depth per port in flits.
    pub queue_depth: usize,
    /// Divider latency in cycles (iterative unit inside the Curry ALU).
    pub div_cycles: u64,
}

impl Default for NocConfig {
    fn default() -> Self {
        Self {
            mesh_cols: 4,
            mesh_rows: 16,
            flit_bits: 72,
            cycle_ns: 1.0,
            curry_alus_per_router: 2,
            bypass_cycles: 1,
            pipeline_cycles: 2,
            queue_depth: 4,
            div_cycles: 4,
        }
    }
}

impl NocConfig {
    pub fn n_routers(&self) -> usize {
        self.mesh_cols * self.mesh_rows
    }
}

/// CXL fabric across devices.
#[derive(Debug, Clone)]
pub struct CxlConfig {
    pub devices: usize,
    /// Collective (broadcast/reduce) bandwidth, GB/s.
    pub collective_gbs: f64,
    /// Point-to-point bandwidth, GB/s.
    pub p2p_gbs: f64,
    /// One-way latency per hop through the switch (ns).
    pub hop_latency_ns: f64,
}

impl Default for CxlConfig {
    fn default() -> Self {
        Self { devices: 32, collective_gbs: 29.44, p2p_gbs: 53.5, hop_latency_ns: 250.0 }
    }
}

/// Full hardware configuration (Table 3).
#[derive(Debug, Clone, Default)]
pub struct HwConfig {
    pub dram: DramConfig,
    pub sram: SramConfig,
    pub hb: HbConfig,
    pub noc: NocConfig,
    pub cxl: CxlConfig,
    pub sram_gang: SramGangDefault,
}

/// Wrapper to give `SramGang` a `Default` without implementing it on the
/// enum (the best gang is workload-dependent; (256,16) wins most, §3.3).
#[derive(Debug, Clone, Copy)]
pub struct SramGangDefault(pub SramGang);

impl Default for SramGangDefault {
    fn default() -> Self {
        SramGangDefault(SramGang::In256Out16)
    }
}

impl HwConfig {
    /// The paper's evaluated configuration (Table 3) verbatim.
    pub fn paper() -> Self {
        Self::default()
    }

    /// CompAir with the optimized (decoupled) column decoder — "CompAir_Opt".
    pub fn paper_opt() -> Self {
        let mut hw = Self::default();
        hw.dram.column_decoder = ColumnDecoder::Decoupled8and4;
        hw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_capacity_covers_every_bank() {
        let hw = HwConfig::paper();
        // 32 MB × 16 banks × 32 channels = 16 GiB per device (regression:
        // a shift-precedence bug used to report one bank's 32 MB)
        assert_eq!(hw.dram.banks_per_device(), 512);
        assert_eq!(hw.dram.device_capacity_bytes(), (32u64 << 20) * 512);
    }

    #[test]
    fn table3_defaults() {
        let hw = HwConfig::paper();
        assert_eq!(hw.dram.t_rcdwr_ns, 14.0);
        assert_eq!(hw.dram.t_rcdrd_ns, 18.0);
        assert_eq!(hw.dram.t_ras_ns, 27.0);
        assert_eq!(hw.dram.t_cl_ns, 25.0);
        assert_eq!(hw.dram.t_rp_ns, 16.0);
        assert_eq!(hw.dram.banks_per_channel, 16);
        assert_eq!(hw.dram.channels_per_device, 32);
        assert_eq!(hw.noc.n_routers(), 64);
        assert_eq!(hw.cxl.devices, 32);
    }

    #[test]
    fn per_bank_bandwidth_is_32gbs() {
        let d = DramConfig::default();
        assert!((d.per_bank_gbs() - 32.0).abs() < 1e-9);
    }

    #[test]
    fn column_decoder_access_widths() {
        let row = 1024;
        assert_eq!(ColumnDecoder::Coupled32to1.sram_access_bytes(row), 32);
        assert_eq!(ColumnDecoder::Coupled32to1.mac_access_bytes(row), 32);
        assert_eq!(ColumnDecoder::Decoupled8and4.sram_access_bytes(row), 128);
        assert_eq!(ColumnDecoder::Decoupled8and4.mac_access_bytes(row), 256);
    }

    #[test]
    fn sram_voltage_interpolation() {
        let mut s = SramConfig::default();
        s.voltage = Voltage(0.9);
        assert!((s.t_access_ns() - 6.8).abs() < 1e-9);
        assert!((s.tflops_w() - 14.4).abs() < 1e-9);
        s.voltage = Voltage(0.6);
        assert!((s.t_access_ns() - 14.1).abs() < 1e-9);
        assert!((s.tflops_w() - 31.6).abs() < 1e-9);
        s.voltage = Voltage(0.75);
        assert!(s.t_access_ns() > 6.8 && s.t_access_ns() < 14.1);
    }

    #[test]
    fn sram_gang_shapes() {
        let m = SramConfig::default();
        assert_eq!(SramGang::In512Out8.shape(&m), (512, 8));
        assert_eq!(SramGang::In256Out16.shape(&m), (256, 16));
    }

    #[test]
    fn hb_bandwidth_meets_dram_per_bank() {
        // §3.3: HB (256 bonds × 6.4 Gbps = 204.8 GB/s) fully covers the
        // 32 GB/s per-bank DRAM read-out.
        let hb = HbConfig::default();
        assert!((hb.gbs_per_bank() - 204.8).abs() < 1e-9);
        assert!(hb.gbs_per_bank() > DramConfig::default().per_bank_gbs());
    }

    #[test]
    fn voltage_clamps() {
        assert_eq!(Voltage(1.5).clamp().0, 0.9);
        assert_eq!(Voltage(0.1).clamp().0, 0.6);
    }

    #[test]
    fn fidelity_names_roundtrip() {
        for f in NocFidelity::all() {
            assert_eq!(NocFidelity::by_name(f.label()), Some(f));
        }
        assert_eq!(NocFidelity::by_name("nope"), None);
        // library default is analytic (the historical behaviour)
        assert_eq!(NocFidelity::default(), NocFidelity::Analytic);
    }
}
