//! LLM model zoo: the architectures the paper evaluates, plus a tiny config
//! used for end-to-end numeric validation against the JAX/Pallas artifacts.

/// Transformer architecture description (decoder-only, Llama-style).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads; < n_heads ⇒ grouped-query attention (GQA).
    pub n_kv_heads: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    /// Uses gated FFN (SiLU gate, Llama-style) vs plain GELU MLP (GPT-style).
    pub gated_ffn: bool,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// GQA group size (query heads per KV head).
    pub fn gqa_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn is_gqa(&self) -> bool {
        self.n_kv_heads < self.n_heads
    }

    /// Weight parameter count of one transformer block's FC layers.
    pub fn block_fc_params(&self) -> u64 {
        let d = self.d_model as u64;
        let kv = (self.n_kv_heads * self.d_head()) as u64;
        let f = self.d_ffn as u64;
        // Q + K + V + O
        let attn = d * d + 2 * d * kv + d * d;
        // gated: up + gate + down; plain: up + down
        let ffn = if self.gated_ffn { 3 * d * f } else { 2 * d * f };
        attn + ffn
    }

    /// Total FC parameter count across all blocks (embeddings excluded: they
    /// are lookup, not PIM matrix work).
    pub fn total_fc_params(&self) -> u64 {
        self.block_fc_params() * self.n_layers as u64
    }

    /// Bytes of one token's KV-cache entry across all layers (BF16).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_kv_heads * self.d_head() * self.n_layers * 2) as u64
    }

    // ---- model zoo (paper §6) ----

    pub fn llama2_7b() -> Self {
        Self {
            name: "llama2-7b",
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            d_ffn: 11008,
            vocab: 32000,
            gated_ffn: true,
        }
    }

    pub fn llama2_13b() -> Self {
        Self {
            name: "llama2-13b",
            n_layers: 40,
            d_model: 5120,
            n_heads: 40,
            n_kv_heads: 40,
            d_ffn: 13824,
            vocab: 32000,
            gated_ffn: true,
        }
    }

    pub fn llama2_70b() -> Self {
        Self {
            name: "llama2-70b",
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            d_ffn: 28672,
            vocab: 32000,
            gated_ffn: true,
        }
    }

    pub fn qwen_72b() -> Self {
        Self {
            name: "qwen-72b",
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 64,
            d_ffn: 24576,
            vocab: 151936,
            gated_ffn: true,
        }
    }

    pub fn gpt3_175b() -> Self {
        Self {
            name: "gpt3-175b",
            n_layers: 96,
            d_model: 12288,
            n_heads: 96,
            n_kv_heads: 96,
            d_ffn: 49152,
            vocab: 50257,
            gated_ffn: false,
        }
    }

    /// Tiny Llama-style config for end-to-end numeric validation against the
    /// AOT-compiled JAX model (must match python/compile/model.py TINY).
    pub fn tiny() -> Self {
        Self {
            name: "tiny",
            n_layers: 2,
            d_model: 64,
            n_heads: 4,
            n_kv_heads: 4,
            d_ffn: 128,
            vocab: 256,
            gated_ffn: true,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" => Some(Self::llama2_7b()),
            "llama2-13b" => Some(Self::llama2_13b()),
            "llama2-70b" => Some(Self::llama2_70b()),
            "qwen-72b" => Some(Self::qwen_72b()),
            "gpt3-175b" => Some(Self::gpt3_175b()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    pub fn zoo() -> Vec<Self> {
        vec![
            Self::llama2_7b(),
            Self::llama2_13b(),
            Self::llama2_70b(),
            Self::qwen_72b(),
            Self::gpt3_175b(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_param_counts_in_expected_range() {
        // FC params are the bulk; checks the configs are the real models.
        let b7 = ModelConfig::llama2_7b().total_fc_params() as f64 / 1e9;
        assert!((5.5..7.5).contains(&b7), "7B fc params = {b7}B");
        let b13 = ModelConfig::llama2_13b().total_fc_params() as f64 / 1e9;
        assert!((11.0..13.5).contains(&b13), "13B fc params = {b13}B");
        let b70 = ModelConfig::llama2_70b().total_fc_params() as f64 / 1e9;
        assert!((60.0..70.0).contains(&b70), "70B fc params = {b70}B");
        let b175 = ModelConfig::gpt3_175b().total_fc_params() as f64 / 1e9;
        assert!((165.0..180.0).contains(&b175), "175B fc params = {b175}B");
    }

    #[test]
    fn gqa_detection() {
        assert!(!ModelConfig::llama2_7b().is_gqa());
        assert!(ModelConfig::llama2_70b().is_gqa());
        assert_eq!(ModelConfig::llama2_70b().gqa_group(), 8);
    }

    #[test]
    fn head_dims() {
        assert_eq!(ModelConfig::llama2_7b().d_head(), 128);
        assert_eq!(ModelConfig::gpt3_175b().d_head(), 128);
    }

    #[test]
    fn kv_bytes_per_token() {
        // 7B: 2 (K,V) * 32 heads * 128 dim * 32 layers * 2 B = 512 KiB/token
        assert_eq!(ModelConfig::llama2_7b().kv_bytes_per_token(), 524_288);
        // 70B GQA: 8 kv heads → 8x smaller per layer but 80 layers
        assert_eq!(ModelConfig::llama2_70b().kv_bytes_per_token(), 327_680);
    }

    #[test]
    fn by_name_roundtrip() {
        for m in ModelConfig::zoo() {
            assert_eq!(ModelConfig::by_name(m.name).unwrap(), m);
        }
        assert!(ModelConfig::by_name("nope").is_none());
    }
}
