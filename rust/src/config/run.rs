//! Run configuration: which architecture variant, model, and workload shape a
//! simulation executes. Constructed from CLI flags or a TOML-subset file.

use crate::util::json::{Json, ToJson};

use super::hw::{HwConfig, NocFidelity, SramGang, Voltage};
use super::model::ModelConfig;
use super::toml::Doc;

/// Architecture variants evaluated in the paper (§7.1 ablation).
/// `Hash` lets the cached cost model key memo entries by variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArchKind {
    /// CENT: pure DRAM-PIM, centralized NLU in the CXL controller.
    Cent,
    /// CENT + localized Curry ALUs (ablation step i).
    CentCurry,
    /// CompAir with baseline 32:1 column decoder (ablation step ii).
    CompAirBase,
    /// Full CompAir with decoupled column decoder (ablation step iii).
    CompAirOpt,
    /// SRAM-PIM stacking DRAM (motivation baseline, Fig 4).
    SramStack,
    /// AttAcc: A100 GPUs + HBM-PIM (hybrid baseline, Fig 15).
    AttAcc,
}

impl ArchKind {
    pub fn label(&self) -> &'static str {
        match self {
            ArchKind::Cent => "CENT",
            ArchKind::CentCurry => "CENT_Curry_ALU",
            ArchKind::CompAirBase => "CompAir_Base",
            ArchKind::CompAirOpt => "CompAir_Opt",
            ArchKind::SramStack => "SRAM_stack",
            ArchKind::AttAcc => "AttAcc",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "cent" => Some(ArchKind::Cent),
            "cent-curry" | "cent_curry_alu" => Some(ArchKind::CentCurry),
            "compair-base" | "compair_base" => Some(ArchKind::CompAirBase),
            "compair" | "compair-opt" | "compair_opt" => Some(ArchKind::CompAirOpt),
            "sram-stack" | "sram_stack" => Some(ArchKind::SramStack),
            "attacc" => Some(ArchKind::AttAcc),
            _ => None,
        }
    }

    /// Every variant, in the paper's ablation order. The single source the
    /// CLI's `list` output derives its arch names from.
    pub fn all() -> [ArchKind; 6] {
        [
            ArchKind::Cent,
            ArchKind::CentCurry,
            ArchKind::CompAirBase,
            ArchKind::CompAirOpt,
            ArchKind::SramStack,
            ArchKind::AttAcc,
        ]
    }

    /// The canonical CLI spelling ([`ArchKind::by_name`] accepts it).
    pub fn cli_name(&self) -> &'static str {
        match self {
            ArchKind::Cent => "cent",
            ArchKind::CentCurry => "cent-curry",
            ArchKind::CompAirBase => "compair-base",
            ArchKind::CompAirOpt => "compair-opt",
            ArchKind::SramStack => "sram-stack",
            ArchKind::AttAcc => "attacc",
        }
    }

    /// Does this variant have SRAM-PIM under the DRAM banks?
    pub fn has_sram(&self) -> bool {
        matches!(self, ArchKind::CompAirBase | ArchKind::CompAirOpt | ArchKind::SramStack)
    }

    /// Does this variant have Curry ALUs in the NoC?
    pub fn has_curry(&self) -> bool {
        matches!(self, ArchKind::CentCurry | ArchKind::CompAirBase | ArchKind::CompAirOpt)
    }
}

/// FC-layer mapping strategy across banks (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FcMapping {
    /// Split the output dimension across banks (baseline DRAM-PIM mapping;
    /// avoids inter-bank reduction, needs input broadcast).
    OutputSplit,
    /// Split the input dimension across banks (needs inter-bank reduction,
    /// which CompAir-NoC makes cheap).
    InputSplit,
}

impl FcMapping {
    pub fn label(&self) -> &'static str {
        match self {
            FcMapping::OutputSplit => "output-split",
            FcMapping::InputSplit => "input-split",
        }
    }
}

/// How operators are placed onto engines (see `mapper`): the hard-coded
/// per-variant assignment, or a per-(phase, shape-class) search clamped to
/// never lose to it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum MappingMode {
    /// The static placement `arch/system.rs` has always used (default —
    /// results are bit-identical to the pre-mapper simulator).
    #[default]
    Static,
    /// Search DRAM-PIM / SRAM-PIM / NoC-ALU / host placement per phase and
    /// shape-class; falls back to static whenever the search cannot
    /// strictly beat it.
    Auto,
}

impl MappingMode {
    pub fn label(&self) -> &'static str {
        match self {
            MappingMode::Static => "static",
            MappingMode::Auto => "auto",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(MappingMode::Static),
            "auto" => Some(MappingMode::Auto),
            _ => None,
        }
    }
}

/// Inference phase. `Hash` lets the cached cost model key memo entries by
/// phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Prefill,
    Decode,
}

impl Phase {
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

/// One simulation run request.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub arch: ArchKind,
    pub model: ModelConfig,
    pub hw: HwConfig,
    pub phase: Phase,
    pub batch: usize,
    /// Context length (tokens already in the KV cache for decode; prompt
    /// length for prefill).
    pub seq_len: usize,
    /// Tokens to generate (decode steps simulated; latency is reported per
    /// token, energy per token).
    pub gen_len: usize,
    /// Tensor-parallel degree across devices.
    pub tp: usize,
    /// Devices available in the CXL fabric.
    pub devices: usize,
    pub sram_gang: SramGang,
    pub fc_mapping: FcMapping,
    /// Operator→engine placement policy: the static per-variant assignment
    /// or the per-shape-class auto search (see `mapper`). Never part of a
    /// memoization key — mapped results are keyed by the concrete
    /// `Mapping` they were priced under, not by the policy that chose it.
    pub mapping: MappingMode,
    /// How NoC collective costs are priced (see `noc::model`): analytic
    /// closed forms, simulator-calibrated closed forms, or the flit-level
    /// simulator itself. Part of every cost-model memoization key.
    pub noc_fidelity: NocFidelity,
    /// Worker threads for the parallel-capable paths this run touches
    /// (calibration anchor fits today; sweeps fan out above `RunConfig`).
    /// `1` (the library default) is fully serial; any value produces
    /// bit-identical results — the pool merges in submission order (see
    /// `util::pool`). Echoed into JSON reports as provenance; never part
    /// of a memoization key because it cannot change a result.
    pub jobs: usize,
}

impl RunConfig {
    pub fn new(arch: ArchKind, model: ModelConfig) -> Self {
        let hw = if arch == ArchKind::CompAirOpt { HwConfig::paper_opt() } else { HwConfig::paper() };
        Self {
            arch,
            model,
            hw,
            phase: Phase::Decode,
            batch: 1,
            seq_len: 4096,
            gen_len: 1,
            tp: 8,
            devices: 32,
            sram_gang: SramGang::In256Out16,
            fc_mapping: FcMapping::OutputSplit,
            mapping: MappingMode::Static,
            // the library default is analytic and explicit — there is no
            // process-wide mutable default (it was a data race waiting to
            // happen under the worker pool); the CLI threads its
            // per-subcommand default through every constructor instead
            noc_fidelity: NocFidelity::Analytic,
            jobs: 1,
        }
    }

    pub fn with(mut self, f: impl FnOnce(&mut Self)) -> Self {
        f(&mut self);
        self
    }

    /// Apply overrides from a parsed TOML-subset document ([run] + [hw.*]).
    pub fn apply_doc(&mut self, doc: &Doc) -> Result<(), String> {
        if let Some(m) = doc.get_str("run.model") {
            self.model =
                ModelConfig::by_name(m).ok_or_else(|| format!("unknown model '{m}'"))?;
        }
        if let Some(a) = doc.get_str("run.arch") {
            self.arch = ArchKind::by_name(a).ok_or_else(|| format!("unknown arch '{a}'"))?;
            if self.arch == ArchKind::CompAirOpt {
                self.hw = HwConfig::paper_opt();
            }
        }
        if let Some(p) = doc.get_str("run.phase") {
            self.phase = match p {
                "prefill" => Phase::Prefill,
                "decode" => Phase::Decode,
                _ => return Err(format!("unknown phase '{p}'")),
            };
        }
        if let Some(v) = doc.get_int("run.batch") {
            self.batch = v as usize;
        }
        if let Some(v) = doc.get_int("run.seqlen") {
            self.seq_len = v as usize;
        }
        if let Some(v) = doc.get_int("run.genlen") {
            self.gen_len = v as usize;
        }
        if let Some(v) = doc.get_int("run.tp") {
            self.tp = v as usize;
        }
        if let Some(v) = doc.get_int("run.devices") {
            self.devices = v as usize;
        }
        if let Some(g) = doc.get_str("run.sram_gang") {
            self.sram_gang = match g {
                "512x8" | "(512,8)" => SramGang::In512Out8,
                "256x16" | "(256,16)" => SramGang::In256Out16,
                _ => return Err(format!("unknown sram_gang '{g}'")),
            };
        }
        if let Some(m) = doc.get_str("run.fc_mapping") {
            self.fc_mapping = match m {
                "output-split" => FcMapping::OutputSplit,
                "input-split" => FcMapping::InputSplit,
                _ => return Err(format!("unknown fc_mapping '{m}'")),
            };
        }
        if let Some(m) = doc.get_str("run.mapping") {
            self.mapping = MappingMode::by_name(m)
                .ok_or_else(|| format!("unknown mapping '{m}' (static | auto)"))?;
        }
        if let Some(f) = doc.get_str("run.noc_fidelity") {
            self.noc_fidelity = NocFidelity::by_name(f)
                .ok_or_else(|| format!("unknown noc_fidelity '{f}' (analytic | calibrated | simulated)"))?;
        }
        if let Some(v) = doc.get_int("run.jobs") {
            if v < 1 {
                return Err("run.jobs must be >= 1".into());
            }
            self.jobs = v as usize;
        }
        if let Some(v) = doc.get_float("hw.sram.voltage") {
            self.hw.sram.voltage = Voltage(v).clamp();
        }
        if let Some(v) = doc.get_float("hw.dram.t_ras_ns") {
            self.hw.dram.t_ras_ns = v;
        }
        if let Some(v) = doc.get_int("hw.cxl.devices") {
            self.hw.cxl.devices = v as usize;
        }
        if self.tp == 0 || self.batch == 0 || self.devices == 0 {
            return Err("tp, batch and devices must be positive".into());
        }
        if self.seq_len == 0 || self.gen_len == 0 {
            return Err("seqlen and genlen must be positive".into());
        }
        if self.tp > self.devices {
            return Err(format!("tp ({}) exceeds devices ({})", self.tp, self.devices));
        }
        Ok(())
    }
}

/// The run-shape summary echoed into every JSON report so a result is
/// self-describing without the command line that produced it.
impl ToJson for RunConfig {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("arch", self.arch.label())
            .field("model", self.model.name)
            .field("phase", self.phase.label())
            .field("batch", self.batch)
            .field("seq_len", self.seq_len)
            .field("gen_len", self.gen_len)
            .field("tp", self.tp)
            .field("devices", self.devices)
            .field("fc_mapping", self.fc_mapping.label())
            .field("mapping", self.mapping.label())
            .field("noc_fidelity", self.noc_fidelity.label())
            .field("jobs", self.jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    #[test]
    fn arch_names_roundtrip() {
        for a in ArchKind::all() {
            assert_eq!(ArchKind::by_name(&a.label().to_ascii_lowercase()), Some(a));
            assert_eq!(ArchKind::by_name(a.cli_name()), Some(a), "cli_name must parse");
        }
    }

    #[test]
    fn capability_flags() {
        assert!(!ArchKind::Cent.has_sram());
        assert!(!ArchKind::Cent.has_curry());
        assert!(ArchKind::CentCurry.has_curry());
        assert!(ArchKind::CompAirOpt.has_sram());
        assert!(ArchKind::CompAirOpt.has_curry());
    }

    #[test]
    fn doc_overrides_apply() {
        let doc = toml::parse(
            r#"
[run]
model = "llama2-13b"
arch = "compair-opt"
phase = "prefill"
batch = 32
seqlen = 8192
tp = 4
sram_gang = "512x8"
fc_mapping = "input-split"
[hw.sram]
voltage = 0.7
"#,
        )
        .unwrap();
        let mut rc = RunConfig::new(ArchKind::Cent, ModelConfig::llama2_7b());
        rc.apply_doc(&doc).unwrap();
        assert_eq!(rc.model.name, "llama2-13b");
        assert_eq!(rc.arch, ArchKind::CompAirOpt);
        assert_eq!(rc.phase, Phase::Prefill);
        assert_eq!(rc.batch, 32);
        assert_eq!(rc.seq_len, 8192);
        assert_eq!(rc.tp, 4);
        assert_eq!(rc.sram_gang, SramGang::In512Out8);
        assert_eq!(rc.fc_mapping, FcMapping::InputSplit);
        assert!((rc.hw.sram.voltage.0 - 0.7).abs() < 1e-9);
        // CompAirOpt upgrade switched the decoder.
        assert_eq!(
            rc.hw.dram.column_decoder,
            crate::config::hw::ColumnDecoder::Decoupled8and4
        );
    }

    #[test]
    fn mapping_mode_roundtrips_and_defaults_static() {
        assert_eq!(RunConfig::new(ArchKind::Cent, ModelConfig::llama2_7b()).mapping, MappingMode::Static);
        for m in [MappingMode::Static, MappingMode::Auto] {
            assert_eq!(MappingMode::by_name(m.label()), Some(m));
        }
        assert_eq!(MappingMode::by_name("AUTO"), Some(MappingMode::Auto));
        assert_eq!(MappingMode::by_name("beam"), None);
    }

    #[test]
    fn doc_mapping_applies_and_rejects() {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        let doc = toml::parse("[run]\nmapping = \"auto\"").unwrap();
        rc.apply_doc(&doc).unwrap();
        assert_eq!(rc.mapping, MappingMode::Auto);
        let doc = toml::parse("[run]\nmapping = \"greedy\"").unwrap();
        assert!(rc.apply_doc(&doc).is_err());
        // the JSON echo is self-describing
        let j = rc.to_json().render();
        assert!(j.contains("\"mapping\":\"auto\""), "{j}");
    }

    #[test]
    fn doc_noc_fidelity_applies_and_rejects() {
        let mut rc = RunConfig::new(ArchKind::Cent, ModelConfig::llama2_7b());
        assert_eq!(rc.noc_fidelity, NocFidelity::Analytic);
        let doc = toml::parse("[run]\nnoc_fidelity = \"calibrated\"").unwrap();
        rc.apply_doc(&doc).unwrap();
        assert_eq!(rc.noc_fidelity, NocFidelity::Calibrated);
        let doc = toml::parse("[run]\nnoc_fidelity = \"exact\"").unwrap();
        assert!(rc.apply_doc(&doc).is_err());
    }

    #[test]
    fn doc_rejects_bad_values() {
        let mut rc = RunConfig::new(ArchKind::Cent, ModelConfig::llama2_7b());
        let doc = toml::parse("[run]\nmodel = \"nope\"").unwrap();
        assert!(rc.apply_doc(&doc).is_err());
        let doc = toml::parse("[run]\ntp = 64\ndevices = 8").unwrap();
        assert!(rc.apply_doc(&doc).is_err());
    }
}
