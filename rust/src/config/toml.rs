//! A small TOML-subset parser for user-facing run configuration files.
//!
//! No serde/toml crates are vendored offline, so the launcher carries its
//! own parser. Supported subset (sufficient for run configs):
//!
//! * `[section]` and `[section.sub]` headers
//! * `key = value` with value ∈ {integer, float, bool, "string", [array of
//!   scalars]}
//! * `#` comments, blank lines
//!
//! Keys are exposed flattened as `section.sub.key`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: flattened `section.key → value`.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub values: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_int(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_int)
    }

    pub fn get_float(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_float)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    /// Keys under a section prefix, e.g. `keys_under("run")`.
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let pfx = format!("{prefix}.");
        self.values.keys().filter(move |k| k.starts_with(&pfx)).map(|k| k.as_str())
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() || !name.chars().all(|c| c.is_alphanumeric() || "._-".contains(c)) {
                return Err(err(lineno, &format!("bad section name '{name}'")));
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || "._-".contains(c)) {
            return Err(err(lineno, &format!("bad key '{key}'")));
        }
        let vtext = line[eq + 1..].trim();
        let value = parse_value(vtext).map_err(|m| err(lineno, &m))?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        doc.values.insert(full, value);
    }
    Ok(doc)
}

fn err(line: usize, msg: &str) -> ParseError {
    ParseError { line, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = body.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split an array body on commas (no nested arrays in the subset, but
/// strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse(
            r#"
# run configuration
top = 1
[run]
model = "llama2-7b"   # the model
batch = 64
seqlen = 4096
tp = 8
use_sram = true
ratio = 0.75
sweep = [1, 2, 4, 8]
[hw.dram]
t_ras_ns = 27.0
"#,
        )
        .unwrap();
        assert_eq!(doc.get_int("top"), Some(1));
        assert_eq!(doc.get_str("run.model"), Some("llama2-7b"));
        assert_eq!(doc.get_int("run.batch"), Some(64));
        assert_eq!(doc.get_bool("run.use_sram"), Some(true));
        assert_eq!(doc.get_float("run.ratio"), Some(0.75));
        assert_eq!(doc.get_float("hw.dram.t_ras_ns"), Some(27.0));
        let arr = doc.get("run.sweep").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[3].as_int(), Some(8));
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.get_float("x"), Some(3.0));
    }

    #[test]
    fn string_with_hash_and_comma() {
        let doc = parse(r#"s = "a#b,c""#).unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b,c"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn rejects_garbage_values() {
        assert!(parse("a = @@").is_err());
        assert!(parse("a = \"open").is_err());
        assert!(parse("a = [1, 2").is_err());
    }

    #[test]
    fn empty_array_ok() {
        let doc = parse("a = []").unwrap();
        assert_eq!(doc.get("a").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn keys_under_prefix() {
        let doc = parse("[s]\na = 1\nb = 2\n[t]\nc = 3").unwrap();
        let keys: Vec<_> = doc.keys_under("s").collect();
        assert_eq!(keys, vec!["s.a", "s.b"]);
    }
}
