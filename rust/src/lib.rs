//! CompAir full-system reproduction library.
//!
//! Three-layer architecture:
//! * L3 (this crate): cycle-approximate simulators for every hardware
//!   substrate in the paper + the SLO-aware serving coordinator;
//! * L2 (python/compile/model.py): JAX transformer block, AOT-lowered to HLO
//!   text under `artifacts/`;
//! * L1 (python/compile/kernels/): Pallas kernels for the compute hot-spots,
//!   validated against a pure-jnp oracle.
//!
//! See README.md for the module map and docs/ARCHITECTURE.md for the
//! module-to-paper mapping and the request-lifecycle walkthrough.
//!
//! The public API funnels through two layers (see docs/ARCHITECTURE.md
//! §"API surface"): the [`arch::CostModel`] trait prices workload shapes on
//! one hardware point (with [`arch::CachedCostModel`] memoizing the serving
//! hot path), and the [`Engine`] facade dispatches every evaluation mode —
//! one-shot simulation, serving, cluster runs — returning report structs
//! that serialize via [`util::json::ToJson`].
#![forbid(unsafe_code)]

pub mod analysis;
pub mod api;
pub mod arch;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod mapper;
pub mod runtime;
pub mod energy;
pub mod figures;
pub mod workload;
pub mod isa;
pub mod noc;
pub mod dram;
pub mod sim;
pub mod sram;
pub mod util;

pub use api::Engine;
