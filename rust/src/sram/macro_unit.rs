//! One SRAM-PIM macro: a 64 kb digital CIM array computing a 128-input ×
//! 8-output BF16 MAC per access, with voltage-scaled latency/efficiency.

use crate::config::SramConfig;
use crate::sim::{CostCounts, OpCost};
use crate::util::bf16::{bf16_mac, bf16_round};

/// One macro. Stateless for timing (latency is per access); carries optional
/// functional weights for numeric validation.
#[derive(Debug, Clone)]
pub struct SramMacro {
    pub cfg: SramConfig,
    /// Functional weight state, row-major `outputs × inputs` (None until
    /// loaded). Timing paths never touch it.
    weights: Option<Vec<f32>>,
}

impl SramMacro {
    pub fn new(cfg: &SramConfig) -> Self {
        Self { cfg: cfg.clone(), weights: None }
    }

    /// Cost of one MAC access: consumes `inputs` BF16 values, produces
    /// `outputs` BF16 partial sums, performing inputs×outputs MACs.
    pub fn access(&self) -> OpCost {
        OpCost {
            latency_ns: self.cfg.t_access_ns(),
            counts: CostCounts {
                sram_access: 1,
                sram_mac: self.cfg.macs_per_access() as u64,
                ..Default::default()
            },
        }
    }

    /// Cost of (re)loading the macro's full weight tile (128×8 BF16 rows
    /// written through the write port). HB transfer cost is accounted by the
    /// DRAM side (`read_to_sram`); this is the array-write time.
    pub fn load_weights_cost(&self) -> OpCost {
        let rows = self.cfg.macro_outputs as u64; // one output-column row per write
        OpCost {
            latency_ns: rows as f64 * self.cfg.t_write_row_ns,
            counts: CostCounts { sram_row_write: rows, ..Default::default() },
        }
    }

    /// Functionally load weights (row-major `outputs × inputs`).
    pub fn load_weights(&mut self, w: &[f32]) {
        assert_eq!(w.len(), self.cfg.macro_inputs * self.cfg.macro_outputs);
        self.weights = Some(w.iter().map(|&v| bf16_round(v)).collect());
    }

    /// Functionally execute one access: `y[o] += Σ_i w[o,i]·x[i]` in BF16.
    pub fn compute(&self, x: &[f32]) -> Vec<f32> {
        let w = self.weights.as_ref().expect("weights not loaded");
        let (i_n, o_n) = (self.cfg.macro_inputs, self.cfg.macro_outputs);
        assert_eq!(x.len(), i_n);
        (0..o_n)
            .map(|o| {
                let mut acc = 0.0f32;
                for i in 0..i_n {
                    acc = bf16_mac(acc, w[o * i_n + i], x[i]);
                }
                bf16_round(acc)
            })
            .collect()
    }

    /// Peak throughput in GFLOPS at the configured voltage.
    pub fn gflops(&self) -> f64 {
        2.0 * self.cfg.macs_per_access() as f64 / self.cfg.t_access_ns()
    }

    /// Power when continuously active, in W.
    pub fn active_power_w(&self) -> f64 {
        self.gflops() / 1e3 / self.cfg.tflops_w()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Voltage;

    #[test]
    fn access_cost_scales_with_voltage() {
        let mut cfg = SramConfig::default();
        cfg.voltage = Voltage(0.9);
        let fast = SramMacro::new(&cfg).access();
        cfg.voltage = Voltage(0.6);
        let slow = SramMacro::new(&cfg).access();
        assert!(slow.latency_ns > fast.latency_ns);
        assert_eq!(fast.counts.sram_mac, 1024);
    }

    #[test]
    fn throughput_and_power_sane() {
        let m = SramMacro::new(&SramConfig::default());
        // 2*1024 flops / 6.8ns ≈ 301 GFLOPS
        assert!((m.gflops() - 301.17).abs() < 1.0, "gflops={}", m.gflops());
        // at 14.4 TFLOPS/W → ~0.021 W, the §3.2 "8KB SRAM-PIMs consume
        // merely 0.022W" figure.
        let p = m.active_power_w();
        assert!((0.015..0.03).contains(&p), "power={p}");
    }

    #[test]
    fn functional_compute_matches_f32() {
        use crate::util::XorShiftRng;
        let cfg = SramConfig::default();
        let mut m = SramMacro::new(&cfg);
        let mut r = XorShiftRng::new(11);
        let w = r.vec_f32(cfg.macro_inputs * cfg.macro_outputs, -1.0, 1.0);
        let x = r.vec_f32(cfg.macro_inputs, -1.0, 1.0);
        m.load_weights(&w);
        let y = m.compute(&x);
        for o in 0..cfg.macro_outputs {
            let exact: f32 =
                (0..cfg.macro_inputs).map(|i| w[o * cfg.macro_inputs + i] * x[i]).sum();
            assert!((y[o] - exact).abs() < 0.3, "y={} exact={exact}", y[o]);
        }
    }

    #[test]
    #[should_panic(expected = "weights not loaded")]
    fn compute_without_weights_panics() {
        let m = SramMacro::new(&SramConfig::default());
        m.compute(&vec![0.0; 128]);
    }

    #[test]
    fn weight_load_cost_counts_rows() {
        let m = SramMacro::new(&SramConfig::default());
        assert_eq!(m.load_weights_cost().counts.sram_row_write, 8);
    }
}
