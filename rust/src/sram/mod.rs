//! SRAM-PIM substrate: the 28nm fabricated digital CIM macro [Guo+ ISSCC'23]
//! and the per-bank gang of four macros hybrid-bonded under a DRAM bank.
pub mod bank;
pub mod macro_unit;

pub use bank::SramBank;
pub use macro_unit::SramMacro;
