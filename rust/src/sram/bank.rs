//! The SRAM-PIM bank: four macros hybrid-bonded under one DRAM-PIM bank,
//! ganged as (512,8) or (256,16) (§3.3), executing batched GeMM tiles with
//! weights streamed from the DRAM bank above.
//!
//! The per-bank GeMM latency is a roofline over two rates:
//! * compute: `accesses × t_access` (one 128×8 MAC array access per tile
//!   column per batch element);
//! * feed: DRAM read-out through the column decoder's SRAM path + HB (weights
//!   once per tile, inputs once per batch, outputs written back).
//!
//! Double-buffering overlaps feed and compute, so the bank runs at
//! `max(compute, feed)` — the divergence-point behaviour of the Fig 20 DSE.

use crate::config::{DramConfig, SramConfig, SramGang};
use crate::dram::PimBank;
use crate::sim::{CostCounts, OpCost};

use super::macro_unit::SramMacro;

/// Weight residency across calls: decode loops reuse the same FC weights
/// every token, but a bank tile rarely fits, so `Reload` is the common case.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightPolicy {
    /// Stream weights from DRAM for every tile (default).
    Reload,
    /// Weights already resident in the macros (single-tile workloads).
    Resident,
}

/// The per-bank SRAM-PIM compute unit.
#[derive(Debug, Clone)]
pub struct SramBank {
    pub sram: SramConfig,
    pub gang: SramGang,
    dram: PimBank,
}

/// Cost breakdown of one bank-level GeMM (returned alongside the total).
#[derive(Debug, Clone, Copy, Default)]
pub struct GemmBreakdown {
    pub compute_ns: f64,
    pub feed_ns: f64,
    pub writeback_ns: f64,
    pub reload_ns: f64,
    pub accesses: u64,
    pub weight_bytes: u64,
    pub io_bytes: u64,
}

impl SramBank {
    pub fn new(sram: &SramConfig, gang: SramGang, dram: &DramConfig) -> Self {
        Self { sram: sram.clone(), gang, dram: PimBank::new(dram) }
    }

    /// Logical ganged shape (inputs, outputs).
    pub fn shape(&self) -> (usize, usize) {
        self.gang.shape(&self.sram)
    }

    /// Batched GeMM of a `out_tile × in_dim` weight tile against `batch`
    /// input vectors, all bank-local.
    pub fn gemm(&self, out_tile: usize, in_dim: usize, batch: usize, policy: WeightPolicy) -> OpCost {
        self.gemm_detailed(out_tile, in_dim, batch, policy).0
    }

    pub fn gemm_detailed(
        &self,
        out_tile: usize,
        in_dim: usize,
        batch: usize,
        policy: WeightPolicy,
    ) -> (OpCost, GemmBreakdown) {
        if out_tile == 0 || in_dim == 0 || batch == 0 {
            return (OpCost::zero(), GemmBreakdown::default());
        }
        let (gi, go) = self.shape();
        let n_in_tiles = in_dim.div_ceil(gi) as u64;
        let n_out_tiles = out_tile.div_ceil(go) as u64;
        let n_tiles = n_in_tiles * n_out_tiles;
        let accesses = n_tiles * batch as u64;

        // Compute: one array access per (tile, batch element); partial sums
        // across in-tiles accumulate in the macro's accumulator registers.
        let compute_ns = accesses as f64 * self.sram.t_access_ns();
        let macs = (out_tile * in_dim * batch) as u64;

        // Feed: weights once per tile (unless resident) + inputs once per
        // batch element, through the DRAM column decoder's SRAM path.
        let weight_bytes = match policy {
            WeightPolicy::Reload => (in_dim * out_tile * 2) as u64,
            WeightPolicy::Resident => 0,
        };
        let input_bytes = (in_dim * batch * 2) as u64;
        let feed = self.dram.read_to_sram(weight_bytes + input_bytes);
        // Results land back in the DRAM bank.
        let output_bytes = (out_tile * batch * 2) as u64;
        let writeback = self.dram.write(output_bytes);

        // Macro array weight-write time (per tile; overlaps poorly with the
        // array's own compute, so serialize it).
        let reload_ns = match policy {
            WeightPolicy::Reload => {
                n_tiles as f64 * SramMacro::new(&self.sram).load_weights_cost().latency_ns
            }
            WeightPolicy::Resident => 0.0,
        };

        let feed_total_ns = feed.latency_ns + writeback.latency_ns;
        let latency_ns = compute_ns.max(feed_total_ns) + reload_ns;

        let counts = CostCounts {
            sram_access: accesses,
            sram_mac: macs,
            sram_row_write: if policy == WeightPolicy::Reload {
                n_tiles * self.sram.macro_outputs as u64 * 4
            } else {
                0
            },
            ..Default::default()
        }
        .add(&feed.counts)
        .add(&writeback.counts);
        // Output write-back also crosses the HB interface (logic → DRAM die).
        let counts = CostCounts { hb_bytes: counts.hb_bytes + output_bytes, ..counts };

        (
            OpCost { latency_ns, counts },
            GemmBreakdown {
                compute_ns,
                feed_ns: feed.latency_ns,
                writeback_ns: writeback.latency_ns,
                reload_ns,
                accesses,
                weight_bytes,
                io_bytes: input_bytes + output_bytes,
            },
        )
    }

    /// Is this GeMM compute-bound (past the Fig 20 divergence point)?
    pub fn is_compute_bound(&self, out_tile: usize, in_dim: usize, batch: usize) -> bool {
        let (_, b) = self.gemm_detailed(out_tile, in_dim, batch, WeightPolicy::Reload);
        b.compute_ns > b.feed_ns + b.writeback_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ColumnDecoder, HwConfig};

    fn bank(gang: SramGang) -> SramBank {
        let hw = HwConfig::paper();
        SramBank::new(&hw.sram, gang, &hw.dram)
    }

    #[test]
    fn batch_amortizes_weight_streaming() {
        // The key §2.2 effect: DRAM-PIM re-streams weights per batch element;
        // SRAM-PIM streams them once. Speedup must grow with batch.
        let s = bank(SramGang::In256Out16);
        let d = PimBank::new(&HwConfig::paper().dram);
        let (o, i) = (10, 5120); // Llama2-13B per-bank Q tile (§3.3)
        let t_d1 = d.gemv(o, i, 1).latency_ns;
        let t_s1 = s.gemm(o, i, 1, WeightPolicy::Reload).latency_ns;
        let t_d32 = d.gemv(o, i, 32).latency_ns;
        let t_s32 = s.gemm(o, i, 32, WeightPolicy::Reload).latency_ns;
        let sp1 = t_d1 / t_s1;
        let sp32 = t_d32 / t_s32;
        assert!(sp1 < 1.5, "batch=1 speedup should be marginal, got {sp1}");
        assert!(sp32 > 4.0, "batch=32 speedup should be large, got {sp32}");
        assert!(sp32 > sp1 * 3.0);
    }

    #[test]
    fn balanced_gang_reduces_feed_pressure() {
        // §3.3: (256,16) halves the weight tiles' dimensional imbalance and
        // beats (512,8) when feed-bound.
        let a = bank(SramGang::In512Out8);
        let b = bank(SramGang::In256Out16);
        let (_, ba) = a.gemm_detailed(16, 4096, 16, WeightPolicy::Reload);
        let (_, bb) = b.gemm_detailed(16, 4096, 16, WeightPolicy::Reload);
        // same MAC count, fewer accesses for the balanced gang on a
        // 16-output tile (it covers 16 outputs per access sweep).
        assert!(bb.accesses <= ba.accesses, "{} vs {}", bb.accesses, ba.accesses);
    }

    #[test]
    fn resident_weights_skip_reload() {
        let s = bank(SramGang::In256Out16);
        let (i, o) = (256, 16); // exactly one tile
        let reload = s.gemm(o, i, 4, WeightPolicy::Reload);
        let resident = s.gemm(o, i, 4, WeightPolicy::Resident);
        assert!(resident.latency_ns < reload.latency_ns);
        assert_eq!(resident.counts.sram_row_write, 0);
    }

    #[test]
    fn decoupled_decoder_speeds_feed_bound_gemm() {
        let hw = HwConfig::paper();
        let mut dram_opt = hw.dram.clone();
        dram_opt.column_decoder = ColumnDecoder::Decoupled8and4;
        let base = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
        let opt = SramBank::new(&hw.sram, SramGang::In256Out16, &dram_opt);
        // Large feed-bound GeMM (batch small → feed dominates)
        let t_base = base.gemm(16, 8192, 2, WeightPolicy::Reload).latency_ns;
        let t_opt = opt.gemm(16, 8192, 2, WeightPolicy::Reload).latency_ns;
        assert!(t_opt < t_base, "opt {t_opt} should beat base {t_base}");
    }

    #[test]
    fn large_batch_becomes_compute_bound() {
        let s = bank(SramGang::In256Out16);
        // skinny output tile at batch 1: feed-bound (left of the Fig 20
        // divergence point)
        assert!(!s.is_compute_bound(16, 4096, 1));
        // balanced tile at large batch: compute-bound (right of it)
        assert!(s.is_compute_bound(256, 2048, 512));
    }

    #[test]
    fn mac_counts_exact() {
        let s = bank(SramGang::In256Out16);
        let c = s.gemm(16, 512, 3, WeightPolicy::Reload);
        assert_eq!(c.counts.sram_mac, 16 * 512 * 3);
        // 2 in-tiles × 1 out-tile × 3 batch = 6 accesses
        assert_eq!(c.counts.sram_access, 6);
    }

    #[test]
    fn zero_dims_are_free() {
        let s = bank(SramGang::In512Out8);
        assert_eq!(s.gemm(0, 128, 1, WeightPolicy::Reload), OpCost::zero());
    }

    #[test]
    fn hb_traffic_includes_writeback() {
        let s = bank(SramGang::In256Out16);
        let c = s.gemm(16, 256, 2, WeightPolicy::Reload);
        let weight = 16 * 256 * 2;
        let input = 256 * 2 * 2;
        let output = 16 * 2 * 2;
        assert_eq!(c.counts.hb_bytes, (weight + input + output) as u64);
    }
}
