//! CompAir-NoC evaluation figures: Fig 21 (area), Fig 22 (Curry ALU latency
//! profits), Fig 23 (path-generation profits), and the beyond-paper
//! `noc-calibration` self-check table (analytic vs flit-level error per
//! collective per anchor shape).

use crate::arch::collective as coll;
use crate::config::{HwConfig, SramGang};
use crate::isa::{Machine, RowProgram};
use crate::noc::area::{curry_alus_resources, softmax_unit_resources, AreaModel};
use crate::noc::model::calibration_report;
use crate::util::pool::par_map_indexed;
use crate::util::table::{fnum, Table};

use super::FigCtx;

/// Fig 21: area of the per-bank logic stack and the Curry ALU share, plus
/// the FPGA-resource comparison against a dedicated Softmax unit.
pub fn fig21(_cx: &FigCtx) -> String {
    let a = AreaModel::default();
    let mut t = Table::new("Fig 21A — per-bank logic-die area (UMC 28nm)", &["component", "mm^2"]);
    t.rowv(vec!["4x SRAM-PIM macro".into(), fnum(4.0 * a.sram_macro_mm2)]);
    t.rowv(vec!["4x router".into(), fnum(4.0 * a.router_mm2)]);
    t.rowv(vec!["total (fits under 1mm^2 DRAM bank)".into(), fnum(a.bank_logic_mm2())]);
    t.rowv(vec![
        "Curry ALUs per router (2.94% of router)".into(),
        fnum(a.curry_alu_mm2()),
    ]);
    let c = curry_alus_resources();
    let s = softmax_unit_resources();
    let mut t2 = Table::new(
        "Fig 21B — FPGA resources: 4 Curry ALUs vs 16-input Softmax unit",
        &["design", "LUTs", "FFs", "BRAM(KB)"],
    );
    t2.rowv(vec!["4x Curry ALU (stream)".into(), (4 * c.luts).to_string(), (4 * c.ffs).to_string(), c.bram_kb.to_string()]);
    t2.rowv(vec!["Softmax-16 unit (buffered)".into(), s.luts.to_string(), s.ffs.to_string(), s.bram_kb.to_string()]);
    t.render() + "\n" + &t2.render()
}

/// Fig 22: latency of the non-linear path — distributed Curry ALUs vs the
/// centralized NLU round trip, per softmax batch.
pub fn fig22(_cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let mut t = Table::new(
        "Fig 22 — non-linear latency: centralized NLU vs Curry ALUs (softmax rows of seqlen)",
        &["seqlen", "rows", "NLU(us)", "Curry(us)", "reduction"],
    );
    let banks: u64 = 512;
    for (seq, rows) in [(4096u64, 512u64), (16384, 512), (65536, 512), (131072, 512)] {
        let elems = seq * rows;
        let nlu =
            coll::nlu_roundtrip(elems * 2, elems * 2, 5 * elems, 32, &hw.dram).latency_ns;
        let per_bank = elems.div_ceil(banks);
        let curry = coll::noc_exp(per_bank, 8, &hw.noc)
            .then(&coll::noc_reduce(rows.div_ceil(32), 16, &hw.noc))
            .then(&coll::noc_scalar_stream(per_bank, &hw.noc))
            .latency_ns;
        t.rowv(vec![
            seq.to_string(),
            rows.to_string(),
            fnum(nlu / 1e3),
            fnum(curry / 1e3),
            format!("{:.0}%", (1.0 - curry / nlu) * 100.0),
        ]);
    }
    t.render()
}

/// Fig 23: path generation (instruction fusion) latency profits, measured
/// on the real ISA machine executing the Fig 13 exponential program. Each
/// (elems, rounds) cell drives its own ISA machines — one pool job each.
pub fn fig23(cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let mut t = Table::new(
        "Fig 23 — path-generation profits (exp program on the ISA machine)",
        &["elems/bank", "rounds", "base(us)", "fused(us)", "saving"],
    );
    let cells = vec![(8usize, 4u32), (16, 6), (32, 6)];
    let rows = par_map_indexed(cx.jobs, cells, |_, (len, rounds)| {
        let run = |fuse: bool| {
            let mut m = Machine::new(&hw, SramGang::In256Out16);
            let xs: Vec<f32> = (0..len).map(|i| 0.05 * i as f32 - 0.4).collect();
            m.write_row(0, 0, &xs);
            let p = RowProgram::exp_program(0, 2000, len, rounds, 1);
            m.run(&p, fuse).latency_ns
        };
        let base = run(false);
        let fused = run(true);
        vec![
            len.to_string(),
            rounds.to_string(),
            fnum(base / 1e3),
            fnum(fused / 1e3),
            format!("{:.0}%", (1.0 - fused / base) * 100.0),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// `noc-calibration`: per-collective anchor-shape comparison of the three
/// NoC costing tiers. `ratio` is the raw analytic error the calibration
/// closes (sim/analytic; historically anywhere in 0.5–2.0×); `err` is the
/// calibrated tier's residual against the simulator — the number ci.sh
/// gates at ≤ 20% (it is the only %-formatted column, which is what the
/// gate's parser keys on).
pub fn noc_calibration(cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let mut t = Table::new(
        "NoC calibration — closed forms vs flit-level mesh, per collective anchor",
        &["collective", "shape", "analytic(ns)", "sim(ns)", "ratio", "calibrated(ns)", "err"],
    );
    for a in calibration_report(&hw, cx.jobs) {
        t.rowv(vec![
            a.collective.to_string(),
            a.shape.clone(),
            fnum(a.analytic_ns),
            fnum(a.simulated_ns),
            fnum(a.raw_ratio()),
            fnum(a.calibrated_ns),
            format!("{:.2}%", a.calibrated_err() * 100.0),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig21_curry_share_and_fit() {
        let s = fig21(&FigCtx::default());
        assert!(s.contains("0.8195") || s.contains("0.819"));
        assert!(s.contains("Curry ALU"));
    }

    #[test]
    fn fig22_reduction_band() {
        // paper: ~30% total non-linear compression, 25% long-text; the
        // distributed path should win clearly at long context
        let s = fig22(&FigCtx::default());
        let reductions: Vec<f64> = s
            .lines()
            .filter_map(|l| l.split_whitespace().last()?.strip_suffix('%')?.parse().ok())
            .collect();
        assert!(!reductions.is_empty());
        assert!(
            reductions.iter().any(|r| *r >= 25.0),
            "expected >=25% somewhere: {reductions:?}"
        );
    }

    #[test]
    fn noc_calibration_errors_gate_at_20pct() {
        // the same contract ci.sh enforces on the rendered table: every
        // %-formatted value is a calibrated-vs-simulated error ≤ 20%
        let s = noc_calibration(&FigCtx::default());
        let errs: Vec<f64> = s
            .lines()
            .filter_map(|l| l.split_whitespace().last()?.strip_suffix('%')?.parse().ok())
            .collect();
        assert!(!errs.is_empty(), "no error column found:\n{s}");
        assert!(errs.len() >= 10, "expected the full anchor grid, got {}", errs.len());
        for e in &errs {
            assert!(*e <= 20.0, "calibrated error {e}% exceeds the 20% gate:\n{s}");
        }
        // every collective appears
        for name in ["reduce", "broadcast", "exp", "sqrt", "scalar-stream"] {
            assert!(s.contains(name), "missing {name}:\n{s}");
        }
    }

    #[test]
    fn fig23_saving_band() {
        // paper: 33-50% latency optimization from path generation
        let s = fig23(&FigCtx::default());
        let savings: Vec<f64> = s
            .lines()
            .filter_map(|l| l.split_whitespace().last()?.strip_suffix('%')?.parse().ok())
            .collect();
        assert!(!savings.is_empty());
        for v in &savings {
            assert!((25.0..95.0).contains(v), "fusion saving {v}%:\n{s}");
        }
    }
}
