//! Serving-scenario comparison tables: the coordinator's SLO-aware
//! continuous batcher driven by every named workload scenario, plus an
//! architecture face-off on the mixed multi-tenant blend. These extend the
//! paper's fixed-shape end-to-end tables toward the trace-driven,
//! SLO-reporting evaluation style of the PIM-serving literature.

use crate::api::Engine;
use crate::config::{ArchKind, ModelConfig};
use crate::util::pool::par_map_indexed;
use crate::util::table::{fenergy_pj, fnum, ftime_ns, Table};
use crate::workload::Scenario;

use super::FigCtx;

fn engine(cx: &FigCtx, arch: ArchKind) -> Engine {
    let mut rc = cx.rc(arch, ModelConfig::llama2_7b());
    rc.tp = 8;
    rc.devices = 32;
    Engine::new(rc)
}

/// Scenario sweep: every named scenario served on CompAir_Opt
/// (llama2-7b, TP=8, 32 devices), reporting throughput, tail latencies,
/// SLO attainment, and energy per token. One pool job per scenario, rows
/// merged in registry order.
pub fn scenarios(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Serving scenarios — CompAir_Opt, llama2-7b, TP=8, 32 devices, seed 42",
        &[
            "scenario", "done", "rej", "pre", "tok/s", "ttft p50", "ttft p99", "tpot p50",
            "slo%", "energy/tok",
        ],
    );
    let rows = par_map_indexed(cx.jobs, Scenario::all(), |_, sc| {
        // cap request counts so full-figure regeneration stays fast
        let name = sc.name;
        let n = sc.default_requests.min(32);
        let r = engine(cx, ArchKind::CompAirOpt).serve_scenario(sc, n, 42).report;
        vec![
            name.to_string(),
            r.completed.to_string(),
            r.rejected.to_string(),
            r.preempted.to_string(),
            fnum(r.throughput_tok_s),
            ftime_ns(r.ttft_p50_ns),
            ftime_ns(r.ttft_p99_ns),
            ftime_ns(r.tpot_p50_ns),
            format!("{:.1}%", r.slo_attainment * 100.0),
            fenergy_pj(r.energy_per_token_pj),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// Architecture face-off on the mixed multi-tenant scenario: CENT vs the
/// CompAir ablation steps, same trace, same SLOs. One pool job per
/// architecture.
pub fn scenario_archs(cx: &FigCtx) -> String {
    let sc = Scenario::by_name("mixed").expect("mixed scenario registered");
    let mut t = Table::new(
        "Mixed multi-tenant scenario across architectures — llama2-7b, TP=8, 32 devices",
        &["arch", "makespan", "tok/s", "ttft p99", "tpot p99", "slo%", "energy/tok"],
    );
    let archs =
        vec![ArchKind::Cent, ArchKind::CentCurry, ArchKind::CompAirBase, ArchKind::CompAirOpt];
    let rows = par_map_indexed(cx.jobs, archs, |_, arch| {
        let r = engine(cx, arch).serve_scenario(sc.clone(), 32, 42).report;
        vec![
            arch.label().to_string(),
            ftime_ns(r.makespan_ns as f64),
            fnum(r.throughput_tok_s),
            ftime_ns(r.ttft_p99_ns),
            ftime_ns(r.tpot_p99_ns),
            format!("{:.1}%", r.slo_attainment * 100.0),
            fenergy_pj(r.energy_per_token_pj),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_table_has_all_scenarios() {
        let s = scenarios(&FigCtx::default());
        for name in Scenario::names() {
            assert!(s.contains(name), "scenario table missing '{name}'");
        }
        assert!(s.contains("slo%") || s.contains("slo"), "SLO column present");
    }

    #[test]
    fn arch_table_covers_ablation() {
        let s = scenario_archs(&FigCtx::default());
        for label in ["CENT", "CompAir_Opt"] {
            assert!(s.contains(label), "arch table missing '{label}'");
        }
    }
}
