//! Motivation figures: Fig 4 (DRAM-PIM vs SRAM-PIM complementarity),
//! Fig 5 (non-linear overhead), Fig 7B (per-bank power).

use crate::arch::pure_sram_requirements;
use crate::config::{ArchKind, HwConfig, ModelConfig, SramGang};
use crate::dram::PimBank;
use crate::energy::EnergyModel;
use crate::sram::bank::{SramBank, WeightPolicy};
use crate::util::pool::par_map_indexed;
use crate::util::table::{fnum, fx, Table};

use super::FigCtx;

/// Fig 4A: pure SRAM-PIM macro count and power for all FC layers.
pub fn fig4a(_cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let mut t = Table::new(
        "Fig 4A — pure SRAM-PIM holding all FC layers (no reloading)",
        &["model", "macros", "power(W)", "vs A100 300W"],
    );
    for m in ModelConfig::zoo() {
        let (macros, power) = pure_sram_requirements(&m, &hw.sram);
        t.rowv(vec![
            m.name.into(),
            format!("{:.2e}", macros as f64),
            fnum(power),
            fx(power / 300.0),
        ]);
    }
    t.render()
}

/// Fig 4B/4C: SRAM-PIM stacking DRAM vs pure DRAM-PIM across batch sizes,
/// for Q/K/V projection (weight-reuse friendly) and SV (input-dependent).
pub fn fig4bc(_cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let m = ModelConfig::llama2_7b();
    let dram = PimBank::new(&hw.dram);
    let sram = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
    let banks = hw.dram.banks_per_device();

    let mut t = Table::new(
        "Fig 4B — Q/K/V projection: SRAM-stack speedup over DRAM-PIM (Llama2-7B)",
        &["batch", "dram(us)", "sram(us)", "speedup"],
    );
    // per-bank Q tile under output-split over a full device
    let out_tile = (3 * m.d_model).div_ceil(banks);
    for batch in [1usize, 2, 4, 8, 16, 32, 64] {
        let d = dram.gemv(out_tile, m.d_model, batch).latency_ns;
        let s = sram.gemm(out_tile, m.d_model, batch, WeightPolicy::Reload).latency_ns;
        t.rowv(vec![
            batch.to_string(),
            fnum(d / 1e3),
            fnum(s / 1e3),
            fx(d / s),
        ]);
    }

    let mut t2 = Table::new(
        "Fig 4C — SV (scores x V): input-dependent matrix, per KV pair",
        &["seqlen", "dram(us)", "sram(us)", "dram wins?"],
    );
    // SV per (batch, head) pair: out=d_head, in=seq; no cross-batch reuse
    for seq in [512usize, 1024, 2048, 4096, 8192] {
        let d = dram.gemv(m.d_head(), seq, 1).latency_ns;
        let s = sram.gemm(m.d_head(), seq, 1, WeightPolicy::Reload).latency_ns;
        t2.rowv(vec![
            seq.to_string(),
            fnum(d / 1e3),
            fnum(s / 1e3),
            (d < s).to_string(),
        ]);
    }
    t.render() + "\n" + &t2.render()
}

/// Fig 5C/5D: non-linear share of transformer-block time and the extra
/// data movement of the centralized NLU (CENT baseline). One pool job per
/// sequence-length point.
pub fn fig5(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Fig 5C/5D — non-linear overhead on pure DRAM-PIM (CENT, Llama2-7B, batch=16)",
        &["seqlen", "layer(us)", "nonlin %", "nlu I/O bytes/layer"],
    );
    let seqs = vec![2048usize, 4096, 8192, 16384, 32768, 65536];
    let rows = par_map_indexed(cx.jobs, seqs, |_, seq| {
        let mut rc = cx.rc(ArchKind::Cent, ModelConfig::llama2_7b());
        rc.batch = 16;
        rc.seq_len = seq;
        let r = crate::api::Engine::new(rc).simulate();
        vec![
            seq.to_string(),
            fnum(r.layer_cost.latency_ns / 1e3),
            format!("{:.1}%", r.nonlinear_frac * 100.0),
            format!("{:.2e}", r.layer_cost.counts.gb_bytes as f64),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// Fig 7B: per-bank power of the DRAM-PIM vs the stacked SRAM-PIM macros.
pub fn fig7b(_cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let em = EnergyModel::new(&hw.sram, hw.hb.pj_per_bit);
    let dram = PimBank::new(&hw.dram);
    // steady GeMV streaming on one bank (GPT3-175B-wide rows)
    let c = dram.gemv(16, 12288, 1);
    let e = em.dynamic(&c.counts);
    let dram_w = e.total_pj() / c.latency_ns; // pJ/ns == W
    let sram_macro = crate::sram::SramMacro::new(&hw.sram);
    let sram_w = 4.0 * sram_macro.active_power_w();
    let mut lv = hw.sram.clone();
    lv.voltage = crate::config::Voltage(0.6);
    let sram_lv_w = 4.0 * crate::sram::SramMacro::new(&lv).active_power_w();
    let mut t = Table::new(
        "Fig 7B — per-bank power (GPT3-175B streaming)",
        &["component", "power(W)"],
    );
    t.rowv(vec!["DRAM-PIM bank (active GeMV)".into(), fnum(dram_w)]);
    t.rowv(vec!["4x 8KB SRAM-PIM @0.9V".into(), fnum(sram_w)]);
    t.rowv(vec!["4x 8KB SRAM-PIM @0.6V".into(), fnum(sram_lv_w)]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4a_shows_infeasibility() {
        let s = fig4a(&FigCtx::default());
        assert!(s.contains("gpt3-175b"));
        // every model must exceed A100 power by a lot
        assert!(s.lines().count() >= 8);
    }

    #[test]
    fn fig4bc_speedup_grows_with_batch() {
        let s = fig4bc(&FigCtx::default());
        assert!(s.contains("Fig 4B"));
        assert!(s.contains("Fig 4C"));
        // batch=64 row should show a multi-x speedup
        let b64 = s.lines().find(|l| l.trim_start().starts_with("64 ")).unwrap();
        let sp: f64 = b64.split_whitespace().last().unwrap().trim_end_matches('x').parse().unwrap();
        assert!(sp > 3.0, "batch-64 speedup {sp}");
    }

    #[test]
    fn fig5_nonlinear_grows() {
        let s = fig5(&FigCtx::default());
        let fracs: Vec<f64> = s
            .lines()
            .filter(|l| l.contains('%'))
            .filter_map(|l| {
                l.split_whitespace().find(|w| w.ends_with('%'))?.trim_end_matches('%').parse().ok()
            })
            .collect();
        assert!(fracs.len() >= 4);
        assert!(fracs.last().unwrap() > fracs.first().unwrap());
    }

    #[test]
    fn fig7b_sram_power_in_paper_band() {
        // §3.2: 8KB SRAM-PIMs consume ~0.022 W each → 4 macros ≈ 0.09 W
        let s = fig7b(&FigCtx::default());
        assert!(s.contains("SRAM-PIM"));
    }
}
