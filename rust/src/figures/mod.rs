//! Figure/table regeneration harness: one entry per paper table and figure
//! (DESIGN.md carries the experiment index). Each function re-runs the
//! simulation fresh and renders the same rows/series the paper plots.
//!
//! Every generator takes a [`FigCtx`] — the explicit knobs a figure run
//! threads through (worker count, NoC costing tier). This replaced a
//! process-wide mutable fidelity default: with figures fanning out across
//! worker threads, global state would be a data race, and explicit
//! parameters were overdue anyway. Figures fan out twice: [`run_all`]
//! runs whole figures as pool jobs, and the sweep-shaped figures
//! additionally run each cell (scenario × arch × replica-count…) as its
//! own job. Both merges are submission-ordered (`util::pool`), so
//! `--jobs N` output is bit-identical to `--jobs 1`.

pub mod cluster;
pub mod endtoend;
pub mod gqa;
pub mod mapping;
pub mod motivation;
pub mod noc_eval;
pub mod serving;

use crate::config::{ArchKind, HwConfig, ModelConfig, NocFidelity, RunConfig};
use crate::util::pool::par_map_indexed;
use crate::util::table::Table;

/// The explicit per-run context every figure generator receives: how many
/// pool workers its cell sweeps may use, and which NoC costing tier its
/// `RunConfig`s select. Plain data, `Copy`, shared read-only across
/// workers — the whole point is that nothing here is process-global.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FigCtx {
    /// Worker threads for the cell sweep inside one figure (and for
    /// [`run_all`]'s figure-level fan-out).
    pub jobs: usize,
    /// The NoC costing tier every figure `RunConfig` runs under.
    pub noc_fidelity: NocFidelity,
}

impl Default for FigCtx {
    fn default() -> Self {
        Self { jobs: 1, noc_fidelity: NocFidelity::Analytic }
    }
}

impl FigCtx {
    /// A figure-cell `RunConfig` with this context's fidelity applied.
    /// Cell configs keep `jobs = 1`: the cells themselves are the pool
    /// jobs, and nesting a per-`System` prefit pool inside them would
    /// oversubscribe without changing any result.
    pub fn rc(&self, arch: ArchKind, model: ModelConfig) -> RunConfig {
        let mut rc = RunConfig::new(arch, model);
        rc.noc_fidelity = self.noc_fidelity;
        rc
    }
}

/// Table 3: the hardware configuration, echoed from the config structs.
pub fn table3(_cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let mut t = Table::new("Table 3 — hardware configuration", &["component", "spec"]);
    t.rowv(vec![
        "DRAM-PIM".into(),
        format!(
            "{}ch/dev, {} banks/ch, {}MB/bank, {} MACs/bank, tRCDWR={} tRCDRD={} tRAS={} tCL={} tRP={} ns",
            hw.dram.channels_per_device,
            hw.dram.banks_per_channel,
            hw.dram.bank_mb,
            hw.dram.macs_per_bank,
            hw.dram.t_rcdwr_ns,
            hw.dram.t_rcdrd_ns,
            hw.dram.t_ras_ns,
            hw.dram.t_cl_ns,
            hw.dram.t_rp_ns
        ),
    ]);
    t.rowv(vec![
        "SRAM-PIM".into(),
        format!(
            "{}kb/array, 4 arrays/bank, t_access {}-{} ns, {}-{} TFLOPS/W (0.9-0.6V)",
            hw.sram.array_kb,
            hw.sram.t_access_fast_ns,
            hw.sram.t_access_slow_ns,
            hw.sram.tflops_w_fast,
            hw.sram.tflops_w_slow
        ),
    ]);
    t.rowv(vec![
        "CompAir-NoC".into(),
        format!(
            "{}x{} 2D-mesh, {} Curry ALUs/router, flit {}b, DOR, SWIFT",
            hw.noc.mesh_cols, hw.noc.mesh_rows, hw.noc.curry_alus_per_router, hw.noc.flit_bits
        ),
    ]);
    t.rowv(vec![
        "CXL".into(),
        format!(
            "{} devices, {} GB/s collective, {} GB/s p2p",
            hw.cxl.devices, hw.cxl.collective_gbs, hw.cxl.p2p_gbs
        ),
    ]);
    t.render()
}

/// All figures in paper order: (id, generator).
pub fn registry() -> Vec<(&'static str, fn(&FigCtx) -> String)> {
    vec![
        ("table3", table3 as fn(&FigCtx) -> String),
        ("fig4a", motivation::fig4a),
        ("fig4bc", motivation::fig4bc),
        ("fig5", motivation::fig5),
        ("fig7b", motivation::fig7b),
        ("fig8", mapping::fig8),
        ("fig9", mapping::fig9),
        ("fig15", endtoend::fig15),
        ("fig16", endtoend::fig16),
        ("fig17", endtoend::fig17),
        ("fig18", endtoend::fig18),
        ("fig19", endtoend::fig19),
        ("fig20", mapping::fig20),
        ("fig21", noc_eval::fig21),
        ("fig22", noc_eval::fig22),
        ("fig23", noc_eval::fig23),
        ("fig24", gqa::fig24),
        ("fig25", gqa::fig25),
        // beyond-paper serving tables (trace-driven, SLO-aware)
        ("scenarios", serving::scenarios),
        ("scenario-archs", serving::scenario_archs),
        ("cluster", cluster::cluster),
        // NoC costing self-check: analytic vs flit-level error per
        // collective anchor, and the calibrated tier's residual
        ("noc-calibration", noc_eval::noc_calibration),
        // auto-mapper vs static placement: phase-shape sweep with
        // machine-checkable never-lose markers, plus a scenario replay
        ("mapping-search", mapping::mapping_search),
    ]
}

/// Run one figure by id.
pub fn run(name: &str, cx: &FigCtx) -> Option<String> {
    registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f(cx))
}

/// Regenerate every registered figure, fanning whole figures out as pool
/// jobs, and return `(id, rendered table)` in registry order — the same
/// pairs, bit-identical, whatever `cx.jobs` is.
pub fn run_all(cx: &FigCtx) -> Vec<(&'static str, String)> {
    par_map_indexed(cx.jobs, registry(), |_, (name, f)| (name, f(cx)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_paper_artifacts() {
        let names: Vec<&str> = registry().iter().map(|(n, _)| *n).collect();
        for expected in [
            "table3", "fig4a", "fig4bc", "fig5", "fig7b", "fig8", "fig9", "fig15", "fig16",
            "fig17", "fig18", "fig19", "fig20", "fig21", "fig22", "fig23", "fig24", "fig25",
            "mapping-search",
        ] {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }

    #[test]
    fn table3_echoes_config() {
        let s = table3(&FigCtx::default());
        assert!(s.contains("tRCDWR=14"));
        assert!(s.contains("4x16") || s.contains("4 arrays"));
    }

    #[test]
    fn unknown_figure_is_none() {
        assert!(run("fig99", &FigCtx::default()).is_none());
    }

    #[test]
    fn fig_ctx_threads_fidelity_into_cell_configs() {
        let cx = FigCtx { jobs: 4, noc_fidelity: NocFidelity::Calibrated };
        let rc = cx.rc(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        assert_eq!(rc.noc_fidelity, NocFidelity::Calibrated);
        assert_eq!(rc.jobs, 1, "cells are the pool jobs; they must not nest pools");
        assert_eq!(FigCtx::default().jobs, 1);
        assert_eq!(FigCtx::default().noc_fidelity, NocFidelity::Analytic);
    }
}
