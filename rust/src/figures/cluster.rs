//! Cluster-serving comparison table: every named scenario served colocated
//! vs disaggregated (prefill/decode pools with priced KV migration) at two
//! replica counts. This is the fabric-level evaluation the PIM-serving
//! literature (Sangam, HPIM) runs — placement and phase separation on a
//! CXL switch — layered over the paper's per-device model.

use crate::api::Engine;
use crate::config::{ArchKind, ModelConfig};
use crate::coordinator::{ClusterConfig, RouterPolicy};
use crate::util::pool::par_map_indexed;
use crate::util::table::{fbytes, fenergy_pj, fnum, ftime_ns, Table};
use crate::workload::Scenario;

use super::FigCtx;

fn engine(cx: &FigCtx) -> Engine {
    let mut rc = cx.rc(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
    rc.tp = 8;
    rc.devices = 32;
    Engine::new(rc)
}

/// Colocated vs disaggregated serving across all scenarios and replica
/// counts {2, 4}: SLO attainment, energy/token, and the KV-migration
/// traffic the disaggregated mode pays (priced through `cxl_p2p`). Every
/// (scenario, replica-count, mode) cell is an independent cluster
/// simulation — each runs as its own pool job, rows merged in sweep
/// order.
pub fn cluster(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Cluster serving — colocated vs disaggregated (CompAir_Opt, llama2-7b, TP=8, \
         32 devices/replica, least-kv router, seed 42)",
        &[
            "scenario", "replicas", "mode", "done", "tok/s", "ttft p99", "slo%", "energy/tok",
            "kv migrated",
        ],
    );
    let mut cells = Vec::new();
    for sc in Scenario::all() {
        // cap request counts so full-figure regeneration stays fast
        let n = sc.default_requests.min(12);
        for replicas in [2usize, 4] {
            for disagg in [None, Some((replicas / 2, replicas - replicas / 2))] {
                cells.push((sc.clone(), n, replicas, disagg));
            }
        }
    }
    let rows = par_map_indexed(cx.jobs, cells, |_, (sc, n, replicas, disagg)| {
        let cfg = ClusterConfig { replicas, disagg, router: RouterPolicy::LeastLoadedKv };
        let mode = match disagg {
            Some((p, d)) => format!("disagg {p}:{d}"),
            None => "colocated".to_string(),
        };
        let name = sc.name;
        let r = engine(cx).cluster_scenario(sc, n, 42, cfg).cluster;
        vec![
            name.to_string(),
            replicas.to_string(),
            mode,
            r.report.completed.to_string(),
            fnum(r.report.throughput_tok_s),
            ftime_ns(r.report.ttft_p99_ns),
            format!("{:.1}%", r.report.slo_attainment * 100.0),
            fenergy_pj(r.report.energy_per_token_pj),
            fbytes(r.migration_bytes),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_table_covers_scenarios_and_modes() {
        let s = cluster(&FigCtx::default());
        for name in Scenario::names() {
            assert!(s.contains(name), "cluster table missing scenario '{name}'");
        }
        assert!(s.contains("colocated"), "colocated rows present");
        assert!(s.contains("disagg 1:1"), "2-replica disaggregated rows present");
        assert!(s.contains("disagg 2:2"), "4-replica disaggregated rows present");
        assert!(s.contains("kv migrated"), "migration traffic column present");
    }
}
