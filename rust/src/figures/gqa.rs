//! Discussion figures (paper §8): Fig 24 (GQA attention — SRAM-stack vs
//! DRAM-PIM latency ratio) and Fig 25 (energy delta of using SRAM for it).
//!
//! For GQA, K/V are shared by `group` query heads, so the K^T / V tiles do
//! get reuse (effective batch = batch × group), unlike MHA attention.

use crate::config::{HwConfig, ModelConfig, SramGang};
use crate::dram::PimBank;
use crate::energy::EnergyModel;
use crate::sram::bank::{SramBank, WeightPolicy};
use crate::util::pool::par_map_indexed;
use crate::util::table::{fnum, Table};

use super::FigCtx;

struct GqaPoint {
    dram_ns: f64,
    sram_ns: f64,
    dram_pj: f64,
    sram_pj: f64,
}

fn gqa_point(m: &ModelConfig, seq: usize, tp: usize, qk: bool) -> GqaPoint {
    let hw = HwConfig::paper();
    let em = EnergyModel::new(&hw.sram, hw.hb.pj_per_bit);
    let dram = PimBank::new(&hw.dram);
    let sram = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
    let group = m.gqa_group();
    let batch = 16usize;
    // TP splits the K^T / V matrices along seq (paper §8)
    let seq_shard = seq.div_ceil(tp);
    // per bank: seq shard spread over the banks serving one kv head
    let banks = hw.dram.banks_per_device();
    let kv_pairs = batch * m.n_kv_heads / tp.min(m.n_kv_heads);
    let banks_per_pair = (banks / kv_pairs.max(1)).max(1);
    let seq_tile = seq_shard.div_ceil(banks_per_pair).max(1);

    // QK^T: "weights" = K^T (seq_tile x d_head), inputs = group*batch queries
    // SV: "weights" = V (d_head x seq_tile), same reuse
    let (out_t, in_t) = if qk { (seq_tile, m.d_head()) } else { (m.d_head(), seq_tile) };
    let reuse = group * batch / batch; // group-fold reuse per kv head
    let eff_batch = batch.max(1) * reuse.max(1) / batch.max(1) * batch; // = batch*group

    let d = dram.gemv(out_t, in_t, eff_batch);
    let s = sram.gemm(out_t, in_t, eff_batch, WeightPolicy::Reload);
    GqaPoint {
        dram_ns: d.latency_ns,
        sram_ns: s.latency_ns,
        dram_pj: em.dynamic(&d.counts).total_pj(),
        sram_pj: em.dynamic(&s.counts).total_pj(),
    }
}

/// Fig 24: latency ratio map (SRAM-stack / DRAM-PIM); < 1 = SRAM wins.
/// One pool job per seqlen row (each prices four TP points).
pub fn fig24(cx: &FigCtx) -> String {
    let m = ModelConfig::llama2_70b();
    let mut out = String::new();
    for (qk, label) in [(true, "QK^T"), (false, "SV")] {
        let mut t = Table::new(
            &format!("Fig 24 — GQA {label} latency ratio SRAM/DRAM (Llama2-70B, group=8; <1 = SRAM wins)"),
            &["seqlen", "TP=1", "TP=2", "TP=4", "TP=8"],
        );
        let seqs = vec![2048usize, 8192, 32768, 131072];
        let rows = par_map_indexed(cx.jobs, seqs, |_, seq| {
            let mut row = vec![seq.to_string()];
            for tp in [1usize, 2, 4, 8] {
                let p = gqa_point(&m, seq, tp, qk);
                row.push(fnum(p.sram_ns / p.dram_ns));
            }
            row
        });
        for row in rows {
            t.rowv(row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Fig 25: energy ratio map (SRAM-stack / DRAM-PIM); > 1 = SRAM costs more.
/// One pool job per seqlen row.
pub fn fig25(cx: &FigCtx) -> String {
    let m = ModelConfig::llama2_70b();
    let mut out = String::new();
    for (qk, label) in [(true, "QK^T"), (false, "SV")] {
        let mut t = Table::new(
            &format!("Fig 25 — GQA {label} energy ratio SRAM/DRAM (Llama2-70B)"),
            &["seqlen", "TP=1", "TP=2", "TP=4", "TP=8"],
        );
        let seqs = vec![2048usize, 8192, 32768, 131072];
        let rows = par_map_indexed(cx.jobs, seqs, |_, seq| {
            let mut row = vec![seq.to_string()];
            for tp in [1usize, 2, 4, 8] {
                let p = gqa_point(&m, seq, tp, qk);
                row.push(fnum(p.sram_pj / p.dram_pj));
            }
            row
        });
        for row in rows {
            t.rowv(row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig24_qk_sram_wins_at_long_seq_low_tp() {
        // §8: "longer sequence and fewer TPs lead to better reusing of
        // SRAM-PIM" for QK^T
        let m = ModelConfig::llama2_70b();
        let long_low = gqa_point(&m, 131072, 1, true);
        assert!(
            long_low.sram_ns < long_low.dram_ns,
            "SRAM should win QK^T at 128K/TP=1: {} vs {}",
            long_low.sram_ns,
            long_low.dram_ns
        );
    }

    #[test]
    fn fig24_renders_both_ops() {
        let s = fig24(&FigCtx::default());
        assert!(s.contains("QK^T") && s.contains("SV"));
    }

    #[test]
    fn fig25_reuse_governs_sram_energy_premium() {
        // §8's core logic: SRAM's energy attractiveness comes from K/V
        // reuse. MHA (group=1, Qwen) gives SRAM no reuse → its relative
        // energy must be worse than under GQA (group=8, Llama2-70B).
        let gqa = ModelConfig::llama2_70b();
        let mha = ModelConfig::qwen_72b();
        let p_gqa = gqa_point(&gqa, 32768, 4, true);
        let p_mha = gqa_point(&mha, 32768, 4, true);
        let r_gqa = p_gqa.sram_pj / p_gqa.dram_pj;
        let r_mha = p_mha.sram_pj / p_mha.dram_pj;
        assert!(
            r_mha > r_gqa,
            "MHA should make SRAM relatively costlier: mha={r_mha} gqa={r_gqa}"
        );
    }
}
