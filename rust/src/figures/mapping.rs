//! Mapping and micro-architecture figures: Fig 8 (gang shapes × mapping),
//! Fig 9 (decoupled column decoder), Fig 20 (SRAM-PIM DSE), plus the
//! beyond-paper `mapping-search` table (auto-mapper vs static placement).

use crate::arch::fc_tiles;
use crate::config::{
    ArchKind, ColumnDecoder, FcMapping, HwConfig, ModelConfig, Phase, SramGang, Voltage,
};
use crate::coordinator::{ServeConfig, Server};
use crate::dram::PimBank;
use crate::mapper::{search_phase, AutoMappedCostModel, SearchConfig};
use crate::sram::bank::{SramBank, WeightPolicy};
use crate::util::pool::par_map_indexed;
use crate::util::table::{fnum, ftime_ns, fx, Table};
use crate::workload::Scenario;

use super::FigCtx;

/// Fig 8: Llama2-13B per-bank Q/K/V + FFN speedups of SRAM-stack over pure
/// DRAM-PIM, for (512,8) output-split vs (256,16) input-split. Tile shapes
/// come from [`fc_tiles`] — the same function `System::fc_cost` tiles
/// with — so the figure can never drift from what the cost model prices
/// (the previous hand-coded input-split row had).
pub fn fig8(_cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let m = ModelConfig::llama2_13b();
    let dram = PimBank::new(&hw.dram);
    let mut out = String::new();
    for (label, mapping, d_in, d_out) in [
        // §3.3: output-split hands each bank a thin d_model-deep tile
        ("Q/K/V output-split", FcMapping::OutputSplit, m.d_model, 3 * m.d_model),
        // input-split reorganization: split d_in across a channel's banks
        ("Q/K/V input-split", FcMapping::InputSplit, m.d_model, 3 * m.d_model),
        ("FFN up output-split", FcMapping::OutputSplit, m.d_model, m.d_ffn),
    ] {
        let (out_tile, in_dim, _active) = fc_tiles(mapping, d_in, d_out, &hw.dram);
        let mut t = Table::new(
            &format!("Fig 8 — {label}: {in_dim} x {out_tile}/bank (Llama2-13B)"),
            &["batch", "dram(us)", "(512,8)(us)", "(256,16)(us)", "best-speedup"],
        );
        let s58 = SramBank::new(&hw.sram, SramGang::In512Out8, &hw.dram);
        let s216 = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
        for batch in [1usize, 4, 16, 64] {
            let d = dram.gemv(out_tile, in_dim, batch).latency_ns;
            let a = s58.gemm(out_tile, in_dim, batch, WeightPolicy::Reload).latency_ns;
            let b = s216.gemm(out_tile, in_dim, batch, WeightPolicy::Reload).latency_ns;
            t.rowv(vec![
                batch.to_string(),
                fnum(d / 1e3),
                fnum(a / 1e3),
                fnum(b / 1e3),
                fx(d / a.min(b)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Fig 9: end-to-end effect of decoupling the column decoder (Llama2-13B).
/// One pool job per (phase, batch, seqlen) cell.
pub fn fig9(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Fig 9 — DRAM-PIM reorganization (decoupled 8:1/4:1 column decoder), Llama2-13B",
        &["phase", "batch", "seqlen", "base(ms)", "opt(ms)", "speedup"],
    );
    let cells = vec![
        (crate::config::Phase::Decode, 16usize, 4096usize),
        (crate::config::Phase::Decode, 64, 4096),
        (crate::config::Phase::Prefill, 1, 2048),
    ];
    let rows = par_map_indexed(cx.jobs, cells, |_, (phase, batch, seq)| {
        let mut base = cx.rc(ArchKind::CompAirBase, ModelConfig::llama2_13b());
        base.phase = phase;
        base.batch = batch;
        base.seq_len = seq;
        let mut opt = base.clone();
        opt.arch = ArchKind::CompAirOpt;
        opt.hw.dram.column_decoder = ColumnDecoder::Decoupled8and4;
        let tb = crate::api::Engine::new(base).simulate().latency_ns;
        let to = crate::api::Engine::new(opt).simulate().latency_ns;
        vec![
            format!("{phase:?}"),
            batch.to_string(),
            seq.to_string(),
            fnum(tb / 1e6),
            fnum(to / 1e6),
            fx(tb / to),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// Fig 20: DSE of the SRAM-PIM gang shape × voltage against the per-bank
/// DRAM feed bandwidth (green line) and the HB ceiling (red line).
pub fn fig20(_cx: &FigCtx) -> String {
    let mut out = String::new();
    for gang in [SramGang::In512Out8, SramGang::In256Out16] {
        let mut t = Table::new(
            &format!("Fig 20 — DSE {} (GeMM 4096x{}-ish tile, batch 16)", gang.label(), 16),
            &["voltage", "latency(us)", "compute-bound?", "feed(GB/s)", "hb(GB/s)"],
        );
        for v in [0.6f64, 0.7, 0.8, 0.9] {
            let mut hw = HwConfig::paper();
            hw.sram.voltage = Voltage(v);
            let bank = SramBank::new(&hw.sram, gang, &hw.dram);
            let (c, b) = bank.gemm_detailed(16, 4096, 16, WeightPolicy::Reload);
            let feed = PimBank::new(&hw.dram).sram_feed_gbs();
            t.rowv(vec![
                format!("{v:.1}V"),
                fnum(c.latency_ns / 1e3),
                (b.compute_ns > b.feed_ns + b.writeback_ns).to_string(),
                fnum(feed),
                fnum(hw.hb.gbs_per_bank()),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Every architecture the auto-mapper can search (AttAcc is a roofline
/// reference with no PIM-fabric cost model, hence no mapping space).
const MAPPED_ARCHS: [ArchKind; 5] = [
    ArchKind::Cent,
    ArchKind::CentCurry,
    ArchKind::CompAirBase,
    ArchKind::CompAirOpt,
    ArchKind::SramStack,
];

/// Mapping search (beyond-paper): the auto-mapper's placement choice vs
/// the paper's hard-coded static assignment.
///
/// Table 1 sweeps phase shapes across every mappable architecture and two
/// model configs; its `r=` tokens are machine-checkable never-lose
/// markers (searched cost / static cost, `<= 1` by construction — ci.sh
/// greps and gates on them). Table 2 replays every named serving scenario
/// under the shape-adaptive [`AutoMappedCostModel`]; makespan ratios are
/// reported without the marker because batching dynamics are not provably
/// monotone in per-iteration latency. One pool job per cell/scenario,
/// rows merged in submission order — bit-identical whatever `cx.jobs` is.
pub fn mapping_search(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Mapping search — searched placement vs static, per phase shape",
        &[
            "arch", "model", "phase", "batch", "seqlen", "space", "static(us)", "auto(us)",
            "never-lose", "mapping",
        ],
    );
    let mut cells = Vec::new();
    for arch in MAPPED_ARCHS {
        for model in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
            for shape in [(Phase::Decode, 32usize, 4096usize), (Phase::Prefill, 1, 2048)] {
                cells.push((arch, model.clone(), shape));
            }
        }
    }
    let rows = par_map_indexed(cx.jobs, cells, |_, (arch, model, (phase, batch, seq))| {
        let name = model.name.to_string();
        let mut rc = cx.rc(arch, model);
        rc.phase = phase;
        rc.batch = batch;
        rc.seq_len = seq;
        let res = search_phase(&rc, phase, batch, seq, &SearchConfig::default());
        vec![
            arch.label().to_string(),
            name,
            format!("{phase:?}"),
            batch.to_string(),
            seq.to_string(),
            res.space_size.to_string(),
            fnum(res.static_cost_ns / 1e3),
            fnum(res.cost_ns / 1e3),
            format!("r={:.4}", res.cost_ns / res.static_cost_ns),
            res.mapping.summary(),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    let mut out = t.render();
    out.push('\n');

    let mut t2 = Table::new(
        "Mapping search — serving scenarios, CompAir_Opt, llama2-7b, TP=8, 32 devices, seed 42",
        &["scenario", "static makespan", "auto makespan", "ratio", "done", "searches"],
    );
    let rows2 = par_map_indexed(cx.jobs, Scenario::all(), |_, sc| {
        let name = sc.name;
        // cap request counts so full-figure regeneration stays fast
        let n = sc.default_requests.min(8);
        let mut rc = cx.rc(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        let cfg = ServeConfig { n_requests: n, seed: 42, scenario: Some(sc), ..Default::default() };
        let server = Server::new(rc.clone(), cfg);
        let st = server.run();
        let auto = AutoMappedCostModel::new(rc);
        let at = server.run_with_model(&auto);
        vec![
            name.to_string(),
            ftime_ns(st.makespan_ns as f64),
            ftime_ns(at.makespan_ns as f64),
            format!("{:.4}", at.makespan_ns as f64 / st.makespan_ns.max(1) as f64),
            format!("{}/{}", st.completed, at.completed),
            auto.searches().to_string(),
        ]
    });
    for row in rows2 {
        t2.rowv(row);
    }
    out.push_str(&t2.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_input_split_competitive() {
        let s = fig8(&FigCtx::default());
        assert!(s.contains("input-split"));
        assert!(s.contains("(256,16)"));
    }

    #[test]
    fn fig8_tiles_are_the_cost_model_tiles() {
        // the figure must price the exact tile shapes System::fc_cost
        // prices — regression guard for the hand-coded drift this fixed
        let hw = HwConfig::paper();
        let m = ModelConfig::llama2_13b();
        let s = fig8(&FigCtx::default());
        for (mapping, d_in, d_out) in [
            (FcMapping::OutputSplit, m.d_model, 3 * m.d_model),
            (FcMapping::InputSplit, m.d_model, 3 * m.d_model),
            (FcMapping::OutputSplit, m.d_model, m.d_ffn),
        ] {
            let (out_tile, in_tile, _) = fc_tiles(mapping, d_in, d_out, &hw.dram);
            let tag = format!("{in_tile} x {out_tile}/bank");
            assert!(s.contains(&tag), "fig8 lost the fc_tiles shape {tag}:\n{s}");
        }
    }

    #[test]
    fn mapping_search_never_loses_and_is_jobs_invariant() {
        let s1 = mapping_search(&FigCtx::default());
        let ratios: Vec<f64> = s1
            .split("r=")
            .skip(1)
            .filter_map(|rest| rest.split_whitespace().next()?.parse().ok())
            .collect();
        // one marker per (arch, model, shape) cell in table 1
        assert_eq!(ratios.len(), MAPPED_ARCHS.len() * 2 * 2, "marker count:\n{s1}");
        for r in &ratios {
            assert!(*r <= 1.0 + 1e-9, "auto mapping lost to static (r={r}):\n{s1}");
        }
        // table 2 covers every named scenario
        for sc in Scenario::all() {
            assert!(s1.contains(sc.name), "missing scenario {}:\n{s1}", sc.name);
        }
        let s4 = mapping_search(&FigCtx { jobs: 4, ..FigCtx::default() });
        assert_eq!(s1, s4, "mapping-search output must not depend on --jobs");
    }

    #[test]
    fn fig9_speedup_in_paper_band() {
        // paper: 1.15-1.5x end-to-end
        let s = fig9(&FigCtx::default());
        let speedups: Vec<f64> = s
            .lines()
            .filter_map(|l| l.split_whitespace().last()?.strip_suffix('x')?.parse().ok())
            .collect();
        assert!(!speedups.is_empty());
        for sp in &speedups {
            assert!((1.0..2.2).contains(sp), "fig9 speedup {sp} out of band:\n{s}");
        }
        assert!(speedups.iter().any(|s| *s > 1.05), "decoupling must help somewhere");
    }

    #[test]
    fn fig20_divergence_point() {
        // below the divergence point (feed-bound) voltage must not matter;
        // the DSE table should show compute-bound=false at batch 16 tiles
        let s = fig20(&FigCtx::default());
        assert!(s.contains("0.6V") && s.contains("0.9V"));
    }
}
