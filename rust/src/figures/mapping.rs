//! Mapping and micro-architecture figures: Fig 8 (gang shapes × mapping),
//! Fig 9 (decoupled column decoder), Fig 20 (SRAM-PIM DSE).

use crate::config::{ArchKind, ColumnDecoder, HwConfig, ModelConfig, SramGang, Voltage};
use crate::dram::PimBank;
use crate::sram::bank::{SramBank, WeightPolicy};
use crate::util::pool::par_map_indexed;
use crate::util::table::{fnum, fx, Table};

use super::FigCtx;

/// Fig 8: Llama2-13B per-bank Q/K/V + FFN speedups of SRAM-stack over pure
/// DRAM-PIM, for (512,8) output-split vs (256,16) input-split.
pub fn fig8(_cx: &FigCtx) -> String {
    let hw = HwConfig::paper();
    let m = ModelConfig::llama2_13b();
    let dram = PimBank::new(&hw.dram);
    let banks = hw.dram.banks_per_device(); // 16 banks x 32 channels
    let mut out = String::new();
    for (label, out_tile, in_dim) in [
        // §3.3: output-split gives each bank a 5120x10 Q/K/V tile
        ("Q/K/V output-split (5120 x 10/bank)", (3 * m.d_model).div_ceil(banks), m.d_model),
        // input-split reorganization: 2560x20 per bank
        ("Q/K/V input-split (2560 x 20/bank)", 2 * (3 * m.d_model).div_ceil(banks), m.d_model / 2),
        ("FFN up (5120 -> 13824/512 banks)", m.d_ffn.div_ceil(banks), m.d_model),
    ] {
        let mut t = Table::new(
            &format!("Fig 8 — {label} (Llama2-13B)"),
            &["batch", "dram(us)", "(512,8)(us)", "(256,16)(us)", "best-speedup"],
        );
        let s58 = SramBank::new(&hw.sram, SramGang::In512Out8, &hw.dram);
        let s216 = SramBank::new(&hw.sram, SramGang::In256Out16, &hw.dram);
        for batch in [1usize, 4, 16, 64] {
            let d = dram.gemv(out_tile, in_dim, batch).latency_ns;
            let a = s58.gemm(out_tile, in_dim, batch, WeightPolicy::Reload).latency_ns;
            let b = s216.gemm(out_tile, in_dim, batch, WeightPolicy::Reload).latency_ns;
            t.rowv(vec![
                batch.to_string(),
                fnum(d / 1e3),
                fnum(a / 1e3),
                fnum(b / 1e3),
                fx(d / a.min(b)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Fig 9: end-to-end effect of decoupling the column decoder (Llama2-13B).
/// One pool job per (phase, batch, seqlen) cell.
pub fn fig9(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Fig 9 — DRAM-PIM reorganization (decoupled 8:1/4:1 column decoder), Llama2-13B",
        &["phase", "batch", "seqlen", "base(ms)", "opt(ms)", "speedup"],
    );
    let cells = vec![
        (crate::config::Phase::Decode, 16usize, 4096usize),
        (crate::config::Phase::Decode, 64, 4096),
        (crate::config::Phase::Prefill, 1, 2048),
    ];
    let rows = par_map_indexed(cx.jobs, cells, |_, (phase, batch, seq)| {
        let mut base = cx.rc(ArchKind::CompAirBase, ModelConfig::llama2_13b());
        base.phase = phase;
        base.batch = batch;
        base.seq_len = seq;
        let mut opt = base.clone();
        opt.arch = ArchKind::CompAirOpt;
        opt.hw.dram.column_decoder = ColumnDecoder::Decoupled8and4;
        let tb = crate::api::Engine::new(base).simulate().latency_ns;
        let to = crate::api::Engine::new(opt).simulate().latency_ns;
        vec![
            format!("{phase:?}"),
            batch.to_string(),
            seq.to_string(),
            fnum(tb / 1e6),
            fnum(to / 1e6),
            fx(tb / to),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// Fig 20: DSE of the SRAM-PIM gang shape × voltage against the per-bank
/// DRAM feed bandwidth (green line) and the HB ceiling (red line).
pub fn fig20(_cx: &FigCtx) -> String {
    let mut out = String::new();
    for gang in [SramGang::In512Out8, SramGang::In256Out16] {
        let mut t = Table::new(
            &format!("Fig 20 — DSE {} (GeMM 4096x{}-ish tile, batch 16)", gang.label(), 16),
            &["voltage", "latency(us)", "compute-bound?", "feed(GB/s)", "hb(GB/s)"],
        );
        for v in [0.6f64, 0.7, 0.8, 0.9] {
            let mut hw = HwConfig::paper();
            hw.sram.voltage = Voltage(v);
            let bank = SramBank::new(&hw.sram, gang, &hw.dram);
            let (c, b) = bank.gemm_detailed(16, 4096, 16, WeightPolicy::Reload);
            let feed = PimBank::new(&hw.dram).sram_feed_gbs();
            t.rowv(vec![
                format!("{v:.1}V"),
                fnum(c.latency_ns / 1e3),
                (b.compute_ns > b.feed_ns + b.writeback_ns).to_string(),
                fnum(feed),
                fnum(hw.hb.gbs_per_bank()),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_input_split_competitive() {
        let s = fig8(&FigCtx::default());
        assert!(s.contains("input-split"));
        assert!(s.contains("(256,16)"));
    }

    #[test]
    fn fig9_speedup_in_paper_band() {
        // paper: 1.15-1.5x end-to-end
        let s = fig9(&FigCtx::default());
        let speedups: Vec<f64> = s
            .lines()
            .filter_map(|l| l.split_whitespace().last()?.strip_suffix('x')?.parse().ok())
            .collect();
        assert!(!speedups.is_empty());
        for sp in &speedups {
            assert!((1.0..2.2).contains(sp), "fig9 speedup {sp} out of band:\n{s}");
        }
        assert!(speedups.iter().any(|s| *s > 1.05), "decoupling must help somewhere");
    }

    #[test]
    fn fig20_divergence_point() {
        // below the divergence point (feed-bound) voltage must not matter;
        // the DSE table should show compute-bound=false at batch 16 tiles
        let s = fig20(&FigCtx::default());
        assert!(s.contains("0.6V") && s.contains("0.9V"));
    }
}
