//! End-to-end evaluation figures: Fig 15 (vs CENT & AttAcc), Fig 16 (decode
//! ablation), Fig 17 (prefill), Fig 18 (TP), Fig 19 (long context).

use crate::api::Engine;
use crate::config::{ArchKind, ModelConfig, Phase, RunConfig};
use crate::util::pool::par_map_indexed;
use crate::util::table::{fenergy_pj, fnum, ftime_ns, fx, Table};

use super::FigCtx;

fn rc(cx: &FigCtx, arch: ArchKind, m: ModelConfig) -> RunConfig {
    cx.rc(arch, m)
}

/// Fig 15: GPT3-175B, batch 64, decode @128K — latency/throughput/energy of
/// CompAir vs CENT (32/96 devices, TP=8) vs AttAcc (4 A100 + 4 HBM-PIM).
/// One pool job per system point; the sweep shares nothing across cells.
pub fn fig15(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Fig 15 — GPT3-175B decode (batch=64, seqlen=128K, TP=8)",
        &["system", "devices", "lat/token", "tok/s", "energy/token"],
    );
    // the 128K points, the AttAcc 4K comparison point, and CompAir at the
    // same 4K shape for the 3.52x energy headline
    let cells: Vec<(ArchKind, usize, usize, String, String)> = vec![
        (ArchKind::Cent, 32, 128 * 1024, ArchKind::Cent.label().into(), "32".into()),
        (ArchKind::CompAirOpt, 32, 128 * 1024, ArchKind::CompAirOpt.label().into(), "32".into()),
        (ArchKind::Cent, 96, 128 * 1024, ArchKind::Cent.label().into(), "96".into()),
        (ArchKind::CompAirOpt, 96, 128 * 1024, ArchKind::CompAirOpt.label().into(), "96".into()),
        (ArchKind::AttAcc, 32, 4096, "AttAcc-4-A100-HBM (4K ctx)".into(), "4+4".into()),
        (ArchKind::CompAirOpt, 96, 4096, "CompAir_Opt (4K ctx, 96dev)".into(), "96".into()),
    ];
    let rows = par_map_indexed(cx.jobs, cells, |_, (arch, devices, seq, system, dev_label)| {
        let mut c = rc(cx, arch, ModelConfig::gpt3_175b());
        c.batch = 64;
        c.seq_len = seq;
        c.tp = 8;
        c.devices = devices;
        let r = Engine::new(c).simulate();
        vec![
            system,
            dev_label,
            ftime_ns(r.latency_ns),
            fnum(r.throughput_tok_s),
            fenergy_pj(r.energy.total_pj()),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// Fig 16: decode throughput ablation over batch × seqlen (Llama2-70B/7B):
/// CENT → CENT+CurryALU → CompAir_Base → CompAir_Opt. Each (model, batch,
/// seqlen) row prices four architectures — one pool job per row.
pub fn fig16(cx: &FigCtx) -> String {
    let mut out = String::new();
    for model in [ModelConfig::llama2_70b(), ModelConfig::llama2_7b()] {
        let mut t = Table::new(
            &format!("Fig 16 — {} decode throughput (tok/s), TP=8, 32 devices", model.name),
            &["batch", "seqlen", "CENT", "+CurryALU", "CompAir_Base", "CompAir_Opt", "best-vs-CENT"],
        );
        let mut cells = Vec::new();
        for batch in [1usize, 16, 64] {
            for seq in [4096usize, 16384, 32768] {
                cells.push((batch, seq));
            }
        }
        let rows = par_map_indexed(cx.jobs, cells, |_, (batch, seq)| {
            let mut row = vec![batch.to_string(), seq.to_string()];
            let mut thr = Vec::new();
            for arch in [
                ArchKind::Cent,
                ArchKind::CentCurry,
                ArchKind::CompAirBase,
                ArchKind::CompAirOpt,
            ] {
                let mut c = rc(cx, arch, model.clone());
                c.batch = batch;
                c.seq_len = seq;
                let r = Engine::new(c).simulate();
                thr.push(r.throughput_tok_s);
                row.push(fnum(r.throughput_tok_s));
            }
            row.push(fx(thr[3] / thr[0]));
            row
        });
        for row in rows {
            t.rowv(row);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Fig 17: prefill latency speedups across the model zoo (0.5K prompt).
/// One pool job per model.
pub fn fig17(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Fig 17 — prefill (0.5K) latency, speedup over CENT",
        &["model", "CENT(ms)", "Base", "Opt", "Opt-speedup"],
    );
    let rows = par_map_indexed(cx.jobs, ModelConfig::zoo(), |_, m| {
        let run = |arch: ArchKind| {
            let mut c = rc(cx, arch, m.clone());
            c.phase = Phase::Prefill;
            c.batch = 1;
            c.seq_len = 512;
            Engine::new(c).simulate().latency_ns
        };
        let cent = run(ArchKind::Cent);
        let base = run(ArchKind::CompAirBase);
        let opt = run(ArchKind::CompAirOpt);
        vec![
            m.name.into(),
            fnum(cent / 1e6),
            fx(cent / base),
            fx(cent / opt),
            fx(cent / opt),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// Fig 18: tensor-parallel sweep — bank utilization and latency. One pool
/// job per TP point.
pub fn fig18(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Fig 18 — TP sweep, Llama2-13B (batch=64, decode, 4K)",
        &["tp", "bank-util", "CENT lat", "CompAir lat", "CompAir speedup"],
    );
    let rows = par_map_indexed(cx.jobs, vec![1usize, 2, 4, 8, 16, 32], |_, tp| {
        let mut a = rc(cx, ArchKind::Cent, ModelConfig::llama2_13b());
        a.batch = 64;
        a.seq_len = 4096;
        a.tp = tp;
        a.devices = 32;
        let mut b = a.clone();
        b.arch = ArchKind::CompAirOpt;
        b.hw = crate::config::HwConfig::paper_opt();
        let ra = Engine::new(a).simulate();
        let rb = Engine::new(b).simulate();
        vec![
            tp.to_string(),
            format!("{:.1}%", rb.bank_util * 100.0),
            ftime_ns(ra.latency_ns),
            ftime_ns(rb.latency_ns),
            fx(ra.latency_ns / rb.latency_ns),
        ]
    });
    for row in rows {
        t.rowv(row);
    }
    t.render()
}

/// Fig 19: very long context (128K ctx, 8K generation) on Qwen-72B and
/// GPT3-175B, with non-linear share. One pool job per model (the speedup
/// column is relative within a model's pair of rows).
pub fn fig19(cx: &FigCtx) -> String {
    let mut t = Table::new(
        "Fig 19 — long context (seq=128K), decode, batch=16, TP=8",
        &["model", "arch", "lat/token", "tok/s", "nonlin %", "speedup"],
    );
    let models = vec![ModelConfig::qwen_72b(), ModelConfig::gpt3_175b()];
    let row_pairs = par_map_indexed(cx.jobs, models, |_, m| {
        let mut results = Vec::new();
        for arch in [ArchKind::Cent, ArchKind::CompAirOpt] {
            let mut c = rc(cx, arch, m.clone());
            c.batch = 16;
            c.seq_len = 128 * 1024;
            c.gen_len = 8192;
            let r = Engine::new(c).simulate();
            results.push((arch, r));
        }
        let base = results[0].1.latency_ns;
        results
            .into_iter()
            .map(|(arch, r)| {
                vec![
                    m.name.to_string(),
                    arch.label().into(),
                    ftime_ns(r.latency_ns),
                    fnum(r.throughput_tok_s),
                    format!("{:.1}%", r.nonlinear_frac * 100.0),
                    fx(base / r.latency_ns),
                ]
            })
            .collect::<Vec<_>>()
    });
    for row in row_pairs.into_iter().flatten() {
        t.rowv(row);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn speedups(s: &str) -> Vec<f64> {
        s.lines()
            .filter_map(|l| l.split_whitespace().last()?.strip_suffix('x')?.parse().ok())
            .collect()
    }

    #[test]
    fn fig15_compair_beats_cent_and_attacc_energy() {
        let s = fig15(&FigCtx::default());
        assert!(s.contains("CompAir_Opt") && s.contains("AttAcc"));
        assert!(s.contains("CENT"));
    }

    #[test]
    fn fig16_best_speedup_band() {
        // paper: 1.95-6.28x decode improvement at batch 64; allow wider sim band
        let s = fig16(&FigCtx::default());
        let sp = speedups(&s);
        assert!(!sp.is_empty());
        let max = sp.iter().cloned().fold(0.0, f64::max);
        assert!((1.9..14.0).contains(&max), "max decode speedup {max}");
    }

    #[test]
    fn fig17_band() {
        // paper: 3.29-5.46x (Base) → 4.1-7.89x (Opt)
        let s = fig17(&FigCtx::default());
        let sp = speedups(&s);
        for v in &sp {
            assert!((1.5..12.0).contains(v), "prefill speedup {v} out of band:\n{s}");
        }
    }

    #[test]
    fn fig18_util_monotone_nonincreasing() {
        let s = fig18(&FigCtx::default());
        let utils: Vec<f64> = s
            .lines()
            .filter_map(|l| {
                l.split_whitespace().nth(1)?.strip_suffix('%')?.parse().ok()
            })
            .collect();
        assert!(utils.len() >= 4);
        for w in utils.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "bank util must not grow with TP: {utils:?}");
        }
    }

    #[test]
    fn fig19_long_context_speedup() {
        // paper: 2.13-2.73x decode improvement at 128K
        let s = fig19(&FigCtx::default());
        let sp: Vec<f64> = speedups(&s).into_iter().filter(|v| *v > 1.01).collect();
        assert!(!sp.is_empty());
        for v in &sp {
            assert!((1.3..8.0).contains(v), "128K speedup {v}:\n{s}");
        }
    }
}
