//! LLM workload model: transformer op-graphs per phase, FLOP/byte math,
//! KV-cache growth, and the named serving scenarios (request-mix traces
//! with per-class SLOs) the coordinator consumes.
pub mod ops;
pub mod traces;

pub use ops::{layer_ops, LlmOp, OpClass};
pub use traces::{Arrivals, LenDist, RequestClass, Scenario, Slo};
