//! LLM workload model: transformer op-graphs per phase, FLOP/byte math, and
//! KV-cache growth.
pub mod ops;

pub use ops::{layer_ops, LlmOp, OpClass};
