//! Named serving scenarios: heterogeneous request-mix traces with per-class
//! SLOs, the workload side of the serving coordinator.
//!
//! Each [`Scenario`] bundles an arrival process (Poisson, bursty diurnal,
//! or offline batch) with a weighted mix of [`RequestClass`]es, every class
//! carrying its own prompt/generation length distributions and a
//! TTFT/per-token latency [`Slo`]. `Scenario::generate` expands the
//! scenario into a concrete, deterministic `Vec<Request>` trace — the same
//! seed always yields the bit-identical trace, which keeps serving runs
//! reproducible end to end.
//!
//! The built-in registry ([`Scenario::all`]) covers the request shapes the
//! ROADMAP asks the coordinator to handle: interactive chat, RAG long
//! prefill, 128K-context decode, offline batch summarization, bursty
//! diurnal traffic, and a mixed multi-tenant blend.

use crate::coordinator::batcher::Request;
use crate::util::XorShiftRng;

/// Per-class service-level objective on request latency.
///
/// A request meets its SLO when its time-to-first-token and its average
/// per-output-token latency are both within target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slo {
    /// Time-to-first-token target (ns): arrival → first decoded token.
    pub ttft_ns: u64,
    /// Average time-per-output-token target (ns) over the decode phase.
    pub tpot_ns: u64,
}

impl Slo {
    /// SLO from millisecond targets (the unit operators think in).
    pub fn from_ms(ttft_ms: f64, tpot_ms: f64) -> Self {
        Self { ttft_ns: (ttft_ms * 1e6) as u64, tpot_ns: (tpot_ms * 1e6) as u64 }
    }

    /// An effectively unbounded SLO (offline/best-effort traffic).
    pub fn relaxed() -> Self {
        Self { ttft_ns: u64::MAX, tpot_ns: u64::MAX }
    }

    /// Did a request with the given observed latencies meet this SLO?
    pub fn met(&self, ttft_ns: u64, tpot_ns: f64) -> bool {
        ttft_ns <= self.ttft_ns && tpot_ns <= self.tpot_ns as f64
    }
}

impl Default for Slo {
    fn default() -> Self {
        Self::relaxed()
    }
}

/// Token-length distribution for prompts and generations.
#[derive(Debug, Clone, Copy)]
pub enum LenDist {
    /// Every request draws exactly this length.
    Fixed(usize),
    /// Uniform in `[lo, hi]` inclusive.
    Uniform { lo: usize, hi: usize },
    /// Bounded Pareto heavy tail: most requests near `min`, rare ones up to
    /// `cap` (the shape real prompt-length logs show).
    Pareto { min: usize, alpha: f64, cap: usize },
}

impl LenDist {
    /// Draw one length (always ≥ 1).
    pub fn sample(&self, rng: &mut XorShiftRng) -> usize {
        let v = match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform { lo, hi } => rng.next_in(lo, hi),
            LenDist::Pareto { min, alpha, cap } => {
                let u = rng.next_f64().max(1e-12);
                ((min as f64 / u.powf(1.0 / alpha)) as usize).min(cap)
            }
        };
        v.max(1)
    }

    /// Largest length this distribution can emit (KV-sizing aid).
    pub fn max_len(&self) -> usize {
        match *self {
            LenDist::Fixed(n) => n.max(1),
            LenDist::Uniform { hi, .. } => hi.max(1),
            LenDist::Pareto { cap, .. } => cap.max(1),
        }
    }
}

/// One tenant/request class inside a scenario.
#[derive(Debug, Clone)]
pub struct RequestClass {
    /// Class label used in per-class reports (e.g. "chat", "rag").
    pub name: &'static str,
    /// Relative sampling weight within the scenario mix.
    pub weight: f64,
    /// Prompt-length distribution (tokens).
    pub prompt: LenDist,
    /// Generation-length distribution (tokens).
    pub gen: LenDist,
    /// Latency objective for this class.
    pub slo: Slo,
}

/// Request arrival process over simulated time.
#[derive(Debug, Clone, Copy)]
pub enum Arrivals {
    /// Homogeneous Poisson arrivals at `rate` requests/s.
    Poisson { rate: f64 },
    /// On/off diurnal modulation: `duty` fraction of every `period_s`
    /// window runs at `peak_rate`, the rest at `base_rate` — the bursty
    /// traffic shape that stresses admission and eviction.
    Bursty { base_rate: f64, peak_rate: f64, period_s: f64, duty: f64 },
    /// Offline batch: every request is present at t = 0 (throughput-bound
    /// scheduling, no arrival jitter).
    Offline,
}

impl Arrivals {
    /// Advance the clock from `now_s` to the next arrival (seconds).
    fn next_after(&self, now_s: f64, rng: &mut XorShiftRng) -> f64 {
        match *self {
            Arrivals::Poisson { rate } => now_s + rng.next_exp(rate),
            Arrivals::Bursty { base_rate, peak_rate, period_s, duty } => {
                // piecewise-constant-rate Poisson: the rate in effect at the
                // current instant drives the next inter-arrival draw
                let phase = (now_s / period_s).fract();
                let rate = if phase < duty { peak_rate } else { base_rate };
                now_s + rng.next_exp(rate)
            }
            Arrivals::Offline => now_s,
        }
    }
}

/// A named serving scenario: arrival process + weighted class mix.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Registry key (`serve --scenario <name>`).
    pub name: &'static str,
    /// One-line description printed by `compair list`.
    pub description: &'static str,
    /// Arrival process shared by all classes.
    pub arrivals: Arrivals,
    /// Weighted request-class mix (at least one class).
    pub classes: Vec<RequestClass>,
    /// Request count a default run uses (CLI `--requests` overrides).
    pub default_requests: usize,
}

impl Scenario {
    /// All built-in scenarios, in registry order.
    pub fn all() -> Vec<Scenario> {
        vec![
            Scenario {
                name: "chat",
                description: "interactive chat: short prompts, short generations, tight TTFT",
                arrivals: Arrivals::Poisson { rate: 32.0 },
                classes: vec![RequestClass {
                    name: "chat",
                    weight: 1.0,
                    prompt: LenDist::Uniform { lo: 64, hi: 512 },
                    gen: LenDist::Uniform { lo: 16, hi: 128 },
                    slo: Slo::from_ms(200.0, 50.0),
                }],
                default_requests: 64,
            },
            Scenario {
                name: "rag",
                description: "retrieval-augmented: long stuffed-context prefill, short answers",
                arrivals: Arrivals::Poisson { rate: 8.0 },
                classes: vec![RequestClass {
                    name: "rag",
                    weight: 1.0,
                    prompt: LenDist::Pareto { min: 2048, alpha: 1.2, cap: 16384 },
                    gen: LenDist::Uniform { lo: 32, hi: 128 },
                    slo: Slo::from_ms(2000.0, 60.0),
                }],
                default_requests: 32,
            },
            Scenario {
                name: "long-context",
                description: "128K-context decode: the paper's Fig 19 shape as live traffic",
                arrivals: Arrivals::Poisson { rate: 0.5 },
                classes: vec![RequestClass {
                    name: "long-ctx",
                    weight: 1.0,
                    prompt: LenDist::Fixed(128 * 1024),
                    gen: LenDist::Uniform { lo: 32, hi: 128 },
                    slo: Slo::from_ms(30_000.0, 100.0),
                }],
                default_requests: 8,
            },
            Scenario {
                name: "batch",
                description: "offline summarization: all requests queued at t=0, SLO-relaxed",
                arrivals: Arrivals::Offline,
                classes: vec![RequestClass {
                    name: "summarize",
                    weight: 1.0,
                    prompt: LenDist::Uniform { lo: 1024, hi: 4096 },
                    gen: LenDist::Uniform { lo: 64, hi: 256 },
                    slo: Slo::relaxed(),
                }],
                default_requests: 48,
            },
            Scenario {
                name: "bursty",
                description: "diurnal bursts: 8x peak-to-base arrival swings over chat traffic",
                arrivals: Arrivals::Bursty {
                    base_rate: 8.0,
                    peak_rate: 64.0,
                    period_s: 2.0,
                    duty: 0.25,
                },
                classes: vec![RequestClass {
                    name: "chat",
                    weight: 1.0,
                    prompt: LenDist::Uniform { lo: 64, hi: 512 },
                    gen: LenDist::Uniform { lo: 16, hi: 128 },
                    slo: Slo::from_ms(400.0, 50.0),
                }],
                default_requests: 64,
            },
            Scenario {
                name: "mixed",
                description: "multi-tenant blend: chat + RAG + background batch sharing the fabric",
                arrivals: Arrivals::Poisson { rate: 16.0 },
                classes: vec![
                    RequestClass {
                        name: "chat",
                        weight: 0.6,
                        prompt: LenDist::Uniform { lo: 64, hi: 512 },
                        gen: LenDist::Uniform { lo: 16, hi: 128 },
                        slo: Slo::from_ms(200.0, 50.0),
                    },
                    RequestClass {
                        name: "rag",
                        weight: 0.25,
                        prompt: LenDist::Pareto { min: 2048, alpha: 1.2, cap: 16384 },
                        gen: LenDist::Uniform { lo: 32, hi: 128 },
                        slo: Slo::from_ms(2000.0, 60.0),
                    },
                    RequestClass {
                        name: "batch",
                        weight: 0.15,
                        prompt: LenDist::Uniform { lo: 1024, hi: 4096 },
                        gen: LenDist::Uniform { lo: 64, hi: 256 },
                        slo: Slo::relaxed(),
                    },
                ],
                default_requests: 64,
            },
        ]
    }

    /// Registry names, in order.
    pub fn names() -> Vec<&'static str> {
        Self::all().into_iter().map(|s| s.name).collect()
    }

    /// Look a scenario up by its registry name.
    pub fn by_name(name: &str) -> Option<Scenario> {
        Self::all().into_iter().find(|s| s.name.eq_ignore_ascii_case(name))
    }

    /// Class names in index order (request `class` fields index into this).
    pub fn class_names(&self) -> Vec<&'static str> {
        self.classes.iter().map(|c| c.name).collect()
    }

    fn pick_class(&self, rng: &mut XorShiftRng) -> usize {
        let total: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut u = rng.next_f64() * total;
        for (i, c) in self.classes.iter().enumerate() {
            u -= c.weight;
            if u < 0.0 {
                return i;
            }
        }
        self.classes.len() - 1
    }

    /// Expand the scenario into `n` concrete requests, sorted by arrival.
    /// Deterministic: identical `(seed, n)` always produces the identical
    /// trace.
    pub fn generate(&self, seed: u64, n: usize) -> Vec<Request> {
        let mut rng = XorShiftRng::new(seed);
        let mut t_s = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for id in 0..n {
            t_s = self.arrivals.next_after(t_s, &mut rng);
            let ci = self.pick_class(&mut rng);
            let c = &self.classes[ci];
            out.push(Request {
                id: id as u64,
                class: ci,
                prompt_len: c.prompt.sample(&mut rng),
                gen_len: c.gen.sample(&mut rng),
                arrived_ns: (t_s * 1e9) as u64,
                slo: c.slo,
                preemptions: 0,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_the_documented_scenarios() {
        let names = Scenario::names();
        for expected in ["chat", "rag", "long-context", "batch", "bursty", "mixed"] {
            assert!(names.contains(&expected), "missing scenario '{expected}'");
        }
        assert!(names.len() >= 5);
    }

    #[test]
    fn by_name_roundtrip_and_unknown() {
        for s in Scenario::all() {
            assert_eq!(Scenario::by_name(s.name).unwrap().name, s.name);
        }
        assert!(Scenario::by_name("nope").is_none());
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        for sc in Scenario::all() {
            let a = sc.generate(7, 40);
            let b = sc.generate(7, 40);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(
                    (x.id, x.class, x.prompt_len, x.gen_len, x.arrived_ns),
                    (y.id, y.class, y.prompt_len, y.gen_len, y.arrived_ns),
                    "{} trace not deterministic",
                    sc.name
                );
            }
            let c = sc.generate(8, 40);
            if !matches!(sc.arrivals, Arrivals::Offline) {
                assert!(
                    a.iter().zip(&c).any(|(x, y)| x.arrived_ns != y.arrived_ns),
                    "{} trace ignores the seed",
                    sc.name
                );
            }
        }
    }

    #[test]
    fn arrivals_are_sorted_and_lengths_in_range() {
        for sc in Scenario::all() {
            let reqs = sc.generate(42, 64);
            assert_eq!(reqs.len(), 64);
            for w in reqs.windows(2) {
                assert!(w[0].arrived_ns <= w[1].arrived_ns, "{} out of order", sc.name);
            }
            for r in &reqs {
                assert!(r.class < sc.classes.len());
                let c = &sc.classes[r.class];
                assert!(r.prompt_len >= 1 && r.prompt_len <= c.prompt.max_len());
                assert!(r.gen_len >= 1 && r.gen_len <= c.gen.max_len());
                assert_eq!(r.slo, c.slo);
            }
        }
    }

    #[test]
    fn offline_arrivals_all_at_zero() {
        let sc = Scenario::by_name("batch").unwrap();
        assert!(sc.generate(1, 16).iter().all(|r| r.arrived_ns == 0));
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // squared coefficient of variation of inter-arrivals: bursty >> 1,
        // Poisson ≈ 1
        let cv2 = |reqs: &[Request]| {
            let gaps: Vec<f64> =
                reqs.windows(2).map(|w| (w[1].arrived_ns - w[0].arrived_ns) as f64).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var =
                gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
            var / (mean * mean)
        };
        let bursty = cv2(&Scenario::by_name("bursty").unwrap().generate(3, 400));
        let chat = cv2(&Scenario::by_name("chat").unwrap().generate(3, 400));
        assert!(bursty > chat, "bursty cv2={bursty:.2} vs poisson cv2={chat:.2}");
    }

    #[test]
    fn pareto_is_heavy_tailed_but_capped() {
        let d = LenDist::Pareto { min: 100, alpha: 1.2, cap: 1000 };
        let mut rng = XorShiftRng::new(11);
        let samples: Vec<usize> = (0..2000).map(|_| d.sample(&mut rng)).collect();
        assert!(samples.iter().all(|&s| (100..=1000).contains(&s)));
        assert!(samples.iter().filter(|&&s| s < 300).count() > 1000, "mass near min");
        assert!(samples.iter().any(|&s| s > 600), "tail reaches toward cap");
    }

    #[test]
    fn mixed_scenario_uses_every_class() {
        let sc = Scenario::by_name("mixed").unwrap();
        let reqs = sc.generate(5, 200);
        for ci in 0..sc.classes.len() {
            assert!(reqs.iter().any(|r| r.class == ci), "class {ci} never sampled");
        }
    }

    #[test]
    fn slo_met_logic() {
        let slo = Slo::from_ms(200.0, 50.0);
        assert!(slo.met(150_000_000, 40e6));
        assert!(!slo.met(250_000_000, 40e6));
        assert!(!slo.met(150_000_000, 60e6));
        assert!(Slo::relaxed().met(u64::MAX - 1, 1e18));
    }
}
