//! Transformer operator decomposition (Fig 3): the op list one layer
//! executes per phase, with exact shapes. The mapping layer lowers these
//! onto the simulated hardware.

use crate::config::{ModelConfig, Phase};

/// Operator class, used for mapping decisions and figure breakdowns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Fc,
    Attention,
    NonLinear,
    Collective,
}

impl OpClass {
    /// Stable lowercase label (used by the JSON report serialization).
    pub fn label(&self) -> &'static str {
        match self {
            OpClass::Fc => "fc",
            OpClass::Attention => "attention",
            OpClass::NonLinear => "nonlinear",
            OpClass::Collective => "collective",
        }
    }
}

/// One operator instance with concrete shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum LlmOp {
    /// Dense layer: `tokens × d_in → tokens × d_out` (weights `d_out×d_in`).
    Fc { name: &'static str, d_in: usize, d_out: usize, tokens: usize },
    /// Q·Kᵀ: per (batch, head): `rows_q × d_head` against a `seq × d_head`
    /// K-cache (input-dependent matrix — no cross-batch reuse).
    AttnQK { batch: usize, heads: usize, rows_q: usize, seq: usize, d_head: usize },
    /// scores·V: per (batch, head): `rows_q × seq` against `seq × d_head`.
    AttnSV { batch: usize, heads: usize, rows_q: usize, seq: usize, d_head: usize },
    /// Row-wise softmax over `rows` rows of length `seq` (exp + reduce +
    /// normalize).
    Softmax { rows: usize, seq: usize },
    /// RoPE on Q and K: `tokens × heads` head-vectors of `d_head`.
    Rope { tokens: usize, heads: usize, d_head: usize },
    /// RMSNorm over `tokens` vectors of `d_model` (square-sum reduce +
    /// rsqrt + scale).
    RmsNorm { tokens: usize, d_model: usize },
    /// Element-wise activation/gating over `tokens × width` (SiLU·gate for
    /// Llama, GELU for GPT).
    Activation { name: &'static str, tokens: usize, width: usize },
    /// Tensor-parallel all-reduce of `tokens × d_model` BF16 across `tp`
    /// devices.
    AllReduce { tokens: usize, d_model: usize },
}

impl LlmOp {
    pub fn class(&self) -> OpClass {
        match self {
            LlmOp::Fc { .. } => OpClass::Fc,
            LlmOp::AttnQK { .. } | LlmOp::AttnSV { .. } => OpClass::Attention,
            LlmOp::Softmax { .. }
            | LlmOp::Rope { .. }
            | LlmOp::RmsNorm { .. }
            | LlmOp::Activation { .. } => OpClass::NonLinear,
            LlmOp::AllReduce { .. } => OpClass::Collective,
        }
    }

    /// MAC count of this op (elementwise/nonlinear ops report their scalar
    /// op count).
    pub fn macs(&self) -> u64 {
        match self {
            LlmOp::Fc { d_in, d_out, tokens, .. } => (d_in * d_out * tokens) as u64,
            LlmOp::AttnQK { batch, heads, rows_q, seq, d_head }
            | LlmOp::AttnSV { batch, heads, rows_q, seq, d_head } => {
                (batch * heads * rows_q * seq * d_head) as u64
            }
            LlmOp::Softmax { rows, seq } => (rows * seq) as u64,
            LlmOp::Rope { tokens, heads, d_head } => (tokens * heads * d_head) as u64,
            LlmOp::RmsNorm { tokens, d_model } => (tokens * d_model) as u64,
            LlmOp::Activation { tokens, width, .. } => (tokens * width) as u64,
            LlmOp::AllReduce { tokens, d_model } => (tokens * d_model) as u64,
        }
    }

    pub fn name(&self) -> String {
        match self {
            LlmOp::Fc { name, .. } => format!("fc:{name}"),
            LlmOp::AttnQK { .. } => "attn:qk".into(),
            LlmOp::AttnSV { .. } => "attn:sv".into(),
            LlmOp::Softmax { .. } => "nl:softmax".into(),
            LlmOp::Rope { .. } => "nl:rope".into(),
            LlmOp::RmsNorm { .. } => "nl:rmsnorm".into(),
            LlmOp::Activation { name, .. } => format!("nl:{name}"),
            LlmOp::AllReduce { .. } => "coll:allreduce".into(),
        }
    }
}

/// The op list of ONE transformer layer for the phase.
///
/// * decode: `rows_q = 1` new token per sequence, KV length = `seq`;
/// * prefill: `rows_q = seq` (we model the full causal pass with the
///   average effective KV length seq/2 for the quadratic terms).
pub fn layer_ops(m: &ModelConfig, phase: Phase, batch: usize, seq: usize) -> Vec<LlmOp> {
    let d = m.d_model;
    let kv_dim = m.n_kv_heads * m.d_head();
    let (tokens, rows_q, eff_seq) = match phase {
        Phase::Decode => (batch, 1, seq),
        Phase::Prefill => (batch * seq, seq, seq.div_ceil(2).max(1)),
    };
    let mut ops = vec![
        LlmOp::RmsNorm { tokens, d_model: d },
        LlmOp::Fc { name: "q", d_in: d, d_out: d, tokens },
        LlmOp::Fc { name: "kv", d_in: d, d_out: 2 * kv_dim, tokens },
        LlmOp::Rope { tokens, heads: m.n_heads + m.n_kv_heads, d_head: m.d_head() },
        LlmOp::AttnQK { batch, heads: m.n_heads, rows_q, seq: eff_seq, d_head: m.d_head() },
        LlmOp::Softmax { rows: batch * m.n_heads * rows_q, seq: eff_seq },
        LlmOp::AttnSV { batch, heads: m.n_heads, rows_q, seq: eff_seq, d_head: m.d_head() },
        LlmOp::Fc { name: "o", d_in: d, d_out: d, tokens },
        LlmOp::AllReduce { tokens, d_model: d },
        LlmOp::RmsNorm { tokens, d_model: d },
    ];
    if m.gated_ffn {
        ops.push(LlmOp::Fc { name: "up", d_in: d, d_out: m.d_ffn, tokens });
        ops.push(LlmOp::Fc { name: "gate", d_in: d, d_out: m.d_ffn, tokens });
        ops.push(LlmOp::Activation { name: "silu_gate", tokens, width: m.d_ffn });
    } else {
        ops.push(LlmOp::Fc { name: "up", d_in: d, d_out: m.d_ffn, tokens });
        ops.push(LlmOp::Activation { name: "gelu", tokens, width: m.d_ffn });
    }
    ops.push(LlmOp::Fc { name: "down", d_in: m.d_ffn, d_out: d, tokens });
    ops.push(LlmOp::AllReduce { tokens, d_model: d });
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_layer_macs_match_closed_form() {
        let m = ModelConfig::llama2_7b();
        let ops = layer_ops(&m, Phase::Decode, 1, 4096);
        let fc_macs: u64 =
            ops.iter().filter(|o| o.class() == OpClass::Fc).map(|o| o.macs()).sum();
        // 7B layer FC: q(d²) + kv(2d·kv) + o(d²) + up/gate/down(3·d·f)
        let d = 4096u64;
        let f = 11008u64;
        assert_eq!(fc_macs, d * d + 2 * d * d + d * d + 3 * d * f);
        let attn_macs: u64 = ops
            .iter()
            .filter(|o| o.class() == OpClass::Attention)
            .map(|o| o.macs())
            .sum();
        assert_eq!(attn_macs, 2 * 32 * 4096 * 128);
    }

    #[test]
    fn prefill_scales_quadratically_in_attention() {
        let m = ModelConfig::llama2_7b();
        let a1: u64 = layer_ops(&m, Phase::Prefill, 1, 1024)
            .iter()
            .filter(|o| o.class() == OpClass::Attention)
            .map(|o| o.macs())
            .sum();
        let a2: u64 = layer_ops(&m, Phase::Prefill, 1, 2048)
            .iter()
            .filter(|o| o.class() == OpClass::Attention)
            .map(|o| o.macs())
            .sum();
        let ratio = a2 as f64 / a1 as f64;
        assert!((3.8..4.2).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn gqa_shrinks_kv_projection() {
        let mha = ModelConfig::qwen_72b();
        let gqa = ModelConfig::llama2_70b();
        let kv_of = |m: &ModelConfig| {
            layer_ops(m, Phase::Decode, 1, 128)
                .iter()
                .find_map(|o| match o {
                    LlmOp::Fc { name: "kv", d_out, .. } => Some(*d_out),
                    _ => None,
                })
                .unwrap()
        };
        assert_eq!(kv_of(&mha), 2 * 8192);
        assert_eq!(kv_of(&gqa), 2 * 1024);
    }

    #[test]
    fn gpt_has_no_gate() {
        let ops = layer_ops(&ModelConfig::gpt3_175b(), Phase::Decode, 4, 128);
        assert!(ops.iter().all(|o| !matches!(o, LlmOp::Fc { name: "gate", .. })));
        assert!(ops.iter().any(|o| matches!(o, LlmOp::Activation { name: "gelu", .. })));
    }

    #[test]
    fn op_names_stable() {
        let ops = layer_ops(&ModelConfig::tiny(), Phase::Decode, 1, 16);
        let names: Vec<String> = ops.iter().map(|o| o.name()).collect();
        assert!(names.contains(&"nl:softmax".to_string()));
        assert!(names.contains(&"fc:down".to_string()));
    }
}
