//! L3 serving coordinator: request queue, SLO-aware continuous batcher,
//! chunked-prefill decode scheduler, and metrics — the vLLM-router-shaped
//! layer that drives the simulated hardware (timing/energy) and, in the
//! end-to-end example, the PJRT runtime (numerics). `cluster` scales the
//! same loop across multiple replicas on the CXL fabric, with optional
//! disaggregated prefill/decode pools and priced KV migration.
pub mod batcher;
pub mod cluster;
pub mod serving;

pub use batcher::{Batcher, BatcherConfig, Request, RequestState};
pub use cluster::{
    run_cluster_scenario, Cluster, ClusterConfig, ClusterReport, ClusterScenarioReport,
    ReplicaReport, RouterPolicy,
};
pub use serving::{
    run_scenario, ClassReport, ScenarioReport, ServeConfig, ServeReport, Server,
};
