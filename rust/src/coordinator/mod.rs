//! L3 serving coordinator: request queue, SLO-aware continuous batcher,
//! chunked-prefill decode scheduler, and metrics — the vLLM-router-shaped
//! layer that drives the simulated hardware (timing/energy) and, in the
//! end-to-end example, the PJRT runtime (numerics).
pub mod batcher;
pub mod serving;

pub use batcher::{Batcher, BatcherConfig, Request, RequestState};
pub use serving::{
    run_scenario, ClassReport, ScenarioReport, ServeConfig, ServeReport, Server,
};
