//! Cluster-scale serving: multiple batcher+simulator replicas on the
//! modeled CXL fabric, with pluggable request routing and an optional
//! disaggregated prefill/decode mode.
//!
//! The paper's topology (§3, §7.1) puts `devices` PIM devices behind one
//! CXL switch, i.e. `devices / tp` independent tensor-parallel replicas.
//! This module serves a workload trace across those replicas: each replica
//! owns its own [`Batcher`], all replicas are costed through one shared
//! [`CachedCostModel`] (identical hardware, so any replica's iteration
//! shape is a cache hit on every other), a router assigns arrivals
//! ([`RouterPolicy`]), and in
//! disaggregated mode the replicas split into a prefill pool and a decode
//! pool. A request prefills in the prefill pool, then its KV cache
//! migrates over the fabric — `kv tokens × ModelConfig::kv_bytes_per_token`
//! bytes priced by [`crate::arch::collective::cxl_p2p`], latency delaying
//! the decode hand-off and bytes billed by the energy model — before
//! decoding in the decode pool.
//!
//! Everything stays deterministic: one event queue drives all replicas,
//! router tie-breaks are by replica index, and a `(scenario, seed,
//! config)` triple reproduces the byte-identical [`ClusterReport`].

use crate::arch::collective::cxl_p2p;
use crate::arch::{CachedCostModel, CostModel, System};
use crate::config::{MappingMode, RunConfig};
use crate::mapper::AutoMappedCostModel;
use crate::sim::{EventQueue, OpCost};
use crate::util::json::{Json, ToJson};
use crate::util::table::{fbytes, fenergy_pj, ftime_ns, Table};
use crate::workload::Scenario;

use super::batcher::{Batcher, Request, RequestState};
use super::serving::{build_report, render_summary, RunTotals, ServeConfig, ServeReport};

/// How the cluster router assigns an arrival to a replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Arrivals rotate over the pool in order (oblivious baseline).
    RoundRobin,
    /// Send to the replica with the least KV committed (resident + queued
    /// + in-flight migrations), ties to the lowest replica index.
    LeastLoadedKv,
    /// Send to the replica where the fewest requests hold a deadline at or
    /// before the newcomer's — the EDF queue it will clear fastest; ties
    /// fall back to least-loaded-KV, then lowest index.
    DeadlineAware,
}

impl RouterPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoadedKv => "least-kv",
            RouterPolicy::DeadlineAware => "deadline",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(RouterPolicy::RoundRobin),
            "least-kv" | "least-loaded-kv" | "kv" => Some(RouterPolicy::LeastLoadedKv),
            "deadline" | "deadline-aware" | "edf" => Some(RouterPolicy::DeadlineAware),
            _ => None,
        }
    }
}

/// Cluster topology + routing configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Replica count (each replica = `rc.devices` devices at `rc.tp`).
    /// Ignored when `disagg` is set (then `replicas = prefill + decode`).
    pub replicas: usize,
    /// `Some((prefill, decode))` splits the replicas into a prefill pool
    /// and a decode pool with KV migration between them; `None` serves
    /// colocated (every replica prefills and decodes).
    pub disagg: Option<(usize, usize)>,
    /// Arrival / migration routing policy.
    pub router: RouterPolicy,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self { replicas: 2, disagg: None, router: RouterPolicy::RoundRobin }
    }
}

impl ClusterConfig {
    /// Total replica count after applying the disaggregation split.
    pub fn replica_count(&self) -> usize {
        match self.disagg {
            Some((p, d)) => p + d,
            None => self.replicas.max(1),
        }
    }

    /// Reject impossible topologies with an operator-readable message.
    pub fn validate(&self) -> Result<(), String> {
        if let Some((p, d)) = self.disagg {
            if p == 0 || d == 0 {
                return Err(format!(
                    "--disagg needs at least one replica in each pool (got {p}:{d})"
                ));
            }
        } else if self.replicas == 0 {
            return Err("--replicas must be positive".into());
        }
        Ok(())
    }
}

/// Per-replica outcome row of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    /// Replica index on the fabric.
    pub id: usize,
    /// "mixed" (colocated), "prefill", or "decode".
    pub role: &'static str,
    /// Arrivals the router assigned here.
    pub routed: u64,
    /// Requests that ran to completion on this replica.
    pub completed: usize,
    /// Decode tokens this replica emitted.
    pub tokens_out: u64,
    /// KV migrations that left this replica (prefill pool).
    pub migrations_out: u64,
    /// KV migrations that landed here (decode pool).
    pub migrations_in: u64,
    /// Simulated time this replica's hardware was executing (ns).
    pub busy_ns: u64,
    /// `busy_ns / cluster makespan`.
    pub utilization: f64,
    /// Peak KV tokens reserved at any iteration boundary.
    pub kv_peak: usize,
}

/// A cluster run's outcome: the aggregate serving report plus fabric-level
/// accounting (per-replica utilization, KV-migration traffic and energy).
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Replica count the run used.
    pub replicas: usize,
    /// Router policy label.
    pub router: &'static str,
    /// The disaggregation split, if any.
    pub disagg: Option<(usize, usize)>,
    /// KV-cache migrations performed (disaggregated mode only).
    pub migrations: u64,
    /// Bytes of KV cache moved over the CXL fabric.
    pub migration_bytes: u64,
    /// Energy spent moving that KV (subset of `report.energy.cxl_pj`).
    pub migration_energy_pj: f64,
    /// One row per replica, in fabric order.
    pub per_replica: Vec<ReplicaReport>,
    /// The aggregate serving report (totals + per-class SLO rows).
    pub report: ServeReport,
}

impl ClusterReport {
    /// Human-readable mode label ("colocated" / "disaggregated P:D").
    pub fn mode(&self) -> String {
        match self.disagg {
            Some((p, d)) => format!("disaggregated {p}p:{d}d"),
            None => "colocated".to_string(),
        }
    }

    /// Render the per-replica utilization table.
    pub fn replica_table(&self) -> Table {
        let mut t = Table::new(
            "per-replica",
            &["replica", "role", "routed", "done", "tokens", "migr in/out", "busy", "util", "kv peak"],
        );
        for r in &self.per_replica {
            t.rowv(vec![
                r.id.to_string(),
                r.role.to_string(),
                r.routed.to_string(),
                r.completed.to_string(),
                r.tokens_out.to_string(),
                format!("{}/{}", r.migrations_in, r.migrations_out),
                ftime_ns(r.busy_ns as f64),
                format!("{:.1}%", r.utilization * 100.0),
                r.kv_peak.to_string(),
            ]);
        }
        t
    }
}

/// A named scenario's cluster-serving outcome on one architecture — the
/// cluster-level analogue of [`super::serving::ScenarioReport`].
#[derive(Debug, Clone)]
pub struct ClusterScenarioReport {
    /// Scenario registry name.
    pub scenario: String,
    /// Architecture label the replicas were costed on.
    pub arch: String,
    /// Model name served.
    pub model: String,
    /// The full cluster report (aggregate + per-replica + migrations).
    pub cluster: ClusterReport,
}

/// Run a named scenario across a replica cluster.
pub fn run_cluster_scenario(
    rc: RunConfig,
    scenario: Scenario,
    n_requests: usize,
    seed: u64,
    cfg: ClusterConfig,
) -> ClusterScenarioReport {
    let name = scenario.name.to_string();
    let arch = rc.arch.label().to_string();
    let model = rc.model.name.to_string();
    let serve = ServeConfig { n_requests, seed, scenario: Some(scenario), ..Default::default() };
    let cluster = Cluster::new(rc, serve, cfg).run();
    ClusterScenarioReport { scenario: name, arch, model, cluster }
}

/// Render the headline cluster metrics (CLI and examples).
pub fn render_cluster_summary(r: &ClusterReport) -> String {
    let mut out = format!(
        "replicas {} ({}) | router {}\n",
        r.replicas,
        r.mode(),
        r.router
    );
    out.push_str(&render_summary(&r.report));
    if r.disagg.is_some() {
        out.push_str(&format!(
            "KV migrations {} | migrated {} | migration energy {}\n",
            r.migrations,
            fbytes(r.migration_bytes),
            fenergy_pj(r.migration_energy_pj)
        ));
    }
    out
}

impl ToJson for ReplicaReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("id", self.id)
            .field("role", self.role)
            .field("routed", self.routed)
            .field("completed", self.completed)
            .field("tokens_out", self.tokens_out)
            .field("migrations_out", self.migrations_out)
            .field("migrations_in", self.migrations_in)
            .field("busy_ns", self.busy_ns)
            .field("utilization", self.utilization)
            .field("kv_peak", self.kv_peak)
    }
}

impl ToJson for ClusterReport {
    fn to_json(&self) -> Json {
        let disagg = self.disagg.map(|(p, d)| {
            Json::obj().field("prefill", p).field("decode", d)
        });
        Json::obj()
            .field("replicas", self.replicas)
            .field("router", self.router)
            .field("mode", self.mode())
            .field("disagg", disagg)
            .field("migrations", self.migrations)
            .field("migration_bytes", self.migration_bytes)
            .field("migration_energy_pj", self.migration_energy_pj)
            .field("per_replica", Json::arr(self.per_replica.iter().map(|r| r.to_json())))
            .field("report", self.report.to_json())
    }
}

impl ToJson for ClusterScenarioReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("scenario", self.scenario.as_str())
            .field("arch", self.arch.as_str())
            .field("model", self.model.as_str())
            .field("cluster", self.cluster.to_json())
    }
}

/// What a replica does in the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    /// Prefill + decode on the same replica (colocated mode).
    Colocated,
    /// Prefill only; hands finished prompts to the decode pool.
    Prefill,
    /// Decode only; receives prefilled requests via KV migration.
    Decode,
}

impl Role {
    fn label(&self) -> &'static str {
        match self {
            Role::Colocated => "mixed",
            Role::Prefill => "prefill",
            Role::Decode => "decode",
        }
    }
}

/// One replica: its batcher plus loop state.
struct Replica {
    role: Role,
    batcher: Batcher,
    /// Prefilled requests migrated here, awaiting decode admission.
    landing: Vec<RequestState>,
    /// KV tokens of migrations routed here but still crossing the fabric
    /// (counted in `kv_load` so routers don't dogpile one destination).
    inflight_kv: usize,
    busy_until: u64,
    iter_pending: bool,
    busy_ns: u64,
    routed: u64,
    tokens_out: u64,
    decode_iters: u64,
    migrations_in: u64,
    migrations_out: u64,
    kv_peak: usize,
}

impl Replica {
    fn new(role: Role, batcher: Batcher) -> Self {
        Self {
            role,
            batcher,
            landing: Vec::new(),
            inflight_kv: 0,
            busy_until: 0,
            iter_pending: false,
            busy_ns: 0,
            routed: 0,
            tokens_out: 0,
            decode_iters: 0,
            migrations_in: 0,
            migrations_out: 0,
            kv_peak: 0,
        }
    }

    /// KV tokens committed to this replica (router load signal): resident
    /// batch + admission queue + landed-but-unadmitted + in-flight
    /// migrations.
    fn kv_load(&self) -> usize {
        self.batcher.kv_in_use()
            + self.batcher.queued_kv_demand()
            + self.inflight_kv
            + self.landing.iter().map(|s| s.kv_footprint()).sum::<usize>()
    }

    /// Requests here holding a deadline at or before `deadline_ns`.
    fn deadline_pressure(&self, deadline_ns: u64) -> usize {
        self.batcher.deadline_pressure(deadline_ns)
            + self.landing.iter().filter(|s| s.req.deadline_ns() <= deadline_ns).count()
    }

    /// Admit migrated requests into the decode batch, earliest deadline
    /// first, while batch and KV budgets allow.
    fn admit_landed(&mut self) {
        while self.batcher.active.len() < self.batcher.cfg.max_batch {
            let head =
                self.batcher.cfg.max_kv_tokens.saturating_sub(self.batcher.kv_in_use());
            let pick = self
                .landing
                .iter()
                .enumerate()
                .filter(|(_, s)| s.kv_footprint() <= head)
                .min_by_key(|(_, s)| (s.req.deadline_ns(), s.req.id))
                .map(|(i, _)| i);
            let Some(i) = pick else { break };
            let s = self.landing.remove(i);
            self.batcher.active.push(s);
        }
    }
}

enum Event {
    Arrival(Request),
    IterationDone(usize),
    /// A migrated request landing at `(replica, state)` after its KV
    /// finished crossing the fabric.
    Migration(usize, RequestState),
}

/// Mutable cluster-wide accounting threaded through the event loop.
struct ClusterState {
    total_cost: OpCost,
    migration_cost: OpCost,
    migrations: u64,
    migration_bytes: u64,
    rr_arrival: usize,
    rr_migration: usize,
}

/// Deterministically pick a replica from `pool = (start, len)`.
fn pick_replica(
    policy: RouterPolicy,
    deadline_ns: u64,
    pool: (usize, usize),
    replicas: &[Replica],
    rr: &mut usize,
) -> usize {
    let (start, len) = pool;
    debug_assert!(len > 0, "routing into an empty pool");
    match policy {
        RouterPolicy::RoundRobin => {
            let i = start + *rr % len;
            *rr += 1;
            i
        }
        RouterPolicy::LeastLoadedKv => (start..start + len)
            .min_by_key(|&i| (replicas[i].kv_load(), i))
            .expect("non-empty pool"),
        RouterPolicy::DeadlineAware => (start..start + len)
            .min_by_key(|&i| (replicas[i].deadline_pressure(deadline_ns), replicas[i].kv_load(), i))
            .expect("non-empty pool"),
    }
}

/// The cluster coordinator: owns the replicas and the shared event clock.
pub struct Cluster {
    rc: RunConfig,
    serve: ServeConfig,
    cfg: ClusterConfig,
}

impl Cluster {
    pub fn new(rc: RunConfig, serve: ServeConfig, cfg: ClusterConfig) -> Self {
        Self { rc, serve, cfg }
    }

    /// The pool arrivals route into.
    fn arrival_pool(&self) -> (usize, usize) {
        match self.cfg.disagg {
            Some((p, _)) => (0, p),
            None => (0, self.cfg.replica_count()),
        }
    }

    /// The pool migrations route into (disaggregated mode only).
    fn decode_pool(&self) -> (usize, usize) {
        match self.cfg.disagg {
            Some((p, d)) => (p, d),
            None => (0, self.cfg.replica_count()),
        }
    }

    /// Plan, cost, and execute one iteration on replica `ri`; returns the
    /// prefilled requests a prefill-pool replica hands off, plus the
    /// iteration end time.
    fn step_replica(
        &self,
        cm: &dyn CostModel,
        ri: usize,
        now: u64,
        replicas: &mut [Replica],
        q: &mut EventQueue<Event>,
        st: &mut ClusterState,
    ) {
        let (handed, end) = {
            let r = &mut replicas[ri];
            if r.iter_pending {
                return;
            }
            match r.role {
                Role::Decode => r.admit_landed(),
                _ => {
                    r.batcher.preempt_for_urgent(now);
                    r.batcher.admit(now);
                }
            }
            if r.batcher.active.is_empty() {
                return;
            }
            let plan = match r.role {
                Role::Decode => Vec::new(),
                _ => r.batcher.plan_prefill(),
            };
            let prefill_tokens: usize = plan.iter().map(|&(_, t)| t).sum();
            let deciders = match r.role {
                Role::Prefill => 0,
                _ => r.batcher.active.iter().filter(|s| s.is_prefilled() && !s.done()).count(),
            };
            if prefill_tokens == 0 && deciders == 0 {
                return;
            }
            let max_kv = r.batcher.active.iter().map(|s| s.kv_tokens()).max().unwrap_or(1);
            let cost = cm.iteration_cost(prefill_tokens, deciders, max_kv);
            let end = now + cost.latency_ns.max(1.0) as u64;
            st.total_cost = st.total_cost.then(&cost);
            r.batcher.advance_prefill(&plan, end);
            if r.role != Role::Prefill {
                let (n, _) = r.batcher.decode_step(end);
                r.tokens_out += n as u64;
                if n > 0 {
                    r.decode_iters += 1;
                }
            }
            // a prefill-pool replica hands every finished prompt to the
            // decode pool instead of decoding it locally
            let mut handed = Vec::new();
            if r.role == Role::Prefill {
                let mut i = 0;
                while i < r.batcher.active.len() {
                    if r.batcher.active[i].is_prefilled() {
                        handed.push(r.batcher.active.swap_remove(i));
                    } else {
                        i += 1;
                    }
                }
                // deterministic hand-off order regardless of swap_remove
                handed.sort_by_key(|s| s.req.id);
                r.migrations_out += handed.len() as u64;
            }
            r.kv_peak = r.kv_peak.max(r.batcher.kv_in_use());
            r.busy_ns += end - now;
            r.busy_until = end;
            r.iter_pending = true;
            q.schedule_at(end, Event::IterationDone(ri));
            (handed, end)
        };
        for s in handed {
            let dest = pick_replica(
                self.cfg.router,
                s.req.deadline_ns(),
                self.decode_pool(),
                replicas,
                &mut st.rr_migration,
            );
            // KV migration priced on the fabric: every resident KV token
            // crosses once, latency delays the hand-off, bytes hit the
            // energy model through the cxl_bytes count
            let bytes = s.kv_tokens() as u64 * self.rc.model.kv_bytes_per_token();
            let mcost = cxl_p2p(bytes, &self.rc.hw.cxl);
            st.total_cost = st.total_cost.then(&mcost);
            st.migration_cost = st.migration_cost.then(&mcost);
            st.migrations += 1;
            st.migration_bytes += bytes;
            replicas[dest].migrations_in += 1;
            replicas[dest].inflight_kv += s.kv_footprint();
            q.schedule_at(end + mcost.latency_ns.max(1.0) as u64, Event::Migration(dest, s));
        }
    }

    /// Run the cluster simulation to completion. All replicas share one
    /// [`CachedCostModel`] (they cost identical hardware), so an iteration
    /// shape priced on any replica is a cache hit on every other. With
    /// `rc.mapping = auto` the shared model is the shape-adaptive
    /// [`AutoMappedCostModel`] — one placement search per (phase,
    /// shape-class) serves every replica.
    pub fn run(&self) -> ClusterReport {
        match self.rc.mapping {
            MappingMode::Static => {
                let cm = CachedCostModel::new(System::new(self.rc.clone()));
                self.run_with_model(&cm)
            }
            MappingMode::Auto => {
                let cm = AutoMappedCostModel::new(self.rc.clone());
                self.run_with_model(&cm)
            }
        }
    }

    /// Run against an explicit [`CostModel`] over the same `RunConfig`
    /// (benchmarks and golden tests compare cached vs uncached here).
    pub fn run_with_model(&self, cm: &dyn CostModel) -> ClusterReport {
        // a mismatched model would label the report with one config while
        // pricing every iteration on another — catch it early
        debug_assert_eq!(cm.base().arch, self.rc.arch, "cost model arch != cluster arch");
        debug_assert_eq!(cm.base().model.name, self.rc.model.name, "cost model != cluster model");
        debug_assert_eq!(cm.base().tp, self.rc.tp, "cost model tp != cluster tp");
        debug_assert_eq!(
            cm.base().devices,
            self.rc.devices,
            "cost model devices != cluster devices"
        );
        debug_assert_eq!(
            cm.base().noc_fidelity,
            self.rc.noc_fidelity,
            "cost model NoC fidelity != cluster fidelity"
        );
        self.cfg.validate().expect("invalid cluster config");
        let n_replicas = self.cfg.replica_count();
        let class_names = self.serve.class_names();
        let mut rejected_by_class = vec![0u64; class_names.len()];

        let mut replicas: Vec<Replica> = (0..n_replicas)
            .map(|i| {
                let role = match self.cfg.disagg {
                    None => Role::Colocated,
                    Some((p, _)) if i < p => Role::Prefill,
                    Some(_) => Role::Decode,
                };
                let mut bcfg = self.serve.batcher.clone();
                // generation KV never materializes on a prefill-pool
                // replica (requests hand off at prefill completion), so
                // reserving it would only throttle prefill concurrency
                if role == Role::Prefill {
                    bcfg.reserve_gen = false;
                }
                Replica::new(role, Batcher::new(bcfg))
            })
            .collect();

        let mut q: EventQueue<Event> = EventQueue::new();
        for r in self.serve.requests() {
            q.schedule_at(r.arrived_ns, Event::Arrival(r));
        }
        let mut st = ClusterState {
            total_cost: OpCost::zero(),
            migration_cost: OpCost::zero(),
            migrations: 0,
            migration_bytes: 0,
            rr_arrival: 0,
            rr_migration: 0,
        };

        while let Some((now, ev)) = q.pop() {
            match ev {
                Event::Arrival(r) => {
                    let ri = pick_replica(
                        self.cfg.router,
                        r.deadline_ns(),
                        self.arrival_pool(),
                        &replicas,
                        &mut st.rr_arrival,
                    );
                    replicas[ri].routed += 1;
                    let class = r.class.min(class_names.len().saturating_sub(1));
                    // a prefill-pool batcher reserves the prompt only, so
                    // screen the full footprint against the decode budget
                    // here — otherwise an oversized request would prefill,
                    // migrate, and strand unadmittable in a landing queue
                    let fits_decode = self.cfg.disagg.is_none()
                        || r.prompt_len + r.gen_len <= self.serve.batcher.max_kv_tokens;
                    if !fits_decode {
                        replicas[ri].batcher.rejected += 1;
                        rejected_by_class[class] += 1;
                    } else if !replicas[ri].batcher.offer(r) {
                        rejected_by_class[class] += 1;
                    }
                    if now >= replicas[ri].busy_until {
                        self.step_replica(cm, ri, now, &mut replicas, &mut q, &mut st);
                    }
                }
                Event::IterationDone(ri) => {
                    replicas[ri].iter_pending = false;
                    self.step_replica(cm, ri, now, &mut replicas, &mut q, &mut st);
                }
                Event::Migration(ri, s) => {
                    replicas[ri].inflight_kv =
                        replicas[ri].inflight_kv.saturating_sub(s.kv_footprint());
                    replicas[ri].landing.push(s);
                    if now >= replicas[ri].busy_until {
                        self.step_replica(cm, ri, now, &mut replicas, &mut q, &mut st);
                    }
                }
            }
        }

        // ---- assemble the cluster report ----
        let makespan = replicas.iter().map(|r| r.busy_until).max().unwrap_or(0).max(1);
        let mut stranded_by_class = vec![0u64; class_names.len()];
        let mut completed: Vec<(RequestState, u64)> = Vec::new();
        let mut per_replica = Vec::with_capacity(n_replicas);
        let mut rejected = 0u64;
        let mut preempted = 0u64;
        let mut unserved = 0usize;
        let mut tokens_out = 0u64;
        let mut decode_iters = 0u64;
        for (i, r) in replicas.iter_mut().enumerate() {
            per_replica.push(ReplicaReport {
                id: i,
                role: r.role.label(),
                routed: r.routed,
                completed: r.batcher.completed.len(),
                tokens_out: r.tokens_out,
                migrations_out: r.migrations_out,
                migrations_in: r.migrations_in,
                busy_ns: r.busy_ns,
                utilization: r.busy_ns as f64 / makespan as f64,
                kv_peak: r.kv_peak,
            });
            let clamp = class_names.len().saturating_sub(1);
            for ci in r.batcher.unserved_classes() {
                stranded_by_class[ci.min(clamp)] += 1;
            }
            for s in &r.landing {
                stranded_by_class[s.req.class.min(clamp)] += 1;
            }
            rejected += r.batcher.rejected;
            preempted += r.batcher.preempted;
            unserved += r.batcher.queued() + r.batcher.active.len() + r.landing.len();
            tokens_out += r.tokens_out;
            decode_iters += r.decode_iters;
            completed.append(&mut r.batcher.completed);
        }

        let report = build_report(
            &self.rc,
            n_replicas,
            &class_names,
            &completed,
            &rejected_by_class,
            &stranded_by_class,
            RunTotals {
                makespan_ns: makespan,
                tokens_out,
                decode_iters,
                cost: st.total_cost,
                rejected,
                preempted,
                unserved,
            },
        );
        let em = crate::energy::EnergyModel::new(&self.rc.hw.sram, self.rc.hw.hb.pj_per_bit);
        ClusterReport {
            replicas: n_replicas,
            router: self.cfg.router.label(),
            disagg: self.cfg.disagg,
            migrations: st.migrations,
            migration_bytes: st.migration_bytes,
            migration_energy_pj: em.dynamic(&st.migration_cost.counts).total_pj(),
            per_replica,
            report,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchKind, ModelConfig};

    fn rc() -> RunConfig {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        rc
    }

    fn run_cluster(
        scenario: &str,
        n: usize,
        seed: u64,
        cfg: ClusterConfig,
    ) -> ClusterReport {
        let serve = ServeConfig {
            n_requests: n,
            seed,
            scenario: Some(Scenario::by_name(scenario).unwrap()),
            ..Default::default()
        };
        Cluster::new(rc(), serve, cfg).run()
    }

    #[test]
    fn colocated_cluster_serves_everything() {
        let r = run_cluster("mixed", 16, 42, ClusterConfig {
            replicas: 2,
            ..Default::default()
        });
        assert_eq!(r.report.completed, 16);
        assert_eq!(r.report.unserved, 0);
        assert_eq!(r.per_replica.len(), 2);
        assert_eq!(r.migrations, 0, "colocated mode never migrates");
        let routed: u64 = r.per_replica.iter().map(|p| p.routed).sum();
        assert_eq!(routed, 16);
        let done: usize = r.per_replica.iter().map(|p| p.completed).sum();
        assert_eq!(done, 16);
        assert!(r.report.tokens_out > 0);
        for p in &r.per_replica {
            assert_eq!(p.role, "mixed");
            assert!((0.0..=1.0).contains(&p.utilization));
        }
    }

    #[test]
    fn every_scenario_serves_on_the_cluster() {
        for sc in Scenario::all() {
            let n = 6.min(sc.default_requests);
            for cfg in [
                ClusterConfig { replicas: 2, ..Default::default() },
                ClusterConfig { disagg: Some((1, 1)), ..Default::default() },
            ] {
                let mode = cfg.disagg.is_some();
                let r = run_cluster(sc.name, n, 42, cfg);
                assert_eq!(
                    r.report.completed, n,
                    "{} (disagg={mode}) lost requests", sc.name
                );
                assert_eq!(r.report.unserved, 0, "{} stranded requests", sc.name);
                assert!(r.report.tokens_out > 0);
                assert!(r.report.energy_per_token_pj > 0.0);
                // the shared audit validator replaces the old per-class
                // finiteness asserts (same predicate `compair audit` runs)
                let rep = crate::analysis::audit::check_serve_report(
                    &format!("{} disagg={mode}", sc.name),
                    &r.report,
                );
                assert!(rep.is_clean(), "{}", rep.render_brief());
            }
        }
    }

    #[test]
    fn router_policies_are_deterministic() {
        for policy in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoadedKv,
            RouterPolicy::DeadlineAware,
        ] {
            let cfg = ClusterConfig { replicas: 3, router: policy, ..Default::default() };
            let a = run_cluster("mixed", 24, 7, cfg.clone());
            let b = run_cluster("mixed", 24, 7, cfg);
            let routed_a: Vec<u64> = a.per_replica.iter().map(|p| p.routed).collect();
            let routed_b: Vec<u64> = b.per_replica.iter().map(|p| p.routed).collect();
            assert_eq!(routed_a, routed_b, "{policy:?} assignment not deterministic");
            assert_eq!(a.report.makespan_ns, b.report.makespan_ns);
            assert_eq!(a.report.tokens_out, b.report.tokens_out);
        }
    }

    #[test]
    fn round_robin_spreads_arrivals_evenly() {
        let r = run_cluster("batch", 16, 42, ClusterConfig {
            replicas: 4,
            router: RouterPolicy::RoundRobin,
            ..Default::default()
        });
        for p in &r.per_replica {
            assert_eq!(p.routed, 4, "round-robin must deal 16 arrivals 4-way");
        }
    }

    #[test]
    fn disaggregation_conserves_requests_and_tokens() {
        let n = 16;
        let cfg = ClusterConfig { disagg: Some((2, 2)), router: RouterPolicy::LeastLoadedKv, ..Default::default() };
        let r = run_cluster("mixed", n, 42, cfg);
        assert_eq!(r.report.completed, n, "all requests must complete");
        assert_eq!(r.report.unserved, 0);
        assert_eq!(r.report.rejected, 0);
        // every request prefills once and migrates exactly once
        assert_eq!(r.migrations, n as u64);
        assert!(r.migration_bytes > 0);
        assert!(r.migration_energy_pj > 0.0, "migration energy must be billed");
        assert!(
            r.report.energy.cxl_pj >= r.migration_energy_pj,
            "migration energy is part of the fabric total"
        );
        // prefill pool never decodes; decode pool emits every token
        for p in &r.per_replica {
            match p.role {
                "prefill" => {
                    assert_eq!(p.tokens_out, 0, "prefill replica {} decoded", p.id);
                    assert_eq!(p.completed, 0, "prefill replica {} completed", p.id);
                    assert_eq!(p.migrations_in, 0);
                }
                "decode" => assert_eq!(p.migrations_out, 0),
                other => panic!("unexpected role {other}"),
            }
        }
        let decode_tokens: u64 = r
            .per_replica
            .iter()
            .filter(|p| p.role == "decode")
            .map(|p| p.tokens_out)
            .sum();
        assert_eq!(decode_tokens, r.report.tokens_out);
        let migrated_in: u64 =
            r.per_replica.iter().map(|p| p.migrations_in).sum();
        assert_eq!(migrated_in, n as u64, "every migration lands exactly once");
        // gen-token conservation against the reproducible trace
        let trace = ServeConfig {
            n_requests: n,
            seed: 42,
            scenario: Some(Scenario::by_name("mixed").unwrap()),
            ..Default::default()
        }
        .requests();
        let want_tokens: u64 = trace.iter().map(|t| t.gen_len as u64).sum();
        assert_eq!(r.report.tokens_out, want_tokens);
        // migration traffic = sum of prompt KV priced per token
        let kv = ModelConfig::llama2_7b().kv_bytes_per_token();
        let want_bytes: u64 = trace.iter().map(|t| t.prompt_len as u64 * kv).sum();
        assert_eq!(r.migration_bytes, want_bytes);
    }

    #[test]
    fn cluster_reports_are_bit_reproducible() {
        let cfg = ClusterConfig {
            disagg: Some((1, 1)),
            router: RouterPolicy::DeadlineAware,
            ..Default::default()
        };
        let a = run_cluster("mixed", 12, 9, cfg.clone());
        let b = run_cluster("mixed", 12, 9, cfg.clone());
        assert_eq!(a.report.makespan_ns, b.report.makespan_ns);
        assert_eq!(a.report.tokens_out, b.report.tokens_out);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.migration_bytes, b.migration_bytes);
        assert!((a.report.energy.total_pj() - b.report.energy.total_pj()).abs() < 1e-9);
        for (x, y) in a.per_replica.iter().zip(&b.per_replica) {
            assert_eq!(x.routed, y.routed);
            assert_eq!(x.completed, y.completed);
            assert_eq!(x.busy_ns, y.busy_ns);
        }
        for (x, y) in a.report.per_class.iter().zip(&b.report.per_class) {
            assert_eq!(x.completed, y.completed);
            assert!((x.slo_attainment - y.slo_attainment).abs() < 1e-12);
        }
        let c = run_cluster("mixed", 12, 10, cfg);
        assert_ne!(a.report.makespan_ns, c.report.makespan_ns, "seed must matter");
    }

    #[test]
    fn shared_cached_model_matches_uncached_bit_for_bit() {
        let serve = ServeConfig {
            n_requests: 12,
            seed: 42,
            scenario: Some(Scenario::by_name("mixed").unwrap()),
            ..Default::default()
        };
        let cfg = ClusterConfig { disagg: Some((1, 1)), ..Default::default() };
        let cluster = Cluster::new(rc(), serve, cfg);
        let uncached = cluster.run_with_model(&System::new(rc()));
        let cached = cluster.run();
        assert_eq!(uncached.report.makespan_ns, cached.report.makespan_ns);
        assert_eq!(uncached.report.tokens_out, cached.report.tokens_out);
        assert_eq!(uncached.migrations, cached.migrations);
        assert_eq!(uncached.migration_bytes, cached.migration_bytes);
        assert_eq!(
            uncached.report.energy.total_pj().to_bits(),
            cached.report.energy.total_pj().to_bits()
        );
        for (a, b) in uncached.per_replica.iter().zip(&cached.per_replica) {
            assert_eq!(a.busy_ns, b.busy_ns);
            assert_eq!(a.tokens_out, b.tokens_out);
        }
    }

    #[test]
    fn more_replicas_cut_offline_makespan() {
        let one = run_cluster("batch", 16, 42, ClusterConfig {
            replicas: 1,
            ..Default::default()
        });
        let four = run_cluster("batch", 16, 42, ClusterConfig {
            replicas: 4,
            ..Default::default()
        });
        assert_eq!(one.report.completed, 16);
        assert_eq!(four.report.completed, 16);
        assert!(
            four.report.makespan_ns < one.report.makespan_ns,
            "4 replicas {} must beat 1 replica {}",
            four.report.makespan_ns,
            one.report.makespan_ns
        );
    }

    #[test]
    fn config_validation_rejects_empty_pools() {
        assert!(ClusterConfig { disagg: Some((0, 2)), ..Default::default() }
            .validate()
            .is_err());
        assert!(ClusterConfig { disagg: Some((2, 0)), ..Default::default() }
            .validate()
            .is_err());
        assert!(ClusterConfig { replicas: 0, disagg: None, router: RouterPolicy::RoundRobin }
            .validate()
            .is_err());
        assert!(ClusterConfig::default().validate().is_ok());
        assert_eq!(
            ClusterConfig { disagg: Some((3, 5)), ..Default::default() }.replica_count(),
            8
        );
    }

    #[test]
    fn router_names_roundtrip() {
        for p in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoadedKv,
            RouterPolicy::DeadlineAware,
        ] {
            assert_eq!(RouterPolicy::by_name(p.label()), Some(p));
        }
        assert!(RouterPolicy::by_name("nope").is_none());
    }

    #[test]
    fn scenario_wrapper_labels_the_run() {
        let sr = run_cluster_scenario(
            rc(),
            Scenario::by_name("chat").unwrap(),
            4,
            42,
            ClusterConfig::default(),
        );
        assert_eq!(sr.scenario, "chat");
        assert_eq!(sr.arch, "CompAir_Opt");
        assert_eq!(sr.model, "llama2-7b");
        assert_eq!(sr.cluster.report.completed, 4);
        let s = render_cluster_summary(&sr.cluster);
        assert!(s.contains("replicas 2"));
        assert!(s.contains("router round-robin"));
    }
}
