//! The serving loop: drives the continuous batcher over simulated time,
//! costing every prefill/decode iteration with the architecture simulator.
//! This is the paper's system running as a service: arrivals, batching,
//! per-token latencies, energy per token.

use crate::arch::System;
use crate::config::{Phase, RunConfig};
use crate::energy::EnergyBreakdown;
use crate::sim::{EventQueue, OpCost};
use crate::util::stats::percentile;
use crate::util::XorShiftRng;

use super::batcher::{Batcher, BatcherConfig, Request};

/// Serving workload + policy configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub batcher: BatcherConfig,
    /// Mean arrival rate (requests/s).
    pub arrival_rate: f64,
    pub n_requests: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            arrival_rate: 16.0,
            n_requests: 64,
            prompt_len: 512,
            gen_len: 32,
            seed: 42,
        }
    }
}

/// Serving results.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completed: usize,
    pub rejected: u64,
    pub makespan_ns: u64,
    pub throughput_tok_s: f64,
    pub ttft_p50_ns: f64,
    pub ttft_p99_ns: f64,
    pub req_latency_p50_ns: f64,
    pub req_latency_p99_ns: f64,
    pub energy: EnergyBreakdown,
    pub decode_iters: u64,
}

enum Event {
    Arrival(Request),
    IterationDone,
}

/// The server: owns the batcher and the hardware simulator.
pub struct Server {
    rc: RunConfig,
    cfg: ServeConfig,
}

impl Server {
    pub fn new(rc: RunConfig, cfg: ServeConfig) -> Self {
        Self { rc, cfg }
    }

    fn iteration_cost(&self, prefill_tokens: usize, decode_batch: usize, max_kv: usize) -> OpCost {
        let mut cost = OpCost::zero();
        if prefill_tokens > 0 {
            let mut rc = self.rc.clone();
            rc.phase = Phase::Prefill;
            rc.batch = 1;
            rc.seq_len = prefill_tokens;
            cost = cost.then(&System::new(rc).run().layer_cost_total());
        }
        if decode_batch > 0 {
            let mut rc = self.rc.clone();
            rc.phase = Phase::Decode;
            rc.batch = decode_batch;
            rc.seq_len = max_kv.max(1);
            cost = cost.then(&System::new(rc).run().layer_cost_total());
        }
        cost
    }

    /// Run the serving simulation to completion.
    pub fn run(&self) -> ServeReport {
        let mut q: EventQueue<Event> = EventQueue::new();
        let mut rng = XorShiftRng::new(self.cfg.seed);
        // schedule all arrivals
        let mut t = 0.0f64;
        for id in 0..self.cfg.n_requests {
            t += rng.next_exp(self.cfg.arrival_rate) * 1e9;
            q.schedule_at(
                t as u64,
                Event::Arrival(Request {
                    id: id as u64,
                    prompt_len: self.cfg.prompt_len,
                    gen_len: self.cfg.gen_len,
                    arrived_ns: t as u64,
                }),
            );
        }

        let mut batcher = Batcher::new(self.cfg.batcher.clone());
        let mut busy_until = 0u64;
        let mut iter_pending = false;
        let mut total_cost = OpCost::zero();
        let mut decode_iters = 0u64;
        let mut tokens_out = 0u64;

        let kick = |batcher: &mut Batcher,
                        q: &mut EventQueue<Event>,
                        now: u64,
                        busy_until: &mut u64,
                        iter_pending: &mut bool,
                        total_cost: &mut OpCost,
                        decode_iters: &mut u64,
                        tokens_out: &mut u64,
                        sys: &Server| {
            if *iter_pending || batcher.idle() {
                return;
            }
            batcher.admit(now);
            if batcher.active.is_empty() {
                return;
            }
            // plan this iteration: prefill the newly admitted, decode the rest
            let pre = batcher.prefill_set();
            let prefill_tokens: usize =
                pre.iter().map(|&i| batcher.active[i].req.prompt_len).sum();
            let deciders =
                batcher.active.iter().filter(|s| s.prefilled && !s.done()).count();
            let max_kv = batcher
                .active
                .iter()
                .map(|s| s.kv_tokens())
                .max()
                .unwrap_or(1);
            let cost = sys.iteration_cost(prefill_tokens, deciders, max_kv);
            let end = now + cost.latency_ns.max(1.0) as u64;
            *total_cost = total_cost.then(&cost);
            batcher.finish_prefill(&pre, end);
            let (n, _) = batcher.decode_step(end);
            *tokens_out += n as u64;
            if n > 0 {
                *decode_iters += 1;
            }
            *busy_until = end;
            *iter_pending = true;
            q.schedule_at(end, Event::IterationDone);
        };

        while let Some((now, ev)) = q.pop() {
            match ev {
                Event::Arrival(r) => {
                    batcher.offer(r);
                    if now >= busy_until {
                        kick(
                            &mut batcher,
                            &mut q,
                            now,
                            &mut busy_until,
                            &mut iter_pending,
                            &mut total_cost,
                            &mut decode_iters,
                            &mut tokens_out,
                            self,
                        );
                    }
                }
                Event::IterationDone => {
                    iter_pending = false;
                    kick(
                        &mut batcher,
                        &mut q,
                        now,
                        &mut busy_until,
                        &mut iter_pending,
                        &mut total_cost,
                        &mut decode_iters,
                        &mut tokens_out,
                        self,
                    );
                }
            }
        }

        let makespan = busy_until.max(1);
        let ttfts: Vec<f64> = batcher
            .completed
            .iter()
            .filter_map(|(s, _)| s.first_token_ns.map(|t| (t - s.req.arrived_ns) as f64))
            .collect();
        let lats: Vec<f64> = batcher
            .completed
            .iter()
            .map(|(s, t)| (*t - s.req.arrived_ns) as f64)
            .collect();
        let em = crate::energy::EnergyModel::new(&self.rc.hw.sram, self.rc.hw.hb.pj_per_bit);
        let mut energy = em.dynamic(&total_cost.counts);
        energy.static_pj =
            self.rc.devices as f64 * em.pim_device_static_w * makespan as f64;

        ServeReport {
            completed: batcher.completed.len(),
            rejected: batcher.rejected,
            makespan_ns: makespan,
            throughput_tok_s: tokens_out as f64 / (makespan as f64 / 1e9),
            ttft_p50_ns: percentile(&ttfts, 50.0),
            ttft_p99_ns: percentile(&ttfts, 99.0),
            req_latency_p50_ns: percentile(&lats, 50.0),
            req_latency_p99_ns: percentile(&lats, 99.0),
            energy,
            decode_iters,
        }
    }
}

impl crate::arch::PhaseReport {
    /// Whole-pass cost (all layers) reconstructed from the report.
    pub fn layer_cost_total(&self) -> OpCost {
        OpCost { latency_ns: self.latency_ns, counts: self.layer_cost.counts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchKind, ModelConfig};

    fn serve(arch: ArchKind, rate: f64) -> ServeReport {
        let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        let cfg = ServeConfig {
            arrival_rate: rate,
            n_requests: 24,
            prompt_len: 128,
            gen_len: 8,
            ..Default::default()
        };
        Server::new(rc, cfg).run()
    }

    #[test]
    fn all_requests_complete() {
        let r = serve(ArchKind::CompAirOpt, 50.0);
        assert_eq!(r.completed, 24);
        assert_eq!(r.rejected, 0);
        assert!(r.throughput_tok_s > 0.0);
        assert!(r.ttft_p99_ns >= r.ttft_p50_ns);
    }

    #[test]
    fn compair_serves_faster_than_cent() {
        let a = serve(ArchKind::CompAirOpt, 1e6);
        let b = serve(ArchKind::Cent, 1e6);
        assert!(
            a.makespan_ns < b.makespan_ns,
            "CompAir {} vs CENT {}",
            a.makespan_ns,
            b.makespan_ns
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = serve(ArchKind::CompAirOpt, 20.0);
        let b = serve(ArchKind::CompAirOpt, 20.0);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.completed, b.completed);
    }

    #[test]
    fn saturation_increases_latency_not_loss() {
        let slow = serve(ArchKind::CompAirOpt, 2.0);
        let fast = serve(ArchKind::CompAirOpt, 1e7);
        assert_eq!(slow.completed, fast.completed);
        // under saturation, queueing delay shows in p99 request latency
        assert!(fast.req_latency_p99_ns >= slow.req_latency_p50_ns);
    }
}
