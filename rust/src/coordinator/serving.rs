//! The serving loop: drives the continuous batcher over simulated time,
//! costing every prefill/decode iteration with the architecture simulator.
//! This is the paper's system running as a service: arrivals, batching,
//! chunked prefill, SLO tracking, per-token latencies, energy per token.
//!
//! Workloads come in two shapes: the homogeneous Poisson stream the
//! original harness used (`prompt_len`/`gen_len`/`arrival_rate`), or a
//! named [`Scenario`] from [`crate::workload::traces`] — a heterogeneous
//! request mix with per-class SLOs. Either way a seeded run is
//! bit-reproducible.

use crate::arch::{CachedCostModel, CostModel, System};
use crate::config::{MappingMode, RunConfig};
use crate::energy::EnergyBreakdown;
use crate::mapper::AutoMappedCostModel;
use crate::sim::{EventQueue, OpCost};
use crate::util::json::{Json, ToJson};
use crate::util::stats::percentile;
use crate::util::table::{fenergy_pj, ftime_ns, Table};
use crate::util::XorShiftRng;
use crate::workload::Scenario;

use super::batcher::{Batcher, BatcherConfig, Request, RequestState};

/// Serving workload + policy configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Batching/admission policy knobs.
    pub batcher: BatcherConfig,
    /// Mean arrival rate (requests/s) for the homogeneous workload.
    pub arrival_rate: f64,
    /// Number of requests to serve.
    pub n_requests: usize,
    /// Homogeneous prompt length (ignored when `scenario` is set).
    pub prompt_len: usize,
    /// Homogeneous generation length (ignored when `scenario` is set).
    pub gen_len: usize,
    /// Trace RNG seed; identical seeds give bit-identical runs.
    pub seed: u64,
    /// Heterogeneous named workload; `None` falls back to the homogeneous
    /// Poisson stream described by the fields above.
    pub scenario: Option<Scenario>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            arrival_rate: 16.0,
            n_requests: 64,
            prompt_len: 512,
            gen_len: 32,
            seed: 42,
            scenario: None,
        }
    }
}

impl ServeConfig {
    /// Expand the configured workload into a concrete arrival trace
    /// (bit-reproducible per seed). Shared by the single-replica server
    /// and the cluster coordinator.
    pub fn requests(&self) -> Vec<Request> {
        match &self.scenario {
            Some(sc) => sc.generate(self.seed, self.n_requests),
            None => {
                let mut rng = XorShiftRng::new(self.seed);
                let mut t = 0.0f64;
                (0..self.n_requests)
                    .map(|id| {
                        t += rng.next_exp(self.arrival_rate) * 1e9;
                        Request::new(id as u64, self.prompt_len, self.gen_len.max(1), t as u64)
                    })
                    .collect()
            }
        }
    }

    /// Report class labels, in request-class index order.
    pub fn class_names(&self) -> Vec<String> {
        match &self.scenario {
            Some(sc) => sc.class_names().iter().map(|s| s.to_string()).collect(),
            None => vec!["all".to_string()],
        }
    }
}

/// Per-request-class serving outcomes (one row of the SLO report).
#[derive(Debug, Clone)]
pub struct ClassReport {
    /// Class label (scenario class name, or "all" for homogeneous runs).
    pub class: String,
    /// Requests of this class that finished.
    pub completed: usize,
    /// Requests of this class dropped by queue backpressure.
    pub rejected: u64,
    /// Median time-to-first-token (ns).
    pub ttft_p50_ns: f64,
    /// 99th-percentile time-to-first-token (ns).
    pub ttft_p99_ns: f64,
    /// Median per-output-token latency (ns).
    pub tpot_p50_ns: f64,
    /// 99th-percentile per-output-token latency (ns).
    pub tpot_p99_ns: f64,
    /// Fraction of served requests meeting their TTFT target.
    pub ttft_attainment: f64,
    /// Fraction of served requests meeting their TPOT target.
    pub tpot_attainment: f64,
    /// Fraction meeting both targets (rejects count as misses).
    pub slo_attainment: f64,
}

/// Serving results. Latency percentiles are over completed requests;
/// attainment fractions count rejected/unserved requests as SLO misses.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Requests that ran to completion.
    pub completed: usize,
    /// Arrivals dropped by admission-queue backpressure.
    pub rejected: u64,
    /// SLO-priority evictions performed (preempted work is recomputed).
    pub preempted: u64,
    /// Requests stranded in the queue at shutdown (0 in healthy runs).
    pub unserved: usize,
    /// Simulated wall-clock of the whole run (ns).
    pub makespan_ns: u64,
    /// Decode tokens emitted over the run.
    pub tokens_out: u64,
    /// Aggregate decode throughput over the makespan (tokens/s).
    pub throughput_tok_s: f64,
    /// Median time-to-first-token (ns).
    pub ttft_p50_ns: f64,
    /// 99th-percentile time-to-first-token (ns).
    pub ttft_p99_ns: f64,
    /// Median per-output-token decode latency (ns).
    pub tpot_p50_ns: f64,
    /// 99th-percentile per-output-token decode latency (ns).
    pub tpot_p99_ns: f64,
    /// Median request latency, arrival → last token (ns).
    pub req_latency_p50_ns: f64,
    /// 99th-percentile request latency (ns).
    pub req_latency_p99_ns: f64,
    /// Fraction of requests meeting both TTFT and TPOT targets.
    pub slo_attainment: f64,
    /// Total energy (dynamic + static) over the run.
    pub energy: EnergyBreakdown,
    /// Energy per emitted decode token (pJ).
    pub energy_per_token_pj: f64,
    /// Iterations that produced at least one decode token.
    pub decode_iters: u64,
    /// One row per request class, in scenario class order.
    pub per_class: Vec<ClassReport>,
}

impl ServeReport {
    /// Render the per-class SLO table (used by the CLI and the figures).
    pub fn class_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["class", "done", "rej", "ttft p50", "ttft p99", "tpot p50", "tpot p99", "slo%"],
        );
        for c in &self.per_class {
            t.rowv(vec![
                c.class.clone(),
                c.completed.to_string(),
                c.rejected.to_string(),
                ftime_ns(c.ttft_p50_ns),
                ftime_ns(c.ttft_p99_ns),
                ftime_ns(c.tpot_p50_ns),
                ftime_ns(c.tpot_p99_ns),
                format!("{:.1}%", c.slo_attainment * 100.0),
            ]);
        }
        t
    }
}

/// A named scenario's serving outcome on one architecture.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    /// Scenario registry name.
    pub scenario: String,
    /// Architecture label the run was costed on.
    pub arch: String,
    /// Model name served.
    pub model: String,
    /// The full serving report (totals + per-class rows).
    pub report: ServeReport,
}

/// Run a named scenario end to end on the given hardware configuration.
pub fn run_scenario(rc: RunConfig, scenario: Scenario, n_requests: usize, seed: u64) -> ScenarioReport {
    let name = scenario.name.to_string();
    let arch = rc.arch.label().to_string();
    let model = rc.model.name.to_string();
    let cfg = ServeConfig {
        n_requests,
        seed,
        scenario: Some(scenario),
        ..Default::default()
    };
    let report = Server::new(rc, cfg).run();
    ScenarioReport { scenario: name, arch, model, report }
}

enum Event {
    Arrival(Request),
    IterationDone,
}

/// Mutable loop state threaded through iterations.
struct LoopState {
    busy_until: u64,
    iter_pending: bool,
    total_cost: OpCost,
    decode_iters: u64,
    tokens_out: u64,
}

/// Aggregate loop counters a serving run hands to [`build_report`].
pub(crate) struct RunTotals {
    pub makespan_ns: u64,
    pub tokens_out: u64,
    pub decode_iters: u64,
    pub cost: OpCost,
    pub rejected: u64,
    pub preempted: u64,
    pub unserved: usize,
}

/// Assemble a [`ServeReport`] from completed requests and loop totals.
/// `device_groups` scales static power (a cluster burns `replicas ×
/// rc.devices` devices for the whole makespan). Attainment denominators
/// are guarded (`max(1)`) so classes with zero served requests report 0,
/// never NaN.
pub(crate) fn build_report(
    rc: &RunConfig,
    device_groups: usize,
    class_names: &[String],
    completed: &[(RequestState, u64)],
    rejected_by_class: &[u64],
    stranded_by_class: &[u64],
    totals: RunTotals,
) -> ServeReport {
    let makespan = totals.makespan_ns.max(1);
    let em = crate::energy::EnergyModel::new(&rc.hw.sram, rc.hw.hb.pj_per_bit);
    let mut energy = em.dynamic(&totals.cost.counts);
    energy.static_pj =
        (device_groups * rc.devices) as f64 * em.pim_device_static_w * makespan as f64;

    let pctl = |v: &[f64], p: f64| if v.is_empty() { 0.0 } else { percentile(v, p) };
    let mut per_class = Vec::with_capacity(class_names.len());
    for (ci, name) in class_names.iter().enumerate() {
        let done: Vec<_> = completed.iter().filter(|(s, _)| s.req.class == ci).collect();
        let ttfts: Vec<f64> =
            done.iter().filter_map(|(s, _)| s.ttft_ns().map(|t| t as f64)).collect();
        let tpots: Vec<f64> = done.iter().map(|(s, t)| s.tpot_ns(*t)).collect();
        let ttft_met = done
            .iter()
            .filter(|(s, _)| s.ttft_ns().map_or(false, |t| t <= s.req.slo.ttft_ns))
            .count();
        let tpot_met =
            done.iter().filter(|(s, t)| s.tpot_ns(*t) <= s.req.slo.tpot_ns as f64).count();
        let both_met = done
            .iter()
            .filter(|(s, t)| s.ttft_ns().map_or(false, |tt| s.req.slo.met(tt, s.tpot_ns(*t))))
            .count();
        // guard the denominator: a class with zero served requests must
        // report 0.0 attainment, not NaN (regression: zero-weight classes
        // and one-request traces put NaN in the scenario tables)
        let served = done.len().max(1);
        let offered = done.len() as u64 + rejected_by_class[ci] + stranded_by_class[ci];
        per_class.push(ClassReport {
            class: name.clone(),
            completed: done.len(),
            rejected: rejected_by_class[ci],
            ttft_p50_ns: pctl(&ttfts, 50.0),
            ttft_p99_ns: pctl(&ttfts, 99.0),
            tpot_p50_ns: pctl(&tpots, 50.0),
            tpot_p99_ns: pctl(&tpots, 99.0),
            ttft_attainment: ttft_met as f64 / served as f64,
            tpot_attainment: tpot_met as f64 / served as f64,
            slo_attainment: both_met as f64 / offered.max(1) as f64,
        });
    }

    let ttfts: Vec<f64> =
        completed.iter().filter_map(|(s, _)| s.ttft_ns().map(|t| t as f64)).collect();
    let tpots: Vec<f64> = completed.iter().map(|(s, t)| s.tpot_ns(*t)).collect();
    let lats: Vec<f64> =
        completed.iter().map(|(s, t)| t.saturating_sub(s.req.arrived_ns) as f64).collect();
    let met = completed
        .iter()
        .filter(|(s, t)| s.ttft_ns().map_or(false, |tt| s.req.slo.met(tt, s.tpot_ns(*t))))
        .count();
    let offered_total = completed.len() as u64 + totals.rejected + totals.unserved as u64;

    ServeReport {
        completed: completed.len(),
        rejected: totals.rejected,
        preempted: totals.preempted,
        unserved: totals.unserved,
        makespan_ns: makespan,
        tokens_out: totals.tokens_out,
        throughput_tok_s: totals.tokens_out as f64 / (makespan as f64 / 1e9),
        ttft_p50_ns: pctl(&ttfts, 50.0),
        ttft_p99_ns: pctl(&ttfts, 99.0),
        tpot_p50_ns: pctl(&tpots, 50.0),
        tpot_p99_ns: pctl(&tpots, 99.0),
        req_latency_p50_ns: pctl(&lats, 50.0),
        req_latency_p99_ns: pctl(&lats, 99.0),
        slo_attainment: met as f64 / offered_total.max(1) as f64,
        energy_per_token_pj: energy.total_pj() / totals.tokens_out.max(1) as f64,
        energy,
        decode_iters: totals.decode_iters,
        per_class,
    }
}

/// The server: owns the batcher and the hardware simulator.
pub struct Server {
    rc: RunConfig,
    cfg: ServeConfig,
}

impl Server {
    pub fn new(rc: RunConfig, cfg: ServeConfig) -> Self {
        Self { rc, cfg }
    }

    /// Plan and cost one batching iteration; schedules its completion.
    fn step(
        &self,
        cm: &dyn CostModel,
        batcher: &mut Batcher,
        q: &mut EventQueue<Event>,
        now: u64,
        st: &mut LoopState,
    ) {
        if st.iter_pending || batcher.idle() {
            return;
        }
        batcher.preempt_for_urgent(now);
        batcher.admit(now);
        if batcher.active.is_empty() {
            return;
        }
        // plan this iteration: a chunk of pending prefills interleaved with
        // one decode step over everything already prefilled
        let plan = batcher.plan_prefill();
        let prefill_tokens: usize = plan.iter().map(|&(_, t)| t).sum();
        let deciders = batcher.active.iter().filter(|s| s.is_prefilled() && !s.done()).count();
        if prefill_tokens == 0 && deciders == 0 {
            return; // nothing schedulable this instant
        }
        let max_kv = batcher.active.iter().map(|s| s.kv_tokens()).max().unwrap_or(1);
        let cost = cm.iteration_cost(prefill_tokens, deciders, max_kv);
        let end = now + cost.latency_ns.max(1.0) as u64;
        st.total_cost = st.total_cost.then(&cost);
        batcher.advance_prefill(&plan, end);
        let (n, _) = batcher.decode_step(end);
        st.tokens_out += n as u64;
        if n > 0 {
            st.decode_iters += 1;
        }
        st.busy_until = end;
        st.iter_pending = true;
        q.schedule_at(end, Event::IterationDone);
    }

    /// Run the serving simulation to completion. The loop drives a
    /// [`CachedCostModel`], so every repeated iteration shape — chunked
    /// prefill re-prices the same `(Prefill, 1, chunk)` pass on each
    /// iteration of a long prompt — becomes a table lookup instead of an
    /// op-graph lowering. With `rc.mapping = auto` the model is the
    /// shape-adaptive [`AutoMappedCostModel`]: prefill and decode classes
    /// search their own operator placements (once per class), and every
    /// iteration is floored at the static cost, so a run can only get
    /// faster — never slower — than `mapping = static`.
    pub fn run(&self) -> ServeReport {
        match self.rc.mapping {
            MappingMode::Static => {
                let cm = CachedCostModel::new(System::new(self.rc.clone()));
                self.run_with_model(&cm)
            }
            MappingMode::Auto => {
                let cm = AutoMappedCostModel::new(self.rc.clone());
                self.run_with_model(&cm)
            }
        }
    }

    /// Run the loop against an explicit [`CostModel`] over the same
    /// `RunConfig` — benchmarks compare cached vs uncached here, and the
    /// golden tests assert the two are bit-identical.
    pub fn run_with_model(&self, cm: &dyn CostModel) -> ServeReport {
        // a mismatched model would label the report with one config while
        // pricing every iteration on another — catch it early
        debug_assert_eq!(cm.base().arch, self.rc.arch, "cost model arch != server arch");
        debug_assert_eq!(cm.base().model.name, self.rc.model.name, "cost model != server model");
        debug_assert_eq!(cm.base().tp, self.rc.tp, "cost model tp != server tp");
        debug_assert_eq!(cm.base().devices, self.rc.devices, "cost model devices != server devices");
        debug_assert_eq!(
            cm.base().noc_fidelity,
            self.rc.noc_fidelity,
            "cost model NoC fidelity != server fidelity"
        );
        let class_names = self.cfg.class_names();
        let mut rejected_by_class = vec![0u64; class_names.len()];

        let mut q: EventQueue<Event> = EventQueue::new();
        for r in self.cfg.requests() {
            q.schedule_at(r.arrived_ns, Event::Arrival(r));
        }

        let mut batcher = Batcher::new(self.cfg.batcher.clone());
        let mut st = LoopState {
            busy_until: 0,
            iter_pending: false,
            total_cost: OpCost::zero(),
            decode_iters: 0,
            tokens_out: 0,
        };

        while let Some((now, ev)) = q.pop() {
            match ev {
                Event::Arrival(r) => {
                    let class = r.class.min(class_names.len().saturating_sub(1));
                    if !batcher.offer(r) {
                        rejected_by_class[class] += 1;
                    }
                    if now >= st.busy_until {
                        self.step(cm, &mut batcher, &mut q, now, &mut st);
                    }
                }
                Event::IterationDone => {
                    st.iter_pending = false;
                    self.step(cm, &mut batcher, &mut q, now, &mut st);
                }
            }
        }

        let mut stranded_by_class = vec![0u64; class_names.len()];
        for ci in batcher.unserved_classes() {
            stranded_by_class[ci.min(class_names.len().saturating_sub(1))] += 1;
        }
        let unserved = batcher.queued() + batcher.active.len();
        build_report(
            &self.rc,
            1,
            &class_names,
            &batcher.completed,
            &rejected_by_class,
            &stranded_by_class,
            RunTotals {
                makespan_ns: st.busy_until,
                tokens_out: st.tokens_out,
                decode_iters: st.decode_iters,
                cost: st.total_cost,
                rejected: batcher.rejected,
                preempted: batcher.preempted,
                unserved,
            },
        )
    }
}

/// Render the headline serving metrics (shared by CLI and examples).
pub fn render_summary(r: &ServeReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "completed {} | rejected {} | preempted {} | unserved {}\n",
        r.completed, r.rejected, r.preempted, r.unserved
    ));
    out.push_str(&format!(
        "makespan {} | throughput {:.1} tok/s | decode iters {}\n",
        ftime_ns(r.makespan_ns as f64),
        r.throughput_tok_s,
        r.decode_iters
    ));
    out.push_str(&format!(
        "TTFT p50/p99 {} / {} | TPOT p50/p99 {} / {}\n",
        ftime_ns(r.ttft_p50_ns),
        ftime_ns(r.ttft_p99_ns),
        ftime_ns(r.tpot_p50_ns),
        ftime_ns(r.tpot_p99_ns)
    ));
    out.push_str(&format!(
        "request latency p50/p99 {} / {}\n",
        ftime_ns(r.req_latency_p50_ns),
        ftime_ns(r.req_latency_p99_ns)
    ));
    out.push_str(&format!(
        "SLO attainment {:.1}% | energy {} | energy/token {}\n",
        r.slo_attainment * 100.0,
        fenergy_pj(r.energy.total_pj()),
        fenergy_pj(r.energy_per_token_pj)
    ));
    out
}

impl ToJson for ServeConfig {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("arrival_rate", self.arrival_rate)
            .field("n_requests", self.n_requests)
            .field("prompt_len", self.prompt_len)
            .field("gen_len", self.gen_len)
            .field("seed", self.seed)
            .field("scenario", self.scenario.as_ref().map(|s| s.name))
            .field("batcher", self.batcher.to_json())
    }
}

impl ToJson for ClassReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("class", self.class.as_str())
            .field("completed", self.completed)
            .field("rejected", self.rejected)
            .field("ttft_p50_ns", self.ttft_p50_ns)
            .field("ttft_p99_ns", self.ttft_p99_ns)
            .field("tpot_p50_ns", self.tpot_p50_ns)
            .field("tpot_p99_ns", self.tpot_p99_ns)
            .field("ttft_attainment", self.ttft_attainment)
            .field("tpot_attainment", self.tpot_attainment)
            .field("slo_attainment", self.slo_attainment)
    }
}

impl ToJson for ServeReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("completed", self.completed)
            .field("rejected", self.rejected)
            .field("preempted", self.preempted)
            .field("unserved", self.unserved)
            .field("makespan_ns", self.makespan_ns)
            .field("tokens_out", self.tokens_out)
            .field("throughput_tok_s", self.throughput_tok_s)
            .field("ttft_p50_ns", self.ttft_p50_ns)
            .field("ttft_p99_ns", self.ttft_p99_ns)
            .field("tpot_p50_ns", self.tpot_p50_ns)
            .field("tpot_p99_ns", self.tpot_p99_ns)
            .field("req_latency_p50_ns", self.req_latency_p50_ns)
            .field("req_latency_p99_ns", self.req_latency_p99_ns)
            .field("slo_attainment", self.slo_attainment)
            .field("energy", self.energy.to_json())
            .field("energy_per_token_pj", self.energy_per_token_pj)
            .field("decode_iters", self.decode_iters)
            .field("per_class", Json::arr(self.per_class.iter().map(|c| c.to_json())))
    }
}

impl ToJson for ScenarioReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("scenario", self.scenario.as_str())
            .field("arch", self.arch.as_str())
            .field("model", self.model.as_str())
            .field("report", self.report.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArchKind, ModelConfig};

    fn serve(arch: ArchKind, rate: f64) -> ServeReport {
        let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        let cfg = ServeConfig {
            arrival_rate: rate,
            n_requests: 24,
            prompt_len: 128,
            gen_len: 8,
            ..Default::default()
        };
        Server::new(rc, cfg).run()
    }

    fn serve_scenario(name: &str, n: usize, seed: u64) -> ServeReport {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        run_scenario(rc, Scenario::by_name(name).unwrap(), n, seed).report
    }

    #[test]
    fn all_requests_complete() {
        let r = serve(ArchKind::CompAirOpt, 50.0);
        assert_eq!(r.completed, 24);
        assert_eq!(r.rejected, 0);
        assert_eq!(r.unserved, 0);
        assert!(r.throughput_tok_s > 0.0);
        assert!(r.ttft_p99_ns >= r.ttft_p50_ns);
    }

    #[test]
    fn compair_serves_faster_than_cent() {
        let a = serve(ArchKind::CompAirOpt, 1e6);
        let b = serve(ArchKind::Cent, 1e6);
        assert!(
            a.makespan_ns < b.makespan_ns,
            "CompAir {} vs CENT {}",
            a.makespan_ns,
            b.makespan_ns
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = serve(ArchKind::CompAirOpt, 20.0);
        let b = serve(ArchKind::CompAirOpt, 20.0);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.completed, b.completed);
    }

    #[test]
    fn auto_mapping_serve_never_slower_and_deterministic() {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::tiny());
        rc.tp = 8;
        rc.devices = 32;
        let cfg = ServeConfig {
            arrival_rate: 50.0,
            n_requests: 12,
            prompt_len: 96,
            gen_len: 6,
            ..Default::default()
        };
        let server = Server::new(rc.clone(), cfg.clone());
        let static_r = server.run();
        rc.mapping = MappingMode::Auto;
        let auto_server = Server::new(rc.clone(), cfg);
        let auto_a = auto_server.run();
        // every iteration is floored at the static cost, so the makespan
        // can only shrink or stay put
        assert!(
            auto_a.makespan_ns <= static_r.makespan_ns,
            "auto {} > static {}",
            auto_a.makespan_ns,
            static_r.makespan_ns
        );
        assert_eq!(auto_a.completed, static_r.completed);
        // and the auto path is bit-reproducible, including across jobs
        rc.jobs = 4;
        let auto_b = Server::new(
            rc,
            ServeConfig {
                arrival_rate: 50.0,
                n_requests: 12,
                prompt_len: 96,
                gen_len: 6,
                ..Default::default()
            },
        )
        .run();
        assert_eq!(auto_a.makespan_ns, auto_b.makespan_ns);
        assert_eq!(auto_a.energy_per_token_pj.to_bits(), auto_b.energy_per_token_pj.to_bits());
    }

    #[test]
    fn saturation_increases_latency_not_loss() {
        let slow = serve(ArchKind::CompAirOpt, 2.0);
        let fast = serve(ArchKind::CompAirOpt, 1e7);
        assert_eq!(slow.completed, fast.completed);
        // under saturation, queueing delay shows in p99 request latency
        assert!(fast.req_latency_p99_ns >= slow.req_latency_p50_ns);
    }

    #[test]
    fn every_scenario_serves_to_completion() {
        for sc in Scenario::all() {
            let n = 8.min(sc.default_requests);
            let r = serve_scenario(sc.name, n, 42);
            assert_eq!(r.completed, n, "{} lost requests", sc.name);
            assert_eq!(r.unserved, 0, "{} stranded requests", sc.name);
            assert!(r.tokens_out > 0, "{} emitted no tokens", sc.name);
            assert!(r.energy_per_token_pj > 0.0);
            assert_eq!(r.per_class.len(), Scenario::by_name(sc.name).unwrap().classes.len());
            let class_total: usize = r.per_class.iter().map(|c| c.completed).sum();
            assert_eq!(class_total, n, "{} per-class rows don't add up", sc.name);
        }
    }

    #[test]
    fn scenario_runs_are_bit_reproducible() {
        let a = serve_scenario("mixed", 16, 7);
        let b = serve_scenario("mixed", 16, 7);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.tokens_out, b.tokens_out);
        assert_eq!(a.preempted, b.preempted);
        assert!((a.energy.total_pj() - b.energy.total_pj()).abs() < 1e-9);
        for (x, y) in a.per_class.iter().zip(&b.per_class) {
            assert_eq!(x.completed, y.completed);
            assert!((x.ttft_p99_ns - y.ttft_p99_ns).abs() < 1e-9);
            assert!((x.slo_attainment - y.slo_attainment).abs() < 1e-12);
        }
        let c = serve_scenario("mixed", 16, 8);
        assert_ne!(a.makespan_ns, c.makespan_ns, "seed must matter");
    }

    #[test]
    fn chunked_prefill_bounds_long_prompt_iterations() {
        // a 128K prompt must be split into prefill_chunk-sized iterations,
        // not one monolithic prefill
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.tp = 8;
        let chunk = 4096;
        let cfg = ServeConfig {
            n_requests: 1,
            prompt_len: 128 * 1024,
            gen_len: 2,
            batcher: BatcherConfig { prefill_chunk: chunk, ..Default::default() },
            ..Default::default()
        };
        let r = Server::new(rc, cfg).run();
        assert_eq!(r.completed, 1);
        // TTFT must cover ≥ prompt/chunk iterations — i.e. the request was
        // actually chunked (a single-shot prefill would take 1 iteration)
        assert!(r.ttft_p50_ns > 0.0);
        assert!(r.tokens_out == 2);
    }

    #[test]
    fn slo_attainment_is_a_fraction_and_relaxed_slos_always_met() {
        let r = serve(ArchKind::CompAirOpt, 100.0); // homogeneous = relaxed SLO
        assert!((r.slo_attainment - 1.0).abs() < 1e-12, "relaxed SLOs must all be met");
        let s = serve_scenario("chat", 16, 42);
        assert!((0.0..=1.0).contains(&s.slo_attainment));
        for c in &s.per_class {
            assert!((0.0..=1.0).contains(&c.slo_attainment));
            assert!(c.ttft_attainment >= c.slo_attainment - 1e-12);
        }
    }

    #[test]
    fn zero_served_classes_report_finite_attainment() {
        // regression: a one-request trace on a multi-class scenario leaves
        // classes with zero served requests; their attainment fractions
        // must be 0.0, never NaN (NaN leaked into the scenario tables)
        let r = serve_scenario("mixed", 1, 42);
        assert_eq!(r.completed, 1);
        let with_work = r.per_class.iter().filter(|c| c.completed > 0).count();
        assert_eq!(with_work, 1, "exactly one class served the single request");
        // finiteness, unit ranges and the zero-completed ⇒ 0.0 attainment
        // contract are enforced by the shared audit validator — the same
        // predicate `compair audit` runs on its serving sample
        let rep = crate::analysis::audit::check_serve_report("mixed n=1", &r);
        assert!(rep.is_clean(), "{}", rep.render_brief());
    }

    #[test]
    fn cached_cost_model_matches_uncached_bit_for_bit() {
        let mut rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        let cfg = ServeConfig {
            n_requests: 12,
            prompt_len: 128,
            gen_len: 8,
            ..Default::default()
        };
        let server = Server::new(rc.clone(), cfg);
        let uncached = server.run_with_model(&System::new(rc));
        let cached = server.run();
        assert_eq!(uncached.makespan_ns, cached.makespan_ns);
        assert_eq!(uncached.tokens_out, cached.tokens_out);
        assert_eq!(uncached.decode_iters, cached.decode_iters);
        assert_eq!(uncached.ttft_p99_ns.to_bits(), cached.ttft_p99_ns.to_bits());
        assert_eq!(
            uncached.energy.total_pj().to_bits(),
            cached.energy.total_pj().to_bits()
        );
    }

    #[test]
    fn offline_batch_maximizes_batching() {
        // all-at-once arrivals should serve with fewer, denser decode
        // iterations than the same work trickled in
        let r = serve_scenario("batch", 16, 42);
        assert_eq!(r.completed, 16);
        assert!(r.decode_iters > 0);
    }
}
