//! Continuous batching: requests join the running batch as slots free up
//! (Orca-style iteration-level scheduling), bounded by a batch-size cap and
//! a KV-capacity budget.

use std::collections::VecDeque;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub arrived_ns: u64,
}

/// Lifecycle state of an admitted request.
#[derive(Debug, Clone)]
pub struct RequestState {
    pub req: Request,
    pub generated: usize,
    pub prefilled: bool,
    pub admitted_ns: u64,
    pub first_token_ns: Option<u64>,
}

impl RequestState {
    pub fn kv_tokens(&self) -> usize {
        self.req.prompt_len + self.generated
    }

    pub fn done(&self) -> bool {
        self.prefilled && self.generated >= self.req.gen_len
    }
}

/// Batcher configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    /// Total KV tokens the fabric can hold (capacity budget).
    pub max_kv_tokens: usize,
    /// Bounded admission queue (backpressure: excess arrivals are rejected).
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 64, max_kv_tokens: 1 << 22, queue_cap: 1024 }
    }
}

/// The continuous batcher.
#[derive(Debug)]
pub struct Batcher {
    pub cfg: BatcherConfig,
    queue: VecDeque<Request>,
    pub active: Vec<RequestState>,
    pub rejected: u64,
    pub completed: Vec<(RequestState, u64)>, // (state, finished_ns)
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, queue: VecDeque::new(), active: Vec::new(), rejected: 0, completed: Vec::new() }
    }

    /// Offer a new request; returns false (and counts a rejection) when the
    /// admission queue is full — the backpressure signal.
    pub fn offer(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.queue_cap {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    fn kv_in_use(&self) -> usize {
        self.active.iter().map(|s| s.kv_tokens()).sum()
    }

    /// Admit queued requests while batch and KV budgets allow (called at
    /// every iteration boundary — continuous batching).
    pub fn admit(&mut self, now_ns: u64) -> usize {
        let mut admitted = 0;
        while self.active.len() < self.cfg.max_batch {
            let Some(front) = self.queue.front() else { break };
            let need = front.prompt_len + front.gen_len;
            if self.kv_in_use() + need > self.cfg.max_kv_tokens {
                break;
            }
            let req = self.queue.pop_front().unwrap();
            self.active.push(RequestState {
                req,
                generated: 0,
                prefilled: false,
                admitted_ns: now_ns,
                first_token_ns: None,
            });
            admitted += 1;
        }
        admitted
    }

    /// Requests needing prefill this iteration.
    pub fn prefill_set(&self) -> Vec<usize> {
        (0..self.active.len()).filter(|&i| !self.active[i].prefilled).collect()
    }

    /// Mark prefill complete.
    pub fn finish_prefill(&mut self, idx: &[usize], now_ns: u64) {
        for &i in idx {
            self.active[i].prefilled = true;
            self.active[i].first_token_ns.get_or_insert(now_ns);
        }
    }

    /// One decode iteration over all prefilled requests; retires finished
    /// ones. Returns (decoded count, max KV length in the step batch).
    pub fn decode_step(&mut self, now_ns: u64) -> (usize, usize) {
        let mut n = 0;
        let mut max_kv = 0;
        for s in self.active.iter_mut().filter(|s| s.prefilled && !s.done()) {
            s.generated += 1;
            n += 1;
            max_kv = max_kv.max(s.kv_tokens());
        }
        let done: Vec<usize> =
            (0..self.active.len()).rev().filter(|&i| self.active[i].done()).collect();
        for i in done {
            let s = self.active.swap_remove(i);
            self.completed.push((s, now_ns));
        }
        (n, max_kv)
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, g: usize) -> Request {
        Request { id, prompt_len: p, gen_len: g, arrived_ns: 0 }
    }

    #[test]
    fn admits_up_to_batch_cap() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        for i in 0..5 {
            assert!(b.offer(req(i, 16, 4)));
        }
        assert_eq!(b.admit(0), 2);
        assert_eq!(b.active.len(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn kv_budget_limits_admission() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 64,
            max_kv_tokens: 100,
            queue_cap: 16,
        });
        b.offer(req(0, 60, 10));
        b.offer(req(1, 60, 10));
        assert_eq!(b.admit(0), 1, "second request would blow the KV budget");
    }

    #[test]
    fn queue_backpressure_rejects() {
        let mut b = Batcher::new(BatcherConfig { queue_cap: 2, ..Default::default() });
        assert!(b.offer(req(0, 1, 1)));
        assert!(b.offer(req(1, 1, 1)));
        assert!(!b.offer(req(2, 1, 1)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn lifecycle_prefill_decode_retire() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.offer(req(0, 8, 2));
        b.admit(0);
        assert_eq!(b.prefill_set(), vec![0]);
        b.finish_prefill(&[0], 100);
        let (n, kv) = b.decode_step(200);
        assert_eq!((n, kv), (1, 9));
        assert!(b.completed.is_empty());
        b.decode_step(300);
        assert_eq!(b.completed.len(), 1);
        assert!(b.idle());
        let (s, t) = &b.completed[0];
        assert_eq!(*t, 300);
        assert_eq!(s.first_token_ns, Some(100));
    }

    #[test]
    fn continuous_admission_as_slots_free() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 1, ..Default::default() });
        b.offer(req(0, 4, 1));
        b.offer(req(1, 4, 1));
        b.admit(0);
        b.finish_prefill(&[0], 0);
        b.decode_step(10); // request 0 done, slot frees
        assert_eq!(b.admit(10), 1);
        assert_eq!(b.active[0].req.id, 1);
    }
}
