//! Continuous batching: requests join the running batch as slots free up
//! (Orca-style iteration-level scheduling), bounded by a batch-size cap and
//! a KV-capacity budget.
//!
//! The batcher is SLO-aware: admission is earliest-deadline-first over the
//! queue (deadline = arrival + TTFT target) with KV-budget backfill, urgent
//! arrivals may preempt looser-SLO active requests (recompute-on-resume
//! eviction), and prefill is chunked so a long prompt cannot monopolize an
//! iteration and starve the decode batch.

use std::collections::VecDeque;

use crate::workload::Slo;

/// One inference request as emitted by a workload trace.
#[derive(Debug, Clone)]
pub struct Request {
    /// Trace-unique id (stable across preemptions).
    pub id: u64,
    /// Index into the scenario's request classes (0 for homogeneous runs).
    pub class: usize,
    /// Prompt tokens to prefill.
    pub prompt_len: usize,
    /// Tokens to generate after prefill.
    pub gen_len: usize,
    /// Arrival time on the simulated clock (ns).
    pub arrived_ns: u64,
    /// Latency objective for this request's class.
    pub slo: Slo,
    /// Times this request was preempted (survives requeueing, so the count
    /// is visible on the completed request).
    pub preemptions: u32,
}

impl Request {
    /// A single-class request with a relaxed SLO (the homogeneous-workload
    /// constructor the pre-scenario callers use).
    pub fn new(id: u64, prompt_len: usize, gen_len: usize, arrived_ns: u64) -> Self {
        Self { id, class: 0, prompt_len, gen_len, arrived_ns, slo: Slo::default(), preemptions: 0 }
    }

    /// Admission deadline: the latest time prefill may complete while still
    /// meeting the TTFT target.
    pub fn deadline_ns(&self) -> u64 {
        self.arrived_ns.saturating_add(self.slo.ttft_ns)
    }
}

/// Lifecycle state of an admitted request.
#[derive(Debug, Clone)]
pub struct RequestState {
    /// The underlying request.
    pub req: Request,
    /// Decode tokens produced so far.
    pub generated: usize,
    /// Prompt tokens prefilled so far (chunked prefill advances this).
    pub prefilled_tokens: usize,
    /// When the request was (last) admitted into the running batch (ns).
    pub admitted_ns: u64,
    /// When the first output token was produced (ns), once prefill finishes.
    pub first_token_ns: Option<u64>,
}

impl RequestState {
    /// Has the whole prompt been prefilled?
    pub fn is_prefilled(&self) -> bool {
        self.prefilled_tokens >= self.req.prompt_len
    }

    /// KV tokens physically resident right now (grows chunk by chunk).
    pub fn kv_tokens(&self) -> usize {
        self.prefilled_tokens + self.generated
    }

    /// Full-reservation KV footprint: the whole prompt *and* the whole
    /// generation, so an admitted request can always run to completion.
    /// Reserving only `prompt_len + generated` (the old accounting) let
    /// admission hand the un-generated tokens of active requests to
    /// newcomers, so resident KV could exceed `max_kv_tokens` mid-decode.
    /// (A batcher with `reserve_gen: false` charges the prompt only — see
    /// [`BatcherConfig::reserve_gen`].)
    pub fn kv_footprint(&self) -> usize {
        self.req.prompt_len + self.req.gen_len
    }

    /// Prompt tokens still to prefill.
    pub fn prefill_remaining(&self) -> usize {
        self.req.prompt_len.saturating_sub(self.prefilled_tokens)
    }

    /// Fully served?
    pub fn done(&self) -> bool {
        self.is_prefilled() && self.generated >= self.req.gen_len
    }

    /// Observed time-to-first-token (ns), once known.
    pub fn ttft_ns(&self) -> Option<u64> {
        self.first_token_ns.map(|t| t.saturating_sub(self.req.arrived_ns))
    }

    /// Observed average per-output-token latency (ns) given the finish
    /// time; 0 for single-token generations.
    pub fn tpot_ns(&self, finished_ns: u64) -> f64 {
        match (self.first_token_ns, self.req.gen_len) {
            (Some(first), g) if g >= 2 => {
                finished_ns.saturating_sub(first) as f64 / (g - 1) as f64
            }
            _ => 0.0,
        }
    }
}

/// Batcher configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max requests resident in the running batch.
    pub max_batch: usize,
    /// Total KV tokens the fabric can hold (capacity budget).
    pub max_kv_tokens: usize,
    /// Bounded admission queue (backpressure: excess arrivals are rejected).
    pub queue_cap: usize,
    /// Max prompt tokens prefilled per iteration (chunked prefill);
    /// `usize::MAX` disables chunking.
    pub prefill_chunk: usize,
    /// Allow urgent queued requests to preempt looser-SLO active ones.
    pub slo_eviction: bool,
    /// Reserve `gen_len` KV at admission alongside the prompt. True for
    /// colocated/decode batchers (decode KV materializes in place); the
    /// cluster's prefill-pool batchers set false, since a request is
    /// handed off at prefill completion and its generation KV never
    /// resides there.
    pub reserve_gen: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 64,
            max_kv_tokens: 1 << 22,
            queue_cap: 1024,
            prefill_chunk: 4096,
            slo_eviction: true,
            reserve_gen: true,
        }
    }
}

impl crate::util::json::ToJson for BatcherConfig {
    fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        // usize::MAX means "chunking disabled" — serialize as null rather
        // than a nonsense integer
        let chunk =
            if self.prefill_chunk == usize::MAX { Json::Null } else { self.prefill_chunk.into() };
        Json::obj()
            .field("max_batch", self.max_batch)
            .field("max_kv_tokens", self.max_kv_tokens)
            .field("queue_cap", self.queue_cap)
            .field("prefill_chunk", chunk)
            .field("slo_eviction", self.slo_eviction)
            .field("reserve_gen", self.reserve_gen)
    }
}

/// The continuous batcher.
#[derive(Debug)]
pub struct Batcher {
    /// Policy knobs.
    pub cfg: BatcherConfig,
    queue: VecDeque<Request>,
    /// Requests currently in the running batch.
    pub active: Vec<RequestState>,
    /// Arrivals dropped because the admission queue was full.
    pub rejected: u64,
    /// Evictions performed to admit tighter-SLO requests.
    pub preempted: u64,
    /// Finished requests as `(state, finished_ns)` pairs.
    pub completed: Vec<(RequestState, u64)>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            queue: VecDeque::new(),
            active: Vec::new(),
            rejected: 0,
            preempted: 0,
            completed: Vec::new(),
        }
    }

    /// Offer a new request; returns false (and counts a rejection) when the
    /// admission queue is full — the backpressure signal — or when the
    /// request can never fit the KV budget at all (it would otherwise sit
    /// in the queue forever as unserved).
    /// KV tokens a request reserves under this batcher's policy: the full
    /// prompt, plus the full generation when `cfg.reserve_gen` is set.
    fn reservation(&self, prompt_len: usize, gen_len: usize) -> usize {
        prompt_len + if self.cfg.reserve_gen { gen_len } else { 0 }
    }

    pub fn offer(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.queue_cap
            || self.reservation(req.prompt_len, req.gen_len) > self.cfg.max_kv_tokens
        {
            self.rejected += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// KV tokens reserved by the running batch (`prompt + gen` per active
    /// request, prompt only under `reserve_gen: false`). Public so cluster
    /// routers can read replica load.
    pub fn kv_in_use(&self) -> usize {
        self.active.iter().map(|s| self.reservation(s.req.prompt_len, s.req.gen_len)).sum()
    }

    /// KV tokens the admission queue will eventually demand (router load
    /// signal: work committed to this batcher but not yet resident).
    pub fn queued_kv_demand(&self) -> usize {
        self.queue.iter().map(|r| self.reservation(r.prompt_len, r.gen_len)).sum()
    }

    /// How many queued + active requests hold a deadline at or before
    /// `deadline_ns` — the work an EDF scheduler will serve ahead of a
    /// request with that deadline (deadline-aware router load signal).
    pub fn deadline_pressure(&self, deadline_ns: u64) -> usize {
        self.queue.iter().filter(|r| r.deadline_ns() <= deadline_ns).count()
            + self.active.iter().filter(|s| s.req.deadline_ns() <= deadline_ns).count()
    }

    /// Index of the queued request with the earliest deadline that fits the
    /// KV budget (ties broken by queue order, i.e. arrival order).
    fn best_admissible(&self) -> Option<usize> {
        let head = self.cfg.max_kv_tokens.saturating_sub(self.kv_in_use());
        let mut best: Option<usize> = None;
        for (i, r) in self.queue.iter().enumerate() {
            if self.reservation(r.prompt_len, r.gen_len) > head {
                continue;
            }
            match best {
                Some(b) if self.queue[b].deadline_ns() <= r.deadline_ns() => {}
                _ => best = Some(i),
            }
        }
        best
    }

    /// Admit queued requests earliest-deadline-first while batch and KV
    /// budgets allow (called at every iteration boundary — continuous
    /// batching). Requests that do not fit the remaining KV budget are
    /// skipped so smaller later arrivals can backfill.
    pub fn admit(&mut self, now_ns: u64) -> usize {
        let mut admitted = 0;
        while self.active.len() < self.cfg.max_batch {
            let Some(i) = self.best_admissible() else { break };
            let req = self.queue.remove(i).expect("index from best_admissible");
            self.active.push(RequestState {
                req,
                generated: 0,
                prefilled_tokens: 0,
                admitted_ns: now_ns,
                first_token_ns: None,
            });
            admitted += 1;
        }
        admitted
    }

    /// SLO-priority eviction: while the most urgent queued request cannot
    /// be admitted for lack of KV room, preempt active requests of strictly
    /// looser SLO classes (largest TTFT target first, least progress lost
    /// as tiebreak). Evicted requests return to the queue and restart from
    /// scratch on re-admission (recompute-on-resume). Returns the number of
    /// evictions performed.
    pub fn preempt_for_urgent(&mut self, _now_ns: u64) -> usize {
        if !self.cfg.slo_eviction {
            return 0;
        }
        let mut evictions = 0;
        loop {
            // the deadline-critical queued request, ignoring current KV
            // headroom (offer() guarantees every queued request fits an
            // empty fabric)
            let Some(urgent) = self
                .queue
                .iter()
                .min_by_key(|r| (r.deadline_ns(), r.id))
                .map(|r| (r.deadline_ns(), r.slo.ttft_ns, self.reservation(r.prompt_len, r.gen_len)))
            else {
                break;
            };
            let (urgent_deadline, urgent_ttft, need) = urgent;
            let headroom = self.cfg.max_kv_tokens.saturating_sub(self.kv_in_use());
            if need <= headroom && self.active.len() < self.cfg.max_batch {
                break; // admit() will take it
            }
            // a victim must be BOTH of a strictly looser SLO class and
            // behind the urgent request in deadline order — admit() is
            // earliest-deadline-first, so evicting an earlier-deadline
            // victim would just see it re-admitted ahead of the urgent
            // request (evict/re-admit livelock)
            let is_victim = |s: &RequestState| {
                s.req.slo.ttft_ns > urgent_ttft && s.req.deadline_ns() > urgent_deadline
            };
            // feasibility first: only start evicting when preempting every
            // eligible victim would actually make room — otherwise victims
            // would thrash (evict, re-admit, recompute) without the urgent
            // request ever fitting
            let evictable: usize = self
                .active
                .iter()
                .filter(|&s| is_victim(s))
                .map(|s| self.reservation(s.req.prompt_len, s.req.gen_len))
                .sum();
            if headroom + evictable < need {
                break;
            }
            // among victims: loosest SLO class first; ties evict the one
            // with the least compute invested
            let victim = self
                .active
                .iter()
                .enumerate()
                .filter(|&(_, s)| is_victim(s))
                .max_by_key(|(_, s)| (s.req.slo.ttft_ns, std::cmp::Reverse(s.kv_tokens())))
                .map(|(i, _)| i);
            let Some(vi) = victim else { break };
            let mut st = self.active.swap_remove(vi);
            st.req.preemptions += 1;
            self.preempted += 1;
            // progress is discarded; the request re-enters the queue with
            // its original arrival (deadline unchanged)
            self.queue.push_front(st.req);
            evictions += 1;
        }
        evictions
    }

    /// Requests needing (more) prefill this iteration.
    pub fn prefill_set(&self) -> Vec<usize> {
        (0..self.active.len()).filter(|&i| !self.active[i].is_prefilled()).collect()
    }

    /// Plan this iteration's chunked prefill: `(active index, tokens)`
    /// allocations in deadline order, totalling at most
    /// `cfg.prefill_chunk` tokens. Long prompts advance chunk by chunk
    /// across iterations instead of stalling the decode batch.
    pub fn plan_prefill(&self) -> Vec<(usize, usize)> {
        let mut order: Vec<usize> = self.prefill_set();
        order.sort_by_key(|&i| (self.active[i].req.deadline_ns(), self.active[i].req.id));
        let mut budget = self.cfg.prefill_chunk;
        let mut plan = Vec::new();
        for i in order {
            if budget == 0 {
                break;
            }
            let take = self.active[i].prefill_remaining().min(budget);
            if take > 0 {
                plan.push((i, take));
                budget = budget.saturating_sub(take);
            }
        }
        plan
    }

    /// Apply a prefill plan: advance each request's prefilled prefix; a
    /// request whose prompt completes records `now_ns` as its first-token
    /// time (its first output token is produced by this same iteration).
    pub fn advance_prefill(&mut self, plan: &[(usize, usize)], now_ns: u64) {
        for &(i, tokens) in plan {
            let s = &mut self.active[i];
            s.prefilled_tokens = (s.prefilled_tokens + tokens).min(s.req.prompt_len);
            if s.is_prefilled() {
                s.first_token_ns.get_or_insert(now_ns);
            }
        }
    }

    /// Mark prefill fully complete for the given indices (the unchunked
    /// path used by callers that plan whole prompts per iteration).
    pub fn finish_prefill(&mut self, idx: &[usize], now_ns: u64) {
        let plan: Vec<(usize, usize)> =
            idx.iter().map(|&i| (i, self.active[i].prefill_remaining())).collect();
        self.advance_prefill(&plan, now_ns);
    }

    /// One decode iteration over all prefilled requests; retires finished
    /// ones. Returns (decoded count, max KV length in the step batch).
    pub fn decode_step(&mut self, now_ns: u64) -> (usize, usize) {
        let mut n = 0;
        let mut max_kv = 0;
        for s in self.active.iter_mut().filter(|s| s.is_prefilled() && !s.done()) {
            s.generated += 1;
            n += 1;
            max_kv = max_kv.max(s.kv_tokens());
        }
        let done: Vec<usize> =
            (0..self.active.len()).rev().filter(|&i| self.active[i].done()).collect();
        for i in done {
            let s = self.active.swap_remove(i);
            self.completed.push((s, now_ns));
        }
        (n, max_kv)
    }

    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Class indices of every request still queued or active — after the
    /// serving loop drains, these are the stranded (unserved) requests.
    pub fn unserved_classes(&self) -> Vec<usize> {
        self.queue
            .iter()
            .map(|r| r.class)
            .chain(self.active.iter().map(|s| s.req.class))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, g: usize) -> Request {
        Request::new(id, p, g, 0)
    }

    fn req_slo(id: u64, p: usize, g: usize, arrived: u64, ttft_ms: f64) -> Request {
        Request { slo: Slo::from_ms(ttft_ms, 1e9), ..Request::new(id, p, g, arrived) }
    }

    #[test]
    fn admits_up_to_batch_cap() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 2, ..Default::default() });
        for i in 0..5 {
            assert!(b.offer(req(i, 16, 4)));
        }
        assert_eq!(b.admit(0), 2);
        assert_eq!(b.active.len(), 2);
        assert_eq!(b.queued(), 3);
    }

    #[test]
    fn kv_budget_limits_admission() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 64,
            max_kv_tokens: 100,
            queue_cap: 16,
            ..Default::default()
        });
        b.offer(req(0, 60, 10));
        b.offer(req(1, 60, 10));
        assert_eq!(b.admit(0), 1, "second request would blow the KV budget");
    }

    #[test]
    fn queue_backpressure_rejects() {
        let mut b = Batcher::new(BatcherConfig { queue_cap: 2, ..Default::default() });
        assert!(b.offer(req(0, 1, 1)));
        assert!(b.offer(req(1, 1, 1)));
        assert!(!b.offer(req(2, 1, 1)));
        assert_eq!(b.rejected, 1);
    }

    #[test]
    fn lifecycle_prefill_decode_retire() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.offer(req(0, 8, 2));
        b.admit(0);
        assert_eq!(b.prefill_set(), vec![0]);
        b.finish_prefill(&[0], 100);
        let (n, kv) = b.decode_step(200);
        assert_eq!((n, kv), (1, 9));
        assert!(b.completed.is_empty());
        b.decode_step(300);
        assert_eq!(b.completed.len(), 1);
        assert!(b.idle());
        let (s, t) = &b.completed[0];
        assert_eq!(*t, 300);
        assert_eq!(s.first_token_ns, Some(100));
    }

    #[test]
    fn continuous_admission_as_slots_free() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 1, ..Default::default() });
        b.offer(req(0, 4, 1));
        b.offer(req(1, 4, 1));
        b.admit(0);
        b.finish_prefill(&[0], 0);
        b.decode_step(10); // request 0 done, slot frees
        assert_eq!(b.admit(10), 1);
        assert_eq!(b.active[0].req.id, 1);
    }

    #[test]
    fn admission_is_earliest_deadline_first() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 1, ..Default::default() });
        // id 0 arrives first but has a loose SLO; id 1 is urgent
        b.offer(req_slo(0, 16, 4, 0, 10_000.0));
        b.offer(req_slo(1, 16, 4, 100, 10.0));
        b.admit(200);
        assert_eq!(b.active[0].req.id, 1, "tighter deadline admitted first");
    }

    #[test]
    fn kv_backfill_skips_oversized_head() {
        let mut b = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        // same deadlines: queue order is the tiebreak; the 90-token head
        // fits, the second 90-token one doesn't, the 8-token one backfills
        b.offer(req(0, 80, 10));
        b.offer(req(1, 80, 10));
        b.offer(req(2, 4, 4));
        assert_eq!(b.admit(0), 2);
        let ids: Vec<u64> = b.active.iter().map(|s| s.req.id).collect();
        assert_eq!(ids, vec![0, 2]);
    }

    #[test]
    fn chunked_prefill_respects_budget_and_completes() {
        let mut b = Batcher::new(BatcherConfig {
            prefill_chunk: 100,
            ..Default::default()
        });
        b.offer(req(0, 250, 1));
        b.admit(0);
        let mut total = 0;
        let mut iters = 0;
        while !b.active[0].is_prefilled() {
            let plan = b.plan_prefill();
            let tokens: usize = plan.iter().map(|&(_, t)| t).sum();
            assert!(tokens <= 100, "chunk budget exceeded: {tokens}");
            assert!(tokens > 0, "prefill must make progress");
            total += tokens;
            iters += 1;
            b.advance_prefill(&plan, iters * 10);
        }
        assert_eq!(total, 250);
        assert_eq!(iters, 3); // 100 + 100 + 50
        assert_eq!(b.active[0].first_token_ns, Some(30));
        // KV grows with the prefilled prefix, never past the prompt
        assert_eq!(b.active[0].kv_tokens(), 250);
    }

    #[test]
    fn chunk_budget_shared_across_requests_in_deadline_order() {
        let mut b = Batcher::new(BatcherConfig {
            prefill_chunk: 64,
            ..Default::default()
        });
        b.offer(req_slo(0, 60, 1, 0, 10_000.0));
        b.offer(req_slo(1, 60, 1, 0, 10.0));
        b.admit(0);
        let plan = b.plan_prefill();
        // urgent request (id 1) drains first; only 4 tokens left for id 0
        let by_id: Vec<(u64, usize)> =
            plan.iter().map(|&(i, t)| (b.active[i].req.id, t)).collect();
        assert_eq!(by_id, vec![(1, 60), (0, 4)]);
    }

    #[test]
    fn urgent_request_preempts_loose_one() {
        let mut b = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        b.offer(req_slo(0, 80, 10, 0, 60_000.0)); // loose batch-class job
        b.admit(0);
        b.finish_prefill(&[0], 10);
        // an urgent request arrives; no KV room
        b.offer(req_slo(1, 50, 10, 20, 10.0));
        assert_eq!(b.admit(20), 0, "no room without eviction");
        let evicted = b.preempt_for_urgent(20);
        assert_eq!(evicted, 1);
        assert_eq!(b.preempted, 1);
        assert_eq!(b.admit(20), 1);
        assert_eq!(b.active[0].req.id, 1);
        // the victim went back to the queue and is re-served later, with
        // the preemption visible on the request itself
        assert_eq!(b.queued(), 1);
        let victim = b.queue.front().unwrap();
        assert_eq!(victim.id, 0);
        assert_eq!(victim.preemptions, 1);
    }

    #[test]
    fn no_eviction_when_it_cannot_make_room() {
        // urgent needs 55 tokens; the only evictable (looser) victim frees
        // 15 and headroom is 15 — evicting can never fit the urgent
        // request, so nothing may be evicted (else the victim would thrash
        // evict → re-admit → recompute while the urgent one still waits)
        let mut b = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        b.offer(req_slo(0, 60, 10, 0, 0.5)); // tighter than urgent: not evictable
        b.offer(req_slo(1, 10, 5, 0, 60_000.0)); // loose: evictable, frees 10
        b.admit(0);
        assert_eq!(b.active.len(), 2);
        // urgent needs 55 > headroom 15 + evictable 15
        b.offer(req_slo(2, 45, 10, 5, 1.0));
        assert_eq!(b.preempt_for_urgent(5), 0, "infeasible eviction must not start");
        assert_eq!(b.preempted, 0);
    }

    #[test]
    fn eviction_never_helps_equal_or_tighter_classes() {
        let mut b = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        b.offer(req_slo(0, 80, 10, 0, 10.0));
        b.admit(0);
        b.offer(req_slo(1, 80, 10, 5, 10.0)); // same class tightness
        assert_eq!(b.preempt_for_urgent(5), 0, "equal SLO classes never preempt");
        b.offer(req_slo(2, 80, 10, 6, 100.0)); // looser than active
        assert_eq!(b.preempt_for_urgent(6), 0, "looser arrivals never preempt");
    }

    #[test]
    fn no_eviction_of_earlier_deadline_victims() {
        // the victim is of a looser class but holds an EARLIER deadline
        // than the urgent arrival; evicting it would livelock — EDF
        // admission would put it straight back ahead of the urgent request
        let mut b = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        // loose class (2000ms) arrived at t=0 → deadline 2.0s
        b.offer(req_slo(0, 80, 10, 0, 2_000.0));
        b.admit(0);
        // tight class (200ms) arrives at 1.9s → deadline 2.1s (later!)
        b.offer(req_slo(1, 50, 10, 1_900_000_000, 200.0));
        assert_eq!(b.preempt_for_urgent(1_900_000_000), 0);
        assert_eq!(b.preempted, 0);
        // the same tight request arriving early (deadline before the
        // victim's) does preempt
        let mut b2 = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        b2.offer(req_slo(0, 80, 10, 0, 2_000.0));
        b2.admit(0);
        b2.offer(req_slo(1, 50, 10, 10, 200.0)); // deadline 0.2s < 2.0s
        assert_eq!(b2.preempt_for_urgent(10), 1);
    }

    #[test]
    fn prompt_only_reservation_admits_more() {
        // a prefill-pool batcher (reserve_gen: false) charges the prompt
        // only, so it packs more concurrent prefills into the same budget
        let mut full = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        let mut prompt_only = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            reserve_gen: false,
            ..Default::default()
        });
        for b in [&mut full, &mut prompt_only] {
            for i in 0..4 {
                assert!(b.offer(req(i, 30, 20)));
            }
        }
        assert_eq!(full.admit(0), 2, "full reservation: 50 tokens each");
        assert_eq!(prompt_only.admit(0), 3, "prompt-only: 30 tokens each");
        assert_eq!(prompt_only.kv_in_use(), 90);
    }

    #[test]
    fn oversized_request_rejected_up_front() {
        // a request that can never fit the KV budget is refused at offer()
        // instead of stranding in the queue forever
        let mut b = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            ..Default::default()
        });
        assert!(!b.offer(req(0, 200, 10)));
        assert_eq!(b.rejected, 1);
        assert_eq!(b.queued(), 0);
        assert!(b.offer(req(1, 50, 10)));
    }

    #[test]
    fn eviction_disabled_by_config() {
        let mut b = Batcher::new(BatcherConfig {
            max_kv_tokens: 100,
            slo_eviction: false,
            ..Default::default()
        });
        b.offer(req_slo(0, 80, 10, 0, 60_000.0));
        b.admit(0);
        b.offer(req_slo(1, 50, 10, 20, 10.0));
        assert_eq!(b.preempt_for_urgent(20), 0);
    }

    #[test]
    fn resident_kv_never_exceeds_budget_under_bursty() {
        // The KV-overcommit regression: admission used to reserve only
        // `prompt + generated` for active requests, so the un-generated
        // tokens of admitted requests were silently handed to newcomers and
        // resident KV blew past `max_kv_tokens` mid-decode. Drive the
        // bursty scenario trace through the batcher and check the resident
        // invariant at every iteration boundary.
        use crate::workload::Scenario;
        let reqs = Scenario::by_name("bursty").unwrap().generate(42, 64);
        let cfg = BatcherConfig {
            max_batch: 64,
            max_kv_tokens: 1024,
            queue_cap: 1024,
            prefill_chunk: 256,
            ..Default::default()
        };
        let budget = cfg.max_kv_tokens;
        let mut b = Batcher::new(cfg);
        let mut pending = reqs.into_iter();
        let mut exhausted = false;
        let mut t = 0u64;
        loop {
            t += 1;
            // trickle arrivals in (two per iteration keeps the queue hot)
            for _ in 0..2 {
                match pending.next() {
                    Some(r) => {
                        b.offer(r);
                    }
                    None => exhausted = true,
                }
            }
            b.preempt_for_urgent(t);
            b.admit(t);
            let plan = b.plan_prefill();
            b.advance_prefill(&plan, t);
            b.decode_step(t);
            let resident: usize = b.active.iter().map(|s| s.kv_tokens()).sum();
            assert!(
                resident <= budget,
                "resident KV {resident} exceeds budget {budget} at iteration {t}"
            );
            // reservations must bound residency too
            assert!(b.kv_in_use() <= budget, "reserved KV exceeds budget at iteration {t}");
            if exhausted && b.idle() {
                break;
            }
            assert!(t < 1_000_000, "batcher failed to drain");
        }
        assert!(!b.completed.is_empty(), "bursty trace must serve requests");
    }

    #[test]
    fn tpot_and_ttft_accounting() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.offer(Request::new(0, 8, 5, 100));
        b.admit(100);
        b.finish_prefill(&[0], 200);
        let mut t = 200;
        while b.completed.is_empty() {
            t += 50;
            b.decode_step(t);
        }
        let (s, fin) = &b.completed[0];
        assert_eq!(s.ttft_ns(), Some(100)); // 200 - 100
        // 5 tokens: first at 250, last at 450 → 4 gaps... first_token is the
        // prefill-complete timestamp (200); finish at 450; tpot = 250/4
        assert_eq!(*fin, 450);
        assert!((s.tpot_ns(*fin) - 62.5).abs() < 1e-9);
    }
}
