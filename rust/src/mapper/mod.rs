//! Operator auto-mapping: which engine runs each transformer op.
//!
//! CompAir's headline wins come from placing every operator on the engine
//! that suits it — DRAM-PIM banks for bandwidth-bound GeMV, SRAM-PIM under
//! the banks for latency-critical matrix work, the in-transit Curry ALUs
//! for non-linear ops, the centralized NLU/host path as the fallback. Up
//! to now `arch/system.rs` hard-coded one such assignment per architecture
//! variant; this module reifies the assignment as data ([`Mapping`]), keeps
//! the hard-coded choice available bit-for-bit ([`Mapping::static_for`]),
//! and searches the placement space for something better
//! ([`search::search_phase`]), in the spirit of the balanced PIM/NoC
//! dataflow searches of LEAP and the heterogeneous-PIM scheduling of HPIM.
//!
//! The search scores whole mappings through `System::run_shape_mapped`
//! (the same lowering the static path uses, so scores are real phase
//! latencies at the configured NoC fidelity) and is clamped to *never
//! lose*: the static mapping is always a scored candidate, and the final
//! answer falls back to it on any tie or regression. `tests/prop_mapper.rs`
//! holds the property suite (never-lose, validity, determinism).
//!
//! [`AutoMappedCostModel`] adapts the search to the serving loop: one
//! search per (phase, shape-class) — classes are pow2 ceilings of
//! (batch, kv-length), so a drifting decode shape re-uses its class's
//! mapping instead of re-searching every iteration — with all pricing
//! memoized in the underlying [`CachedCostModel`].

pub mod search;

pub use search::{search_phase, search_space_size, SearchConfig, SearchResult};

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use crate::arch::cost_model::compose_iteration;
use crate::arch::{CacheStats, CachedCostModel, CostModel, PhaseReport, System};
use crate::config::{ArchKind, Phase, RunConfig};
use crate::sim::OpCost;
use crate::workload::LlmOp;

/// An engine an operator can execute on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Placement {
    /// DRAM-PIM bank MAC lanes (bandwidth-bound GeMV).
    DramPim,
    /// SRAM-PIM arrays stacked under the banks (latency-critical matmul).
    SramPim,
    /// In-transit Curry ALUs in the NoC routers (non-linear ops).
    NocAlu,
    /// The centralized NLU / CXL-controller path (always available).
    Host,
}

impl Placement {
    pub fn label(&self) -> &'static str {
        match self {
            Placement::DramPim => "dram-pim",
            Placement::SramPim => "sram-pim",
            Placement::NocAlu => "noc-alu",
            Placement::Host => "host",
        }
    }

    /// One-letter code for compact mapping summaries.
    pub fn code(&self) -> char {
        match self {
            Placement::DramPim => 'D',
            Placement::SramPim => 'S',
            Placement::NocAlu => 'N',
            Placement::Host => 'H',
        }
    }
}

/// One placement decision slot: every operator `workload::layer_ops` can
/// emit folds onto exactly one slot, so a [`Mapping`] is a fixed-size
/// array rather than a per-op table. FC slots are keyed by the projection
/// name (their shapes differ, so their best engines may too).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(usize)]
pub enum Slot {
    FcQ = 0,
    FcKv,
    FcO,
    FcUp,
    FcGate,
    FcDown,
    AttnQK,
    AttnSV,
    Softmax,
    Rope,
    RmsNorm,
    Activation,
    AllReduce,
}

/// Number of decision slots in a [`Mapping`].
pub const N_SLOTS: usize = 13;

impl Slot {
    /// Every slot, in declaration order (the canonical search order).
    pub fn all() -> [Slot; N_SLOTS] {
        [
            Slot::FcQ,
            Slot::FcKv,
            Slot::FcO,
            Slot::FcUp,
            Slot::FcGate,
            Slot::FcDown,
            Slot::AttnQK,
            Slot::AttnSV,
            Slot::Softmax,
            Slot::Rope,
            Slot::RmsNorm,
            Slot::Activation,
            Slot::AllReduce,
        ]
    }

    /// The slot an operator instance decides under.
    pub fn of_op(op: &LlmOp) -> Slot {
        match op {
            LlmOp::Fc { name, .. } => match *name {
                "q" => Slot::FcQ,
                "kv" => Slot::FcKv,
                "o" => Slot::FcO,
                "up" => Slot::FcUp,
                "gate" => Slot::FcGate,
                "down" => Slot::FcDown,
                other => unreachable!("unknown FC projection '{other}'"),
            },
            LlmOp::AttnQK { .. } => Slot::AttnQK,
            LlmOp::AttnSV { .. } => Slot::AttnSV,
            LlmOp::Softmax { .. } => Slot::Softmax,
            LlmOp::Rope { .. } => Slot::Rope,
            LlmOp::RmsNorm { .. } => Slot::RmsNorm,
            LlmOp::Activation { .. } => Slot::Activation,
            LlmOp::AllReduce { .. } => Slot::AllReduce,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Slot::FcQ => "fc:q",
            Slot::FcKv => "fc:kv",
            Slot::FcO => "fc:o",
            Slot::FcUp => "fc:up",
            Slot::FcGate => "fc:gate",
            Slot::FcDown => "fc:down",
            Slot::AttnQK => "attn:qk",
            Slot::AttnSV => "attn:sv",
            Slot::Softmax => "nl:softmax",
            Slot::Rope => "nl:rope",
            Slot::RmsNorm => "nl:rmsnorm",
            Slot::Activation => "nl:act",
            Slot::AllReduce => "coll:allreduce",
        }
    }
}

/// The engines a slot may legally run on under an architecture variant,
/// **static placement first** (deterministic tie-breaking: candidate 0 of
/// every enumeration is exactly the static mapping).
///
/// Validity rules (the property suite pins them):
/// * FC projections: DRAM-PIM always; SRAM-PIM only where the variant
///   stacks SRAM under the banks.
/// * Attention score/value matmuls: DRAM-PIM only — K/V are
///   input-dependent, so they live where the KV cache lives (§8).
/// * Non-linear ops (softmax/rope/rmsnorm/activation): the host NLU
///   always works; the Curry ALUs only where the variant has them; and
///   **never** a PIM engine — exp/rsqrt have no MAC-lane lowering.
/// * All-reduce: the CXL fabric (host) only.
pub fn supported_placements(slot: Slot, arch: ArchKind) -> Vec<Placement> {
    match slot {
        Slot::FcQ | Slot::FcKv | Slot::FcO | Slot::FcUp | Slot::FcGate | Slot::FcDown => {
            if arch.has_sram() {
                vec![Placement::SramPim, Placement::DramPim]
            } else {
                vec![Placement::DramPim]
            }
        }
        Slot::AttnQK | Slot::AttnSV => vec![Placement::DramPim],
        Slot::Softmax | Slot::Rope | Slot::RmsNorm | Slot::Activation => {
            if arch.has_curry() {
                vec![Placement::NocAlu, Placement::Host]
            } else {
                vec![Placement::Host]
            }
        }
        Slot::AllReduce => vec![Placement::Host],
    }
}

/// A complete per-slot placement assignment. `Copy + Eq + Hash` so it can
/// key memoization maps and be compared bit-for-bit across search runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Mapping {
    places: [Placement; N_SLOTS],
}

impl Mapping {
    /// The hard-coded placement `arch/system.rs` has always used: FC on
    /// SRAM-PIM where stacked (else DRAM-PIM), attention on DRAM-PIM,
    /// non-linear ops on the Curry ALUs where present (else host NLU),
    /// collectives on the fabric. This is the `StaticMapping` baseline —
    /// `System::run_shape` lowers through it, so it is the pre-mapper
    /// behavior by construction, not by re-implementation.
    pub fn static_for(arch: ArchKind) -> Mapping {
        let mut places = [Placement::Host; N_SLOTS];
        for slot in Slot::all() {
            places[slot as usize] = supported_placements(slot, arch)[0];
        }
        Mapping { places }
    }

    pub fn get(&self, slot: Slot) -> Placement {
        self.places[slot as usize]
    }

    /// A copy with one slot rebound.
    pub fn with(mut self, slot: Slot, p: Placement) -> Mapping {
        self.places[slot as usize] = p;
        self
    }

    /// The placement governing an operator instance.
    pub fn placement_of(&self, op: &LlmOp) -> Placement {
        self.get(Slot::of_op(op))
    }

    /// Does every slot sit on an engine the variant supports?
    pub fn is_valid_for(&self, arch: ArchKind) -> bool {
        Slot::all()
            .iter()
            .all(|s| supported_placements(*s, arch).contains(&self.get(*s)))
    }

    /// Compact human-readable summary, FC slots then attention/non-linear/
    /// collective, e.g. `fc:SSDSSD attn:DD nl:NNNN coll:H`.
    pub fn summary(&self) -> String {
        let code = |s: Slot| self.get(s).code();
        format!(
            "fc:{}{}{}{}{}{} attn:{}{} nl:{}{}{}{} coll:{}",
            code(Slot::FcQ),
            code(Slot::FcKv),
            code(Slot::FcO),
            code(Slot::FcUp),
            code(Slot::FcGate),
            code(Slot::FcDown),
            code(Slot::AttnQK),
            code(Slot::AttnSV),
            code(Slot::Softmax),
            code(Slot::Rope),
            code(Slot::RmsNorm),
            code(Slot::Activation),
            code(Slot::AllReduce),
        )
    }
}

/// A [`CostModel`] that searches for the best mapping per (phase,
/// shape-class) and prices iterations under it — never worse than static.
///
/// Shape classes are pow2 ceilings of (batch, seq): decode shapes drift
/// every step as the KV grows, so searching per exact shape would melt the
/// serving loop. One search runs at the class ceiling (the conservative
/// representative) and its winner is reused for every shape in the class.
/// Because a class winner found at the ceiling may not win at every member
/// shape, the *pricing* step re-compares mapped vs static at the actual
/// shape and takes the cheaper one — that comparison, not the search, is
/// what makes the never-lose property hold per iteration, unconditionally.
///
/// Determinism: the search is deterministic per (config, shape-class) and
/// jobs-invariant (see `search`), the class cache is keyed data, and all
/// pricing flows through the memoized, bit-stable `CachedCostModel` — so a
/// serve run under this model is bit-identical across `--jobs` counts.
pub struct AutoMappedCostModel {
    inner: CachedCostModel<System>,
    static_map: Mapping,
    search: SearchConfig,
    rc: RunConfig,
    /// Chosen mapping per (phase, class-batch, class-seq).
    chosen: RefCell<HashMap<(Phase, usize, usize), Mapping>>,
    searches: Cell<u64>,
}

impl AutoMappedCostModel {
    pub fn new(rc: RunConfig) -> Self {
        let search = SearchConfig::from_rc(&rc);
        Self::with_search(rc, search)
    }

    pub fn with_search(rc: RunConfig, search: SearchConfig) -> Self {
        assert_ne!(rc.arch, ArchKind::AttAcc, "AttAcc has no PIM-fabric cost model");
        let static_map = Mapping::static_for(rc.arch);
        Self {
            inner: CachedCostModel::new(System::new(rc.clone())),
            static_map,
            search,
            rc,
            chosen: RefCell::new(HashMap::new()),
            searches: Cell::new(0),
        }
    }

    /// Pow2-ceiling shape class: all of `(batch, seq)` in
    /// `(2^k..=2^(k+1)-1, 2^j..=2^(j+1)-1)`... share one searched mapping.
    pub fn shape_class(batch: usize, seq: usize) -> (usize, usize) {
        (batch.max(1).next_power_of_two(), seq.max(1).next_power_of_two())
    }

    /// Searches actually executed (≤ one per distinct (phase, class)).
    pub fn searches(&self) -> u64 {
        self.searches.get()
    }

    /// Cache counters of the underlying memoizing model.
    pub fn stats(&self) -> CacheStats {
        self.inner.stats()
    }

    /// The mapping serving this shape's class (searched once, then cached).
    pub fn mapping_for(&self, phase: Phase, batch: usize, seq: usize) -> Mapping {
        if search_space_size(&self.rc) <= 1 {
            return self.static_map; // nothing to decide on this variant
        }
        let (cb, cs) = Self::shape_class(batch, seq);
        if let Some(m) = self.chosen.borrow().get(&(phase, cb, cs)) {
            return *m;
        }
        let res = search_phase(&self.rc, phase, cb, cs, &self.search);
        self.searches.set(self.searches.get() + 1);
        self.chosen.borrow_mut().insert((phase, cb, cs), res.mapping);
        res.mapping
    }

    /// Whole-pass total under the class mapping, floored by static at the
    /// *actual* shape (ties go static): the per-iteration never-lose rule.
    fn phase_total_auto(&self, phase: Phase, batch: usize, seq: usize) -> OpCost {
        let m = self.mapping_for(phase, batch, seq);
        let st = self.inner.phase_total(phase, batch, seq);
        if m == self.static_map {
            return st;
        }
        let mt = self.inner.phase_total_mapped(&m, phase, batch, seq);
        if mt.latency_ns < st.latency_ns {
            mt
        } else {
            st
        }
    }
}

impl CostModel for AutoMappedCostModel {
    fn base(&self) -> &RunConfig {
        self.inner.base()
    }

    fn phase_report(&self, phase: Phase, batch: usize, seq_len: usize) -> PhaseReport {
        let m = self.mapping_for(phase, batch, seq_len);
        if m != self.static_map {
            let st = self.inner.phase_total(phase, batch, seq_len);
            let mt = self.inner.phase_total_mapped(&m, phase, batch, seq_len);
            if mt.latency_ns < st.latency_ns {
                return self.inner.phase_report_mapped(&m, phase, batch, seq_len);
            }
        }
        self.inner.phase_report(phase, batch, seq_len)
    }

    fn iteration_cost(&self, prefill_tokens: usize, decode_batch: usize, max_kv: usize) -> OpCost {
        compose_iteration(
            &|phase, batch, seq| self.phase_total_auto(phase, batch, seq),
            prefill_tokens,
            decode_batch,
            max_kv,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::workload::layer_ops;

    fn rc(arch: ArchKind) -> RunConfig {
        RunConfig::new(arch, ModelConfig::llama2_7b())
    }

    #[test]
    fn every_layer_op_folds_onto_a_slot() {
        for model in [ModelConfig::llama2_7b(), ModelConfig::gpt3_175b()] {
            for phase in [Phase::Decode, Phase::Prefill] {
                for op in layer_ops(&model, phase, 4, 256) {
                    let slot = Slot::of_op(&op);
                    assert!(Slot::all().contains(&slot), "{op:?}");
                }
            }
        }
    }

    #[test]
    fn static_mapping_mirrors_capability_flags() {
        for arch in [
            ArchKind::Cent,
            ArchKind::CentCurry,
            ArchKind::CompAirBase,
            ArchKind::CompAirOpt,
            ArchKind::SramStack,
        ] {
            let m = Mapping::static_for(arch);
            assert!(m.is_valid_for(arch), "{arch:?}");
            let fc_want = if arch.has_sram() { Placement::SramPim } else { Placement::DramPim };
            let nl_want = if arch.has_curry() { Placement::NocAlu } else { Placement::Host };
            for s in [Slot::FcQ, Slot::FcKv, Slot::FcO, Slot::FcUp, Slot::FcGate, Slot::FcDown] {
                assert_eq!(m.get(s), fc_want, "{arch:?} {s:?}");
            }
            for s in [Slot::Softmax, Slot::Rope, Slot::RmsNorm, Slot::Activation] {
                assert_eq!(m.get(s), nl_want, "{arch:?} {s:?}");
            }
            assert_eq!(m.get(Slot::AttnQK), Placement::DramPim);
            assert_eq!(m.get(Slot::AttnSV), Placement::DramPim);
            assert_eq!(m.get(Slot::AllReduce), Placement::Host);
        }
    }

    #[test]
    fn nonlinear_ops_never_admit_pim_engines() {
        for arch in ArchKind::all() {
            for slot in [Slot::Softmax, Slot::Rope, Slot::RmsNorm, Slot::Activation] {
                let opts = supported_placements(slot, arch);
                assert!(!opts.contains(&Placement::DramPim), "{arch:?} {slot:?}");
                assert!(!opts.contains(&Placement::SramPim), "{arch:?} {slot:?}");
                assert!(opts.contains(&Placement::Host), "host fallback is universal");
            }
        }
    }

    #[test]
    fn option_lists_lead_with_the_static_choice() {
        for arch in ArchKind::all() {
            let m = Mapping::static_for(arch);
            for slot in Slot::all() {
                assert_eq!(supported_placements(slot, arch)[0], m.get(slot), "{arch:?} {slot:?}");
            }
        }
    }

    #[test]
    fn with_rebinds_one_slot_and_invalid_mappings_are_caught() {
        let m = Mapping::static_for(ArchKind::Cent);
        let bad = m.with(Slot::Softmax, Placement::DramPim);
        assert_eq!(bad.get(Slot::Softmax), Placement::DramPim);
        assert_eq!(bad.get(Slot::FcQ), m.get(Slot::FcQ));
        assert!(!bad.is_valid_for(ArchKind::Cent), "softmax on banks must be invalid");
        // sram placement on a variant without stacked sram is invalid too
        let bad2 = m.with(Slot::FcQ, Placement::SramPim);
        assert!(!bad2.is_valid_for(ArchKind::Cent));
        assert!(m.with(Slot::FcQ, Placement::DramPim).is_valid_for(ArchKind::CompAirOpt));
    }

    #[test]
    fn summary_is_compact_and_slot_ordered() {
        let s = Mapping::static_for(ArchKind::CompAirOpt).summary();
        assert_eq!(s, "fc:SSSSSS attn:DD nl:NNNN coll:H");
        let s = Mapping::static_for(ArchKind::Cent).summary();
        assert_eq!(s, "fc:DDDDDD attn:DD nl:HHHH coll:H");
    }

    #[test]
    fn shape_class_is_pow2_ceiling() {
        assert_eq!(AutoMappedCostModel::shape_class(1, 1), (1, 1));
        assert_eq!(AutoMappedCostModel::shape_class(3, 4097), (4, 8192));
        assert_eq!(AutoMappedCostModel::shape_class(16, 4096), (16, 4096));
        assert_eq!(AutoMappedCostModel::shape_class(0, 0), (1, 1), "degenerate shapes clamp");
    }

    #[test]
    fn auto_model_searches_once_per_shape_class() {
        let cm = AutoMappedCostModel::new(rc(ArchKind::CompAirOpt).with(|c| c.model = ModelConfig::tiny()));
        let _ = cm.iteration_cost(0, 16, 1000);
        let after_first = cm.searches();
        assert!(after_first >= 1);
        // 1001..1024 stays in the (16, 1024) class: no new search
        let _ = cm.iteration_cost(0, 16, 1010);
        assert_eq!(cm.searches(), after_first);
        // crossing the pow2 boundary opens a new class
        let _ = cm.iteration_cost(0, 16, 1030);
        assert_eq!(cm.searches(), after_first + 1);
    }

    #[test]
    fn auto_model_on_searchless_arch_is_static_verbatim() {
        // CENT has a single-candidate space: the auto model must not
        // search at all and must price exactly like the cached static path
        let auto = AutoMappedCostModel::new(rc(ArchKind::Cent));
        let cached = CachedCostModel::new(System::new(rc(ArchKind::Cent)));
        for (pf, db, kv) in [(0usize, 8usize, 2048usize), (256, 0, 0), (128, 4, 512)] {
            assert_eq!(auto.iteration_cost(pf, db, kv), cached.iteration_cost(pf, db, kv));
        }
        assert_eq!(auto.searches(), 0);
        let a = auto.phase_report(Phase::Decode, 8, 2048);
        let b = cached.phase_report(Phase::Decode, 8, 2048);
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
    }

    #[test]
    fn auto_iteration_never_loses_to_static() {
        for arch in [ArchKind::CentCurry, ArchKind::CompAirOpt, ArchKind::SramStack] {
            let base = rc(arch).with(|c| c.model = ModelConfig::tiny());
            let auto = AutoMappedCostModel::new(base.clone());
            let cached = CachedCostModel::new(System::new(base));
            for (pf, db, kv) in [(0usize, 16usize, 2048usize), (512, 0, 0), (256, 8, 1024)] {
                let a = auto.iteration_cost(pf, db, kv).latency_ns;
                let s = cached.iteration_cost(pf, db, kv).latency_ns;
                assert!(a <= s, "{arch:?} pf={pf} db={db} kv={kv}: auto {a} > static {s}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "AttAcc")]
    fn auto_model_rejects_attacc() {
        let _ = AutoMappedCostModel::new(rc(ArchKind::AttAcc));
    }
}
