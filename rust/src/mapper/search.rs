//! Mapping search: exhaustive over small placement spaces, beam otherwise,
//! scored through the real lowering at the configured NoC fidelity.
//!
//! Candidates are whole [`Mapping`]s priced by
//! `System::run_shape_mapped(phase, batch, seq, mapping).latency_ns` — the
//! same code path the chosen mapping will later run under, so the search
//! optimizes exactly what the report measures. Scoring fans out on
//! `util::pool::par_map_indexed` in fixed-size chunks (each worker builds
//! its own `System`; the memoizing tiers are `!Sync` by design), and the
//! chunking is independent of the worker count, so scores — and therefore
//! the chosen mapping — are bit-identical whatever `jobs` is.
//!
//! The never-lose guarantee is structural: the static mapping is always
//! candidate 0, the argmin prefers earlier candidates on ties, and a final
//! clamp returns static outright unless the best candidate is strictly
//! cheaper. Beam search starts *from* the static mapping and keeps it in
//! the scored set, so narrowing the beam can cost optimality but never
//! correctness.

use std::collections::HashMap;

use crate::arch::System;
use crate::config::{ArchKind, Phase, RunConfig};
use crate::util::pool::par_map_indexed;

use super::{supported_placements, Mapping, Placement, Slot};

/// Search policy knobs. Defaults match the CLI: spaces up to
/// `exhaustive_limit` candidates are enumerated outright (every variant in
/// the paper fits — the largest, a gated-FFN model on CompAir, has
/// 2⁶·2⁴ = 1024 candidates), larger spaces fall back to slot-by-slot beam
/// expansion of width `beam_width`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SearchConfig {
    /// Candidates kept per beam round (beam mode only).
    pub beam_width: usize,
    /// Largest placement-space size enumerated exhaustively.
    pub exhaustive_limit: usize,
    /// Worker threads for candidate scoring (result-invariant).
    pub jobs: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        Self { beam_width: 8, exhaustive_limit: 2048, jobs: 1 }
    }
}

impl SearchConfig {
    /// Defaults with the run's worker budget applied.
    pub fn from_rc(rc: &RunConfig) -> Self {
        Self { jobs: rc.jobs.max(1), ..Self::default() }
    }
}

/// Outcome of one mapping search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchResult {
    /// The chosen mapping (== `static_mapping` whenever nothing strictly
    /// beats it).
    pub mapping: Mapping,
    /// Phase latency (ns) under the chosen mapping.
    pub cost_ns: f64,
    /// The hard-coded baseline the search is clamped against.
    pub static_mapping: Mapping,
    /// Phase latency (ns) under the static mapping.
    pub static_cost_ns: f64,
    /// Distinct candidates priced (incl. the static baseline).
    pub candidates_scored: usize,
    /// Total placement-space size for this (arch, model).
    pub space_size: usize,
    /// Whether the whole space was enumerated (vs beam).
    pub exhaustive: bool,
}

/// The slots with more than one legal engine under this config, with their
/// option lists (static choice first), in canonical slot order. A gate
/// projection only exists on gated-FFN models, so it is pinned static
/// elsewhere — searching a slot the op list never emits would only inflate
/// the space.
pub fn decision_slots(rc: &RunConfig) -> Vec<(Slot, Vec<Placement>)> {
    Slot::all()
        .into_iter()
        .filter(|s| !(matches!(s, Slot::FcGate) && !rc.model.gated_ffn))
        .filter_map(|s| {
            let opts = supported_placements(s, rc.arch);
            if opts.len() > 1 {
                Some((s, opts))
            } else {
                None
            }
        })
        .collect()
}

/// Number of distinct legal mappings for this (arch, model): the product
/// of the decision slots' option counts (1 when nothing is searchable).
pub fn search_space_size(rc: &RunConfig) -> usize {
    decision_slots(rc).iter().map(|(_, o)| o.len()).product::<usize>().max(1)
}

/// Scoring chunk size. Fixed — *not* derived from `jobs` — so the
/// (chunk → worker) partition never changes the per-candidate arithmetic
/// and results stay bit-identical across worker counts.
const SCORE_CHUNK: usize = 32;

/// Price each candidate at the shape; element `i` is candidate `i`'s phase
/// latency in ns, in input order, bit-identical whatever `jobs` is.
fn score_candidates(
    rc: &RunConfig,
    phase: Phase,
    batch: usize,
    seq: usize,
    candidates: &[Mapping],
    jobs: usize,
) -> Vec<f64> {
    let chunks: Vec<Vec<Mapping>> =
        candidates.chunks(SCORE_CHUNK).map(|c| c.to_vec()).collect();
    let scored = par_map_indexed(jobs, chunks, |_, chunk| {
        // each worker prices through its own System; keep the nested
        // prefit pool off (the chunk itself is already a pool job)
        let mut wrc = rc.clone();
        wrc.jobs = 1;
        // debug builds re-verify every candidate through the static
        // mapping validator before pricing it: an illegal placement must
        // fail here with a diagnostic, not misprice silently
        #[cfg(debug_assertions)]
        for m in &chunk {
            let diags = crate::analysis::map_check::check_mapping(&wrc, m);
            assert!(
                diags.is_clean(),
                "mapper scored an illegal candidate:\n{}",
                diags.render_brief()
            );
        }
        let sys = System::new(wrc);
        chunk
            .iter()
            .map(|m| sys.run_shape_mapped(phase, batch, seq, m).latency_ns)
            .collect::<Vec<f64>>()
    });
    scored.into_iter().flatten().collect()
}

/// Search the placement space for one phase shape. Deterministic per
/// (config, shape): candidate enumeration and tie-breaking are fixed
/// orders, scoring is jobs-invariant, and the result is clamped to the
/// static baseline — `cost_ns <= static_cost_ns` always, with
/// `mapping == static_mapping` unless something is strictly cheaper.
pub fn search_phase(
    rc: &RunConfig,
    phase: Phase,
    batch: usize,
    seq: usize,
    cfg: &SearchConfig,
) -> SearchResult {
    assert_ne!(rc.arch, ArchKind::AttAcc, "AttAcc has no PIM-fabric mapping space");
    let static_mapping = Mapping::static_for(rc.arch);
    let slots = decision_slots(rc);
    let space_size = search_space_size(rc);
    let jobs = cfg.jobs.max(1);

    let exhaustive = space_size <= cfg.exhaustive_limit.max(1);
    let (best, best_cost, static_cost, scored_n) = if exhaustive {
        // mixed-radix enumeration in slot order; index 0 selects every
        // slot's first option, i.e. exactly the static mapping
        let mut candidates = Vec::with_capacity(space_size);
        for idx in 0..space_size {
            let mut m = static_mapping;
            let mut rest = idx;
            for (slot, opts) in &slots {
                m = m.with(*slot, opts[rest % opts.len()]);
                rest /= opts.len();
            }
            candidates.push(m);
        }
        // up-front legality rejection: the mixed-radix enumeration only
        // emits supported engines, so this is a guard against option-list
        // regressions, never a filter in practice (candidate 0 — the
        // static mapping — is always legal, so index/score alignment and
        // the never-lose baseline are preserved)
        candidates.retain(|m| m.is_valid_for(rc.arch));
        let scores = score_candidates(rc, phase, batch, seq, &candidates, jobs);
        let mut best_i = 0usize;
        for (i, s) in scores.iter().enumerate() {
            // strict '<' keeps the earliest (most-static-like) candidate
            // on ties
            if s.total_cmp(&scores[best_i]) == std::cmp::Ordering::Less {
                best_i = i;
            }
        }
        (candidates[best_i], scores[best_i], scores[0], candidates.len())
    } else {
        // beam: grow slot by slot from the static mapping; undecided slots
        // stay static, so every frontier entry is a complete, scoreable
        // mapping and the static baseline survives every round
        let static_cost = score_candidates(rc, phase, batch, seq, &[static_mapping], jobs)[0];
        let mut scored: HashMap<Mapping, f64> = HashMap::new();
        scored.insert(static_mapping, static_cost);
        let mut beam: Vec<(Mapping, f64)> = vec![(static_mapping, static_cost)];
        for (slot, opts) in &slots {
            let mut frontier: Vec<Mapping> = Vec::new();
            for (m, _) in &beam {
                for &p in opts {
                    let cand = m.with(*slot, p);
                    if cand.is_valid_for(rc.arch)
                        && !scored.contains_key(&cand)
                        && !frontier.contains(&cand)
                    {
                        frontier.push(cand);
                    }
                }
            }
            let fresh = score_candidates(rc, phase, batch, seq, &frontier, jobs);
            for (m, s) in frontier.iter().zip(&fresh) {
                scored.insert(*m, *s);
            }
            let mut pool: Vec<(Mapping, f64)> = beam.clone();
            pool.extend(frontier.into_iter().zip(fresh));
            // stable sort: equal scores keep insertion order (beam
            // survivors, then frontier), so ties resolve deterministically
            pool.sort_by(|a, b| a.1.total_cmp(&b.1));
            pool.truncate(cfg.beam_width.max(1));
            beam = pool;
        }
        let (bm, bc) = beam[0];
        (bm, bc, static_cost, scored.len())
    };

    // never-lose clamp: only a strictly cheaper mapping dethrones static
    if best_cost < static_cost {
        SearchResult {
            mapping: best,
            cost_ns: best_cost,
            static_mapping,
            static_cost_ns: static_cost,
            candidates_scored: scored_n,
            space_size,
            exhaustive,
        }
    } else {
        SearchResult {
            mapping: static_mapping,
            cost_ns: static_cost,
            static_mapping,
            static_cost_ns: static_cost,
            candidates_scored: scored_n,
            space_size,
            exhaustive,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn rc(arch: ArchKind) -> RunConfig {
        RunConfig::new(arch, ModelConfig::tiny())
    }

    #[test]
    fn space_sizes_match_capability_flags() {
        // tiny is gated-FFN: 6 FC slots + 4 nonlinear slots are decidable
        assert_eq!(search_space_size(&rc(ArchKind::Cent)), 1);
        assert_eq!(search_space_size(&rc(ArchKind::CentCurry)), 16);
        assert_eq!(search_space_size(&rc(ArchKind::SramStack)), 64);
        assert_eq!(search_space_size(&rc(ArchKind::CompAirOpt)), 1024);
        // ungated model drops the gate slot
        let mut ungated = rc(ArchKind::CompAirOpt);
        ungated.model = ModelConfig::gpt3_175b();
        assert_eq!(search_space_size(&ungated), 512);
    }

    #[test]
    fn candidate_zero_is_the_static_mapping() {
        let cfg = SearchConfig::default();
        for arch in [ArchKind::CentCurry, ArchKind::CompAirOpt, ArchKind::SramStack] {
            let res = search_phase(&rc(arch), Phase::Decode, 8, 512, &cfg);
            assert!(res.exhaustive);
            assert_eq!(res.space_size, res.candidates_scored);
            assert_eq!(res.static_mapping, Mapping::static_for(arch));
            assert!(res.cost_ns <= res.static_cost_ns, "{arch:?}");
            assert!(res.mapping.is_valid_for(arch), "{arch:?}");
        }
    }

    #[test]
    fn searchless_space_returns_static_immediately() {
        let res = search_phase(&rc(ArchKind::Cent), Phase::Decode, 4, 256, &SearchConfig::default());
        assert_eq!(res.mapping, Mapping::static_for(ArchKind::Cent));
        assert_eq!(res.space_size, 1);
        assert_eq!(res.cost_ns.to_bits(), res.static_cost_ns.to_bits());
    }

    #[test]
    fn scores_are_jobs_invariant() {
        for arch in [ArchKind::CompAirOpt, ArchKind::SramStack] {
            let base = search_phase(
                &rc(arch),
                Phase::Decode,
                16,
                1024,
                &SearchConfig { jobs: 1, ..SearchConfig::default() },
            );
            for jobs in [2usize, 4] {
                let got = search_phase(
                    &rc(arch),
                    Phase::Decode,
                    16,
                    1024,
                    &SearchConfig { jobs, ..SearchConfig::default() },
                );
                assert_eq!(got.mapping, base.mapping, "{arch:?} jobs={jobs}");
                assert_eq!(got.cost_ns.to_bits(), base.cost_ns.to_bits(), "{arch:?} jobs={jobs}");
                assert_eq!(
                    got.static_cost_ns.to_bits(),
                    base.static_cost_ns.to_bits(),
                    "{arch:?} jobs={jobs}"
                );
            }
        }
    }

    #[test]
    fn beam_mode_still_never_loses() {
        // force beam by shrinking the exhaustive limit below the space
        let cfg = SearchConfig { beam_width: 2, exhaustive_limit: 1, jobs: 1 };
        for arch in [ArchKind::CentCurry, ArchKind::CompAirOpt] {
            let res = search_phase(&rc(arch), Phase::Decode, 8, 512, &cfg);
            assert!(!res.exhaustive, "{arch:?}");
            assert!(res.cost_ns <= res.static_cost_ns, "{arch:?}");
            assert!(res.mapping.is_valid_for(arch), "{arch:?}");
        }
    }

    #[test]
    fn wide_beam_matches_exhaustive_on_a_small_space() {
        // with the beam wide enough to retain every partial assignment,
        // slot-by-slot expansion enumerates the full product space and
        // must land on the exhaustive winner
        let exh = search_phase(
            &rc(ArchKind::SramStack),
            Phase::Decode,
            8,
            512,
            &SearchConfig::default(),
        );
        let beam = search_phase(
            &rc(ArchKind::SramStack),
            Phase::Decode,
            8,
            512,
            &SearchConfig { beam_width: 4096, exhaustive_limit: 1, jobs: 1 },
        );
        assert!(!beam.exhaustive);
        assert_eq!(beam.mapping, exh.mapping);
        assert_eq!(beam.cost_ns.to_bits(), exh.cost_ns.to_bits());
    }

    #[test]
    fn beam_scores_are_jobs_invariant_too() {
        let mk = |jobs| {
            search_phase(
                &rc(ArchKind::CompAirOpt),
                Phase::Prefill,
                1,
                256,
                &SearchConfig { beam_width: 4, exhaustive_limit: 1, jobs },
            )
        };
        let a = mk(1);
        let b = mk(4);
        assert_eq!(a.mapping, b.mapping);
        assert_eq!(a.cost_ns.to_bits(), b.cost_ns.to_bits());
        assert_eq!(a.candidates_scored, b.candidates_scored);
    }

    #[test]
    #[should_panic(expected = "AttAcc")]
    fn attacc_has_no_mapping_space() {
        let _ = search_phase(&rc(ArchKind::AttAcc), Phase::Decode, 1, 64, &SearchConfig::default());
    }
}
