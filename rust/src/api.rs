//! The `Engine` facade: one typed entry point for every evaluation mode.
//!
//! The four harnesses the repo grew — one-shot simulation
//! (`arch::simulate`), the figure tables, the serving loop, and the
//! cluster coordinator — used to each re-plumb `RunConfig → System →
//! report` by hand. `Engine` owns that plumbing once: construct it from a
//! [`RunConfig`] and ask for the lens you want.
//!
//! ```no_run
//! use compair::config::{ArchKind, ModelConfig, RunConfig};
//! use compair::coordinator::{ClusterConfig, ServeConfig};
//! use compair::Engine;
//!
//! let rc = RunConfig::new(ArchKind::CompAirOpt, ModelConfig::llama2_7b());
//! let engine = Engine::new(rc);
//! let phase = engine.simulate();                     // one-shot phase report
//! let serve = engine.serve(ServeConfig::default());  // SLO-aware serving sim
//! let cluster = engine.cluster(ServeConfig::default(), ClusterConfig::default());
//! # let _ = (phase, serve, cluster);
//! ```
//!
//! Every report the facade returns implements
//! [`ToJson`](crate::util::json::ToJson), which is what the CLI's
//! `--format json` renders. Under the hood the serving and cluster paths
//! drive a [`CachedCostModel`] (see `arch/cost_model.rs`), so repeated
//! iteration shapes are memoized instead of re-lowering the op-graph.
//!
//! NoC collective costs are priced at the fidelity the run config selects
//! (`rc.noc_fidelity`, see `noc::model`): analytic closed forms,
//! simulator-calibrated forms, or the flit-level mesh itself. Pick a tier
//! with the builder, e.g.
//! `Engine::new(rc).with(|rc| rc.noc_fidelity = NocFidelity::Calibrated)`;
//! the fidelity is part of every memoization key, so cached results never
//! mix tiers.

use crate::analysis::{audit, audit_lattice, config_check, map_check, prove, CheckReport};
use crate::arch::{attacc, AttAccConfig, CachedCostModel, PhaseReport, System};
use crate::config::{ArchKind, MappingMode, RunConfig};
use crate::coordinator::{
    Cluster, ClusterConfig, ClusterReport, ClusterScenarioReport, ScenarioReport, ServeConfig,
    ServeReport, Server,
};
use crate::mapper::{search_phase, Mapping, SearchConfig, SearchResult};
use crate::workload::Scenario;

/// One architecture/model/fabric point, evaluated under any lens.
#[derive(Debug, Clone)]
pub struct Engine {
    rc: RunConfig,
}

impl Engine {
    pub fn new(rc: RunConfig) -> Self {
        Self { rc }
    }

    /// Builder-style tweak of the underlying run configuration.
    pub fn with(mut self, f: impl FnOnce(&mut RunConfig)) -> Self {
        f(&mut self.rc);
        self
    }

    /// The run configuration this engine evaluates.
    pub fn rc(&self) -> &RunConfig {
        &self.rc
    }

    /// Statically verify this point without executing anything: the
    /// config consistency pass over `rc`, plus — for the PIM variants —
    /// the mapping validator over the placement the run would actually
    /// use (the paper's static assignment; `mapping = auto` candidates
    /// are checked inside the search itself). The AttAcc roofline has no
    /// mapping space, so it gets the config pass only. Returns a
    /// normalized [`CheckReport`]; `compair check` and the CI gate call
    /// this per (arch, model) point.
    pub fn check(&self) -> CheckReport {
        let mut rep = config_check::check_run(&self.rc);
        if self.rc.arch != ArchKind::AttAcc {
            rep.extend(map_check::check_mapping(&self.rc, &Mapping::static_for(self.rc.arch)));
        }
        rep.normalize();
        rep
    }

    /// Semantically audit this point: report sanity, op/energy
    /// conservation, cache coherence, and — per mapping mode —
    /// monotonicity or the never-lose re-proof, all at the standard shape
    /// anchors (see `analysis::audit`). Complements [`Engine::check`]:
    /// `check` proves the *inputs* are legal, `audit` proves the *numbers*
    /// obey the physics. Returns a normalized [`CheckReport`] with
    /// `aud.*` codes; `compair audit` fans the full lattice through the
    /// same pass.
    pub fn audit(&self) -> CheckReport {
        let point = audit_lattice::AuditPoint {
            arch: self.rc.arch,
            model: self.rc.model.clone(),
            fidelity: self.rc.noc_fidelity,
            mapping: self.rc.mapping,
        };
        audit::audit_point(&point, &audit::AuditOptions::default())
    }

    /// Statically *prove* this point over its whole shape box: capture
    /// the cost pipeline as a unit-checked expression IR and certify
    /// unit consistency, monotonicity, overflow headroom, interval
    /// bounds and energy-pricing coverage compositionally (see
    /// `analysis::prove`). Completes the three-tier story: `check`
    /// proves the inputs are legal, `audit` samples the physics at
    /// anchor shapes, `prove` certifies the closed forms for *every*
    /// shape in the box. Simulated-fidelity points and the AttAcc
    /// roofline have no closed-form IR, so they get the point-independent
    /// pricing-coverage proof only. Returns a normalized [`CheckReport`]
    /// with `prv.*` codes; `compair prove` fans the full lattice through
    /// the same pass.
    pub fn prove(&self) -> CheckReport {
        use crate::config::{NocFidelity, Phase};
        let mut rep = prove::check_global();
        if self.rc.arch != ArchKind::AttAcc && self.rc.noc_fidelity != NocFidelity::Simulated {
            for phase in [Phase::Decode, Phase::Prefill] {
                let point = prove::ProvePoint {
                    arch: self.rc.arch,
                    model: self.rc.model.clone(),
                    fidelity: self.rc.noc_fidelity,
                    phase,
                };
                let (point_rep, _summary) = prove::prove_point(&point);
                rep.extend(point_rep);
            }
        }
        rep.normalize();
        rep
    }

    /// A fresh, independent memoizing cost model over this configuration.
    /// (The serving/cluster paths construct their own equivalent cache per
    /// run — this one is for callers driving `CostModel` directly, e.g.
    /// `run_with_model` or shape sweeps.) Panics for [`ArchKind::AttAcc`]
    /// (own roofline simulator; a silent PIM-fabric answer would be
    /// plausible-looking but wrong).
    pub fn cost_model(&self) -> CachedCostModel<System> {
        assert_ne!(self.rc.arch, ArchKind::AttAcc, "AttAcc has no PIM-fabric cost model");
        CachedCostModel::new(System::new(self.rc.clone()))
    }

    /// One-shot simulation of the configured phase. Unlike the legacy
    /// `arch::simulate`, this dispatches every architecture variant,
    /// including the AttAcc roofline baseline. With `rc.mapping = auto`
    /// the PIM variants search operator placement first and report the
    /// phase under the winner (never worse than static — see `mapper`);
    /// the AttAcc roofline has no mapping space and ignores the knob.
    pub fn simulate(&self) -> PhaseReport {
        match self.rc.arch {
            ArchKind::AttAcc => attacc::simulate(&self.rc, &AttAccConfig::default()),
            _ => match self.rc.mapping {
                MappingMode::Static => System::new(self.rc.clone()).run(),
                MappingMode::Auto => {
                    let res = self.search_mapping();
                    System::new(self.rc.clone()).run_shape_mapped(
                        self.rc.phase,
                        self.rc.batch,
                        self.rc.seq_len,
                        &res.mapping,
                    )
                }
            },
        }
    }

    /// One-shot simulation under an explicit operator mapping (must be
    /// legal for the configured variant). Panics for [`ArchKind::AttAcc`]
    /// (no PIM-fabric mapping space).
    pub fn simulate_mapped(&self, m: &Mapping) -> PhaseReport {
        assert_ne!(self.rc.arch, ArchKind::AttAcc, "AttAcc has no PIM-fabric mapping space");
        assert!(
            m.is_valid_for(self.rc.arch),
            "mapping {} is invalid for {:?}",
            m.summary(),
            self.rc.arch
        );
        System::new(self.rc.clone()).run_shape_mapped(self.rc.phase, self.rc.batch, self.rc.seq_len, m)
    }

    /// Search operator placement for the configured phase shape (scored
    /// with `rc.jobs` workers; result is jobs-invariant). Panics for
    /// [`ArchKind::AttAcc`].
    pub fn search_mapping(&self) -> SearchResult {
        assert_ne!(self.rc.arch, ArchKind::AttAcc, "AttAcc has no PIM-fabric mapping space");
        search_phase(
            &self.rc,
            self.rc.phase,
            self.rc.batch,
            self.rc.seq_len,
            &SearchConfig::from_rc(&self.rc),
        )
    }

    /// Continuous-batching serving simulation on this hardware point.
    /// Panics for [`ArchKind::AttAcc`]: the roofline baseline has no
    /// PIM-fabric serving model, so a silent CENT-shaped answer would be
    /// plausible-looking but wrong.
    pub fn serve(&self, cfg: ServeConfig) -> ServeReport {
        assert_ne!(self.rc.arch, ArchKind::AttAcc, "AttAcc has no serving model");
        Server::new(self.rc.clone(), cfg).run()
    }

    /// Serve one named scenario end to end (labels the report with the
    /// scenario/arch/model triple). Panics for [`ArchKind::AttAcc`]
    /// (see [`Engine::serve`]).
    pub fn serve_scenario(&self, sc: Scenario, n_requests: usize, seed: u64) -> ScenarioReport {
        assert_ne!(self.rc.arch, ArchKind::AttAcc, "AttAcc has no serving model");
        crate::coordinator::run_scenario(self.rc.clone(), sc, n_requests, seed)
    }

    /// Multi-replica serving over the modeled CXL fabric. Panics for
    /// [`ArchKind::AttAcc`] (see [`Engine::serve`]).
    pub fn cluster(&self, serve: ServeConfig, cfg: ClusterConfig) -> ClusterReport {
        assert_ne!(self.rc.arch, ArchKind::AttAcc, "AttAcc has no serving model");
        Cluster::new(self.rc.clone(), serve, cfg).run()
    }

    /// Batch-evaluate a sweep of configurations on up to `jobs` worker
    /// threads, returning one one-shot [`PhaseReport`] per config **in
    /// input order** — element `i` is exactly
    /// `Engine::new(configs[i]).simulate()`, bit-identical whatever `jobs`
    /// is (see `util::pool`). Each job builds its own `System` (the
    /// memoizing models are deliberately `!Sync`), so jobs share nothing
    /// but the configs. This is the batch face of the facade: the figure
    /// sweeps and the `simulate --sweep-*` CLI paths fan out through it.
    pub fn sweep(configs: Vec<RunConfig>, jobs: usize) -> Vec<PhaseReport> {
        crate::util::pool::par_map_indexed(jobs, configs, |_, rc| Engine::new(rc).simulate())
    }

    /// Cluster-serve one named scenario (labelled, for the figure tables).
    /// Panics for [`ArchKind::AttAcc`] (see [`Engine::serve`]).
    pub fn cluster_scenario(
        &self,
        scenario: Scenario,
        n_requests: usize,
        seed: u64,
        cfg: ClusterConfig,
    ) -> ClusterScenarioReport {
        assert_ne!(self.rc.arch, ArchKind::AttAcc, "AttAcc has no serving model");
        crate::coordinator::run_cluster_scenario(self.rc.clone(), scenario, n_requests, seed, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CostModel;
    use crate::config::ModelConfig;

    fn rc(arch: ArchKind) -> RunConfig {
        let mut rc = RunConfig::new(arch, ModelConfig::llama2_7b());
        rc.tp = 8;
        rc.devices = 32;
        rc
    }

    #[test]
    fn simulate_covers_every_arch_kind() {
        for arch in [
            ArchKind::Cent,
            ArchKind::CentCurry,
            ArchKind::CompAirBase,
            ArchKind::CompAirOpt,
            ArchKind::SramStack,
            ArchKind::AttAcc,
        ] {
            let r = Engine::new(rc(arch)).simulate();
            assert!(r.latency_ns > 0.0, "{arch:?} produced no latency");
            assert!(r.throughput_tok_s > 0.0, "{arch:?} produced no throughput");
        }
    }

    #[test]
    fn with_tweaks_the_config() {
        let e = Engine::new(rc(ArchKind::CompAirOpt)).with(|rc| rc.batch = 64);
        assert_eq!(e.rc().batch, 64);
    }

    #[test]
    fn cost_model_matches_simulate() {
        let e = Engine::new(rc(ArchKind::CompAirOpt));
        let cm = e.cost_model();
        let a = e.simulate();
        let b = cm.phase_report(e.rc().phase, e.rc().batch, e.rc().seq_len);
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
    }

    #[test]
    fn fidelity_knob_threads_through_the_facade() {
        use crate::config::NocFidelity;
        for f in NocFidelity::all() {
            let e = Engine::new(rc(ArchKind::CompAirOpt)).with(|rc| rc.noc_fidelity = f);
            assert_eq!(e.rc().noc_fidelity, f);
            let r = e.simulate();
            assert!(r.latency_ns > 0.0 && r.latency_ns.is_finite(), "{f:?}");
            // the cost model inherits the tier and reproduces the facade
            let cm = e.cost_model();
            let b = cm.phase_report(e.rc().phase, e.rc().batch, e.rc().seq_len);
            assert_eq!(r.latency_ns.to_bits(), b.latency_ns.to_bits(), "{f:?}");
        }
    }

    #[test]
    fn sweep_matches_a_serial_loop_bit_for_bit() {
        let mut configs = Vec::new();
        for arch in [ArchKind::Cent, ArchKind::CompAirBase, ArchKind::CompAirOpt, ArchKind::AttAcc]
        {
            for batch in [1usize, 16] {
                let mut c = rc(arch);
                c.batch = batch;
                configs.push(c);
            }
        }
        let serial: Vec<_> = configs.iter().map(|c| Engine::new(c.clone()).simulate()).collect();
        for jobs in [1usize, 4] {
            let swept = Engine::sweep(configs.clone(), jobs);
            assert_eq!(swept.len(), serial.len());
            for (i, (a, b)) in serial.iter().zip(&swept).enumerate() {
                assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits(), "jobs={jobs} i={i}");
                assert_eq!(
                    a.throughput_tok_s.to_bits(),
                    b.throughput_tok_s.to_bits(),
                    "jobs={jobs} i={i}"
                );
                assert_eq!(a.layer_cost, b.layer_cost, "jobs={jobs} i={i}");
            }
        }
    }

    #[test]
    fn simulate_mapped_with_static_mapping_equals_simulate() {
        use crate::mapper::Mapping;
        let e = Engine::new(rc(ArchKind::CompAirOpt));
        let a = e.simulate();
        let b = e.simulate_mapped(&Mapping::static_for(ArchKind::CompAirOpt));
        assert_eq!(a.latency_ns.to_bits(), b.latency_ns.to_bits());
        assert_eq!(a.layer_cost, b.layer_cost);
    }

    #[test]
    fn auto_mapping_simulate_never_loses() {
        use crate::config::MappingMode;
        for arch in [ArchKind::CentCurry, ArchKind::CompAirOpt, ArchKind::SramStack] {
            let mut c = rc(arch);
            c.model = ModelConfig::tiny();
            c.batch = 16;
            let static_lat = Engine::new(c.clone()).simulate().latency_ns;
            c.mapping = MappingMode::Auto;
            let auto_lat = Engine::new(c).simulate().latency_ns;
            assert!(auto_lat <= static_lat, "{arch:?}: auto {auto_lat} > static {static_lat}");
        }
    }

    #[test]
    fn search_mapping_matches_simulate_auto() {
        use crate::config::MappingMode;
        let mut c = rc(ArchKind::CompAirOpt);
        c.model = ModelConfig::tiny();
        let e = Engine::new(c.clone());
        let res = e.search_mapping();
        assert!(res.cost_ns <= res.static_cost_ns);
        let direct = e.simulate_mapped(&res.mapping);
        assert_eq!(direct.latency_ns.to_bits(), res.cost_ns.to_bits());
        c.mapping = MappingMode::Auto;
        let auto = Engine::new(c).simulate();
        assert_eq!(auto.latency_ns.to_bits(), res.cost_ns.to_bits());
    }

    #[test]
    fn sweep_carries_the_mapping_knob() {
        use crate::config::MappingMode;
        let mut auto_c = rc(ArchKind::CompAirOpt);
        auto_c.model = ModelConfig::tiny();
        auto_c.mapping = MappingMode::Auto;
        let mut static_c = auto_c.clone();
        static_c.mapping = MappingMode::Static;
        let swept = Engine::sweep(vec![static_c.clone(), auto_c.clone()], 2);
        assert_eq!(swept[0].latency_ns.to_bits(), Engine::new(static_c).simulate().latency_ns.to_bits());
        assert_eq!(swept[1].latency_ns.to_bits(), Engine::new(auto_c).simulate().latency_ns.to_bits());
        assert!(swept[1].latency_ns <= swept[0].latency_ns);
    }

    #[test]
    #[should_panic(expected = "mapping space")]
    fn simulate_mapped_rejects_attacc() {
        use crate::mapper::Mapping;
        let _ = Engine::new(rc(ArchKind::AttAcc))
            .simulate_mapped(&Mapping::static_for(ArchKind::Cent));
    }

    #[test]
    fn check_passes_every_arch_on_the_default_point() {
        for arch in ArchKind::all() {
            let rep = Engine::new(rc(arch)).check();
            assert!(rep.is_clean(), "{arch:?}:\n{}", rep.render_brief());
        }
    }

    #[test]
    fn audit_passes_the_default_compair_point() {
        let mut c = rc(ArchKind::CompAirOpt);
        c.model = ModelConfig::tiny();
        let rep = Engine::new(c).audit();
        assert!(rep.is_clean(), "{}", rep.render_brief());
    }

    #[test]
    fn prove_passes_the_default_compair_point() {
        let mut c = rc(ArchKind::CompAirOpt);
        c.model = ModelConfig::tiny();
        let rep = Engine::new(c).prove();
        assert_eq!(rep.errors(), 0, "{}", rep.render_brief());
    }

    #[test]
    fn prove_degrades_to_global_proofs_for_attacc() {
        // no System lowering -> only the point-independent pricing pass
        let rep = Engine::new(rc(ArchKind::AttAcc)).prove();
        assert!(rep.is_clean(), "{}", rep.render_brief());
    }

    #[test]
    fn check_flags_a_broken_config() {
        let mut c = rc(ArchKind::CompAirOpt);
        c.tp = 5; // does not divide 32 devices
        let rep = Engine::new(c).check();
        assert!(rep.has_code("cfg.tp-remainder"), "{}", rep.render_brief());
    }

    #[test]
    fn serve_and_cluster_run_through_the_facade() {
        let e = Engine::new(rc(ArchKind::CompAirOpt));
        let cfg = ServeConfig { n_requests: 8, prompt_len: 64, gen_len: 4, ..Default::default() };
        let s = e.serve(cfg.clone());
        assert_eq!(s.completed, 8);
        let c = e.cluster(cfg, ClusterConfig { replicas: 2, ..Default::default() });
        assert_eq!(c.report.completed, 8);
        assert_eq!(c.per_replica.len(), 2);
    }
}
