//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only consumer of its output and the only place numerics execute at
//! request time. Interchange is HLO *text* (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids the crate's xla_extension 0.5.1
//! rejects, while the text parser reassigns ids cleanly.
//!
//! The PJRT backend is feature-gated: without the `pjrt` feature (the
//! offline default — the `xla` binding crate cannot be fetched in
//! air-gapped environments) this module compiles to a stub with the same
//! API whose constructors return a clean, documented error. The
//! cross-layer tests in `tests/integration_runtime.rs` detect that error
//! and skip with a message instead of failing.

use std::path::PathBuf;

/// Error type shared by the real and stub backends, so callers are
/// feature-independent.
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Runtime-layer result alias (used by examples' `main` signatures too).
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// The artifacts directory (override with COMPAIR_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("COMPAIR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// An f32 tensor travelling in/out of the runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Row-major element data; `data.len()` equals the product of `dims`.
    pub data: Vec<f32>,
    /// Dimension sizes (empty for a scalar).
    pub dims: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: &[usize]) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>(), "shape/len mismatch");
        Self { data, dims: dims.to_vec() }
    }

    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], dims: vec![] }
    }
}

pub use backend::{LoadedModel, Runtime};

/// Real PJRT execution through the `xla` binding crate.
#[cfg(feature = "pjrt")]
mod backend {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use super::{artifacts_dir, Result, RuntimeError, Tensor};

    fn rerr(ctx: &str, e: impl std::fmt::Display) -> RuntimeError {
        RuntimeError(format!("{ctx}: {e}"))
    }

    /// A loaded, compiled computation.
    pub struct LoadedModel {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    fn to_literal(t: &Tensor) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&t.data);
        let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).map_err(|e| rerr("reshaping input literal", e))
    }

    impl LoadedModel {
        /// Execute with f32 inputs; returns all tuple outputs as f32 tensors.
        pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let lits: Vec<xla::Literal> =
                inputs.iter().map(to_literal).collect::<Result<_>>()?;
            self.run_literals(lits)
        }

        /// Execute with f32 tensors plus one trailing i32 scalar (the decode
        /// step's `pos` argument).
        pub fn run_with_i32_scalar(&self, inputs: &[Tensor], scalar: i32) -> Result<Vec<Tensor>> {
            let mut lits: Vec<xla::Literal> =
                inputs.iter().map(to_literal).collect::<Result<_>>()?;
            lits.push(xla::Literal::scalar(scalar));
            self.run_literals(lits)
        }

        fn run_literals(&self, lits: Vec<xla::Literal>) -> Result<Vec<Tensor>> {
            let result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| rerr("executing computation", e))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| rerr("fetching result literal", e))?;
            let parts = out.to_tuple().map_err(|e| rerr("untupling result", e))?;
            parts
                .into_iter()
                .map(|lit| {
                    let shape = lit.array_shape().map_err(|e| rerr("reading shape", e))?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit.to_vec::<f32>().map_err(|e| rerr("reading data", e))?;
                    Ok(Tensor { data, dims })
                })
                .collect()
        }
    }

    /// The PJRT runtime with a model cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        cache: HashMap<String, LoadedModel>,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| rerr("creating PJRT CPU client", e))?;
            Ok(Self { client, dir: artifacts_dir(), cache: HashMap::new() })
        }

        pub fn with_dir(dir: &Path) -> Result<Self> {
            let mut rt = Self::cpu()?;
            rt.dir = dir.to_path_buf();
            Ok(rt)
        }

        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.dir.join(format!("{name}.hlo.txt"))
        }

        /// Load (compile) an artifact by name, e.g. "decode_step".
        pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
            if !self.cache.contains_key(name) {
                let path = self.artifact_path(name);
                if !path.exists() {
                    return Err(RuntimeError(format!(
                        "artifact '{}' not found at {} — run `make artifacts` first",
                        name,
                        path.display()
                    )));
                }
                let path_str = path
                    .to_str()
                    .ok_or_else(|| RuntimeError("non-utf8 artifact path".into()))?;
                let proto = xla::HloModuleProto::from_text_file(path_str)
                    .map_err(|e| rerr(&format!("parsing HLO text for '{name}'"), e))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| rerr(&format!("compiling '{name}'"), e))?;
                self.cache
                    .insert(name.to_string(), LoadedModel { name: name.to_string(), exe });
            }
            Ok(&self.cache[name])
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }
}

/// Stub backend: same API, every execution path errors with a documented
/// skip message so callers (and tests) can detect and skip cleanly.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use std::path::{Path, PathBuf};

    use super::{Result, RuntimeError, Tensor};

    const MSG: &str = "PJRT runtime unavailable: built without the `pjrt` feature. \
Enable it with `cargo build --features pjrt` (requires a vendored `xla` binding \
crate — see rust/Cargo.toml) and build the artifacts with `make artifacts`.";

    /// Stub of the compiled-model handle; all execution paths error.
    pub struct LoadedModel {
        pub name: String,
    }

    impl LoadedModel {
        pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(RuntimeError(MSG.into()))
        }

        pub fn run_with_i32_scalar(
            &self,
            _inputs: &[Tensor],
            _scalar: i32,
        ) -> Result<Vec<Tensor>> {
            Err(RuntimeError(MSG.into()))
        }
    }

    /// Stub runtime: construction fails with the skip message.
    pub struct Runtime {
        dir: PathBuf,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Err(RuntimeError(MSG.into()))
        }

        pub fn with_dir(_dir: &Path) -> Result<Self> {
            Err(RuntimeError(MSG.into()))
        }

        pub fn artifact_path(&self, name: &str) -> PathBuf {
            self.dir.join(format!("{name}.hlo.txt"))
        }

        pub fn load(&mut self, _name: &str) -> Result<&LoadedModel> {
            Err(RuntimeError(MSG.into()))
        }

        pub fn platform(&self) -> String {
            "stub (no pjrt feature)".into()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.dims, vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "shape/len mismatch")]
    fn tensor_bad_shape_panics() {
        Tensor::new(vec![1.0; 3], &[2, 2]);
    }

    #[test]
    fn scalar_tensor_has_no_dims() {
        let t = Tensor::scalar(3.5);
        assert!(t.dims.is_empty());
        assert_eq!(t.data, vec![3.5]);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let mut rt = match Runtime::cpu() {
            Ok(r) => r,
            Err(e) => {
                // stub build: the skip message must be self-documenting
                assert!(e.to_string().contains("pjrt"), "unhelpful stub error: {e}");
                return;
            }
        };
        let err = match rt.load("definitely_not_there") {
            Err(e) => e,
            Ok(_) => panic!("expected a missing-artifact error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
