//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only consumer of its output and the only place numerics execute at
//! request time. Interchange is HLO *text* (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids the crate's xla_extension 0.5.1
//! rejects, while the text parser reassigns ids cleanly.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// The artifacts directory (override with COMPAIR_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("COMPAIR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A loaded, compiled computation.
pub struct LoadedModel {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// An f32 tensor travelling in/out of the runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: &[usize]) -> Self {
        assert_eq!(data.len(), dims.iter().product::<usize>(), "shape/len mismatch");
        Self { data, dims: dims.to_vec() }
    }

    pub fn scalar(v: f32) -> Self {
        Self { data: vec![v], dims: vec![] }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

impl LoadedModel {
    /// Execute with f32 inputs; returns all tuple outputs as f32 tensors.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.run_literals(lits)
    }

    /// Execute with f32 tensors plus one trailing i32 scalar (the decode
    /// step's `pos` argument).
    pub fn run_with_i32_scalar(&self, inputs: &[Tensor], scalar: i32) -> Result<Vec<Tensor>> {
        let mut lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        lits.push(xla::Literal::scalar(scalar));
        self.run_literals(lits)
    }

    fn run_literals(&self, lits: Vec<xla::Literal>) -> Result<Vec<Tensor>> {
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let out = result[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape()?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>()?;
                Ok(Tensor { data, dims })
            })
            .collect()
    }
}

/// The PJRT runtime with a model cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, LoadedModel>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir: artifacts_dir(), cache: HashMap::new() })
    }

    pub fn with_dir(dir: &Path) -> Result<Self> {
        let mut rt = Self::cpu()?;
        rt.dir = dir.to_path_buf();
        Ok(rt)
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load (compile) an artifact by name, e.g. "decode_step".
    pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
        if !self.cache.contains_key(name) {
            let path = self.artifact_path(name);
            if !path.exists() {
                bail!(
                    "artifact '{}' not found at {} — run `make artifacts` first",
                    name,
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text for '{name}'"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling '{name}'"))?;
            self.cache.insert(name.to_string(), LoadedModel { name: name.to_string(), exe });
        }
        Ok(&self.cache[name])
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.dims, vec![2, 2]);
    }

    #[test]
    #[should_panic(expected = "shape/len mismatch")]
    fn tensor_bad_shape_panics() {
        Tensor::new(vec![1.0; 3], &[2, 2]);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let mut rt = match Runtime::cpu() {
            Ok(r) => r,
            Err(_) => return, // no PJRT in this environment — skip
        };
        let err = match rt.load("definitely_not_there") {
            Err(e) => e,
            Ok(_) => panic!("expected a missing-artifact error"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
