//! Area and FPGA-resource model for CompAir-NoC (paper Fig 21).
//!
//! The paper synthesizes the router RTL with Synopsys DC on UMC 28nm and
//! reports: SRAM-PIM + routers of one bank occupy 0.8195 mm² (under the
//! ~1 mm² DRAM bank), with the Curry ALUs costing only 2.94% of the router.
//! We encode those published component areas; the Fig 21B FPGA comparison
//! (4 Curry ALUs vs a dedicated 16-input Softmax unit) is encoded from the
//! same ratio family: stream processing needs far fewer buffers.

/// 28nm areas in mm².
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// One 8KB SRAM-PIM macro [Chen+ ISSCC'25]: 0.136 mm².
    pub sram_macro_mm2: f64,
    /// One SWIFT-class router (5-port, 72b flits, 4-deep queues).
    pub router_mm2: f64,
    /// Curry ALU fraction of the router (2 ALUs): 2.94%.
    pub curry_fraction: f64,
    /// DRAM-PIM bank footprint (1ynm, 32MB): ~1 mm².
    pub dram_bank_mm2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            sram_macro_mm2: 0.136,
            // back-solved from the paper's 0.8195 mm² per-bank logic total:
            // (0.8195 − 4×0.136) / 4 routers
            router_mm2: (0.8195 - 4.0 * 0.136) / 4.0,
            curry_fraction: 0.0294,
            dram_bank_mm2: 1.0,
        }
    }
}

impl AreaModel {
    /// Logic-die area under one DRAM bank: 4 macros + 4 routers.
    pub fn bank_logic_mm2(&self) -> f64 {
        4.0 * self.sram_macro_mm2 + 4.0 * self.router_mm2
    }

    /// Area of the Curry ALUs in one router.
    pub fn curry_alu_mm2(&self) -> f64 {
        self.router_mm2 * self.curry_fraction
    }

    /// Does the logic die fit under the DRAM bank (3D stacking feasibility)?
    pub fn fits_under_bank(&self) -> bool {
        self.bank_logic_mm2() <= self.dram_bank_mm2
    }

    /// Extra bond area for the decoupled column decoder (§3.4: "just 10%
    /// area of one DRAM bank").
    pub fn decoupled_decoder_overhead_mm2(&self) -> f64 {
        0.10 * self.dram_bank_mm2
    }
}

/// FPGA synthesis resources (Fig 21B): four Curry ALUs vs one dedicated
/// 16-input Softmax unit.
#[derive(Debug, Clone, Copy)]
pub struct FpgaResources {
    pub luts: u64,
    pub ffs: u64,
    pub bram_kb: u64,
}

/// Four Curry ALUs (BF16 add+mul+div each, stream processing, no buffers).
pub fn curry_alus_resources() -> FpgaResources {
    FpgaResources { luts: 2_210, ffs: 1_480, bram_kb: 0 }
}

/// A customised 16-input Softmax hardware unit: exp LUT pipelines, adder
/// tree, normalization dividers, and input/output buffering.
pub fn softmax_unit_resources() -> FpgaResources {
    FpgaResources { luts: 9_840, ffs: 7_120, bram_kb: 36 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bank_logic_area() {
        let a = AreaModel::default();
        assert!((a.bank_logic_mm2() - 0.8195).abs() < 1e-9);
        assert!(a.fits_under_bank());
    }

    #[test]
    fn curry_alu_is_tiny() {
        let a = AreaModel::default();
        assert!(a.curry_alu_mm2() < 0.003);
        assert!((a.curry_alu_mm2() / a.router_mm2 - 0.0294).abs() < 1e-9);
    }

    #[test]
    fn curry_beats_dedicated_softmax_unit() {
        let c = curry_alus_resources();
        let s = softmax_unit_resources();
        assert!(c.luts * 4 < s.luts, "Curry ALUs must use ≥4x fewer LUTs");
        assert!(c.bram_kb == 0 && s.bram_kb > 0, "stream processing avoids buffer BRAM");
    }

    #[test]
    fn decoder_overhead_within_bond_budget() {
        let a = AreaModel::default();
        assert!(a.decoupled_decoder_overhead_mm2() <= 0.1 * a.dram_bank_mm2 + 1e-12);
    }
}
