//! RoPE data rearrangement through the NoC (paper §4.3.1, Fig 12).
//!
//! RoPE needs, per head vector, a neighbour swap with odd-position negation:
//! `(x0, x1) → (-x1, x0)` for every adjacent pair — scalar work a row-wide
//! SIMD PIM cannot do in place. The four bank-local routers buffer scalars
//! in their ArgRegs and re-emit them swapped/negated in a five-stage
//! schedule; the DRAM bank then finishes RoPE with an element-wise multiply
//! against the cos/sin tables.

use crate::config::NocConfig;
use crate::sim::{CostCounts, OpCost};
use crate::util::bf16::bf16_round;

use super::mesh::Mesh;
use super::packet::{Packet, PacketType, PathStep, RouterId, StepOp};

/// Functional reference: the pair swap with negation.
/// `out[2i] = -x[2i+1]; out[2i+1] = x[2i]` (the NoC_Exchange(R-, …, 1, 2)
/// semantics: position x swaps with (x+1)%2 in its group, '-' = negate the
/// value landing on an even position).
pub fn rope_rearrange(x: &[f32]) -> Vec<f32> {
    assert!(x.len() % 2 == 0, "RoPE exchange needs an even-length vector");
    let mut out = vec![0.0; x.len()];
    for i in 0..x.len() / 2 {
        out[2 * i] = bf16_round(-x[2 * i + 1]);
        out[2 * i + 1] = x[2 * i];
    }
    out
}

/// Apply full RoPE functionally (rearrange + cos/sin EWMUL), matching the
/// hardware split: NoC does the rearrangement, DRAM-PIM lanes do the
/// multiplies. `cos`/`sin` are per-position tables of x.len().
pub fn rope_apply(x: &[f32], cos: &[f32], sin: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), cos.len());
    assert_eq!(x.len(), sin.len());
    let rot = rope_rearrange(x);
    x.iter()
        .zip(&rot)
        .zip(cos.iter().zip(sin))
        .map(|((&xv, &rv), (&c, &s))| bf16_round(bf16_round(xv * c) + bf16_round(rv * s)))
        .collect()
}

/// Cycle cost of rearranging an `n_elems` head vector inside one bank using
/// its 4 routers (Fig 12C's five-stage pipeline). Matches the paper's
/// measured 34 cycles for a 128-element vector.
pub fn exchange_cost(n_elems: usize, cfg: &NocConfig) -> OpCost {
    if n_elems == 0 {
        return OpCost::zero();
    }
    let pairs = (n_elems as u64).div_ceil(2);
    let routers = 4u64; // routers per bank
    // Each router handles ceil(pairs/4) pairs; a pair costs 2 cycles in the
    // steady five-stage pipeline (in, swap/negate+out), +2 cycles fill/drain.
    let cycles = pairs.div_ceil(routers) * 2 + 2;
    OpCost {
        latency_ns: cycles as f64 * cfg.cycle_ns,
        counts: CostCounts {
            // each element passes the local port twice (in + out)
            noc_flit_hops: 2 * n_elems as u64,
            // one negate per pair
            noc_alu_ops: pairs,
            ..Default::default()
        },
    }
}

/// Simulate the exchange of a (small) vector on the mesh for one bank row:
/// elements stream through the bank's four routers; odd elements negate via
/// the Curry ALU (×-1 on ALU0) and land swapped. Used by tests to validate
/// the closed form's shape and the functional result.
pub fn simulate_exchange(mesh: &mut Mesh, bank: usize, x: &[f32]) -> (OpCost, Vec<f32>) {
    assert!(x.len() % 2 == 0);
    let n = x.len();
    let mut out = vec![0.0f32; n];
    // Configure every router in this bank row to negate on ALU0.
    for col in 0..mesh.cfg.mesh_cols {
        mesh.configure_alu(RouterId::new(col, bank), 0, -1.0, StepOp::Sub, 0.0);
    }
    // Odd positions: negate in transit and deliver at even slot's router.
    // Even positions: plain relay to the odd slot's router. Pairs round-
    // robin over the four routers.
    let mut tags: Vec<(u64, usize, bool)> = Vec::new(); // (packet id, pair, is_even_src)
    for p in 0..n / 2 {
        let col = p % mesh.cfg.mesh_cols;
        let r = RouterId::new(col, bank);
        let pe = Packet::new(PacketType::Exchange, r, x[2 * p], vec![PathStep::relay(r)]);
        let po = Packet::new(
            PacketType::Exchange,
            r,
            x[2 * p + 1],
            vec![PathStep::compute(r, StepOp::Mul)],
        );
        tags.push((mesh.inject(pe), p, true));
        tags.push((mesh.inject(po), p, false));
    }
    let cost = mesh.run(100_000);
    for d in mesh.take_deliveries() {
        let (_, pair, is_even_src) = tags.iter().find(|(id, _, _)| *id == d.packet_id).unwrap();
        if *is_even_src {
            out[2 * pair + 1] = d.value; // even source lands on odd slot
        } else {
            out[2 * pair] = d.value; // odd source (negated) lands on even slot
        }
    }
    (cost, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NocConfig;

    #[test]
    fn rearrange_reference() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(rope_rearrange(&x), vec![-2.0, 1.0, -4.0, 3.0]);
    }

    #[test]
    fn paper_34_cycles_for_128() {
        // §4.3.1: "completes the rearrangement of Q or K vectors in only 34
        // cycles per bank" for Llama2-7B (d_head = 128).
        let c = exchange_cost(128, &NocConfig::default());
        assert_eq!(c.latency_ns, 34.0, "got {} cycles", c.latency_ns);
    }

    #[test]
    fn cost_scales_with_length() {
        let cfg = NocConfig::default();
        assert!(exchange_cost(256, &cfg).latency_ns > exchange_cost(128, &cfg).latency_ns);
        assert_eq!(exchange_cost(0, &cfg), OpCost::zero());
    }

    #[test]
    fn mesh_simulation_matches_reference() {
        let mut m = Mesh::new(&NocConfig::default());
        let x: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let (cost, got) = simulate_exchange(&mut m, 3, &x);
        assert_eq!(got, rope_rearrange(&x));
        assert!(cost.latency_ns > 0.0);
    }

    #[test]
    fn rope_apply_is_rotation() {
        // With cos=cosθ, sin=sinθ constant, each pair rotates by θ: check
        // the norm is preserved (up to bf16 rounding).
        let theta = 0.3f32;
        let x = [0.6f32, 0.8, -0.5, 0.5];
        let cos = [theta.cos(); 4];
        let sin = [theta.sin(); 4];
        let y = rope_apply(&x, &cos, &sin);
        for p in 0..2 {
            let n_in = (x[2 * p].powi(2) + x[2 * p + 1].powi(2)).sqrt();
            let n_out = (y[2 * p].powi(2) + y[2 * p + 1].powi(2)).sqrt();
            assert!((n_in - n_out).abs() < 0.02, "pair {p}: {n_in} vs {n_out}");
        }
    }

    #[test]
    #[should_panic(expected = "even-length")]
    fn odd_length_rejected() {
        rope_rearrange(&[1.0, 2.0, 3.0]);
    }
}
