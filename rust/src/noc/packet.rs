//! Packet-Level ISA (paper Table 2) — the NoC's execution format.
//!
//! One packet fits one 72-bit flit:
//! `Type(4b) | Data(16b, BF16) | IterNum(4b) | Path[0..3](12b each)`
//! and each path step is
//! `x(4b) | y(4b) | WrReg(1b) | IterTag(1b) | Opcode(2b)`.
//!
//! The simulator carries the payload as f32 rounded through BF16 at every
//! ALU touch, so functional results match the 16-bit datapath.

use crate::util::bf16::bf16_round;

/// Router coordinate in the per-channel mesh (4 cols × 16 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RouterId {
    pub x: u8,
    pub y: u8,
}

impl RouterId {
    pub fn new(x: usize, y: usize) -> Self {
        Self { x: x as u8, y: y as u8 }
    }

    pub fn manhattan(&self, o: &RouterId) -> u64 {
        (self.x.abs_diff(o.x) + self.y.abs_diff(o.y)) as u64
    }
}

/// The 2-bit in-transit opcode of a path step (paper: +=, -=, *=, /=).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl StepOp {
    /// `value (op)= arg` — the unary Currying application.
    pub fn apply(&self, value: f32, arg: f32) -> f32 {
        let v = bf16_round(value);
        let a = bf16_round(arg);
        bf16_round(match self {
            StepOp::Add => v + a,
            StepOp::Sub => v - a,
            StepOp::Mul => v * a,
            StepOp::Div => v / a,
        })
    }
}

/// One waypoint of a packet's computational path.
#[derive(Debug, Clone, Copy, PartialEq)]
/// ALU binding rule (each router has two Curry ALUs): multiplicative ops
/// ({Mul, Div}) execute on ALU0, additive ops ({Add, Sub}) on ALU1. This is
/// how Fig 13's exponential binds three distinct ArgRegs (x, k, 1) onto two
/// routers. WrReg steps address the target ALU through the (otherwise
/// unused) opcode bits, surfaced here as `wr_alu`.
pub struct PathStep {
    pub at: RouterId,
    /// Write the flit payload into an ArgReg instead of computing.
    pub wr_reg: bool,
    /// Which ALU a WrReg step writes (encoded in the opcode bits).
    pub wr_alu: u8,
    /// After computing, update ArgReg with IterOp/IterArg (dynamic args).
    pub iter_tag: bool,
    /// In-transit operation; None = pure relay waypoint.
    pub op: Option<StepOp>,
}

impl PathStep {
    pub fn relay(at: RouterId) -> Self {
        Self { at, wr_reg: false, wr_alu: 0, iter_tag: false, op: None }
    }

    pub fn compute(at: RouterId, op: StepOp) -> Self {
        Self { at, wr_reg: false, wr_alu: 0, iter_tag: false, op: Some(op) }
    }

    pub fn compute_iter(at: RouterId, op: StepOp) -> Self {
        Self { at, wr_reg: false, wr_alu: 0, iter_tag: true, op: Some(op) }
    }

    pub fn write_reg(at: RouterId, alu: u8) -> Self {
        assert!(alu < 2);
        Self { at, wr_reg: true, wr_alu: alu, iter_tag: false, op: None }
    }

    /// WrReg + Opcode together: `ArgReg ← payload (op) ArgReg` — the
    /// order-insensitive accumulation mode the reduce trees use (§4.3.3:
    /// "use ArgReg as the result of reduction for each non-leaf node").
    /// Flits arriving in any order fold into the accumulator without
    /// operand matching.
    pub fn accumulate(at: RouterId, op: StepOp) -> Self {
        Self { at, wr_reg: true, wr_alu: op_alu(op), iter_tag: false, op: Some(op) }
    }

    /// The ALU this step engages at its router.
    pub fn alu_index(&self) -> usize {
        if self.wr_reg && self.op.is_none() {
            self.wr_alu as usize
        } else {
            match self.op {
                Some(op) => op_alu(op) as usize,
                None => 0,
            }
        }
    }
}

/// The ALU-binding rule: multiplicative ops on ALU0, additive on ALU1.
fn op_alu(op: StepOp) -> u8 {
    match op {
        StepOp::Mul | StepOp::Div => 0,
        StepOp::Add | StepOp::Sub => 1,
    }
}

/// Packet type (4b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PacketType {
    None,
    Scalar,
    Reduce,
    Exchange,
    Broadcast,
    Read,
    Write,
}

/// A single-flit packet executing a (possibly iterated) path.
#[derive(Debug, Clone)]
pub struct Packet {
    pub ptype: PacketType,
    /// BF16 payload (kept as f32, rounded at each touch).
    pub data: f32,
    /// Times the path is traversed (≥1). IterNum field, 4b → ≤ 15.
    pub iter_num: u8,
    /// Up to 4 waypoints per traversal.
    pub path: Vec<PathStep>,
    /// Injection router (the bank-local port it enters from).
    pub src: RouterId,
    /// Monotonic id for tracing/arbitration fairness.
    pub id: u64,
}

impl Packet {
    pub fn new(ptype: PacketType, src: RouterId, data: f32, path: Vec<PathStep>) -> Self {
        assert!(!path.is_empty(), "packet needs at least one waypoint");
        assert!(path.len() <= 4, "packet-level ISA supports up to 4 relay nodes per loop");
        Self { ptype, data: bf16_round(data), iter_num: 1, path, src, id: 0 }
    }

    pub fn with_iter(mut self, n: u8) -> Self {
        assert!((1..=15).contains(&n), "IterNum is a 4-bit field (1..=15)");
        self.iter_num = n;
        self
    }

    /// Final delivery router.
    pub fn dest(&self) -> RouterId {
        self.path.last().unwrap().at
    }

    /// Total waypoint visits (path length × iterations).
    pub fn total_steps(&self) -> usize {
        self.path.len() * self.iter_num as usize
    }

    /// Serialized bit width (Table 2) — checked against the flit budget.
    pub fn bits(&self) -> usize {
        4 + 16 + 4 + 4 * 12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_ops_bf16() {
        assert_eq!(StepOp::Add.apply(1.0, 2.0), 3.0);
        assert_eq!(StepOp::Sub.apply(1.0, 2.0), -1.0);
        assert_eq!(StepOp::Mul.apply(3.0, 2.0), 6.0);
        assert_eq!(StepOp::Div.apply(3.0, 2.0), 1.5);
    }

    #[test]
    fn packet_fits_flit_budget() {
        let r = RouterId::new(0, 0);
        let p = Packet::new(PacketType::Scalar, r, 1.0, vec![PathStep::relay(r)]);
        assert!(p.bits() <= 72, "packet {}b exceeds 72b flit", p.bits());
    }

    #[test]
    #[should_panic(expected = "4 relay nodes")]
    fn path_longer_than_4_rejected() {
        let r = RouterId::new(0, 0);
        Packet::new(PacketType::Scalar, r, 0.0, vec![PathStep::relay(r); 5]);
    }

    #[test]
    #[should_panic(expected = "4-bit field")]
    fn iter_num_bounds() {
        let r = RouterId::new(0, 0);
        let _ = Packet::new(PacketType::Scalar, r, 0.0, vec![PathStep::relay(r)]).with_iter(16);
    }

    #[test]
    fn manhattan_distance() {
        assert_eq!(RouterId::new(0, 0).manhattan(&RouterId::new(3, 15)), 18);
    }

    #[test]
    fn total_steps_counts_iterations() {
        let r = RouterId::new(1, 1);
        let p = Packet::new(
            PacketType::Scalar,
            r,
            0.0,
            vec![PathStep::relay(r), PathStep::relay(r)],
        )
        .with_iter(6);
        assert_eq!(p.total_steps(), 12);
    }
}
