//! Reduce / broadcast trees over banks (paper §4.3.3).
//!
//! A width-16 reduction is a 4-level binary tree mapped onto one mesh
//! column (the bank-local routers); the four columns run four parallel
//! trees. Non-leaf routers accumulate into their ALU1 ArgReg using the
//! order-insensitive accumulate step, so no operand matching is needed.
//!
//! Stages are dependency-ordered (a parent's partial must include its
//! subtree before being forwarded up), so the schedule injects stage by
//! stage and the mesh runs to idle in between — the same bank-controller
//! sequencing real hardware would use.

use crate::sim::OpCost;
use crate::util::bf16::bf16_round;

use super::mesh::Mesh;
use super::packet::{Packet, PacketType, PathStep, RouterId, StepOp};

/// Result of a tree collective.
#[derive(Debug, Clone)]
pub struct TreeResult {
    pub cost: OpCost,
    /// Per-column reduced value (reduce) or delivered values (broadcast).
    pub values: Vec<f32>,
    /// Raw deliveries with positions: (column, bank, value). Empty for
    /// reduce (the result lives in the root's ArgReg).
    pub deliveries: Vec<(usize, usize, f32)>,
}

/// Reduce `values[col][bank]` down each column's tree to `root_bank`,
/// running all `values.len()` column-trees in parallel. `op` is typically
/// Add (Softmax denominators, partial-sum folds).
///
/// Returns the per-column reduction results and the total cost.
pub fn reduce(
    mesh: &mut Mesh,
    values: &[Vec<f32>],
    op: StepOp,
    root_bank: usize,
    banks: usize,
) -> TreeResult {
    assert!(banks.is_power_of_two(), "tree reduction needs a power-of-two bank count");
    assert!(values.len() <= mesh.cfg.mesh_cols);
    assert!(banks <= mesh.cfg.mesh_rows);
    assert!(root_bank < banks);
    let n_cols = values.len();

    for v in values {
        assert_eq!(v.len(), banks);
    }

    // Relabel banks so the tree roots at `root_bank`: node id = bank XOR root.
    let relabel = |logical: usize| logical ^ root_bank;

    // Mirror of each *logical* node's running partial (what the ArgRegs at
    // the corresponding physical routers will hold).
    let mut partial: Vec<Vec<f32>> = values
        .iter()
        .map(|v| (0..banks).map(|l| bf16_round(v[relabel(l)])).collect())
        .collect();

    // Accumulation binds to the ALU the op class selects.
    let alu = PathStep::accumulate(RouterId::new(0, 0), op).alu_index();

    // Initialize every router's accumulator ArgReg with its own value (the
    // bank writes its local router through the local port; 1 cycle, 0 hops).
    for (col, vals) in values.iter().enumerate() {
        for bank in 0..banks {
            mesh.configure_alu(RouterId::new(col, bank), alu, vals[bank], StepOp::Add, 0.0);
        }
    }

    let mut cost = OpCost::zero();
    let levels = banks.trailing_zeros();
    for s in 0..levels {
        let stride = 1usize << s;
        // Senders: logical ids that are odd multiples of `stride`.
        for col in 0..n_cols {
            for logical in (stride..banks).step_by(2 * stride) {
                let sender = relabel(logical);
                let receiver = relabel(logical - stride);
                let val = partial[col][logical];
                let p = Packet::new(
                    PacketType::Reduce,
                    RouterId::new(col, sender),
                    val,
                    vec![PathStep::accumulate(RouterId::new(col, receiver), op)],
                );
                mesh.inject(p);
                let acc = op.apply(val, partial[col][logical - stride]);
                partial[col][logical - stride] = acc;
            }
        }
        cost = cost.then(&mesh.run(1_000_000));
        mesh.take_deliveries();
    }

    let values_out: Vec<f32> = (0..n_cols)
        .map(|col| {
            let got = mesh.alu_arg(RouterId::new(col, root_bank), alu);
            debug_assert_eq!(got, partial[col][0], "ArgReg mirror divergence");
            got
        })
        .collect();
    TreeResult { cost, values: values_out, deliveries: Vec::new() }
}

/// Broadcast `values[col]` from `src_bank` to all `banks` banks of each
/// column — the reduce tree run in reverse. Delivered flits eject at each
/// bank's local port.
pub fn broadcast(
    mesh: &mut Mesh,
    values: &[f32],
    src_bank: usize,
    banks: usize,
) -> TreeResult {
    assert!(banks.is_power_of_two());
    assert!(values.len() <= mesh.cfg.mesh_cols);
    assert!(src_bank < banks);
    let n_cols = values.len();
    let relabel = |logical: usize| logical ^ src_bank;

    let mut cost = OpCost::zero();
    let levels = banks.trailing_zeros();
    // Walk levels top-down: at level s (from high), holders forward to the
    // partner `stride` away.
    for s in (0..levels).rev() {
        let stride = 1usize << s;
        for col in 0..n_cols {
            for logical in (0..banks).step_by(2 * stride) {
                let holder = relabel(logical);
                let target = relabel(logical + stride);
                let p = Packet::new(
                    PacketType::Broadcast,
                    RouterId::new(col, holder),
                    values[col],
                    vec![PathStep::relay(RouterId::new(col, target))],
                );
                mesh.inject(p);
            }
        }
        cost = cost.then(&mesh.run(1_000_000));
    }
    let delivered = mesh.take_deliveries();
    // every bank except src receives a copy, per column
    debug_assert_eq!(delivered.len(), n_cols * (banks - 1));
    TreeResult {
        cost,
        values: delivered.iter().map(|d| d.value).collect(),
        deliveries: delivered
            .iter()
            .map(|d| (d.at.x as usize, d.at.y as usize, d.value))
            .collect(),
    }
}

/// Closed-form stage count of a tree collective (for analytic sizing):
/// log2(banks) stages, each bounded by the longest hop at that stage.
pub fn tree_stage_hops(banks: usize) -> u64 {
    let mut total = 0u64;
    let mut stride = 1usize;
    while stride < banks {
        total += stride as u64;
        stride <<= 1;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NocConfig;

    fn mesh() -> Mesh {
        Mesh::new(&NocConfig::default())
    }

    #[test]
    fn reduce_16_banks_sums_exactly() {
        let mut m = mesh();
        let vals: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let r = reduce(&mut m, &[vals.clone()], StepOp::Add, 0, 16);
        assert_eq!(r.values[0], 120.0);
        assert!(r.cost.latency_ns > 0.0);
        assert!(r.cost.counts.noc_alu_ops >= 15, "15 accumulations needed");
    }

    #[test]
    fn reduce_rooted_anywhere() {
        for root in [0usize, 5, 15] {
            let mut m = mesh();
            let vals: Vec<f32> = (0..16).map(|i| (i + 1) as f32).collect();
            let r = reduce(&mut m, &[vals], StepOp::Add, root, 16);
            assert_eq!(r.values[0], 136.0, "root={root}");
        }
    }

    #[test]
    fn four_parallel_trees() {
        let mut m = mesh();
        let cols: Vec<Vec<f32>> =
            (0..4).map(|c| (0..16).map(|i| (c * 16 + i) as f32).collect()).collect();
        let r = reduce(&mut m, &cols, StepOp::Add, 0, 16);
        for (c, v) in r.values.iter().enumerate() {
            let expect: f32 = (0..16).map(|i| (c * 16 + i) as f32).sum();
            assert_eq!(*v, expect);
        }
    }

    #[test]
    fn parallel_trees_cheaper_than_serial() {
        // 4 trees in parallel should take much less than 4× one tree.
        let one = {
            let mut m = mesh();
            reduce(&mut m, &[vec![1.0; 16]], StepOp::Add, 0, 16).cost.latency_ns
        };
        let four = {
            let mut m = mesh();
            reduce(&mut m, &vec![vec![1.0; 16]; 4], StepOp::Add, 0, 16).cost.latency_ns
        };
        assert!(four < 2.0 * one, "four={four} one={one}");
    }

    #[test]
    fn tree_scaling_is_logarithmic_not_linear() {
        // The §3.3/§4.1 claim: NoC tree reduction avoids the global buffer's
        // bank-serialized gather. A serialized reduce over 16 banks costs
        // 15× the 2-bank transfer; the tree must scale ≪ that.
        let t2 = {
            let mut m = mesh();
            reduce(&mut m, &[vec![1.0; 2]], StepOp::Add, 0, 2).cost.latency_ns
        };
        let t16 = {
            let mut m = mesh();
            reduce(&mut m, &[vec![1.0; 16]], StepOp::Add, 0, 16).cost.latency_ns
        };
        assert!(t16 < 15.0 * t2 / 1.5, "t16={t16} t2={t2} — not logarithmic");
    }

    #[test]
    fn broadcast_reaches_all_banks() {
        let mut m = mesh();
        let r = broadcast(&mut m, &[3.5, 4.5], 2, 16);
        assert_eq!(r.values.len(), 2 * 15);
        assert!(r.values.iter().all(|&v| v == 3.5 || v == 4.5));
    }

    #[test]
    fn broadcast_smaller_groups() {
        let mut m = mesh();
        let r = broadcast(&mut m, &[1.0], 0, 4);
        assert_eq!(r.values.len(), 3);
    }

    #[test]
    fn stage_hops_closed_form() {
        assert_eq!(tree_stage_hops(16), 1 + 2 + 4 + 8);
        assert_eq!(tree_stage_hops(2), 1);
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn non_pow2_rejected() {
        let mut m = mesh();
        reduce(&mut m, &[vec![1.0; 12]], StepOp::Add, 0, 12);
    }

    #[test]
    fn reduce_with_mul() {
        let mut m = mesh();
        let r = reduce(&mut m, &[vec![2.0, 2.0, 2.0, 2.0]], StepOp::Mul, 0, 4);
        assert_eq!(r.values[0], 16.0);
    }
}
