//! Flit-level, cycle-stepped simulation of the per-channel CompAir-NoC:
//! a 4×16 2D mesh of SWIFT-style routers with DOR (X-then-Y) routing,
//! credit-based input queues, and two Curry ALUs per router executing
//! in-transit operations in parallel with switch traversal (Fig 11C).
//!
//! Modeling notes:
//! * Bypass-hit traversal is 1 cycle/hop; bypass misses emerge from output
//!   -link arbitration (losers stall ≥1 cycle), matching SWIFT's 1-2 cycle
//!   behaviour without modelling the full 5-stage pipeline.
//! * The divider is iterative: a Div path-step holds the flit for
//!   `div_cycles` before it may move on.
//! * Links are point-to-point: entry conflicts cannot happen; only output
//!   links arbitrate (round-robin across input ports).

use std::collections::VecDeque;

use crate::config::NocConfig;
use crate::sim::{CostCounts, OpCost};

use super::curry::CurryAlu;
use super::packet::{Packet, RouterId, StepOp};

const PORT_LOCAL: usize = 0;
const PORT_N: usize = 1;
const PORT_E: usize = 2;
const PORT_S: usize = 3;
const PORT_W: usize = 4;
const N_PORTS: usize = 5;

/// A packet in flight with its execution cursor.
#[derive(Debug, Clone)]
struct InFlight {
    packet: Packet,
    /// Index of the next waypoint to execute.
    step_idx: usize,
    /// Path traversals remaining (including the current one).
    iters_left: u8,
    /// Busy until this cycle (iterative divider occupancy).
    busy_until: u64,
}

impl InFlight {
    fn current_target(&self) -> RouterId {
        self.packet.path[self.step_idx].at
    }
}

/// A delivered packet.
#[derive(Debug, Clone)]
pub struct Delivery {
    pub packet_id: u64,
    pub value: f32,
    pub at: RouterId,
    pub cycle: u64,
}

#[derive(Debug)]
struct Router {
    id: RouterId,
    inputs: [VecDeque<InFlight>; N_PORTS],
    alus: [CurryAlu; 2],
    /// Round-robin arbitration pointer.
    rr: usize,
    /// Flits across this router's input queues (skip-empty fast path).
    occupancy: usize,
}

impl Router {
    fn new(id: RouterId) -> Self {
        Self {
            id,
            inputs: Default::default(),
            alus: [CurryAlu::new(), CurryAlu::new()],
            rr: 0,
            occupancy: 0,
        }
    }
}

/// The mesh simulator.
pub struct Mesh {
    pub cfg: NocConfig,
    routers: Vec<Router>,
    cycle: u64,
    /// (inject_cycle, packet) waiting to enter the network.
    pending: Vec<(u64, Packet)>,
    next_id: u64,
    pub delivered: Vec<Delivery>,
    flit_hops: u64,
    alu_ops_at_start: u64,
    /// Flits currently resident in router queues (O(1) idle check — §Perf:
    /// scanning 64 routers x 5 queues per cycle dominated `run`).
    in_network: usize,
}

impl Mesh {
    pub fn new(cfg: &NocConfig) -> Self {
        let routers = (0..cfg.mesh_rows)
            .flat_map(|y| (0..cfg.mesh_cols).map(move |x| Router::new(RouterId::new(x, y))))
            .collect();
        Self {
            cfg: cfg.clone(),
            routers,
            cycle: 0,
            pending: Vec::new(),
            next_id: 0,
            delivered: Vec::new(),
            flit_hops: 0,
            alu_ops_at_start: 0,
            in_network: 0,
        }
    }

    fn idx(&self, id: RouterId) -> usize {
        debug_assert!((id.x as usize) < self.cfg.mesh_cols, "x={} out of mesh", id.x);
        debug_assert!((id.y as usize) < self.cfg.mesh_rows, "y={} out of mesh", id.y);
        id.y as usize * self.cfg.mesh_cols + id.x as usize
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Statically configure a router's Curry ALU (program-load time;
    /// corresponds to NoC_Access writes before the phase starts).
    pub fn configure_alu(
        &mut self,
        at: RouterId,
        alu: usize,
        arg_reg: f32,
        iter_op: StepOp,
        iter_arg: f32,
    ) {
        let i = self.idx(at);
        self.routers[i].alus[alu].configure(arg_reg, iter_op, iter_arg);
    }

    pub fn alu_arg(&self, at: RouterId, alu: usize) -> f32 {
        self.routers[self.idx(at)].alus[alu].arg_reg
    }

    /// Inject a packet at its `src` router's local port at `cycle`.
    pub fn inject_at(&mut self, cycle: u64, mut p: Packet) -> u64 {
        assert!(cycle >= self.cycle, "injection into the past");
        p.id = self.next_id;
        self.next_id += 1;
        let id = p.id;
        self.pending.push((cycle, p));
        id
    }

    pub fn inject(&mut self, p: Packet) -> u64 {
        self.inject_at(self.cycle, p)
    }

    fn port_toward(&self, from: RouterId, to: RouterId) -> usize {
        // DOR: X first, then Y.
        if to.x > from.x {
            PORT_E
        } else if to.x < from.x {
            PORT_W
        } else if to.y > from.y {
            PORT_S
        } else if to.y < from.y {
            PORT_N
        } else {
            PORT_LOCAL
        }
    }

    fn neighbor(&self, from: RouterId, port: usize) -> (RouterId, usize) {
        // Returns (neighbor id, the neighbor's input port facing us).
        match port {
            PORT_N => (RouterId::new(from.x as usize, from.y as usize - 1), PORT_S),
            PORT_S => (RouterId::new(from.x as usize, from.y as usize + 1), PORT_N),
            PORT_E => (RouterId::new(from.x as usize + 1, from.y as usize), PORT_W),
            PORT_W => (RouterId::new(from.x as usize - 1, from.y as usize), PORT_E),
            _ => unreachable!("no neighbor through local port"),
        }
    }

    /// Execute the flit's step at its waypoint router. Returns true when the
    /// packet completed its full (iterated) path and was delivered.
    fn execute_step(
        router: &mut Router,
        inflight: &mut InFlight,
        div_cycles: u64,
        cycle: u64,
    ) -> bool {
        let step = inflight.packet.path[inflight.step_idx];
        debug_assert_eq!(step.at, router.id);
        let alu = &mut router.alus[step.alu_index()];
        if step.wr_reg {
            match step.op {
                // Accumulation mode: ArgReg ← payload (op) ArgReg.
                Some(op) => {
                    let acc = op.apply(inflight.packet.data, alu.arg_reg);
                    alu.arg_reg = acc;
                    alu.ops_executed += 1;
                    inflight.packet.data = acc;
                }
                None => alu.write_reg(inflight.packet.data),
            }
        } else if let Some(op) = step.op {
            inflight.packet.data = alu.apply(op, inflight.packet.data, step.iter_tag);
            if op == StepOp::Div {
                inflight.busy_until = cycle + div_cycles;
            }
        }
        // Advance the cursor.
        if inflight.step_idx + 1 < inflight.packet.path.len() {
            inflight.step_idx += 1;
            false
        } else if inflight.iters_left > 1 {
            inflight.iters_left -= 1;
            inflight.step_idx = 0;
            false
        } else {
            true
        }
    }

    /// Advance one cycle. Returns the number of flit movements made.
    pub fn step(&mut self) -> usize {
        let cycle = self.cycle;
        // 1. Inject pending packets whose time has come (into local ports).
        //    Stable extraction preserves injection order — local-port FIFO
        //    ordering is what serializes a WrReg ahead of its compute flit.
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].0 <= cycle {
                let (_, p) = self.pending.remove(i);
                let idx = self.idx(p.src);
                let inflight =
                    InFlight { iters_left: p.iter_num, packet: p, step_idx: 0, busy_until: 0 };
                self.routers[idx].inputs[PORT_LOCAL].push_back(inflight);
                self.routers[idx].occupancy += 1;
                self.in_network += 1;
            } else {
                i += 1;
            }
        }

        // 2. Arbitrate and move. Each output link carries ≤1 flit/cycle.
        //    Moves land in the neighbor's queue *next* cycle; we stage them.
        let mut moves: Vec<(usize, usize, InFlight)> = Vec::new(); // (router, port, flit)
        let mut moved = 0usize;
        for r_idx in 0..self.routers.len() {
            if self.routers[r_idx].occupancy == 0 {
                continue; // §Perf: most routers are empty most cycles
            }
            let mut used_ports = [false; N_PORTS];
            let rr0 = self.routers[r_idx].rr;
            for k in 0..N_PORTS {
                let port = (rr0 + k) % N_PORTS;
                // Process the head flit of this input queue, if any.
                let (head_ready, at_waypoint) = {
                    let r = &self.routers[r_idx];
                    match r.inputs[port].front() {
                        None => (false, false),
                        Some(f) => {
                            (f.busy_until <= cycle, f.current_target() == r.id)
                        }
                    }
                };
                if !head_ready {
                    continue;
                }
                // Execute waypoint steps in place (ALU runs parallel to
                // traversal; repeated same-router steps execute back-to-back
                // only via re-queue next cycle).
                if at_waypoint {
                    let r = &mut self.routers[r_idx];
                    let mut f = r.inputs[port].pop_front().unwrap();
                    let done = Self::execute_step(r, &mut f, self.cfg.div_cycles, cycle);
                    moved += 1; // in-place execution is forward progress
                    if done {
                        self.delivered.push(Delivery {
                            packet_id: f.packet.id,
                            value: f.packet.data,
                            at: r.id,
                            cycle,
                        });
                        self.in_network -= 1;
                        r.occupancy -= 1;
                        continue;
                    }
                    // Not done: re-insert at head to route toward the next
                    // waypoint this same cycle (flit-compute overlaps ST).
                    r.inputs[port].push_front(f);
                }
                // Route toward the (possibly new) target.
                let (target, rid) = {
                    let r = &self.routers[r_idx];
                    let f = r.inputs[port].front().unwrap();
                    if f.busy_until > cycle {
                        continue; // divider still busy after an in-place step
                    }
                    (f.current_target(), r.id)
                };
                if target == rid {
                    // Next waypoint is this same router (e.g. iterating in
                    // place); execute again next cycle.
                    continue;
                }
                let out_port = self.port_toward(rid, target);
                if used_ports[out_port] {
                    continue; // output link taken this cycle (bypass miss)
                }
                let (n_id, n_port) = self.neighbor(rid, out_port);
                let n_idx = self.idx(n_id);
                if self.routers[n_idx].inputs[n_port].len()
                    + moves.iter().filter(|(ri, pi, _)| *ri == n_idx && *pi == n_port).count()
                    >= self.cfg.queue_depth
                {
                    continue; // backpressure: no credit at the neighbor
                }
                used_ports[out_port] = true;
                let f = self.routers[r_idx].inputs[port].pop_front().unwrap();
                self.routers[r_idx].occupancy -= 1;
                moves.push((n_idx, n_port, f));
                moved += 1;
            }
            self.routers[r_idx].rr = (rr0 + 1) % N_PORTS;
        }
        for (r_idx, port, f) in moves {
            self.routers[r_idx].inputs[port].push_back(f);
            self.routers[r_idx].occupancy += 1;
            self.flit_hops += 1;
        }
        self.cycle += 1;
        moved
    }

    /// True when no flits are in flight or pending. O(1).
    pub fn idle(&self) -> bool {
        self.pending.is_empty() && self.in_network == 0
    }

    /// Run until idle (or `max_cycles`), returning the phase cost.
    pub fn run(&mut self, max_cycles: u64) -> OpCost {
        let start_cycle = self.cycle;
        let mut stall = 0u64;
        while !self.idle() {
            let before = self.delivered.len();
            let moved = self.step();
            if moved == 0 && self.delivered.len() == before && self.pending.is_empty() {
                stall += 1;
                // All remaining flits may be divider-busy; only give up after
                // a long genuine deadlock window.
                assert!(
                    stall <= self.cfg.div_cycles + 64,
                    "NoC deadlock at cycle {} ({} flits stuck)",
                    self.cycle,
                    self.routers.iter().map(|r| r.inputs.iter().map(|q| q.len()).sum::<usize>()).sum::<usize>()
                );
            } else {
                stall = 0;
            }
            assert!(
                self.cycle - start_cycle <= max_cycles,
                "NoC run exceeded {max_cycles} cycles"
            );
        }
        let elapsed = self.cycle - start_cycle;
        let alu_ops: u64 =
            self.routers.iter().flat_map(|r| r.alus.iter()).map(|a| a.ops_executed).sum();
        let new_alu_ops = alu_ops - self.alu_ops_at_start;
        self.alu_ops_at_start = alu_ops;
        let hops = self.flit_hops;
        self.flit_hops = 0;
        OpCost {
            latency_ns: elapsed as f64 * self.cfg.cycle_ns,
            counts: CostCounts {
                noc_flit_hops: hops,
                noc_alu_ops: new_alu_ops,
                ..Default::default()
            },
        }
    }

    /// Take and clear deliveries.
    pub fn take_deliveries(&mut self) -> Vec<Delivery> {
        std::mem::take(&mut self.delivered)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::packet::{PacketType, PathStep};

    fn mesh() -> Mesh {
        Mesh::new(&NocConfig::default())
    }

    #[test]
    fn single_hop_delivery() {
        let mut m = mesh();
        let src = RouterId::new(0, 0);
        let dst = RouterId::new(0, 1);
        let p = Packet::new(PacketType::Write, src, 7.0, vec![PathStep::relay(dst)]);
        m.inject(p);
        let cost = m.run(100);
        let d = m.take_deliveries();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].value, 7.0);
        assert_eq!(d[0].at, dst);
        assert!(cost.latency_ns >= 1.0 && cost.latency_ns < 10.0, "lat={}", cost.latency_ns);
        assert_eq!(cost.counts.noc_flit_hops, 1);
    }

    #[test]
    fn dor_hop_count_matches_manhattan() {
        let mut m = mesh();
        let src = RouterId::new(0, 0);
        let dst = RouterId::new(3, 15);
        m.inject(Packet::new(PacketType::Write, src, 1.0, vec![PathStep::relay(dst)]));
        let cost = m.run(200);
        assert_eq!(cost.counts.noc_flit_hops, src.manhattan(&dst));
        // uncongested: ~1 cycle/hop + injection/ejection
        assert!(cost.latency_ns <= (src.manhattan(&dst) + 4) as f64);
    }

    #[test]
    fn in_transit_compute_applies() {
        let mut m = mesh();
        let a = RouterId::new(1, 2);
        let src = RouterId::new(0, 0);
        let dst = RouterId::new(3, 4);
        // additive ops bind to ALU1 per the binding rule
        m.configure_alu(a, 1, 10.0, StepOp::Add, 0.0);
        let p = Packet::new(
            PacketType::Scalar,
            src,
            5.0,
            vec![PathStep::compute(a, StepOp::Add), PathStep::relay(dst)],
        );
        m.inject(p);
        m.run(200);
        let d = m.take_deliveries();
        assert_eq!(d[0].value, 15.0);
        assert_eq!(d[0].at, dst);
    }

    #[test]
    fn wr_reg_writes_argreg() {
        let mut m = mesh();
        let a = RouterId::new(2, 3);
        m.inject(Packet::new(
            PacketType::Write,
            RouterId::new(0, 3),
            42.0,
            vec![PathStep::write_reg(a, 1)],
        ));
        m.run(100);
        assert_eq!(m.alu_arg(a, 1), 42.0);
    }

    #[test]
    fn iterative_exponential_on_mesh_matches_reference() {
        // Fig 13: exp(x) via 6 Horner iterations across two routers. The
        // ALU-binding rule puts *=x on ra.ALU0, /=k on rb.ALU0 (with the
        // iter-decrement of k), and +=1 on ra.ALU1 — three ArgRegs on two
        // routers, exactly the paper's "two parallel exponentiations across
        // four routers" layout.
        for &x in &[0.5f32, 1.0, -0.5] {
            let rounds = 6u8;
            let mut m = mesh();
            let ra = RouterId::new(0, 1);
            let rb = RouterId::new(1, 1);
            m.configure_alu(ra, 0, x, StepOp::Sub, 0.0); // *= x
            m.configure_alu(rb, 0, rounds as f32, StepOp::Sub, 1.0); // /= k; k -= 1
            m.configure_alu(ra, 1, 1.0, StepOp::Sub, 0.0); // += 1
            let p = Packet::new(
                PacketType::Scalar,
                RouterId::new(0, 0),
                1.0,
                vec![
                    PathStep::compute(ra, StepOp::Mul),
                    PathStep::compute_iter(rb, StepOp::Div),
                    PathStep::compute(ra, StepOp::Add),
                ],
            )
            .with_iter(rounds);
            m.inject(p);
            m.run(10_000);
            let d = m.take_deliveries();
            assert_eq!(d.len(), 1);
            let expect = crate::noc::curry::curry_exp(x, rounds as u32);
            assert_eq!(d[0].value, expect, "x={x}");
            let rel = ((d[0].value - x.exp()) / x.exp()).abs();
            assert!(rel < 0.02, "x={x}: mesh exp {} vs true {}", d[0].value, x.exp());
        }
    }

    #[test]
    fn contention_extends_latency() {
        // Two packets fighting for the same column link vs one alone.
        let dst = RouterId::new(0, 8);
        let mk = |src: RouterId| Packet::new(PacketType::Write, src, 1.0, vec![PathStep::relay(dst)]);
        let mut m1 = mesh();
        m1.inject(mk(RouterId::new(0, 0)));
        let t1 = m1.run(1000).latency_ns;
        let mut m2 = mesh();
        for _ in 0..8 {
            m2.inject(mk(RouterId::new(0, 0)));
        }
        let t2 = m2.run(1000).latency_ns;
        assert!(t2 > t1, "serialized injection must take longer: {t2} vs {t1}");
    }

    #[test]
    fn backpressure_no_flit_loss() {
        // Saturate one destination from all four columns; everything must
        // still be delivered (credits prevent loss).
        let mut m = mesh();
        let dst = RouterId::new(3, 15);
        let mut n = 0;
        for x in 0..4 {
            for y in 0..8 {
                m.inject(Packet::new(
                    PacketType::Write,
                    RouterId::new(x, y),
                    (x + y) as f32,
                    vec![PathStep::relay(dst)],
                ));
                n += 1;
            }
        }
        m.run(100_000);
        assert_eq!(m.take_deliveries().len(), n);
    }

    #[test]
    fn divider_occupancy_slows_chain() {
        let mut fast_cfg = NocConfig::default();
        fast_cfg.div_cycles = 0;
        let run_with = |cfg: &NocConfig| {
            let mut m = Mesh::new(cfg);
            let a = RouterId::new(1, 1);
            m.configure_alu(a, 0, 2.0, StepOp::Sub, 0.0);
            let p = Packet::new(
                PacketType::Scalar,
                RouterId::new(0, 0),
                64.0,
                vec![PathStep::compute(a, StepOp::Div), PathStep::relay(RouterId::new(2, 1))],
            )
            .with_iter(4);
            m.inject(p);
            let c = m.run(10_000);
            (c.latency_ns, m.take_deliveries()[0].value)
        };
        let (t_fast, v_fast) = run_with(&fast_cfg);
        let (t_slow, v_slow) = run_with(&NocConfig::default());
        assert!(t_slow > t_fast);
        assert_eq!(v_fast, v_slow);
        assert_eq!(v_fast, 64.0 / 16.0); // ÷2 four times... per iteration path hits Div once
    }
}
