//! Fidelity-tiered NoC costing — the one place the analytic collectives
//! and the flit-level mesh meet (see `docs/ARCHITECTURE.md`, "NoC fidelity
//! & calibration").
//!
//! Every serving and cluster number in this repo prices the paper's
//! headline contribution — in-transit non-linear computation on
//! CompAir-NoC (§4) — through five collective cost functions: reduce,
//! broadcast, exp, sqrt and the scalar stream. [`NocModel`] abstracts how
//! those are priced, with three tiers selected by
//! [`NocFidelity`](crate::config::NocFidelity):
//!
//! * [`AnalyticNoc`] — the closed forms in [`crate::arch::collective`].
//!   Fast, validated only to within 0.5–2.0× of the simulator.
//! * [`SimulatedNoc`] — drives the flit-level [`Mesh`], the
//!   [`trees`] reduce/broadcast schedules, and the ISA
//!   [`Machine`](crate::isa::Machine) directly. The simulator prices one
//!   steady-state *granule* — a full-width chunk (one element per mesh
//!   column) for reduce/broadcast/scalar-stream, one 2-lane wave for
//!   exp/sqrt — exactly, then replicates it `ceil(elems / granule)` times.
//!   This mirrors the bank-controller's chunk-sequential schedule (the
//!   trees inject stage by stage and run to idle; the lanes re-arm per
//!   wave) and is the same chunking structure the closed forms use, so the
//!   tier stays usable at figure-sweep scale: one small mesh run per
//!   distinct shape class, memoized, plus O(1) replication.
//! * [`CalibratedNoc`] — the closed forms with a per-collective
//!   multiplicative latency correction fitted against the simulator at a
//!   small grid of anchor shapes (geometric-mean ratio over the anchors,
//!   keyed by the collective's structural parameter: the power-of-two bank
//!   ceiling for trees, the round count for exp/sqrt). Fast like analytic,
//!   accurate like simulation. Event counts stay analytic — the correction
//!   repairs *latency*, the energy accounting is count-based and already
//!   agrees — and corrections are memoized per model instance, so a
//!   serving run pays for each anchor simulation once.
//!
//! Because both sides share the chunk/wave-linear structure, the fitted
//! ratio is volume-invariant: the calibrated tier reproduces the simulator
//! at every anchor shape to within float rounding, which the
//! `noc-calibration` figure table and the ci.sh self-check gate assert
//! (≤ 20% is the contract; the observed error is ~0). What the correction
//! genuinely adds is the flit-level truth inside a granule — injection
//! serialization, output-link arbitration, divider occupancy — that the
//! closed forms approximate with per-stage constants.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::arch::collective as coll;
use crate::config::{HwConfig, NocConfig, NocFidelity};
use crate::isa::{Machine, RowProgram};
use crate::sim::OpCost;
use crate::util::stats::geomean;

use super::mesh::Mesh;
use super::packet::{Packet, PacketType, PathStep, RouterId, StepOp};
use super::trees;

/// The five NoC collectives the cost model prices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NocCollective {
    Reduce,
    Broadcast,
    Exp,
    Sqrt,
    ScalarStream,
}

impl NocCollective {
    pub fn label(&self) -> &'static str {
        match self {
            NocCollective::Reduce => "reduce",
            NocCollective::Broadcast => "broadcast",
            NocCollective::Exp => "exp",
            NocCollective::Sqrt => "sqrt",
            NocCollective::ScalarStream => "scalar-stream",
        }
    }

    pub fn all() -> [NocCollective; 5] {
        [
            NocCollective::Reduce,
            NocCollective::Broadcast,
            NocCollective::Exp,
            NocCollective::Sqrt,
            NocCollective::ScalarStream,
        ]
    }
}

/// One NoC costing tier. Object-safe: [`crate::arch::System`] holds a
/// `Box<dyn NocModel>` chosen by the run's [`NocFidelity`].
///
/// Shape conventions match `arch::collective`: `elems` is the total
/// element count for reduce/broadcast (spread over the mesh columns) and
/// the per-bank count for exp/sqrt/scalar-stream; `banks` is the tree
/// height; `rounds` the iteration count of the Horner/Newton chains.
pub trait NocModel {
    fn fidelity(&self) -> NocFidelity;
    fn cfg(&self) -> &NocConfig;
    fn reduce(&self, elems: u64, banks: u64) -> OpCost;
    fn broadcast(&self, elems: u64, banks: u64) -> OpCost;
    fn exp(&self, elems_per_bank: u64, rounds: u64) -> OpCost;
    fn sqrt(&self, elems_per_bank: u64, rounds: u64) -> OpCost;
    fn scalar_stream(&self, elems_per_bank: u64) -> OpCost;

    /// Warm any lazily fitted state using up to `jobs` worker threads.
    /// Results are bit-identical to the lazy serial fit (the fit is a pure
    /// function of the hardware config; parallelism only changes when the
    /// anchor simulations run). Default: nothing to warm — the analytic
    /// tier has no state, and the simulated tier's granule set depends on
    /// the query stream. [`CalibratedNoc`] overrides this to fan its
    /// anchor-grid fits out over the pool.
    fn prefit(&self, jobs: usize) {
        let _ = jobs;
    }
}

/// Build the tier selected by `fidelity` over this hardware point.
pub fn build(fidelity: NocFidelity, hw: &HwConfig) -> Box<dyn NocModel> {
    match fidelity {
        NocFidelity::Analytic => Box::new(AnalyticNoc::new(hw.noc.clone())),
        NocFidelity::Simulated => Box::new(SimulatedNoc::new(hw)),
        NocFidelity::Calibrated => Box::new(CalibratedNoc::new(hw)),
    }
}

/// Uniform dispatch over the trait by collective kind. `param` is the
/// structural parameter (banks for trees, rounds for exp/sqrt; ignored by
/// the scalar stream) — used by the calibration fit, the report, and the
/// property tests.
pub fn collective_cost(m: &dyn NocModel, kind: NocCollective, elems: u64, param: u64) -> OpCost {
    match kind {
        NocCollective::Reduce => m.reduce(elems, param),
        NocCollective::Broadcast => m.broadcast(elems, param),
        NocCollective::Exp => m.exp(elems, param),
        NocCollective::Sqrt => m.sqrt(elems, param),
        NocCollective::ScalarStream => m.scalar_stream(elems),
    }
}

// ---------------------------------------------------------------- analytic

/// Tier 1: the closed forms of `arch::collective`, verbatim.
pub struct AnalyticNoc {
    cfg: NocConfig,
}

impl AnalyticNoc {
    pub fn new(cfg: NocConfig) -> Self {
        Self { cfg }
    }
}

impl NocModel for AnalyticNoc {
    fn fidelity(&self) -> NocFidelity {
        NocFidelity::Analytic
    }

    fn cfg(&self) -> &NocConfig {
        &self.cfg
    }

    fn reduce(&self, elems: u64, banks: u64) -> OpCost {
        coll::noc_reduce(elems, banks, &self.cfg)
    }

    fn broadcast(&self, elems: u64, banks: u64) -> OpCost {
        coll::noc_broadcast(elems, banks, &self.cfg)
    }

    fn exp(&self, elems_per_bank: u64, rounds: u64) -> OpCost {
        coll::noc_exp(elems_per_bank, rounds, &self.cfg)
    }

    fn sqrt(&self, elems_per_bank: u64, rounds: u64) -> OpCost {
        coll::noc_sqrt(elems_per_bank, rounds, &self.cfg)
    }

    fn scalar_stream(&self, elems_per_bank: u64) -> OpCost {
        coll::noc_scalar_stream(elems_per_bank, &self.cfg)
    }
}

// --------------------------------------------------------------- simulated

/// Parallel Horner/Newton lanes per bank (paper Fig 13: two iterated
/// packets across the bank's four routers). Shared with the closed forms.
const LANES: u64 = 2;

/// The tree schedules need a power-of-two height within the mesh; callers
/// pass arbitrary bank counts (e.g. `banks_per_pair.min(16)`), which the
/// simulator rounds up to the next power of two, capped at the largest
/// power of two that fits the column. This matches the closed form, whose
/// stage ladder also climbs to the power-of-two ceiling.
fn tree_banks(banks: u64, mesh_rows: usize) -> u64 {
    let cap = (mesh_rows as u64 + 1).next_power_of_two() / 2; // largest pow2 ≤ rows
    // beyond the mesh column the granule cannot represent the request and
    // the calibrated ≡ simulated contract would silently void — a hard
    // error beats a quietly wrong cost model (unreachable from System,
    // which never asks for trees taller than a channel's bank column)
    assert!(
        banks <= cap.max(2),
        "NoC tree over {banks} banks exceeds the {mesh_rows}-row mesh column"
    );
    banks.next_power_of_two().clamp(2, cap.max(2))
}

/// Tier 3: drive the flit-level simulators at the requested shape.
///
/// Granule costs (one chunk / one wave) are memoized per `(collective,
/// structural parameter)`, so repeated shapes — the serving hot path —
/// re-run nothing. Results are deterministic: the mesh is cycle-stepped
/// with no randomness, so cached and fresh instances agree bit-for-bit.
pub struct SimulatedNoc {
    hw: HwConfig,
    granules: RefCell<HashMap<(NocCollective, u64), OpCost>>,
}

impl SimulatedNoc {
    pub fn new(hw: &HwConfig) -> Self {
        Self { hw: hw.clone(), granules: RefCell::new(HashMap::new()) }
    }

    fn cols(&self) -> u64 {
        self.hw.noc.mesh_cols as u64
    }

    /// Memoized cost of one granule of `kind` at structural param `key`.
    fn granule(&self, kind: NocCollective, key: u64) -> OpCost {
        if let Some(c) = self.granules.borrow().get(&(kind, key)) {
            return *c;
        }
        let c = match kind {
            NocCollective::Reduce => self.sim_reduce_chunk(key as usize),
            NocCollective::Broadcast => self.sim_broadcast_chunk(key as usize),
            NocCollective::Exp => self.sim_exp_wave(key as u32),
            NocCollective::Sqrt => self.sim_sqrt_wave(key as u8),
            NocCollective::ScalarStream => self.sim_scalar_chunk(),
        };
        self.granules.borrow_mut().insert((kind, key), c);
        c
    }

    /// One full-width reduce chunk: one element per mesh column, each
    /// folded down a `banks`-tall tree (the four columns run in parallel,
    /// exactly as `trees::reduce` schedules them).
    fn sim_reduce_chunk(&self, banks: usize) -> OpCost {
        let mut mesh = Mesh::new(&self.hw.noc);
        let vals: Vec<Vec<f32>> = (0..self.hw.noc.mesh_cols)
            .map(|c| (0..banks).map(|b| (c + b + 1) as f32).collect())
            .collect();
        trees::reduce(&mut mesh, &vals, StepOp::Add, 0, banks).cost
    }

    /// One full-width broadcast chunk: one scalar per column fanned out to
    /// `banks` banks down the reverse tree.
    fn sim_broadcast_chunk(&self, banks: usize) -> OpCost {
        let mut mesh = Mesh::new(&self.hw.noc);
        let vals = vec![1.0f32; self.hw.noc.mesh_cols];
        trees::broadcast(&mut mesh, &vals, 0, banks).cost
    }

    /// One 2-lane exponential wave through the ISA machine: the Fig 13
    /// Horner program over `LANES` scalars on one bank, path-generation
    /// fused — DRAM endpoints, ALU configuration and the iterated mesh
    /// packets all priced by their own simulators.
    fn sim_exp_wave(&self, rounds: u32) -> OpCost {
        let mut m = Machine::new(&self.hw, self.hw.sram_gang.0);
        let xs: Vec<f32> = (0..LANES).map(|i| 0.2 + 0.1 * i as f32).collect();
        m.write_row(0, 0, &xs);
        let p = RowProgram::exp_program(0, 500, xs.len(), rounds, 1);
        m.run(&p, true)
    }

    /// One 2-lane Newton-sqrt wave on the mesh: per lane an iterated
    /// 3-step chain over two routers with Heron's op mix — one divide
    /// (occupying the iterative divider), one add, one halve per round.
    /// No row-level sqrt program exists, so the wave is driven at the
    /// packet level; timing is value-independent, the payloads are chosen
    /// to stay finite.
    fn sim_sqrt_wave(&self, rounds: u8) -> OpCost {
        let mut mesh = Mesh::new(&self.hw.noc);
        for lane in 0..LANES as usize {
            let ra = RouterId::new(2 * lane, 1);
            let rb = RouterId::new(2 * lane + 1, 1);
            mesh.configure_alu(rb, 0, 1.5, StepOp::Sub, 0.0); // x/y divide
            mesh.configure_alu(ra, 1, 0.5, StepOp::Sub, 0.0); // + x/y term
            mesh.configure_alu(ra, 0, 0.5, StepOp::Sub, 0.0); // halve
            let p = Packet::new(
                PacketType::Scalar,
                RouterId::new(2 * lane, 1),
                2.0,
                vec![
                    PathStep::compute(rb, StepOp::Div),
                    PathStep::compute(ra, StepOp::Add),
                    PathStep::compute(ra, StepOp::Mul),
                ],
            )
            .with_iter(rounds);
            mesh.inject(p);
        }
        mesh.run(1_000_000)
    }

    /// Price the granules for `keys` on up to `jobs` workers and seed the
    /// memo table with them in submission order. Each job drives a fresh,
    /// independent simulator instance (the memo tables are `RefCell` and
    /// deliberately `!Sync`), and the mesh is deterministic, so the seeded
    /// values are bit-identical to what the lazy serial path would have
    /// computed — parallelism changes when a granule is priced, never what
    /// it costs.
    pub fn prefit_keys(&self, keys: &[(NocCollective, u64)], jobs: usize) {
        let mut todo: Vec<(NocCollective, u64)> = Vec::new();
        for k in keys {
            if !self.granules.borrow().contains_key(k) && !todo.contains(k) {
                todo.push(*k);
            }
        }
        let hw = self.hw.clone();
        let costs = crate::util::pool::par_map_indexed(jobs, todo, move |_, (kind, key)| {
            (kind, key, SimulatedNoc::new(&hw).granule(kind, key))
        });
        let mut memo = self.granules.borrow_mut();
        for (kind, key, c) in costs {
            memo.insert((kind, key), c);
        }
    }

    /// One scalar-stream chunk: one in-place divide per column router (the
    /// softmax divide's steady state, four routers wide).
    fn sim_scalar_chunk(&self) -> OpCost {
        let mut mesh = Mesh::new(&self.hw.noc);
        for c in 0..self.hw.noc.mesh_cols {
            let at = RouterId::new(c, 0);
            mesh.configure_alu(at, 0, 2.0, StepOp::Sub, 0.0);
            mesh.inject(Packet::new(
                PacketType::Scalar,
                at,
                1.0,
                vec![PathStep::compute(at, StepOp::Div)],
            ));
        }
        mesh.run(1_000_000)
    }
}

impl NocModel for SimulatedNoc {
    fn fidelity(&self) -> NocFidelity {
        NocFidelity::Simulated
    }

    fn cfg(&self) -> &NocConfig {
        &self.hw.noc
    }

    fn reduce(&self, elems: u64, banks: u64) -> OpCost {
        if elems == 0 || banks <= 1 {
            return OpCost::zero();
        }
        let chunks = elems.div_ceil(self.cols());
        self.granule(NocCollective::Reduce, tree_banks(banks, self.hw.noc.mesh_rows))
            .repeat(chunks)
    }

    fn broadcast(&self, elems: u64, banks: u64) -> OpCost {
        if elems == 0 || banks <= 1 {
            return OpCost::zero();
        }
        let chunks = elems.div_ceil(self.cols());
        self.granule(NocCollective::Broadcast, tree_banks(banks, self.hw.noc.mesh_rows))
            .repeat(chunks)
    }

    fn exp(&self, elems_per_bank: u64, rounds: u64) -> OpCost {
        if elems_per_bank == 0 || rounds == 0 {
            return OpCost::zero();
        }
        // the fused chain iterates in the packet's 4-bit IterNum field;
        // beyond it the wave cannot be represented and the tiers would
        // silently diverge — a hard error in every build, like tree_banks
        assert!(rounds <= 15, "{rounds}-round chain exceeds the 4-bit IterNum field");
        let waves = elems_per_bank.div_ceil(LANES);
        self.granule(NocCollective::Exp, rounds).repeat(waves)
    }

    fn sqrt(&self, elems_per_bank: u64, rounds: u64) -> OpCost {
        if elems_per_bank == 0 || rounds == 0 {
            return OpCost::zero();
        }
        assert!(rounds <= 15, "{rounds}-round chain exceeds the 4-bit IterNum field");
        let waves = elems_per_bank.div_ceil(LANES);
        self.granule(NocCollective::Sqrt, rounds).repeat(waves)
    }

    fn scalar_stream(&self, elems_per_bank: u64) -> OpCost {
        if elems_per_bank == 0 {
            return OpCost::zero();
        }
        let chunks = elems_per_bank.div_ceil(self.cols());
        self.granule(NocCollective::ScalarStream, 0).repeat(chunks)
    }
}

// -------------------------------------------------------------- calibrated

/// Element-count anchors used to fit one correction factor (in granules:
/// one granule and eight granules of the collective's unit volume).
const ANCHOR_GRANULES: [u64; 2] = [1, 8];

/// Granule width in elements for each collective (mesh columns for the
/// chunked collectives, lane width for the iterated ones).
fn granule_elems(kind: NocCollective, cols: u64) -> u64 {
    match kind {
        NocCollective::Reduce | NocCollective::Broadcast | NocCollective::ScalarStream => cols,
        NocCollective::Exp | NocCollective::Sqrt => LANES,
    }
}

/// The structural-parameter key a calibration factor is fitted under —
/// the same normalization the simulated tier applies, so anchors and
/// lookups land on identical granules. Round counts beyond the 4-bit
/// IterNum field (which the simulated tier rejects outright) fit at the
/// 15-round ceiling: the calibrated tier extrapolates the closed form
/// with the nearest simulable correction rather than refusing the query.
pub fn factor_key(kind: NocCollective, param: u64, mesh_rows: usize) -> u64 {
    match kind {
        NocCollective::Reduce | NocCollective::Broadcast => tree_banks(param, mesh_rows),
        NocCollective::Exp | NocCollective::Sqrt => param.clamp(1, 15),
        NocCollective::ScalarStream => 0,
    }
}

/// Tier 2: closed forms, latency-corrected against the simulator.
pub struct CalibratedNoc {
    analytic: AnalyticNoc,
    sim: SimulatedNoc,
    factors: RefCell<HashMap<(NocCollective, u64), f64>>,
}

impl CalibratedNoc {
    pub fn new(hw: &HwConfig) -> Self {
        Self {
            analytic: AnalyticNoc::new(hw.noc.clone()),
            sim: SimulatedNoc::new(hw),
            factors: RefCell::new(HashMap::new()),
        }
    }

    /// The fitted multiplicative latency correction for `kind` at the
    /// normalized structural parameter: geometric mean of sim/analytic
    /// latency ratios over the anchor volumes, computed lazily and
    /// memoized. Falls back to 1.0 (pure analytic) if the ratio
    /// degenerates — a collective both models price at zero.
    pub fn factor(&self, kind: NocCollective, param: u64) -> f64 {
        let key = factor_key(kind, param, self.sim.hw.noc.mesh_rows);
        if let Some(f) = self.factors.borrow().get(&(kind, key)) {
            return *f;
        }
        let unit = granule_elems(kind, self.sim.cols());
        let ratios: Vec<f64> = ANCHOR_GRANULES
            .iter()
            .map(|&g| {
                let elems = g * unit;
                let a = collective_cost(&self.analytic, kind, elems, key).latency_ns;
                let s = collective_cost(&self.sim, kind, elems, key).latency_ns;
                if a > 0.0 { s / a } else { 0.0 }
            })
            .collect();
        let f = geomean(&ratios);
        let f = if f.is_finite() && f > 0.0 { f } else { 1.0 };
        self.factors.borrow_mut().insert((kind, key), f);
        f
    }

    /// The simulator the corrections are fitted against (shared so report
    /// callers don't re-run anchor simulations in a second instance).
    pub fn sim(&self) -> &SimulatedNoc {
        &self.sim
    }

    fn corrected(&self, kind: NocCollective, elems: u64, param: u64) -> OpCost {
        let a = collective_cost(&self.analytic, kind, elems, param);
        if a.latency_ns <= 0.0 {
            return a; // degenerate shape: nothing to correct
        }
        // counts stay analytic — the correction repairs latency, the
        // energy model prices events and already agrees across tiers
        OpCost { latency_ns: a.latency_ns * self.factor(kind, param), counts: a.counts }
    }
}

impl NocModel for CalibratedNoc {
    fn fidelity(&self) -> NocFidelity {
        NocFidelity::Calibrated
    }

    fn cfg(&self) -> &NocConfig {
        self.analytic.cfg()
    }

    fn reduce(&self, elems: u64, banks: u64) -> OpCost {
        self.corrected(NocCollective::Reduce, elems, banks)
    }

    fn broadcast(&self, elems: u64, banks: u64) -> OpCost {
        self.corrected(NocCollective::Broadcast, elems, banks)
    }

    fn exp(&self, elems_per_bank: u64, rounds: u64) -> OpCost {
        self.corrected(NocCollective::Exp, elems_per_bank, rounds)
    }

    fn sqrt(&self, elems_per_bank: u64, rounds: u64) -> OpCost {
        self.corrected(NocCollective::Sqrt, elems_per_bank, rounds)
    }

    fn scalar_stream(&self, elems_per_bank: u64) -> OpCost {
        self.corrected(NocCollective::ScalarStream, elems_per_bank, 0)
    }

    /// Fit every anchor-grid correction now, pricing the anchor granules
    /// on up to `jobs` workers. The fit is a pure function of the hardware
    /// config and the mesh is deterministic, so the warmed factors are
    /// bit-identical to the lazy serial fit — only *when* the anchor
    /// simulations run changes. After this, `factor()` and the
    /// calibration report are pure memo lookups.
    fn prefit(&self, jobs: usize) {
        let rows = self.sim.hw.noc.mesh_rows;
        let mut keys: Vec<(NocCollective, u64)> = Vec::new();
        for (kind, _elems, param) in anchor_grid(&self.sim.hw) {
            let key = (kind, factor_key(kind, param, rows));
            if !keys.contains(&key) {
                keys.push(key);
            }
        }
        self.sim.prefit_keys(&keys, jobs);
        // the granules are warm; the fits themselves are cheap arithmetic
        // over the memo and run serially in grid order
        for (kind, key) in keys {
            let _ = self.factor(kind, key);
        }
    }
}

// ------------------------------------------------------------ calibration report

/// One anchor shape's three-way costing, for the `noc-calibration` figure
/// and the ci.sh self-check gate.
#[derive(Debug, Clone)]
pub struct CalibAnchor {
    pub collective: &'static str,
    /// Human-readable shape, e.g. `elems=32 banks=16`.
    pub shape: String,
    pub analytic_ns: f64,
    pub simulated_ns: f64,
    pub calibrated_ns: f64,
}

impl CalibAnchor {
    /// Raw analytic error: sim/analytic latency ratio (the 0.5–2.0× band
    /// the calibration exists to close).
    pub fn raw_ratio(&self) -> f64 {
        self.simulated_ns / self.analytic_ns
    }

    /// Relative error of the calibrated tier against the simulator.
    pub fn calibrated_err(&self) -> f64 {
        (self.calibrated_ns - self.simulated_ns).abs() / self.simulated_ns
    }
}

/// The anchor grid: every `(collective, volume, structural param)` triple
/// the calibration is fitted and self-checked on. Volumes are in whole
/// granules (`ANCHOR_GRANULES`), so the closed forms' ceil-chunking is
/// exact at every anchor.
pub fn anchor_grid(hw: &HwConfig) -> Vec<(NocCollective, u64, u64)> {
    let cols = hw.noc.mesh_cols as u64;
    let mut grid = Vec::new();
    for banks in [4u64, hw.noc.mesh_rows as u64] {
        for g in ANCHOR_GRANULES {
            grid.push((NocCollective::Reduce, g * cols, banks));
            grid.push((NocCollective::Broadcast, g * cols, banks));
        }
    }
    for rounds in [4u64, 8] {
        for g in ANCHOR_GRANULES {
            grid.push((NocCollective::Exp, g * LANES, rounds));
            grid.push((NocCollective::Sqrt, g * LANES, rounds));
        }
    }
    for g in ANCHOR_GRANULES {
        grid.push((NocCollective::ScalarStream, g * cols, 0));
    }
    grid
}

/// Price every anchor through all three tiers, warming the anchor
/// simulations on up to `jobs` workers first (`jobs <= 1` is the serial
/// path; either way the rows are bit-identical — see
/// [`NocModel::prefit`]). This is the data behind the `noc-calibration`
/// figure; tests and the CI gate assert `calibrated_err() ≤ 0.2` on
/// every row.
pub fn calibration_report(hw: &HwConfig, jobs: usize) -> Vec<CalibAnchor> {
    let analytic = AnalyticNoc::new(hw.noc.clone());
    let cal = CalibratedNoc::new(hw);
    cal.prefit(jobs);
    let sim = cal.sim(); // shared memo: each anchor's mesh run happens once
    anchor_grid(hw)
        .into_iter()
        .map(|(kind, elems, param)| {
            let shape = match kind {
                NocCollective::Reduce | NocCollective::Broadcast => {
                    format!("elems={elems} banks={param}")
                }
                NocCollective::Exp | NocCollective::Sqrt => {
                    format!("elems/bank={elems} rounds={param}")
                }
                NocCollective::ScalarStream => format!("elems/bank={elems}"),
            };
            CalibAnchor {
                collective: kind.label(),
                shape,
                analytic_ns: collective_cost(&analytic, kind, elems, param).latency_ns,
                simulated_ns: collective_cost(sim, kind, elems, param).latency_ns,
                calibrated_ns: collective_cost(&cal, kind, elems, param).latency_ns,
            }
        })
        .collect()
}

/// Declared sanity band for fitted calibration factors. The raw
/// analytic-vs-simulator ratio is historically within 0.5–2.0× at every
/// anchor, so a fit escaping this (deliberately generous) band means the
/// closed forms and the mesh have structurally diverged — the semantic
/// auditor flags it as `aud.calibration-bounds` rather than letting a
/// nonsense correction silently rescale every calibrated latency.
pub const FACTOR_BOUNDS: (f64, f64) = (0.2, 5.0);

/// Every fitted correction factor over the anchor grid, as
/// `(collective label, normalized structural key, factor)` rows in grid
/// order — the input to the auditor's `aud.calibration-bounds` check.
/// `factor()` already falls back to 1.0 on degenerate fits, so every row
/// is the factor calibrated pricing would actually apply.
pub fn calibration_factors(hw: &HwConfig, jobs: usize) -> Vec<(&'static str, u64, f64)> {
    let cal = CalibratedNoc::new(hw);
    cal.prefit(jobs);
    let rows = hw.noc.mesh_rows;
    let mut keys: Vec<(NocCollective, u64)> = Vec::new();
    for (kind, _elems, param) in anchor_grid(hw) {
        let key = (kind, factor_key(kind, param, rows));
        if !keys.contains(&key) {
            keys.push(key);
        }
    }
    keys.into_iter().map(|(kind, key)| (kind.label(), key, cal.factor(kind, key))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwConfig {
        HwConfig::paper()
    }

    #[test]
    fn analytic_tier_is_the_closed_forms_bit_for_bit() {
        let hw = hw();
        let m = AnalyticNoc::new(hw.noc.clone());
        for (elems, banks) in [(4u64, 16u64), (100, 12), (0, 16), (8, 1)] {
            assert_eq!(m.reduce(elems, banks), coll::noc_reduce(elems, banks, &hw.noc));
            assert_eq!(m.broadcast(elems, banks), coll::noc_broadcast(elems, banks, &hw.noc));
        }
        assert_eq!(m.exp(16, 8), coll::noc_exp(16, 8, &hw.noc));
        assert_eq!(m.sqrt(16, 4), coll::noc_sqrt(16, 4, &hw.noc));
        assert_eq!(m.scalar_stream(64), coll::noc_scalar_stream(64, &hw.noc));
    }

    #[test]
    fn simulated_tier_is_deterministic_across_instances() {
        let hw = hw();
        let a = SimulatedNoc::new(&hw);
        let b = SimulatedNoc::new(&hw);
        for (elems, banks) in [(4u64, 16u64), (32, 16), (8, 4)] {
            let x = a.reduce(elems, banks);
            let y = b.reduce(elems, banks);
            assert_eq!(x.latency_ns.to_bits(), y.latency_ns.to_bits());
            assert_eq!(x.counts, y.counts);
            // memoized second ask is bit-identical too
            assert_eq!(a.reduce(elems, banks), x);
        }
        assert_eq!(a.exp(8, 8).latency_ns.to_bits(), b.exp(8, 8).latency_ns.to_bits());
        assert_eq!(a.sqrt(8, 4).latency_ns.to_bits(), b.sqrt(8, 4).latency_ns.to_bits());
    }

    #[test]
    fn simulated_tier_replicates_chunks_linearly() {
        let hw = hw();
        let m = SimulatedNoc::new(&hw);
        let cols = hw.noc.mesh_cols as u64;
        let one = m.reduce(cols, 16).latency_ns;
        let eight = m.reduce(8 * cols, 16).latency_ns;
        assert!(one > 0.0);
        assert!((eight / one - 8.0).abs() < 1e-9, "chunk replication must be exact");
        // a ragged count rounds up to whole chunks, like the closed form
        assert_eq!(m.reduce(cols + 1, 16).latency_ns, m.reduce(2 * cols, 16).latency_ns);
    }

    #[test]
    fn simulated_sqrt_prices_divider_occupancy() {
        let mut fast = hw();
        fast.noc.div_cycles = 0;
        let slow = SimulatedNoc::new(&hw());
        let quick = SimulatedNoc::new(&fast);
        assert!(
            slow.sqrt(2, 8).latency_ns > quick.sqrt(2, 8).latency_ns,
            "the iterative divider must stretch the Newton wave"
        );
    }

    #[test]
    fn degenerate_shapes_are_zero_in_every_tier() {
        let hw = hw();
        for f in NocFidelity::all() {
            let m = build(f, &hw);
            assert_eq!(m.fidelity(), f);
            assert_eq!(m.reduce(0, 16), OpCost::zero(), "{f:?}");
            assert_eq!(m.reduce(64, 1), OpCost::zero(), "{f:?}");
            assert_eq!(m.broadcast(64, 0), OpCost::zero(), "{f:?}");
            assert_eq!(m.exp(0, 8), OpCost::zero(), "{f:?}");
            assert_eq!(m.exp(16, 0), OpCost::zero(), "{f:?}");
            assert_eq!(m.sqrt(16, 0), OpCost::zero(), "{f:?}");
            assert_eq!(m.scalar_stream(0), OpCost::zero(), "{f:?}");
        }
    }

    #[test]
    fn calibrated_matches_simulator_within_20pct_at_every_anchor() {
        let report = calibration_report(&hw(), 1);
        assert!(!report.is_empty());
        for a in &report {
            assert!(a.analytic_ns > 0.0 && a.simulated_ns > 0.0, "{} {}", a.collective, a.shape);
            assert!(
                a.calibrated_err() <= 0.2,
                "{} {}: calibrated {} vs simulated {} (err {:.3})",
                a.collective,
                a.shape,
                a.calibrated_ns,
                a.simulated_ns,
                a.calibrated_err()
            );
        }
    }

    #[test]
    fn calibrated_keeps_analytic_event_counts() {
        let hw = hw();
        let cal = CalibratedNoc::new(&hw);
        let ana = AnalyticNoc::new(hw.noc.clone());
        for (elems, banks) in [(16u64, 16u64), (64, 8)] {
            assert_eq!(cal.reduce(elems, banks).counts, ana.reduce(elems, banks).counts);
        }
        assert_eq!(cal.exp(16, 8).counts, ana.exp(16, 8).counts);
        assert_eq!(cal.sqrt(16, 4).counts, ana.sqrt(16, 4).counts);
    }

    #[test]
    fn parallel_prefit_matches_lazy_serial_fit_bit_for_bit() {
        let hw = hw();
        let warmed = CalibratedNoc::new(&hw);
        warmed.prefit(4);
        let lazy = CalibratedNoc::new(&hw);
        for (kind, _elems, param) in anchor_grid(&hw) {
            assert_eq!(
                warmed.factor(kind, param).to_bits(),
                lazy.factor(kind, param).to_bits(),
                "{kind:?} param={param}"
            );
        }
        // and through the corrected latencies, not just the raw factors
        assert_eq!(warmed.reduce(64, 16).latency_ns.to_bits(), lazy.reduce(64, 16).latency_ns.to_bits());
        assert_eq!(warmed.exp(16, 8).latency_ns.to_bits(), lazy.exp(16, 8).latency_ns.to_bits());
    }

    #[test]
    fn calibration_report_is_jobs_invariant() {
        let hw = hw();
        let serial = calibration_report(&hw, 1);
        let pooled = calibration_report(&hw, 4);
        assert_eq!(serial.len(), pooled.len());
        for (a, b) in serial.iter().zip(&pooled) {
            assert_eq!(a.collective, b.collective);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.analytic_ns.to_bits(), b.analytic_ns.to_bits());
            assert_eq!(a.simulated_ns.to_bits(), b.simulated_ns.to_bits());
            assert_eq!(a.calibrated_ns.to_bits(), b.calibrated_ns.to_bits());
        }
    }

    #[test]
    fn prefit_keys_seeds_the_granule_memo() {
        let hw = hw();
        let sim = SimulatedNoc::new(&hw);
        let keys = [(NocCollective::Reduce, 16u64), (NocCollective::Exp, 8u64), (NocCollective::Exp, 8u64)];
        sim.prefit_keys(&keys, 4);
        assert!(sim.granules.borrow().contains_key(&(NocCollective::Reduce, 16)));
        assert!(sim.granules.borrow().contains_key(&(NocCollective::Exp, 8)));
        // seeded granules are what a cold instance computes
        let cold = SimulatedNoc::new(&hw);
        assert_eq!(
            sim.reduce(4, 16).latency_ns.to_bits(),
            cold.reduce(4, 16).latency_ns.to_bits()
        );
    }

    #[test]
    fn correction_factors_are_memoized_and_reused() {
        let cal = CalibratedNoc::new(&hw());
        let f1 = cal.factor(NocCollective::Reduce, 16);
        let f2 = cal.factor(NocCollective::Reduce, 16);
        assert_eq!(f1.to_bits(), f2.to_bits());
        assert!(f1 > 0.0 && f1.is_finite());
        // non-power-of-two params share the normalized key's factor
        let f3 = cal.factor(NocCollective::Reduce, 12);
        assert_eq!(f1.to_bits(), f3.to_bits());
    }
}
