//! The Curry ALU (paper §4.2, Fig 11D).
//!
//! A unary, single-operand ALU: the flit carries a currying function
//! (`InputOp` + left value), the ALU statically holds the right value in
//! `ArgReg`. `IterArg`/`IterOp` allow the ArgReg itself to be updated after
//! each application (the dynamic-argument mode driving Fig 13's iterative
//! exponential).

use crate::util::bf16::bf16_round;

use super::packet::StepOp;

/// One Curry ALU instance (two live in every router).
#[derive(Debug, Clone)]
pub struct CurryAlu {
    /// The statically-held right operand.
    pub arg_reg: f32,
    /// Update applied to ArgReg when a flit carries IterTag.
    pub iter_op: StepOp,
    pub iter_arg: f32,
    /// Operations executed (for energy/utilization accounting).
    pub ops_executed: u64,
}

impl Default for CurryAlu {
    fn default() -> Self {
        Self { arg_reg: 0.0, iter_op: StepOp::Sub, iter_arg: 0.0, ops_executed: 0 }
    }
}

impl CurryAlu {
    pub fn new() -> Self {
        Self::default()
    }

    /// Configure the static state (NoC_Access Wr at program setup).
    pub fn configure(&mut self, arg_reg: f32, iter_op: StepOp, iter_arg: f32) {
        self.arg_reg = bf16_round(arg_reg);
        self.iter_op = iter_op;
        self.iter_arg = bf16_round(iter_arg);
    }

    /// Write the ArgReg from a flit payload (WrReg path-step bit).
    pub fn write_reg(&mut self, value: f32) {
        self.arg_reg = bf16_round(value);
    }

    /// Apply the flit's InputOp against ArgReg; if `iter_tag`, then update
    /// ArgReg with IterOp/IterArg afterwards. Returns the transformed
    /// payload.
    pub fn apply(&mut self, op: StepOp, value: f32, iter_tag: bool) -> f32 {
        let out = op.apply(value, self.arg_reg);
        self.ops_executed += 1;
        if iter_tag {
            self.arg_reg = self.iter_op.apply(self.arg_reg, self.iter_arg);
            self.ops_executed += 1;
        }
        out
    }
}

/// Reference software implementation of the Fig 13 iterative exponential:
/// Horner-form Taylor series evaluated exactly as the NoC executes it —
/// per iteration: `t *= x; t /= k; t += 1; k -= 1`, ArgReg k counting
/// down from `rounds`, everything rounded through BF16.
pub fn curry_exp(x: f32, rounds: u32) -> f32 {
    let mut t = 1.0f32;
    let mut k = rounds as f32;
    for _ in 0..rounds {
        t = StepOp::Mul.apply(t, x);
        t = StepOp::Div.apply(t, k);
        t = StepOp::Add.apply(t, 1.0);
        k = StepOp::Sub.apply(k, 1.0);
    }
    t
}

/// Range-reduced Curry exponential: `exp(x) = exp(x/2^s)^(2^s)`.
///
/// The Horner chain only converges for |x| ≲ 2 in BF16; the softmax path
/// clamps scores to [-8, 0] and runs the chain on x/4 followed by two
/// squaring passes through the Mul ALU. Must match
/// `python/compile/kernels/ref.curry_exp_rr_ref` exactly.
pub fn curry_exp_rr(x: f32, rounds: u32, squarings: u32) -> f32 {
    let mut t = curry_exp(bf16_round(x) / (1u32 << squarings) as f32, rounds);
    for _ in 0..squarings {
        t = StepOp::Mul.apply(t, t);
    }
    t
}

/// Newton-iteration square root as the NoC executes it:
/// `y ← (y + x/y) / 2`, seeded at `x.max(1.0)`, BF16-rounded per step.
pub fn curry_sqrt(x: f32, rounds: u32) -> f32 {
    if x <= 0.0 {
        return 0.0;
    }
    let mut y = bf16_round(x.max(1.0));
    for _ in 0..rounds {
        let q = StepOp::Div.apply(x, y);
        let s = StepOp::Add.apply(y, q);
        y = StepOp::Div.apply(s, 2.0);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_op_mode() {
        // Fig 11D left: InputVal += ArgReg with ArgReg=2
        let mut alu = CurryAlu::new();
        alu.configure(2.0, StepOp::Add, 0.0);
        assert_eq!(alu.apply(StepOp::Add, 5.0, false), 7.0);
        assert_eq!(alu.arg_reg, 2.0);
    }

    #[test]
    fn iter_op_mode() {
        // Fig 11D right: ArgReg += IterArg → ArgReg goes 2 → 3
        let mut alu = CurryAlu::new();
        alu.configure(2.0, StepOp::Add, 1.0);
        let _ = alu.apply(StepOp::Add, 0.0, true);
        assert_eq!(alu.arg_reg, 3.0);
        assert_eq!(alu.ops_executed, 2);
    }

    #[test]
    fn exp_taylor_converges() {
        for &x in &[0.0f32, 0.25, 0.5, 1.0, -0.5, -1.0] {
            let approx = curry_exp(x, 6);
            let exact = x.exp();
            let rel = ((approx - exact) / exact).abs();
            assert!(rel < 0.01, "x={x}: approx={approx} exact={exact} rel={rel}");
        }
    }

    #[test]
    fn exp_iter_rounds_improve_accuracy() {
        let x = 1.0f32;
        let e3 = (curry_exp(x, 3) - x.exp()).abs();
        let e6 = (curry_exp(x, 6) - x.exp()).abs();
        assert!(e6 <= e3);
    }

    #[test]
    fn exp_rr_converges_over_wide_range() {
        for i in 0..=64 {
            let x = -8.0 + i as f32 * 0.125;
            let approx = curry_exp_rr(x, 8, 2);
            let abs = (approx - x.exp()).abs();
            assert!(abs < 0.02, "x={x}: approx={approx} exp={} abs={abs}", x.exp());
        }
    }

    #[test]
    fn sqrt_newton_converges() {
        for &x in &[0.25f32, 1.0, 2.0, 9.0, 100.0] {
            let approx = curry_sqrt(x, 8);
            let rel = ((approx - x.sqrt()) / x.sqrt()).abs();
            assert!(rel < 0.01, "x={x}: approx={approx} rel={rel}");
        }
        assert_eq!(curry_sqrt(0.0, 8), 0.0);
    }

    #[test]
    fn write_reg_rounds_bf16() {
        let mut alu = CurryAlu::new();
        alu.write_reg(1.0 + f32::EPSILON);
        assert_eq!(alu.arg_reg, 1.0);
    }
}
