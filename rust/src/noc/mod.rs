//! CompAir-NoC: the in-transit-computable network-on-chip (paper §4).
//!
//! * `packet` — the Packet-Level ISA execution format (Table 2);
//! * `curry` — the Curry ALU and reference iterative non-linear functions;
//! * `mesh` — flit-level cycle simulation of the 4×16 per-channel mesh;
//! * `trees` — reduce/broadcast tree schedules over banks (§4.3.3);
//! * `exchange` — RoPE neighbour-swap schedules (§4.3.1);
//! * `area` — the Fig 21 area model (Synopsys DC numbers encoded);
//! * `model` — the fidelity-tiered [`NocModel`] costing interface
//!   (analytic / calibrated / simulated) every system-level cost flows
//!   through.
pub mod area;
pub mod curry;
pub mod exchange;
pub mod mesh;
pub mod model;
pub mod packet;
pub mod trees;

pub use curry::{curry_exp, curry_exp_rr, curry_sqrt, CurryAlu};
pub use mesh::{Delivery, Mesh};
pub use model::{
    calibration_factors, calibration_report, collective_cost, AnalyticNoc, CalibAnchor,
    CalibratedNoc, NocCollective, NocModel, SimulatedNoc, FACTOR_BOUNDS,
};
pub use packet::{Packet, PacketType, PathStep, RouterId, StepOp};
