//! The deterministic pow2 lattice `compair audit` walks.
//!
//! An audit point fixes everything but the workload shape — architecture
//! variant, model, NoC fidelity tier, and mapping mode — and the shape
//! anchors / pow2 chains below fix the shapes each invariant is proved
//! at. The lattice is a pure function of `(filters, deep)`: no
//! randomness, no environment, so `compair audit` covers the identical
//! points however the work is fanned out, and `--jobs N` output is
//! byte-identical to `--jobs 1` by the pool's submission-order merge.
//!
//! The default lattice keeps the gate fast: two models (the test-sized
//! `tiny` and the paper's `llama2-7b`), the analytic and calibrated NoC
//! tiers, static mapping everywhere, plus one auto-mapping point per
//! non-roofline arch on `tiny` (where the search space is exhaustively
//! enumerable). `--deep` widens to the full model zoo, the flit-level
//! simulated tier, and longer monotonicity chains.

use crate::config::{ArchKind, MappingMode, ModelConfig, NocFidelity, Phase, RunConfig};

/// One workload shape an invariant is proved at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeAnchor {
    pub phase: Phase,
    pub batch: usize,
    pub seq_len: usize,
}

impl ShapeAnchor {
    pub fn label(&self) -> String {
        format!("{} b={} s={}", self.phase.label(), self.batch, self.seq_len)
    }
}

/// One (arch × model × fidelity × mapping) lattice point; shapes vary
/// per check inside it.
#[derive(Debug, Clone)]
pub struct AuditPoint {
    pub arch: ArchKind,
    pub model: ModelConfig,
    pub fidelity: NocFidelity,
    pub mapping: MappingMode,
}

impl AuditPoint {
    /// Stable display/context label, e.g. `compair-opt/tiny/calibrated/static`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/{}",
            self.arch.cli_name(),
            self.model.name,
            self.fidelity.label(),
            self.mapping.label()
        )
    }

    /// The base run configuration this point audits (shape fields are
    /// overridden per anchor; `jobs = 1` because audit points already fan
    /// out on the pool and nested pools would break nothing but waste
    /// threads).
    pub fn rc(&self) -> RunConfig {
        let mut rc = RunConfig::new(self.arch, self.model.clone());
        rc.noc_fidelity = self.fidelity;
        rc.mapping = self.mapping;
        rc.jobs = 1;
        rc
    }
}

/// The shape anchors every per-point invariant is proved at.
pub fn shape_anchors(deep: bool) -> Vec<ShapeAnchor> {
    let mut v = vec![
        ShapeAnchor { phase: Phase::Prefill, batch: 1, seq_len: 128 },
        ShapeAnchor { phase: Phase::Prefill, batch: 4, seq_len: 512 },
        ShapeAnchor { phase: Phase::Decode, batch: 1, seq_len: 256 },
        ShapeAnchor { phase: Phase::Decode, batch: 8, seq_len: 1024 },
    ];
    if deep {
        v.push(ShapeAnchor { phase: Phase::Prefill, batch: 16, seq_len: 2048 });
        v.push(ShapeAnchor { phase: Phase::Decode, batch: 64, seq_len: 4096 });
    }
    v
}

/// Pow2 batch chain for the monotonicity check (seq held fixed).
pub fn batch_chain(deep: bool) -> Vec<usize> {
    if deep {
        vec![1, 2, 4, 8, 16, 32]
    } else {
        vec![1, 2, 4, 8]
    }
}

/// Pow2 context chain for the monotonicity check (batch held fixed).
pub fn seq_chain(deep: bool) -> Vec<usize> {
    if deep {
        vec![128, 256, 512, 1024, 2048, 4096]
    } else {
        vec![128, 256, 512, 1024]
    }
}

/// Pow2 KV chain for the iteration-cost monotonicity check.
pub fn kv_chain(deep: bool) -> Vec<usize> {
    if deep {
        vec![256, 512, 1024, 2048, 4096, 8192]
    } else {
        vec![256, 512, 1024, 2048]
    }
}

/// Models the default lattice covers when `--model` is not given.
pub fn default_models(deep: bool) -> Vec<ModelConfig> {
    if deep {
        ModelConfig::zoo()
    } else {
        vec![ModelConfig::tiny(), ModelConfig::by_name("llama2-7b").expect("zoo model")]
    }
}

/// NoC fidelity tiers each (arch, model) pair is audited under.
pub fn fidelities(deep: bool) -> Vec<NocFidelity> {
    if deep {
        NocFidelity::all().to_vec()
    } else {
        vec![NocFidelity::Analytic, NocFidelity::Calibrated]
    }
}

/// Expand the full point lattice for the selected archs and models, in a
/// fixed deterministic order (arch-major, then model, fidelity, mapping).
/// The AttAcc roofline has no NoC tiers, no PIM cost model and no mapping
/// space, so it contributes exactly one report-sanity point per model;
/// auto-mapping points run on `tiny` only, where every variant's search
/// space is exhaustively enumerable and the never-lose re-proof is cheap.
pub fn points(archs: &[ArchKind], models: &[ModelConfig], deep: bool) -> Vec<AuditPoint> {
    let mut pts = Vec::new();
    for &arch in archs {
        for model in models {
            if arch == ArchKind::AttAcc {
                pts.push(AuditPoint {
                    arch,
                    model: model.clone(),
                    fidelity: NocFidelity::Analytic,
                    mapping: MappingMode::Static,
                });
                continue;
            }
            for fid in fidelities(deep) {
                pts.push(AuditPoint {
                    arch,
                    model: model.clone(),
                    fidelity: fid,
                    mapping: MappingMode::Static,
                });
            }
            if model.name == "tiny" {
                pts.push(AuditPoint {
                    arch,
                    model: model.clone(),
                    fidelity: NocFidelity::Analytic,
                    mapping: MappingMode::Auto,
                });
            }
        }
    }
    pts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lattice_is_deterministic_and_pow2() {
        assert_eq!(points(&ArchKind::all(), &default_models(false), false).len(), {
            // 5 PIM archs × 2 models × 2 fidelities + 5 auto points on tiny
            // + 1 AttAcc point per model
            5 * 2 * 2 + 5 + 2
        });
        for chain in [batch_chain(true), seq_chain(true), kv_chain(true)] {
            assert!(chain.windows(2).all(|w| w[1] == 2 * w[0]), "{chain:?} is not pow2");
        }
        let a = points(&ArchKind::all(), &default_models(true), true);
        let b = points(&ArchKind::all(), &default_models(true), true);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label(), y.label());
        }
    }

    #[test]
    fn deep_widens_the_lattice() {
        assert!(shape_anchors(true).len() > shape_anchors(false).len());
        assert!(default_models(true).len() > default_models(false).len());
        assert!(fidelities(true).len() > fidelities(false).len());
    }

    #[test]
    fn attacc_points_are_sanity_only() {
        let pts = points(&[ArchKind::AttAcc], &default_models(false), false);
        assert_eq!(pts.len(), 2);
        for p in pts {
            assert_eq!(p.mapping, MappingMode::Static);
            assert_eq!(p.fidelity, NocFidelity::Analytic);
        }
    }
}
