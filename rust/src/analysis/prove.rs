//! `compair prove` — static proofs over the captured cost-expression IR.
//!
//! `compair check` lints configs and programs; `compair audit` samples
//! semantic invariants at anchor shapes. This pass closes the remaining
//! gap: claims about the *whole* shape space, certified compositionally
//! instead of sampled. The cost pipeline is run once per box corner in
//! capture mode ([`crate::arch::System::run_shape_captured`]), which
//! yields a cost-expression DAG ([`super::cost_ir`]) whose leaves are the
//! closed-form primitives and whose interior nodes are the `OpCost`
//! combinators. Four passes then run over that DAG:
//!
//! * **Units** — every DAG node must carry `Unit::Ns` (leaves enter as
//!   nanoseconds; `then`/`join`/`repeat`/`replicate` all preserve the
//!   unit), and every `CostCounts` field keeps its declared `Count`/
//!   `Bytes` unit through pricing into `Pj` (`prv.unit-mismatch`).
//! * **Monotonicity** — the pre-epilogue phase total must be provably
//!   non-decreasing in every active shape variable, via the monotone-op
//!   whitelist on shape expressions and [`super::cost_ir::node_dir`],
//!   not via sampling (`prv.non-monotone`, `prv.whitelist-escape`).
//! * **Interval bounds** — on a certified cell the box endpoints bound
//!   latency/energy/event totals, so the summary's lo/hi columns are
//!   sound, and count-multiplier chains stay inside the u64 overflow
//!   headroom (`prv.overflow`).
//! * **Pricing coverage** — every `CostCounts` field is priced by the
//!   [`EnergyModel`] exactly once, or is an explicitly declared
//!   bookkeeping counter (`prv.unpriced-counter`, `prv.double-priced`).
//!
//! The soundness anchor is `prv.eval-drift`: at every evaluated corner
//! the captured IR replays bit-for-bit against the concrete pipeline
//! (and the capture-on run against the capture-off run), so the DAG the
//! proofs run over is known to *be* the pipeline, not a model of it.
//!
//! ## Cell subdivision
//!
//! The pipeline takes shape-dependent branches (the attention `pairs >=
//! banks` split, the calibrated NoC factor-key memo). Each branch
//! decision is recorded as a monotone [`Guard`] during capture. The
//! prover subdivides the shape box into cells until all four cell
//! corners agree on the guard vector — guards are monotone in the shape
//! variables, so corner agreement implies the whole cell lowers through
//! one IR — and the root direction is `Inc`/`Constant` in every active
//! variable. A bounded budget caps subdivision; exhaustion degrades to a
//! `prv.guard-unstable` *warning* (bounds then cover certified cells
//! only) rather than an unsound claim. A final pairwise-dominance sweep
//! over every evaluated corner cross-checks the compositional argument
//! against the concrete numbers.

use std::collections::BTreeMap;
use std::rc::Rc;

use super::cost_ir::{
    count_unit, node_dir, replay, Captured, Guard, Node, NodeKind, ShapeVar, Unit, VarBox,
    COUNT_HEADROOM,
};
use super::{CheckReport, Diag};
use crate::arch::System;
use crate::config::{ArchKind, ModelConfig, NocFidelity, Phase, RunConfig};
use crate::energy::model::UNPRICED_BOOKKEEPING;
use crate::energy::EnergyModel;
use crate::sim::CostCounts;
use crate::util::json::{Json, ToJson};

/// Subdivision budget per prove point. The calibrated factor-key guards
/// band the batch axis into a handful of plateaus, so real points
/// certify in well under this; the cap bounds pathological configs.
pub const CELL_BUDGET: usize = 96;

/// Additive-term budget backing [`COUNT_HEADROOM`]: the per-leaf
/// overflow pass proves each leaf contribution `<= u64::MAX / 256`, so
/// the *sum* stays below `u64::MAX` only while a phase total composes
/// at most 256 leaf terms per counter. The walk enforces that too.
pub const LEAF_TERM_BUDGET: usize = 256;

/// One (arch × model × fidelity × phase) point the prover certifies.
/// Unlike an audit point the phase is part of the point: the shape box
/// and the active variables differ between decode and prefill.
#[derive(Debug, Clone)]
pub struct ProvePoint {
    pub arch: ArchKind,
    pub model: ModelConfig,
    pub fidelity: NocFidelity,
    pub phase: Phase,
}

impl ProvePoint {
    /// Stable display/context label, e.g. `compair-opt/tiny/calibrated/decode`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/{}",
            self.arch.cli_name(),
            self.model.name,
            self.fidelity.label(),
            self.phase.label()
        )
    }

    /// The run configuration this point proves over (`jobs = 1`: prove
    /// points already fan out on the pool).
    pub fn rc(&self) -> RunConfig {
        let mut rc = RunConfig::new(self.arch, self.model.clone());
        rc.noc_fidelity = self.fidelity;
        rc.jobs = 1;
        rc
    }
}

/// Models the default prove lattice covers (mirrors the audit default:
/// `tiny` plus the paper's `llama2-7b`).
pub fn default_models() -> Vec<ModelConfig> {
    super::audit_lattice::default_models(false)
}

/// The prove lattice for a filter set: every non-roofline arch, both
/// phases, and the two closed-form NoC tiers. The simulated tier lowers
/// through flit-level `Mono::Opaque` leaves and is certified by `compair
/// audit`'s sampled chains instead; AttAcc is a roofline model with no
/// `System` lowering at all.
pub fn points(archs: &[ArchKind], models: &[ModelConfig]) -> Vec<ProvePoint> {
    let mut pts = Vec::new();
    for &arch in archs {
        if arch == ArchKind::AttAcc {
            continue;
        }
        for model in models {
            for fidelity in [NocFidelity::Analytic, NocFidelity::Calibrated] {
                for phase in [Phase::Decode, Phase::Prefill] {
                    pts.push(ProvePoint { arch, model: model.clone(), fidelity, phase });
                }
            }
        }
    }
    pts
}

/// The shape box a phase is certified over. Axis order follows
/// [`ShapeVar::index`]: `[batch, seq, kv]`; inactive axes are singleton.
pub fn shape_box(phase: Phase) -> VarBox {
    match phase {
        // Decode ranges over (batch, kv-context); seq is per-token.
        Phase::Decode => VarBox { lo: [1, 1, 128], hi: [64, 1, 8192] },
        // Prefill ranges over (batch, prompt length); kv grows with seq.
        Phase::Prefill => VarBox { lo: [1, 128, 1], hi: [8, 4096, 1] },
    }
}

/// The shape variables a phase's box actually ranges over.
pub fn active_vars(phase: Phase) -> [ShapeVar; 2] {
    match phase {
        Phase::Decode => [ShapeVar::Batch, ShapeVar::Kv],
        Phase::Prefill => [ShapeVar::Batch, ShapeVar::Seq],
    }
}

/// Sound interval bounds for one certified prove point, reported as a
/// proof-summary row (not a diagnostic): on every certified cell the IR
/// is non-decreasing in each active variable, so the cell's lo/hi
/// corners bound it and the global extrema are the min/max over cells.
#[derive(Debug, Clone)]
pub struct ProveSummary {
    pub label: String,
    /// Cells processed (certified + split + failed).
    pub cells: usize,
    /// Cells whose guard vector stabilized and whose direction certified.
    pub certified: usize,
    /// Distinct box corners evaluated (capture + replay + drift checks).
    pub corners: usize,
    /// False when the cell budget ran out: bounds cover certified cells
    /// only and a `prv.guard-unstable` warning was emitted.
    pub complete: bool,
    pub lat_lo_ns: f64,
    pub lat_hi_ns: f64,
    pub pj_lo: f64,
    pub pj_hi: f64,
    /// Largest total event count over the box (overflow headroom check
    /// passes at this corner).
    pub events_hi: u64,
}

impl ToJson for ProveSummary {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("point", self.label.as_str())
            .field("cells", self.cells)
            .field("certified", self.certified)
            .field("corners", self.corners)
            .field("complete", self.complete)
            .field("lat_lo_ns", self.lat_lo_ns)
            .field("lat_hi_ns", self.lat_hi_ns)
            .field("pj_lo", self.pj_lo)
            .field("pj_hi", self.pj_hi)
            .field("events_hi", self.events_hi)
    }
}

// ---------------------------------------------------------------------------
// DAG walks (pure: usable on doctored nodes from tests)
// ---------------------------------------------------------------------------

fn walk<'a>(n: &'a Node, path: &mut String, f: &mut impl FnMut(&'a Node, &str)) {
    f(n, path);
    let len = path.len();
    let mut child = |seg: &str, c: &'a Node, f: &mut dyn FnMut(&'a Node, &str)| {
        path.push('/');
        path.push_str(seg);
        walk_dyn(c, path, f);
        path.truncate(len);
    };
    match &n.kind {
        NodeKind::Leaf(_) => {}
        NodeKind::Then(a, b) => {
            child("then.a", a, f);
            child("then.b", b, f);
        }
        NodeKind::Join(a, b) => {
            child("join.a", a, f);
            child("join.b", b, f);
        }
        NodeKind::Repeat(a, _, _) => child("repeat", a, f),
        NodeKind::Replicate(a, _, _) => child("replicate", a, f),
    }
}

fn walk_dyn<'a>(n: &'a Node, path: &mut String, f: &mut dyn FnMut(&'a Node, &str)) {
    walk(n, path, &mut |n, p| f(n, p))
}

fn node_name(n: &Node) -> &'static str {
    match &n.kind {
        NodeKind::Leaf(l) => l.name,
        NodeKind::Then(..) => "then",
        NodeKind::Join(..) => "join",
        NodeKind::Repeat(..) => "repeat",
        NodeKind::Replicate(..) => "replicate",
    }
}

/// Unit-consistency pass. Leaves enter the DAG as `Unit::Ns` and every
/// combinator preserves its operands' unit, so every node must carry
/// `Ns`; any other tag means a combinator produced a unit it cannot
/// (`prv.unit-mismatch`). The `Count`/`Bytes` side of the unit system
/// lives on `CostCounts` fields and is discharged by [`check_pricing`],
/// which proves each of those units is priced into `Pj` exactly once.
pub fn check_units(root: &Node, ctx: &str, rep: &mut CheckReport) {
    walk(root, &mut String::from("root"), &mut |n, path| {
        if n.unit != Unit::Ns {
            rep.push(Diag::error(
                "prv.unit-mismatch",
                format!("{ctx} {path}"),
                format!(
                    "{} node carries unit {} but its combinator can only produce ns",
                    node_name(n),
                    n.unit.label()
                ),
            ));
        }
    });
}

/// Whitelist pass: every shape expression reachable from the DAG — leaf
/// arguments and `repeat`/`replicate` trip counts — must be built from
/// the monotone-op whitelist. An [`SymE::Opaque`] marker anywhere means
/// a value entered the IR that the direction analysis cannot reason
/// about, which would silently weaken every monotonicity certificate;
/// it is reported with full provenance instead (`prv.whitelist-escape`).
pub fn check_whitelist(root: &Node, ctx: &str, rep: &mut CheckReport) {
    let mut escape = |label: &'static str, what: &str, path: &str| {
        rep.push(Diag::error(
            "prv.whitelist-escape",
            format!("{ctx} {path}"),
            format!("{what} uses non-whitelisted opaque expression '{label}'"),
        ));
    };
    walk(root, &mut String::from("root"), &mut |n, path| match &n.kind {
        NodeKind::Leaf(l) => {
            for (i, a) in l.args.iter().enumerate() {
                if let Some(label) = a.find_opaque() {
                    escape(label, &format!("leaf {} arg #{i}", l.name), path);
                }
            }
        }
        NodeKind::Repeat(_, k, _) | NodeKind::Replicate(_, k, _) => {
            if let Some(label) = k.find_opaque() {
                escape(label, "trip count", path);
            }
        }
        _ => {}
    });
}

/// Overflow-headroom pass, run at the hi corner of each certified cell
/// (counts are non-decreasing there, so it is the worst case). Each
/// leaf's count fields, multiplied by the u128 product of every
/// ancestor `repeat`/`replicate` trip count, must stay within
/// [`COUNT_HEADROOM`]; together with the [`LEAF_TERM_BUDGET`] cap on
/// additive leaf terms this proves the u64 accumulation cannot wrap
/// (the runtime `CostCounts` ops saturate + debug-assert as a backstop,
/// this pass makes the shipped configs' totals exact by construction).
pub fn check_overflow(root: &Node, ctx: &str, rep: &mut CheckReport) {
    let mut terms = 0usize;
    overflow_walk(root, 1u128, &mut String::from("root"), ctx, rep, &mut terms);
    if terms > LEAF_TERM_BUDGET {
        rep.push(Diag::error(
            "prv.overflow",
            format!("{ctx} root"),
            format!(
                "{terms} additive leaf terms exceed the {LEAF_TERM_BUDGET}-term budget backing the headroom divisor"
            ),
        ));
    }
}

fn overflow_walk(
    n: &Node,
    mult: u128,
    path: &mut String,
    ctx: &str,
    rep: &mut CheckReport,
    terms: &mut usize,
) {
    match &n.kind {
        NodeKind::Leaf(l) => {
            *terms += 1;
            for (field, v) in l.cost.counts.fields() {
                if v as u128 * mult > COUNT_HEADROOM as u128 {
                    rep.push(Diag::error(
                        "prv.overflow",
                        format!("{ctx} {path}"),
                        format!(
                            "leaf {} contributes {v} x{mult} to '{field}', exceeding the u64 headroom {COUNT_HEADROOM}",
                            l.name
                        ),
                    ));
                }
            }
        }
        NodeKind::Then(a, b) | NodeKind::Join(a, b) => {
            let len = path.len();
            path.push_str("/a");
            overflow_walk(a, mult, path, ctx, rep, terms);
            path.truncate(len);
            path.push_str("/b");
            overflow_walk(b, mult, path, ctx, rep, terms);
            path.truncate(len);
        }
        NodeKind::Repeat(a, _, k) | NodeKind::Replicate(a, _, k) => {
            let len = path.len();
            path.push_str("/x");
            overflow_walk(a, mult.saturating_mul(*k as u128), path, ctx, rep, terms);
            path.truncate(len);
        }
    }
}

/// Compositional monotonicity pass over one (sub)box: the root must be
/// provably non-decreasing in every listed variable via the whitelist
/// direction calculus — no sampling (`prv.non-monotone`). The cell
/// driver calls [`node_dir`] directly so it can subdivide first; this
/// entry point is the single-cell form tests exercise on doctored IR.
pub fn check_monotone(root: &Node, vars: &[ShapeVar], bx: &VarBox, ctx: &str, rep: &mut CheckReport) {
    for &v in vars {
        let d = node_dir(root, v, bx);
        if !d.non_decreasing() {
            rep.push(Diag::error(
                "prv.non-monotone",
                format!("{ctx} root"),
                format!(
                    "phase total is not provably non-decreasing in {} over the cell (direction {:?})",
                    v.label(),
                    d
                ),
            ));
        }
    }
}

/// Replay the captured IR and require bit-for-bit agreement with the
/// concrete totals recorded at capture time — latency, every count
/// field, and the priced dynamic energy (`prv.eval-drift`). This is the
/// soundness anchor: it pins the DAG the other passes reason over to
/// the pipeline that produced it.
pub fn check_replay(cap: &Captured, em: &EnergyModel, ctx: &str, rep: &mut CheckReport) {
    let r = replay(&cap.root);
    if r.latency_ns.to_bits() != cap.total.latency_ns.to_bits()
        || r.counts.fields() != cap.total.counts.fields()
    {
        rep.push(Diag::error(
            "prv.eval-drift",
            ctx.to_string(),
            "replaying the captured IR disagrees bit-for-bit with the recorded pipeline total",
        ));
    } else if em.dynamic(&r.counts).total_pj().to_bits() != cap.dynamic_pj.to_bits() {
        rep.push(Diag::error(
            "prv.eval-drift",
            ctx.to_string(),
            "pricing the replayed counts disagrees bit-for-bit with the recorded dynamic energy",
        ));
    }
}

/// Pricing-coverage pass over a declarative rule set: every
/// `CostCounts` field must be priced by exactly one rule or appear in
/// the bookkeeping allowlist (`prv.unpriced-counter` /
/// `prv.double-priced`), and every rule must name a registered field.
/// [`check_global`] feeds it the shipped [`EnergyModel::pricing_rules`];
/// tests feed doctored rule lists.
pub fn check_pricing(
    rules: &[(&str, &str)],
    bookkeeping: &[&str],
    ctx: &str,
    rep: &mut CheckReport,
) {
    let fields = CostCounts::default().fields();
    for (field, _) in fields {
        let priced: Vec<&str> =
            rules.iter().filter(|(f, _)| *f == field).map(|(_, c)| *c).collect();
        let declared_bookkeeping = bookkeeping.contains(&field);
        let unit = count_unit(field).label();
        if priced.is_empty() && !declared_bookkeeping {
            rep.push(Diag::error(
                "prv.unpriced-counter",
                format!("{ctx} {field}"),
                format!("counter '{field}' ({unit}) escapes the energy model: no pricing rule and not declared bookkeeping"),
            ));
        } else if !priced.is_empty() && declared_bookkeeping {
            rep.push(Diag::error(
                "prv.double-priced",
                format!("{ctx} {field}"),
                format!(
                    "counter '{field}' is declared bookkeeping but priced via '{}'",
                    priced[0]
                ),
            ));
        } else if priced.len() > 1 {
            rep.push(Diag::error(
                "prv.double-priced",
                format!("{ctx} {field}"),
                format!("counter '{field}' is billed {} times ({})", priced.len(), priced.join(", ")),
            ));
        }
    }
    for (f, component) in rules {
        if !fields.iter().any(|(name, _)| name == f) {
            rep.push(Diag::error(
                "prv.unit-mismatch",
                format!("{ctx} {f}"),
                format!("pricing rule '{component}' prices unknown counter '{f}' (no declared unit)"),
            ));
        }
    }
}

/// The point-independent proofs: pricing coverage of the shipped energy
/// model against the declared bookkeeping allowlist. Run once per
/// invocation, not per lattice point.
pub fn check_global() -> CheckReport {
    let mut rep = CheckReport::default();
    let rules = EnergyModel::pricing_rules();
    check_pricing(&rules, UNPRICED_BOOKKEEPING, "energy-model", &mut rep);
    rep.normalize();
    rep
}

// ---------------------------------------------------------------------------
// The cell-subdivision driver
// ---------------------------------------------------------------------------

struct CornerEval {
    root: Rc<Node>,
    guards: Vec<Guard>,
    latency_ns: f64,
    dynamic_pj: f64,
    events: u64,
}

fn cell_label(cell: &VarBox, vars: &[ShapeVar; 2]) -> String {
    let part = |v: ShapeVar| {
        let i = v.index();
        format!("{}={}..{}", v.label(), cell.lo[i], cell.hi[i])
    };
    format!("{} {}", part(vars[0]), part(vars[1]))
}

/// The 4 cell corners, lo-corner first and hi-corner last; inactive
/// axes stay at the (singleton) cell value.
fn corner_pts(cell: &VarBox, vars: &[ShapeVar; 2]) -> [[u64; 3]; 4] {
    let mut out = [[0u64; 3]; 4];
    for (i, slot) in out.iter_mut().enumerate() {
        let mut p = cell.lo;
        if i & 1 != 0 {
            p[vars[0].index()] = cell.hi[vars[0].index()];
        }
        if i & 2 != 0 {
            p[vars[1].index()] = cell.hi[vars[1].index()];
        }
        *slot = p;
    }
    out
}

/// Split the widest active dimension at its midpoint; `None` when the
/// cell is a single point in every active dimension.
fn split_dim(cell: &VarBox, vars: &[ShapeVar; 2]) -> Option<(usize, u64)> {
    let mut best: Option<(usize, u64)> = None;
    for v in vars {
        let i = v.index();
        let w = cell.hi[i] - cell.lo[i];
        if w > 0 && best.map_or(true, |(bi, _)| w > cell.hi[bi] - cell.lo[bi]) {
            best = Some((i, cell.lo[i] + w / 2));
        }
    }
    best
}

fn eval_corner(
    sys: &System,
    phase: Phase,
    vals: [u64; 3],
    m: &crate::mapper::Mapping,
    label: &str,
    rep: &mut CheckReport,
) -> CornerEval {
    let batch = vals[ShapeVar::Batch.index()] as usize;
    let seq = match phase {
        Phase::Decode => vals[ShapeVar::Kv.index()],
        Phase::Prefill => vals[ShapeVar::Seq.index()],
    } as usize;
    let ctx = format!("{label} b={batch} s={seq}");
    let plain = sys.run_shape_mapped(phase, batch, seq, m);
    let (traced, cap) = sys.run_shape_captured(phase, batch, seq, m);
    if plain.latency_ns.to_bits() != traced.latency_ns.to_bits()
        || plain.energy.total_pj().to_bits() != traced.energy.total_pj().to_bits()
    {
        rep.push(Diag::error(
            "prv.eval-drift",
            ctx.clone(),
            "capture-on run disagrees bit-for-bit with the capture-off run",
        ));
    }
    check_replay(&cap, &sys.em, &ctx, rep);
    CornerEval {
        root: cap.root,
        guards: cap.guards,
        latency_ns: cap.total.latency_ns,
        dynamic_pj: cap.dynamic_pj,
        events: cap.total.counts.total_events(),
    }
}

/// Certify one prove point over its whole shape box. Returns the
/// diagnostics plus the proof-summary row with sound interval bounds.
pub fn prove_point(p: &ProvePoint) -> (CheckReport, ProveSummary) {
    prove_point_budget(p, CELL_BUDGET)
}

/// [`prove_point`] with an explicit cell budget. Exposed so the budget-
/// exhaustion path (`prv.guard-unstable`) is testable without a
/// pathological hardware config; production callers use the default.
pub fn prove_point_budget(p: &ProvePoint, budget: usize) -> (CheckReport, ProveSummary) {
    let mut rep = CheckReport::default();
    let label = p.label();
    let sys = System::new(p.rc());
    let m = sys.static_mapping();
    let vars = active_vars(p.phase);
    let mut memo: BTreeMap<[u64; 3], CornerEval> = BTreeMap::new();
    let mut stack = vec![shape_box(p.phase)];
    let mut cells = 0usize;
    let mut certified = 0usize;
    let mut complete = true;
    let (mut lat_lo, mut lat_hi) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut pj_lo, mut pj_hi) = (f64::INFINITY, f64::NEG_INFINITY);
    let mut events_hi = 0u64;

    while let Some(cell) = stack.pop() {
        if cells == budget {
            complete = false;
            rep.push(Diag::warning(
                "prv.guard-unstable",
                format!("{label} [{}]", cell_label(&cell, &vars)),
                format!(
                    "cell budget ({budget}) exhausted before guards stabilized; bounds cover certified cells only"
                ),
            ));
            break;
        }
        cells += 1;
        let pts = corner_pts(&cell, &vars);
        for pt in pts {
            if !memo.contains_key(&pt) {
                let ce = eval_corner(&sys, p.phase, pt, &m, &label, &mut rep);
                memo.insert(pt, ce);
            }
        }
        let guards_stable = pts[1..].iter().all(|pt| memo[pt].guards == memo[&pts[0]].guards);
        let root = memo[&pts[0]].root.clone();
        let dir_ok = vars.iter().all(|&v| node_dir(&root, v, &cell).non_decreasing());
        if guards_stable && dir_ok {
            certified += 1;
            let cctx = format!("{label} [{}]", cell_label(&cell, &vars));
            check_units(&root, &cctx, &mut rep);
            check_whitelist(&root, &cctx, &mut rep);
            check_overflow(&memo[&pts[3]].root, &cctx, &mut rep);
            let (lo, hi) = (&memo[&pts[0]], &memo[&pts[3]]);
            lat_lo = lat_lo.min(lo.latency_ns);
            lat_hi = lat_hi.max(hi.latency_ns);
            pj_lo = pj_lo.min(lo.dynamic_pj);
            pj_hi = pj_hi.max(hi.dynamic_pj);
            events_hi = events_hi.max(hi.events);
        } else if let Some((i, mid)) = split_dim(&cell, &vars) {
            let mut a = cell;
            a.hi[i] = mid;
            let mut b = cell;
            b.lo[i] = mid + 1;
            stack.push(b);
            stack.push(a);
        } else if !guards_stable {
            // A single-point cell has four identical corners, so guards
            // agree by construction; defensive fallback only.
            complete = false;
            rep.push(Diag::warning(
                "prv.guard-unstable",
                format!("{label} [{}]", cell_label(&cell, &vars)),
                "guards differ on an unsplittable cell",
            ));
        } else {
            check_monotone(
                &root,
                &vars,
                &cell,
                &format!("{label} [{}]", cell_label(&cell, &vars)),
                &mut rep,
            );
        }
    }

    // Cross-check the compositional certificate against the concrete
    // corner numbers: componentwise-dominated shapes must not cost more.
    let keys: Vec<[u64; 3]> = memo.keys().copied().collect();
    for (i, a) in keys.iter().enumerate() {
        for b in keys.iter().skip(i + 1) {
            let (p_lo, p_hi) = if a.iter().zip(b).all(|(x, y)| x <= y) {
                (a, b)
            } else if b.iter().zip(a).all(|(x, y)| x <= y) {
                (b, a)
            } else {
                continue;
            };
            let (lo, hi) = (&memo[p_lo], &memo[p_hi]);
            if lo.latency_ns > hi.latency_ns || lo.dynamic_pj > hi.dynamic_pj || lo.events > hi.events
            {
                rep.push(Diag::error(
                    "prv.non-monotone",
                    format!("{label} {p_lo:?} vs {p_hi:?}"),
                    "a dominated shape evaluates to a larger total than its dominator",
                ));
            }
        }
    }

    rep.normalize();
    let corners = memo.len();
    let finite = |v: f64| if v.is_finite() { v } else { 0.0 };
    let summary = ProveSummary {
        label,
        cells,
        certified,
        corners,
        complete,
        lat_lo_ns: finite(lat_lo),
        lat_hi_ns: finite(lat_hi),
        pj_lo: finite(pj_lo),
        pj_hi: finite(pj_hi),
        events_hi,
    };
    (rep, summary)
}

#[cfg(test)]
mod tests {
    use super::super::cost_ir::{LeafNode, Mono, SymE};
    use super::*;
    use crate::sim::OpCost;

    fn lit(v: u64) -> Rc<SymE> {
        Rc::new(SymE::Const(v))
    }

    fn plain_leaf() -> Rc<Node> {
        Node::leaf("test.leaf", vec![lit(4)], Mono::IncAll, OpCost::latency(1.0))
    }

    fn report_of(f: impl FnOnce(&mut CheckReport)) -> CheckReport {
        let mut rep = CheckReport::default();
        f(&mut rep);
        rep.normalize();
        rep
    }

    fn codes(rep: &CheckReport) -> Vec<&'static str> {
        let mut v: Vec<&'static str> = rep.diags.iter().map(|d| d.code).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    #[test]
    fn clean_leaf_passes_all_structural_checks() {
        let n = plain_leaf();
        let bx = VarBox { lo: [1, 1, 1], hi: [8, 1, 1] };
        let rep = report_of(|rep| {
            check_units(&n, "t", rep);
            check_whitelist(&n, "t", rep);
            check_overflow(&n, "t", rep);
            check_monotone(&n, &[ShapeVar::Batch], &bx, "t", rep);
        });
        assert!(rep.is_clean(), "{:?}", rep.diags);
    }

    #[test]
    fn doctored_unit_fires_only_unit_mismatch() {
        let bad = Rc::new(Node {
            unit: Unit::Bytes,
            kind: NodeKind::Leaf(LeafNode {
                name: "test.bad-unit",
                args: vec![],
                mono: Mono::IncAll,
                cost: OpCost::zero(),
            }),
        });
        let root = Rc::new(Node {
            unit: Unit::Ns,
            kind: NodeKind::Then(plain_leaf(), bad),
        });
        let rep = report_of(|rep| {
            check_units(&root, "t", rep);
            check_whitelist(&root, "t", rep);
            check_overflow(&root, "t", rep);
        });
        assert_eq!(codes(&rep), vec!["prv.unit-mismatch"]);
        assert!(rep.diags[0].context.contains("then.b"), "{}", rep.diags[0].context);
    }

    #[test]
    fn doctored_opaque_arg_fires_only_whitelist_escape() {
        let opaque = Rc::new(SymE::Opaque { label: "rng", value: 3 });
        let n = Node::leaf("test.opaque", vec![opaque], Mono::IncAll, OpCost::latency(1.0));
        let rep = report_of(|rep| {
            check_units(&n, "t", rep);
            check_whitelist(&n, "t", rep);
            check_overflow(&n, "t", rep);
        });
        assert_eq!(codes(&rep), vec!["prv.whitelist-escape"]);
        assert!(rep.diags[0].message.contains("rng"));
    }

    #[test]
    fn doctored_opaque_trip_count_fires_whitelist_escape() {
        let root = Rc::new(Node {
            unit: Unit::Ns,
            kind: NodeKind::Repeat(
                plain_leaf(),
                Rc::new(SymE::Opaque { label: "env", value: 2 }),
                2,
            ),
        });
        let rep = report_of(|rep| check_whitelist(&root, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.whitelist-escape"]);
    }

    #[test]
    fn doctored_multiplier_chain_fires_only_overflow() {
        let mut c = OpCost::latency(1.0);
        c.counts.dram_mac = 1 << 40;
        let leaf = Node::leaf("test.hot", vec![], Mono::IncAll, c);
        let k = 1u64 << 30;
        let root = Rc::new(Node {
            unit: Unit::Ns,
            kind: NodeKind::Repeat(leaf, lit(k), k),
        });
        let rep = report_of(|rep| {
            check_units(&root, "t", rep);
            check_whitelist(&root, "t", rep);
            check_overflow(&root, "t", rep);
        });
        assert_eq!(codes(&rep), vec!["prv.overflow"]);
        assert!(rep.diags[0].message.contains("dram_mac"));
    }

    #[test]
    fn doctored_decreasing_construct_fires_only_non_monotone() {
        // floor_div(8, batch) is Dec in batch over [1,8]: a whitelisted
        // expression, but the wrong direction for a cost argument.
        let e = Rc::new(SymE::FloorDiv(lit(8), Rc::new(SymE::Var(ShapeVar::Batch))));
        let n = Node::leaf("test.dec", vec![e], Mono::IncAll, OpCost::latency(1.0));
        let bx = VarBox { lo: [1, 1, 1], hi: [8, 1, 1] };
        let rep = report_of(|rep| {
            check_units(&n, "t", rep);
            check_whitelist(&n, "t", rep);
            check_monotone(&n, &[ShapeVar::Batch], &bx, "t", rep);
        });
        assert_eq!(codes(&rep), vec!["prv.non-monotone"]);
    }

    #[test]
    fn doctored_opaque_leaf_model_is_not_certifiable() {
        let n = Node::leaf(
            "test.sim",
            vec![Rc::new(SymE::Var(ShapeVar::Batch))],
            Mono::Opaque,
            OpCost::latency(1.0),
        );
        let bx = VarBox { lo: [1, 1, 1], hi: [8, 1, 1] };
        let rep = report_of(|rep| check_monotone(&n, &[ShapeVar::Batch], &bx, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.non-monotone"]);
    }

    #[test]
    fn doctored_total_fires_only_eval_drift() {
        let em = EnergyModel::new(&crate::config::HwConfig::paper().sram, 1.0);
        let root = plain_leaf();
        let good = Captured {
            root: root.clone(),
            guards: vec![],
            total: replay(&root),
            dynamic_pj: em.dynamic(&replay(&root).counts).total_pj(),
        };
        let rep = report_of(|rep| check_replay(&good, &em, "t", rep));
        assert!(rep.is_clean(), "{:?}", rep.diags);

        let mut bad = good;
        bad.total.latency_ns += 1.0;
        let rep = report_of(|rep| check_replay(&bad, &em, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.eval-drift"]);
    }

    #[test]
    fn doctored_energy_fires_eval_drift() {
        let em = EnergyModel::new(&crate::config::HwConfig::paper().sram, 1.0);
        let root = plain_leaf();
        let cap = Captured {
            root: root.clone(),
            guards: vec![],
            total: replay(&root),
            dynamic_pj: em.dynamic(&replay(&root).counts).total_pj() + 1.0,
        };
        let rep = report_of(|rep| check_replay(&cap, &em, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.eval-drift"]);
    }

    #[test]
    fn doctored_rules_fire_unpriced_and_double_priced() {
        let shipped = EnergyModel::pricing_rules();
        // drop one rule -> exactly prv.unpriced-counter
        let missing: Vec<(&str, &str)> =
            shipped.iter().filter(|(f, _)| *f != "dram_mac").map(|&(f, c)| (f, c)).collect();
        let rep = report_of(|rep| check_pricing(&missing, UNPRICED_BOOKKEEPING, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.unpriced-counter"]);
        assert!(rep.diags[0].context.contains("dram_mac"));

        // duplicate one rule -> exactly prv.double-priced
        let mut doubled: Vec<(&str, &str)> = shipped.iter().map(|&(f, c)| (f, c)).collect();
        doubled.push(("dram_mac", "dram_pj"));
        let rep = report_of(|rep| check_pricing(&doubled, UNPRICED_BOOKKEEPING, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.double-priced"]);

        // price a declared bookkeeping counter -> prv.double-priced
        let mut priced_bk: Vec<(&str, &str)> = shipped.iter().map(|&(f, c)| (f, c)).collect();
        priced_bk.push(("sram_access", "sram_pj"));
        let rep = report_of(|rep| check_pricing(&priced_bk, UNPRICED_BOOKKEEPING, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.double-priced"]);

        // rule naming an unknown counter -> prv.unit-mismatch
        let mut unknown: Vec<(&str, &str)> = shipped.iter().map(|&(f, c)| (f, c)).collect();
        unknown.push(("warp_divergence", "gpu_pj"));
        let rep = report_of(|rep| check_pricing(&unknown, UNPRICED_BOOKKEEPING, "t", rep));
        assert_eq!(codes(&rep), vec!["prv.unit-mismatch"]);
    }

    #[test]
    fn shipped_energy_model_proves_clean() {
        let rep = check_global();
        assert!(rep.is_clean(), "{:?}", rep.diags);
    }

    #[test]
    fn lattice_skips_attacc_and_simulated() {
        let pts = points(&ArchKind::all(), &default_models());
        assert!(!pts.is_empty());
        for p in &pts {
            assert_ne!(p.arch, ArchKind::AttAcc);
            assert_ne!(p.fidelity, NocFidelity::Simulated);
        }
        // arch-major deterministic order, both phases present
        assert!(pts.iter().any(|p| p.phase == Phase::Decode));
        assert!(pts.iter().any(|p| p.phase == Phase::Prefill));
    }

    #[test]
    fn prove_point_certifies_a_shipped_config() {
        let p = ProvePoint {
            arch: ArchKind::CompAirOpt,
            model: ModelConfig::tiny(),
            fidelity: NocFidelity::Calibrated,
            phase: Phase::Decode,
        };
        let (rep, sum) = prove_point(&p);
        assert_eq!(rep.errors(), 0, "{:?}", rep.diags);
        assert!(sum.certified > 0);
        assert!(sum.corners >= 4);
        assert!(sum.lat_lo_ns > 0.0 && sum.lat_lo_ns <= sum.lat_hi_ns);
        assert!(sum.pj_lo > 0.0 && sum.pj_lo <= sum.pj_hi);
        assert!(sum.events_hi > 0);
    }
}
