//! Static analysis: the `compair check` verification passes.
//!
//! Three passes over the artifacts the rest of the crate *executes* —
//! Row-Level ISA programs ([`isa_lint`]), operator placements
//! ([`map_check`]) and run/hardware/model configurations
//! ([`config_check`]) — each reporting through one shared diagnostics
//! type ([`Diag`]) so the CLI, the `Engine::check` facade, the CI gate
//! and the debug-assert hooks in `Machine::run` / the mapper scorer all
//! speak the same language. Every diagnostic carries a stable code from
//! [`ALL_CODES`]; `tests/static_analysis.rs` keeps a seeded-defect
//! corpus proving each code can actually fire.
//!
//! The passes are pure functions of their inputs: no I/O, no
//! interpreter state, no randomness. Reports are normalized to a
//! deterministic order, so `compair check --format json` is
//! byte-identical however the work is fanned out.

pub mod audit;
pub mod audit_lattice;
pub mod config_check;
pub mod cost_ir;
pub mod isa_lint;
pub mod map_check;
pub mod prove;

use crate::config::HwConfig;
use crate::config::SramGang;
use crate::isa::row::{RowProgram, ALL_BANKS};
use crate::util::json::{Json, ToJson};
use crate::util::table::Table;

/// How bad a diagnostic is. `Error` means the artifact would misbehave
/// (or panic) if executed; `Warning` flags a suspicious-but-runnable
/// condition (dead stores, capacity overflows the analytic tiers price
/// as streaming rather than reject).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn label(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One finding: a stable machine-readable `code`, a severity, a
/// `context` naming where it was found (instruction index, slot label,
/// config field) and a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diag {
    pub severity: Severity,
    pub code: &'static str,
    pub context: String,
    pub message: String,
}

impl Diag {
    pub fn error(code: &'static str, context: impl Into<String>, message: impl Into<String>) -> Diag {
        Diag { severity: Severity::Error, code, context: context.into(), message: message.into() }
    }

    pub fn warning(
        code: &'static str,
        context: impl Into<String>,
        message: impl Into<String>,
    ) -> Diag {
        Diag { severity: Severity::Warning, code, context: context.into(), message: message.into() }
    }

    /// One-line rendering (the debug-assert hooks panic with these).
    pub fn render(&self) -> String {
        format!("{} [{}] {}: {}", self.severity.label(), self.code, self.context, self.message)
    }
}

impl ToJson for Diag {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("severity", self.severity.label())
            .field("code", self.code)
            .field("context", self.context.as_str())
            .field("message", self.message.as_str())
    }
}

/// Every lint code a pass can emit. The negative-corpus test asserts
/// each one fires on at least one seeded defect, so a code can't rot
/// into dead configuration.
pub const ALL_CODES: &[&str] = &[
    // isa_lint
    "isa.addr-bounds",
    "isa.mask-range",
    "isa.mask-empty",
    "isa.len-zero",
    "isa.exchange-shape",
    "isa.use-before-def",
    "isa.dead-store",
    "isa.lane-overflow",
    "isa.alu-conflict",
    "isa.div-occupancy",
    "isa.sram-order",
    "isa.sram-capacity",
    "isa.count-drift",
    // map_check
    "map.illegal-placement",
    "map.nonlinear-on-pim",
    "map.sram-capacity",
    "map.kv-capacity",
    "map.weight-capacity",
    // config_check
    "cfg.mesh-banks",
    "cfg.head-divisibility",
    "cfg.kv-dtype",
    "cfg.shape-positive",
    "cfg.tp-devices",
    "cfg.tp-remainder",
    "cfg.fabric-devices",
    "cfg.gang-macros",
    "cfg.voltage-corner",
    "cfg.flit-capacity",
    "cfg.slo-sanity",
    "cfg.disagg-split",
    // audit (semantic invariants over the cost pipeline)
    "aud.non-finite",
    "aud.negative",
    "aud.unit-range",
    "aud.op-conservation",
    "aud.energy-conservation",
    "aud.bytes-conservation",
    "aud.monotonic",
    "aud.cache-coherence",
    "aud.never-lose",
    "aud.fidelity-band",
    "aud.calibration-bounds",
    // prove (static proofs over the captured cost-expression IR)
    "prv.unit-mismatch",
    "prv.non-monotone",
    "prv.whitelist-escape",
    "prv.guard-unstable",
    "prv.overflow",
    "prv.unpriced-counter",
    "prv.double-priced",
    "prv.eval-drift",
];

/// One-line meaning per registered code, behind `compair check
/// --list-codes` / `--explain <code>`. Total coverage of [`ALL_CODES`] is
/// enforced by `tests/audit.rs` (`descriptions_cover_every_registered_code`).
pub fn code_description(code: &str) -> Option<&'static str> {
    Some(match code {
        // isa_lint
        "isa.addr-bounds" => "an instruction addresses past the bank memory",
        "isa.mask-range" => "a bank mask sets bits beyond the channel's banks",
        "isa.mask-empty" => "a bank mask selects no banks (the op is a no-op)",
        "isa.len-zero" => "an instruction has a zero element length",
        "isa.exchange-shape" => "a NoC exchange's offset/group/len shape is inconsistent",
        "isa.use-before-def" => "a bank address range is read before any store reaches it",
        "isa.dead-store" => "a store is fully overwritten before any read",
        "isa.lane-overflow" => "a fused chain needs more router columns than the mesh has",
        "isa.alu-conflict" => "two chained steps bind the same router ALU with different args",
        "isa.div-occupancy" => "back-to-back divides oversubscribe the iterative divider",
        "isa.sram-order" => "an SRAM gang compute precedes the write that loads it",
        "isa.sram-capacity" => "an SRAM write exceeds the gang's weight capacity",
        "isa.count-drift" => "statically derived flit/op counts drift from the closed forms",
        // map_check
        "map.illegal-placement" => "a slot is mapped to an engine that cannot execute it",
        "map.nonlinear-on-pim" => "a non-linear op is placed on a PIM MAC engine",
        "map.sram-capacity" => "an FC projection oversubscribes SRAM gang residency (streams)",
        "map.kv-capacity" => "the KV cache at max context exceeds device DRAM (streams)",
        "map.weight-capacity" => "per-device weights exceed device DRAM capacity (streams)",
        // config_check
        "cfg.mesh-banks" => "mesh rows != banks per channel",
        "cfg.head-divisibility" => "model head count does not divide the model dimension",
        "cfg.kv-dtype" => "bookkept kv_bytes_per_token disagrees with the geometric value",
        "cfg.shape-positive" => "a workload shape field (batch/seq/gen) is zero",
        "cfg.tp-devices" => "tensor-parallel degree exceeds the device count",
        "cfg.tp-remainder" => "devices do not split evenly into tp groups",
        "cfg.fabric-devices" => "device count exceeds the CXL fabric's ports",
        "cfg.gang-macros" => "SRAM gang shape does not tile the per-bank macros",
        "cfg.voltage-corner" => "SRAM voltage is outside the characterized corners",
        "cfg.flit-capacity" => "flit width cannot carry the 72-bit packet encoding",
        "cfg.slo-sanity" => "a scenario SLO is zero, non-finite, or inverted",
        "cfg.disagg-split" => "a disaggregated split has an empty pool or wrong total",
        // audit
        "aud.non-finite" => "a report carries a NaN or infinite number",
        "aud.negative" => "a latency/energy/throughput field is negative",
        "aud.unit-range" => "a fraction/utilization/attainment is outside [0, 1]",
        "aud.op-conservation" => "per-op costs do not compose to the phase total",
        "aud.energy-conservation" => "energy breakdown disagrees with independently re-priced counts",
        "aud.bytes-conservation" => "bytes in != bytes out across a collective or KV migration",
        "aud.monotonic" => "cost decreased when the workload grew along a pow2 chain",
        "aud.cache-coherence" => "a memoizing cost model diverges from the uncached reference",
        "aud.never-lose" => "an auto-mapped cost exceeds the static mapping's",
        "aud.fidelity-band" => "a calibrated anchor is outside its gated band of the simulator",
        "aud.calibration-bounds" => "a fitted NoC factor is non-finite or outside FACTOR_BOUNDS",
        // prove
        "prv.unit-mismatch" => "a cost-IR node carries a unit its combinator cannot produce",
        "prv.non-monotone" => "latency/energy is not provably non-decreasing in a shape variable",
        "prv.whitelist-escape" => "a shape expression uses an op outside the monotone whitelist",
        "prv.guard-unstable" => "cell subdivision exhausted its budget before guards stabilized",
        "prv.overflow" => "a count multiplier chain exceeds the u64 overflow headroom",
        "prv.unpriced-counter" => "a CostCounts field escapes the EnergyModel pricing rules",
        "prv.double-priced" => "a CostCounts field is priced by more than one rule (double billed)",
        "prv.eval-drift" => "replaying the captured IR disagrees with the concrete pipeline",
        _ => return None,
    })
}

/// An accumulated, deterministically ordered set of diagnostics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CheckReport {
    pub diags: Vec<Diag>,
}

impl CheckReport {
    pub fn push(&mut self, d: Diag) {
        debug_assert!(ALL_CODES.contains(&d.code), "unregistered lint code {}", d.code);
        self.diags.push(d);
    }

    pub fn extend(&mut self, other: CheckReport) {
        self.diags.extend(other.diags);
    }

    /// Deterministic order — errors first, then by (code, context,
    /// message) — with exact duplicates collapsed. Every public
    /// entry point returns a normalized report.
    pub fn normalize(&mut self) {
        self.diags.sort();
        self.diags.dedup();
    }

    pub fn errors(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// No errors (warnings are allowed — the debug-assert hooks and the
    /// CI gate key off this).
    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    pub fn has_code(&self, code: &str) -> bool {
        self.diags.iter().any(|d| d.code == code)
    }

    /// One diagnostic per line (panic payloads, terse logs).
    pub fn render_brief(&self) -> String {
        self.diags.iter().map(Diag::render).collect::<Vec<_>>().join("\n")
    }

    /// The human-readable diagnostics table for `--format text`.
    pub fn render_table(&self, title: &str) -> String {
        let mut t = Table::new(title, &["severity", "code", "context", "message"]);
        for d in &self.diags {
            t.row(&[
                d.severity.label().to_string(),
                d.code.to_string(),
                d.context.clone(),
                d.message.clone(),
            ]);
        }
        t.render()
    }
}

impl ToJson for CheckReport {
    fn to_json(&self) -> Json {
        Json::obj()
            .field("errors", self.errors())
            .field("warnings", self.warnings())
            .field("ok", self.is_clean())
            .field("diags", Json::arr(self.diags.iter().map(Diag::to_json)))
    }
}

/// Lint the shipped Row-Level programs: the exponential kernel at the
/// NoC calibration anchor shapes, with its input row declared
/// initialized, plus the static flit/op count cross-check against the
/// `arch/collective.rs` closed forms at the same anchors. This is the
/// arch-independent slice of `compair check` (the programs do not vary
/// per architecture variant).
pub fn check_isa_programs(hw: &HwConfig) -> CheckReport {
    let mut rep = CheckReport::default();
    // mirror noc::model::ANCHOR_GRANULES × the exp-round grid the
    // collective tests pin: (elems, rounds)
    for (len, rounds) in [(2usize, 8u32), (16, 8), (16, 4)] {
        let prog = RowProgram::exp_program(0, 4096, len, rounds, ALL_BANKS);
        let opts = isa_lint::LintOptions::with_inputs(vec![(0, len)]);
        rep.extend(isa_lint::lint(&prog, hw, SramGang::In256Out16, &opts));
        rep.extend(isa_lint::exp_count_crosscheck(len, rounds, hw, 0.25));
    }
    rep.normalize();
    rep
}
