//! Static validator for operator→engine [`Mapping`]s.
//!
//! Placement legality is an error (the lowering would execute an op on
//! an engine with no implementation for it — non-linears have no MAC-lane
//! lowering, attention must live with the KV cache). Capacity findings
//! are warnings: the analytic tiers price oversubscribed weights/KV as
//! streaming traffic rather than rejecting them, but an operator reading
//! the report should know the hardware would be reloading.

use crate::config::RunConfig;
use crate::mapper::{supported_placements, Mapping, Placement, Slot};

use super::{CheckReport, Diag};

/// The FC projection shapes of a model, `(name, out_dim, in_dim)`.
fn fc_projections(rc: &RunConfig) -> Vec<(&'static str, usize, usize)> {
    let m = &rc.model;
    let d = m.d_model;
    let kv = 2 * m.n_kv_heads * m.d_head();
    let mut v = vec![("q", d, d), ("kv", kv, d), ("o", d, d), ("up", m.d_ffn, d), ("down", d, m.d_ffn)];
    if m.gated_ffn {
        v.push(("gate", m.d_ffn, d));
    }
    v
}

/// Check one mapping against the run's architecture, model and hardware.
/// The report is normalized before returning.
pub fn check_mapping(rc: &RunConfig, m: &Mapping) -> CheckReport {
    let mut rep = CheckReport::default();

    // 1. Placement legality per slot.
    for slot in Slot::all() {
        let p = m.get(slot);
        if supported_placements(slot, rc.arch).contains(&p) {
            continue;
        }
        let nonlinear =
            matches!(slot, Slot::Softmax | Slot::Rope | Slot::RmsNorm | Slot::Activation);
        if nonlinear && matches!(p, Placement::DramPim | Placement::SramPim) {
            rep.push(Diag::error(
                "map.nonlinear-on-pim",
                slot.label(),
                format!(
                    "{} placed on {}: exp/rsqrt have no MAC-lane lowering on PIM banks",
                    slot.label(),
                    p.label()
                ),
            ));
        } else {
            rep.push(Diag::error(
                "map.illegal-placement",
                slot.label(),
                format!("{} is not a supported engine for {} on {}", p.label(), slot.label(), rc.arch.label()),
            ));
        }
    }

    // 2. Device capacity: weights + KV at the configured max context must
    //    fit the per-device DRAM (warning: the simulator prices overflow
    //    as streaming, but real hardware would be swapping). Degenerate
    //    model shapes are config_check's findings, not capacity ones.
    if rc.model.n_heads == 0 || rc.model.n_kv_heads == 0 {
        rep.normalize();
        return rep;
    }
    let capacity = rc.hw.dram.device_capacity_bytes();
    let tp = rc.tp.max(1);
    let weight_bytes = rc.model.total_fc_params() * 2 / tp as u64;
    if weight_bytes > capacity {
        rep.push(Diag::warning(
            "map.weight-capacity",
            "weights",
            format!(
                "{} weight bytes per device (tp {tp}) exceed the {} per-device DRAM capacity",
                weight_bytes, capacity
            ),
        ));
    }
    let context = rc.seq_len + rc.gen_len;
    let kv_bytes = (rc.batch * context) as u64 * rc.model.kv_bytes_per_token() / tp as u64;
    if kv_bytes.saturating_add(weight_bytes) > capacity {
        rep.push(Diag::warning(
            "map.kv-capacity",
            "kv-cache",
            format!(
                "KV cache needs {kv_bytes} bytes per device at batch {} x context {context} \
                 on top of {weight_bytes} weight bytes, exceeding the {capacity}-byte device",
                rc.batch
            ),
        ));
    }

    // 3. SRAM gang residency: an FC slot on SRAM-PIM whose per-bank weight
    //    share exceeds the gang's resident capacity runs under the reload
    //    policy (priced, but worth surfacing).
    let (gi, go) = rc.sram_gang.shape(&rc.hw.sram);
    let resident_bytes = gi * go * 2;
    let banks = rc.hw.dram.banks_per_device().max(1);
    for (name, out, inp) in fc_projections(rc) {
        let slot = match name {
            "q" => Slot::FcQ,
            "kv" => Slot::FcKv,
            "o" => Slot::FcO,
            "up" => Slot::FcUp,
            "gate" => Slot::FcGate,
            _ => Slot::FcDown,
        };
        if m.get(slot) != Placement::SramPim {
            continue;
        }
        let per_bank = out * inp * 2 / tp / banks;
        if per_bank > resident_bytes {
            rep.push(Diag::warning(
                "map.sram-capacity",
                slot.label(),
                format!(
                    "{per_bank} weight bytes per bank exceed the {go}x{gi} gang's \
                     {resident_bytes} resident bytes: the projection streams via weight reload"
                ),
            ));
        }
    }

    rep.normalize();
    rep
}

/// The error-severity subset of placement legality, as a cheap predicate
/// for the mapper search (capacity warnings must not veto candidates).
pub fn placement_legal(rc: &RunConfig, m: &Mapping) -> bool {
    m.is_valid_for(rc.arch)
}
