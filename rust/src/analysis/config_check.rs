//! Cross-field consistency checks over `RunConfig` / `HwConfig` /
//! `ModelConfig` (plus cluster splits and scenario SLOs).
//!
//! Errors are invariants the rest of the crate assumes and would panic
//! or silently misprice without (mesh rows = banks per channel, head
//! divisibility, tensor-parallel degree vs devices). Warnings flag
//! configurations that run but probably aren't what the operator meant
//! (idle devices from a non-dividing TP degree, out-of-corner voltage).

use crate::config::{RunConfig, Voltage};
use crate::coordinator::cluster::ClusterConfig;
use crate::workload::{Scenario, Slo};

use super::{CheckReport, Diag};

/// Check one run configuration. Pure; normalized report.
pub fn check_run(rc: &RunConfig) -> CheckReport {
    let mut rep = CheckReport::default();
    let hw = &rc.hw;
    let m = &rc.model;

    // The Row-Level ISA identifies mesh rows with banks: every bank owns
    // one router row (Fig 12). The translator and interpreter both index
    // routers by bank.
    if hw.noc.mesh_rows != hw.dram.banks_per_channel {
        rep.push(Diag::error(
            "cfg.mesh-banks",
            "hw.noc.mesh_rows",
            format!(
                "mesh has {} router rows but the channel has {} banks; \
                 bank-indexed packet paths would fall off the mesh",
                hw.noc.mesh_rows, hw.dram.banks_per_channel
            ),
        ));
    }

    // Model head geometry: d_head and the GQA group are integer divisions
    // the op shapes rely on.
    if m.n_heads == 0 || m.d_model % m.n_heads != 0 {
        rep.push(Diag::error(
            "cfg.head-divisibility",
            "model.n_heads",
            format!("d_model {} is not divisible into {} heads", m.d_model, m.n_heads),
        ));
    }
    if m.n_kv_heads == 0 || (m.n_heads > 0 && m.n_heads % m.n_kv_heads != 0) {
        rep.push(Diag::error(
            "cfg.head-divisibility",
            "model.n_kv_heads",
            format!("{} heads do not group evenly over {} KV heads", m.n_heads, m.n_kv_heads),
        ));
    }

    // kv_bytes_per_token must equal 2 bytes/elem x K+V x heads x layers;
    // truncating head division makes the bookkept KV footprint drift from
    // the geometric one.
    if m.n_heads > 0 {
        let exact = 2.0 * 2.0 * m.n_kv_heads as f64 * (m.d_model as f64 / m.n_heads as f64)
            * m.n_layers as f64;
        let booked = m.kv_bytes_per_token() as f64;
        if (booked - exact).abs() > 1e-6 {
            rep.push(Diag::error(
                "cfg.kv-dtype",
                "model.kv_bytes_per_token",
                format!(
                    "bookkept {booked} bytes/token vs {exact} from BF16 x 2 x {} KV heads \
                     x d_head x {} layers",
                    m.n_kv_heads, m.n_layers
                ),
            ));
        }
    }

    // Shape positivity: zero batch/seq/gen degenerate into div-by-zero
    // waves and empty phases downstream.
    if rc.batch == 0 || rc.seq_len == 0 || rc.gen_len == 0 {
        rep.push(Diag::error(
            "cfg.shape-positive",
            "run.batch/seq_len/gen_len",
            format!(
                "batch {}, seq_len {}, gen_len {} must all be positive",
                rc.batch, rc.seq_len, rc.gen_len
            ),
        ));
    }

    // Parallelism: tp devices must exist on the fabric.
    if rc.tp == 0 || rc.devices == 0 || rc.tp > rc.devices {
        rep.push(Diag::error(
            "cfg.tp-devices",
            "run.tp",
            format!("tp {} needs at least that many of the {} devices", rc.tp, rc.devices),
        ));
    } else if rc.devices % rc.tp != 0 {
        rep.push(Diag::warning(
            "cfg.tp-remainder",
            "run.devices",
            format!("{} devices leave {} idle at tp {}", rc.devices, rc.devices % rc.tp, rc.tp),
        ));
    }
    if rc.devices > hw.cxl.devices {
        rep.push(Diag::error(
            "cfg.fabric-devices",
            "run.devices",
            format!("run wants {} devices but the CXL fabric hosts {}", rc.devices, hw.cxl.devices),
        ));
    }

    // The gang must tile exactly onto the bank's macros: a logical shape
    // that doesn't use macro_inputs x macro_outputs x macros_per_bank
    // MACs would mis-price every SRAM pass.
    let (gi, go) = rc.sram_gang.shape(&hw.sram);
    let macro_macs = hw.sram.macro_inputs * hw.sram.macro_outputs * hw.sram.macros_per_bank;
    if gi * go != macro_macs {
        rep.push(Diag::error(
            "cfg.gang-macros",
            "run.sram_gang",
            format!(
                "gang shape {go}x{gi} ({} MACs) does not tile the bank's {} macro MACs",
                gi * go,
                macro_macs
            ),
        ));
    }

    // Voltage outside the published corners is clamped by the model —
    // the configured value silently isn't the simulated one.
    let v = hw.sram.voltage.0;
    if !(Voltage::MIN..=Voltage::MAX).contains(&v) {
        rep.push(Diag::warning(
            "cfg.voltage-corner",
            "hw.sram.voltage",
            format!(
                "{v} V is outside the published [{}, {}] corners and will be clamped",
                Voltage::MIN,
                Voltage::MAX
            ),
        ));
    }

    // A fused-chain packet needs 72 flit bits (4 path steps + header);
    // narrower flits can't carry the paper's path encoding.
    if hw.noc.flit_bits < 72 {
        rep.push(Diag::warning(
            "cfg.flit-capacity",
            "hw.noc.flit_bits",
            format!(
                "{}-bit flits cannot carry the 72-bit fused-chain path encoding \
                 (multi-flit packets are not modeled)",
                hw.noc.flit_bits
            ),
        ));
    }

    rep.normalize();
    rep
}

/// SLO sanity for one class: targets must be positive, and time-to-first-
/// token at or above per-token latency (a TTFT tighter than one decode
/// step is unmeetable by construction).
pub fn check_slo(slo: &Slo, context: &str) -> CheckReport {
    let mut rep = CheckReport::default();
    if slo.ttft_ns == 0 || slo.tpot_ns == 0 {
        rep.push(Diag::error(
            "cfg.slo-sanity",
            context,
            "SLO targets must be positive (use Slo::relaxed() for best-effort)".to_string(),
        ));
    } else if slo.ttft_ns < slo.tpot_ns {
        rep.push(Diag::warning(
            "cfg.slo-sanity",
            context,
            format!(
                "TTFT target {} ns is tighter than the per-token target {} ns",
                slo.ttft_ns, slo.tpot_ns
            ),
        ));
    }
    rep.normalize();
    rep
}

/// SLO sanity across the built-in scenario zoo (arch-independent; run
/// once per `compair check`).
pub fn check_scenarios() -> CheckReport {
    let mut rep = CheckReport::default();
    for sc in Scenario::all() {
        for class in &sc.classes {
            rep.extend(check_slo(&class.slo, &format!("scenario {} class {}", sc.name, class.name)));
        }
    }
    rep.normalize();
    rep
}

/// Cluster split sanity: wraps `ClusterConfig::validate` into the
/// diagnostics framework (empty disagg pools, zero replicas).
pub fn check_cluster(cfg: &ClusterConfig) -> CheckReport {
    let mut rep = CheckReport::default();
    if let Err(e) = cfg.validate() {
        rep.push(Diag::error("cfg.disagg-split", "cluster", e));
    }
    rep.normalize();
    rep
}
